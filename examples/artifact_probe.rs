//! Artifact micro-probe (§Perf tooling): compile ONE HLO artifact on the
//! deployment PJRT runtime and time its execution — used to sweep tile
//! shapes against the runtime that actually serves them (jax's bundled
//! XLA and the deployment xla_extension can differ wildly; see
//! EXPERIMENTS.md §Perf).
//!
//!     cargo run --release --example artifact_probe -- <file.hlo.txt> B M D [reps]

use falkon::runtime::exe::{literal_from_f32, literal_scalar, Exe};
use falkon::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    anyhow::ensure!(args.len() >= 4, "usage: artifact_probe <hlo> B M D [reps]");
    let path = std::path::PathBuf::from(&args[0]);
    let (b, m, d): (usize, usize, usize) =
        (args[1].parse()?, args[2].parse()?, args[3].parse()?);
    let reps: usize = args.get(4).map(|s| s.parse()).transpose()?.unwrap_or(5);

    let t = Timer::start();
    let exe = Exe::compile_file(&path, "probe")?;
    println!("compile: {:.2}s", t.elapsed_s());

    let x = literal_from_f32(&vec![0.1; b * d], &[b, d])?;
    let c = literal_from_f32(&vec![0.2; m * d], &[m, d])?;
    let u = literal_from_f32(&vec![0.3; m], &[m])?;
    let v = literal_from_f32(&vec![0.0; b], &[b])?;
    let mask = literal_from_f32(&vec![1.0; b], &[b])?;
    let p = literal_scalar(1.0);
    let argv = [&x, &c, &u, &v, &mask, &p];

    let _ = exe.call1_f32(&argv)?; // warmup
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t = Timer::start();
        let _ = exe.call1_f32(&argv)?;
        best = best.min(t.elapsed_s());
    }
    let evals = (b * m * 2) as f64;
    println!(
        "execute: {:.2}ms  ({:.1} GFLOP/s)",
        best * 1e3,
        evals * (2 * d + 6) as f64 / best / 1e9
    );
    Ok(())
}

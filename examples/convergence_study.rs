//! Convergence study (Thm. 1-2 in action): traces the gap between the
//! FALKON iterate and the exact Nyström estimator across CG iterations,
//! for preconditioned vs un-preconditioned CG vs gradient descent —
//! reproducing the paper's core algorithmic claim that the Nyström
//! preconditioner turns O(√n) iterations into O(log n).
//!
//!     cargo run --release --example convergence_study

use falkon::baselines::{nystrom_cg, nystrom_direct, nystrom_gd};
use falkon::data::synth;
use falkon::falkon::{fit_with_callback, CgOptions, FalkonConfig};
use falkon::kernels::Kernel;
use falkon::linalg::vec_ops::rel_diff;
use falkon::runtime::Engine;
use falkon::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let n = 8000;
    let m = 256;
    let sigma = 1.5;
    let lam = 1.0 / (n as f64).sqrt(); // the paper's λ = 1/√n regime
    let t_max = 40;

    let mut rng = Rng::new(2);
    let mut data = synth::smooth_regression(&mut rng, n, 5, 0.05);
    // zero-mean targets so centered and uncentered solvers coincide
    let ybar = falkon::linalg::vec_ops::mean(&data.y);
    for v in &mut data.y {
        *v -= ybar;
    }
    let engine = Engine::xla_default().unwrap_or_else(|e| {
        eprintln!("falling back to rust engine: {e}");
        Engine::rust()
    });
    println!("engine: {}  n={n} M={m} λ={lam:.4}", engine.name());

    // ground truth: exact Nyström solution with the same centers (seed 9)
    let direct = nystrom_direct::fit(
        &engine, &data.x, &data.y, Kernel::Gaussian, sigma, lam, m, &mut Rng::new(9),
    )?;
    let target = direct.predict(&engine, &data.x)?;

    let gap = |alpha: &[f64], centers: &falkon::linalg::Mat| -> f64 {
        let p = engine
            .predict(Kernel::Gaussian, &data.x, centers, alpha, sigma)
            .unwrap();
        rel_diff(&p, &target)
    };

    // FALKON (preconditioned CG)
    let mut falkon_curve: Vec<Vec<f64>> = Vec::new();
    let cfg = FalkonConfig {
        sigma,
        lam,
        m,
        t: t_max,
        seed: 9,
        eps: 1e-12,
        center_y: false, // compare against the (uncentered) exact Nyström solve
        ..Default::default()
    };
    let mut cb = |_k: usize, alpha: &[f64]| falkon_curve.push(alpha.to_vec());
    let model = fit_with_callback(&engine, &data.x, &data.y, &cfg, Some(&mut cb))?;
    assert_eq!(model.centers.data, direct.centers.data, "same centers");

    // plain CG (no preconditioner)
    let mut cg_curve: Vec<Vec<f64>> = Vec::new();
    let mut cb2 = |_k: usize, a: &[f64]| cg_curve.push(a.to_vec());
    let cg = nystrom_cg::fit(
        &engine,
        &data.x,
        &data.y,
        Kernel::Gaussian,
        sigma,
        lam,
        m,
        CgOptions { t_max, tol: 0.0 },
        &mut Rng::new(9),
        Some(&mut cb2),
    )?;

    // gradient descent
    let mut gd_curve: Vec<Vec<f64>> = Vec::new();
    let mut cb3 = |_k: usize, a: &[f64]| gd_curve.push(a.to_vec());
    let gd = nystrom_gd::fit_with_callback(
        &engine,
        &data.x,
        &data.y,
        Kernel::Gaussian,
        sigma,
        lam,
        m,
        t_max,
        &mut Rng::new(9),
        Some(&mut cb3),
    )?;

    println!("\nrelative prediction gap to the exact Nyström solution:");
    println!("{:>5} {:>14} {:>14} {:>14}", "iter", "FALKON", "plain CG", "grad descent");
    let mut falkon_hits = None;
    let mut cg_hits = None;
    for k in (0..t_max).step_by(2) {
        let f = gap(&falkon_curve[k], &model.centers);
        let c = gap(&cg_curve[k], &cg.centers);
        let g = gap(&gd_curve[k], &gd.centers);
        println!("{:>5} {f:>14.3e} {c:>14.3e} {g:>14.3e}", k + 1);
        if f < 1e-4 && falkon_hits.is_none() {
            falkon_hits = Some(k + 1);
        }
        if c < 1e-4 && cg_hits.is_none() {
            cg_hits = Some(k + 1);
        }
    }
    let f_final = gap(falkon_curve.last().unwrap(), &model.centers);
    let c_final = gap(cg_curve.last().unwrap(), &cg.centers);
    let g_final = gap(gd_curve.last().unwrap(), &gd.centers);
    println!(
        "\nafter {t_max} iterations: FALKON {f_final:.3e} | plain CG {c_final:.3e} | GD {g_final:.3e}"
    );
    println!(
        "iterations to 1e-4 gap: FALKON {:?}, plain CG {:?}",
        falkon_hits, cg_hits
    );

    anyhow::ensure!(
        f_final < 1e-4,
        "FALKON should reach the Nyström solution within {t_max} iters (gap {f_final})"
    );
    anyhow::ensure!(
        f_final < c_final && f_final < g_final,
        "preconditioning should dominate: {f_final} vs cg {c_final} / gd {g_final}"
    );
    println!("\nOK: the preconditioner delivers the paper's exponential convergence.");
    Ok(())
}

//! End-to-end driver (the MillionSongs experiment, Table 2, at laptop
//! scale): trains FALKON on the `songs` analogue (d = 90 regression)
//! through the full AOT stack, logs the test-error curve across CG
//! iterations, and compares against the exact Nyström direct solver —
//! demonstrating the paper's claim that a handful of preconditioned CG
//! iterations reach the quality of the direct O(nM²) solve.
//!
//!     cargo run --release --example millionsongs_scale [-- --n 50000]
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use falkon::baselines::nystrom_direct;
use falkon::bench::{fmt_secs, BenchArgs, Table};
use falkon::data::{synth, ZScore};
use falkon::falkon::{fit_with_callback, FalkonConfig};
use falkon::kernels::Kernel;
use falkon::metrics;
use falkon::runtime::Engine;
use falkon::util::rng::Rng;
use falkon::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::from_env();
    let n = args.usize_or("--n", 50_000);
    let m = args.usize_or("--m", 2048);

    let mut rng = Rng::new(1);
    println!("generating songs analogue: n={n}, d=90 …");
    let data = synth::songs(&mut rng, n);
    let (mut train, mut test) = data.split(0.2, &mut rng);
    ZScore::normalize(&mut train, &mut test);

    let engine = Engine::xla_default().unwrap_or_else(|e| {
        eprintln!("falling back to rust engine: {e}");
        Engine::rust()
    });
    println!("engine: {}  n_train={}  M={m}", engine.name(), train.n());

    // paper's MillionSongs setup: gaussian kernel, tiny λ (1e-6)
    let config = FalkonConfig {
        kernel: Kernel::Gaussian,
        sigma: 6.0,
        lam: 1e-6,
        m,
        t: 20,
        seed: 11,
        ..Default::default()
    };

    // trace test error per CG iteration (cheap: M² per iteration + one
    // blocked predict on a 2k subsample of the test set)
    let probe_n = test.n().min(2000);
    let probe_x = test.x.slice_rows(0, probe_n);
    let probe_y = &test.y[..probe_n];
    let mut curve: Vec<(usize, f64)> = Vec::new();
    // the callback stores per-iteration alphas; predictions happen after
    // the fit (the engine is busy inside it)
    let mut alphas: Vec<(usize, Vec<f64>)> = Vec::new();
    let mut cb = |k: usize, alpha: &[f64]| alphas.push((k, alpha.to_vec()));

    let timer = Timer::start();
    let model = fit_with_callback(&engine, &train.x, &train.y, &config, Some(&mut cb))?;
    let fit_s = timer.elapsed_s();
    println!("\nfit: {} ({} CG iters)\n{}", fmt_secs(fit_s), model.cg_iters, model.phases.report());

    println!("test-error curve (MSE on {probe_n}-row probe):");
    for (k, alpha) in &alphas {
        if *k % 2 == 1 || *k == model.cg_iters {
            let mut preds =
                engine.predict(config.kernel, &probe_x, &model.centers, alpha, config.sigma)?;
            for p in &mut preds {
                *p += model.y_offset; // callback alphas solve the centered problem
            }
            let mse = metrics::mse(&preds, probe_y);
            println!("  iter {k:>3}: MSE {mse:.5}");
            curve.push((*k, mse));
        }
    }

    // full test metrics
    let preds = model.predict(&engine, &test.x)?;
    let mse = metrics::mse(&preds, &test.y);
    let rel = metrics::relative_error(&preds, &test.y);
    println!("\nFALKON  : MSE {mse:.5}  rel.err {rel:.3e}  time {}", fmt_secs(fit_s));

    // baseline: exact Nyström direct solve, same M
    let t2 = Timer::start();
    let direct = nystrom_direct::fit(
        &engine,
        &train.x,
        &train.y,
        Kernel::Gaussian,
        6.0,
        1e-6,
        m,
        &mut Rng::new(11),
    )?;
    let direct_s = t2.elapsed_s();
    let dp = direct.predict(&engine, &test.x)?;
    let dmse = metrics::mse(&dp, &test.y);
    println!(
        "Nyström : MSE {dmse:.5}  rel.err {:.3e}  time {}",
        metrics::relative_error(&dp, &test.y),
        fmt_secs(direct_s)
    );

    let mut table = Table::new(
        "MillionSongs analogue (paper Table 2 row shape)",
        &["algorithm", "MSE", "rel. error", "time"],
    );
    table.row(&[
        "FALKON".into(),
        format!("{mse:.4}"),
        format!("{rel:.3e}"),
        fmt_secs(fit_s),
    ]);
    table.row(&[
        "Nyström direct".into(),
        format!("{dmse:.4}"),
        format!("{:.3e}", metrics::relative_error(&dp, &test.y)),
        fmt_secs(direct_s),
    ]);
    table.print();

    // the paper's qualitative claims, asserted:
    anyhow::ensure!(
        mse <= dmse * 1.05,
        "FALKON ({mse}) should match the direct Nyström solution ({dmse})"
    );
    let (first_mse, last_mse) = (curve.first().unwrap().1, curve.last().unwrap().1);
    anyhow::ensure!(
        last_mse <= first_mse,
        "error curve should be non-increasing ({first_mse} -> {last_mse})"
    );
    println!("\nOK: FALKON matches the direct solve; error decays across iterations.");
    Ok(())
}

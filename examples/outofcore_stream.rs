//! Out-of-core walkthrough: write a CSV "on disk" dataset, stream-convert
//! it to the chunked binary shard format, train FALKON with a chunk
//! budget far smaller than the dataset, and bulk-score the shard — the
//! full feature matrix is never resident after the CSV is written.
//!
//!     cargo run --release --example outofcore_stream
//!
//! The same flow is available from the CLI:
//!
//!     falkon convert --input data.csv --output data.shard
//!     falkon train   --dataset data.shard --stream --chunk-rows 8192 --engine rust
//!     falkon predict --model model.json --dataset data.shard

use falkon::data::shard::{self, ShardSource};
use falkon::data::stream_text::CsvSource;
use falkon::falkon::{fit_source, FalkonConfig};
use falkon::metrics;
use falkon::runtime::Engine;
use falkon::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let dir = std::env::temp_dir();
    let csv_path = dir.join("falkon_example_stream.csv");
    let shard_path = dir.join("falkon_example_stream.shard");
    let csv_path = csv_path.to_string_lossy().into_owned();
    let shard_path = shard_path.to_string_lossy().into_owned();

    // 1. a 20k-row CSV (label first, like MillionSongs distributions)
    let mut rng = Rng::new(0);
    let (n, d) = (20_000usize, 6usize);
    let mut csv = String::from("y,f0,f1,f2,f3,f4,f5\n");
    for _ in 0..n {
        let row = rng.normals(d);
        let y: f64 = row.iter().map(|v| (v * 1.3).sin()).sum::<f64>() + 0.05 * rng.normal();
        let cells: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
        csv.push_str(&format!("{y},{}\n", cells.join(",")));
    }
    std::fs::write(&csv_path, &csv)?;
    println!("wrote {csv_path} ({} KiB)", csv.len() / 1024);

    // 2. stream-convert: the CSV is parsed lazily, 2048 rows at a time,
    //    and lands as shard records — O(chunk) memory end to end
    let mut lazy = CsvSource::open(&csv_path, true, 2048)?;
    let rows = shard::write_source(&shard_path, &mut lazy)?;
    println!("converted {rows} rows -> {shard_path}");

    // 3. out-of-core fit: chunk budget = n/10 rows; every CG iteration
    //    re-streams the shard instead of holding X in memory
    let chunk_rows = n / 10;
    let source = ShardSource::open(&shard_path, chunk_rows)?;
    println!(
        "fitting with chunk budget {chunk_rows} rows (~{} KiB resident of {} KiB total)",
        chunk_rows * d * 8 / 1024,
        n * d * 8 / 1024
    );
    let engine = Engine::rust();
    let config = FalkonConfig {
        sigma: 2.0,
        lam: 1e-4,
        m: 512,
        t: 12,
        seed: 7,
        ..Default::default()
    };
    let model = fit_source(&engine, Box::new(source), &config)?;
    println!("fit done\n{}", model.phases.report());

    // 4. bulk-score the shard (streamed too) and report training error
    let mut eval = ShardSource::open(&shard_path, chunk_rows)?;
    let score = falkon::serve::predict_source(&model, &engine, &mut eval)?;
    let mse = metrics::mse(&score.preds, &score.targets);
    let var = falkon::linalg::vec_ops::variance(&score.targets);
    println!(
        "train MSE = {mse:.4} (target variance {var:.4}, R² = {:.3}); \
         peak resident chunk = {} KiB",
        1.0 - mse / var,
        score.max_chunk_bytes / 1024
    );
    anyhow::ensure!(mse < var, "model failed to beat the mean predictor");

    let _ = std::fs::remove_file(&csv_path);
    let _ = std::fs::remove_file(&shard_path);
    Ok(())
}

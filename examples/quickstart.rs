//! Quickstart: fit FALKON on a synthetic regression problem through the
//! full three-layer stack (Pallas-kernel HLO artifacts → PJRT → rust
//! coordinator) and evaluate on held-out data.
//!
//!     make artifacts && cargo run --release --example quickstart

use falkon::data::{synth, ZScore};
use falkon::falkon::{fit, FalkonConfig};
use falkon::metrics;
use falkon::runtime::Engine;
use falkon::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. data: 20k-point smooth regression problem, 80/20 split, z-scored
    let mut rng = Rng::new(0);
    let data = synth::smooth_regression(&mut rng, 20_000, 10, 0.1);
    let (mut train, mut test) = data.split(0.2, &mut rng);
    ZScore::normalize(&mut train, &mut test);

    // 2. engine: the AOT XLA artifacts if built, else the pure-rust path
    let engine = Engine::xla_default().unwrap_or_else(|e| {
        eprintln!("falling back to rust engine: {e}");
        Engine::rust()
    });
    println!("engine: {}", engine.name());

    // 3. FALKON in the paper's theoretical regime: λ = 1/√n, M ≈ √n·log n
    //    (rounded to a compiled artifact size), t ≈ log n iterations.
    let n = train.n() as f64;
    let config = FalkonConfig {
        sigma: 2.5,
        lam: 1.0 / n.sqrt(),
        m: 1024,
        t: 15,
        seed: 7,
        ..Default::default()
    };
    let model = fit(&engine, &train.x, &train.y, &config)?;
    println!(
        "fit done: {} CG iterations\n{}",
        model.cg_iters,
        model.phases.report()
    );

    // 4. evaluate
    let preds = model.predict(&engine, &test.x)?;
    let mse = metrics::mse(&preds, &test.y);
    let var = falkon::linalg::vec_ops::variance(&test.y);
    println!(
        "test MSE = {mse:.4}  (target variance {var:.4}, R² = {:.3})",
        1.0 - mse / var
    );
    anyhow::ensure!(mse < var, "model failed to beat the mean predictor");
    Ok(())
}

//! Serving example: train a FALKON model, stand up the dynamic-batching
//! prediction server (the L3 request path: rust + compiled artifacts,
//! no python), fire a multi-client request storm, and report
//! latency/throughput plus batching efficiency.
//!
//!     cargo run --release --example serve_predictions

use falkon::data::{synth, ZScore};
use falkon::falkon::{fit, FalkonConfig};
use falkon::runtime::Engine;
use falkon::serve::{ServeConfig, Server};
use falkon::util::rng::Rng;
use falkon::util::timer::Timer;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    // train a small model on the SUSY analogue
    let mut rng = Rng::new(4);
    let data = synth::susy(&mut rng, 10_000);
    let (mut train, mut test) = data.split(0.2, &mut rng);
    ZScore::normalize(&mut train, &mut test);
    let engine_name = if Engine::xla_default().is_ok() { "xla" } else { "rust" };
    let engine = Engine::by_name(engine_name, 1)?;
    let config = FalkonConfig {
        sigma: 4.0,
        lam: 1e-6,
        m: 512,
        t: 15,
        seed: 1,
        ..Default::default()
    };
    println!("training on {} rows ({} engine)…", train.n(), engine.name());
    let model = fit(&engine, &train.x, &train.y, &config)?;
    let d = model.centers.cols;
    drop(engine); // the server thread builds its own

    // serve under a storm of concurrent clients
    let server = Server::start(
        model,
        ServeConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(2),
            engine: engine_name.into(),
            ..Default::default()
        },
    )?;
    let clients = 8;
    let per_client = 400;
    println!("firing {clients} clients × {per_client} requests…");
    let timer = Timer::start();
    let latencies: Vec<f64> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let h = server.handle();
                let rows = &test.x;
                s.spawn(move || {
                    let mut lats = Vec::with_capacity(per_client);
                    for i in 0..per_client {
                        let row = rows.row((c * per_client + i) % rows.rows).to_vec();
                        let t = Timer::start();
                        h.predict(row).unwrap();
                        lats.push(t.elapsed_s());
                    }
                    lats
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    let wall = timer.elapsed_s();
    let stats = server.stop();

    let mut lats = latencies;
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |q: f64| lats[((lats.len() as f64 - 1.0) * q) as usize] * 1e3;
    let total = (clients * per_client) as f64;
    println!(
        "\nthroughput: {:.0} req/s over {:.2}s  (d={d})",
        total / wall,
        wall
    );
    println!(
        "latency ms: p50={:.2}  p90={:.2}  p99={:.2}  max={:.2}",
        pct(0.5),
        pct(0.9),
        pct(0.99),
        pct(1.0)
    );
    println!(
        "batching: {} batches, mean batch size {:.1}",
        stats.batches, stats.mean_batch
    );
    anyhow::ensure!(stats.requests == clients as u64 * per_client as u64);
    anyhow::ensure!(
        stats.mean_batch > 1.5,
        "dynamic batching should coalesce concurrent clients (got {:.2})",
        stats.mean_batch
    );
    println!("\nOK: dynamic batching coalesced the request storm.");
    Ok(())
}

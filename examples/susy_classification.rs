//! SUSY / HIGGS (Table 3) at laptop scale: binary classification with
//! FALKON on the physics-like analogues, reporting c-err and AUC — the
//! same metrics as the paper — plus a comparison against the exact-KRR
//! gold standard on a subsample (KRR at full n would be O(n³)).
//!
//!     cargo run --release --example susy_classification [-- --n 40000]

use falkon::baselines::krr;
use falkon::bench::{fmt_secs, BenchArgs, Table};
use falkon::data::{synth, ZScore};
use falkon::falkon::{fit, FalkonConfig};
use falkon::kernels::Kernel;
use falkon::metrics;
use falkon::runtime::Engine;
use falkon::util::rng::Rng;
use falkon::util::timer::Timer;

fn run_dataset(
    engine: &Engine,
    name: &str,
    n: usize,
    sigma: f64,
    lam: f64,
    m: usize,
    table: &mut Table,
) -> anyhow::Result<()> {
    let mut rng = Rng::new(3);
    let data = synth::by_name(name, &mut rng, n).unwrap();
    let (mut train, mut test) = data.split(0.2, &mut rng);
    ZScore::normalize(&mut train, &mut test);

    let config = FalkonConfig {
        kernel: Kernel::Gaussian,
        sigma,
        lam,
        m,
        t: 20,
        seed: 5,
        ..Default::default()
    };
    let timer = Timer::start();
    let model = fit(engine, &train.x, &train.y, &config)?;
    let fit_s = timer.elapsed_s();
    let preds = model.predict(engine, &test.x)?;
    let cerr = metrics::binary_error(&preds, &test.y);
    let auc = metrics::auc(&preds, &test.y);
    println!(
        "{name}: FALKON  n={} c-err={:.2}% AUC={auc:.4} in {}",
        train.n(),
        100.0 * cerr,
        fmt_secs(fit_s)
    );
    table.row(&[
        name.into(),
        "FALKON".into(),
        format!("{}", train.n()),
        format!("{:.2}%", 100.0 * cerr),
        format!("{auc:.4}"),
        fmt_secs(fit_s),
    ]);

    // exact KRR on a 3k subsample — the accuracy anchor (paper compares
    // against full solvers run on clusters; our anchor is subsampled KRR)
    let sub = train.select(&Rng::new(7).choose(train.n(), 3000.min(train.n())));
    let t2 = Timer::start();
    let krr_model = krr::fit(&sub.x, &sub.y, Kernel::Gaussian, sigma, lam)?;
    let krr_s = t2.elapsed_s();
    let kp = krr_model.predict(&test.x);
    table.row(&[
        name.into(),
        "KRR (3k sub)".into(),
        format!("{}", sub.n()),
        format!("{:.2}%", 100.0 * metrics::binary_error(&kp, &test.y)),
        format!("{:.4}", metrics::auc(&kp, &test.y)),
        fmt_secs(krr_s),
    ]);

    // FALKON on the full n must beat/match KRR on the subsample
    let krr_auc = metrics::auc(&kp, &test.y);
    anyhow::ensure!(
        auc >= krr_auc - 0.01,
        "{name}: FALKON AUC {auc:.4} below subsampled-KRR {krr_auc:.4}"
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::from_env();
    let n = args.usize_or("--n", 40_000);
    let engine = Engine::xla_default().unwrap_or_else(|e| {
        eprintln!("falling back to rust engine: {e}");
        Engine::rust()
    });
    println!("engine: {}\n", engine.name());

    let mut table = Table::new(
        "SUSY / HIGGS analogues (paper Table 3 row shape)",
        &["dataset", "algorithm", "n", "c-err", "AUC", "time"],
    );
    // paper settings: SUSY σ=4 λ=1e-6 M=1e4; HIGGS σ≈5 λ=1e-8 M=1e5
    // (M rounded to compiled sizes at this scale)
    run_dataset(&engine, "susy", n, 4.0, 1e-6, 1024, &mut table)?;
    run_dataset(&engine, "higgs", n, 5.0, 1e-8, 2048, &mut table)?;
    table.print();
    println!("OK: FALKON at full n matches or beats subsampled exact KRR.");
    Ok(())
}

"""AOT pipeline: lower every manifest entry to HLO *text* under artifacts/.

Run once at build time (``make artifacts``); the rust runtime then loads
``artifacts/manifest.json``, compiles the HLO it needs lazily via PJRT,
and executes it on the training / request path. Python is never imported
at runtime.

Interchange format is HLO **text**, not a serialized HloModuleProto: the
image's xla_extension 0.5.1 rejects jax>=0.5 protos (64-bit instruction
ids); the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Every function is lowered with ``return_tuple=True`` — the rust side
unwraps the tuple (``to_tuple1`` / ``to_tuple2``).

Usage:
    cd python && python -m compile.aot --out-dir ../artifacts
    python -m compile.aot --filter knm_matvec_gaussian   # subset rebuild
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import manifest, model

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """jax lowering -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _shape(*dims) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(dims, F32)


def signature(e: dict) -> tuple[list, list[str], list[str]]:
    """(input ShapeDtypeStructs, input names, output names) for an entry.

    This fixes the argument order contract with the rust runtime — change
    it only together with rust/src/runtime/executable.rs.
    """
    b, m, d = e["b"], e["m"], e["d"]
    if e["op"] == "knm_matvec":
        return (
            [_shape(b, d), _shape(m, d), _shape(m), _shape(b), _shape(b), _shape()],
            ["x", "c", "u", "v", "mask", "param"],
            ["w"],
        )
    if e["op"] == "kernel_block":
        return ([_shape(b, d), _shape(m, d), _shape()], ["x", "c", "param"], ["kr"])
    if e["op"] == "kmm":
        return ([_shape(m, d), _shape()], ["c", "param"], ["kmm"])
    if e["op"] == "precond":
        return ([_shape(m, m), _shape(), _shape()], ["kmm", "lam", "eps"], ["t", "a"])
    raise ValueError(f"unknown op {e['op']!r}")


def fn_for(e: dict):
    """The jax function implementing an entry (returns a tuple)."""
    kern, impl = e["kern"], e["impl"]
    if e["op"] == "knm_matvec":
        return lambda x, c, u, v, mask, p: (
            model.knm_matvec(kern, impl, x, c, u, v, mask, p),
        )
    if e["op"] == "kernel_block":
        return lambda x, c, p: (model.kernel_block(kern, impl, x, c, p),)
    if e["op"] == "kmm":
        return lambda c, p: (model.kmm(kern, c, p),)
    if e["op"] == "precond":
        return lambda k, lam, eps: model.precond(k, lam, eps)
    raise ValueError(f"unknown op {e['op']!r}")


def lower_entry(e: dict, out_dir: str) -> dict:
    """Lower one entry, write ``<name>.hlo.txt``, return its manifest row."""
    shapes, in_names, out_names = signature(e)
    # keep_unused: the linear kernel ignores `param`; without this jax
    # prunes the parameter and the HLO signature no longer matches the
    # rust-side calling contract.
    lowered = jax.jit(fn_for(e), keep_unused=True).lower(*shapes)
    text = to_hlo_text(lowered)
    fname = manifest.name(e) + ".hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    row = dict(e)
    row["file"] = fname
    row["inputs"] = [
        dict(name=n, shape=list(s.shape)) for n, s in zip(in_names, shapes)
    ]
    row["outputs"] = out_names
    return row


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--filter", default="", help="only entries whose name contains this")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    os.makedirs(args.out_dir, exist_ok=True)
    rows = []
    entries = [e for e in manifest.entries() if args.filter in manifest.name(e)]
    t0 = time.time()
    for i, e in enumerate(entries):
        t1 = time.time()
        rows.append(lower_entry(e, args.out_dir))
        if not args.quiet:
            print(
                f"[{i + 1}/{len(entries)}] {manifest.name(e)}"
                f" ({time.time() - t1:.2f}s)",
                file=sys.stderr,
            )
    if args.filter:
        # partial rebuild: merge into the existing manifest instead of
        # clobbering it with only the filtered subset
        mpath = os.path.join(args.out_dir, "manifest.json")
        if os.path.exists(mpath):
            with open(mpath) as f:
                old = {r["file"]: r for r in json.load(f).get("entries", [])}
            old.update({r["file"]: r for r in rows})
            rows = sorted(old.values(), key=lambda r: r["file"])
    meta = dict(
        version=1,
        block=manifest.BLOCK,
        test_block=manifest.TEST_BLOCK,
        entries=rows,
    )
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print(
        f"wrote {len(rows)} artifacts + manifest.json to {args.out_dir}"
        f" in {time.time() - t0:.1f}s"
    )


if __name__ == "__main__":
    main()

# L1: Pallas kernels for the FALKON compute hot-spot + pure-jnp oracle.
from . import block, matvec, ref, tiles  # noqa: F401

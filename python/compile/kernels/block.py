"""Pallas kernel: materialize one kernel block Kr = K(X_block, C).

Used by prediction (Kr @ alpha happens on the rust side or in the predict
op) and by the approximate-leverage-score sketch. The FALKON CG hot path
does NOT use this op — it uses the fused matvec (matvec.py) that never
writes Kr to HBM.

Grid: (B/TB, M/TM); each step computes one (TB, TM) tile in VMEM from the
(TB, D) row slab and (TM, D) center slab and writes it to its output slot.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import tiles


def _kernel(kern):
    def body(x_ref, c_ref, p_ref, o_ref):
        o_ref[...] = tiles.tile_kernel(kern, x_ref[...], c_ref[...], p_ref[0, 0])

    return body


def kernel_block(kern: str, x, c, param):
    """K(x, c) -> (B, M) via a tiled Pallas grid (interpret mode).

    param is a scalar (traced); it is reshaped to (1, 1) and broadcast to
    every grid step.
    """
    b, d = x.shape
    m, _ = c.shape
    tb, tm = tiles.pick_tiles(kern, b, m)
    p = jnp.asarray(param, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _kernel(kern),
        grid=(b // tb, m // tm),
        in_specs=[
            pl.BlockSpec((tb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tm, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, tm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, m), jnp.float32),
        interpret=True,
    )(x, c, p)

"""Pallas kernels: the FALKON fused Nyström matvec (the compute hot-spot).

The paper's Alg. 1 processes K_nM in row blocks so the full matrix is
never materialized:

    w = Kr^T (mask * (Kr u + v)),   Kr = K(X_block, C)

We express this as two Pallas grids over the SAME tile schedule, computing
each (TB, TM) tile of Kr on the fly in VMEM both times — Kr never touches
HBM, which is exactly the paper's O(M^2)-working-memory trick translated
from "GPU block buffer" to "VMEM tile + BlockSpec HBM<->VMEM schedule":

  stage 1 (kr_matvec):    y = Kr @ u + v      grid (B/TB, M/TM), j inner,
                                              accumulates into the (TB,)
                                              output slab revisited per i
  stage 2 (kr_matvec_t):  w = Kr^T @ y        grid (M/TM, B/TB), i inner,
                                              accumulates into (TM,) slabs

The mask multiply between the stages is a (B,)-element op done in plain
jnp (it fuses into the surrounding XLA graph).

Accumulation across grid steps relies on Pallas's sequential-grid
revisiting semantics (the output block index map ignores the inner grid
dimension), the standard TPU reduction pattern.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import tiles


def _mv_kernel(kern):
    """y_tile(i) accumulates Kr(i, j) @ u(j) over j; initialized to v(i)."""

    def body(x_ref, c_ref, u_ref, v_ref, p_ref, o_ref):
        j = pl.program_id(1)

        @pl.when(j == 0)
        def _init():
            o_ref[...] = v_ref[...]

        kr = tiles.tile_kernel(kern, x_ref[...], c_ref[...], p_ref[0, 0])
        o_ref[...] += kr @ u_ref[...]

    return body


def _mvt_kernel(kern):
    """w_tile(j) accumulates Kr(i, j)^T @ y(i) over i; initialized to 0."""

    def body(x_ref, c_ref, y_ref, p_ref, o_ref):
        i = pl.program_id(1)

        @pl.when(i == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        kr = tiles.tile_kernel(kern, x_ref[...], c_ref[...], p_ref[0, 0])
        o_ref[...] += kr.T @ y_ref[...]

    return body


def kr_matvec(kern: str, x, c, u, v, param):
    """y = K(x, c) @ u + v -> (B,)."""
    b, d = x.shape
    m, _ = c.shape
    tb, tm = tiles.pick_tiles(kern, b, m)
    p = jnp.asarray(param, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _mv_kernel(kern),
        grid=(b // tb, m // tm),
        in_specs=[
            pl.BlockSpec((tb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tm, d), lambda i, j: (j, 0)),
            pl.BlockSpec((tm,), lambda i, j: (j,)),
            pl.BlockSpec((tb,), lambda i, j: (i,)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tb,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(x, c, u, v, p)


def kr_matvec_t(kern: str, x, c, y, param):
    """w = K(x, c)^T @ y -> (M,)."""
    b, d = x.shape
    m, _ = c.shape
    tb, tm = tiles.pick_tiles(kern, b, m)
    p = jnp.asarray(param, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _mvt_kernel(kern),
        grid=(m // tm, b // tb),
        in_specs=[
            pl.BlockSpec((tb, d), lambda j, i: (i, 0)),
            pl.BlockSpec((tm, d), lambda j, i: (j, 0)),
            pl.BlockSpec((tb,), lambda j, i: (i,)),
            pl.BlockSpec((1, 1), lambda j, i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tm,), lambda j, i: (j,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=True,
    )(x, c, y, p)


def knm_matvec(kern: str, x, c, u, v, mask, param):
    """Fused FALKON block op: w = Kr^T (mask * (Kr u + v)) -> (M,)."""
    y = kr_matvec(kern, x, c, u, v, param)
    y = mask * y
    return kr_matvec_t(kern, x, c, y, param)

"""Pure-jnp oracle for the L1 Pallas kernels.

Everything here is the *definition* of correct behaviour: the Pallas
kernels in ``block.py`` / ``matvec.py`` and the L2 ops in ``model.py``
are tested (pytest + hypothesis) against these functions.

Kernel functions follow the paper's conventions:

- gaussian:  K(x, c) = exp(-||x - c||^2 / (2 sigma^2))        (Sect. 5)
- laplacian: K(x, c) = exp(-||x - c||_1 / sigma)
- linear:    K(x, c) = <x, c>                                  (YELP, Sect. 5)

``param`` is the kernel hyperparameter (sigma for gaussian/laplacian,
ignored for linear — pass 1.0).
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

KERNELS = ("gaussian", "laplacian", "linear")


def chol_lower(a: jnp.ndarray) -> jnp.ndarray:
    """Lower-Cholesky factor as *plain HLO ops* (left-looking column
    algorithm in a fori_loop).

    ``jnp.linalg.cholesky`` lowers on CPU to a LAPACK typed-FFI
    custom-call which the deployment XLA (xla_extension 0.5.1) rejects;
    this formulation lowers to dot/select/dynamic-update ops only, so the
    precond artifact stays loadable everywhere. O(M³) like LAPACK, one
    extra O(M²) matvec per column.
    """
    m = a.shape[0]
    idx = jnp.arange(m)

    def body(j, l):
        # column j from columns < j: c = A[:, j] - L @ L[j, :]
        row = l[j, :]
        c = lax.dynamic_slice_in_dim(a, j, 1, axis=1)[:, 0] - l @ row
        piv = jnp.sqrt(jnp.maximum(c[j], 0.0))
        col = jnp.where(idx >= j, c / piv, 0.0)
        return l.at[:, j].set(col)

    return lax.fori_loop(0, m, body, jnp.zeros_like(a))


def _inv_lower(l: jnp.ndarray) -> jnp.ndarray:
    """Inverse of a lower-triangular matrix by recursive 2x2 blocking —
    pure matmuls/concats (no TriangularSolve custom-call), O(p³)."""
    p = l.shape[0]
    if p == 1:
        return 1.0 / l
    h = p // 2
    a, b, c = l[:h, :h], l[h:, :h], l[h:, h:]
    ai, ci = _inv_lower(a), _inv_lower(c)
    top = jnp.concatenate([ai, jnp.zeros((h, p - h), l.dtype)], axis=1)
    bot = jnp.concatenate([-ci @ (b @ ai), ci], axis=1)
    return jnp.concatenate([top, bot], axis=0)


def chol_lower_fast(a: jnp.ndarray, panel: int = 64) -> jnp.ndarray:
    """Right-looking *blocked* Cholesky: the per-column fori_loop of
    ``chol_lower`` only runs inside panel×panel diagonal blocks; the panel
    column solve uses the matmul-only triangular inverse and the trailing
    update is one GEMM per panel.

    §Perf finding: 11x faster than the column loop on jax 0.8's bundled
    XLA — but ~250x SLOWER on the deployment runtime (xla_extension
    0.5.1 mis-optimizes the unrolled panel graph), so the precond
    artifact uses ``chol_lower``; this variant is kept (and tested) for
    newer runtimes. Measure on the runtime you ship. See EXPERIMENTS.md.

    Requires ``panel | M`` (all compiled artifact sizes are powers of
    two); falls back to ``chol_lower`` otherwise.
    """
    m = a.shape[0]
    if m <= panel or m % panel != 0:
        return chol_lower(a)
    out = jnp.zeros_like(a)
    trail = a
    for pb in range(m // panel):
        j0 = pb * panel
        apan = trail[j0:, j0 : j0 + panel]
        l11 = chol_lower(apan[:panel, :])
        x = apan[panel:, :] @ _inv_lower(l11).T
        out = out.at[j0:, j0 : j0 + panel].set(jnp.concatenate([l11, x], axis=0))
        if j0 + panel < m:
            upd = trail[j0 + panel :, j0 + panel :] - x @ x.T
            trail = trail.at[j0 + panel :, j0 + panel :].set(upd)
    return out


def sq_dists(x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Pairwise squared euclidean distances, (B, D) x (M, D) -> (B, M).

    Uses the expansion ||x||^2 + ||c||^2 - 2 x.c so the dominant cost is a
    matmul (the same structure the Pallas kernel feeds to the MXU).
    """
    xx = jnp.sum(x * x, axis=-1, keepdims=True)          # (B, 1)
    cc = jnp.sum(c * c, axis=-1, keepdims=True).T        # (1, M)
    cross = x @ c.T                                      # (B, M)
    return jnp.maximum(xx + cc - 2.0 * cross, 0.0)


def l1_dists(x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Pairwise L1 distances, (B, D) x (M, D) -> (B, M)."""
    return jnp.sum(jnp.abs(x[:, None, :] - c[None, :, :]), axis=-1)


def kernel_matrix(kern: str, x: jnp.ndarray, c: jnp.ndarray, param) -> jnp.ndarray:
    """Dense kernel block K(x_i, c_j) -> (B, M). The oracle for all ops."""
    if kern == "gaussian":
        return jnp.exp(-sq_dists(x, c) / (2.0 * param * param))
    if kern == "laplacian":
        return jnp.exp(-l1_dists(x, c) / param)
    if kern == "linear":
        return x @ c.T
    raise ValueError(f"unknown kernel {kern!r}")


def knm_matvec(kern, x, c, u, v, mask, param):
    """The FALKON hot-path op for one row block (Alg. 1's KnM_times_vector):

        w = Kr^T (mask * (Kr u + v)),   Kr = K(x, c)

    mask zeroes padded rows so blocked+padded execution is exact.
    """
    kr = kernel_matrix(kern, x, c, param)
    y = mask * (kr @ u + v)
    return kr.T @ y


def kmm(kern, c, param):
    """Center-center kernel matrix K_MM."""
    return kernel_matrix(kern, c, c, param)


def precond(kmm_mat: jnp.ndarray, lam, eps):
    """Preconditioner factors (Eq. 13 / Alg. 1), both upper-triangular:

        T = chol(K_MM + eps*M*I)   with K_MM + eps*M*I = T^T T
        A = chol(T T^T / M + lam*I) with  .            = A^T A

    Returned as *upper* factors to match MATLAB ``chol`` so the rust
    triangular solves mirror Alg. 1 line by line.
    """
    m = kmm_mat.shape[0]
    kj = kmm_mat + eps * m * jnp.eye(m, dtype=kmm_mat.dtype)
    t_up = chol_lower(kj).T                              # upper: K = T^T T
    a_in = t_up @ t_up.T / m + lam * jnp.eye(m, dtype=kmm_mat.dtype)
    a_up = chol_lower(a_in).T                            # upper: . = A^T A
    return t_up, a_up

"""Shared tile-level math for the Pallas kernels.

A "tile" is the (TB, TM) piece of a kernel block that lives in VMEM while
the grid walks the (B/TB, M/TM) schedule. The tile computation is written
so the dominant flops are a single (TB, D) x (D, TM) matmul, i.e. the part
the MXU executes on real TPU hardware; the rest is cheap element-wise tail
on the VPU.

TPU adaptation notes (DESIGN.md section "Hardware adaptation"):

- gaussian/linear tiles use the matmul expansion, MXU-friendly;
- laplacian needs |x - c| summed over D, which has no matmul form; its
  tile materializes a (TB, TM, D) broadcast, so laplacian uses smaller
  tiles (TILES["laplacian"]) to stay within a VMEM-like budget.
"""

from __future__ import annotations

import jax.numpy as jnp

#: default (TB, TM) tile shapes per kernel; must divide the block shapes.
#: (TB=1024, TM=256) won the §Perf sweep on the CPU deployment target:
#: 2.1x over (256, 256) at D=512 and never worse elsewhere (the full row
#: block per grid step amortizes the ||x||² recompute across center
#: tiles). VMEM at the largest compiled D stays ~3.7 MiB — see
#: EXPERIMENTS.md §Perf.
TILES = {
    "gaussian": (1024, 256),
    "linear": (1024, 256),
    "laplacian": (64, 64),
}


def pick_tiles(kern: str, b: int, m: int) -> tuple[int, int]:
    """Largest default tile that divides (b, m); falls back to the full
    extent for small/test shapes."""
    tb0, tm0 = TILES[kern]

    def fit(n, t0):
        t = min(n, t0)
        while n % t != 0:
            t -= 1
        return t

    return fit(b, tb0), fit(m, tm0)


def tile_kernel(kern: str, x, c, param):
    """Kernel tile K(x, c) for x:(TB, D), c:(TM, D) -> (TB, TM).

    Mirrors ref.kernel_matrix but written for a VMEM-resident tile.
    """
    if kern == "gaussian":
        xx = jnp.sum(x * x, axis=-1, keepdims=True)          # (TB, 1)
        cc = jnp.sum(c * c, axis=-1, keepdims=True).T        # (1, TM)
        cross = jnp.dot(x, c.T, preferred_element_type=jnp.float32)
        sq = jnp.maximum(xx + cc - 2.0 * cross, 0.0)
        return jnp.exp(-sq / (2.0 * param * param))
    if kern == "laplacian":
        d1 = jnp.sum(jnp.abs(x[:, None, :] - c[None, :, :]), axis=-1)
        return jnp.exp(-d1 / param)
    if kern == "linear":
        return jnp.dot(x, c.T, preferred_element_type=jnp.float32)
    raise ValueError(f"unknown kernel {kern!r}")


def vmem_bytes(kern: str, b: int, m: int, d: int) -> int:
    """Estimated VMEM working set (bytes, f32) for one grid step — used by
    the perf analysis in DESIGN.md / EXPERIMENTS.md, not at runtime."""
    tb, tm = pick_tiles(kern, b, m)
    base = (tb * d) + (tm * d) + (tb * tm) + tb + tm         # x, c, tile, vecs
    if kern == "laplacian":
        base += tb * tm * d                                   # broadcast diff
    return 4 * base

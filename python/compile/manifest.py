"""The artifact manifest: which (op, kernel, impl, shape) variants are
AOT-compiled by ``aot.py`` and therefore available to the rust runtime.

HLO has static shapes, so every variant the coordinator may execute must
be listed here. The runtime pads rows (masked, exact) and feature columns
(zero padding, exact for gaussian/laplacian/linear) but requires an exact
match on M — see DESIGN.md "Artifact contract".

Entries are plain dicts so they serialize straight into
``artifacts/manifest.json`` for the rust side.
"""

from __future__ import annotations

#: hot-path row block size. One value keeps the artifact set small; the
#: coordinator streams any n through blocks of this many rows.
BLOCK = 1024

#: tiny shapes compiled alongside the defaults so `cargo test` integration
#: tests stay fast.
TEST_BLOCK = 64

#: Nystrom-center counts available to the runtime (exact match required).
MS = (32, 256, 512, 1024, 2048)

#: padded feature widths (runtime picks the smallest >= dataset d).
DS = (8, 32, 128, 512)

#: which (kernel, D) combinations are compiled. Laplacian tiles blow up
#: as (TB, TM, D) (see kernels/tiles.py) so it is restricted to small D.
KERNEL_DS = {
    "gaussian": (8, 32, 128, 512),
    "linear": (8, 32, 128, 512),
    "laplacian": (8, 32),
}

IMPLS = ("pallas", "jnp")


def _bs_for(m: int) -> tuple[int, ...]:
    # tiny Ms exist only for the integration-test artifact set
    return (TEST_BLOCK,) if m == 32 else (TEST_BLOCK, BLOCK)


def entries() -> list[dict]:
    """Full default manifest (list of artifact descriptors)."""
    out: list[dict] = []
    for kern, ds in KERNEL_DS.items():
        for m in MS:
            for d in ds:
                for b in _bs_for(m):
                    for impl in IMPLS:
                        out.append(dict(op="knm_matvec", kern=kern, impl=impl,
                                        b=b, m=m, d=d))
                        out.append(dict(op="kernel_block", kern=kern, impl=impl,
                                        b=b, m=m, d=d))
                out.append(dict(op="kmm", kern=kern, impl="jnp", b=0, m=m, d=d))
    for m in MS:
        out.append(dict(op="precond", kern="", impl="jnp", b=0, m=m, d=0))
    return out


def name(e: dict) -> str:
    """Canonical artifact file stem for an entry."""
    if e["op"] == "precond":
        return f"precond_m{e['m']}"
    if e["op"] == "kmm":
        return f"kmm_{e['kern']}_m{e['m']}_d{e['d']}"
    return f"{e['op']}_{e['kern']}_{e['impl']}_b{e['b']}_m{e['m']}_d{e['d']}"

"""L2: the FALKON compute graph, composed from the L1 kernels.

Each public function here is one AOT artifact entry point: a pure jax
function over statically-shaped f32 arrays, lowered once by ``aot.py`` to
HLO text and executed from the rust coordinator via PJRT. Python never
runs on the training/request path.

Two implementations are exposed for the data-touching ops:

- ``impl="pallas"`` — the paper-faithful tiled kernels (kernels/matvec.py,
  kernels/block.py) that compute Kr tiles on the fly in VMEM;
- ``impl="jnp"``    — the same math as plain XLA ops (kernels/ref.py),
  letting XLA's own fusion handle the block. Numerically cross-checked in
  pytest; the runtime can select either, and EXPERIMENTS.md section "Perf"
  compares them on the CPU deployment target.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import matvec as _mv
from .kernels import block as _bl
from .kernels import ref as _ref

IMPLS = ("pallas", "jnp")


def knm_matvec(kern: str, impl: str, x, c, u, v, mask, param):
    """w = Kr^T (mask * (Kr u + v)) for one row block — the CG hot path.

    Signature (all f32): x:(B,D) c:(M,D) u:(M,) v:(B,) mask:(B,) param:()
    -> w:(M,)
    """
    if impl == "pallas":
        return _mv.knm_matvec(kern, x, c, u, v, mask, param)
    if impl == "jnp":
        return _ref.knm_matvec(kern, x, c, u, v, mask, param)
    raise ValueError(f"unknown impl {impl!r}")


def kernel_block(kern: str, impl: str, x, c, param):
    """Kr = K(x, c) -> (B, M). Prediction / leverage-score sketch op."""
    if impl == "pallas":
        return _bl.kernel_block(kern, x, c, param)
    if impl == "jnp":
        return _ref.kernel_matrix(kern, x, c, param)
    raise ValueError(f"unknown impl {impl!r}")


def predict_block(kern: str, impl: str, x, c, alpha, param):
    """f(x_i) = sum_j alpha_j K(x_i, c_j) for one row block -> (B,)."""
    kr = kernel_block(kern, impl, x, c, param)
    return kr @ alpha


def kmm(kern: str, c, param):
    """K_MM over the Nystrom centers (preconditioner input) -> (M, M)."""
    return _ref.kernel_matrix(kern, c, c, param)


def precond(kmm_mat, lam, eps):
    """Preconditioner factorization (Eq. 13): upper-triangular (T, A).

        T = chol(K_MM + eps*M*I),  A = chol(T T^T / M + lam*I)

    Cost 4/3 M^3 flops, once per fit; XLA Cholesky. lam and eps are
    runtime scalars so one artifact serves every regularization setting.
    """
    return _ref.precond(kmm_mat, lam, eps)


def dense_falkon_system(kern: str, x, c, y, lam, param):
    """Small-scale oracle: materialize H = K_nM^T K_nM + lam*n*K_MM and
    z = K_nM^T y (Eq. 8). Only used by tests to validate the blocked CG
    path end-to-end — never lowered for the runtime at scale."""
    n = x.shape[0]
    knm = _ref.kernel_matrix(kern, x, c, param)
    kmm_mat = _ref.kernel_matrix(kern, c, c, param)
    h = knm.T @ knm + lam * n * kmm_mat
    z = knm.T @ y
    return h, z

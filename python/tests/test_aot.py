"""AOT pipeline tests: every manifest entry lowers to parseable HLO text,
signatures match the documented contract, and the emitted artifacts (when
present) agree with the manifest on disk.
"""

import json
import os

import numpy as np
import pytest

from compile import aot, manifest

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_manifest_names_unique():
    es = manifest.entries()
    names = [manifest.name(e) for e in es]
    assert len(names) == len(set(names))


def test_manifest_covers_required_ops():
    ops = {e["op"] for e in manifest.entries()}
    assert ops == {"knm_matvec", "kernel_block", "kmm", "precond"}


def test_signature_shapes():
    e = dict(op="knm_matvec", kern="gaussian", impl="pallas", b=64, m=32, d=8)
    shapes, in_names, out_names = aot.signature(e)
    assert in_names == ["x", "c", "u", "v", "mask", "param"]
    assert [tuple(s.shape) for s in shapes] == [(64, 8), (32, 8), (32,), (64,), (64,), ()]
    assert out_names == ["w"]
    e = dict(op="precond", kern="", impl="jnp", b=0, m=32, d=0)
    shapes, in_names, out_names = aot.signature(e)
    assert in_names == ["kmm", "lam", "eps"] and out_names == ["t", "a"]


@pytest.mark.parametrize(
    "e",
    [
        dict(op="knm_matvec", kern="gaussian", impl="pallas", b=64, m=32, d=8),
        dict(op="knm_matvec", kern="laplacian", impl="jnp", b=64, m=32, d=8),
        dict(op="kernel_block", kern="linear", impl="pallas", b=64, m=32, d=8),
        dict(op="kmm", kern="gaussian", impl="jnp", b=0, m=32, d=8),
        dict(op="precond", kern="", impl="jnp", b=0, m=32, d=0),
    ],
    ids=lambda e: manifest.name(e),
)
def test_lower_entry_produces_valid_hlo(tmp_path, e):
    row = aot.lower_entry(e, str(tmp_path))
    text = (tmp_path / row["file"]).read_text()
    assert "ENTRY" in text and "HloModule" in text
    # every input is an f32 parameter of the documented shape
    for i, inp in enumerate(row["inputs"]):
        assert f"parameter({i})" in text
    # lowered with return_tuple=True -> ROOT is a tuple
    assert "ROOT" in text


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_built_artifacts_match_manifest():
    with open(os.path.join(ART_DIR, "manifest.json")) as f:
        meta = json.load(f)
    assert meta["version"] == 1
    assert meta["block"] == manifest.BLOCK
    rows = meta["entries"]
    assert len(rows) == len(manifest.entries())
    for row in rows:
        path = os.path.join(ART_DIR, row["file"])
        assert os.path.exists(path), row["file"]
    # spot-check one file parses as HLO text
    with open(os.path.join(ART_DIR, rows[0]["file"])) as f:
        assert "HloModule" in f.read(200)


def test_hlo_numerics_roundtrip():
    """Lower a tiny matvec, re-execute the HLO through the XLA client, and
    compare to the oracle — the python half of the interchange contract
    (the rust half is rust/tests/integration.rs)."""
    import jax.numpy as jnp
    from jax._src.lib import xla_client as xc
    from compile.kernels import ref

    e = dict(op="knm_matvec", kern="gaussian", impl="pallas", b=64, m=32, d=8)
    shapes, _, _ = aot.signature(e)
    import jax

    lowered = jax.jit(aot.fn_for(e)).lower(*shapes)
    text = aot.to_hlo_text(lowered)
    # execute the lowered module directly in-process
    rng = np.random.default_rng(0)
    x = rng.normal(size=(64, 8)).astype(np.float32)
    c = rng.normal(size=(32, 8)).astype(np.float32)
    u = rng.normal(size=(32,)).astype(np.float32)
    v = rng.normal(size=(64,)).astype(np.float32)
    mask = np.ones(64, np.float32)
    p = np.float32(1.5)
    got = np.asarray(jax.jit(aot.fn_for(e))(x, c, u, v, mask, p)[0])
    want = np.asarray(ref.knm_matvec("gaussian", jnp.asarray(x), jnp.asarray(c), u, v, mask, p))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
    assert "HloModule" in text

"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

hypothesis sweeps shapes and kernel parameters; numpy fixtures pin a few
exact regression values so a silent oracle change is caught too.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import block, matvec, ref, tiles

KERNELS = ref.KERNELS


def mk(rng, *shape):
    return rng.normal(size=shape).astype(np.float32)


# ---------------------------------------------------------------------------
# oracle self-checks (pin the math itself)
# ---------------------------------------------------------------------------


def test_gaussian_oracle_values():
    x = np.array([[0.0, 0.0], [3.0, 4.0]], np.float32)
    c = np.array([[0.0, 0.0]], np.float32)
    k = np.asarray(ref.kernel_matrix("gaussian", jnp.asarray(x), jnp.asarray(c), 1.0))
    # ||(3,4)||^2 = 25 -> exp(-12.5)
    np.testing.assert_allclose(k[:, 0], [1.0, np.exp(-12.5)], rtol=1e-6)


def test_laplacian_oracle_values():
    x = np.array([[1.0, -2.0]], np.float32)
    c = np.array([[0.0, 0.0], [1.0, -2.0]], np.float32)
    k = np.asarray(ref.kernel_matrix("laplacian", jnp.asarray(x), jnp.asarray(c), 2.0))
    np.testing.assert_allclose(k[0], [np.exp(-3.0 / 2.0), 1.0], rtol=1e-6)


def test_linear_oracle_is_gram():
    rng = np.random.default_rng(1)
    x, c = mk(rng, 5, 3), mk(rng, 4, 3)
    k = np.asarray(ref.kernel_matrix("linear", jnp.asarray(x), jnp.asarray(c), 1.0))
    np.testing.assert_allclose(k, x @ c.T, rtol=1e-6)


def test_gaussian_diag_is_one():
    rng = np.random.default_rng(2)
    c = mk(rng, 6, 4)
    k = np.asarray(ref.kmm("gaussian", jnp.asarray(c), 0.7))
    np.testing.assert_allclose(np.diag(k), np.ones(6), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(k, k.T, rtol=1e-5, atol=1e-6)


def test_sq_dists_non_negative_and_exact():
    rng = np.random.default_rng(3)
    x, c = mk(rng, 7, 5), mk(rng, 9, 5)
    d = np.asarray(ref.sq_dists(jnp.asarray(x), jnp.asarray(c)))
    brute = ((x[:, None, :] - c[None, :, :]) ** 2).sum(-1)
    assert (d >= 0).all()
    np.testing.assert_allclose(d, brute, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# pallas vs oracle — hypothesis shape/param sweep
# ---------------------------------------------------------------------------

shape_strategy = st.tuples(
    st.sampled_from([1, 2, 3, 5, 8, 64, 96]),       # B
    st.sampled_from([1, 2, 4, 8, 32, 48]),          # M
    st.sampled_from([1, 2, 3, 8, 17]),              # D
)


@pytest.mark.parametrize("kern", KERNELS)
@settings(max_examples=12, deadline=None)
@given(shape=shape_strategy, seed=st.integers(0, 2**31 - 1),
       param=st.sampled_from([0.5, 1.0, 2.0, 6.0]))
def test_kernel_block_matches_oracle(kern, shape, seed, param):
    b, m, d = shape
    rng = np.random.default_rng(seed)
    x, c = mk(rng, b, d), mk(rng, m, d)
    got = np.asarray(block.kernel_block(kern, x, c, param))
    want = np.asarray(ref.kernel_matrix(kern, jnp.asarray(x), jnp.asarray(c), param))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("kern", KERNELS)
@settings(max_examples=12, deadline=None)
@given(shape=shape_strategy, seed=st.integers(0, 2**31 - 1),
       param=st.sampled_from([0.5, 1.0, 3.0]))
def test_knm_matvec_matches_oracle(kern, shape, seed, param):
    b, m, d = shape
    rng = np.random.default_rng(seed)
    x, c = mk(rng, b, d), mk(rng, m, d)
    u, v = mk(rng, m), mk(rng, b)
    mask = (rng.random(b) > 0.3).astype(np.float32)
    got = np.asarray(matvec.knm_matvec(kern, x, c, u, v, mask, param))
    want = np.asarray(ref.knm_matvec(kern, jnp.asarray(x), jnp.asarray(c), u, v, mask, param))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("kern", KERNELS)
def test_matvec_stages_separately(kern):
    rng = np.random.default_rng(7)
    b, m, d = 96, 48, 8
    x, c, u, v = mk(rng, b, d), mk(rng, m, d), mk(rng, m), mk(rng, b)
    kr = np.asarray(ref.kernel_matrix(kern, jnp.asarray(x), jnp.asarray(c), 1.3))
    y = np.asarray(matvec.kr_matvec(kern, x, c, u, v, 1.3))
    np.testing.assert_allclose(y, kr @ u + v, rtol=2e-4, atol=2e-4)
    w = np.asarray(matvec.kr_matvec_t(kern, x, c, y, 1.3))
    np.testing.assert_allclose(w, kr.T @ y, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# padding exactness — the runtime's artifact contract
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kern", KERNELS)
def test_row_padding_with_mask_is_exact(kern):
    """Padding rows with garbage + mask=0 must give the unpadded answer."""
    rng = np.random.default_rng(11)
    b, bpad, m, d = 40, 64, 32, 8
    x, c, u = mk(rng, b, d), mk(rng, m, d), mk(rng, m)
    v = mk(rng, b)
    xp = np.concatenate([x, 99.0 * np.ones((bpad - b, d), np.float32)])
    vp = np.concatenate([v, 55.0 * np.ones(bpad - b, np.float32)])
    mask = np.concatenate([np.ones(b, np.float32), np.zeros(bpad - b, np.float32)])
    got = np.asarray(matvec.knm_matvec(kern, xp, c, u, vp, mask, 1.5))
    want = np.asarray(
        ref.knm_matvec(kern, jnp.asarray(x), jnp.asarray(c), u, v,
                       np.ones(b, np.float32), 1.5)
    )
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("kern", KERNELS)
def test_feature_zero_padding_is_exact(kern):
    """Zero-padding feature columns must not change any kernel value."""
    rng = np.random.default_rng(12)
    b, m, d, dpad = 16, 8, 5, 12
    x, c = mk(rng, b, d), mk(rng, m, d)
    xp = np.concatenate([x, np.zeros((b, dpad - d), np.float32)], axis=1)
    cp = np.concatenate([c, np.zeros((m, dpad - d), np.float32)], axis=1)
    a = np.asarray(ref.kernel_matrix(kern, jnp.asarray(xp), jnp.asarray(cp), 2.0))
    bref = np.asarray(ref.kernel_matrix(kern, jnp.asarray(x), jnp.asarray(c), 2.0))
    np.testing.assert_allclose(a, bref, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# tiles helpers
# ---------------------------------------------------------------------------


def test_pick_tiles_divides():
    for kern in KERNELS:
        for b in (1, 7, 64, 96, 1024):
            for m in (1, 3, 32, 256, 2048):
                tb, tm = tiles.pick_tiles(kern, b, m)
                assert b % tb == 0 and m % tm == 0
                assert 1 <= tb <= b and 1 <= tm <= m


def test_vmem_budget_default_tiles():
    # default gaussian tile at the largest compiled D stays under 16 MiB
    assert tiles.vmem_bytes("gaussian", 1024, 2048, 512) <= 16 * 2**20
    assert tiles.vmem_bytes("laplacian", 1024, 2048, 32) <= 16 * 2**20


# ---------------------------------------------------------------------------
# pure-HLO cholesky (used by the precond artifact — ref.chol_lower)
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(m=st.sampled_from([1, 2, 5, 16, 33]), seed=st.integers(0, 2**31 - 1))
def test_chol_lower_matches_numpy(m, seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(m, m))
    spd = (a @ a.T + m * np.eye(m)).astype(np.float32)
    l = np.asarray(ref.chol_lower(jnp.asarray(spd)))
    # lower triangular, positive diagonal, reconstructs
    np.testing.assert_allclose(l, np.tril(l))
    assert (np.diag(l) > 0).all()
    np.testing.assert_allclose(l @ l.T, spd, rtol=5e-4, atol=5e-4)
    want = np.linalg.cholesky(spd.astype(np.float64))
    np.testing.assert_allclose(l, want, rtol=5e-3, atol=5e-3)


def test_chol_lower_lowers_without_custom_calls():
    """The whole point of chol_lower: the precond artifact must contain no
    custom-call (LAPACK FFI) ops, or the deployment XLA rejects it."""
    import jax
    from compile import aot

    e = dict(op="precond", kern="", impl="jnp", b=0, m=32, d=0)
    shapes, _, _ = aot.signature(e)
    lowered = jax.jit(aot.fn_for(e), keep_unused=True).lower(*shapes)
    text = aot.to_hlo_text(lowered)
    assert "custom-call" not in text, "precond HLO contains a custom-call"


@settings(max_examples=10, deadline=None)
@given(m=st.sampled_from([16, 64, 128, 192]), seed=st.integers(0, 2**31 - 1))
def test_chol_lower_fast_matches_reference(m, seed):
    """The blocked (§Perf) factorization must agree with the column-wise
    reference — including the non-divisible fallback path (m=192 uses
    panel 64 evenly; m=16 takes the fallback)."""
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(m, m))
    spd = (a @ a.T + m * np.eye(m)).astype(np.float32)
    fast = np.asarray(ref.chol_lower_fast(jnp.asarray(spd)))
    slow = np.asarray(ref.chol_lower(jnp.asarray(spd)))
    np.testing.assert_allclose(fast, slow, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(fast @ fast.T, spd, rtol=5e-4, atol=5e-4)

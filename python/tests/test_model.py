"""L2 correctness: model ops, preconditioner factorization, and a full
numpy FALKON reference run (Alg. 1/2) validating that the preconditioned
CG on the blocked ops converges to the exact Nystrom estimator — the same
contract the rust coordinator implements.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def mk(rng, *shape):
    return rng.normal(size=shape).astype(np.float32)


# ---------------------------------------------------------------------------
# precond factorization
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(m=st.sampled_from([2, 3, 8, 24]), seed=st.integers(0, 2**31 - 1),
       lam=st.sampled_from([1e-6, 1e-3, 0.1]))
def test_precond_factors(m, seed, lam):
    rng = np.random.default_rng(seed)
    c = mk(rng, m, 4)
    kmm = np.asarray(ref.kernel_matrix("gaussian", jnp.asarray(c), jnp.asarray(c), 1.0))
    eps = 1e-6
    t, a = model.precond(jnp.asarray(kmm), lam, eps)
    t, a = np.asarray(t, np.float64), np.asarray(a, np.float64)
    # upper triangular
    assert np.allclose(t, np.triu(t))
    assert np.allclose(a, np.triu(a))
    # T^T T = KMM + eps*M*I
    np.testing.assert_allclose(t.T @ t, kmm + eps * m * np.eye(m), rtol=1e-3, atol=1e-4)
    # A^T A = T T^T / M + lam I
    np.testing.assert_allclose(a.T @ a, t @ t.T / m + lam * np.eye(m), rtol=1e-3, atol=1e-4)


def test_precond_rank_deficient_kmm():
    """Duplicate centers make K_MM singular; the eps*M jitter must keep the
    factorization finite (Alg. 1's `eps*M*eye(M)` guard)."""
    rng = np.random.default_rng(5)
    c = mk(rng, 4, 3)
    c = np.concatenate([c, c[:2]])  # exact duplicates -> singular KMM
    kmm = np.asarray(ref.kernel_matrix("gaussian", jnp.asarray(c), jnp.asarray(c), 1.0))
    t, a = model.precond(jnp.asarray(kmm), 1e-4, 1e-5)
    assert np.isfinite(np.asarray(t)).all()
    assert np.isfinite(np.asarray(a)).all()


# ---------------------------------------------------------------------------
# model op dispatch (impl x kernel parity)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kern", ref.KERNELS)
def test_impls_agree(kern):
    rng = np.random.default_rng(9)
    b, m, d = 64, 32, 8
    x, c, u, v = mk(rng, b, d), mk(rng, m, d), mk(rng, m), mk(rng, b)
    mask = np.ones(b, np.float32)
    w_p = np.asarray(model.knm_matvec(kern, "pallas", x, c, u, v, mask, 1.2))
    w_j = np.asarray(model.knm_matvec(kern, "jnp", x, c, u, v, mask, 1.2))
    np.testing.assert_allclose(w_p, w_j, rtol=2e-4, atol=2e-4)
    k_p = np.asarray(model.kernel_block(kern, "pallas", x, c, 1.2))
    k_j = np.asarray(model.kernel_block(kern, "jnp", x, c, 1.2))
    np.testing.assert_allclose(k_p, k_j, rtol=3e-5, atol=3e-5)


def test_predict_block():
    rng = np.random.default_rng(10)
    b, m, d = 64, 32, 8
    x, c, alpha = mk(rng, b, d), mk(rng, m, d), mk(rng, m)
    got = np.asarray(model.predict_block("gaussian", "pallas", x, c, alpha, 2.0))
    kr = np.asarray(ref.kernel_matrix("gaussian", jnp.asarray(x), jnp.asarray(c), 2.0))
    np.testing.assert_allclose(got, kr @ alpha, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# full-algorithm reference: preconditioned CG == exact Nystrom (Lemma 5)
# ---------------------------------------------------------------------------


def falkon_numpy(kern, x, c, y, lam, param, t_iters, blocks=4):
    """Alg. 2 in numpy float64, built on the blocked op contract.

    This is the oracle the rust coordinator is tested against (the same
    sequence of artifact calls, orchestrated here in numpy).
    """
    n, m = x.shape[0], c.shape[0]
    kmm = np.asarray(ref.kernel_matrix(kern, jnp.asarray(c), jnp.asarray(c), param), np.float64)
    tt = np.linalg.cholesky(kmm + 1e-10 * m * np.eye(m)).T          # upper
    aa = np.linalg.cholesky(tt @ tt.T / m + lam * np.eye(m)).T      # upper

    from scipy.linalg import solve_triangular as tri

    def knm_mv(u, v):
        """sum over blocks of Kr^T (Kr u + v) — blocked like the runtime."""
        w = np.zeros(m)
        for s in range(0, n, (n + blocks - 1) // blocks):
            e = min(n, s + (n + blocks - 1) // blocks)
            kr = np.asarray(
                ref.kernel_matrix(kern, jnp.asarray(x[s:e]), jnp.asarray(c), param),
                np.float64,
            )
            w += kr.T @ (kr @ u + v[s:e])
        return w

    def bhb(u):
        au = tri(aa, u, lower=False)
        tau = tri(tt, au, lower=False)
        w = knm_mv(tau, np.zeros(n)) / n
        return tri(aa.T, tri(tt.T, w, lower=True) + lam * au, lower=True)

    r = tri(aa.T, tri(tt.T, knm_mv(np.zeros(m), y / n), lower=True), lower=True)

    # conjgrad (Alg. 2)
    beta = np.zeros(m)
    p, rr = r.copy(), r.copy()
    rsold = rr @ rr
    for _ in range(t_iters):
        ap = bhb(p)
        alpha = rsold / (p @ ap)
        beta += alpha * p
        rr -= alpha * ap
        rsnew = rr @ rr
        p = rr + (rsnew / rsold) * p
        rsold = rsnew
    return tri(tt, tri(aa, beta, lower=False), lower=False)


def nystrom_exact(kern, x, c, y, lam, param):
    """Direct solve of Eq. 8 (float64)."""
    n = x.shape[0]
    knm = np.asarray(ref.kernel_matrix(kern, jnp.asarray(x), jnp.asarray(c), param), np.float64)
    kmm = np.asarray(ref.kernel_matrix(kern, jnp.asarray(c), jnp.asarray(c), param), np.float64)
    h = knm.T @ knm + lam * n * kmm + 1e-12 * np.eye(c.shape[0])
    return np.linalg.solve(h, knm.T @ y)


@pytest.mark.parametrize("kern,param,m,d", [("gaussian", 1.5, 40, 6), ("linear", 1.0, 6, 8)])
def test_falkon_converges_to_exact_nystrom(kern, param, m, d):
    """Lemma 5: FALKON with enough CG iterations equals the exact Nystrom
    estimator; with the preconditioner it takes only a handful.

    For the linear kernel K_MM = C C^T has rank <= d, so m <= d keeps the
    Nystrom system well-posed (the rank-deficient path is exercised by
    test_precond_rank_deficient_kmm)."""
    scipy = pytest.importorskip("scipy")  # noqa: F841
    rng = np.random.default_rng(21)
    n = 400
    x = rng.normal(size=(n, d))
    c = x[rng.choice(n, m, replace=False)]
    w0 = rng.normal(size=d)
    y = np.tanh(x @ w0) + 0.1 * rng.normal(size=n)
    lam = 1e-4

    alpha_exact = nystrom_exact(kern, x.astype(np.float32), c.astype(np.float32),
                                y, lam, param)
    alpha_falkon = falkon_numpy(kern, x.astype(np.float32), c.astype(np.float32),
                                y, lam, param, t_iters=20)
    # compare in prediction space (coefficients can be ill-conditioned)
    kt = np.asarray(ref.kernel_matrix(kern, jnp.asarray(x[:50].astype(np.float32)),
                                      jnp.asarray(c.astype(np.float32)), param), np.float64)
    np.testing.assert_allclose(kt @ alpha_falkon, kt @ alpha_exact, rtol=1e-4, atol=1e-5)


def test_preconditioner_speeds_up_cg():
    """The paper's core claim in miniature: iterations-to-tolerance with
    the FALKON preconditioner are far fewer than plain CG on Eq. 8.

    Thm. 2 requires M >~ 1/lam for cond(B^T H B) = O(1); the paper's
    regime is lam = 1/sqrt(n), M ~ sqrt(n) log n — used here."""
    pytest.importorskip("scipy")
    rng = np.random.default_rng(31)
    n, m, d = 500, 50, 4
    x = rng.normal(size=(n, d))
    c = x[rng.choice(n, m, replace=False)]
    y = np.sin(x[:, 0]) + 0.05 * rng.normal(size=n)
    lam, param = 1.0 / np.sqrt(n), 1.0

    knm = np.asarray(ref.kernel_matrix("gaussian", jnp.asarray(x.astype(np.float32)),
                                       jnp.asarray(c.astype(np.float32)), param), np.float64)
    kmm = np.asarray(ref.kernel_matrix("gaussian", jnp.asarray(c.astype(np.float32)),
                                       jnp.asarray(c.astype(np.float32)), param), np.float64)
    h = knm.T @ knm + lam * n * kmm
    alpha_star = np.linalg.solve(h + 1e-12 * np.eye(m), knm.T @ y)
    target = knm @ alpha_star

    def cg_iters_plain():
        b = knm.T @ y
        beta = np.zeros(m); r = b.copy(); p = r.copy(); rs = r @ r
        for it in range(1, 1001):
            ap = h @ p
            a = rs / (p @ ap)
            beta += a * p; r -= a * ap
            rsn = r @ r
            if np.linalg.norm(knm @ beta - target) / np.linalg.norm(target) < 1e-3:
                return it
            p = r + (rsn / rs) * p; rs = rsn
        return 1001

    # FALKON preconditioned CG, counting iterations to the same tolerance
    from scipy.linalg import solve_triangular as tri
    tt = np.linalg.cholesky(kmm + 1e-10 * m * np.eye(m)).T
    aa = np.linalg.cholesky(tt @ tt.T / m + lam * np.eye(m)).T

    def bhb(u):
        au = tri(aa, u, lower=False); tau = tri(tt, au, lower=False)
        w = knm.T @ (knm @ tau) / n
        return tri(aa.T, tri(tt.T, w, lower=True) + lam * au, lower=True)

    def alpha_of(beta):
        return tri(tt, tri(aa, beta, lower=False), lower=False)

    rr = tri(aa.T, tri(tt.T, knm.T @ (y / n), lower=True), lower=True)
    beta = np.zeros(m); p = rr.copy(); rs = rr @ rr
    falkon_iters = 1001
    for it in range(1, 1001):
        ap = bhb(p)
        a = rs / (p @ ap)
        beta += a * p; rr -= a * ap
        rsn = rr @ rr
        if np.linalg.norm(knm @ alpha_of(beta) - target) / np.linalg.norm(target) < 1e-3:
            falkon_iters = it
            break
        p = rr + (rsn / rs) * p; rs = rsn

    plain = cg_iters_plain()
    assert falkon_iters <= 15, f"preconditioned CG took {falkon_iters} iters"
    assert falkon_iters * 3 <= plain, (falkon_iters, plain)

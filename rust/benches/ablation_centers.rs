//! **A4 (Thm. 4-5 / Sect. 4.2)** — leverage-score vs uniform center
//! selection: on an imbalanced design (strongly non-uniform leverage
//! scores), approximate-leverage-score sampling should reach uniform
//! sampling's best accuracy with strictly fewer centers M.
//!
//! Emits `BENCH_centers.json` (override with `--json <path>`) with the
//! full sweep, the equal-accuracy-at-smaller-M crossover verdict, and a
//! streamed leg pinning `fit_source`/`approx_leverage_scores_source`
//! against the in-memory path (≤1e-8 at equal seed).
//!
//! Runs on the rust engine so M can sweep freely below the compiled
//! artifact sizes (the math is identical; cross-engine equality is
//! covered by rust/tests/integration.rs).

mod common;

use falkon::bench::{write_json, BenchArgs, Table};
use falkon::data::synth;
use falkon::data::MemSource;
use falkon::falkon::lscores::{approx_leverage_scores, approx_leverage_scores_source};
use falkon::falkon::{fit, fit_source, Centers, FalkonConfig};
use falkon::kernels::Kernel;
use falkon::linalg::vec_ops::max_abs_diff;
use falkon::metrics;
use falkon::runtime::Engine;
use falkon::util::json::Value;
use falkon::util::rng::Rng;

/// A leverage mean within this factor of uniform's best counts as
/// "equal accuracy" for the crossover gate (seed noise on the mean sits
/// well inside it; the ratio at the crossover M is typically 0.6-0.95).
const SLACK: f64 = 1.05;

fn pilot(m: usize) -> usize {
    (8 * m).clamp(256, 512)
}

fn config(m: usize, sigma: f64, lam: f64, centers: Centers, seed: u64) -> FalkonConfig {
    FalkonConfig {
        kernel: Kernel::Gaussian,
        sigma,
        lam,
        m,
        t: 40,
        tol: 1e-10,
        centers,
        seed,
        ..Default::default()
    }
}

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::from_env();
    let engine = Engine::rust();
    let smoke = args.flag("--smoke");
    let n = common::scale(&args, 6_000);
    let lam = 1e-4;
    let sigma = 4.0;
    // the smoke sweep sees fewer rare points per sub-cluster, so it
    // averages more selection seeds to keep the crossover gate stable
    let seeds: Vec<u64> = if smoke {
        (71..=80).collect()
    } else {
        (71..=76).collect()
    };
    let ms = if smoke {
        vec![8usize, 16, 32, 64, 128]
    } else {
        vec![16usize, 32, 64, 128, 256, 512]
    };
    let json_path = args
        .get("--json")
        .unwrap_or("BENCH_centers.json")
        .to_string();

    // imbalanced design: 3% rare mass scattered over distant sub-clusters
    // -> strongly non-uniform leverage scores (see synth::rare_cluster)
    let mut rng = Rng::new(70);
    let data = synth::rare_cluster(&mut rng, n + n / 4, 8, 0.03);
    let (train, test) = data.split(0.2, &mut rng);

    let mut table = Table::new(
        "Ablation A4: uniform vs approx-leverage-score centers (test MSE)",
        &["M", "uniform", "leverage", "lev/uni"],
    );
    let mut crossover_seen = false;
    let mut sweep: Vec<(usize, f64, f64)> = Vec::new();
    for &m in &ms {
        let mut mses = [Vec::new(), Vec::new()];
        for &seed in &seeds {
            for (i, centers) in [
                Centers::Uniform,
                // pilot must be big enough to see the rare sub-clusters
                Centers::ApproxLeverage { sketch: pilot(m) },
            ]
            .into_iter()
            .enumerate()
            {
                let cfg = config(m, sigma, lam, centers, seed);
                let model = fit(&engine, &train.x, &train.y, &cfg)?;
                let mse = metrics::mse(&model.predict(&engine, &test.x)?, &test.y);
                mses[i].push(mse);
            }
        }
        let mean = |v: &Vec<f64>| v.iter().sum::<f64>() / v.len() as f64;
        let (u, l) = (mean(&mses[0]), mean(&mses[1]));
        if l < u * 0.97 {
            crossover_seen = true;
        }
        sweep.push((m, u, l));
        table.row(&[
            format!("{m}"),
            format!("{u:.5}"),
            format!("{l:.5}"),
            format!("{:.2}", l / u),
        ]);
    }
    table.print();

    // equal-accuracy-at-smaller-M gate: the smallest M where leverage
    // reaches uniform's best mean MSE over the whole sweep (with SLACK)
    let (uni_best_m, uni_best) = sweep
        .iter()
        .map(|&(m, u, _)| (m, u))
        .min_by(|a, b| a.1.total_cmp(&b.1))
        .expect("non-empty sweep");
    let crossover_m = sweep
        .iter()
        .find(|&&(m, _, l)| m < uni_best_m && l <= SLACK * uni_best)
        .map(|&(m, _, _)| m);
    println!(
        "\nuniform best: {uni_best:.5} at M={uni_best_m}; leverage reaches it (x{SLACK}) at M={crossover_m:?}"
    );

    // streamed leg at a mid-sweep M: the DataSource pipeline must agree
    // with the in-memory path at equal seed, and streamed leverage must
    // keep its edge over streamed uniform
    let m_mid = ms[ms.len() / 2];
    let chunk = 173;
    let seed = seeds[0];
    let sketch = pilot(m_mid);
    let mut rng_a = Rng::new(seed);
    let mem_scores = approx_leverage_scores(
        &engine,
        &train.x,
        Kernel::Gaussian,
        sigma,
        lam,
        sketch,
        &mut rng_a,
    )?;
    let mut src = MemSource::new(train.clone(), chunk);
    let mut rng_b = Rng::new(seed);
    let src_scores = approx_leverage_scores_source(
        &engine,
        &mut src,
        Kernel::Gaussian,
        sigma,
        lam,
        sketch,
        &mut rng_b,
    )?;
    let scores_diff = max_abs_diff(&mem_scores, &src_scores);

    let lev_cfg = config(m_mid, sigma, lam, Centers::ApproxLeverage { sketch }, seed);
    let mem_model = fit(&engine, &train.x, &train.y, &lev_cfg)?;
    let src_model = fit_source(
        &engine,
        Box::new(MemSource::new(train.clone(), chunk)),
        &lev_cfg,
    )?;
    let mem_preds = mem_model.predict(&engine, &test.x)?;
    let src_preds = src_model.predict(&engine, &test.x)?;
    let pred_diff = max_abs_diff(&mem_preds, &src_preds);
    let stream_lev_mse = metrics::mse(&src_preds, &test.y);

    let uni_cfg = config(m_mid, sigma, lam, Centers::Uniform, seed);
    let uni_model = fit_source(
        &engine,
        Box::new(MemSource::new(train.clone(), chunk)),
        &uni_cfg,
    )?;
    let stream_uni_mse = metrics::mse(&uni_model.predict(&engine, &test.x)?, &test.y);
    println!(
        "streamed leg (M={m_mid}, chunk={chunk}): scores diff {scores_diff:.2e}, pred diff {pred_diff:.2e}, MSE lev {stream_lev_mse:.5} vs uni {stream_uni_mse:.5}"
    );

    let report = Value::obj(vec![
        ("schema", Value::str("falkon/bench_centers/v1")),
        ("smoke", Value::Bool(smoke)),
        ("n_train", Value::num(train.n() as f64)),
        ("sigma", Value::num(sigma)),
        ("lam", Value::num(lam)),
        ("seeds", Value::num(seeds.len() as f64)),
        ("slack", Value::num(SLACK)),
        (
            "sweep",
            Value::arr(
                sweep
                    .iter()
                    .map(|&(m, u, l)| {
                        Value::obj(vec![
                            ("m", Value::num(m as f64)),
                            ("sketch", Value::num(pilot(m) as f64)),
                            ("uniform_mse", Value::num(u)),
                            ("leverage_mse", Value::num(l)),
                            ("ratio", Value::num(l / u)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("uni_best_mse", Value::num(uni_best)),
        ("uni_best_m", Value::num(uni_best_m as f64)),
        (
            "leverage_crossover_m",
            crossover_m.map_or(Value::Null, |m| Value::num(m as f64)),
        ),
        ("crossover_at_smaller_m", Value::Bool(crossover_m.is_some())),
        (
            "stream",
            Value::obj(vec![
                ("m", Value::num(m_mid as f64)),
                ("chunk_rows", Value::num(chunk as f64)),
                ("scores_max_abs_diff", Value::num(scores_diff)),
                ("pred_max_abs_diff", Value::num(pred_diff)),
                ("streamed_leverage_mse", Value::num(stream_lev_mse)),
                ("streamed_uniform_mse", Value::num(stream_uni_mse)),
            ]),
        ),
    ]);
    write_json(&json_path, &report)?;
    println!("wrote {json_path}");

    println!("\npaper target (Thm. 4-5): on designs with non-uniform leverage scores, leverage-score sampling needs smaller M for the same accuracy (ratio < 1 at small M, converging to 1 as M grows).");
    assert!(
        crossover_seen,
        "leverage-score sampling never beat uniform on the rare-cluster design"
    );
    assert!(
        crossover_m.is_some(),
        "leverage never reached uniform's best MSE ({uni_best:.5} at M={uni_best_m}) at a smaller M"
    );
    assert!(
        scores_diff <= 1e-8,
        "streamed leverage scores drifted from in-memory: {scores_diff:.3e}"
    );
    assert!(
        pred_diff <= 1e-8,
        "streamed leverage fit drifted from in-memory: {pred_diff:.3e}"
    );
    Ok(())
}

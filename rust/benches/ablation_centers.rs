//! **A4 (Thm. 4-5 / Sect. 4.2)** — leverage-score vs uniform center
//! selection: on a low-effective-dimension design (strongly non-uniform
//! leverage scores), approximate-leverage-score sampling should reach a
//! given accuracy with fewer centers M than uniform sampling.
//!
//! Runs on the rust engine so M can sweep freely below the compiled
//! artifact sizes (the math is identical; cross-engine equality is
//! covered by rust/tests/integration.rs).

mod common;

use falkon::bench::{BenchArgs, Table};
use falkon::data::synth;
use falkon::falkon::{fit, Centers, FalkonConfig};
use falkon::kernels::Kernel;
use falkon::metrics;
use falkon::runtime::Engine;
use falkon::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::from_env();
    let engine = Engine::rust();
    let n = common::scale(&args, 6_000);
    let lam = 1e-4;
    let sigma = 1.0;
    let seeds = [71u64, 72, 73, 74, 75, 76];
    let ms = if args.flag("--smoke") {
        vec![8usize, 16, 32]
    } else {
        vec![8usize, 16, 32, 64, 128, 256]
    };

    // imbalanced design: 3% rare distant cluster -> strongly non-uniform
    // leverage scores (see synth::rare_cluster)
    let mut rng = Rng::new(70);
    let data = synth::rare_cluster(&mut rng, n + n / 4, 8, 0.03);
    let (train, test) = data.split(0.2, &mut rng);

    let mut table = Table::new(
        "Ablation A4: uniform vs approx-leverage-score centers (test MSE)",
        &["M", "uniform", "leverage", "lev/uni"],
    );
    let mut crossover_seen = false;
    for &m in &ms {
        let mut mses = [Vec::new(), Vec::new()];
        for &seed in &seeds {
            for (i, centers) in [
                Centers::Uniform,
                Centers::ApproxLeverage {
                    // pilot must be big enough to see the rare cluster
                    sketch: (8 * m).clamp(256, 512),
                },
            ]
            .into_iter()
            .enumerate()
            {
                let cfg = FalkonConfig {
                    kernel: Kernel::Gaussian,
                    sigma,
                    lam,
                    m,
                    t: 40,
                    tol: 1e-10,
                    centers,
                    seed,
                    ..Default::default()
                };
                let model = fit(&engine, &train.x, &train.y, &cfg)?;
                let mse = metrics::mse(&model.predict(&engine, &test.x)?, &test.y);
                mses[i].push(mse);
            }
        }
        let mean = |v: &Vec<f64>| v.iter().sum::<f64>() / v.len() as f64;
        let (u, l) = (mean(&mses[0]), mean(&mses[1]));
        if l < u * 0.97 {
            crossover_seen = true;
        }
        table.row(&[
            format!("{m}"),
            format!("{u:.5}"),
            format!("{l:.5}"),
            format!("{:.2}", l / u),
        ]);
    }
    table.print();
    println!("\npaper target (Thm. 4-5): on designs with non-uniform leverage scores, leverage-score sampling needs smaller M for the same accuracy (ratio < 1 at small M, converging to 1 as M grows).");
    assert!(
        crossover_seen,
        "leverage-score sampling never beat uniform on the low-effective-dim design"
    );
    Ok(())
}

//! **A2 (Thm. 1)** — the excess-risk gap between the FALKON iterate and
//! the exact Nyström estimator decays exponentially, ~e^{-νt} with
//! ν ≥ 1/2 in the Thm. 2 regime. This bench traces the gap per iteration
//! in *prediction space* and fits ν from the log-linear tail.

mod common;

use falkon::baselines::nystrom_direct;
use falkon::bench::{loglog_slope, BenchArgs, Table};
use falkon::data::synth;
use falkon::falkon::{fit_with_callback, FalkonConfig};
use falkon::kernels::Kernel;
use falkon::linalg::vec_ops::rel_diff;
use falkon::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::from_env();
    let engine = common::bench_engine();
    let n = common::scale(&args, 8_000);
    let mut rng = Rng::new(51);
    let mut data = synth::smooth_regression(&mut rng, n, 5, 0.05);
    // zero-mean targets so the centered/uncentered paths coincide
    let ybar = falkon::linalg::vec_ops::mean(&data.y);
    for v in &mut data.y {
        *v -= ybar;
    }
    let nf = data.x.rows as f64;
    let lam = 1.0 / nf.sqrt();
    let m = 512;
    let sigma = 1.5;
    let t_max = 24;

    // exact Nyström with identical centers (same seed stream)
    let direct = nystrom_direct::fit(
        &engine, &data.x, &data.y, Kernel::Gaussian, sigma, lam, m, &mut Rng::new(9),
    )?;
    let target = direct.predict(&engine, &data.x)?;

    let mut alphas: Vec<Vec<f64>> = Vec::new();
    let mut cb = |_k: usize, a: &[f64]| alphas.push(a.to_vec());
    let cfg = FalkonConfig {
        kernel: Kernel::Gaussian,
        sigma,
        lam,
        m,
        t: t_max,
        seed: 9,
        eps: 1e-12,
        center_y: false, // gap measured against the uncentered Nyström solve
        ..Default::default()
    };
    let model = fit_with_callback(&engine, &data.x, &data.y, &cfg, Some(&mut cb))?;
    assert_eq!(model.centers.data, direct.centers.data);

    let mut table = Table::new(
        "Ablation A2: ‖f_t − f_Nyström‖ / ‖f_Nyström‖ per CG iteration",
        &["t", "gap", "log-gap"],
    );
    let mut ts = Vec::new();
    let mut gaps = Vec::new();
    for (k, alpha) in alphas.iter().enumerate() {
        let p = engine.predict(Kernel::Gaussian, &data.x, &model.centers, alpha, sigma)?;
        let gap = rel_diff(&p, &target).max(1e-16);
        table.row(&[
            format!("{}", k + 1),
            format!("{gap:.3e}"),
            format!("{:.2}", gap.ln()),
        ]);
        if gap > 1e-12 {
            ts.push((k + 1) as f64);
            gaps.push(gap);
        }
    }
    table.print();

    // fit gap ≈ C·e^{-νt} on the decaying segment: ν = -d(ln gap)/dt
    let take = ts.len().min(12).max(2);
    let lin: Vec<f64> = gaps[..take].iter().map(|g| g.ln()).collect();
    let tseg: Vec<f64> = ts[..take].to_vec();
    // linear (not log-log) slope of ln(gap) vs t:
    let mt = tseg.iter().sum::<f64>() / take as f64;
    let mg = lin.iter().sum::<f64>() / take as f64;
    let mut num = 0.0;
    let mut den = 0.0;
    for i in 0..take {
        num += (tseg[i] - mt) * (lin[i] - mg);
        den += (tseg[i] - mt) * (tseg[i] - mt);
    }
    let nu = -num / den;
    println!("\nfitted exponential rate ν = {nu:.3}  (Thm. 2 target: ν ≥ 0.5)");
    let _ = loglog_slope; // (log-log helper used by other benches)
    assert!(nu >= 0.4, "ν = {nu} too small — preconditioning not effective");
    Ok(())
}

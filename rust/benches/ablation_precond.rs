//! **A1 (Thm. 2 / Sect. 3)** — what the preconditioner does to the
//! condition number and to iterations-to-convergence.
//!
//! For a sweep of (λ, M) this bench materializes the preconditioned
//! operator W = BᵀHB column-by-column, measures its extreme eigenvalues
//! (power iteration on W and on σmax·I − W), and counts CG iterations to
//! a fixed residual tolerance with and without the preconditioner.
//!
//! Paper targets: cond(W) = O(1) (≤ ~17, ν ≥ 1/2) once M ≳ 1/λ, giving
//! O(log n) iterations, while the plain system's condition number (and
//! its iteration count) explodes as λ shrinks.

mod common;

use falkon::bench::{BenchArgs, Table};
use falkon::data::synth;
use falkon::falkon::{conjgrad, prepare, CgOptions, FalkonConfig};
use falkon::kernels::Kernel;
use falkon::linalg::gemm;
use falkon::linalg::mat::Mat;
use falkon::util::rng::Rng;

/// Extreme eigenvalues of a dense symmetric PSD matrix via power
/// iteration (λmax) and shifted power iteration (λmin).
fn eig_extremes(w: &Mat, rng: &mut Rng) -> (f64, f64) {
    let m = w.rows;
    let power = |mat: &dyn Fn(&[f64]) -> Vec<f64>, rng: &mut Rng| -> f64 {
        let mut v = rng.normals(m);
        let mut lam = 0.0;
        for _ in 0..200 {
            let nrm = falkon::linalg::vec_ops::norm2(&v).max(1e-300);
            for x in &mut v {
                *x /= nrm;
            }
            let wv = mat(&v);
            lam = falkon::linalg::vec_ops::dot(&v, &wv);
            v = wv;
        }
        lam
    };
    let lmax = power(&|v| gemm::matvec(w, v), rng);
    // λmin(W) = lmax_shift − λmax(lmax·I − W)
    let shifted = power(
        &|v| {
            let wv = gemm::matvec(w, v);
            v.iter().zip(&wv).map(|(a, b)| lmax * a - b).collect()
        },
        rng,
    );
    (lmax, (lmax - shifted).max(1e-12))
}

fn materialize<'p, 'a>(apply: impl Fn(&[f64]) -> Vec<f64>, m: usize) -> Mat {
    let mut w = Mat::zeros(m, m);
    for j in 0..m {
        let mut e = vec![0.0; m];
        e[j] = 1.0;
        let col = apply(&e);
        for i in 0..m {
            w[(i, j)] = col[i];
        }
    }
    w
}

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::from_env();
    let engine = common::bench_engine();
    // keep n above the largest M in the sweep even in smoke mode
    let n = common::scale(&args, 8_000).max(2_200);
    let mut rng = Rng::new(41);
    let data = synth::smooth_regression(&mut rng, n, 5, 0.05);
    let nf = data.x.rows as f64;

    let mut table = Table::new(
        "Ablation A1: preconditioning vs condition number (Thm. 2)",
        &[
            "λ",
            "M",
            "M·λ",
            "cond(W) precond",
            "cond plain",
            "iters precond",
            "iters plain",
        ],
    );

    let lams = [1.0 / nf.sqrt(), 1e-3, 1e-4];
    let ms = [256usize, 512, 1024];
    for &lam in &lams {
        for &m in &ms {
            let cfg = FalkonConfig {
                kernel: Kernel::Gaussian,
                sigma: 1.5,
                lam,
                m,
                t: 1,
                seed: 9,
                eps: 1e-12,
                ..Default::default()
            };
            let state = prepare(&engine, &data.x, &cfg)?;
            let bhb = state.bhb();
            // preconditioned operator
            let w = materialize(|v| bhb.apply(v).unwrap(), m);
            let (wmax, wmin) = eig_extremes(&w, &mut rng);
            let cond_w = wmax / wmin;
            // plain operator H/n (same spectrum shape as H)
            let kmm = engine.kmm(Kernel::Gaussian, &state.sel.c, 1.5)?;
            let plain = |v: &[f64]| {
                let mut hv = state.plan.apply(v, None).unwrap();
                let kv = gemm::matvec(&kmm, v);
                for j in 0..m {
                    hv[j] = hv[j] / nf + lam * kv[j];
                }
                hv
            };
            let h = materialize(plain, m);
            let (hmax, hmin) = eig_extremes(&h, &mut rng);
            let cond_h = hmax / hmin;

            // iterations to residual 1e-8 on the shared rhs
            let y = &data.y;
            let r_pre = bhb.rhs(y)?;
            let pre = conjgrad(
                |p| bhb.apply(p),
                &r_pre,
                CgOptions {
                    t_max: 1500,
                    tol: 1e-8,
                },
                None,
            )?;
            let zeros = vec![0.0; m];
            let yn: Vec<f64> = y.iter().map(|v| v / nf).collect();
            let z = state.plan.apply(&zeros, Some(&yn))?;
            let pl = conjgrad(
                |p| Ok(plain(p)),
                &z,
                CgOptions {
                    t_max: 1500,
                    tol: 1e-8,
                },
                None,
            )?;
            let iters_str = |r: &falkon::falkon::CgResult| {
                if r.converged {
                    format!("{}", r.iters)
                } else {
                    format!(">{}", r.iters)
                }
            };
            table.row(&[
                format!("{lam:.1e}"),
                format!("{m}"),
                format!("{:.1}", m as f64 * lam),
                format!("{cond_w:.1}"),
                format!("{cond_h:.2e}"),
                iters_str(&pre),
                iters_str(&pl),
            ]);
            // Thm. 2 regime check: M >= ~1/λ ⇒ cond(W) small
            if m as f64 * lam >= 5.0 {
                assert!(cond_w < 17.0, "λ={lam} M={m}: cond(W)={cond_w}");
            }
        }
    }
    table.print();
    println!("\npaper target: cond(W) ≤ ~17 (ν ≥ 1/2) once M ≳ 1/λ; plain-system condition number and iterations explode as λ → 0 while FALKON's stay O(1)/O(log n).");
    Ok(())
}

//! **A3 (Thm. 3)** — statistical rate: with λ = 1/√n, M ≈ √n·log n and
//! t ≈ log n, FALKON's excess risk decays as n^{-1/2}. We measure test
//! MSE minus the (known) noise floor on a source-condition-satisfying
//! synthetic across n and fit the log-log slope; target ≈ −0.5 (up to
//! finite-sample noise — we accept [−0.8, −0.25] and, more importantly,
//! monotone decay).

mod common;

use falkon::bench::{loglog_slope, BenchArgs, Table};
use falkon::data::synth;
use falkon::falkon::{fit, FalkonConfig};
use falkon::kernels::Kernel;
use falkon::metrics;
use falkon::util::rng::Rng;

fn artifact_m(target: usize) -> usize {
    *[256usize, 512, 1024, 2048]
        .iter()
        .min_by_key(|&&m| m.abs_diff(target))
        .unwrap()
}

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::from_env();
    let engine = common::bench_engine();
    let noise = 0.1f64;
    let ns: Vec<usize> = if args.flag("--smoke") {
        vec![500, 1000, 2000]
    } else {
        vec![1000, 2000, 4000, 8000, 16000, 32000]
    };
    let seeds = [61u64, 62, 63];

    let mut table = Table::new(
        "Ablation A3: excess risk vs n (λ=1/√n, M=√n·log n, t=log n)",
        &["n", "M", "test MSE", "excess risk", "±"],
    );
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for &n in &ns {
        let mut excesses = Vec::new();
        let mut m_used = 0;
        for &seed in &seeds {
            let mut rng = Rng::new(seed ^ n as u64);
            let data = synth::smooth_regression(&mut rng, n + n / 4, 4, noise);
            let (train, test) = data.split(0.2, &mut rng);
            let nf = train.n() as f64;
            m_used = artifact_m((nf.sqrt() * nf.ln()) as usize);
            let cfg = FalkonConfig {
                kernel: Kernel::Gaussian,
                sigma: 1.5,
                lam: 1.0 / nf.sqrt(),
                m: m_used,
                t: (0.5 * nf.ln()).ceil() as usize + 5,
                seed,
                ..Default::default()
            };
            let model = fit(&engine, &train.x, &train.y, &cfg)?;
            let mse = metrics::mse(&model.predict(&engine, &test.x)?, &test.y);
            excesses.push((mse - noise * noise).max(1e-9));
        }
        let mean = excesses.iter().sum::<f64>() / excesses.len() as f64;
        let sd = (excesses
            .iter()
            .map(|e| (e - mean) * (e - mean))
            .sum::<f64>()
            / excesses.len() as f64)
            .sqrt();
        table.row(&[
            format!("{n}"),
            format!("{m_used}"),
            format!("{:.5}", mean + noise * noise),
            format!("{mean:.5}"),
            format!("{sd:.5}"),
        ]);
        xs.push(n as f64);
        ys.push(mean);
    }
    table.print();
    let slope = loglog_slope(&xs, &ys);
    println!("\nexcess-risk log-log slope: {slope:.3}  (Thm. 3 target: −0.5)");
    assert!(
        ys.last().unwrap() < ys.first().unwrap(),
        "excess risk must decay with n"
    );
    assert!(
        (-1.1..=-0.15).contains(&slope),
        "slope {slope} outside plausible band around −0.5"
    );
    Ok(())
}

//! Shared helpers for the bench targets (harness = false).
#![allow(dead_code)] // each bench uses a subset of these helpers

use falkon::bench::BenchArgs;
use falkon::runtime::Engine;

/// Engine for benches: XLA artifacts when built, rust otherwise (the
/// tables note which engine ran).
pub fn bench_engine() -> Engine {
    match Engine::xla_default() {
        Ok(e) => e,
        Err(err) => {
            eprintln!("[bench] artifacts unavailable ({err}); using rust engine");
            Engine::rust()
        }
    }
}

/// Smoke mode shrinks problem sizes so `cargo bench` can be validated
/// quickly: `FALKON_BENCH_SMOKE=1 cargo bench` or `-- --smoke`.
pub fn scale(args: &BenchArgs, full: usize) -> usize {
    if args.flag("--smoke") {
        (full / 8).max(600)
    } else {
        full
    }
}

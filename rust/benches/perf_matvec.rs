//! **P1 (§Perf)** — hot-path throughput of the blocked Nyström matvec
//! (the op that dominates every fit): engines × shapes, reporting time
//! per apply, kernel evaluations/s and effective GFLOP/s, a rust-engine
//! worker sweep, and a fit phase breakdown. Emits the machine-readable
//! `BENCH_matvec.json` (override with `--json <path>`) so the perf
//! trajectory is tracked from PR to PR — this is the measurement harness
//! behind EXPERIMENTS.md §Perf and the ≥3× apply acceptance gate.

mod common;

use falkon::bench::{fmt_secs, time_fn, write_json, BenchArgs, Table};
use falkon::data::synth;
use falkon::falkon::{fit, FalkonConfig};
use falkon::kernels::{tol, Kernel};
use falkon::linalg::mat::Mat;
use falkon::linalg::mat32::{Dtype, MatF32};
use falkon::linalg::vec_ops::max_abs_diff;
use falkon::runtime::{Engine, EngineOptions, Impl, Isa, SimdMode};
use falkon::util::json::Value;
use falkon::util::rng::Rng;

/// ~flops per gaussian kernel evaluation with the matmul expansion:
/// 2d (cross term) + ~6 tail ops.
fn flops_per_eval(d: usize) -> f64 {
    (2 * d + 6) as f64
}

fn engines() -> Vec<(String, Engine)> {
    let mut out = Vec::new();
    if let Ok(e) = Engine::xla(EngineOptions {
        imp: Impl::Pallas,
        workers: 1,
        ..Default::default()
    }) {
        out.push(("xla/pallas".to_string(), e));
    }
    if let Ok(e) = Engine::xla(EngineOptions {
        imp: Impl::Jnp,
        workers: 1,
        ..Default::default()
    }) {
        out.push(("xla/jnp".to_string(), e));
    }
    out.push(("rust".to_string(), Engine::rust()));
    out
}

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::from_env();
    let n = common::scale(&args, 32_768);
    let reps = if args.flag("--smoke") { 2 } else { 5 };
    let json_path = args.get("--json").unwrap_or("BENCH_matvec.json").to_string();

    let mut table = Table::new(
        "P1: blocked Nyström matvec throughput (one BHB data pass)",
        &["engine", "n", "M", "d", "workers", "t/apply", "Gevals/s", "GFLOP/s"],
    );
    let mut apply_records: Vec<Value> = Vec::new();

    // (10, 1024) is the acceptance shape: apply latency there gates PRs
    for (d, m) in [(10usize, 1024usize), (32, 512), (32, 2048), (128, 1024)] {
        let mut rng = Rng::new(81);
        let x = Mat::from_vec(n, d, rng.normals(n * d));
        let c = x.select_rows(&rng.choose(n, m));
        let u = rng.normals(m);
        for (name, engine) in engines() {
            let plan = engine.matvec_plan(Kernel::Gaussian, &x, &c, 1.0)?;
            let evals = plan.kernel_evals_per_apply() as f64;
            let stats = time_fn(1, reps, || {
                let _ = plan.apply(&u, None).unwrap();
            });
            table.row(&[
                name.clone(),
                format!("{n}"),
                format!("{m}"),
                format!("{d}"),
                "1".to_string(),
                fmt_secs(stats.median),
                format!("{:.2}", evals / stats.median / 1e9),
                format!("{:.1}", evals * flops_per_eval(d) / stats.median / 1e9),
            ]);
            apply_records.push(Value::obj(vec![
                ("engine", Value::str(name.clone())),
                ("kernel", Value::str("gaussian")),
                ("n", Value::num(n as f64)),
                ("m", Value::num(m as f64)),
                ("d", Value::num(d as f64)),
                ("workers", Value::num(1.0)),
                ("apply", stats.to_json()),
                ("evals_per_apply", Value::num(evals)),
                ("evals_per_s", Value::num(evals / stats.median)),
                (
                    "gflops",
                    Value::num(evals * flops_per_eval(d) / stats.median / 1e9),
                ),
            ]));
        }
    }
    table.print();

    // rust-engine worker sweep on the acceptance shape (d=10, M=1024)
    let mut sweep_records: Vec<Value> = Vec::new();
    {
        let (d, m) = (10usize, 1024usize.min(n / 2));
        let mut rng = Rng::new(83);
        let x = Mat::from_vec(n, d, rng.normals(n * d));
        let c = x.select_rows(&rng.choose(n, m));
        let u = rng.normals(m);
        let mut wtable = Table::new(
            "P1b: rust engine worker sweep (gaussian, d=10)",
            &["workers", "t/apply", "Gevals/s", "speedup"],
        );
        let mut base = f64::NAN;
        for workers in [1usize, 2, 4, 8] {
            let eng = Engine::rust_with(EngineOptions {
                imp: Impl::Pallas,
                workers,
                ..Default::default()
            });
            let plan = eng.matvec_plan(Kernel::Gaussian, &x, &c, 1.0)?;
            let evals = plan.kernel_evals_per_apply() as f64;
            let stats = time_fn(1, reps, || {
                let _ = plan.apply(&u, None).unwrap();
            });
            if workers == 1 {
                base = stats.median;
            }
            let speedup = base / stats.median;
            wtable.row(&[
                format!("{workers}"),
                fmt_secs(stats.median),
                format!("{:.2}", evals / stats.median / 1e9),
                format!("{speedup:.2}x"),
            ]);
            sweep_records.push(Value::obj(vec![
                ("workers", Value::num(workers as f64)),
                ("n", Value::num(n as f64)),
                ("m", Value::num(m as f64)),
                ("d", Value::num(d as f64)),
                ("apply", stats.to_json()),
                ("evals_per_s", Value::num(evals / stats.median)),
                ("speedup_vs_1", Value::num(speedup)),
            ]));
        }
        wtable.print();
    }

    // mixed-precision leg: rust plans with f32 row-block storage against
    // the f64 baseline — speedup from halved panel-stream bandwidth, and
    // max-abs-error against the f64 oracle **on the same rounded values**
    // asserted within the documented tolerance model (kernels::tol), not
    // an ad-hoc epsilon. CI gates on the JSON: best speedup ≥ 1.3x.
    let mut mixed_records: Vec<Value> = Vec::new();
    {
        let mut mtable = Table::new(
            "P1c: mixed precision (rust engine, f32 storage / f64 accumulation)",
            &["kernel", "d", "M", "t/apply f64", "t/apply f32", "speedup", "max|err|", "bound"],
        );
        for (d, m) in [(10usize, 1024usize.min(n / 2)), (128, 1024usize.min(n / 2))] {
            let mut rng = Rng::new(84);
            let x = Mat::from_vec(n, d, rng.normals(n * d));
            let c = x.select_rows(&rng.choose(n, m));
            let u = rng.normals(m);
            let eng64 = Engine::rust();
            let eng32 = Engine::rust_with(EngineOptions {
                dtype: Dtype::F32,
                ..Default::default()
            });
            let plan64 = eng64.matvec_plan(Kernel::Gaussian, &x, &c, 1.0)?;
            let plan32 = eng32.matvec_plan(Kernel::Gaussian, &x, &c, 1.0)?;
            let s64 = time_fn(1, reps, || {
                let _ = plan64.apply(&u, None).unwrap();
            });
            let s32 = time_fn(1, reps, || {
                let _ = plan32.apply(&u, None).unwrap();
            });
            // accuracy: compare against the f64 plan rebuilt on the
            // rounded-and-widened inputs, so storage rounding (measured
            // by the e2e RMSE tests) is excluded and the bound applies
            let xr = MatF32::from_mat(&x);
            let cr = MatF32::from_mat(&c);
            let oracle = eng64.matvec_plan(Kernel::Gaussian, &xr.to_mat(), &cr.to_mat(), 1.0)?;
            let want = oracle.apply(&u, None)?;
            let got = plan32.apply(&u, None)?;
            let err = max_abs_diff(&got, &want);
            let bound = tol::matvec_bound(Kernel::Gaussian, &xr, &cr, x.rows, &u, None);
            anyhow::ensure!(
                err <= bound,
                "f32 apply error {err:.3e} above the documented bound {bound:.3e} (d={d} M={m})"
            );
            let speedup = s64.median / s32.median;
            mtable.row(&[
                "gaussian".into(),
                format!("{d}"),
                format!("{m}"),
                fmt_secs(s64.median),
                fmt_secs(s32.median),
                format!("{speedup:.2}x"),
                format!("{err:.2e}"),
                format!("{bound:.2e}"),
            ]);
            mixed_records.push(Value::obj(vec![
                ("kernel", Value::str("gaussian")),
                ("n", Value::num(n as f64)),
                ("m", Value::num(m as f64)),
                ("d", Value::num(d as f64)),
                ("apply_f64", s64.to_json()),
                ("apply_f32", s32.to_json()),
                ("speedup", Value::num(speedup)),
                ("max_abs_err", Value::num(err)),
                ("err_bound", Value::num(bound)),
                ("within_model", Value::Bool(err <= bound)),
            ]));
        }
        mtable.print();
    }

    // SIMD leg: the best runtime-detected panel arm against the forced
    // scalar tiles, on both storage tiers — speedup from the explicit
    // AVX2/NEON panels, max-abs-error asserted within the documented
    // SIMD tolerance model (kernels::tol). CI gates on the JSON: best
    // f32 speedup ≥ 1.5x (≥ 1.15x f64 when no f32 records exist); the
    // leg records but does not gate when the host has no vector arm.
    let simd_isa = Isa::detect_best();
    let mut simd_records: Vec<Value> = Vec::new();
    {
        let force = match simd_isa {
            Isa::Scalar => SimdMode::Scalar,
            Isa::Avx2 => SimdMode::Avx2,
            Isa::Neon => SimdMode::Neon,
        };
        let mut stable = Table::new(
            "P1d: SIMD panels vs scalar tiles (rust engine)",
            &["dtype", "d", "M", "t/apply scalar", "t/apply simd", "speedup", "max|err|", "bound"],
        );
        for (dtype, dname) in [(Dtype::F64, "f64"), (Dtype::F32, "f32")] {
            for (d, m) in [(10usize, 1024usize.min(n / 2)), (128, 1024usize.min(n / 2))] {
                let mut rng = Rng::new(85);
                let x = Mat::from_vec(n, d, rng.normals(n * d));
                let c = x.select_rows(&rng.choose(n, m));
                let u = rng.normals(m);
                let eng_simd = Engine::rust_with(EngineOptions {
                    dtype,
                    simd: force,
                    ..Default::default()
                });
                let eng_scalar = Engine::rust_with(EngineOptions {
                    dtype,
                    simd: SimdMode::Scalar,
                    ..Default::default()
                });
                let plan_simd = eng_simd.matvec_plan(Kernel::Gaussian, &x, &c, 1.0)?;
                let plan_scalar = eng_scalar.matvec_plan(Kernel::Gaussian, &x, &c, 1.0)?;
                let t_simd = time_fn(1, reps, || {
                    let _ = plan_simd.apply(&u, None).unwrap();
                });
                let t_scalar = time_fn(1, reps, || {
                    let _ = plan_scalar.apply(&u, None).unwrap();
                });
                let got = plan_simd.apply(&u, None)?;
                let want = plan_scalar.apply(&u, None)?;
                let err = max_abs_diff(&got, &want);
                // f64 tier: the dedicated SIMD-vs-scalar reassociation
                // bound; f32 tier: both arms round identically staged
                // arguments to f32, so the (larger) f32-vs-f64 compute
                // bound is a valid conservative ceiling
                let bound = match dtype {
                    Dtype::F64 => tol::simd_matvec_bound(Kernel::Gaussian, &x, &c, 1.0, &u, None),
                    Dtype::F32 => {
                        let xr = MatF32::from_mat(&x);
                        let cr = MatF32::from_mat(&c);
                        tol::matvec_bound(Kernel::Gaussian, &xr, &cr, x.rows, &u, None)
                    }
                };
                anyhow::ensure!(
                    err <= bound,
                    "SIMD {dname} apply error {err:.3e} above the documented bound \
                     {bound:.3e} (d={d} M={m}, isa={})",
                    simd_isa.name()
                );
                let speedup = t_scalar.median / t_simd.median;
                stable.row(&[
                    dname.into(),
                    format!("{d}"),
                    format!("{m}"),
                    fmt_secs(t_scalar.median),
                    fmt_secs(t_simd.median),
                    format!("{speedup:.2}x"),
                    format!("{err:.2e}"),
                    format!("{bound:.2e}"),
                ]);
                simd_records.push(Value::obj(vec![
                    ("kernel", Value::str("gaussian")),
                    ("isa", Value::str(simd_isa.name())),
                    ("dtype", Value::str(dname)),
                    ("n", Value::num(n as f64)),
                    ("m", Value::num(m as f64)),
                    ("d", Value::num(d as f64)),
                    ("apply_scalar", t_scalar.to_json()),
                    ("apply_simd", t_simd.to_json()),
                    ("speedup", Value::num(speedup)),
                    ("max_abs_err", Value::num(err)),
                    ("err_bound", Value::num(bound)),
                    ("within_model", Value::Bool(err <= bound)),
                ]));
            }
        }
        stable.print();
    }

    let report = Value::obj(vec![
        ("schema", Value::str("falkon/bench_matvec/v4")),
        ("n", Value::num(n as f64)),
        ("reps", Value::num(reps as f64)),
        ("smoke", Value::Bool(args.flag("--smoke"))),
        ("simd_isa", Value::str(simd_isa.name())),
        ("apply", Value::arr(apply_records)),
        ("workers_sweep", Value::arr(sweep_records)),
        ("mixed", Value::arr(mixed_records)),
        ("simd", Value::arr(simd_records)),
    ]);
    write_json(&json_path, &report)?;
    println!("\nwrote {json_path}");

    // fit phase breakdown on the default path
    let engine = common::bench_engine();
    let mut rng = Rng::new(82);
    let data = synth::smooth_regression(&mut rng, n, 10, 0.1);
    let cfg = FalkonConfig {
        kernel: Kernel::Gaussian,
        sigma: 2.0,
        lam: 1.0 / (n as f64).sqrt(),
        m: 1024,
        t: 15,
        seed: 1,
        ..Default::default()
    };
    let model = fit(&engine, &data.x, &data.y, &cfg)?;
    println!(
        "\nfit phase breakdown ({} engine, n={n}, M=1024, t=15):\n{}",
        engine.name(),
        model.phases.report()
    );
    Ok(())
}

//! **P1 (§Perf)** — hot-path throughput of the blocked Nyström matvec
//! (the op that dominates every fit): engines × shapes, reporting time
//! per apply, kernel evaluations/s and effective GFLOP/s, plus a fit
//! phase breakdown. This is the measurement harness behind
//! EXPERIMENTS.md §Perf.

mod common;

use falkon::bench::{fmt_secs, time_fn, BenchArgs, Table};
use falkon::data::synth;
use falkon::falkon::{fit, FalkonConfig};
use falkon::kernels::Kernel;
use falkon::linalg::mat::Mat;
use falkon::runtime::{Engine, EngineOptions, Impl};
use falkon::util::rng::Rng;

/// ~flops per gaussian kernel evaluation with the matmul expansion:
/// 2d (cross term) + ~6 tail ops.
fn flops_per_eval(d: usize) -> f64 {
    (2 * d + 6) as f64
}

fn engines() -> Vec<(String, Engine)> {
    let mut out = Vec::new();
    if let Ok(e) = Engine::xla(EngineOptions {
        imp: Impl::Pallas,
        workers: 1,
    }) {
        out.push(("xla/pallas".to_string(), e));
    }
    if let Ok(e) = Engine::xla(EngineOptions {
        imp: Impl::Jnp,
        workers: 1,
    }) {
        out.push(("xla/jnp".to_string(), e));
    }
    out.push(("rust".to_string(), Engine::rust()));
    out
}

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::from_env();
    let n = common::scale(&args, 32_768);
    let reps = if args.flag("--smoke") { 2 } else { 5 };

    let mut table = Table::new(
        "P1: blocked Nyström matvec throughput (one BHB data pass)",
        &["engine", "n", "M", "d", "t/apply", "Gevals/s", "GFLOP/s"],
    );

    for (d, m) in [(32usize, 512usize), (32, 2048), (128, 1024)] {
        let mut rng = Rng::new(81);
        let x = Mat::from_vec(n, d, rng.normals(n * d));
        let c = x.select_rows(&rng.choose(n, m));
        let u = rng.normals(m);
        for (name, engine) in engines() {
            let plan = engine.matvec_plan(Kernel::Gaussian, &x, &c, 1.0)?;
            let evals = plan.kernel_evals_per_apply() as f64;
            let stats = time_fn(1, reps, || {
                let _ = plan.apply(&u, None).unwrap();
            });
            table.row(&[
                name.clone(),
                format!("{n}"),
                format!("{m}"),
                format!("{d}"),
                fmt_secs(stats.median),
                format!("{:.2}", evals / stats.median / 1e9),
                format!("{:.1}", evals * flops_per_eval(d) / stats.median / 1e9),
            ]);
        }
    }
    table.print();

    // fit phase breakdown on the default path
    let engine = common::bench_engine();
    let mut rng = Rng::new(82);
    let data = synth::smooth_regression(&mut rng, n, 10, 0.1);
    let cfg = FalkonConfig {
        kernel: Kernel::Gaussian,
        sigma: 2.0,
        lam: 1.0 / (n as f64).sqrt(),
        m: 1024,
        t: 15,
        seed: 1,
        ..Default::default()
    };
    let model = fit(&engine, &data.x, &data.y, &cfg)?;
    println!(
        "\nfit phase breakdown ({} engine, n={n}, M=1024, t=15):\n{}",
        engine.name(),
        model.phases.report()
    );
    Ok(())
}

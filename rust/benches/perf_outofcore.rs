//! **Out-of-core pipeline** (DESIGN.md § "Out-of-core path") — fit and
//! score a synthetic dataset several times larger than the configured
//! chunk budget through the sharded `DataSource` path, against the
//! in-memory path as the baseline. Emits the machine-readable
//! `BENCH_outofcore.json` (override with `--json <path>`):
//!
//! - `max_resident_chunk_bytes` — the peak-RSS proxy: the largest
//!   feature chunk any streamed sweep held resident. The acceptance gate
//!   (asserted in-bench and re-checked from the JSON in CI) is that it
//!   stays **below the full dataset bytes** while predictions agree with
//!   the in-memory fit to ≤ 1e-8.
//! - fit wall-clock and bulk-predict rows/s for both paths (the streamed
//!   path re-reads the shard every CG iteration — the I/O-for-memory
//!   trade the paper's O(n) memory claim is about).
//!
//! A third leg re-encodes the shard as f32 (`--dtype f32` storage) and
//! repeats the streamed fit at the same chunk-row budget: the gate is
//! peak resident chunk bytes **exactly half** the f64 leg's, with
//! predictions within storage-rounding distance of the in-memory fit.
//!
//! `--inject-faults` adds a fault leg: the same streamed fit through a
//! deterministic [`FaultySource`] schedule of transient read faults. The
//! retry layer must absorb every one of them — the gate is that the
//! faulted coefficients are **bitwise identical** to the fault-free
//! streamed fit, with the injected-fault count reported in the JSON.

use falkon::bench::{fmt_secs, time_fn, write_json, BenchArgs, Table};
use falkon::data::shard::{self, ShardSource};
use falkon::data::source::{Chunk, DataSource};
use falkon::data::synth;
use falkon::falkon::{fit, prepare_source, solve, FalkonConfig, FalkonModel};
use falkon::linalg::vec_ops::{max_abs_diff, mean};
use falkon::runtime::Engine;
use falkon::util::fault::{FaultKind, FaultPlan, FaultySource};
use falkon::util::json::Value;
use falkon::util::rng::Rng;
use falkon::util::timer::Timer;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Forwards to a [`FaultySource`] while mirroring its injection counter
/// into a shared cell (`prepare_source` consumes the boxed source).
struct CountingFaults {
    inner: FaultySource,
    injected: Arc<AtomicUsize>,
}

impl DataSource for CountingFaults {
    fn d(&self) -> usize {
        self.inner.d()
    }

    fn len_hint(&self) -> Option<usize> {
        self.inner.len_hint()
    }

    fn reset(&mut self) -> anyhow::Result<()> {
        self.inner.reset()
    }

    fn next_chunk(&mut self) -> anyhow::Result<Option<Chunk>> {
        let r = self.inner.next_chunk();
        self.injected.store(self.inner.injected(), Ordering::Relaxed);
        r
    }

    fn chunk_rows(&self) -> usize {
        self.inner.chunk_rows()
    }

    fn n_classes(&self) -> usize {
        self.inner.n_classes()
    }

    fn name(&self) -> &str {
        self.inner.name()
    }

    fn skipped_rows(&self) -> usize {
        self.inner.skipped_rows()
    }
}

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::from_env();
    let smoke = args.flag("--smoke");
    let inject_faults = args.flag("--inject-faults");
    let json_path = args
        .get("--json")
        .unwrap_or("BENCH_outofcore.json")
        .to_string();
    let (n, d, m, t) = if smoke {
        (6_000usize, 8usize, 128usize, 8usize)
    } else {
        (50_000, 10, 1024, 15)
    };
    let chunk_rows = args.usize_or("--chunk-rows", n / 8);
    let workers = args.usize_or("--workers", 1);
    let full_bytes = n * d * 8;

    let mut rng = Rng::new(17);
    let data = synth::smooth_regression(&mut rng, n, d, 0.05);
    let shard_path = std::env::temp_dir()
        .join(format!("falkon_bench_ooc_{}.shard", std::process::id()))
        .to_string_lossy()
        .into_owned();
    let t_conv = Timer::start();
    shard::write_dataset(&shard_path, &data)?;
    let convert_s = t_conv.elapsed_s();

    let config = FalkonConfig {
        sigma: 2.0,
        lam: 1e-4,
        m,
        t,
        seed: 3,
        ..Default::default()
    };
    let eng = if workers > 1 {
        Engine::rust_with(falkon::runtime::EngineOptions {
            workers,
            ..Default::default()
        })
    } else {
        Engine::rust()
    };

    // -- in-memory fit (baseline) -----------------------------------------
    let t_mem = Timer::start();
    let model_mem = fit(&eng, &data.x, &data.y, &config)?;
    let fit_mem_s = t_mem.elapsed_s();

    // -- out-of-core fit through prepare/solve so the plan's residency
    //    proxy is observable -----------------------------------------------
    let t_ooc = Timer::start();
    let src = ShardSource::open(&shard_path, chunk_rows)?;
    let (mut state, y) = prepare_source(&eng, Box::new(src), &config)?;
    let y_offset = mean(&y);
    let yc: Vec<f64> = y.iter().map(|v| v - y_offset).collect();
    let (alpha, cg) = solve(&mut state, &yc, None)?;
    let fit_ooc_s = t_ooc.elapsed_s();
    let resident = state.plan.resident_x_bytes().unwrap_or(full_bytes);
    let model_ooc = FalkonModel {
        config: config.clone(),
        centers: state.sel.c.clone(),
        alpha,
        y_offset,
        phases: state.phases.clone(),
        cg_iters: cg.iters,
        cg_residuals: cg.residuals,
        cg_stop: cg.stop,
        report: state.report.clone(),
    };

    // -- fault-injection leg (--inject-faults): same streamed fit under
    //    a deterministic transient-fault schedule; the retry layer must
    //    absorb every fault without changing a single bit ----------------
    let mut injected_faults = 0usize;
    let mut fit_faulted_s = 0.0f64;
    if inject_faults {
        let plan = FaultPlan::new()
            .at(0, FaultKind::TransientRead, 1)
            .seeded_transient(0xFA11, 100, 1);
        let counter = Arc::new(AtomicUsize::new(0));
        let src = CountingFaults {
            inner: FaultySource::new(Box::new(ShardSource::open(&shard_path, chunk_rows)?), plan),
            injected: counter.clone(),
        };
        let t_flt = Timer::start();
        let (mut fstate, fy) = prepare_source(&eng, Box::new(src), &config)?;
        let f_offset = mean(&fy);
        let fyc: Vec<f64> = fy.iter().map(|v| v - f_offset).collect();
        let (falpha, _) = solve(&mut fstate, &fyc, None)?;
        fit_faulted_s = t_flt.elapsed_s();
        injected_faults = counter.load(Ordering::Relaxed);
        anyhow::ensure!(injected_faults > 0, "fault schedule never fired");
        anyhow::ensure!(
            falpha == model_ooc.alpha,
            "faulted streamed fit diverged from the fault-free one"
        );
    }

    // -- f32-storage leg: re-encode the shard at 4 bytes/element and run
    //    the same streamed fit at the same chunk-row budget. The peak
    //    resident chunk must be exactly half the f64 leg's, and the fit
    //    must land within storage-rounding distance of the in-memory one
    //    (the per-apply error is pinned by the kernels::tol property
    //    tests; end-to-end the drift stays far below the noise floor) ----
    let shard32_path = std::env::temp_dir()
        .join(format!("falkon_bench_ooc_{}_f32.shard", std::process::id()))
        .to_string_lossy()
        .into_owned();
    {
        let mut reencode = ShardSource::open(&shard_path, chunk_rows)?;
        shard::write_source_dtype(
            &shard32_path,
            &mut reencode,
            falkon::linalg::mat32::Dtype::F32,
        )?;
    }
    let t_32 = Timer::start();
    let src32 = ShardSource::open(&shard32_path, chunk_rows)?;
    let (mut state32, y32) = prepare_source(&eng, Box::new(src32), &config)?;
    let y32_offset = mean(&y32);
    let y32c: Vec<f64> = y32.iter().map(|v| v - y32_offset).collect();
    let (alpha32, cg32) = solve(&mut state32, &y32c, None)?;
    let fit_f32_s = t_32.elapsed_s();
    let resident32 = state32.plan.resident_x_bytes().unwrap_or(full_bytes);
    anyhow::ensure!(
        2 * resident32 == resident,
        "f32 resident chunk bytes {resident32} not half the f64 leg's {resident}"
    );
    let model_f32 = FalkonModel {
        config: config.clone(),
        centers: state32.sel.c.clone(),
        alpha: alpha32,
        y_offset: y32_offset,
        phases: state32.phases.clone(),
        cg_iters: cg32.iters,
        cg_residuals: cg32.residuals,
        cg_stop: cg32.stop,
        report: state32.report.clone(),
    };

    // -- agreement + residency gates --------------------------------------
    let p_mem = model_mem.predict(&eng, &data.x)?;
    let p_ooc = model_ooc.predict(&eng, &data.x)?;
    let pred_diff = max_abs_diff(&p_mem, &p_ooc);
    anyhow::ensure!(
        pred_diff < 1e-8,
        "out-of-core predictions diverge from in-memory: {pred_diff}"
    );
    anyhow::ensure!(
        resident < full_bytes,
        "resident chunk bytes {resident} not below dataset bytes {full_bytes}"
    );
    let p_f32 = model_f32.predict(&eng, &data.x)?;
    let pred_diff_f32 = max_abs_diff(&p_mem, &p_f32);
    anyhow::ensure!(
        pred_diff_f32 < 1e-2,
        "f32-storage streamed fit drifted from in-memory: {pred_diff_f32}"
    );

    // -- bulk predict throughput ------------------------------------------
    let reps = if smoke { 1 } else { 3 };
    let pred_mem_stats = time_fn(1, reps, || {
        let _ = model_mem.predict(&eng, &data.x).unwrap();
    });
    let pred_ooc_stats = time_fn(1, reps, || {
        let mut src = ShardSource::open(&shard_path, chunk_rows).unwrap();
        let _ = falkon::serve::predict_source(&model_ooc, &eng, &mut src).unwrap();
    });
    let rows_s_mem = n as f64 / pred_mem_stats.median;
    let rows_s_ooc = n as f64 / pred_ooc_stats.median;

    let mut table = Table::new(
        "out-of-core vs in-memory (gaussian smooth regression)",
        &["path", "fit", "predict", "rows/s", "resident X"],
    );
    table.row(&[
        "in-memory".into(),
        fmt_secs(fit_mem_s),
        fmt_secs(pred_mem_stats.median),
        format!("{rows_s_mem:.0}"),
        format!("{} KiB", full_bytes / 1024),
    ]);
    table.row(&[
        "sharded".into(),
        fmt_secs(fit_ooc_s),
        fmt_secs(pred_ooc_stats.median),
        format!("{rows_s_ooc:.0}"),
        format!("{} KiB", resident / 1024),
    ]);
    table.row(&[
        "sharded f32".into(),
        fmt_secs(fit_f32_s),
        "-".into(),
        "-".into(),
        format!("{} KiB", resident32 / 1024),
    ]);
    if inject_faults {
        table.row(&[
            "sharded+faults".into(),
            fmt_secs(fit_faulted_s),
            "-".into(),
            "-".into(),
            format!("{injected_faults} faults absorbed"),
        ]);
    }
    table.print();
    println!(
        "\nn={n} d={d} M={m} t={t} chunk_rows={chunk_rows} | resident/full = {:.3}, \
         pred diff = {pred_diff:.2e} | f32 resident/f64 resident = {:.3}, \
         f32 pred diff = {pred_diff_f32:.2e}",
        resident as f64 / full_bytes as f64,
        resident32 as f64 / resident as f64
    );

    let report = Value::obj(vec![
        ("schema", Value::str("falkon/bench_outofcore/v2")),
        ("smoke", Value::Bool(smoke)),
        ("n", Value::num(n as f64)),
        ("d", Value::num(d as f64)),
        ("m", Value::num(m as f64)),
        ("t", Value::num(t as f64)),
        ("workers", Value::num(workers as f64)),
        ("chunk_rows", Value::num(chunk_rows as f64)),
        ("full_dataset_bytes", Value::num(full_bytes as f64)),
        ("max_resident_chunk_bytes", Value::num(resident as f64)),
        (
            "resident_ratio",
            Value::num(resident as f64 / full_bytes as f64),
        ),
        ("convert_s", Value::num(convert_s)),
        ("fit_in_memory_s", Value::num(fit_mem_s)),
        ("fit_outofcore_s", Value::num(fit_ooc_s)),
        (
            "fit_slowdown_vs_memory",
            Value::num(fit_ooc_s / fit_mem_s.max(1e-12)),
        ),
        ("predict_in_memory", pred_mem_stats.to_json()),
        ("predict_outofcore", pred_ooc_stats.to_json()),
        ("predict_rows_s_in_memory", Value::num(rows_s_mem)),
        ("predict_rows_s_outofcore", Value::num(rows_s_ooc)),
        ("pred_max_abs_diff", Value::num(pred_diff)),
        ("f32_resident_chunk_bytes", Value::num(resident32 as f64)),
        (
            "f32_resident_ratio_vs_f64",
            Value::num(resident32 as f64 / resident as f64),
        ),
        ("fit_f32_s", Value::num(fit_f32_s)),
        ("f32_pred_max_abs_diff", Value::num(pred_diff_f32)),
        ("inject_faults", Value::Bool(inject_faults)),
        ("injected_faults", Value::num(injected_faults as f64)),
        ("fit_faulted_s", Value::num(fit_faulted_s)),
    ]);
    write_json(&json_path, &report)?;
    println!("wrote {json_path}");
    let _ = std::fs::remove_file(&shard_path);
    let _ = std::fs::remove_file(&shard32_path);
    Ok(())
}

//! **P2 (§Perf "Setup path")** — latency of the per-fit O(M²d) + O(M³)
//! preconditioner setup at the paper's M = √n regime: tiled K_MM
//! formation, blocked Cholesky, blocked multi-RHS TRSM, and the full
//! `Engine::precond`, each against its scalar reference, over an M sweep
//! plus a worker-pool sweep. Emits the machine-readable
//! `BENCH_precond.json` (override with `--json <path>`) so the setup path
//! gets the same before/after discipline as `BENCH_matvec.json`. The
//! acceptance gate is the recorded `chol_speedup_vs_ref` at M = 2048
//! (blocked must be ≥2× the scalar reference).

use falkon::bench::{fmt_secs, time_fn, write_json, BenchArgs, Table};
use falkon::kernels::{self, Kernel};
use falkon::linalg::mat::Mat;
use falkon::linalg::{chol, tri};
use falkon::runtime::{Engine, EngineOptions, Impl, Isa};
use falkon::util::json::Value;
use falkon::util::pool::WorkerPool;
use falkon::util::rng::Rng;

/// SPD shift used for the factorization targets (mirrors the engine's
/// jittered K_MM + eps·M·I).
const EPS: f64 = 1e-8;

fn fmt_opt(s: Option<f64>) -> String {
    s.map(fmt_secs).unwrap_or_else(|| "-".into())
}

fn speedup(ref_s: Option<f64>, fast_s: f64) -> Option<f64> {
    ref_s.map(|r| r / fast_s)
}

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::from_env();
    let smoke = args.flag("--smoke");
    let json_path = args
        .get("--json")
        .unwrap_or("BENCH_precond.json")
        .to_string();
    let reps = if smoke { 1 } else { 3 };
    let d = 10usize;
    let workers = args.usize_or("--workers", 4);
    let ms: Vec<usize> = if smoke {
        vec![128, 256]
    } else {
        vec![512, 1024, 2048, 4096]
    };
    // the scalar references are O(M³) with strided access; past this M
    // only the blocked paths run (the acceptance speedup is at 2048)
    let ref_cap = if smoke { 256 } else { 2048 };
    // RHS width for the multi-RHS TRSM (the lscores/solve_spd_mat shape)
    let nrhs = if smoke { 32 } else { 256 };
    let pool = WorkerPool::new("bench-precond", workers)?;

    let mut table = Table::new(
        "P2: preconditioner setup path (gaussian, d=10)",
        &[
            "M", "kmm", "kmm_ref", "chol", "chol_ref", "chol_x", "trsm", "trsm_ref", "precond",
        ],
    );
    let mut sweep_records: Vec<Value> = Vec::new();

    for &m in &ms {
        let mut rng = Rng::new(91);
        let c = Mat::from_vec(m, d, rng.normals(m * d));

        // -- K_MM formation ------------------------------------------------
        let kmm_stats = time_fn(1, reps, || {
            let _ = kernels::kmm(Kernel::Gaussian, &c, 1.0);
        });
        let kmm_pool_stats = time_fn(1, reps, || {
            let _ = kernels::kmm_par(Kernel::Gaussian, &c, 1.0, Some(&pool), Isa::global());
        });
        let kmm_ref_stats = (m <= ref_cap).then(|| {
            time_fn(0, reps, || {
                let _ = kernels::kernel_block_ref(Kernel::Gaussian, &c, &c, 1.0);
            })
        });

        // -- blocked Cholesky ---------------------------------------------
        let mut kj = kernels::kmm(Kernel::Gaussian, &c, 1.0);
        kj.add_diag(EPS * m as f64);
        let chol_stats = time_fn(1, reps, || {
            let _ = chol::cholesky_upper_blocked(&kj, chol::CHOL_BLOCK, None).unwrap();
        });
        let chol_pool_stats = time_fn(1, reps, || {
            let _ = chol::cholesky_upper_blocked(&kj, chol::CHOL_BLOCK, Some(&pool)).unwrap();
        });
        let chol_ref_stats = (m <= ref_cap).then(|| {
            time_fn(0, reps.min(2), || {
                let _ = chol::cholesky_upper_ref(&kj).unwrap();
            })
        });

        // -- blocked multi-RHS TRSM ---------------------------------------
        let r = chol::cholesky_upper_blocked(&kj, chol::CHOL_BLOCK, None).unwrap();
        let b = Mat::from_vec(m, nrhs, rng.normals(m * nrhs));
        let trsm_stats = time_fn(1, reps, || {
            let y = tri::solve_lower_t_mat(&r, &b);
            let _ = tri::solve_upper_mat(&r, &y);
        });
        let trsm_ref_stats = (m <= ref_cap).then(|| {
            time_fn(0, reps, || {
                let y = tri::solve_lower_t_mat_ref(&r, &b);
                let _ = tri::solve_upper_mat_ref(&r, &y);
            })
        });

        // -- full preconditioner (pooled engine, chol + SYRK + chol) ------
        let eng = Engine::rust_with(EngineOptions {
            imp: Impl::Pallas,
            workers,
            ..Default::default()
        });
        let kmm_mat = eng.kmm(Kernel::Gaussian, &c, 1.0)?;
        let precond_stats = time_fn(0, reps, || {
            let _ = eng.precond(&kmm_mat, 1e-3, EPS).unwrap();
        });

        let chol_speedup = speedup(chol_ref_stats.map(|s| s.median), chol_stats.median);
        table.row(&[
            format!("{m}"),
            fmt_secs(kmm_stats.median),
            fmt_opt(kmm_ref_stats.map(|s| s.median)),
            fmt_secs(chol_stats.median),
            fmt_opt(chol_ref_stats.map(|s| s.median)),
            chol_speedup
                .map(|s| format!("{s:.2}x"))
                .unwrap_or_else(|| "-".into()),
            fmt_secs(trsm_stats.median),
            fmt_opt(trsm_ref_stats.map(|s| s.median)),
            fmt_secs(precond_stats.median),
        ]);

        let mut rec: Vec<(&str, Value)> = vec![
            ("m", Value::num(m as f64)),
            ("d", Value::num(d as f64)),
            ("nrhs", Value::num(nrhs as f64)),
            ("workers", Value::num(workers as f64)),
            ("kmm", kmm_stats.to_json()),
            ("kmm_pool", kmm_pool_stats.to_json()),
            ("chol", chol_stats.to_json()),
            ("chol_pool", chol_pool_stats.to_json()),
            ("trsm", trsm_stats.to_json()),
            ("precond", precond_stats.to_json()),
        ];
        if let Some(s) = kmm_ref_stats {
            rec.push(("kmm_ref", s.to_json()));
            rec.push((
                "kmm_speedup_vs_ref",
                Value::num(s.median / kmm_stats.median),
            ));
        }
        if let Some(s) = chol_ref_stats {
            rec.push(("chol_ref", s.to_json()));
            rec.push(("chol_speedup_vs_ref", Value::num(s.median / chol_stats.median)));
            rec.push((
                "chol_pool_speedup_vs_ref",
                Value::num(s.median / chol_pool_stats.median),
            ));
        }
        if let Some(s) = trsm_ref_stats {
            rec.push(("trsm_ref", s.to_json()));
            rec.push((
                "trsm_speedup_vs_ref",
                Value::num(s.median / trsm_stats.median),
            ));
        }
        sweep_records.push(Value::obj(rec));
    }
    table.print();

    // -- pool worker sweep on the largest ref-comparable shape ------------
    let m_sweep = *ms.last().unwrap().min(&2048);
    let mut wtable = Table::new(
        "P2b: setup-path worker sweep (blocked chol + kmm)",
        &["workers", "chol", "kmm", "chol speedup", "kmm speedup"],
    );
    let mut worker_records: Vec<Value> = Vec::new();
    {
        let mut rng = Rng::new(93);
        let c = Mat::from_vec(m_sweep, d, rng.normals(m_sweep * d));
        let mut kj = kernels::kmm(Kernel::Gaussian, &c, 1.0);
        kj.add_diag(EPS * m_sweep as f64);
        let mut chol_base = f64::NAN;
        let mut kmm_base = f64::NAN;
        for w in [1usize, 2, 4, 8] {
            let wpool = if w > 1 {
                Some(WorkerPool::new("bench-precond-sweep", w)?)
            } else {
                None
            };
            let p = wpool.as_ref();
            let chol_stats = time_fn(1, reps, || {
                let _ = chol::cholesky_upper_blocked(&kj, chol::CHOL_BLOCK, p).unwrap();
            });
            let kmm_stats = time_fn(1, reps, || {
                let _ = kernels::kmm_par(Kernel::Gaussian, &c, 1.0, p, Isa::global());
            });
            if w == 1 {
                chol_base = chol_stats.median;
                kmm_base = kmm_stats.median;
            }
            wtable.row(&[
                format!("{w}"),
                fmt_secs(chol_stats.median),
                fmt_secs(kmm_stats.median),
                format!("{:.2}x", chol_base / chol_stats.median),
                format!("{:.2}x", kmm_base / kmm_stats.median),
            ]);
            worker_records.push(Value::obj(vec![
                ("workers", Value::num(w as f64)),
                ("m", Value::num(m_sweep as f64)),
                ("chol", chol_stats.to_json()),
                ("kmm", kmm_stats.to_json()),
                ("chol_speedup_vs_1", Value::num(chol_base / chol_stats.median)),
                ("kmm_speedup_vs_1", Value::num(kmm_base / kmm_stats.median)),
            ]));
        }
    }
    wtable.print();

    let report = Value::obj(vec![
        ("schema", Value::str("falkon/bench_precond/v1")),
        ("smoke", Value::Bool(smoke)),
        ("d", Value::num(d as f64)),
        ("reps", Value::num(reps as f64)),
        ("ref_cap", Value::num(ref_cap as f64)),
        ("sweep", Value::arr(sweep_records)),
        ("workers_sweep", Value::arr(worker_records)),
    ]);
    write_json(&json_path, &report)?;
    println!("\nwrote {json_path}");
    Ok(())
}

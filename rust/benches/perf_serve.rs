//! **Network serving bench** (DESIGN.md §Serving) — stand up the TCP
//! front door on a loopback ephemeral port, fire an open-loop storm of
//! concurrent clients, and measure the request path end to end: wire
//! protocol, cross-connection admission batching, panel-amortized
//! predict sweeps. Emits `BENCH_serve.json` (override with `--json`).
//!
//! Three phases, each a gate the JSON re-checks in CI:
//!
//! 1. **Correctness** — network predictions must be **bitwise equal** to
//!    direct `model.predict` (f64s travel as raw IEEE-754 bits).
//! 2. **Latency/throughput storm** — C clients × R single-row requests;
//!    reports p50/p99 latency, rows/s, and the mean executed batch size
//!    (must exceed 1: concurrent sockets coalesce into shared sweeps).
//! 3. **Hot swap under load** — a swapper thread flips the served model
//!    between two checkpoints while clients hammer 8-row batch
//!    requests. Gates: zero request errors (swap drops nothing) and
//!    every reply vector bitwise-matches *one* model's oracle whole —
//!    answers are never mixed across a swap within a request.

use falkon::bench::{write_json, BenchArgs, Table};
use falkon::data::synth;
use falkon::falkon::{fit, model_io, FalkonConfig};
use falkon::runtime::Engine;
use falkon::serve::net::{Client, NetServer};
use falkon::serve::registry::ModelRegistry;
use falkon::serve::ServeConfig;
use falkon::util::json::Value;
use falkon::util::rng::Rng;
use falkon::util::timer::Timer;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn train_and_save(
    seed: u64,
    n: usize,
    d: usize,
    m: usize,
    t: usize,
    path: &str,
) -> anyhow::Result<falkon::falkon::FalkonModel> {
    let mut rng = Rng::new(seed);
    let data = synth::smooth_regression(&mut rng, n, d, 0.05);
    let eng = Engine::rust();
    let cfg = FalkonConfig {
        sigma: 2.0,
        lam: 1e-4,
        m,
        t,
        seed,
        ..Default::default()
    };
    let model = fit(&eng, &data.x, &data.y, &cfg)?;
    model_io::save(&model, path)?;
    // serve-side truth is the file: return the loaded model so oracles
    // match the served coefficients bit for bit
    model_io::load(path)
}

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::from_env();
    let smoke = args.flag("--smoke");
    let json_path = args.get("--json").unwrap_or("BENCH_serve.json").to_string();
    let (n, d, m, t) = if smoke {
        (4_000usize, 8usize, 128usize, 8usize)
    } else {
        (20_000, 10, 512, 15)
    };
    let clients = args.usize_or("--clients", if smoke { 4 } else { 8 });
    let per_client = args.usize_or("--requests", if smoke { 150 } else { 1000 });
    let max_batch = args.usize_or("--max-batch", 64);

    let pid = std::process::id();
    let tmp = std::env::temp_dir();
    let path_a = tmp.join(format!("falkon_serve_bench_a_{pid}.json"));
    let path_a = path_a.to_str().unwrap().to_string();
    let path_b = tmp.join(format!("falkon_serve_bench_b_{pid}.json"));
    let path_b = path_b.to_str().unwrap().to_string();

    println!("training two checkpoints (n={n} d={d} M={m} t={t})…");
    let model_a = train_and_save(11, n, d, m, t, &path_a)?;
    let model_b = train_and_save(12, n, d, m, t, &path_b)?;

    // request features, shared by every phase
    let mut rng = Rng::new(99);
    let probe = synth::smooth_regression(&mut rng, 2_000.min(n), d, 0.05);
    let eng = Engine::rust();
    let oracle_a = model_a.predict(&eng, &probe.x)?;
    let oracle_b = model_b.predict(&eng, &probe.x)?;

    let registry = Arc::new(ModelRegistry::new());
    registry.load_file("default", &path_a)?;
    let server = NetServer::start(
        registry,
        ServeConfig {
            max_batch,
            max_wait: Duration::from_millis(2),
            engine: "rust".into(),
            workers: 1,
        },
        "127.0.0.1:0",
    )?;
    let addr = server.addr().to_string();
    println!("serving on {addr}");

    // -- phase 1: bitwise correctness over the wire -----------------------
    {
        let mut c = Client::connect(&addr)?;
        for i in 0..32 {
            let got = c.predict_one("default", probe.x.row(i))?;
            anyhow::ensure!(
                got.to_bits() == oracle_a[i].to_bits(),
                "row {i}: network {got} != direct {}",
                oracle_a[i]
            );
        }
        let rows = 50;
        let got = c.predict_batch("default", rows, &probe.x.data[..rows * d])?;
        anyhow::ensure!(
            got.iter()
                .zip(&oracle_a[..rows])
                .all(|(a, b)| a.to_bits() == b.to_bits()),
            "batch predictions diverge from direct predict"
        );
        anyhow::ensure!(
            c.predict_one("nope", probe.x.row(0)).is_err(),
            "unknown model must be a typed error"
        );
        println!("correctness: network == direct predict (bitwise)");
    }

    // -- phase 2: open-loop storm, single-row latency ---------------------
    let timer = Timer::start();
    let lat_all: Vec<f64> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|ci| {
                let addr = addr.clone();
                let x = &probe.x;
                let oracle = &oracle_a;
                s.spawn(move || -> anyhow::Result<Vec<f64>> {
                    let mut c = Client::connect(&addr)?;
                    let mut lats = Vec::with_capacity(per_client);
                    for i in 0..per_client {
                        let row = (ci * per_client + i) % x.rows;
                        let t = Timer::start();
                        let got = c.predict_one("default", x.row(row))?;
                        lats.push(t.elapsed_s());
                        anyhow::ensure!(
                            got.to_bits() == oracle[row].to_bits(),
                            "storm row {row} diverged"
                        );
                    }
                    Ok(lats)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("client thread panicked"))
            .collect::<anyhow::Result<Vec<_>>>()
            .map(|v| v.into_iter().flatten().collect())
    })?;
    let storm_wall = timer.elapsed_s();
    let total_requests = (clients * per_client) as f64;
    let rows_s = total_requests / storm_wall;
    let mut lats = lat_all;
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |q: f64| lats[((lats.len() as f64 - 1.0) * q) as usize] * 1e3;
    let (p50_ms, p99_ms) = (pct(0.5), pct(0.99));
    let storm_stats = {
        let mut c = Client::connect(&addr)?;
        c.stats("default")?
    };

    // -- phase 3: hot swap under load -------------------------------------
    let stop_swapping = Arc::new(AtomicBool::new(false));
    let swap_errors = Arc::new(AtomicU64::new(0));
    let mixed_replies = Arc::new(AtomicU64::new(0));
    let swap_rows = 8usize;
    let swap_per_client = per_client / 4;
    let swaps_done = std::thread::scope(|s| -> anyhow::Result<u64> {
        let swapper = {
            let addr = addr.clone();
            let stop = stop_swapping.clone();
            let (pa, pb) = (path_a.clone(), path_b.clone());
            s.spawn(move || -> anyhow::Result<u64> {
                let mut c = Client::connect(&addr)?;
                let mut count = 0u64;
                while !stop.load(Ordering::SeqCst) {
                    let path = if count % 2 == 0 { &pb } else { &pa };
                    c.swap("default", path)?;
                    count += 1;
                    std::thread::sleep(Duration::from_millis(2));
                }
                Ok(count)
            })
        };
        let loaders: Vec<_> = (0..clients)
            .map(|ci| {
                let addr = addr.clone();
                let x = &probe.x;
                let (oa, ob) = (&oracle_a, &oracle_b);
                let errors = swap_errors.clone();
                let mixed = mixed_replies.clone();
                s.spawn(move || -> anyhow::Result<()> {
                    let mut c = Client::connect(&addr)?;
                    for i in 0..swap_per_client {
                        let start = (ci * 61 + i * 7) % (x.rows - swap_rows);
                        match c.predict_batch(
                            "default",
                            swap_rows,
                            &x.data[start * x.cols..(start + swap_rows) * x.cols],
                        ) {
                            Ok(got) => {
                                let all_a = got
                                    .iter()
                                    .zip(&oa[start..start + swap_rows])
                                    .all(|(g, o)| g.to_bits() == o.to_bits());
                                let all_b = got
                                    .iter()
                                    .zip(&ob[start..start + swap_rows])
                                    .all(|(g, o)| g.to_bits() == o.to_bits());
                                if !(all_a || all_b) {
                                    mixed.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                            Err(_) => {
                                errors.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                    Ok(())
                })
            })
            .collect();
        for h in loaders {
            h.join().expect("load thread panicked")?;
        }
        stop_swapping.store(true, Ordering::SeqCst);
        swapper.join().expect("swapper thread panicked")
    })?;
    let swap_errs = swap_errors.load(Ordering::Relaxed);
    let mixed = mixed_replies.load(Ordering::Relaxed);
    let swap_ok = swap_errs == 0 && mixed == 0 && swaps_done >= 1;
    anyhow::ensure!(
        swap_ok,
        "hot swap under load: {swap_errs} request errors, {mixed} mixed replies, {swaps_done} swaps"
    );

    let final_stats = {
        let mut c = Client::connect(&addr)?;
        c.stats("default")?
    };
    server.stop();
    let _ = std::fs::remove_file(&path_a);
    let _ = std::fs::remove_file(&path_b);

    // -- report -----------------------------------------------------------
    anyhow::ensure!(p99_ms.is_finite() && p99_ms > 0.0, "p99 not finite");
    anyhow::ensure!(rows_s > 0.0, "rows/s not positive");
    anyhow::ensure!(
        storm_stats.serve.mean_batch > 1.0,
        "concurrent sockets never coalesced (mean_batch {:.2})",
        storm_stats.serve.mean_batch
    );
    let mut table = Table::new(
        "network serving (loopback TCP, rust engine)",
        &["clients", "requests", "p50 ms", "p99 ms", "rows/s", "mean batch"],
    );
    table.row(&[
        format!("{clients}"),
        format!("{}", clients * per_client),
        format!("{p50_ms:.2}"),
        format!("{p99_ms:.2}"),
        format!("{rows_s:.0}"),
        format!("{:.1}", storm_stats.serve.mean_batch),
    ]);
    table.print();
    println!(
        "\nhot swap under load: {swaps_done} swaps, {swap_errs} dropped requests, \
         {mixed} mixed replies ({} batch requests)",
        clients * swap_per_client
    );

    let report = Value::obj(vec![
        ("schema", Value::str("falkon/bench_serve/v1")),
        ("smoke", Value::Bool(smoke)),
        ("n", Value::num(n as f64)),
        ("d", Value::num(d as f64)),
        ("m", Value::num(m as f64)),
        ("clients", Value::num(clients as f64)),
        ("requests_per_client", Value::num(per_client as f64)),
        ("max_batch", Value::num(max_batch as f64)),
        ("p50_ms", Value::num(p50_ms)),
        ("p99_ms", Value::num(p99_ms)),
        ("rows_s", Value::num(rows_s)),
        ("storm_wall_s", Value::num(storm_wall)),
        ("mean_batch", Value::num(storm_stats.serve.mean_batch)),
        ("batches", Value::num(final_stats.serve.batches as f64)),
        ("requests_total", Value::num(final_stats.serve.requests as f64)),
        ("rejected", Value::num(final_stats.serve.rejected as f64)),
        ("engine_fallbacks", Value::num(final_stats.serve.engine_fallbacks as f64)),
        ("swaps_under_load", Value::num(swaps_done as f64)),
        ("swap_request_errors", Value::num(swap_errs as f64)),
        ("swap_mixed_replies", Value::num(mixed as f64)),
        ("swap_under_load_ok", Value::Bool(swap_ok)),
    ]);
    write_json(&json_path, &report)?;
    println!("wrote {json_path}");
    Ok(())
}

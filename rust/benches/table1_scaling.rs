//! **Table 1** — empirical train-time complexity.
//!
//! The paper's Table 1 is analytic (train time n√n for FALKON vs n² for
//! Nyström-direct-style methods vs n³ for exact KRR). This bench measures
//! wall-clock fit time across n on the same workload and fits log-log
//! slopes; the reproduction target is the *exponent ordering and rough
//! values*, not absolute seconds:
//!
//!   FALKON          ≈ n^1.5   (M = √n·log n, t fixed ≈ log n)
//!   Nyström direct  ≈ n^2     (M = √n·log n ⇒ nM² = n²·log²n)
//!   exact KRR       ≈ n^3     (measured on small n only)

mod common;

use falkon::baselines::{krr, nystrom_direct};
use falkon::bench::{fmt_secs, loglog_slope, BenchArgs, Table};
use falkon::data::synth;
use falkon::falkon::{fit, FalkonConfig};
use falkon::kernels::Kernel;
use falkon::metrics;
use falkon::util::rng::Rng;
use falkon::util::timer::Timer;

/// round M to the nearest compiled artifact size
fn artifact_m(target: usize) -> usize {
    *[256usize, 512, 1024, 2048]
        .iter()
        .min_by_key(|&&m| m.abs_diff(target))
        .unwrap()
}

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::from_env();
    let smoke = args.flag("--smoke");
    let engine = common::bench_engine();
    let ns: Vec<usize> = if smoke {
        vec![1000, 2000, 4000]
    } else {
        vec![2000, 4000, 8000, 16000, 32000, 64000]
    };
    let krr_cap = if smoke { 1000 } else { 4000 };
    let d = 10;
    let sigma = 2.0;

    let mut table = Table::new(
        "Table 1 (empirical): train time vs n",
        &["n", "M", "FALKON", "mse", "Nyström direct", "mse", "KRR", "mse"],
    );
    let (mut t_falkon, mut t_nys, mut t_krr) = (vec![], vec![], vec![]);
    let (mut n_f, mut n_n, mut n_k) = (vec![], vec![], vec![]);

    for &n in &ns {
        let mut rng = Rng::new(100 + n as u64);
        let data = synth::smooth_regression(&mut rng, n + n / 4, d, 0.1);
        let (train, test) = data.split(0.2, &mut rng);
        let nf = train.n() as f64;
        let lam = 1.0 / nf.sqrt();
        let m = artifact_m((nf.sqrt() * nf.ln()) as usize);
        let cfg = FalkonConfig {
            kernel: Kernel::Gaussian,
            sigma,
            lam,
            m,
            t: (0.5 * nf.ln()).ceil() as usize + 3,
            seed: 1,
            ..Default::default()
        };

        let timer = Timer::start();
        let fm = fit(&engine, &train.x, &train.y, &cfg)?;
        let falkon_s = timer.elapsed_s();
        let fmse = metrics::mse(&fm.predict(&engine, &test.x)?, &test.y);
        t_falkon.push(falkon_s);
        n_f.push(nf);

        let timer = Timer::start();
        let nm = nystrom_direct::fit(
            &engine, &train.x, &train.y, Kernel::Gaussian, sigma, lam, m, &mut Rng::new(1),
        )?;
        let nys_s = timer.elapsed_s();
        let nmse = metrics::mse(&nm.predict(&engine, &test.x)?, &test.y);
        t_nys.push(nys_s);
        n_n.push(nf);

        let (krr_cell, krr_mse_cell) = if train.n() <= krr_cap {
            let timer = Timer::start();
            let km = krr::fit(&train.x, &train.y, Kernel::Gaussian, sigma, lam)?;
            let s = timer.elapsed_s();
            let kmse = metrics::mse(&km.predict(&test.x), &test.y);
            t_krr.push(s);
            n_k.push(nf);
            (fmt_secs(s), format!("{kmse:.4}"))
        } else {
            ("-".into(), "-".into())
        };

        table.row(&[
            format!("{}", train.n()),
            format!("{m}"),
            fmt_secs(falkon_s),
            format!("{fmse:.4}"),
            fmt_secs(nys_s),
            format!("{nmse:.4}"),
            krr_cell,
            krr_mse_cell,
        ]);
    }
    table.print();

    let sf = loglog_slope(&n_f, &t_falkon);
    let sn = loglog_slope(&n_n, &t_nys);
    println!("\nlog-log slopes (paper: FALKON n^1.5, Nyström-direct n^2, KRR n^3):");
    println!("  FALKON          : n^{sf:.2}");
    println!("  Nyström direct  : n^{sn:.2}");
    if n_k.len() >= 2 {
        println!("  exact KRR       : n^{:.2}", loglog_slope(&n_k, &t_krr));
    }
    println!(
        "\ncrossover: FALKON/Nyström time ratio at n={}: {:.2}x (should grow with n)",
        n_f.last().unwrap(),
        t_nys.last().unwrap() / t_falkon.last().unwrap()
    );
    if !smoke {
        assert!(sf < sn, "FALKON slope {sf:.2} must be below Nyström-direct {sn:.2}");
    }
    Ok(())
}

//! **Table 2** — the regression/multiclass datasets (MillionSongs, YELP,
//! TIMIT) on their synthetic analogues at laptop scale. Reproduction
//! target: the row *shape* — FALKON matches the direct Nyström solver's
//! accuracy (the stand-in for the converged comparators in the paper's
//! table) at a fraction of the time, on all three workload types
//! (dense gaussian regression, sparse linear regression, one-vs-all
//! multiclass).

mod common;

use falkon::baselines::nystrom_direct;
use falkon::bench::{fmt_secs, BenchArgs, Table};
use falkon::data::{synth, ZScore};
use falkon::falkon::{fit, fit_multiclass, FalkonConfig};
use falkon::kernels::Kernel;
use falkon::metrics;
use falkon::util::rng::Rng;
use falkon::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::from_env();
    let engine = common::bench_engine();
    let mut table = Table::new(
        "Table 2 (analogues): MillionSongs / YELP / TIMIT",
        &["dataset", "algorithm", "n", "metric", "value", "time"],
    );

    // -- MillionSongs analogue: gaussian regression, σ=6, λ=1e-6 ---------
    {
        let n = common::scale(&args, 30_000);
        let mut rng = Rng::new(21);
        let data = synth::songs(&mut rng, n);
        let (mut train, mut test) = data.split(0.2, &mut rng);
        ZScore::normalize(&mut train, &mut test);
        let cfg = FalkonConfig {
            kernel: Kernel::Gaussian,
            sigma: 6.0,
            lam: 1e-6,
            m: 1024,
            t: 20,
            seed: 2,
            ..Default::default()
        };
        let timer = Timer::start();
        let fm = fit(&engine, &train.x, &train.y, &cfg)?;
        let fs = timer.elapsed_s();
        let fp = fm.predict(&engine, &test.x)?;
        table.row(&[
            "songs".into(),
            "FALKON".into(),
            format!("{}", train.n()),
            "MSE / rel.err".into(),
            format!(
                "{:.4} / {:.3e}",
                metrics::mse(&fp, &test.y),
                metrics::relative_error(&fp, &test.y)
            ),
            fmt_secs(fs),
        ]);
        let timer = Timer::start();
        let nm = nystrom_direct::fit(
            &engine, &train.x, &train.y, Kernel::Gaussian, 6.0, 1e-6, 1024, &mut Rng::new(2),
        )?;
        let ns = timer.elapsed_s();
        let np = nm.predict(&engine, &test.x)?;
        table.row(&[
            "songs".into(),
            "Nyström direct".into(),
            format!("{}", train.n()),
            "MSE / rel.err".into(),
            format!(
                "{:.4} / {:.3e}",
                metrics::mse(&np, &test.y),
                metrics::relative_error(&np, &test.y)
            ),
            fmt_secs(ns),
        ]);
        let (f_mse, n_mse) = (metrics::mse(&fp, &test.y), metrics::mse(&np, &test.y));
        assert!(
            f_mse <= 1.05 * n_mse,
            "songs: FALKON {f_mse} vs direct {n_mse}"
        );
    }

    // -- YELP analogue: linear kernel on sparse binary features ----------
    {
        let n = common::scale(&args, 20_000);
        let mut rng = Rng::new(22);
        let data = synth::yelp(&mut rng, n);
        // paper: YELP features are NOT z-scored
        let (train, test) = data.split(0.2, &mut rng);
        let cfg = FalkonConfig {
            kernel: Kernel::Linear,
            sigma: 1.0,
            lam: 1e-6,
            m: 1024,
            t: 20,
            seed: 3,
            ..Default::default()
        };
        let timer = Timer::start();
        let fm = fit(&engine, &train.x, &train.y, &cfg)?;
        let fs = timer.elapsed_s();
        let fp = fm.predict(&engine, &test.x)?;
        table.row(&[
            "yelp".into(),
            "FALKON (linear)".into(),
            format!("{}", train.n()),
            "RMSE".into(),
            format!("{:.4}", metrics::rmse(&fp, &test.y)),
            fmt_secs(fs),
        ]);
        // sanity: beats predicting the mean
        let var = falkon::linalg::vec_ops::variance(&test.y);
        assert!(metrics::mse(&fp, &test.y) < 0.5 * var);
    }

    // -- TIMIT analogue: 8-class one-vs-all, d=440 ----------------------
    {
        let n = common::scale(&args, 12_000);
        let mut rng = Rng::new(23);
        let data = synth::timit(&mut rng, n);
        let (mut train, mut test) = data.split(0.2, &mut rng);
        ZScore::normalize(&mut train, &mut test);
        let cfg = FalkonConfig {
            kernel: Kernel::Gaussian,
            sigma: 15.0,
            lam: 1e-9,
            m: 1024,
            t: 15,
            seed: 4,
            ..Default::default()
        };
        let timer = Timer::start();
        let fm = fit_multiclass(&engine, &train, &cfg)?;
        let fs = timer.elapsed_s();
        let pred = fm.predict_class(&engine, &test.x)?;
        let labels = test.labels.as_ref().unwrap();
        let cerr =
            pred.iter().zip(labels).filter(|(a, b)| a != b).count() as f64 / pred.len() as f64;
        table.row(&[
            "timit".into(),
            "FALKON (8-class)".into(),
            format!("{}", train.n()),
            "c-err".into(),
            format!("{:.2}%", 100.0 * cerr),
            fmt_secs(fs),
        ]);
        // far better than the 87.5% chance error
        assert!(cerr < 0.55, "timit c-err {cerr}");
    }

    table.print();
    println!("\npaper Table 2 reference: FALKON MSE 80.10 / rel 4.51e-3 (songs), RMSE 0.833 (YELP), c-err 32.3% (TIMIT) — absolute values differ on synthetic analogues; the reproduction target is FALKON ≥ direct-solver accuracy at lower time.");
    Ok(())
}

//! **Table 3** — the classification datasets (SUSY, HIGGS, IMAGENET) on
//! their synthetic analogues. Reproduction target: the row shape — FALKON
//! reaches the accuracy of the converged Nyström solver (the stand-in for
//! the table's cluster-scale comparators) in a fraction of the time, and
//! reports the paper's metrics (c-err, AUC).
//!
//! Also home of the **multi-RHS multiclass sweep** (DESIGN.md §Perf
//! "Multi-RHS path"): batched `fit_multiclass` (block CG over
//! `apply_multi`, one panel sweep per iteration for all K classes) vs the
//! per-class loop (`fit_multiclass_looped`, K panel sweeps per iteration)
//! over K ∈ {2, 8, 32, 144}, written to `BENCH_multiclass.json`. Gates:
//! batched-vs-looped speedup ≥ 1.5× at K = 8 (CI smoke scale) and ≥ 3×
//! at K = 32 (full scale), with predictions agreeing to ≤ 1e-8.

mod common;

use falkon::baselines::nystrom_direct;
use falkon::bench::{fmt_secs, write_json, BenchArgs, Table};
use falkon::data::{synth, ZScore};
use falkon::falkon::{fit, fit_multiclass, fit_multiclass_looped, prepare, solve, FalkonConfig};
use falkon::kernels::Kernel;
use falkon::metrics;
use falkon::runtime::Engine;
use falkon::util::json::Value;
use falkon::util::rng::Rng;
use falkon::util::timer::Timer;

fn binary_rows(
    engine: &falkon::runtime::Engine,
    table: &mut Table,
    name: &str,
    n: usize,
    sigma: f64,
    lam: f64,
    m: usize,
) -> anyhow::Result<()> {
    let mut rng = Rng::new(31);
    let data = synth::by_name(name, &mut rng, n).unwrap();
    let (mut train, mut test) = data.split(0.2, &mut rng);
    ZScore::normalize(&mut train, &mut test);

    let cfg = FalkonConfig {
        kernel: Kernel::Gaussian,
        sigma,
        lam,
        m,
        t: 20,
        seed: 6,
        ..Default::default()
    };
    let timer = Timer::start();
    let fm = fit(engine, &train.x, &train.y, &cfg)?;
    let fs = timer.elapsed_s();
    let fp = fm.predict(engine, &test.x)?;
    let (f_cerr, f_auc) = (metrics::binary_error(&fp, &test.y), metrics::auc(&fp, &test.y));
    table.row(&[
        name.into(),
        "FALKON".into(),
        format!("{}", train.n()),
        format!("{:.2}%", 100.0 * f_cerr),
        format!("{f_auc:.4}"),
        fmt_secs(fs),
    ]);

    let timer = Timer::start();
    let nm = nystrom_direct::fit(
        engine, &train.x, &train.y, Kernel::Gaussian, sigma, lam, m, &mut Rng::new(6),
    )?;
    let ns = timer.elapsed_s();
    let np = nm.predict(engine, &test.x)?;
    let n_auc = metrics::auc(&np, &test.y);
    table.row(&[
        name.into(),
        "Nyström direct".into(),
        format!("{}", train.n()),
        format!("{:.2}%", 100.0 * metrics::binary_error(&np, &test.y)),
        format!("{n_auc:.4}"),
        fmt_secs(ns),
    ]);
    assert!(
        f_auc >= n_auc - 0.005,
        "{name}: FALKON AUC {f_auc} below direct {n_auc}"
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::from_env();
    // `--mc-only`: run just the multi-RHS multiclass sweep (the CI smoke
    // gate) without the Table 3 dataset rows
    if args.flag("--mc-only") {
        return multiclass_sweep(&args);
    }
    let engine = common::bench_engine();
    let mut table = Table::new(
        "Table 3 (analogues): SUSY / HIGGS / IMAGENET",
        &["dataset", "algorithm", "n", "c-err", "AUC", "time"],
    );

    // paper: SUSY σ=4 λ=1e-6 M=1e4; HIGGS λ=1e-8 M=1e5 (M scaled down
    // with our n; σ in z-scored units)
    binary_rows(&engine, &mut table, "susy", common::scale(&args, 40_000), 4.0, 1e-6, 1024)?;
    binary_rows(&engine, &mut table, "higgs", common::scale(&args, 40_000), 5.0, 1e-8, 2048)?;

    // IMAGENET analogue: 16-class one-vs-all over CNN-feature-like inputs
    {
        let n = common::scale(&args, 16_000);
        let mut rng = Rng::new(32);
        let data = synth::imagenet(&mut rng, n);
        // paper: IMAGENET features are not z-scored
        let (train, test) = data.split(0.2, &mut rng);
        // raw (un-z-scored) distances are ~spread·√(2d) ≈ 224; σ ≈ half
        let cfg = FalkonConfig {
            kernel: Kernel::Gaussian,
            sigma: 110.0,
            lam: 1e-9,
            m: 1024,
            t: 15,
            seed: 7,
            ..Default::default()
        };
        let timer = Timer::start();
        let fm = fit_multiclass(&engine, &train, &cfg)?;
        let fs = timer.elapsed_s();
        let pred = fm.predict_class(&engine, &test.x)?;
        let labels = test.labels.as_ref().unwrap();
        let cerr =
            pred.iter().zip(labels).filter(|(a, b)| a != b).count() as f64 / pred.len() as f64;
        table.row(&[
            "imagenet".into(),
            "FALKON (16-class)".into(),
            format!("{}", train.n()),
            format!("{:.2}%", 100.0 * cerr),
            "-".into(),
            fmt_secs(fs),
        ]);
        assert!(cerr < 0.45, "imagenet c-err {cerr} (chance 0.9375)");
    }

    table.print();
    println!("\npaper Table 3 reference: c-err 19.6% AUC 0.877 (SUSY), AUC 0.833 (HIGGS), c-err 20.7% (IMAGENET) — synthetic analogues reproduce the row shape (FALKON ≈ converged-solver accuracy, less time), not the absolute values.");

    multiclass_sweep(&args)?;
    Ok(())
}

/// Batched-vs-looped one-vs-all sweep over the class count K. Runs on the
/// single-worker Rust engine (the acceptance shape: Gaussian, n = 20k,
/// M = 1024, d = 10) and writes `BENCH_multiclass.json`. The looped
/// baseline is measured in full up to `LOOPED_CAP_FULL` classes; beyond
/// that its per-class solves are measured on a subset and extrapolated
/// linearly (each class pays an identical CG run over the shared state).
fn multiclass_sweep(args: &BenchArgs) -> anyhow::Result<()> {
    const LOOPED_CAP_FULL: usize = 32;
    const LOOPED_SAMPLE: usize = 16;
    let smoke = args.flag("--smoke");
    let json_path = args
        .get("--json")
        .unwrap_or("BENCH_multiclass.json")
        .to_string();
    let (n, m) = if smoke { (2500, 256) } else { (20_000, 1024) };
    let d = 10usize;
    let t = 10usize;
    let ks: Vec<usize> = if smoke {
        vec![2, 8, 32]
    } else {
        vec![2, 8, 32, 144]
    };
    let eval_rows = n.min(500);
    // single worker: the speedup measured is pure panel amortization,
    // not threading
    let engine = Engine::rust();
    let cfg_base = FalkonConfig {
        kernel: Kernel::Gaussian,
        sigma: 6.0,
        lam: 1e-6,
        m,
        t,
        seed: 11,
        ..Default::default()
    };

    let mut table = Table::new(
        "Multi-RHS multiclass: batched block-CG vs per-class loop (gaussian, rust, 1 worker)",
        &["K", "batched", "looped", "speedup", "batched evals/s", "max |Δscore|"],
    );
    let mut records: Vec<Value> = Vec::new();
    let speedup_at = |records: &[Value], k: usize| -> Option<f64> {
        records
            .iter()
            .find(|r| r.get("k").as_usize() == Some(k))
            .and_then(|r| r.get("speedup").as_f64())
    };

    for &k in &ks {
        let mut rng = Rng::new(101);
        let data = synth::blobs(&mut rng, n, d, k);
        let eval_x = data.x.slice_rows(0, eval_rows);
        let cfg = cfg_base.clone();

        // -- batched fit (prepare + one block CG) -------------------------
        let timer = Timer::start();
        let batched = fit_multiclass(&engine, &data, &cfg)?;
        let batched_s = timer.elapsed_s();
        let batched_iters: usize = batched.cg_iters.iter().copied().max().unwrap_or(0);
        // one rhs pass + max_iters applies, each n·M kernel evals
        let batched_evals = (n * m) as f64 * (batched_iters + 1) as f64;

        // -- looped baseline ----------------------------------------------
        let (looped_s, looped_classes, score_diff) = if k <= LOOPED_CAP_FULL {
            let timer = Timer::start();
            let looped = fit_multiclass_looped(&engine, &data, &cfg)?;
            let looped_s = timer.elapsed_s();
            let sb = batched.scores_mat(&engine, &eval_x)?;
            let sl = looped.scores_mat(&engine, &eval_x)?;
            (looped_s, k, Some(sb.max_abs_diff(&sl)))
        } else {
            // measure prepare once plus LOOPED_SAMPLE per-class solves and
            // extrapolate: every class runs the same fixed-t CG over the
            // same shared state
            let timer = Timer::start();
            let mut state = prepare(&engine, &data.x, &cfg)?;
            let prep_s = timer.elapsed_s();
            let timer = Timer::start();
            for kc in 0..LOOPED_SAMPLE {
                let yk = data.label_targets(kc);
                let _ = solve(&mut state, &yk, None)?;
            }
            let solve_s = timer.elapsed_s();
            (
                prep_s + solve_s * k as f64 / LOOPED_SAMPLE as f64,
                LOOPED_SAMPLE,
                None,
            )
        };
        let looped_evals = (n * m) as f64 * (t + 1) as f64 * k as f64;
        let speedup = looped_s / batched_s;

        table.row(&[
            format!("{k}"),
            fmt_secs(batched_s),
            if looped_classes == k {
                fmt_secs(looped_s)
            } else {
                format!("{} (est {looped_classes}/{k})", fmt_secs(looped_s))
            },
            format!("{speedup:.2}x"),
            format!("{:.2e}", batched_evals / batched_s),
            score_diff
                .map(|v| format!("{v:.1e}"))
                .unwrap_or_else(|| "-".into()),
        ]);

        let mut rec: Vec<(&str, Value)> = vec![
            ("k", Value::num(k as f64)),
            ("n", Value::num(n as f64)),
            ("m", Value::num(m as f64)),
            ("d", Value::num(d as f64)),
            ("t", Value::num(t as f64)),
            ("batched_fit_s", Value::num(batched_s)),
            ("looped_fit_s", Value::num(looped_s)),
            ("looped_classes_measured", Value::num(looped_classes as f64)),
            ("speedup", Value::num(speedup)),
            ("batched_evals_per_s", Value::num(batched_evals / batched_s)),
            ("looped_evals_per_s", Value::num(looped_evals / looped_s)),
        ];
        if let Some(diff) = score_diff {
            rec.push(("max_score_diff", Value::num(diff)));
            assert!(
                diff <= 1e-8,
                "K={k}: batched vs looped predictions differ by {diff}"
            );
        }
        records.push(Value::obj(rec));
    }
    table.print();

    let report = Value::obj(vec![
        ("schema", Value::str("falkon/bench_multiclass/v1")),
        ("smoke", Value::Bool(smoke)),
        ("engine", Value::str(engine.name())),
        ("workers", Value::num(1.0)),
        ("sweep", Value::arr(records.clone())),
    ]);
    write_json(&json_path, &report)?;
    println!("\nwrote {json_path}");

    // gates: the CI smoke gate is K = 8 ≥ 1.5×; the full-scale acceptance
    // shape is K = 32 ≥ 3× (asserted only at full scale where timing
    // noise is negligible relative to the margin)
    let s8 = speedup_at(&records, 8).expect("K=8 record");
    assert!(
        s8 >= 1.5,
        "batched-vs-looped speedup at K=8 is {s8:.2}x (< 1.5x gate)"
    );
    if !smoke {
        let s32 = speedup_at(&records, 32).expect("K=32 record");
        assert!(
            s32 >= 3.0,
            "batched-vs-looped speedup at K=32 is {s32:.2}x (< 3x acceptance gate)"
        );
    }
    Ok(())
}

//! **Table 3** — the classification datasets (SUSY, HIGGS, IMAGENET) on
//! their synthetic analogues. Reproduction target: the row shape — FALKON
//! reaches the accuracy of the converged Nyström solver (the stand-in for
//! the table's cluster-scale comparators) in a fraction of the time, and
//! reports the paper's metrics (c-err, AUC).

mod common;

use falkon::baselines::nystrom_direct;
use falkon::bench::{fmt_secs, BenchArgs, Table};
use falkon::data::{synth, ZScore};
use falkon::falkon::{fit, fit_multiclass, FalkonConfig};
use falkon::kernels::Kernel;
use falkon::metrics;
use falkon::util::rng::Rng;
use falkon::util::timer::Timer;

fn binary_rows(
    engine: &falkon::runtime::Engine,
    table: &mut Table,
    name: &str,
    n: usize,
    sigma: f64,
    lam: f64,
    m: usize,
) -> anyhow::Result<()> {
    let mut rng = Rng::new(31);
    let data = synth::by_name(name, &mut rng, n).unwrap();
    let (mut train, mut test) = data.split(0.2, &mut rng);
    ZScore::normalize(&mut train, &mut test);

    let cfg = FalkonConfig {
        kernel: Kernel::Gaussian,
        sigma,
        lam,
        m,
        t: 20,
        seed: 6,
        ..Default::default()
    };
    let timer = Timer::start();
    let fm = fit(engine, &train.x, &train.y, &cfg)?;
    let fs = timer.elapsed_s();
    let fp = fm.predict(engine, &test.x)?;
    let (f_cerr, f_auc) = (metrics::binary_error(&fp, &test.y), metrics::auc(&fp, &test.y));
    table.row(&[
        name.into(),
        "FALKON".into(),
        format!("{}", train.n()),
        format!("{:.2}%", 100.0 * f_cerr),
        format!("{f_auc:.4}"),
        fmt_secs(fs),
    ]);

    let timer = Timer::start();
    let nm = nystrom_direct::fit(
        engine, &train.x, &train.y, Kernel::Gaussian, sigma, lam, m, &mut Rng::new(6),
    )?;
    let ns = timer.elapsed_s();
    let np = nm.predict(engine, &test.x)?;
    let n_auc = metrics::auc(&np, &test.y);
    table.row(&[
        name.into(),
        "Nyström direct".into(),
        format!("{}", train.n()),
        format!("{:.2}%", 100.0 * metrics::binary_error(&np, &test.y)),
        format!("{n_auc:.4}"),
        fmt_secs(ns),
    ]);
    assert!(
        f_auc >= n_auc - 0.005,
        "{name}: FALKON AUC {f_auc} below direct {n_auc}"
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::from_env();
    let engine = common::bench_engine();
    let mut table = Table::new(
        "Table 3 (analogues): SUSY / HIGGS / IMAGENET",
        &["dataset", "algorithm", "n", "c-err", "AUC", "time"],
    );

    // paper: SUSY σ=4 λ=1e-6 M=1e4; HIGGS λ=1e-8 M=1e5 (M scaled down
    // with our n; σ in z-scored units)
    binary_rows(&engine, &mut table, "susy", common::scale(&args, 40_000), 4.0, 1e-6, 1024)?;
    binary_rows(&engine, &mut table, "higgs", common::scale(&args, 40_000), 5.0, 1e-8, 2048)?;

    // IMAGENET analogue: 16-class one-vs-all over CNN-feature-like inputs
    {
        let n = common::scale(&args, 16_000);
        let mut rng = Rng::new(32);
        let data = synth::imagenet(&mut rng, n);
        // paper: IMAGENET features are not z-scored
        let (train, test) = data.split(0.2, &mut rng);
        // raw (un-z-scored) distances are ~spread·√(2d) ≈ 224; σ ≈ half
        let cfg = FalkonConfig {
            kernel: Kernel::Gaussian,
            sigma: 110.0,
            lam: 1e-9,
            m: 1024,
            t: 15,
            seed: 7,
            ..Default::default()
        };
        let timer = Timer::start();
        let fm = fit_multiclass(&engine, &train, &cfg)?;
        let fs = timer.elapsed_s();
        let pred = fm.predict_class(&engine, &test.x)?;
        let labels = test.labels.as_ref().unwrap();
        let cerr =
            pred.iter().zip(labels).filter(|(a, b)| a != b).count() as f64 / pred.len() as f64;
        table.row(&[
            "imagenet".into(),
            "FALKON (16-class)".into(),
            format!("{}", train.n()),
            format!("{:.2}%", 100.0 * cerr),
            "-".into(),
            fmt_secs(fs),
        ]);
        assert!(cerr < 0.45, "imagenet c-err {cerr} (chance 0.9375)");
    }

    table.print();
    println!("\npaper Table 3 reference: c-err 19.6% AUC 0.877 (SUSY), AUC 0.833 (HIGGS), c-err 20.7% (IMAGENET) — synthetic analogues reproduce the row shape (FALKON ≈ converged-solver accuracy, less time), not the absolute values.");
    Ok(())
}

//! Exact kernel ridge regression (Eq. 4-5): `(K_nn + λnI) α = y` by direct
//! Cholesky. O(n²) memory, O(n³) time — the statistical gold standard and
//! the scaling upper bound in Table 1.

use crate::kernels::{self, Kernel};
use crate::linalg::chol;
use crate::linalg::mat::Mat;
use anyhow::{Context, Result};

#[derive(Debug, Clone)]
pub struct KrrModel {
    pub kernel: Kernel,
    pub sigma: f64,
    pub lam: f64,
    /// training inputs — KRR needs all of them at test time (Table 1's
    /// O(n) test-time column)
    pub x: Mat,
    pub alpha: Vec<f64>,
}

pub fn fit(x: &Mat, y: &[f64], kernel: Kernel, sigma: f64, lam: f64) -> Result<KrrModel> {
    anyhow::ensure!(x.rows == y.len());
    let n = x.rows;
    let mut k = kernels::kernel_block(kernel, x, x, sigma);
    k.add_diag(lam * n as f64 + 1e-12);
    let alpha = chol::solve_spd(&k, y).context("KRR solve")?;
    Ok(KrrModel {
        kernel,
        sigma,
        lam,
        x: x.clone(),
        alpha,
    })
}

impl KrrModel {
    pub fn predict(&self, x: &Mat) -> Vec<f64> {
        kernels::predict(self.kernel, x, &self.x, &self.alpha, self.sigma)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::metrics;
    use crate::util::rng::Rng;

    #[test]
    fn interpolates_with_tiny_lambda() {
        let mut rng = Rng::new(1);
        let data = synth::smooth_regression(&mut rng, 120, 3, 0.0);
        let m = fit(&data.x, &data.y, Kernel::Gaussian, 1.0, 1e-10).unwrap();
        let preds = m.predict(&data.x);
        assert!(metrics::mse(&preds, &data.y) < 1e-6);
    }

    #[test]
    fn regularization_shrinks_predictions() {
        let mut rng = Rng::new(2);
        let data = synth::smooth_regression(&mut rng, 100, 3, 0.1);
        let loose = fit(&data.x, &data.y, Kernel::Gaussian, 1.0, 1e-8).unwrap();
        let tight = fit(&data.x, &data.y, Kernel::Gaussian, 1.0, 10.0).unwrap();
        let norm = |v: &[f64]| crate::linalg::vec_ops::norm2(v);
        assert!(norm(&tight.predict(&data.x)) < 0.5 * norm(&loose.predict(&data.x)));
    }

    #[test]
    fn generalizes_on_smooth_target() {
        let mut rng = Rng::new(3);
        let data = synth::smooth_regression(&mut rng, 500, 4, 0.05);
        let (train, test) = data.split(0.3, &mut rng);
        let m = fit(&train.x, &train.y, Kernel::Gaussian, 2.0, 1e-6).unwrap();
        let err = metrics::mse(&m.predict(&test.x), &test.y);
        let var = crate::linalg::vec_ops::variance(&test.y);
        assert!(err < 0.3 * var, "{err} vs {var}");
    }
}

//! Comparator algorithms from the paper's Table 1, implemented on the same
//! substrates so the scaling/accuracy benches measure algorithms, not
//! implementation quality:
//!
//! - [`krr`] — exact kernel ridge regression, direct O(n³) solve;
//! - [`nystrom_direct`] — basic Nyström (Eq. 8), direct O(nM² + M³) solve;
//! - [`nystrom_gd`] — Nyström + early-stopped gradient descent
//!   (NYTRO-style [23]);
//! - [`nystrom_cg`] — Nyström + *un-preconditioned* CG: the ablation that
//!   isolates the paper's preconditioner contribution.
pub mod krr;
pub mod nystrom_cg;
pub mod nystrom_direct;
pub mod nystrom_gd;

//! Nyström + conjugate gradient **without** the FALKON preconditioner —
//! the ablation isolating the paper's core contribution (Sect. 3): same
//! subspace, same CG, same blocked matvec; only B is missing. Thm. 2 says
//! this needs ~√(cond(H)) iterations where FALKON needs O(log n).

use crate::falkon::cg::{conjgrad, CgOptions, CgResult};
use crate::kernels::Kernel;
use crate::linalg::gemm;
use crate::linalg::mat::Mat;
use crate::runtime::Engine;
use crate::util::rng::Rng;
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct CgModel {
    pub kernel: Kernel,
    pub sigma: f64,
    pub lam: f64,
    pub centers: Mat,
    pub alpha: Vec<f64>,
    pub cg: CgResult,
}

#[allow(clippy::too_many_arguments)]
pub fn fit(
    engine: &Engine,
    x: &Mat,
    y: &[f64],
    kernel: Kernel,
    sigma: f64,
    lam: f64,
    m: usize,
    opts: CgOptions,
    rng: &mut Rng,
    on_iter: Option<&mut dyn FnMut(usize, &[f64])>,
) -> Result<CgModel> {
    anyhow::ensure!(x.rows == y.len());
    let n = x.rows;
    let idx = rng.choose(n, m.min(n));
    let centers = x.select_rows(&idx);
    let kmm = engine.kmm(kernel, &centers, sigma)?;
    let plan = engine.matvec_plan(kernel, x, &centers, sigma)?;
    let mm = centers.rows;

    // H α = z with H = K_nMᵀK_nM + λn·K_MM, z = K_nMᵀ y
    // (scaled by 1/n to keep residuals comparable with FALKON's)
    let apply = |p: &[f64]| -> Result<Vec<f64>> {
        let mut hp = plan.apply(p, None)?;
        let kv = gemm::matvec(&kmm, p);
        for j in 0..mm {
            hp[j] = hp[j] / n as f64 + lam * kv[j];
        }
        Ok(hp)
    };
    let zeros = vec![0.0f64; mm];
    let yn: Vec<f64> = y.iter().map(|v| v / n as f64).collect();
    let z = plan.apply(&zeros, Some(&yn))?;

    let cg = conjgrad(apply, &z, opts, on_iter)?;
    Ok(CgModel {
        kernel,
        sigma,
        lam,
        centers,
        alpha: cg.beta.clone(),
        cg,
    })
}

impl CgModel {
    pub fn predict(&self, engine: &Engine, x: &Mat) -> Result<Vec<f64>> {
        engine.predict(self.kernel, x, &self.centers, &self.alpha, self.sigma)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn converges_to_direct_solution_eventually() {
        let mut rng = Rng::new(1);
        let mut data = synth::smooth_regression(&mut rng, 300, 3, 0.05);
        // zero-mean targets: CG here is uncentered, direct centers
        let ybar = crate::linalg::vec_ops::mean(&data.y);
        for v in &mut data.y {
            *v -= ybar;
        }
        let eng = Engine::rust();
        let direct = crate::baselines::nystrom_direct::fit(
            &eng,
            &data.x,
            &data.y,
            Kernel::Gaussian,
            1.5,
            1e-3,
            30,
            &mut Rng::new(4),
        )
        .unwrap();
        let cg = fit(
            &eng,
            &data.x,
            &data.y,
            Kernel::Gaussian,
            1.5,
            1e-3,
            30,
            CgOptions {
                t_max: 2000,
                tol: 1e-12,
            },
            &mut Rng::new(4),
            None,
        )
        .unwrap();
        let pd = direct.predict(&eng, &data.x).unwrap();
        let pc = cg.predict(&eng, &data.x).unwrap();
        let rel = crate::linalg::vec_ops::rel_diff(&pc, &pd);
        assert!(rel < 1e-3, "rel {rel}");
    }

    #[test]
    fn needs_many_more_iterations_than_falkon() {
        // the paper's headline ablation, in miniature
        let mut rng = Rng::new(2);
        let n = 500;
        let data = synth::smooth_regression(&mut rng, n, 3, 0.05);
        let eng = Engine::rust();
        let lam = 1.0 / (n as f64).sqrt();

        let falkon_cfg = crate::falkon::FalkonConfig {
            sigma: 1.5,
            lam,
            m: 50,
            t: 400,
            tol: 1e-9,
            seed: 5,
            ..Default::default()
        };
        let fm = crate::falkon::fit(&eng, &data.x, &data.y, &falkon_cfg).unwrap();

        let cg = fit(
            &eng,
            &data.x,
            &data.y,
            Kernel::Gaussian,
            1.5,
            lam,
            50,
            CgOptions {
                t_max: 400,
                tol: 1e-9,
            },
            &mut Rng::new(5),
            None,
        )
        .unwrap();
        assert!(
            fm.cg_iters * 3 <= cg.cg.iters,
            "falkon {} vs plain {}",
            fm.cg_iters,
            cg.cg.iters
        );
    }
}

//! Basic Nyström with a direct solver (Eq. 8): form
//! `H = K_nMᵀK_nM + λn·K_MM` in M×M blocks and solve by Cholesky.
//! O(nM²) time, O(M²) memory — the "Nyström, random features [7-9]" row of
//! Table 1. FALKON's claim is matching its accuracy at O(nMt) with t≈log n.

use crate::kernels::Kernel;
use crate::linalg::chol;
use crate::linalg::mat::Mat;
use crate::runtime::Engine;
use crate::util::rng::Rng;
use anyhow::{Context, Result};

#[derive(Debug, Clone)]
pub struct NystromModel {
    pub kernel: Kernel,
    pub sigma: f64,
    pub lam: f64,
    pub centers: Mat,
    pub alpha: Vec<f64>,
    /// mean of the training targets, added back at predict time (same
    /// intercept handling as the FALKON estimator, for fair comparison)
    pub y_offset: f64,
}

/// Fit with uniformly sampled centers. Kernel blocks stream through the
/// engine so the XLA artifacts serve this baseline too.
pub fn fit(
    engine: &Engine,
    x: &Mat,
    y: &[f64],
    kernel: Kernel,
    sigma: f64,
    lam: f64,
    m: usize,
    rng: &mut Rng,
) -> Result<NystromModel> {
    let idx = rng.choose(x.rows, m.min(x.rows));
    let centers = x.select_rows(&idx);
    fit_with_centers(engine, x, y, kernel, sigma, lam, centers)
}

pub fn fit_with_centers(
    engine: &Engine,
    x: &Mat,
    y: &[f64],
    kernel: Kernel,
    sigma: f64,
    lam: f64,
    centers: Mat,
) -> Result<NystromModel> {
    anyhow::ensure!(x.rows == y.len());
    let y_offset = crate::linalg::vec_ops::mean(y);
    let y: Vec<f64> = y.iter().map(|v| v - y_offset).collect();
    let y = &y[..];
    let (n, m) = (x.rows, centers.rows);
    // stream blocks: H += KrᵀKr, z += Krᵀ y_b
    let mut h = Mat::zeros(m, m);
    let mut z = vec![0.0f64; m];
    let block = 2048usize;
    let mut start = 0;
    while start < n {
        let end = (start + block).min(n);
        let xb = x.slice_rows(start, end);
        let kr = engine.kernel_block(kernel, &xb, &centers, sigma)?;
        for i in 0..kr.rows {
            let row = kr.row(i);
            let yi = y[start + i];
            for a in 0..m {
                let ra = row[a];
                if ra == 0.0 {
                    continue;
                }
                let hrow = h.row_mut(a);
                for b in a..m {
                    hrow[b] += ra * row[b];
                }
                z[a] += ra * yi;
            }
        }
        start = end;
    }
    for a in 0..m {
        for b in 0..a {
            h[(a, b)] = h[(b, a)];
        }
    }
    let kmm = engine.kmm(kernel, &centers, sigma)?;
    for a in 0..m {
        for b in 0..m {
            h[(a, b)] += lam * n as f64 * kmm[(a, b)];
        }
    }
    // jitter for rank-deficient K_MM (e.g. linear kernel with M > d)
    let mut jit = 1e-10 * (1.0 + h[(0, 0)].abs());
    let alpha = loop {
        let mut hj = h.clone();
        hj.add_diag(jit);
        match chol::solve_spd(&hj, &z) {
            Ok(a) => break a,
            Err(_) if jit < 1e3 => jit *= 100.0,
            Err(e) => return Err(e).context("Nyström direct solve"),
        }
    };
    Ok(NystromModel {
        kernel,
        sigma,
        lam,
        centers,
        alpha,
        y_offset,
    })
}

impl NystromModel {
    pub fn predict(&self, engine: &Engine, x: &Mat) -> Result<Vec<f64>> {
        let mut p = engine.predict(self.kernel, x, &self.centers, &self.alpha, self.sigma)?;
        if self.y_offset != 0.0 {
            for v in &mut p {
                *v += self.y_offset;
            }
        }
        Ok(p)
    }
}

#[cfg(test)]
mod tests {
    use crate::kernels;
    use super::*;
    use crate::data::synth;
    use crate::metrics;

    #[test]
    fn matches_dense_construction() {
        let mut rng = Rng::new(1);
        let mut data = synth::smooth_regression(&mut rng, 250, 3, 0.05);
        // zero-mean targets: the dense reference below is uncentered
        let ybar = crate::linalg::vec_ops::mean(&data.y);
        for v in &mut data.y {
            *v -= ybar;
        }
        let eng = Engine::rust();
        let model = fit(
            &eng,
            &data.x,
            &data.y,
            Kernel::Gaussian,
            1.5,
            1e-4,
            30,
            &mut Rng::new(5),
        )
        .unwrap();
        // dense reference
        let mut rng2 = Rng::new(5);
        let idx = rng2.choose(250, 30);
        let c = data.x.select_rows(&idx);
        let knm = kernels::kernel_block(Kernel::Gaussian, &data.x, &c, 1.5);
        let kmm = kernels::kmm(Kernel::Gaussian, &c, 1.5);
        let mut h = crate::linalg::gemm::matmul(&knm.t(), &knm);
        for a in 0..30 {
            for b in 0..30 {
                h[(a, b)] += 1e-4 * 250.0 * kmm[(a, b)];
            }
        }
        h.add_diag(1e-10 * (1.0 + h[(0, 0)].abs()));
        let z = crate::linalg::gemm::matvec_t(&knm, &data.y);
        let alpha = chol::solve_spd(&h, &z).unwrap();
        let rel = crate::linalg::vec_ops::rel_diff(&model.alpha, &alpha);
        assert!(rel < 1e-8, "rel {rel}");
    }

    #[test]
    fn learns() {
        let mut rng = Rng::new(2);
        let data = synth::smooth_regression(&mut rng, 700, 4, 0.05);
        let (train, test) = data.split(0.25, &mut rng);
        let eng = Engine::rust();
        let model = fit(
            &eng, &train.x, &train.y, Kernel::Gaussian, 2.0, 1e-5, 120, &mut rng,
        )
        .unwrap();
        let err = metrics::mse(&model.predict(&eng, &test.x).unwrap(), &test.y);
        let var = crate::linalg::vec_ops::variance(&test.y);
        assert!(err < 0.35 * var, "{err} vs {var}");
    }

    #[test]
    fn rank_deficient_linear_kernel_survives() {
        // linear kernel, M > d -> singular H; jitter path must handle it
        let mut rng = Rng::new(3);
        let data = synth::smooth_regression(&mut rng, 200, 3, 0.05);
        let eng = Engine::rust();
        let model = fit(
            &eng, &data.x, &data.y, Kernel::Linear, 1.0, 1e-6, 40, &mut rng,
        )
        .unwrap();
        assert!(model.alpha.iter().all(|a| a.is_finite()));
    }
}

//! Nyström + gradient descent with early stopping (NYTRO-style [23]) — the
//! "Nyström + iterative [23, 24]" row of Table 1. Uses the same blocked
//! matvec plan as FALKON but *no preconditioner*: the paper's point is
//! that this needs t ≈ O(√n) iterations where FALKON needs O(log n).
//!
//! Iteration (Eq. 6 restricted to the Nyström space):
//!   α ← α − (τ/n)·[K_nMᵀ(K_nM α − y) + λn·K_MM α]

use crate::kernels::Kernel;
use crate::linalg::gemm;
use crate::linalg::mat::Mat;
use crate::runtime::Engine;
use crate::util::rng::Rng;
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct GdModel {
    pub kernel: Kernel,
    pub sigma: f64,
    pub lam: f64,
    pub centers: Mat,
    pub alpha: Vec<f64>,
    pub iters: usize,
}

/// Power-iteration estimate of the largest eigenvalue of the (normalized)
/// Nyström Hessian — sets a stable step size τ = 1/L.
fn estimate_lipschitz(
    plan: &crate::runtime::MatvecPlan,
    kmm: &Mat,
    lam: f64,
    rng: &mut Rng,
) -> Result<f64> {
    let m = kmm.rows;
    let n = plan.n() as f64;
    let mut v: Vec<f64> = rng.normals(m);
    let mut lmax = 1.0;
    for _ in 0..12 {
        let norm = crate::linalg::vec_ops::norm2(&v).max(1e-300);
        for x in &mut v {
            *x /= norm;
        }
        let mut hv = plan.apply(&v, None)?;
        let kv = gemm::matvec(kmm, &v);
        for j in 0..m {
            hv[j] = hv[j] / n + lam * kv[j];
        }
        lmax = crate::linalg::vec_ops::dot(&v, &hv).abs().max(1e-300);
        v = hv;
    }
    Ok(lmax)
}

#[allow(clippy::too_many_arguments)]
pub fn fit(
    engine: &Engine,
    x: &Mat,
    y: &[f64],
    kernel: Kernel,
    sigma: f64,
    lam: f64,
    m: usize,
    t: usize,
    rng: &mut Rng,
) -> Result<GdModel> {
    fit_with_callback(engine, x, y, kernel, sigma, lam, m, t, rng, None)
}

/// `on_iter(k, α)` traces iterates for the convergence-comparison benches.
#[allow(clippy::too_many_arguments)]
pub fn fit_with_callback(
    engine: &Engine,
    x: &Mat,
    y: &[f64],
    kernel: Kernel,
    sigma: f64,
    lam: f64,
    m: usize,
    t: usize,
    rng: &mut Rng,
    mut on_iter: Option<&mut dyn FnMut(usize, &[f64])>,
) -> Result<GdModel> {
    anyhow::ensure!(x.rows == y.len());
    let n = x.rows;
    let idx = rng.choose(n, m.min(n));
    let centers = x.select_rows(&idx);
    let kmm = engine.kmm(kernel, &centers, sigma)?;
    let plan = engine.matvec_plan(kernel, x, &centers, sigma)?;
    let mm = centers.rows;

    let lip = estimate_lipschitz(&plan, &kmm, lam, rng)?;
    let tau = 1.0 / lip;

    // gradient of (1/2n)||K_nM α − y||² + (λ/2) αᵀK_MM α:
    //   g = (1/n)·K_nMᵀ(K_nM α − y) + λ·K_MM α
    let neg_y: Vec<f64> = y.iter().map(|v| -v).collect();
    let mut alpha = vec![0.0f64; mm];
    for k in 1..=t {
        let mut g = plan.apply(&alpha, Some(&neg_y))?; // K_nMᵀ(K_nM α − y)
        let kv = gemm::matvec(&kmm, &alpha);
        for j in 0..mm {
            g[j] = g[j] / n as f64 + lam * kv[j];
        }
        for j in 0..mm {
            alpha[j] -= tau * g[j];
        }
        if let Some(cb) = on_iter.as_deref_mut() {
            cb(k, &alpha);
        }
    }
    Ok(GdModel {
        kernel,
        sigma,
        lam,
        centers,
        alpha,
        iters: t,
    })
}

impl GdModel {
    pub fn predict(&self, engine: &Engine, x: &Mat) -> Result<Vec<f64>> {
        engine.predict(self.kernel, x, &self.centers, &self.alpha, self.sigma)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::metrics;

    #[test]
    fn descends_towards_nystrom_solution() {
        let mut rng = Rng::new(1);
        let mut data = synth::smooth_regression(&mut rng, 400, 3, 0.05);
        // zero-mean targets: GD here is uncentered, direct centers
        let ybar = crate::linalg::vec_ops::mean(&data.y);
        for v in &mut data.y {
            *v -= ybar;
        }
        let eng = Engine::rust();
        // reference: direct Nyström with the same centers (same rng stream)
        // well-conditioned regime (lam = 1/sqrt(n)) so plain GD converges
        // within a sane iteration budget; the ill-conditioned contrast is
        // exactly what ablation_precond measures
        let lam = 0.05;
        let direct = crate::baselines::nystrom_direct::fit(
            &eng,
            &data.x,
            &data.y,
            Kernel::Gaussian,
            1.5,
            lam,
            40,
            &mut Rng::new(9),
        )
        .unwrap();
        let gd = fit(
            &eng,
            &data.x,
            &data.y,
            Kernel::Gaussian,
            1.5,
            lam,
            40,
            600,
            &mut Rng::new(9),
        )
        .unwrap();
        assert_eq!(gd.centers.data, direct.centers.data);
        let pd = direct.predict(&eng, &data.x).unwrap();
        let pg = gd.predict(&eng, &data.x).unwrap();
        let rel = crate::linalg::vec_ops::rel_diff(&pg, &pd);
        assert!(rel < 0.05, "rel {rel}");
    }

    #[test]
    fn more_iterations_reduce_training_error() {
        let mut rng = Rng::new(2);
        let data = synth::smooth_regression(&mut rng, 300, 3, 0.05);
        let eng = Engine::rust();
        let short = fit(
            &eng, &data.x, &data.y, Kernel::Gaussian, 1.5, 1e-4, 30, 5,
            &mut Rng::new(3),
        )
        .unwrap();
        let long = fit(
            &eng, &data.x, &data.y, Kernel::Gaussian, 1.5, 1e-4, 30, 200,
            &mut Rng::new(3),
        )
        .unwrap();
        let e_short = metrics::mse(&short.predict(&eng, &data.x).unwrap(), &data.y);
        let e_long = metrics::mse(&long.predict(&eng, &data.x).unwrap(), &data.y);
        assert!(e_long < e_short, "{e_long} vs {e_short}");
    }
}

//! Micro-benchmark + experiment-report harness (substrate — `criterion` is
//! unavailable offline; see DESIGN.md §3). Used by every `rust/benches/*`
//! target: timing with warmup and repeats, simple stats, aligned table
//! printing that mirrors the paper's table layout, and log-log slope
//! fitting for the complexity experiments.

use crate::util::json::Value;
use crate::util::timer::Timer;

/// Timing statistics over repeats (seconds).
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub median: f64,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub reps: usize,
}

impl Stats {
    /// Machine-readable form for the BENCH_*.json reports.
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("median_s", Value::num(self.median)),
            ("min_s", Value::num(self.min)),
            ("max_s", Value::num(self.max)),
            ("mean_s", Value::num(self.mean)),
            ("reps", Value::num(self.reps as f64)),
        ])
    }
}

/// Write a machine-readable bench report (pretty-printed JSON). Reports
/// like `BENCH_matvec.json` are the perf trajectory the repo tracks from
/// PR to PR.
pub fn write_json(path: &str, v: &Value) -> std::io::Result<()> {
    std::fs::write(path, v.to_string_pretty())
}

/// Time `f` with `warmup` unmeasured runs and `reps` measured runs.
pub fn time_fn(warmup: usize, reps: usize, mut f: impl FnMut()) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(reps.max(1));
    for _ in 0..reps.max(1) {
        let t = Timer::start();
        f();
        times.push(t.elapsed_s());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Stats {
        median: times[times.len() / 2],
        min: times[0],
        max: *times.last().unwrap(),
        mean: times.iter().sum::<f64>() / times.len() as f64,
        reps: times.len(),
    }
}

/// Least-squares slope of log(y) vs log(x) — the empirical complexity
/// exponent for Table 1 (`time ~ n^slope`).
pub fn loglog_slope(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let lx: Vec<f64> = xs.iter().map(|v| v.ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|v| v.ln()).collect();
    let mx = lx.iter().sum::<f64>() / lx.len() as f64;
    let my = ly.iter().sum::<f64>() / ly.len() as f64;
    let mut num = 0.0;
    let mut den = 0.0;
    for i in 0..lx.len() {
        num += (lx[i] - mx) * (ly[i] - my);
        den += (lx[i] - mx) * (lx[i] - mx);
    }
    num / den
}

/// Fixed-width table printer matching the paper's row layout.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells.to_vec());
    }

    pub fn to_string(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::new();
            for (c, cell) in cells.iter().enumerate() {
                s.push_str(&format!("{:>w$}  ", cell, w = widths[c]));
            }
            s.trim_end().to_string() + "\n"
        };
        out.push_str(&line(&self.headers, &widths));
        out.push_str(&format!(
            "{}\n",
            "-".repeat(widths.iter().sum::<usize>() + 2 * ncol)
        ));
        for row in &self.rows {
            out.push_str(&line(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_string());
    }
}

/// Format seconds human-readably for table cells.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.0}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.1}ms", s * 1e3)
    } else if s < 100.0 {
        format!("{s:.2}s")
    } else {
        format!("{:.1}m", s / 60.0)
    }
}

/// Parse simple `--key value` / `--flag` bench arguments (smoke-mode etc.).
pub struct BenchArgs {
    args: Vec<String>,
}

impl BenchArgs {
    pub fn from_env() -> BenchArgs {
        BenchArgs {
            args: std::env::args().collect(),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.args.iter().any(|a| a == name)
            || std::env::var("FALKON_BENCH_SMOKE").is_ok() && name == "--smoke"
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.args
            .iter()
            .position(|a| a == name)
            .and_then(|i| self.args.get(i + 1))
            .map(|s| s.as_str())
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_measures() {
        let s = time_fn(1, 3, || std::thread::sleep(std::time::Duration::from_millis(2)));
        assert!(s.median >= 0.0015, "{s:?}");
        assert_eq!(s.reps, 3);
        assert!(s.min <= s.median && s.median <= s.max);
    }

    #[test]
    fn slope_recovers_exponents() {
        let xs = [1e3, 2e3, 4e3, 8e3];
        let quad: Vec<f64> = xs.iter().map(|x| 3.0 * x * x).collect();
        assert!((loglog_slope(&xs, &quad) - 2.0).abs() < 1e-9);
        let n15: Vec<f64> = xs.iter().map(|x| 0.5 * x.powf(1.5)).collect();
        assert!((loglog_slope(&xs, &n15) - 1.5).abs() < 1e-9);
    }

    #[test]
    fn table_formats() {
        let mut t = Table::new("demo", &["algo", "time"]);
        t.row(&["FALKON".into(), "55s".into()]);
        t.row(&["KRR".into(), "10m".into()]);
        let s = t.to_string();
        assert!(s.contains("== demo =="));
        assert!(s.contains("FALKON"));
    }

    #[test]
    fn stats_json_roundtrips() {
        let s = Stats {
            median: 0.5,
            min: 0.25,
            max: 1.0,
            mean: 0.55,
            reps: 4,
        };
        let v = s.to_json();
        assert_eq!(v.get("median_s").as_f64(), Some(0.5));
        assert_eq!(v.get("reps").as_usize(), Some(4));
        let back = crate::util::json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(back.get("min_s").as_f64(), Some(0.25));
    }

    #[test]
    fn write_json_emits_parseable_file() {
        let path = std::env::temp_dir().join("falkon_bench_json_test.json");
        let v = Value::obj(vec![("a", Value::num(1.0))]);
        write_json(path.to_str().unwrap(), &v).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(crate::util::json::parse(&text).unwrap(), v);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(5e-5).ends_with("µs"));
        assert!(fmt_secs(0.05).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
        assert!(fmt_secs(600.0).ends_with('m'));
    }
}

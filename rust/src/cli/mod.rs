//! Declarative CLI argument parser (substrate — `clap` is unavailable
//! offline; see DESIGN.md §3). Supports `--key value`, `--flag`, typed
//! accessors with defaults, required keys, and generated `--help` text.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Clone)]
struct FlagSpec {
    name: &'static str,
    help: &'static str,
    default: Option<String>,
    is_switch: bool,
}

/// A parser for one (sub)command.
#[derive(Debug, Clone)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    flags: Vec<FlagSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Command {
        Command {
            name,
            about,
            flags: Vec::new(),
        }
    }

    /// `--name <value>` with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.flags.push(FlagSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_switch: false,
        });
        self
    }

    /// required `--name <value>`.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec {
            name,
            help,
            default: None,
            is_switch: false,
        });
        self
    }

    /// boolean `--name` switch.
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec {
            name,
            help,
            default: Some(String::new()),
            is_switch: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for f in &self.flags {
            let arg = if f.is_switch {
                format!("--{}", f.name)
            } else {
                format!("--{} <v>", f.name)
            };
            let def = match (&f.default, f.is_switch) {
                (Some(d), false) if !d.is_empty() => format!(" [default: {d}]"),
                (None, _) => " [required]".to_string(),
                _ => String::new(),
            };
            s.push_str(&format!("  {arg:<24} {}{def}\n", f.help));
        }
        s
    }

    /// Parse raw args (after the subcommand name).
    pub fn parse(&self, args: &[String]) -> Result<Parsed> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                bail!("{}", self.usage());
            }
            let Some(name) = a.strip_prefix("--") else {
                bail!("unexpected argument {a:?}\n\n{}", self.usage());
            };
            let spec = self
                .flags
                .iter()
                .find(|f| f.name == name)
                .ok_or_else(|| anyhow!("unknown flag --{name}\n\n{}", self.usage()))?;
            if spec.is_switch {
                values.insert(name.to_string(), "true".to_string());
                i += 1;
            } else {
                let v = args
                    .get(i + 1)
                    .ok_or_else(|| anyhow!("--{name} needs a value"))?;
                values.insert(name.to_string(), v.clone());
                i += 2;
            }
        }
        for f in &self.flags {
            if !values.contains_key(f.name) {
                match &f.default {
                    Some(d) => {
                        if !f.is_switch {
                            values.insert(f.name.to_string(), d.clone());
                        }
                    }
                    None => bail!("missing required --{}\n\n{}", f.name, self.usage()),
                }
            }
        }
        Ok(Parsed { values })
    }
}

/// Parsed flag values with typed accessors.
#[derive(Debug)]
pub struct Parsed {
    values: BTreeMap<String, String>,
}

impl Parsed {
    pub fn str(&self, name: &str) -> &str {
        self.values.get(name).map(|s| s.as_str()).unwrap_or("")
    }

    pub fn flag(&self, name: &str) -> bool {
        self.values.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn usize(&self, name: &str) -> Result<usize> {
        self.str(name)
            .parse()
            .map_err(|e| anyhow!("--{name}: {e}"))
    }

    pub fn f64(&self, name: &str) -> Result<f64> {
        self.str(name)
            .parse()
            .map_err(|e| anyhow!("--{name}: {e}"))
    }

    pub fn u64(&self, name: &str) -> Result<u64> {
        self.str(name)
            .parse()
            .map_err(|e| anyhow!("--{name}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("train", "fit a model")
            .opt("m", "1024", "centers")
            .opt("sigma", "1.0", "width")
            .req("dataset", "which dataset")
            .switch("verbose", "log more")
    }

    fn args(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let p = cmd()
            .parse(&args(&["--dataset", "susy", "--m", "256"]))
            .unwrap();
        assert_eq!(p.usize("m").unwrap(), 256);
        assert_eq!(p.f64("sigma").unwrap(), 1.0);
        assert_eq!(p.str("dataset"), "susy");
        assert!(!p.flag("verbose"));
    }

    #[test]
    fn switch_parses() {
        let p = cmd()
            .parse(&args(&["--dataset", "x", "--verbose"]))
            .unwrap();
        assert!(p.flag("verbose"));
    }

    #[test]
    fn missing_required_errors() {
        let e = cmd().parse(&args(&["--m", "5"])).unwrap_err().to_string();
        assert!(e.contains("--dataset"), "{e}");
    }

    #[test]
    fn unknown_flag_errors_with_usage() {
        let e = cmd()
            .parse(&args(&["--dataset", "x", "--bogus", "1"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("unknown flag"), "{e}");
        assert!(e.contains("options:"), "{e}");
    }

    #[test]
    fn value_flag_without_value_errors() {
        let e = cmd().parse(&args(&["--dataset"])).unwrap_err().to_string();
        assert!(e.contains("needs a value"), "{e}");
    }

    #[test]
    fn usage_mentions_all_flags() {
        let u = cmd().usage();
        for f in ["--m", "--sigma", "--dataset", "--verbose"] {
            assert!(u.contains(f), "{u}");
        }
    }
}

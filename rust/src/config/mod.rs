//! Experiment configuration: a JSON-serializable description of one
//! training run (dataset, hyperparameters, engine) used by the launcher
//! (`falkon train --config …`) and recorded into every experiment report
//! so runs are reproducible.

use crate::falkon::{Centers, FalkonConfig};
use crate::kernels::Kernel;
use crate::util::json::{self, Value};
use anyhow::{anyhow, Result};

#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// dataset name (synthetic analogue) or a path (libsvm/csv)
    pub dataset: String,
    /// rows to generate for synthetic datasets
    pub n: usize,
    pub test_frac: f64,
    pub normalize: bool,
    pub falkon: FalkonConfig,
    /// "xla" | "xla-jnp" | "rust"
    pub engine: String,
    pub workers: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            dataset: "susy".into(),
            n: 20_000,
            test_frac: 0.2,
            normalize: true,
            falkon: FalkonConfig::default(),
            engine: "xla".into(),
            workers: 1,
        }
    }
}

impl ExperimentConfig {
    pub fn to_json(&self) -> Value {
        let f = &self.falkon;
        let centers = match &f.centers {
            Centers::Uniform => Value::str("uniform"),
            Centers::ApproxLeverage { sketch } => Value::obj(vec![
                ("method", Value::str("leverage")),
                ("sketch", Value::num(*sketch as f64)),
            ]),
        };
        Value::obj(vec![
            ("dataset", Value::str(self.dataset.clone())),
            ("n", Value::num(self.n as f64)),
            ("test_frac", Value::num(self.test_frac)),
            ("normalize", Value::Bool(self.normalize)),
            ("engine", Value::str(self.engine.clone())),
            ("workers", Value::num(self.workers as f64)),
            ("kernel", Value::str(f.kernel.name())),
            ("sigma", Value::num(f.sigma)),
            ("lam", Value::num(f.lam)),
            ("m", Value::num(f.m as f64)),
            ("t", Value::num(f.t as f64)),
            ("eps", Value::num(f.eps)),
            ("tol", Value::num(f.tol)),
            ("seed", Value::num(f.seed as f64)),
            ("centers", centers),
        ])
    }

    pub fn from_json(v: &Value) -> Result<ExperimentConfig> {
        let mut cfg = ExperimentConfig::default();
        let get_num = |k: &str, d: f64| v.get(k).as_f64().unwrap_or(d);
        if let Some(s) = v.get("dataset").as_str() {
            cfg.dataset = s.to_string();
        }
        cfg.n = v.get("n").as_usize().unwrap_or(cfg.n);
        cfg.test_frac = get_num("test_frac", cfg.test_frac);
        cfg.normalize = v.get("normalize").as_bool().unwrap_or(cfg.normalize);
        if let Some(s) = v.get("engine").as_str() {
            cfg.engine = s.to_string();
        }
        cfg.workers = v.get("workers").as_usize().unwrap_or(1);
        let f = &mut cfg.falkon;
        if let Some(k) = v.get("kernel").as_str() {
            f.kernel = Kernel::parse(k).ok_or_else(|| anyhow!("unknown kernel {k}"))?;
        }
        f.sigma = get_num("sigma", f.sigma);
        f.lam = get_num("lam", f.lam);
        f.m = v.get("m").as_usize().unwrap_or(f.m);
        f.t = v.get("t").as_usize().unwrap_or(f.t);
        f.eps = get_num("eps", f.eps);
        f.tol = get_num("tol", f.tol);
        f.seed = v.get("seed").as_f64().unwrap_or(0.0) as u64;
        match v.get("centers") {
            Value::Str(s) if s == "uniform" => f.centers = Centers::Uniform,
            Value::Obj(_) => {
                let c = v.get("centers");
                if c.get("method").as_str() == Some("leverage") {
                    f.centers = Centers::ApproxLeverage {
                        sketch: c.get("sketch").as_usize().unwrap_or(f.m),
                    };
                }
            }
            _ => {}
        }
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)?;
        let v = json::parse(&text).map_err(|e| anyhow!("parsing {path}: {e}"))?;
        ExperimentConfig::from_json(&v)
    }

    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_default() {
        let cfg = ExperimentConfig::default();
        let v = cfg.to_json();
        let back = ExperimentConfig::from_json(&v).unwrap();
        assert_eq!(back.dataset, cfg.dataset);
        assert_eq!(back.falkon.m, cfg.falkon.m);
        assert_eq!(back.falkon.lam, cfg.falkon.lam);
        assert!(matches!(back.falkon.centers, Centers::Uniform));
    }

    #[test]
    fn roundtrip_leverage() {
        let mut cfg = ExperimentConfig::default();
        cfg.falkon.centers = Centers::ApproxLeverage { sketch: 512 };
        cfg.falkon.kernel = Kernel::Linear;
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert!(matches!(
            back.falkon.centers,
            Centers::ApproxLeverage { sketch: 512 }
        ));
        assert_eq!(back.falkon.kernel, Kernel::Linear);
    }

    #[test]
    fn partial_json_uses_defaults() {
        let v = json::parse(r#"{"dataset": "higgs", "m": 256}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&v).unwrap();
        assert_eq!(cfg.dataset, "higgs");
        assert_eq!(cfg.falkon.m, 256);
        assert_eq!(cfg.falkon.t, FalkonConfig::default().t);
    }

    #[test]
    fn rejects_bad_kernel() {
        let v = json::parse(r#"{"kernel": "polynomial"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&v).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let cfg = ExperimentConfig::default();
        let path = std::env::temp_dir().join("falkon_cfg_test.json");
        cfg.save(path.to_str().unwrap()).unwrap();
        let back = ExperimentConfig::load(path.to_str().unwrap()).unwrap();
        assert_eq!(back.n, cfg.n);
        let _ = std::fs::remove_file(path);
    }
}

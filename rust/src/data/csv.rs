//! Numeric CSV reader (label-in-first-column convention, as distributed
//! for MillionSongs/HIGGS/SUSY) — the second path for swapping real data
//! in for the synthetic analogues.

use super::dataset::Dataset;
use crate::linalg::mat::Mat;
use std::io::BufRead;

#[derive(Debug)]
pub struct CsvError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for CsvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "csv error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for CsvError {}

/// Parse one CSV line into label + feature values, or `None` for blank
/// lines. Shared by the eager [`read`] and the lazy
/// [`crate::data::stream_text::CsvSource`], so both agree on every edge
/// case (blank lines, whitespace, missing trailing newline).
pub(crate) fn parse_line(raw: &str, lineno: usize) -> Result<Option<(f64, Vec<f64>)>, CsvError> {
    let line = raw.trim();
    if line.is_empty() {
        return Ok(None);
    }
    let mut vals = Vec::new();
    for tok in line.split(',') {
        vals.push(tok.trim().parse::<f64>().map_err(|e| CsvError {
            line: lineno,
            msg: format!("bad number {tok:?}: {e}"),
        })?);
    }
    if vals.len() < 2 {
        return Err(CsvError {
            line: lineno,
            msg: "need label + at least one feature".into(),
        });
    }
    let label = vals[0];
    let feats = vals.split_off(1);
    Ok(Some((label, feats)))
}

/// Parse rows of comma-separated floats. `has_header` skips line 1.
/// Returns (labels, features) with the first column as the label.
pub fn read(r: impl BufRead, has_header: bool) -> Result<(Vec<f64>, Mat), CsvError> {
    let mut y = Vec::new();
    let mut rows: Vec<Vec<f64>> = Vec::new();
    let mut width = None;
    for (lineno, line) in r.lines().enumerate() {
        if has_header && lineno == 0 {
            continue;
        }
        let line = line.map_err(|e| CsvError {
            line: lineno + 1,
            msg: e.to_string(),
        })?;
        let Some((label, feats)) = parse_line(&line, lineno + 1)? else {
            continue;
        };
        let vals_len = feats.len() + 1;
        match width {
            None => width = Some(vals_len),
            Some(w) if w != vals_len => {
                return Err(CsvError {
                    line: lineno + 1,
                    msg: format!("ragged row: {vals_len} cols, expected {w}"),
                })
            }
            _ => {}
        }
        y.push(label);
        rows.push(feats);
    }
    Ok((y, Mat::from_rows(&rows)))
}

pub fn load_regression(path: &str, has_header: bool) -> anyhow::Result<Dataset> {
    let f = std::fs::File::open(path)?;
    let (y, x) = read(std::io::BufReader::new(f), has_header)?;
    Ok(Dataset::new_regression(path, x, y))
}

pub fn load_binary(path: &str, has_header: bool) -> anyhow::Result<Dataset> {
    let f = std::fs::File::open(path)?;
    let (y, x) = read(std::io::BufReader::new(f), has_header)?;
    let y = y
        .into_iter()
        .map(|v| if v > 0.0 { 1.0 } else { -1.0 })
        .collect();
    Ok(Dataset::new_binary(path, x, y))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_rows() {
        let (y, x) = read(Cursor::new("1.0,2.0,3.0\n-1.0,4.0,5.0\n"), false).unwrap();
        assert_eq!(y, vec![1.0, -1.0]);
        assert_eq!((x.rows, x.cols), (2, 2));
        assert_eq!(x[(1, 1)], 5.0);
    }

    #[test]
    fn skips_header() {
        let (y, _) = read(Cursor::new("label,f1\n2.5,1.0\n"), true).unwrap();
        assert_eq!(y, vec![2.5]);
    }

    #[test]
    fn rejects_ragged_and_garbage() {
        assert!(read(Cursor::new("1,2\n1,2,3\n"), false).is_err());
        assert!(read(Cursor::new("1,abc\n"), false).is_err());
        assert!(read(Cursor::new("1\n"), false).is_err());
    }
}

//! Dataset container + preprocessing (z-score normalization, splits) —
//! mirrors the paper's protocol: "For datasets which do not have a fixed
//! test set, we set apart 20% of the data for testing. For all datasets,
//! but YELP and IMAGENET, we normalize the features by their z-score."

use crate::linalg::mat::Mat;
use crate::util::rng::Rng;

/// Supervised dataset. `y` always holds the regression target or the
/// ±1 binary label; for multiclass tasks `labels` additionally holds the
/// class index per row (one-vs-all training reads `label_targets`).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: Mat,
    pub y: Vec<f64>,
    pub labels: Option<Vec<usize>>,
    pub n_classes: usize,
    pub name: String,
}

impl Dataset {
    pub fn new_regression(name: &str, x: Mat, y: Vec<f64>) -> Self {
        assert_eq!(x.rows, y.len());
        Dataset {
            x,
            y,
            labels: None,
            n_classes: 0,
            name: name.to_string(),
        }
    }

    pub fn new_binary(name: &str, x: Mat, y: Vec<f64>) -> Self {
        assert!(y.iter().all(|v| *v == 1.0 || *v == -1.0));
        Self {
            n_classes: 2,
            ..Dataset::new_regression(name, x, y)
        }
    }

    pub fn new_multiclass(name: &str, x: Mat, labels: Vec<usize>, n_classes: usize) -> Self {
        assert_eq!(x.rows, labels.len());
        assert!(labels.iter().all(|&l| l < n_classes));
        let y = labels.iter().map(|&l| l as f64).collect();
        Dataset {
            x,
            y,
            labels: Some(labels),
            n_classes,
            name: name.to_string(),
        }
    }

    pub fn n(&self) -> usize {
        self.x.rows
    }

    pub fn d(&self) -> usize {
        self.x.cols
    }

    pub fn is_multiclass(&self) -> bool {
        self.labels.is_some()
    }

    /// ±1 targets for the one-vs-all subproblem of class k.
    pub fn label_targets(&self, k: usize) -> Vec<f64> {
        let labels = self.labels.as_ref().expect("not a multiclass dataset");
        labels
            .iter()
            .map(|&l| if l == k { 1.0 } else { -1.0 })
            .collect()
    }

    pub fn select(&self, idx: &[usize]) -> Dataset {
        Dataset {
            x: self.x.select_rows(idx),
            y: idx.iter().map(|&i| self.y[i]).collect(),
            labels: self
                .labels
                .as_ref()
                .map(|l| idx.iter().map(|&i| l[i]).collect()),
            n_classes: self.n_classes,
            name: self.name.clone(),
        }
    }

    /// Shuffled train/test split; `test_frac` in (0, 1).
    pub fn split(&self, test_frac: f64, rng: &mut Rng) -> (Dataset, Dataset) {
        assert!(test_frac > 0.0 && test_frac < 1.0);
        let n = self.n();
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let n_test = ((n as f64) * test_frac).round().max(1.0) as usize;
        let (test_idx, train_idx) = idx.split_at(n_test);
        (self.select(train_idx), self.select(test_idx))
    }
}

/// Per-feature affine normalizer fit on training data, applied to both
/// splits (z-score).
#[derive(Debug, Clone)]
pub struct ZScore {
    pub mean: Vec<f64>,
    pub std: Vec<f64>,
}

impl ZScore {
    pub fn fit(x: &Mat) -> ZScore {
        let d = x.cols;
        let n = x.rows.max(1) as f64;
        let mut mean = vec![0.0; d];
        for i in 0..x.rows {
            for (m, v) in mean.iter_mut().zip(x.row(i)) {
                *m += v;
            }
        }
        for m in &mut mean {
            *m /= n;
        }
        let mut var = vec![0.0; d];
        for i in 0..x.rows {
            for j in 0..d {
                let c = x[(i, j)] - mean[j];
                var[j] += c * c;
            }
        }
        let std = var
            .into_iter()
            .map(|v| (v / n).sqrt().max(1e-12))
            .collect();
        ZScore { mean, std }
    }

    pub fn apply(&self, x: &Mat) -> Mat {
        let mut out = x.clone();
        self.apply_mut(&mut out);
        out
    }

    /// In-place [`ZScore::apply`] — the streaming pipeline normalizes each
    /// resident chunk without allocating a second copy.
    pub fn apply_mut(&self, x: &mut Mat) {
        assert_eq!(x.cols, self.mean.len(), "zscore dim mismatch");
        for i in 0..x.rows {
            let row = x.row_mut(i);
            for j in 0..row.len() {
                row[j] = (row[j] - self.mean[j]) / self.std[j];
            }
        }
    }

    /// Dtype-aware [`ZScore::apply_mut`]: f64 blocks normalize in place;
    /// f32 blocks normalize through f64 intermediates (the stats are f64)
    /// and round once back to storage.
    pub fn apply_block(&self, x: &mut crate::linalg::mat32::XBlock) {
        use crate::linalg::mat32::XBlock;
        match x {
            XBlock::F64(m) => self.apply_mut(m),
            XBlock::F32(m) => {
                assert_eq!(m.cols, self.mean.len(), "zscore dim mismatch");
                for i in 0..m.rows {
                    let row = m.row_mut(i);
                    for j in 0..row.len() {
                        row[j] = ((row[j] as f64 - self.mean[j]) / self.std[j]) as f32;
                    }
                }
            }
        }
    }

    /// Fit on train, transform both in place.
    pub fn normalize(train: &mut Dataset, test: &mut Dataset) -> ZScore {
        let z = ZScore::fit(&train.x);
        train.x = z.apply(&train.x);
        test.x = z.apply(&test.x);
        z
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        let x = Mat::from_rows(&[
            vec![1.0, 10.0],
            vec![2.0, 20.0],
            vec![3.0, 30.0],
            vec![4.0, 40.0],
            vec![5.0, 50.0],
        ]);
        Dataset::new_regression("toy", x, vec![1.0, 2.0, 3.0, 4.0, 5.0])
    }

    #[test]
    fn split_partitions() {
        let d = toy();
        let mut rng = Rng::new(1);
        let (tr, te) = d.split(0.4, &mut rng);
        assert_eq!(tr.n() + te.n(), 5);
        assert_eq!(te.n(), 2);
        // y stays aligned with x (row payload check: y == x[:,0])
        for ds in [&tr, &te] {
            for i in 0..ds.n() {
                assert_eq!(ds.y[i], ds.x[(i, 0)]);
            }
        }
    }

    #[test]
    fn zscore_unit_moments() {
        let d = toy();
        let z = ZScore::fit(&d.x);
        let nx = z.apply(&d.x);
        for j in 0..2 {
            let col: Vec<f64> = (0..nx.rows).map(|i| nx[(i, j)]).collect();
            let m = crate::linalg::vec_ops::mean(&col);
            let v = crate::linalg::vec_ops::variance(&col);
            assert!(m.abs() < 1e-12);
            assert!((v - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn zscore_applies_train_stats_to_test() {
        let mut tr = toy();
        let mut te = toy();
        te.x.scale(2.0);
        let z = ZScore::normalize(&mut tr, &mut te);
        // test was scaled by 2 -> normalized test col mean is nonzero
        assert!(z.mean[0] > 0.0);
        assert!(te.x[(0, 0)] != tr.x[(0, 0)]);
    }

    #[test]
    fn multiclass_targets() {
        let x = Mat::from_rows(&[vec![0.0], vec![1.0], vec![2.0]]);
        let d = Dataset::new_multiclass("mc", x, vec![0, 1, 2], 3);
        assert_eq!(d.label_targets(1), vec![-1.0, 1.0, -1.0]);
        assert!(d.is_multiclass());
    }

    #[test]
    #[should_panic]
    fn binary_requires_pm1() {
        let x = Mat::from_rows(&[vec![0.0]]);
        Dataset::new_binary("bad", x, vec![0.5]);
    }
}

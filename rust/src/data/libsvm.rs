//! LIBSVM-format reader, so the real SUSY / HIGGS / YELP-style datasets can
//! replace the synthetic analogues when available:
//!
//! ```text
//! <label> <index>:<value> <index>:<value> ...
//! ```
//!
//! Indices are 1-based (standard); the feature dimension is the max index
//! seen unless `dim` pins it.

use super::dataset::Dataset;
use crate::linalg::mat::Mat;
use std::io::BufRead;

#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "libsvm parse error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse one libsvm line into (label, 0-based sparse features), or `None`
/// for blank/comment-only lines. Shared by the eager [`read`] and the
/// lazy [`crate::data::stream_text::LibsvmSource`], so both agree on
/// every edge case (comments, blank lines, out-of-order indices).
pub(crate) fn parse_line(
    raw: &str,
    lineno: usize,
) -> Result<Option<(f64, Vec<(usize, f64)>)>, ParseError> {
    let line = raw.split('#').next().unwrap_or("").trim();
    if line.is_empty() {
        return Ok(None);
    }
    let mut parts = line.split_ascii_whitespace();
    let label: f64 = parts.next().unwrap().parse().map_err(|e| ParseError {
        line: lineno,
        msg: format!("bad label: {e}"),
    })?;
    let mut feats = Vec::new();
    for tok in parts {
        let (i, v) = tok.split_once(':').ok_or_else(|| ParseError {
            line: lineno,
            msg: format!("expected index:value, got {tok:?}"),
        })?;
        let i: usize = i.parse().map_err(|e| ParseError {
            line: lineno,
            msg: format!("bad index: {e}"),
        })?;
        let v: f64 = v.parse().map_err(|e| ParseError {
            line: lineno,
            msg: format!("bad value: {e}"),
        })?;
        if i == 0 {
            return Err(ParseError {
                line: lineno,
                msg: "libsvm indices are 1-based".into(),
            });
        }
        feats.push((i - 1, v));
    }
    Ok(Some((label, feats)))
}

/// Parse from any reader. `dim = Some(d)` pins the feature count (features
/// beyond it error); `None` infers it from the data.
pub fn read(r: impl BufRead, dim: Option<usize>) -> Result<(Mat, Vec<f64>), ParseError> {
    let mut rows: Vec<Vec<(usize, f64)>> = Vec::new();
    let mut ys = Vec::new();
    let mut max_idx = 0usize;
    for (lineno, line) in r.lines().enumerate() {
        let line = line.map_err(|e| ParseError {
            line: lineno + 1,
            msg: e.to_string(),
        })?;
        let Some((label, feats)) = parse_line(&line, lineno + 1)? else {
            continue;
        };
        for &(j, _) in &feats {
            max_idx = max_idx.max(j + 1);
        }
        ys.push(label);
        rows.push(feats);
    }
    let d = match dim {
        Some(d) => {
            if max_idx > d {
                return Err(ParseError {
                    line: 0,
                    msg: format!("feature index {max_idx} exceeds pinned dim {d}"),
                });
            }
            d
        }
        None => max_idx,
    };
    let mut x = Mat::zeros(rows.len(), d);
    for (i, feats) in rows.iter().enumerate() {
        for &(j, v) in feats {
            x[(i, j)] = v;
        }
    }
    Ok((x, ys))
}

/// Load a regression dataset from a libsvm file.
pub fn load_regression(path: &str, dim: Option<usize>) -> anyhow::Result<Dataset> {
    let f = std::fs::File::open(path)?;
    let (x, y) = read(std::io::BufReader::new(f), dim)?;
    Ok(Dataset::new_regression(path, x, y))
}

/// Load a ±1 binary classification dataset (0/1 labels are remapped).
pub fn load_binary(path: &str, dim: Option<usize>) -> anyhow::Result<Dataset> {
    let f = std::fs::File::open(path)?;
    let (x, y) = read(std::io::BufReader::new(f), dim)?;
    let y = y
        .into_iter()
        .map(|v| if v > 0.0 { 1.0 } else { -1.0 })
        .collect();
    Ok(Dataset::new_binary(path, x, y))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_basic() {
        let src = "1 1:0.5 3:2.0\n-1 2:1.5\n";
        let (x, y) = read(Cursor::new(src), None).unwrap();
        assert_eq!((x.rows, x.cols), (2, 3));
        assert_eq!(y, vec![1.0, -1.0]);
        assert_eq!(x[(0, 0)], 0.5);
        assert_eq!(x[(0, 2)], 2.0);
        assert_eq!(x[(1, 1)], 1.5);
        assert_eq!(x[(1, 0)], 0.0);
    }

    #[test]
    fn skips_comments_and_blanks() {
        let src = "# header\n\n1 1:1.0 # trailing\n";
        let (x, y) = read(Cursor::new(src), None).unwrap();
        assert_eq!(x.rows, 1);
        assert_eq!(y, vec![1.0]);
    }

    #[test]
    fn pinned_dim() {
        let (x, _) = read(Cursor::new("0 1:1\n"), Some(5)).unwrap();
        assert_eq!(x.cols, 5);
        assert!(read(Cursor::new("0 9:1\n"), Some(5)).is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(read(Cursor::new("abc 1:1\n"), None).is_err());
        assert!(read(Cursor::new("1 nocolon\n"), None).is_err());
        assert!(read(Cursor::new("1 0:1\n"), None).is_err()); // 0-based index
        assert!(read(Cursor::new("1 2:xyz\n"), None).is_err());
    }
}

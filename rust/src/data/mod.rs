//! Data pipeline: dataset container + normalization, synthetic analogues
//! of the paper's evaluation datasets, and loaders for real data.
pub mod csv;
pub mod dataset;
pub mod libsvm;
pub mod synth;

pub use dataset::{Dataset, ZScore};

//! Data pipeline: the in-memory [`Dataset`] container + normalization,
//! synthetic analogues of the paper's evaluation datasets, eager loaders
//! for real data, and the **out-of-core pipeline** — a chunked
//! [`source::DataSource`] abstraction (in-memory, binary shard, lazy
//! libsvm/CSV backends) that streams datasets larger than RAM through
//! fit and predict with O(chunk) resident features (see
//! DESIGN.md § "Out-of-core path").
pub mod csv;
pub mod dataset;
pub mod libsvm;
pub mod shard;
pub mod source;
pub mod stream_text;
pub mod synth;

pub use dataset::{Dataset, ZScore};
pub use source::{CastSource, Chunk, DataSource, MemSource, NanPolicy, SanitizeSource, ZScoreSource};

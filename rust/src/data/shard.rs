//! Chunked binary shard format — the on-disk backend of the out-of-core
//! pipeline (`falkon convert` writes it, [`ShardSource`] streams it).
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! header:  magic "FALKSHRD" | version u32 | flags u32 | d u64
//!          | n_classes u64 | name_len u32 | name (utf-8)
//! records: rows u64 | x rows·d f64|f32 | y rows f64 | labels rows u64
//! ```
//!
//! `flags` bit 0 ([`FLAG_LABELS`]) marks a labels block per record;
//! bit 1 ([`FLAG_F32`]) marks f32 feature storage — the x payload is
//! 4 bytes/element and [`ShardSource`] serves `Dtype::F32` chunks
//! straight from disk, so an out-of-core sweep over an f32 shard is
//! half the bytes end to end. Targets (`y`) and labels always stay
//! f64/u64: they are O(rows), not O(rows·d), and the CG right-hand
//! side must not lose precision. Readers reject any flag bit they do
//! not know (a shard written by a newer falkon must fail loudly, not
//! be misread at the wrong record stride).
//!
//! Records are appended as data arrives, so a conversion from a text
//! stream is single-pass and never needs the row count up front. The
//! reader scans the record headers once at `open` (seeking over the
//! payloads — O(records) work, O(1) memory), which yields the exact row
//! count and lets the reader's [`DataSource::next_chunk`] serve any chunk budget with
//! positioned reads: a chunk never exceeds `min(budget, record rows)`
//! resident rows. `std` has no portable mmap, so chunk access is
//! seek+read — the working-set property (only the requested rows touch
//! memory) is the same.

use super::dataset::Dataset;
use super::source::{Chunk, DataSource, DEFAULT_CHUNK_ROWS};
use crate::linalg::mat::Mat;
use crate::linalg::mat32::{Dtype, MatF32, XBlock};
use anyhow::{Context, Result};
use std::fs::File;
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};

const MAGIC: &[u8; 8] = b"FALKSHRD";
const VERSION: u32 = 1;
/// Header flag bit 0: each record carries a labels block.
pub const FLAG_LABELS: u32 = 1;
/// Header flag bit 1: x payloads are f32 (4 bytes/element).
pub const FLAG_F32: u32 = 2;
/// Every flag bit this reader understands; anything else is rejected.
const KNOWN_FLAGS: u32 = FLAG_LABELS | FLAG_F32;

fn write_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Read a u64 or detect a clean end-of-file (None). A partial trailing
/// integer is a corrupt shard and errors.
fn try_read_u64(r: &mut impl Read) -> Result<Option<u64>> {
    let mut b = [0u8; 8];
    let mut got = 0;
    while got < 8 {
        let k = r.read(&mut b[got..])?;
        if k == 0 {
            anyhow::ensure!(got == 0, "truncated record header ({got} of 8 bytes)");
            return Ok(None);
        }
        got += k;
    }
    Ok(Some(u64::from_le_bytes(b)))
}

fn read_f64s(r: &mut impl Read, count: usize) -> Result<Vec<f64>> {
    let mut buf = vec![0u8; count * 8];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn read_u64s(r: &mut impl Read, count: usize) -> Result<Vec<u64>> {
    let mut buf = vec![0u8; count * 8];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn read_f32s(r: &mut impl Read, count: usize) -> Result<Vec<f32>> {
    let mut buf = vec![0u8; count * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Incremental shard writer: create with the schema, append row blocks
/// as they arrive, `finish` to flush. Single-pass — the total row count
/// is never needed up front.
pub struct ShardWriter {
    w: BufWriter<File>,
    d: usize,
    has_labels: bool,
    dtype: Dtype,
    rows: usize,
}

impl ShardWriter {
    /// Create an f64-storage shard (the default format).
    pub fn create(
        path: &str,
        d: usize,
        n_classes: usize,
        has_labels: bool,
        name: &str,
    ) -> Result<ShardWriter> {
        ShardWriter::create_with_dtype(path, d, n_classes, has_labels, name, Dtype::F64)
    }

    /// Create a shard with an explicit feature storage format. `F32`
    /// sets [`FLAG_F32`] and serializes x payloads at 4 bytes/element —
    /// incoming f64 blocks are rounded once at write time, which is how
    /// `falkon convert --dtype f32` produces half-size shards.
    pub fn create_with_dtype(
        path: &str,
        d: usize,
        n_classes: usize,
        has_labels: bool,
        name: &str,
        dtype: Dtype,
    ) -> Result<ShardWriter> {
        anyhow::ensure!(d > 0, "shard needs at least one feature");
        let f = File::create(path).with_context(|| format!("creating shard {path}"))?;
        let mut w = BufWriter::new(f);
        w.write_all(MAGIC)?;
        write_u32(&mut w, VERSION)?;
        let mut flags = if has_labels { FLAG_LABELS } else { 0 };
        if dtype == Dtype::F32 {
            flags |= FLAG_F32;
        }
        write_u32(&mut w, flags)?;
        write_u64(&mut w, d as u64)?;
        write_u64(&mut w, n_classes as u64)?;
        let name_bytes = name.as_bytes();
        write_u32(&mut w, name_bytes.len() as u32)?;
        w.write_all(name_bytes)?;
        Ok(ShardWriter {
            w,
            d,
            has_labels,
            dtype,
            rows: 0,
        })
    }

    /// Append one record from an `f64` block (cast to the shard dtype on
    /// write). Empty blocks are skipped (a record's row count must be
    /// positive so the reader's record scan terminates cleanly).
    pub fn write_chunk(&mut self, x: &Mat, y: &[f64], labels: Option<&[usize]>) -> Result<()> {
        self.write_record(x.rows, x.cols, y, labels, |buf, dtype| match dtype {
            Dtype::F64 => {
                for &v in &x.data {
                    buf.extend_from_slice(&v.to_le_bytes());
                }
            }
            Dtype::F32 => {
                for &v in &x.data {
                    buf.extend_from_slice(&(v as f32).to_le_bytes());
                }
            }
        })
    }

    /// Append one record from either storage format. An f32 block going
    /// into an f32 shard is serialized bit-exactly (no widen/narrow
    /// round trip); mixed cases cast once at write time.
    pub fn write_chunk_block(
        &mut self,
        x: &XBlock,
        y: &[f64],
        labels: Option<&[usize]>,
    ) -> Result<()> {
        match x {
            XBlock::F64(m) => self.write_chunk(m, y, labels),
            XBlock::F32(m) => {
                self.write_record(m.rows, m.cols, y, labels, |buf, dtype| match dtype {
                    Dtype::F64 => {
                        for &v in &m.data {
                            buf.extend_from_slice(&(v as f64).to_le_bytes());
                        }
                    }
                    Dtype::F32 => {
                        for &v in &m.data {
                            buf.extend_from_slice(&v.to_le_bytes());
                        }
                    }
                })
            }
        }
    }

    fn write_record(
        &mut self,
        rows: usize,
        cols: usize,
        y: &[f64],
        labels: Option<&[usize]>,
        push_x: impl FnOnce(&mut Vec<u8>, Dtype),
    ) -> Result<()> {
        anyhow::ensure!(cols == self.d, "chunk d {} != shard d {}", cols, self.d);
        anyhow::ensure!(rows == y.len(), "chunk x rows {} != y len {}", rows, y.len());
        anyhow::ensure!(
            labels.is_some() == self.has_labels,
            "chunk labels presence does not match the shard schema"
        );
        if rows == 0 {
            return Ok(());
        }
        if let Some(l) = labels {
            anyhow::ensure!(l.len() == rows, "labels len != rows");
        }
        // serialize the record into one buffer and write it in a single
        // call — per-value write_all through the BufWriter dominates
        // convert throughput on large chunks
        let payload =
            rows * cols * self.dtype.size_of() + (y.len() + labels.map_or(0, |l| l.len())) * 8;
        let mut buf = Vec::with_capacity(8 + payload);
        buf.extend_from_slice(&(rows as u64).to_le_bytes());
        push_x(&mut buf, self.dtype);
        for &v in y {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        if let Some(l) = labels {
            for &v in l {
                buf.extend_from_slice(&(v as u64).to_le_bytes());
            }
        }
        self.w.write_all(&buf)?;
        self.rows += rows;
        Ok(())
    }

    /// Flush and return the total rows written.
    pub fn finish(mut self) -> Result<usize> {
        self.w.flush()?;
        Ok(self.rows)
    }
}

/// Write an in-memory [`Dataset`] as a single-record shard (one record
/// lets the reader re-chunk at any budget).
pub fn write_dataset(path: &str, data: &Dataset) -> Result<()> {
    let mut w = ShardWriter::create(
        path,
        data.d(),
        data.n_classes,
        data.labels.is_some(),
        &data.name,
    )?;
    w.write_chunk(&data.x, &data.y, data.labels.as_deref())?;
    w.finish()?;
    Ok(())
}

/// Stream-convert any [`DataSource`] into a shard, one record per source
/// chunk — single pass, O(chunk) memory. Returns the rows written.
/// The shard's storage format follows the first chunk's dtype (use
/// [`write_source_dtype`] to force one). Transient source errors are
/// retried with bounded backoff; a retried read re-delivers the
/// suppressed chunk, so the shard is identical to a fault-free
/// conversion.
pub fn write_source(path: &str, source: &mut dyn DataSource) -> Result<usize> {
    write_source_impl(path, source, None)
}

/// [`write_source`] with an explicit storage format — the engine of
/// `falkon convert --dtype f32` (each f64 chunk is rounded once on its
/// way to disk; the shard is half the size and streams as f32).
pub fn write_source_dtype(path: &str, source: &mut dyn DataSource, dtype: Dtype) -> Result<usize> {
    write_source_impl(path, source, Some(dtype))
}

fn write_source_impl(
    path: &str,
    source: &mut dyn DataSource,
    dtype: Option<Dtype>,
) -> Result<usize> {
    let retry = crate::util::fault::RetryPolicy::default();
    retry.run("convert: reset", || source.reset())?;
    // peek the first chunk to learn whether the stream carries labels and
    // (absent an override) which storage format to use — both live in
    // the header, which must be written before any record
    let first = retry.run("convert: next_chunk", || source.next_chunk())?;
    let has_labels = first.as_ref().map(|c| c.labels.is_some()).unwrap_or(false);
    let dtype = dtype.or_else(|| first.as_ref().map(|c| c.dtype())).unwrap_or_default();
    let mut w = ShardWriter::create_with_dtype(
        path,
        source.d(),
        source.n_classes(),
        has_labels,
        source.name(),
        dtype,
    )?;
    if let Some(chunk) = first {
        w.write_chunk_block(&chunk.x, &chunk.y, chunk.labels.as_deref())?;
    }
    while let Some(chunk) = retry.run("convert: next_chunk", || source.next_chunk())? {
        w.write_chunk_block(&chunk.x, &chunk.y, chunk.labels.as_deref())?;
    }
    w.finish()
}

/// Offset + row count of one record's payload (`off` points at the
/// record's `rows` header field).
struct RecordMeta {
    off: u64,
    rows: usize,
}

/// Seek-based streaming reader over a shard file. `open` scans the
/// record headers once (exact row count, record offsets); `next_chunk`
/// then reads at most `chunk_rows` rows per call with positioned reads,
/// never crossing a record boundary.
pub struct ShardSource {
    file: File,
    d: usize,
    n_classes: usize,
    has_labels: bool,
    dtype: Dtype,
    name: String,
    records: Vec<RecordMeta>,
    n: usize,
    chunk_rows: usize,
    rec: usize,
    row_in_rec: usize,
    row_global: usize,
}

impl ShardSource {
    pub fn open(path: &str, chunk_rows: usize) -> Result<ShardSource> {
        let mut file = File::open(path).with_context(|| format!("opening shard {path}"))?;
        let mut magic = [0u8; 8];
        file.read_exact(&mut magic)
            .with_context(|| format!("reading shard header of {path}"))?;
        anyhow::ensure!(&magic == MAGIC, "{path} is not a falkon shard (bad magic)");
        let version = read_u32(&mut file)?;
        anyhow::ensure!(version == VERSION, "unsupported shard version {version}");
        let flags = read_u32(&mut file)?;
        // unknown flag bits change the record layout (FLAG_F32 already
        // does: 4-byte x elements); a reader that ignored them would scan
        // record headers at the wrong stride and serve garbage rows.
        // Fatal, not transient — retrying cannot fix a newer format.
        if flags & !KNOWN_FLAGS != 0 {
            return Err(crate::util::fault::FaultError::fatal(format!(
                "shard {path} has unknown flag bits {:#x} (known mask {KNOWN_FLAGS:#x}) — \
                 written by a newer falkon?",
                flags & !KNOWN_FLAGS
            )));
        }
        let has_labels = flags & FLAG_LABELS != 0;
        let dtype = if flags & FLAG_F32 != 0 {
            Dtype::F32
        } else {
            Dtype::F64
        };
        let d = read_u64(&mut file)? as usize;
        anyhow::ensure!(d > 0, "shard has zero feature dim");
        let n_classes = read_u64(&mut file)? as usize;
        let name_len = read_u32(&mut file)? as usize;
        let mut name_bytes = vec![0u8; name_len];
        file.read_exact(&mut name_bytes)?;
        let name = String::from_utf8(name_bytes).context("shard name is not utf-8")?;

        // record scan: headers only, payloads seeked over. `len` bounds
        // every record end, so a corrupt row count (however large) fails
        // the truncation check instead of overflowing the seek offset.
        let row_bytes = (d * dtype.size_of() + (1 + usize::from(has_labels)) * 8) as u64;
        let len = file.metadata()?.len();
        let mut records = Vec::new();
        let mut n = 0usize;
        loop {
            let off = file.stream_position()?;
            let Some(rows) = try_read_u64(&mut file)? else {
                break;
            };
            anyhow::ensure!(rows > 0, "shard record at offset {off} has zero rows");
            let end = off as u128 + 8 + rows as u128 * row_bytes as u128;
            anyhow::ensure!(
                end <= len as u128,
                "shard record at offset {off} is truncated ({end} > file len {len})"
            );
            let rows = rows as usize;
            records.push(RecordMeta { off, rows });
            n += rows;
            file.seek(SeekFrom::Start(end as u64))?;
        }
        Ok(ShardSource {
            file,
            d,
            n_classes,
            has_labels,
            dtype,
            name,
            records,
            n,
            chunk_rows: chunk_rows.max(1),
            rec: 0,
            row_in_rec: 0,
            row_global: 0,
        })
    }

    /// Feature storage format of this shard ([`FLAG_F32`] ⇒ `F32`).
    pub fn dtype(&self) -> Dtype {
        self.dtype
    }
}

impl DataSource for ShardSource {
    fn d(&self) -> usize {
        self.d
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.n)
    }

    fn reset(&mut self) -> Result<()> {
        self.rec = 0;
        self.row_in_rec = 0;
        self.row_global = 0;
        Ok(())
    }

    fn next_chunk(&mut self) -> Result<Option<Chunk>> {
        if self.rec >= self.records.len() {
            return Ok(None);
        }
        let (off, rec_rows) = {
            let rm = &self.records[self.rec];
            (rm.off, rm.rows)
        };
        let take = (rec_rows - self.row_in_rec).min(self.chunk_rows);
        let base = off + 8; // past the rows header
        let esize = self.dtype.size_of();
        // x block (element width follows the header dtype flag)
        self.file.seek(SeekFrom::Start(
            base + (self.row_in_rec * self.d * esize) as u64,
        ))?;
        let x = match self.dtype {
            Dtype::F64 => {
                let xdata = read_f64s(&mut self.file, take * self.d)?;
                XBlock::F64(Mat::from_vec(take, self.d, xdata))
            }
            Dtype::F32 => {
                let xdata = read_f32s(&mut self.file, take * self.d)?;
                XBlock::F32(MatF32::from_vec(take, self.d, xdata))
            }
        };
        // y block (always f64, after the full x payload of the record)
        let y_base = base + (rec_rows * self.d * esize) as u64;
        self.file
            .seek(SeekFrom::Start(y_base + (self.row_in_rec * 8) as u64))?;
        let y = read_f64s(&mut self.file, take)?;
        // labels block
        let labels = if self.has_labels {
            self.file.seek(SeekFrom::Start(
                y_base + (rec_rows * 8) as u64 + (self.row_in_rec * 8) as u64,
            ))?;
            Some(
                read_u64s(&mut self.file, take)?
                    .into_iter()
                    .map(|v| v as usize)
                    .collect(),
            )
        } else {
            None
        };
        let start = self.row_global;
        self.row_global += take;
        self.row_in_rec += take;
        if self.row_in_rec == rec_rows {
            self.rec += 1;
            self.row_in_rec = 0;
        }
        Ok(Some(Chunk { start, x, y, labels }))
    }

    fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }

    fn n_classes(&self) -> usize {
        self.n_classes
    }

    fn name(&self) -> &str {
        &self.name
    }
}

/// Load a whole shard into memory (small shards / the in-memory CLI path).
pub fn load(path: &str) -> Result<Dataset> {
    let mut src = ShardSource::open(path, DEFAULT_CHUNK_ROWS)?;
    super::source::collect(&mut src)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::source::{collect, MemSource};
    use crate::data::synth;
    use crate::util::ptest::check;
    use crate::util::rng::Rng;

    fn tmp(tag: &str) -> String {
        std::env::temp_dir()
            .join(format!("falkon_shard_{tag}_{}.shard", std::process::id()))
            .to_string_lossy()
            .into_owned()
    }

    #[test]
    fn roundtrip_regression_bitwise() {
        let data = synth::smooth_regression(&mut Rng::new(3), 257, 6, 0.05);
        let path = tmp("reg");
        write_dataset(&path, &data).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.x.data, data.x.data);
        assert_eq!(back.y, data.y);
        assert_eq!(back.d(), 6);
        assert_eq!(back.name, data.name);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn roundtrip_multiclass_bitwise() {
        let data = synth::blobs(&mut Rng::new(4), 120, 5, 3);
        let path = tmp("mc");
        write_dataset(&path, &data).unwrap();
        let back = load(&path).unwrap();
        assert!(back.is_multiclass());
        assert_eq!(back.n_classes, 3);
        assert_eq!(back.labels, data.labels);
        assert_eq!(back.x.data, data.x.data);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn reader_rechunks_at_any_budget() {
        // property: Dataset -> shard -> DataSource equals the in-memory
        // source bitwise, for random record sizes and read budgets
        check("shard roundtrip", 12, |g| {
            let n = g.usize_in(1, 200);
            let d = g.usize_in(1, 9);
            let mut rng = Rng::new(g.case as u64 + 100);
            let data = crate::data::Dataset::new_regression(
                "p",
                crate::linalg::mat::Mat::from_vec(n, d, rng.normals(n * d)),
                rng.normals(n),
            );
            let rec_rows = g.usize_in(1, n + 20);
            let budget = g.usize_in(1, n + 20);
            let path = tmp(&format!("prop{}", g.case));
            // write in rec_rows-sized records via the streaming writer
            let mut src = MemSource::new(data.clone(), rec_rows);
            let wrote = write_source(&path, &mut src).unwrap();
            assert_eq!(wrote, n);
            let mut shard = ShardSource::open(&path, budget).unwrap();
            assert_eq!(shard.len_hint(), Some(n));
            let back = collect(&mut shard).unwrap();
            assert_eq!(back.x.data, data.x.data, "x mismatch");
            assert_eq!(back.y, data.y, "y mismatch");
            // chunks never exceed the budget
            shard.reset().unwrap();
            while let Some(c) = shard.next_chunk().unwrap() {
                assert!(c.rows() <= budget);
            }
            let _ = std::fs::remove_file(&path);
        });
    }

    #[test]
    fn incremental_writer_appends_records() {
        let data = synth::smooth_regression(&mut Rng::new(8), 90, 4, 0.05);
        let path = tmp("incr");
        let mut w = ShardWriter::create(&path, 4, 0, false, "incr").unwrap();
        for start in (0..90).step_by(40) {
            let end = (start + 40).min(90);
            w.write_chunk(&data.x.slice_rows(start, end), &data.y[start..end], None)
                .unwrap();
        }
        assert_eq!(w.finish().unwrap(), 90);
        let back = load(&path).unwrap();
        assert_eq!(back.x.data, data.x.data);
        assert_eq!(back.y, data.y);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        let path = tmp("bad");
        std::fs::write(&path, b"NOTASHARDxxxxxxxxxxxx").unwrap();
        assert!(ShardSource::open(&path, 64).is_err());
        // valid shard, then cut the file short
        let data = synth::smooth_regression(&mut Rng::new(9), 40, 3, 0.05);
        write_dataset(&path, &data).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 16]).unwrap();
        assert!(ShardSource::open(&path, 64).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn f32_shard_roundtrips_rounded_values_at_half_size() {
        let data = synth::smooth_regression(&mut Rng::new(11), 300, 6, 0.05);
        let p64 = tmp("d64");
        let p32 = tmp("d32");
        write_source(&p64, &mut MemSource::new(data.clone(), 64)).unwrap();
        write_source_dtype(&p32, &mut MemSource::new(data.clone(), 64), Dtype::F32).unwrap();
        // the x payload dominates, so the f32 shard is close to half size
        let s64 = std::fs::metadata(&p64).unwrap().len() as f64;
        let s32 = std::fs::metadata(&p32).unwrap().len() as f64;
        assert!(s32 < 0.7 * s64, "f32 shard {s32}B vs f64 {s64}B");
        let mut src = ShardSource::open(&p32, 77).unwrap();
        assert_eq!(src.dtype(), Dtype::F32);
        assert_eq!(src.len_hint(), Some(300));
        src.reset().unwrap();
        let c = src.next_chunk().unwrap().unwrap();
        assert_eq!(c.dtype(), Dtype::F32);
        // chunks stop at record boundaries (64-row records here)
        assert_eq!(c.x_bytes(), 64 * 6 * 4, "4 bytes/element resident");
        // values are the f64 originals rounded exactly once; y bit-exact
        let back = collect(&mut src).unwrap();
        let want: Vec<f64> = data.x.data.iter().map(|&v| (v as f32) as f64).collect();
        assert_eq!(back.x.data, want);
        assert_eq!(back.y, data.y);
        let _ = std::fs::remove_file(&p64);
        let _ = std::fs::remove_file(&p32);
    }

    #[test]
    fn f32_chunks_serialize_bit_exactly_into_f32_shards() {
        // an f32 source converted with no override keeps its dtype and
        // the payload round-trips without a widen/narrow cycle
        let data = synth::blobs(&mut Rng::new(12), 80, 4, 3);
        let path = tmp("f32auto");
        let mut src = MemSource::with_dtype(data.clone(), 33, Dtype::F32);
        assert_eq!(write_source(&path, &mut src).unwrap(), 80);
        let mut shard = ShardSource::open(&path, 19).unwrap();
        assert_eq!(shard.dtype(), Dtype::F32);
        let back = collect(&mut shard).unwrap();
        let want: Vec<f64> = data.x.data.iter().map(|&v| (v as f32) as f64).collect();
        assert_eq!(back.x.data, want);
        assert_eq!(back.labels, data.labels);
        // widening an f32 shard back to f64 records is also lossless
        let p64 = tmp("widen");
        let mut up = ShardSource::open(&path, 19).unwrap();
        write_source_dtype(&p64, &mut up, Dtype::F64).unwrap();
        let wide = load(&p64).unwrap();
        assert_eq!(wide.x.data, want);
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&p64);
    }

    #[test]
    fn rejects_unknown_flag_bits_fatally() {
        // a shard from a future format version must fail loudly at open,
        // not be scanned at the wrong record stride. flags is the u32 at
        // bytes 12..16 (after magic + version).
        let data = synth::smooth_regression(&mut Rng::new(13), 20, 3, 0.05);
        let path = tmp("flags");
        write_dataset(&path, &data).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[12] |= 0x4; // unknown bit 2
        std::fs::write(&path, &bytes).unwrap();
        let err = ShardSource::open(&path, 64).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown flag bits"), "{msg}");
        assert_eq!(
            crate::util::fault::classify(&err),
            crate::util::fault::ErrorClass::Fatal,
            "unknown-format errors must never be retried"
        );
        // known bits still open fine after restoring
        bytes[12] &= !0x4;
        std::fs::write(&path, &bytes).unwrap();
        assert!(ShardSource::open(&path, 64).is_ok());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn f32_reader_rechunks_at_any_budget() {
        check("f32 shard rechunk", 8, |g| {
            let n = g.usize_in(1, 150);
            let d = g.usize_in(1, 7);
            let mut rng = Rng::new(g.case as u64 + 500);
            let data = crate::data::Dataset::new_regression(
                "p32",
                crate::linalg::mat::Mat::from_vec(n, d, rng.normals(n * d)),
                rng.normals(n),
            );
            let rec_rows = g.usize_in(1, n + 10);
            let budget = g.usize_in(1, n + 10);
            let path = tmp(&format!("prop32_{}", g.case));
            let mut src = MemSource::with_dtype(data.clone(), rec_rows, Dtype::F32);
            assert_eq!(write_source(&path, &mut src).unwrap(), n);
            let mut shard = ShardSource::open(&path, budget).unwrap();
            let back = collect(&mut shard).unwrap();
            let want: Vec<f64> = data.x.data.iter().map(|&v| (v as f32) as f64).collect();
            assert_eq!(back.x.data, want, "x mismatch");
            assert_eq!(back.y, data.y, "y mismatch");
            shard.reset().unwrap();
            while let Some(c) = shard.next_chunk().unwrap() {
                assert!(c.rows() <= budget);
                assert_eq!(c.dtype(), Dtype::F32);
            }
            let _ = std::fs::remove_file(&path);
        });
    }

    #[test]
    fn empty_chunks_are_skipped() {
        let path = tmp("empty");
        let mut w = ShardWriter::create(&path, 2, 0, false, "e").unwrap();
        w.write_chunk(&Mat::zeros(0, 2), &[], None).unwrap();
        let x = Mat::from_rows(&[vec![1.0, 2.0]]);
        w.write_chunk(&x, &[3.0], None).unwrap();
        assert_eq!(w.finish().unwrap(), 1);
        let back = load(&path).unwrap();
        assert_eq!(back.n(), 1);
        assert_eq!(back.y, vec![3.0]);
        let _ = std::fs::remove_file(&path);
    }
}

//! Chunked data access — the out-of-core pipeline's core abstraction.
//!
//! A [`DataSource`] yields the dataset as a sequence of contiguous row
//! blocks ([`Chunk`]s), so the n-dependent passes (center selection,
//! normalization statistics, the CG matvec sweeps, bulk prediction) can
//! run with only O(chunk) feature rows resident instead of the full
//! `n × d` matrix. Three backends implement it:
//!
//! - [`MemSource`] wraps an in-memory [`Dataset`] (the default path, and
//!   the oracle the streaming paths are property-tested against),
//! - [`crate::data::shard::ShardSource`] reads the chunked binary shard
//!   format with positioned reads (written by `falkon convert`),
//! - [`crate::data::stream_text::LibsvmSource`] /
//!   [`crate::data::stream_text::CsvSource`] parse text formats lazily,
//!   one chunk at a time.
//!
//! [`ZScoreSource`] wraps any source and applies a z-score transform to
//! every chunk on the fly; [`ZScore::fit_source`] computes the per-feature
//! mean/variance in one streaming pass (Welford), so normalization never
//! materializes the dataset either.
//!
//! Sources are rewindable ([`DataSource::reset`]): one FALKON fit sweeps
//! the stream once per CG iteration plus twice during setup, and the
//! streaming [`crate::runtime::MatvecPlan`] resets the source at the top
//! of every apply.

use super::dataset::{Dataset, ZScore};
use crate::linalg::mat::Mat;
use crate::linalg::mat32::{Dtype, XBlock};
use anyhow::Result;

/// Default rows per chunk (8192 rows × d features × 8 bytes resident at
/// f64 storage; half that under `--dtype f32`).
pub const DEFAULT_CHUNK_ROWS: usize = 8192;

/// Rows that fit a byte budget at feature dimension `d` (at least 1),
/// assuming `f64` feature storage. Dtype-aware callers should use
/// [`rows_for_budget_dtype`].
pub fn rows_for_budget(budget_bytes: usize, d: usize) -> usize {
    rows_for_budget_dtype(budget_bytes, d, Dtype::F64)
}

/// Rows that fit a byte budget at feature dimension `d` and storage
/// format `dtype` (at least 1) — f32 storage fits twice the rows of f64
/// in the same budget.
pub fn rows_for_budget_dtype(budget_bytes: usize, d: usize, dtype: Dtype) -> usize {
    (budget_bytes / (dtype.size_of() * d.max(1))).max(1)
}

/// One resident row block of a streamed dataset. `start` is the global
/// index of the first row; consecutive chunks of a sweep are contiguous
/// (`next.start == prev.start + prev.rows()`). Features are held in
/// either storage format ([`XBlock`]); targets/labels stay `f64`/`usize`
/// — they are O(rows), not O(rows × d).
#[derive(Debug, Clone)]
pub struct Chunk {
    /// global index of row 0 of this chunk
    pub start: usize,
    /// `rows × d` features, f64 or f32 storage
    pub x: XBlock,
    /// regression target / ±1 label / class index per row
    pub y: Vec<f64>,
    /// class indices (multiclass sources only)
    pub labels: Option<Vec<usize>>,
}

impl Chunk {
    pub fn rows(&self) -> usize {
        self.x.rows()
    }

    /// Resident feature bytes of this chunk (the out-of-core memory
    /// unit) — dtype-aware: 8 bytes/element for f64 storage, 4 for f32.
    pub fn x_bytes(&self) -> usize {
        self.x.bytes()
    }

    /// Storage format of this chunk's features.
    pub fn dtype(&self) -> Dtype {
        self.x.dtype()
    }
}

/// A rewindable stream of dataset chunks. Implementations are `Send` so
/// a streaming matvec plan stays movable across threads like the
/// in-memory plan.
pub trait DataSource: Send {
    /// Feature dimension of every chunk.
    fn d(&self) -> usize;

    /// Exact total row count if known without a full data pass (all
    /// shipped backends know it; `None` routes center selection to
    /// reservoir sampling).
    fn len_hint(&self) -> Option<usize>;

    /// Rewind to the first chunk. Called before every sweep.
    fn reset(&mut self) -> Result<()>;

    /// The next row block, or `None` at end of stream.
    fn next_chunk(&mut self) -> Result<Option<Chunk>>;

    /// Configured chunk budget in rows (actual chunks may be smaller at
    /// stream tail or record boundaries).
    fn chunk_rows(&self) -> usize;

    /// Number of classes (0 = regression, 2 = binary, K = multiclass).
    fn n_classes(&self) -> usize {
        0
    }

    /// Dataset display name.
    fn name(&self) -> &str {
        "source"
    }

    /// Rows dropped by a sanitizing wrapper since the last [`reset`]
    /// (0 for raw backends; see [`SanitizeSource`]).
    ///
    /// [`reset`]: DataSource::reset
    fn skipped_rows(&self) -> usize {
        0
    }
}

/// Materialize a source into an in-memory [`Dataset`] (loading small
/// shards, and the round-trip oracle of the streaming tests).
pub fn collect(source: &mut dyn DataSource) -> Result<Dataset> {
    source.reset()?;
    let d = source.d();
    let mut xdata: Vec<f64> = Vec::new();
    let mut y: Vec<f64> = Vec::new();
    let mut labels: Vec<usize> = Vec::new();
    let mut any_labels = false;
    while let Some(chunk) = source.next_chunk()? {
        anyhow::ensure!(chunk.start == y.len(), "source chunks must be contiguous");
        chunk.x.extend_f64(&mut xdata);
        y.extend_from_slice(&chunk.y);
        if let Some(l) = &chunk.labels {
            any_labels = true;
            labels.extend_from_slice(l);
        }
    }
    let n = y.len();
    let x = Mat::from_vec(n, d, xdata);
    if any_labels {
        anyhow::ensure!(labels.len() == n, "labels missing on some chunks");
        Ok(Dataset::new_multiclass(
            source.name(),
            x,
            labels,
            source.n_classes(),
        ))
    } else {
        let mut ds = Dataset::new_regression(source.name(), x, y);
        ds.n_classes = source.n_classes();
        Ok(ds)
    }
}

/// In-memory backend: chunked views over a [`Dataset`]. The chunks copy
/// their rows (the trait yields owned blocks), so prefer the plain
/// `Dataset` paths when everything fits — this backend exists as the
/// oracle and for mixing in-memory data into source-shaped APIs.
pub struct MemSource {
    data: Dataset,
    chunk_rows: usize,
    dtype: Dtype,
    pos: usize,
}

impl MemSource {
    pub fn new(data: Dataset, chunk_rows: usize) -> MemSource {
        MemSource::with_dtype(data, chunk_rows, Dtype::F64)
    }

    /// In-memory source emitting chunks in the given storage format (the
    /// `F32` arm rounds each chunk's features once at emission).
    pub fn with_dtype(data: Dataset, chunk_rows: usize, dtype: Dtype) -> MemSource {
        MemSource {
            data,
            chunk_rows: chunk_rows.max(1),
            dtype,
            pos: 0,
        }
    }

    /// Recover the wrapped dataset.
    pub fn into_inner(self) -> Dataset {
        self.data
    }
}

impl DataSource for MemSource {
    fn d(&self) -> usize {
        self.data.d()
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.data.n())
    }

    fn reset(&mut self) -> Result<()> {
        self.pos = 0;
        Ok(())
    }

    fn next_chunk(&mut self) -> Result<Option<Chunk>> {
        let n = self.data.n();
        if self.pos >= n {
            return Ok(None);
        }
        let start = self.pos;
        let end = (start + self.chunk_rows).min(n);
        self.pos = end;
        Ok(Some(Chunk {
            start,
            x: XBlock::from_mat_dtype(self.data.x.slice_rows(start, end), self.dtype),
            y: self.data.y[start..end].to_vec(),
            labels: self.data.labels.as_ref().map(|l| l[start..end].to_vec()),
        }))
    }

    fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }

    fn n_classes(&self) -> usize {
        self.data.n_classes
    }

    fn name(&self) -> &str {
        &self.data.name
    }
}

/// Normalizing adapter: applies a fitted [`ZScore`] to every chunk's
/// features on the fly, so the streamed data is normalized without a
/// materialized copy (the out-of-core analogue of [`ZScore::apply`]).
pub struct ZScoreSource {
    inner: Box<dyn DataSource>,
    z: ZScore,
}

impl ZScoreSource {
    pub fn new(inner: Box<dyn DataSource>, z: ZScore) -> ZScoreSource {
        assert_eq!(z.mean.len(), inner.d(), "zscore dim != source dim");
        ZScoreSource { inner, z }
    }
}

impl DataSource for ZScoreSource {
    fn d(&self) -> usize {
        self.inner.d()
    }

    fn len_hint(&self) -> Option<usize> {
        self.inner.len_hint()
    }

    fn reset(&mut self) -> Result<()> {
        self.inner.reset()
    }

    fn next_chunk(&mut self) -> Result<Option<Chunk>> {
        let mut chunk = match self.inner.next_chunk()? {
            Some(c) => c,
            None => return Ok(None),
        };
        self.z.apply_block(&mut chunk.x);
        Ok(Some(chunk))
    }

    fn chunk_rows(&self) -> usize {
        self.inner.chunk_rows()
    }

    fn n_classes(&self) -> usize {
        self.inner.n_classes()
    }

    fn name(&self) -> &str {
        self.inner.name()
    }

    fn skipped_rows(&self) -> usize {
        self.inner.skipped_rows()
    }
}

/// What to do with a row whose features or target are NaN/±Inf.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum NanPolicy {
    /// error out with the offending global row index (the default —
    /// silent data corruption should be loud)
    #[default]
    FailFast,
    /// drop the row and count it ([`DataSource::skipped_rows`] reports
    /// the per-sweep total)
    Skip,
}

impl NanPolicy {
    pub fn parse(s: &str) -> anyhow::Result<NanPolicy> {
        match s {
            "fail" | "fail-fast" => Ok(NanPolicy::FailFast),
            "skip" => Ok(NanPolicy::Skip),
            other => anyhow::bail!("unknown --nan-policy {other:?} (expected fail|skip)"),
        }
    }
}

/// Sanitizing adapter: validates every chunk's rows for non-finite
/// features/targets at the chunk boundary, applying a [`NanPolicy`].
/// Under `Skip` the emitted stream is renumbered to stay contiguous and
/// `len_hint` becomes `None` (the surviving row count is unknowable
/// without a full pass, which routes center selection to reservoir
/// sampling); under `FailFast` the stream is passed through untouched
/// until the first bad row, which fails fatally with its global index.
pub struct SanitizeSource {
    inner: Box<dyn DataSource>,
    policy: NanPolicy,
    emitted: usize,
    skipped: usize,
}

impl SanitizeSource {
    pub fn new(inner: Box<dyn DataSource>, policy: NanPolicy) -> SanitizeSource {
        SanitizeSource {
            inner,
            policy,
            emitted: 0,
            skipped: 0,
        }
    }
}

fn row_is_finite(chunk: &Chunk, i: usize) -> bool {
    chunk.y[i].is_finite() && chunk.x.row_is_finite(i)
}

impl DataSource for SanitizeSource {
    fn d(&self) -> usize {
        self.inner.d()
    }

    fn len_hint(&self) -> Option<usize> {
        match self.policy {
            NanPolicy::FailFast => self.inner.len_hint(),
            NanPolicy::Skip => None,
        }
    }

    fn reset(&mut self) -> Result<()> {
        self.emitted = 0;
        self.skipped = 0;
        self.inner.reset()
    }

    fn next_chunk(&mut self) -> Result<Option<Chunk>> {
        loop {
            let chunk = match self.inner.next_chunk()? {
                Some(c) => c,
                None => return Ok(None),
            };
            let rows = chunk.rows();
            let bad: Vec<usize> = (0..rows).filter(|&i| !row_is_finite(&chunk, i)).collect();
            if bad.is_empty() {
                let start = self.emitted;
                self.emitted += rows;
                return Ok(Some(Chunk { start, ..chunk }));
            }
            match self.policy {
                NanPolicy::FailFast => {
                    return Err(crate::util::fault::FaultError::fatal(format!(
                        "non-finite value in row {} of {} (rerun with --nan-policy skip \
                         to drop such rows)",
                        chunk.start + bad[0],
                        self.inner.name(),
                    )));
                }
                NanPolicy::Skip => {
                    self.skipped += bad.len();
                    let keep: Vec<usize> =
                        (0..rows).filter(|i| row_is_finite(&chunk, *i)).collect();
                    if keep.is_empty() {
                        continue; // whole chunk dropped; pull the next one
                    }
                    // select_rows preserves the chunk's storage format,
                    // so a sanitized f32 stream stays f32
                    let x = chunk.x.select_rows(&keep);
                    let mut y = Vec::with_capacity(keep.len());
                    let mut labels = chunk.labels.as_ref().map(|_| Vec::with_capacity(keep.len()));
                    for &i in &keep {
                        y.push(chunk.y[i]);
                        if let (Some(out), Some(src)) = (labels.as_mut(), chunk.labels.as_ref()) {
                            out.push(src[i]);
                        }
                    }
                    let start = self.emitted;
                    self.emitted += keep.len();
                    return Ok(Some(Chunk { start, x, y, labels }));
                }
            }
        }
    }

    fn chunk_rows(&self) -> usize {
        self.inner.chunk_rows()
    }

    fn n_classes(&self) -> usize {
        self.inner.n_classes()
    }

    fn name(&self) -> &str {
        self.inner.name()
    }

    fn skipped_rows(&self) -> usize {
        self.skipped
    }
}

/// Dtype adapter: re-emits every chunk of the wrapped source in a target
/// storage format, so `--dtype f32` works over any backend (text streams,
/// shards, in-memory) without each of them knowing about casting. Chunks
/// already in the target format pass through untouched; f64→f32 rounds
/// each feature once (the only lossy step of the mixed-precision path).
pub struct CastSource {
    inner: Box<dyn DataSource>,
    dtype: Dtype,
}

impl CastSource {
    pub fn new(inner: Box<dyn DataSource>, dtype: Dtype) -> CastSource {
        CastSource { inner, dtype }
    }
}

impl DataSource for CastSource {
    fn d(&self) -> usize {
        self.inner.d()
    }

    fn len_hint(&self) -> Option<usize> {
        self.inner.len_hint()
    }

    fn reset(&mut self) -> Result<()> {
        self.inner.reset()
    }

    fn next_chunk(&mut self) -> Result<Option<Chunk>> {
        Ok(self.inner.next_chunk()?.map(|mut c| {
            c.x = c.x.into_dtype(self.dtype);
            c
        }))
    }

    fn chunk_rows(&self) -> usize {
        self.inner.chunk_rows()
    }

    fn n_classes(&self) -> usize {
        self.inner.n_classes()
    }

    fn name(&self) -> &str {
        self.inner.name()
    }

    fn skipped_rows(&self) -> usize {
        self.inner.skipped_rows()
    }
}

impl ZScore {
    /// Fit per-feature mean/std in one streaming pass (Welford's update,
    /// numerically stable at any n) — the out-of-core counterpart of
    /// [`ZScore::fit`], which needs the full matrix resident. Population
    /// variance and the 1e-12 std floor match the in-memory fit.
    pub fn fit_source(source: &mut dyn DataSource) -> Result<ZScore> {
        source.reset()?;
        let d = source.d();
        let mut n = 0.0f64;
        let mut mean = vec![0.0f64; d];
        let mut m2 = vec![0.0f64; d];
        let mut row = vec![0.0f64; d];
        while let Some(chunk) = source.next_chunk()? {
            for i in 0..chunk.rows() {
                n += 1.0;
                chunk.x.row_f64_into(i, &mut row);
                for j in 0..d {
                    let delta = row[j] - mean[j];
                    mean[j] += delta / n;
                    m2[j] += delta * (row[j] - mean[j]);
                }
            }
        }
        anyhow::ensure!(n > 0.0, "cannot fit a z-score on an empty source");
        let std = m2.iter().map(|&v| (v / n).sqrt().max(1e-12)).collect();
        Ok(ZScore { mean, std })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::rng::Rng;

    fn toy(n: usize) -> Dataset {
        synth::smooth_regression(&mut Rng::new(5), n, 4, 0.05)
    }

    #[test]
    fn mem_source_roundtrips() {
        let data = toy(101);
        let mut src = MemSource::new(data.clone(), 17);
        assert_eq!(src.len_hint(), Some(101));
        assert_eq!(src.d(), 4);
        let back = collect(&mut src).unwrap();
        assert_eq!(back.x.data, data.x.data);
        assert_eq!(back.y, data.y);
        assert_eq!(back.n_classes, 0);
    }

    #[test]
    fn chunks_are_contiguous_and_budgeted() {
        let data = toy(100);
        let mut src = MemSource::new(data, 33);
        src.reset().unwrap();
        let mut seen = 0;
        let mut sizes = Vec::new();
        while let Some(c) = src.next_chunk().unwrap() {
            assert_eq!(c.start, seen);
            assert!(c.rows() <= 33);
            assert_eq!(c.x_bytes(), c.rows() * 4 * 8);
            seen += c.rows();
            sizes.push(c.rows());
        }
        assert_eq!(seen, 100);
        assert_eq!(sizes, vec![33, 33, 33, 1]);
    }

    #[test]
    fn reset_replays_the_stream() {
        let data = toy(50);
        let mut src = MemSource::new(data, 16);
        let a = collect(&mut src).unwrap();
        let b = collect(&mut src).unwrap();
        assert_eq!(a.x.data, b.x.data);
    }

    #[test]
    fn mem_source_preserves_labels() {
        let data = synth::blobs(&mut Rng::new(9), 60, 3, 4);
        let mut src = MemSource::new(data.clone(), 13);
        let back = collect(&mut src).unwrap();
        assert!(back.is_multiclass());
        assert_eq!(back.n_classes, 4);
        assert_eq!(back.labels, data.labels);
    }

    #[test]
    fn streaming_zscore_matches_in_memory() {
        let data = toy(400);
        let want = ZScore::fit(&data.x);
        let mut src = MemSource::new(data, 37);
        let got = ZScore::fit_source(&mut src).unwrap();
        for j in 0..4 {
            assert!((got.mean[j] - want.mean[j]).abs() < 1e-10, "mean {j}");
            assert!((got.std[j] - want.std[j]).abs() < 1e-10, "std {j}");
        }
    }

    #[test]
    fn zscore_source_normalizes_chunks() {
        let data = toy(200);
        let z = ZScore::fit(&data.x);
        let want = z.apply(&data.x);
        let mut src = ZScoreSource::new(Box::new(MemSource::new(data, 41)), z);
        let got = collect(&mut src).unwrap();
        assert_eq!(got.x.data, want.data);
    }

    fn poison(mut data: Dataset, rows: &[usize], hit_y: bool) -> Dataset {
        for &i in rows {
            if hit_y {
                data.y[i] = f64::NAN;
            } else {
                data.x.row_mut(i)[0] = f64::INFINITY;
            }
        }
        data
    }

    #[test]
    fn sanitize_skip_drops_and_renumbers() {
        let clean = toy(90);
        let dirty = poison(clean.clone(), &[3, 40, 41, 89], false);
        let mut src = SanitizeSource::new(Box::new(MemSource::new(dirty, 30)), NanPolicy::Skip);
        assert_eq!(src.len_hint(), None, "skip mode cannot promise a length");
        let got = collect(&mut src).unwrap();
        assert_eq!(got.y.len(), 86);
        assert_eq!(src.skipped_rows(), 4);
        // surviving rows keep their order and values
        let want_y: Vec<f64> = clean
            .y
            .iter()
            .enumerate()
            .filter(|(i, _)| ![3usize, 40, 41, 89].contains(i))
            .map(|(_, v)| *v)
            .collect();
        assert_eq!(got.y, want_y);
    }

    #[test]
    fn sanitize_fail_fast_names_the_row() {
        let dirty = poison(toy(50), &[23], true);
        let mut src =
            SanitizeSource::new(Box::new(MemSource::new(dirty, 20)), NanPolicy::FailFast);
        let err = collect(&mut src).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("row 23"), "{msg}");
        assert_eq!(
            crate::util::fault::classify(&err),
            crate::util::fault::ErrorClass::Fatal
        );
    }

    #[test]
    fn sanitize_passes_clean_data_through() {
        let data = toy(70);
        let mut src =
            SanitizeSource::new(Box::new(MemSource::new(data.clone(), 19)), NanPolicy::Skip);
        let got = collect(&mut src).unwrap();
        assert_eq!(got.x.data, data.x.data);
        assert_eq!(got.y, data.y);
        assert_eq!(src.skipped_rows(), 0);
    }

    #[test]
    fn sanitize_reset_clears_the_skip_counter() {
        let dirty = poison(toy(40), &[5, 6], false);
        let mut src = SanitizeSource::new(Box::new(MemSource::new(dirty, 10)), NanPolicy::Skip);
        collect(&mut src).unwrap();
        assert_eq!(src.skipped_rows(), 2);
        let again = collect(&mut src).unwrap(); // collect resets first
        assert_eq!(src.skipped_rows(), 2);
        assert_eq!(again.y.len(), 38);
    }

    #[test]
    fn sanitize_drops_fully_poisoned_chunks() {
        // chunk 1 (rows 10..20) is entirely bad: the stream must skip
        // it and stay contiguous
        let dirty = poison(toy(30), &(10..20).collect::<Vec<_>>(), false);
        let mut src = SanitizeSource::new(Box::new(MemSource::new(dirty, 10)), NanPolicy::Skip);
        src.reset().unwrap();
        let mut seen = 0;
        while let Some(c) = src.next_chunk().unwrap() {
            assert_eq!(c.start, seen);
            seen += c.rows();
        }
        assert_eq!(seen, 20);
        assert_eq!(src.skipped_rows(), 10);
    }

    #[test]
    fn f32_mem_source_halves_bytes_and_rounds_once() {
        let data = toy(100);
        let mut src = MemSource::with_dtype(data.clone(), 33, Dtype::F32);
        src.reset().unwrap();
        let mut widened: Vec<f64> = Vec::new();
        while let Some(c) = src.next_chunk().unwrap() {
            assert_eq!(c.dtype(), Dtype::F32);
            assert_eq!(c.x_bytes(), c.rows() * 4 * 4, "4 bytes/element");
            c.x.extend_f64(&mut widened);
        }
        // every element is the f64 value rounded once to f32
        let want: Vec<f64> = data.x.data.iter().map(|&v| (v as f32) as f64).collect();
        assert_eq!(widened, want);
    }

    #[test]
    fn cast_source_converts_either_way() {
        let data = toy(60);
        // f64 -> f32
        let mut down = CastSource::new(Box::new(MemSource::new(data.clone(), 25)), Dtype::F32);
        down.reset().unwrap();
        let c = down.next_chunk().unwrap().unwrap();
        assert_eq!(c.dtype(), Dtype::F32);
        assert_eq!(c.x_bytes(), 25 * 4 * 4);
        // f32 -> f64 widens exactly back to the rounded values
        let mut up = CastSource::new(
            Box::new(MemSource::with_dtype(data.clone(), 25, Dtype::F32)),
            Dtype::F64,
        );
        let back = collect(&mut up).unwrap();
        let want: Vec<f64> = data.x.data.iter().map(|&v| (v as f32) as f64).collect();
        assert_eq!(back.x.data, want);
        // identity cast passes chunks through untouched
        let mut same = CastSource::new(Box::new(MemSource::new(data.clone(), 25)), Dtype::F64);
        let same_back = collect(&mut same).unwrap();
        assert_eq!(same_back.x.data, data.x.data);
    }

    #[test]
    fn zscore_source_normalizes_f32_chunks_within_rounding() {
        let data = toy(120);
        let z = ZScore::fit(&data.x);
        let want = z.apply(&data.x);
        let (mean, std) = (z.mean.clone(), z.std.clone());
        let mut src = ZScoreSource::new(
            Box::new(MemSource::with_dtype(data, 31, Dtype::F32)),
            z,
        );
        src.reset().unwrap();
        let mut seen = 0;
        while let Some(c) = src.next_chunk().unwrap() {
            assert_eq!(c.dtype(), Dtype::F32, "zscore keeps the storage format");
            for i in 0..c.rows() {
                for j in 0..4 {
                    let got = c.x.element(i, j);
                    let w = want[(seen + i, j)];
                    // storage rounding propagated through the affine map
                    // (eps32·|x|/std) plus the rounding back to f32 storage
                    // (eps32·|w|): |Δ| ≤ eps32·(|mean|/std + 2|w|)
                    let eps32 = f32::EPSILON as f64;
                    let tol = eps32 * (mean[j].abs() / std[j] + 2.0 * w.abs()) + 1e-9;
                    assert!((got - w).abs() < tol, "({i},{j}): {got} vs {w}");
                }
            }
            seen += c.rows();
        }
        assert_eq!(seen, 120);
    }

    #[test]
    fn sanitize_skip_preserves_f32_dtype() {
        let dirty = poison(toy(50), &[7, 8], false);
        let mut src = SanitizeSource::new(
            Box::new(MemSource::with_dtype(dirty, 20, Dtype::F32)),
            NanPolicy::Skip,
        );
        src.reset().unwrap();
        let mut seen = 0;
        while let Some(c) = src.next_chunk().unwrap() {
            assert_eq!(c.dtype(), Dtype::F32);
            assert_eq!(c.start, seen);
            seen += c.rows();
        }
        assert_eq!(seen, 48);
        assert_eq!(src.skipped_rows(), 2);
    }

    #[test]
    fn streaming_zscore_fit_handles_f32_chunks() {
        // stats over an f32 stream = stats of the rounded values
        let data = toy(200);
        let mut rounded = data.clone();
        for v in &mut rounded.x.data {
            *v = (*v as f32) as f64;
        }
        let want = ZScore::fit(&rounded.x);
        let mut src = MemSource::with_dtype(data, 37, Dtype::F32);
        let got = ZScore::fit_source(&mut src).unwrap();
        for j in 0..4 {
            assert!((got.mean[j] - want.mean[j]).abs() < 1e-10, "mean {j}");
            assert!((got.std[j] - want.std[j]).abs() < 1e-10, "std {j}");
        }
    }

    #[test]
    fn budget_helper_is_dtype_aware() {
        // f32 fits exactly twice the rows of f64 in the same budget
        assert_eq!(rows_for_budget_dtype(8 * 10 * 64, 10, Dtype::F32), 128);
        assert_eq!(rows_for_budget_dtype(8 * 10 * 64, 10, Dtype::F64), 64);
        assert_eq!(
            rows_for_budget(8 * 10 * 64, 10),
            rows_for_budget_dtype(8 * 10 * 64, 10, Dtype::F64)
        );
        assert_eq!(rows_for_budget_dtype(0, 10, Dtype::F32), 1);
    }

    #[test]
    fn nan_policy_parses() {
        assert_eq!(NanPolicy::parse("fail").unwrap(), NanPolicy::FailFast);
        assert_eq!(NanPolicy::parse("skip").unwrap(), NanPolicy::Skip);
        assert!(NanPolicy::parse("lol").is_err());
    }

    #[test]
    fn budget_helper_floors_at_one_row() {
        assert_eq!(rows_for_budget(0, 10), 1);
        assert_eq!(rows_for_budget(8 * 10 * 64, 10), 64);
        assert_eq!(rows_for_budget(1 << 20, 0), 1 << 20 >> 3);
    }
}

//! Chunked data access — the out-of-core pipeline's core abstraction.
//!
//! A [`DataSource`] yields the dataset as a sequence of contiguous row
//! blocks ([`Chunk`]s), so the n-dependent passes (center selection,
//! normalization statistics, the CG matvec sweeps, bulk prediction) can
//! run with only O(chunk) feature rows resident instead of the full
//! `n × d` matrix. Three backends implement it:
//!
//! - [`MemSource`] wraps an in-memory [`Dataset`] (the default path, and
//!   the oracle the streaming paths are property-tested against),
//! - [`crate::data::shard::ShardSource`] reads the chunked binary shard
//!   format with positioned reads (written by `falkon convert`),
//! - [`crate::data::stream_text::LibsvmSource`] /
//!   [`crate::data::stream_text::CsvSource`] parse text formats lazily,
//!   one chunk at a time.
//!
//! [`ZScoreSource`] wraps any source and applies a z-score transform to
//! every chunk on the fly; [`ZScore::fit_source`] computes the per-feature
//! mean/variance in one streaming pass (Welford), so normalization never
//! materializes the dataset either.
//!
//! Sources are rewindable ([`DataSource::reset`]): one FALKON fit sweeps
//! the stream once per CG iteration plus twice during setup, and the
//! streaming [`crate::runtime::MatvecPlan`] resets the source at the top
//! of every apply.

use super::dataset::{Dataset, ZScore};
use crate::linalg::mat::Mat;
use anyhow::Result;

/// Default rows per chunk (8192 rows × d features × 8 bytes resident).
pub const DEFAULT_CHUNK_ROWS: usize = 8192;

/// Rows that fit a byte budget at feature dimension `d` (at least 1).
pub fn rows_for_budget(budget_bytes: usize, d: usize) -> usize {
    (budget_bytes / (8 * d.max(1))).max(1)
}

/// One resident row block of a streamed dataset. `start` is the global
/// index of the first row; consecutive chunks of a sweep are contiguous
/// (`next.start == prev.start + prev.x.rows`).
#[derive(Debug, Clone)]
pub struct Chunk {
    /// global index of row 0 of this chunk
    pub start: usize,
    /// `rows × d` features
    pub x: Mat,
    /// regression target / ±1 label / class index per row
    pub y: Vec<f64>,
    /// class indices (multiclass sources only)
    pub labels: Option<Vec<usize>>,
}

impl Chunk {
    pub fn rows(&self) -> usize {
        self.x.rows
    }

    /// Resident feature bytes of this chunk (the out-of-core memory unit).
    pub fn x_bytes(&self) -> usize {
        self.x.data.len() * std::mem::size_of::<f64>()
    }
}

/// A rewindable stream of dataset chunks. Implementations are `Send` so
/// a streaming matvec plan stays movable across threads like the
/// in-memory plan.
pub trait DataSource: Send {
    /// Feature dimension of every chunk.
    fn d(&self) -> usize;

    /// Exact total row count if known without a full data pass (all
    /// shipped backends know it; `None` routes center selection to
    /// reservoir sampling).
    fn len_hint(&self) -> Option<usize>;

    /// Rewind to the first chunk. Called before every sweep.
    fn reset(&mut self) -> Result<()>;

    /// The next row block, or `None` at end of stream.
    fn next_chunk(&mut self) -> Result<Option<Chunk>>;

    /// Configured chunk budget in rows (actual chunks may be smaller at
    /// stream tail or record boundaries).
    fn chunk_rows(&self) -> usize;

    /// Number of classes (0 = regression, 2 = binary, K = multiclass).
    fn n_classes(&self) -> usize {
        0
    }

    /// Dataset display name.
    fn name(&self) -> &str {
        "source"
    }
}

/// Materialize a source into an in-memory [`Dataset`] (loading small
/// shards, and the round-trip oracle of the streaming tests).
pub fn collect(source: &mut dyn DataSource) -> Result<Dataset> {
    source.reset()?;
    let d = source.d();
    let mut xdata: Vec<f64> = Vec::new();
    let mut y: Vec<f64> = Vec::new();
    let mut labels: Vec<usize> = Vec::new();
    let mut any_labels = false;
    while let Some(chunk) = source.next_chunk()? {
        anyhow::ensure!(chunk.start == y.len(), "source chunks must be contiguous");
        xdata.extend_from_slice(&chunk.x.data);
        y.extend_from_slice(&chunk.y);
        if let Some(l) = &chunk.labels {
            any_labels = true;
            labels.extend_from_slice(l);
        }
    }
    let n = y.len();
    let x = Mat::from_vec(n, d, xdata);
    if any_labels {
        anyhow::ensure!(labels.len() == n, "labels missing on some chunks");
        Ok(Dataset::new_multiclass(
            source.name(),
            x,
            labels,
            source.n_classes(),
        ))
    } else {
        let mut ds = Dataset::new_regression(source.name(), x, y);
        ds.n_classes = source.n_classes();
        Ok(ds)
    }
}

/// In-memory backend: chunked views over a [`Dataset`]. The chunks copy
/// their rows (the trait yields owned blocks), so prefer the plain
/// `Dataset` paths when everything fits — this backend exists as the
/// oracle and for mixing in-memory data into source-shaped APIs.
pub struct MemSource {
    data: Dataset,
    chunk_rows: usize,
    pos: usize,
}

impl MemSource {
    pub fn new(data: Dataset, chunk_rows: usize) -> MemSource {
        MemSource {
            data,
            chunk_rows: chunk_rows.max(1),
            pos: 0,
        }
    }

    /// Recover the wrapped dataset.
    pub fn into_inner(self) -> Dataset {
        self.data
    }
}

impl DataSource for MemSource {
    fn d(&self) -> usize {
        self.data.d()
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.data.n())
    }

    fn reset(&mut self) -> Result<()> {
        self.pos = 0;
        Ok(())
    }

    fn next_chunk(&mut self) -> Result<Option<Chunk>> {
        let n = self.data.n();
        if self.pos >= n {
            return Ok(None);
        }
        let start = self.pos;
        let end = (start + self.chunk_rows).min(n);
        self.pos = end;
        Ok(Some(Chunk {
            start,
            x: self.data.x.slice_rows(start, end),
            y: self.data.y[start..end].to_vec(),
            labels: self.data.labels.as_ref().map(|l| l[start..end].to_vec()),
        }))
    }

    fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }

    fn n_classes(&self) -> usize {
        self.data.n_classes
    }

    fn name(&self) -> &str {
        &self.data.name
    }
}

/// Normalizing adapter: applies a fitted [`ZScore`] to every chunk's
/// features on the fly, so the streamed data is normalized without a
/// materialized copy (the out-of-core analogue of [`ZScore::apply`]).
pub struct ZScoreSource {
    inner: Box<dyn DataSource>,
    z: ZScore,
}

impl ZScoreSource {
    pub fn new(inner: Box<dyn DataSource>, z: ZScore) -> ZScoreSource {
        assert_eq!(z.mean.len(), inner.d(), "zscore dim != source dim");
        ZScoreSource { inner, z }
    }
}

impl DataSource for ZScoreSource {
    fn d(&self) -> usize {
        self.inner.d()
    }

    fn len_hint(&self) -> Option<usize> {
        self.inner.len_hint()
    }

    fn reset(&mut self) -> Result<()> {
        self.inner.reset()
    }

    fn next_chunk(&mut self) -> Result<Option<Chunk>> {
        let mut chunk = match self.inner.next_chunk()? {
            Some(c) => c,
            None => return Ok(None),
        };
        self.z.apply_mut(&mut chunk.x);
        Ok(Some(chunk))
    }

    fn chunk_rows(&self) -> usize {
        self.inner.chunk_rows()
    }

    fn n_classes(&self) -> usize {
        self.inner.n_classes()
    }

    fn name(&self) -> &str {
        self.inner.name()
    }
}

impl ZScore {
    /// Fit per-feature mean/std in one streaming pass (Welford's update,
    /// numerically stable at any n) — the out-of-core counterpart of
    /// [`ZScore::fit`], which needs the full matrix resident. Population
    /// variance and the 1e-12 std floor match the in-memory fit.
    pub fn fit_source(source: &mut dyn DataSource) -> Result<ZScore> {
        source.reset()?;
        let d = source.d();
        let mut n = 0.0f64;
        let mut mean = vec![0.0f64; d];
        let mut m2 = vec![0.0f64; d];
        while let Some(chunk) = source.next_chunk()? {
            for i in 0..chunk.x.rows {
                n += 1.0;
                let row = chunk.x.row(i);
                for j in 0..d {
                    let delta = row[j] - mean[j];
                    mean[j] += delta / n;
                    m2[j] += delta * (row[j] - mean[j]);
                }
            }
        }
        anyhow::ensure!(n > 0.0, "cannot fit a z-score on an empty source");
        let std = m2.iter().map(|&v| (v / n).sqrt().max(1e-12)).collect();
        Ok(ZScore { mean, std })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::rng::Rng;

    fn toy(n: usize) -> Dataset {
        synth::smooth_regression(&mut Rng::new(5), n, 4, 0.05)
    }

    #[test]
    fn mem_source_roundtrips() {
        let data = toy(101);
        let mut src = MemSource::new(data.clone(), 17);
        assert_eq!(src.len_hint(), Some(101));
        assert_eq!(src.d(), 4);
        let back = collect(&mut src).unwrap();
        assert_eq!(back.x.data, data.x.data);
        assert_eq!(back.y, data.y);
        assert_eq!(back.n_classes, 0);
    }

    #[test]
    fn chunks_are_contiguous_and_budgeted() {
        let data = toy(100);
        let mut src = MemSource::new(data, 33);
        src.reset().unwrap();
        let mut seen = 0;
        let mut sizes = Vec::new();
        while let Some(c) = src.next_chunk().unwrap() {
            assert_eq!(c.start, seen);
            assert!(c.rows() <= 33);
            assert_eq!(c.x_bytes(), c.rows() * 4 * 8);
            seen += c.rows();
            sizes.push(c.rows());
        }
        assert_eq!(seen, 100);
        assert_eq!(sizes, vec![33, 33, 33, 1]);
    }

    #[test]
    fn reset_replays_the_stream() {
        let data = toy(50);
        let mut src = MemSource::new(data, 16);
        let a = collect(&mut src).unwrap();
        let b = collect(&mut src).unwrap();
        assert_eq!(a.x.data, b.x.data);
    }

    #[test]
    fn mem_source_preserves_labels() {
        let data = synth::blobs(&mut Rng::new(9), 60, 3, 4);
        let mut src = MemSource::new(data.clone(), 13);
        let back = collect(&mut src).unwrap();
        assert!(back.is_multiclass());
        assert_eq!(back.n_classes, 4);
        assert_eq!(back.labels, data.labels);
    }

    #[test]
    fn streaming_zscore_matches_in_memory() {
        let data = toy(400);
        let want = ZScore::fit(&data.x);
        let mut src = MemSource::new(data, 37);
        let got = ZScore::fit_source(&mut src).unwrap();
        for j in 0..4 {
            assert!((got.mean[j] - want.mean[j]).abs() < 1e-10, "mean {j}");
            assert!((got.std[j] - want.std[j]).abs() < 1e-10, "std {j}");
        }
    }

    #[test]
    fn zscore_source_normalizes_chunks() {
        let data = toy(200);
        let z = ZScore::fit(&data.x);
        let want = z.apply(&data.x);
        let mut src = ZScoreSource::new(Box::new(MemSource::new(data, 41)), z);
        let got = collect(&mut src).unwrap();
        assert_eq!(got.x.data, want.data);
    }

    #[test]
    fn budget_helper_floors_at_one_row() {
        assert_eq!(rows_for_budget(0, 10), 1);
        assert_eq!(rows_for_budget(8 * 10 * 64, 10), 64);
        assert_eq!(rows_for_budget(1 << 20, 0), 1 << 20 >> 3);
    }
}

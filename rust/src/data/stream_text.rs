//! Lazily-parsed text backends for the out-of-core pipeline: libsvm and
//! CSV files served chunk by chunk through [`DataSource`], so a file
//! larger than RAM streams through fit/predict with O(chunk) resident
//! features.
//!
//! `open` runs one cheap validation scan (line-by-line, O(1) memory) that
//! counts rows and infers the feature dimension, so `len_hint` is exact
//! and malformed lines fail at open time rather than mid-fit. Each
//! [`DataSource::reset`] reopens the file; parsing shares the exact
//! line-level grammar of the eager loaders (`data::libsvm::read`,
//! `data::csv::read`), which remain the round-trip oracles in the tests.

use super::source::{Chunk, DataSource};
use crate::linalg::mat::Mat;
use crate::linalg::mat32::{Dtype, XBlock};
use anyhow::{Context, Result};
use std::fs::File;
use std::io::{BufRead, BufReader};

/// Streaming libsvm reader (`<label> <index>:<value> ...`, 1-based
/// indices, `#` comments). Out-of-order and gapped indices are fine —
/// each row scatters into a dense `d`-vector.
pub struct LibsvmSource {
    path: String,
    name: String,
    d: usize,
    n: usize,
    chunk_rows: usize,
    dtype: Dtype,
    reader: Option<BufReader<File>>,
    lineno: usize,
    row: usize,
}

impl LibsvmSource {
    /// Emit chunks in the given storage format (parsing stays f64; the
    /// `F32` arm rounds each chunk once at emission).
    pub fn with_dtype(mut self, dtype: Dtype) -> LibsvmSource {
        self.dtype = dtype;
        self
    }

    /// Open + validation scan. `dim = Some(d)` pins the feature count
    /// (indices beyond it error); `None` infers it as the max index seen.
    pub fn open(path: &str, dim: Option<usize>, chunk_rows: usize) -> Result<LibsvmSource> {
        let f = File::open(path).with_context(|| format!("opening libsvm file {path}"))?;
        let mut r = BufReader::new(f);
        let mut line = String::new();
        let mut lineno = 0usize;
        let mut n = 0usize;
        let mut max_idx = 0usize;
        loop {
            line.clear();
            if r.read_line(&mut line)
                .with_context(|| format!("reading {path}"))?
                == 0
            {
                break;
            }
            lineno += 1;
            if let Some((_, feats)) = super::libsvm::parse_line(&line, lineno)? {
                n += 1;
                for &(j, _) in &feats {
                    max_idx = max_idx.max(j + 1);
                }
            }
        }
        let d = match dim {
            Some(d) => {
                anyhow::ensure!(
                    max_idx <= d,
                    "feature index {max_idx} exceeds pinned dim {d} in {path}"
                );
                d
            }
            None => max_idx,
        };
        anyhow::ensure!(n > 0, "{path} has no data rows");
        anyhow::ensure!(d > 0, "{path} has no features");
        Ok(LibsvmSource {
            path: path.to_string(),
            name: path.to_string(),
            d,
            n,
            chunk_rows: chunk_rows.max(1),
            dtype: Dtype::F64,
            reader: None,
            lineno: 0,
            row: 0,
        })
    }
}

impl DataSource for LibsvmSource {
    fn d(&self) -> usize {
        self.d
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.n)
    }

    fn reset(&mut self) -> Result<()> {
        let f = File::open(&self.path)
            .with_context(|| format!("reopening libsvm file {}", self.path))?;
        self.reader = Some(BufReader::new(f));
        self.lineno = 0;
        self.row = 0;
        Ok(())
    }

    fn next_chunk(&mut self) -> Result<Option<Chunk>> {
        if self.reader.is_none() {
            self.reset()?;
        }
        let r = self.reader.as_mut().unwrap();
        let mut xdata: Vec<f64> = Vec::with_capacity(self.chunk_rows.min(self.n) * self.d);
        let mut y: Vec<f64> = Vec::with_capacity(self.chunk_rows.min(self.n));
        let mut line = String::new();
        while y.len() < self.chunk_rows {
            line.clear();
            if r.read_line(&mut line)? == 0 {
                break;
            }
            self.lineno += 1;
            if let Some((label, feats)) = super::libsvm::parse_line(&line, self.lineno)? {
                let base = xdata.len();
                xdata.resize(base + self.d, 0.0);
                for &(j, v) in &feats {
                    anyhow::ensure!(
                        j < self.d,
                        "feature index {} exceeds dim {} on line {} of {} \
                         (file changed since open?)",
                        j + 1,
                        self.d,
                        self.lineno,
                        self.path
                    );
                    xdata[base + j] = v;
                }
                y.push(label);
            }
        }
        if y.is_empty() {
            return Ok(None);
        }
        let rows = y.len();
        let start = self.row;
        self.row += rows;
        Ok(Some(Chunk {
            start,
            x: XBlock::from_mat_dtype(Mat::from_vec(rows, self.d, xdata), self.dtype),
            y,
            labels: None,
        }))
    }

    fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }

    fn name(&self) -> &str {
        &self.name
    }
}

/// Streaming numeric CSV reader (label in the first column, like the
/// eager `data::csv` loader).
pub struct CsvSource {
    path: String,
    name: String,
    has_header: bool,
    d: usize,
    n: usize,
    chunk_rows: usize,
    dtype: Dtype,
    reader: Option<BufReader<File>>,
    lineno: usize,
    row: usize,
}

impl CsvSource {
    /// Emit chunks in the given storage format (parsing stays f64; the
    /// `F32` arm rounds each chunk once at emission).
    pub fn with_dtype(mut self, dtype: Dtype) -> CsvSource {
        self.dtype = dtype;
        self
    }

    /// Open + validation scan (counts rows, checks a consistent width).
    pub fn open(path: &str, has_header: bool, chunk_rows: usize) -> Result<CsvSource> {
        let f = File::open(path).with_context(|| format!("opening csv file {path}"))?;
        let mut r = BufReader::new(f);
        let mut line = String::new();
        let mut lineno = 0usize;
        let mut n = 0usize;
        let mut width: Option<usize> = None;
        loop {
            line.clear();
            if r.read_line(&mut line)
                .with_context(|| format!("reading {path}"))?
                == 0
            {
                break;
            }
            lineno += 1;
            if has_header && lineno == 1 {
                continue;
            }
            if let Some((_, feats)) = super::csv::parse_line(&line, lineno)? {
                let w = feats.len() + 1;
                match width {
                    None => width = Some(w),
                    Some(prev) => anyhow::ensure!(
                        prev == w,
                        "ragged row on line {lineno} of {path}: {w} cols, expected {prev}"
                    ),
                }
                n += 1;
            }
        }
        anyhow::ensure!(n > 0, "{path} has no data rows");
        let d = width.unwrap() - 1;
        Ok(CsvSource {
            path: path.to_string(),
            name: path.to_string(),
            has_header,
            d,
            n,
            chunk_rows: chunk_rows.max(1),
            dtype: Dtype::F64,
            reader: None,
            lineno: 0,
            row: 0,
        })
    }
}

impl DataSource for CsvSource {
    fn d(&self) -> usize {
        self.d
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.n)
    }

    fn reset(&mut self) -> Result<()> {
        let f = File::open(&self.path)
            .with_context(|| format!("reopening csv file {}", self.path))?;
        self.reader = Some(BufReader::new(f));
        self.lineno = 0;
        self.row = 0;
        Ok(())
    }

    fn next_chunk(&mut self) -> Result<Option<Chunk>> {
        if self.reader.is_none() {
            self.reset()?;
        }
        let r = self.reader.as_mut().unwrap();
        let mut xdata: Vec<f64> = Vec::with_capacity(self.chunk_rows.min(self.n) * self.d);
        let mut y: Vec<f64> = Vec::with_capacity(self.chunk_rows.min(self.n));
        let mut line = String::new();
        while y.len() < self.chunk_rows {
            line.clear();
            if r.read_line(&mut line)? == 0 {
                break;
            }
            self.lineno += 1;
            if self.has_header && self.lineno == 1 {
                continue;
            }
            if let Some((label, feats)) = super::csv::parse_line(&line, self.lineno)? {
                anyhow::ensure!(
                    feats.len() == self.d,
                    "ragged row on line {} of {}: {} features, expected {} \
                     (file changed since open?)",
                    self.lineno,
                    self.path,
                    feats.len(),
                    self.d
                );
                xdata.extend_from_slice(&feats);
                y.push(label);
            }
        }
        if y.is_empty() {
            return Ok(None);
        }
        let rows = y.len();
        let start = self.row;
        self.row += rows;
        Ok(Some(Chunk {
            start,
            x: XBlock::from_mat_dtype(Mat::from_vec(rows, self.d, xdata), self.dtype),
            y,
            labels: None,
        }))
    }

    fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }

    fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::source::collect;
    use std::io::Cursor;

    fn tmp(tag: &str, contents: &str) -> String {
        let p = std::env::temp_dir()
            .join(format!("falkon_stream_{tag}_{}.txt", std::process::id()))
            .to_string_lossy()
            .into_owned();
        std::fs::write(&p, contents).unwrap();
        p
    }

    #[test]
    fn libsvm_stream_matches_eager() {
        // blank lines, comments, out-of-order indices, no trailing newline
        let src = "# header comment\n1 3:3.0 1:1.0\n\n-1 2:2.5 # trailing\n2 1:0.5 4:4.0";
        let path = tmp("lsvm", src);
        let (want_x, want_y) = crate::data::libsvm::read(Cursor::new(src), None).unwrap();
        let mut s = LibsvmSource::open(&path, None, 2).unwrap();
        assert_eq!(s.len_hint(), Some(3));
        assert_eq!(s.d(), 4);
        let got = collect(&mut s).unwrap();
        assert_eq!(got.x.data, want_x.data);
        assert_eq!(got.y, want_y);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn libsvm_out_of_order_indices_scatter() {
        let path = tmp("order", "1 5:5.0 2:2.0 1:1.0\n");
        let mut s = LibsvmSource::open(&path, None, 8).unwrap();
        let got = collect(&mut s).unwrap();
        assert_eq!(got.x.data, vec![1.0, 2.0, 0.0, 0.0, 5.0]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn libsvm_pinned_dim_and_errors() {
        let path = tmp("pin", "1 2:2.0\n");
        let s = LibsvmSource::open(&path, Some(6), 8).unwrap();
        assert_eq!(s.d(), 6);
        assert!(LibsvmSource::open(&path, Some(1), 8).is_err());
        let _ = std::fs::remove_file(&path);
        let bad = tmp("badl", "1 nocolon\n");
        assert!(LibsvmSource::open(&bad, None, 8).is_err());
        let _ = std::fs::remove_file(&bad);
    }

    #[test]
    fn csv_stream_matches_eager() {
        // header, blank line, missing trailing newline
        let src = "label,f1,f2\n1.0,2.0,3.0\n\n-1.0,4.5,5.5";
        let path = tmp("csv", src);
        let (want_y, want_x) = crate::data::csv::read(Cursor::new(src), true).unwrap();
        let mut s = CsvSource::open(&path, true, 1).unwrap();
        assert_eq!(s.len_hint(), Some(2));
        assert_eq!(s.d(), 2);
        let got = collect(&mut s).unwrap();
        assert_eq!(got.x.data, want_x.data);
        assert_eq!(got.y, want_y);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn csv_rejects_ragged_and_empty() {
        let ragged = tmp("rag", "1,2\n1,2,3\n");
        assert!(CsvSource::open(&ragged, false, 4).is_err());
        let _ = std::fs::remove_file(&ragged);
        let empty = tmp("emp", "\n\n");
        assert!(CsvSource::open(&empty, false, 4).is_err());
        let _ = std::fs::remove_file(&empty);
    }

    #[test]
    fn f32_stream_rounds_once_and_halves_bytes() {
        let src = "1.0,0.1,3.0\n-1.0,4.5,5.5\n2.0,0.2,0.3\n";
        let path = tmp("csv32", src);
        let mut s = CsvSource::open(&path, false, 2).unwrap().with_dtype(Dtype::F32);
        s.reset().unwrap();
        let c = s.next_chunk().unwrap().unwrap();
        assert_eq!(c.dtype(), Dtype::F32);
        assert_eq!(c.x_bytes(), 2 * 2 * 4, "f32 chunk is 4 bytes/element");
        // values are the f64 parse rounded once to f32
        assert_eq!(c.x.element(0, 0), 0.1f32 as f64);
        assert_eq!(c.x.element(1, 1), 5.5);
        // y stays f64 exactly
        assert_eq!(c.y, vec![1.0, -1.0]);
        // libsvm twin
        let lpath = tmp("lsvm32", "1 1:0.1 2:2.0\n");
        let mut ls = LibsvmSource::open(&lpath, None, 4)
            .unwrap()
            .with_dtype(Dtype::F32);
        ls.reset().unwrap();
        let lc = ls.next_chunk().unwrap().unwrap();
        assert_eq!(lc.dtype(), Dtype::F32);
        assert_eq!(lc.x.element(0, 0), 0.1f32 as f64);
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&lpath);
    }

    #[test]
    fn reset_replays_and_chunks_are_contiguous() {
        let mut body = String::new();
        for i in 0..23 {
            body.push_str(&format!("{i},1.0,{i}.5\n"));
        }
        let path = tmp("replay", &body);
        let mut s = CsvSource::open(&path, false, 7).unwrap();
        let a = collect(&mut s).unwrap();
        let b = collect(&mut s).unwrap();
        assert_eq!(a.x.data, b.x.data);
        assert_eq!(a.n(), 23);
        s.reset().unwrap();
        let mut seen = 0;
        while let Some(c) = s.next_chunk().unwrap() {
            assert_eq!(c.start, seen);
            assert!(c.rows() <= 7);
            seen += c.rows();
        }
        assert_eq!(seen, 23);
        let _ = std::fs::remove_file(&path);
    }
}

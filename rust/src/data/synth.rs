//! Synthetic workload generators — laptop-scale analogues of the paper's
//! six evaluation datasets plus generic designs for the theory ablations.
//!
//! The real MillionSongs/YELP/TIMIT/SUSY/HIGGS/IMAGENET data are not
//! available in this environment (see DESIGN.md §3); each generator below
//! matches its dataset in task type, feature dimensionality, target/label
//! structure and noise character, so every code path the paper exercises
//! (kernel choice, λ/σ regime, one-vs-all multiclass, AUC evaluation) runs
//! unchanged. Real data can be swapped in through `data::libsvm`/`data::csv`.

use super::dataset::Dataset;
use crate::linalg::mat::Mat;
use crate::util::rng::Rng;

fn normal_mat(rng: &mut Rng, n: usize, d: usize) -> Mat {
    Mat::from_vec(n, d, rng.normals(n * d))
}

/// Smooth random nonlinearity: a fixed mixture of `k` gaussian bumps in
/// feature space. Lives in the RKHS of a gaussian kernel with width ~`w`,
/// so targets built from it satisfy the paper's source condition (r=1/2).
struct BumpMix {
    centers: Mat,
    weights: Vec<f64>,
    width: f64,
}

impl BumpMix {
    fn new(rng: &mut Rng, k: usize, d: usize, width: f64) -> Self {
        BumpMix {
            centers: normal_mat(rng, k, d),
            weights: rng.normals(k),
            width,
        }
    }

    fn eval(&self, x: &[f64]) -> f64 {
        let mut acc = 0.0;
        for j in 0..self.centers.rows {
            let c = self.centers.row(j);
            let mut sq = 0.0;
            for i in 0..x.len() {
                let d = x[i] - c[i];
                sq += d * d;
            }
            acc += self.weights[j] * (-sq / (2.0 * self.width * self.width)).exp();
        }
        acc
    }
}

/// MillionSongs analogue (Table 2): regression, d = 90, audio-feature-like
/// inputs (correlated gaussians), smooth nonlinear target + noise. The
/// paper predicts release year; targets here are zero-mean continuous.
pub fn songs(rng: &mut Rng, n: usize) -> Dataset {
    let d = 90;
    let x = normal_mat(rng, n, d);
    let f = BumpMix::new(rng, 40, d, 6.0);
    // year-like targets (mean ~1980, learnable spread ~30, noise ~8) so
    // MSE and the paper's "relative error" metric land on MillionSongs'
    // scale (MSE ~80, rel.err ~5e-3)
    let y: Vec<f64> = (0..n)
        .map(|i| 1980.0 + 30.0 * f.eval(x.row(i)) + 8.0 * rng.normal())
        .collect();
    Dataset::new_regression("songs", x, y)
}

/// YELP analogue (Table 2): linear-kernel regression over high-dimensional
/// sparse binary n-gram-presence features; target = sparse linear model of
/// the active features (review stars), plus noise.
pub fn yelp(rng: &mut Rng, n: usize) -> Dataset {
    let d = 512;
    let active = 24; // ~5% feature density, like 3-gram presence vectors
    let w: Vec<f64> = rng.normals(d).iter().map(|v| v * 0.4).collect();
    let mut x = Mat::zeros(n, d);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let idx = rng.choose(d, active);
        let row = x.row_mut(i);
        let mut s = 0.0;
        for &j in &idx {
            row[j] = 1.0;
            s += w[j];
        }
        y[i] = s + 0.2 * rng.normal();
    }
    Dataset::new_regression("yelp", x, y)
}

/// TIMIT analogue (Table 2): multiclass classification, d = 440 acoustic-
/// feature-like inputs, 8 phone-group classes with heavy overlap (paper's
/// c-err is ~32%, i.e. the classes are far from separable).
pub fn timit(rng: &mut Rng, n: usize) -> Dataset {
    let d = 440;
    let k = 8;
    let centers = normal_mat(rng, k, d);
    let spread = 12.0; // heavy overlap: tuned for paper-like ~30% c-err
    let mut x = Mat::zeros(n, d);
    let mut labels = vec![0usize; n];
    for i in 0..n {
        let c = rng.below(k);
        labels[i] = c;
        let row = x.row_mut(i);
        let cr = centers.row(c);
        for j in 0..d {
            row[j] = cr[j] + spread * rng.normal();
        }
    }
    Dataset::new_multiclass("timit", x, labels, k)
}

/// SUSY analogue (Table 3): binary classification, d = 18 kinematic
/// features; signal/background differ by a shifted nonlinear manifold with
/// strong overlap (paper c-err 19.6%, AUC 0.877).
pub fn susy(rng: &mut Rng, n: usize) -> Dataset {
    let d = 18;
    let f = BumpMix::new(rng, 20, d, 3.0);
    let mut x = normal_mat(rng, n, d);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let pos = rng.f64() < 0.5;
        y[i] = if pos { 1.0 } else { -1.0 };
        if pos {
            // signal events shift along a nonlinear direction
            let row = x.row_mut(i);
            let shift = 0.9 + 0.3 * f.eval(row);
            row[0] += 1.25 * shift;
            row[1] += 0.6 * shift;
            for v in row.iter_mut().skip(2).take(4) {
                *v += 0.35 * shift;
            }
        }
    }
    Dataset::new_binary("susy", x, y)
}

/// HIGGS analogue (Table 3): binary, d = 28, weaker separation than SUSY
/// (paper AUC 0.833) — smaller shift, more features involved.
pub fn higgs(rng: &mut Rng, n: usize) -> Dataset {
    let d = 28;
    let mut x = normal_mat(rng, n, d);
    let f = BumpMix::new(rng, 30, d, 4.0);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let pos = rng.f64() < 0.5;
        y[i] = if pos { 1.0 } else { -1.0 };
        if pos {
            let row = x.row_mut(i);
            let s = 0.8 + 0.4 * f.eval(row).tanh();
            for v in row.iter_mut().take(10) {
                *v += 0.75 * s;
            }
        }
    }
    Dataset::new_binary("higgs", x, y)
}

/// IMAGENET analogue (Table 3): 16-class classification over d = 512
/// pretrained-CNN-feature-like inputs — classes are compact clusters with
/// moderate overlap (paper top-1 c-err 20.7% on Inception-V4 features).
pub fn imagenet(rng: &mut Rng, n: usize) -> Dataset {
    let d = 512;
    let k = 16;
    let centers = normal_mat(rng, k, d);
    let spread = 7.0; // tuned for paper-like ~20% top-1 error
    let mut x = Mat::zeros(n, d);
    let mut labels = vec![0usize; n];
    for i in 0..n {
        let c = rng.below(k);
        labels[i] = c;
        let row = x.row_mut(i);
        let cr = centers.row(c);
        for j in 0..d {
            row[j] = cr[j] + spread * rng.normal();
        }
    }
    Dataset::new_multiclass("imagenet", x, labels, k)
}

/// Generic smooth regression used by the scaling bench (Table 1) and the
/// statistical-rate ablation (Thm. 3): target in the gaussian RKHS
/// (source condition r = 1/2) with additive noise.
pub fn smooth_regression(rng: &mut Rng, n: usize, d: usize, noise: f64) -> Dataset {
    let x = normal_mat(rng, n, d);
    let f = BumpMix::new(rng, 25, d, 2.0);
    let y: Vec<f64> = (0..n)
        .map(|i| f.eval(x.row(i)) + noise * rng.normal())
        .collect();
    Dataset::new_regression("smooth", x, y)
}

/// Low-effective-dimension design for the leverage-scores ablation
/// (Thm. 4/5): inputs concentrate near a `d_eff`-dimensional subspace with
/// a small cloud of off-subspace points, so leverage scores are strongly
/// non-uniform and leverage-score sampling needs fewer centers.
pub fn low_effective_dim(rng: &mut Rng, n: usize, d: usize, d_eff: usize) -> Dataset {
    assert!(d_eff <= d);
    let mut x = Mat::zeros(n, d);
    let f = BumpMix::new(rng, 15, d, 2.0);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let row = x.row_mut(i);
        // bulk directions with fast-decaying scale; 2% outliers at full scale
        let outlier = rng.f64() < 0.02;
        for j in 0..d {
            let scale = if outlier {
                1.0
            } else if j < d_eff {
                1.0 / (1.0 + j as f64)
            } else {
                0.01
            };
            row[j] = scale * rng.normal();
        }
        y[i] = f.eval(row) + 0.05 * rng.normal();
    }
    Dataset::new_regression("low_eff_dim", x, y)
}

/// How many distant sub-clusters [`rare_cluster`] scatters its rare mass
/// over. Each sub-cluster needs its own Nyström center, so uniform
/// sampling must land a draw in every one while leverage-score sampling
/// is steered there by the scores.
pub const RARE_SUBCLUSTERS: usize = 5;

/// Imbalanced design for the leverage-scores ablation: a dominant blob
/// plus a small (`rare_frac`) slice of mass scattered over
/// [`RARE_SUBCLUSTERS`] distant sub-clusters sharing a target level.
/// Every rare point is shifted by +8 on coordinate 0 (so the rare mass
/// is linearly separable from the bulk) and by +8 on one of
/// `RARE_SUBCLUSTERS` additional coordinates picking its sub-cluster.
/// The rare points carry high ridge leverage scores, so leverage-score
/// sampling reliably covers all sub-clusters while uniform sampling
/// misses some at small M — the regime where Thm. 4-5 predict a
/// separation.
pub fn rare_cluster(rng: &mut Rng, n: usize, d: usize, rare_frac: f64) -> Dataset {
    assert!(d >= 2, "rare_cluster needs d >= 2");
    let mut x = Mat::zeros(n, d);
    let mut y = vec![0.0; n];
    let f = BumpMix::new(rng, 10, d, 2.0);
    for i in 0..n {
        let rare = rng.f64() < rare_frac;
        let sub = if rare {
            Some(1 + rng.below(RARE_SUBCLUSTERS) % (d - 1))
        } else {
            None
        };
        let row = x.row_mut(i);
        for (j, v) in row.iter_mut().enumerate() {
            let shift = if rare && (j == 0 || Some(j) == sub) {
                8.0
            } else {
                0.0
            };
            *v = rng.normal() + shift;
        }
        y[i] = if rare { 4.0 } else { f.eval(row) } + 0.05 * rng.normal();
    }
    Dataset::new_regression("rare_cluster", x, y)
}

/// Generic k-class gaussian-blob problem for the multiclass sweeps (the
/// paper's one-vs-all workloads range from 10 classes on MNIST-8M to 144
/// on TIMIT): well-separated cluster centers with mild within-class
/// spread, so any K is learnable at laptop-scale n and the bench's
/// batched-vs-looped comparison measures compute, not model difficulty.
pub fn blobs(rng: &mut Rng, n: usize, d: usize, k: usize) -> Dataset {
    assert!(k >= 2, "blobs needs at least two classes");
    let centers = normal_mat(rng, k, d);
    let mut x = Mat::zeros(n, d);
    let mut labels = vec![0usize; n];
    for i in 0..n {
        let c = rng.below(k);
        labels[i] = c;
        let row = x.row_mut(i);
        let cr = centers.row(c);
        for j in 0..d {
            row[j] = 3.0 * cr[j] + 0.8 * rng.normal();
        }
    }
    Dataset::new_multiclass("blobs", x, labels, k)
}

/// Look up a paper-dataset analogue by name (CLI/bench entry point).
pub fn by_name(name: &str, rng: &mut Rng, n: usize) -> Option<Dataset> {
    Some(match name {
        "songs" | "millionsongs" => songs(rng, n),
        "yelp" => yelp(rng, n),
        "timit" => timit(rng, n),
        "susy" => susy(rng, n),
        "higgs" => higgs(rng, n),
        "imagenet" => imagenet(rng, n),
        "smooth" => smooth_regression(rng, n, 10, 0.1),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_paper_dims() {
        let mut rng = Rng::new(1);
        assert_eq!(songs(&mut rng, 50).d(), 90);
        assert_eq!(yelp(&mut rng, 50).d(), 512);
        assert_eq!(timit(&mut rng, 50).d(), 440);
        assert_eq!(susy(&mut rng, 50).d(), 18);
        assert_eq!(higgs(&mut rng, 50).d(), 28);
        assert_eq!(imagenet(&mut rng, 50).d(), 512);
    }

    #[test]
    fn blobs_cover_all_classes() {
        let d = blobs(&mut Rng::new(7), 2000, 6, 12);
        assert_eq!(d.n_classes, 12);
        assert!(d.is_multiclass());
        let labels = d.labels.as_ref().unwrap();
        for k in 0..12 {
            assert!(labels.iter().any(|&l| l == k), "class {k} empty");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = susy(&mut Rng::new(9), 100);
        let b = susy(&mut Rng::new(9), 100);
        assert_eq!(a.x.data, b.x.data);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn binary_labels_balanced() {
        let d = susy(&mut Rng::new(2), 4000);
        let pos = d.y.iter().filter(|v| **v > 0.0).count();
        assert!((1700..2300).contains(&pos), "{pos}");
    }

    #[test]
    fn susy_classes_separated_but_overlapping() {
        // mean of feature 0 differs by roughly the planted shift
        let d = susy(&mut Rng::new(3), 8000);
        let (mut mp, mut mn, mut np_, mut nn) = (0.0, 0.0, 0, 0);
        for i in 0..d.n() {
            if d.y[i] > 0.0 {
                mp += d.x[(i, 0)];
                np_ += 1;
            } else {
                mn += d.x[(i, 0)];
                nn += 1;
            }
        }
        let gap = mp / np_ as f64 - mn / nn as f64;
        assert!(gap > 0.5 && gap < 2.0, "gap {gap}");
    }

    #[test]
    fn yelp_rows_are_sparse_binary() {
        let d = yelp(&mut Rng::new(4), 30);
        for i in 0..d.n() {
            let nz = d.x.row(i).iter().filter(|v| **v != 0.0).count();
            assert_eq!(nz, 24);
            assert!(d.x.row(i).iter().all(|v| *v == 0.0 || *v == 1.0));
        }
    }

    #[test]
    fn multiclass_label_ranges() {
        let d = timit(&mut Rng::new(5), 200);
        assert_eq!(d.n_classes, 8);
        assert!(d.labels.as_ref().unwrap().iter().all(|&l| l < 8));
        let d = imagenet(&mut Rng::new(5), 200);
        assert_eq!(d.n_classes, 16);
    }

    #[test]
    fn by_name_roundtrip() {
        let mut rng = Rng::new(6);
        for name in ["songs", "yelp", "timit", "susy", "higgs", "imagenet", "smooth"] {
            assert!(by_name(name, &mut rng, 20).is_some(), "{name}");
        }
        assert!(by_name("nope", &mut rng, 20).is_none());
    }

    #[test]
    fn rare_cluster_is_imbalanced() {
        let d = rare_cluster(&mut Rng::new(8), 5000, 6, 0.03);
        let rare = (0..d.n()).filter(|&i| d.x[(i, 0)] > 4.0).count();
        assert!((100..260).contains(&rare), "rare count {rare}");
        // the rare mass is scattered over all sub-clusters (coords 1..=5)
        let subs: std::collections::HashSet<usize> = (0..d.n())
            .filter(|&i| d.x[(i, 0)] > 4.0)
            .filter_map(|i| (1..6).find(|&j| d.x[(i, j)] > 4.0))
            .collect();
        assert_eq!(subs.len(), RARE_SUBCLUSTERS, "sub-clusters {subs:?}");
    }

    #[test]
    fn low_eff_dim_has_decaying_scales() {
        let d = low_effective_dim(&mut Rng::new(7), 2000, 20, 5);
        let var_of = |j: usize| {
            let col: Vec<f64> = (0..d.n()).map(|i| d.x[(i, j)]).collect();
            crate::linalg::vec_ops::variance(&col)
        };
        assert!(var_of(0) > 5.0 * var_of(10));
    }
}

//! Nyström center selection — Sect. A of the paper: uniform sampling and
//! approximate-leverage-score sampling with the Def. 2 reweighting matrix D.

use crate::data::source::DataSource;
use crate::linalg::mat::Mat;
use crate::linalg::mat32::XBlock;
use crate::runtime::Engine;
use crate::util::rng::{CategoricalSampler, Rng};
use anyhow::Result;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Center-selection strategy.
#[derive(Debug, Clone)]
pub enum Centers {
    /// Uniform subsampling of the training set (Thm. 3 regime).
    Uniform,
    /// Approximate leverage scores (Def. 1 / Thm. 4-5 regime): a uniform
    /// pilot sketch of `sketch` columns estimates the ridge leverage
    /// scores at level `lam`, then centers are drawn ∝ l̂_i(λ).
    ApproxLeverage { sketch: usize },
}

/// Selected centers plus the Def. 2 diagonal reweighting (None ⇔ identity).
#[derive(Debug, Clone)]
pub struct SelectedCenters {
    pub c: Mat,
    pub indices: Vec<usize>,
    /// D_jj = 1/sqrt(n p_j) for leverage-score sampling (Def. 2)
    pub d_weights: Option<Vec<f64>>,
    /// the estimated leverage scores (diagnostics / benches)
    pub scores: Option<Vec<f64>>,
}

impl Centers {
    pub fn select(
        &self,
        engine: &Engine,
        x: &Mat,
        kern: crate::kernels::Kernel,
        sigma: f64,
        lam: f64,
        m: usize,
        rng: &mut Rng,
    ) -> Result<SelectedCenters> {
        match self {
            Centers::Uniform => {
                let indices = rng.choose(x.rows, m.min(x.rows));
                Ok(SelectedCenters {
                    c: x.select_rows(&indices),
                    indices,
                    d_weights: None,
                    scores: None,
                })
            }
            Centers::ApproxLeverage { sketch } => {
                let scores =
                    super::lscores::approx_leverage_scores(engine, x, kern, sigma, lam, *sketch, rng)?;
                let (indices, d_weights) = sample_by_scores(&scores, m, x.rows, rng);
                Ok(SelectedCenters {
                    c: x.select_rows(&indices),
                    indices,
                    d_weights: Some(d_weights),
                    scores: Some(scores),
                })
            }
        }
    }

    /// Streamed [`Centers::select`] over a rewindable [`DataSource`] —
    /// the selection phase of `prepare_source`. Collects the targets
    /// into `y_out` during the first pass (they are O(n) coordinator
    /// state either way), and returns the same
    /// [`SelectedCenters`] contract as the in-memory path.
    ///
    /// * `Uniform`, known length: the **same** `rng.choose(n, m)` draw as
    ///   the in-memory path, gathered by [`CenterGather`] — bit-identical
    ///   centers at equal seed.
    /// * `Uniform`, unknown length: Algorithm-R [`Reservoir`].
    /// * `ApproxLeverage`, known length: the streamed sketch
    ///   ([`super::lscores::sketch_source`]), scores materialized in a
    ///   chunked pass (O(n) like the targets), then the same
    ///   [`sample_by_scores`] draw as in-memory — equal centers, weights
    ///   and rng stream position at equal seed — and one more gather pass
    ///   for the center rows.
    /// * `ApproxLeverage`, unknown length: chunk scores feed a
    ///   [`WeightedReservoir`], so centers are drawn ∝ l̂_i(λ) without
    ///   ever holding all n scores.
    ///
    /// Every pass runs under the engine's retry policy. The caller owns
    /// `source.reset()` ordering — this method always rewinds first.
    #[allow(clippy::too_many_arguments)]
    pub fn select_source(
        &self,
        engine: &Engine,
        source: &mut dyn DataSource,
        kern: crate::kernels::Kernel,
        sigma: f64,
        lam: f64,
        m: usize,
        rng: &mut Rng,
        y_out: &mut Vec<f64>,
    ) -> Result<SelectedCenters> {
        let retry = engine.opts().retry;
        let d = source.d();
        anyhow::ensure!(d > 0, "source has no features");
        match self {
            Centers::Uniform => {
                retry.run("center pass: reset", || source.reset())?;
                let (c, indices) = match source.len_hint() {
                    Some(n) => {
                        anyhow::ensure!(n > 0, "source is empty");
                        // same draw as Centers::Uniform on the in-memory path
                        let indices = rng.choose(n, m.min(n));
                        let mut gather = CenterGather::new(&indices, d);
                        let mut seen = 0usize;
                        while let Some(chunk) =
                            retry.run("centers: next_chunk", || source.next_chunk())?
                        {
                            anyhow::ensure!(
                                chunk.start == seen,
                                "source chunks must be contiguous"
                            );
                            seen += chunk.x.rows();
                            gather.offer_block(chunk.start, &chunk.x);
                            y_out.extend_from_slice(&chunk.y);
                        }
                        anyhow::ensure!(seen == n, "source yielded {seen} rows, len_hint said {n}");
                        (gather.finish()?, indices)
                    }
                    None => {
                        let mut res = Reservoir::new(m.max(1), d);
                        let mut seen = 0usize;
                        let mut row = vec![0.0f64; d];
                        while let Some(chunk) =
                            retry.run("centers: next_chunk", || source.next_chunk())?
                        {
                            anyhow::ensure!(
                                chunk.start == seen,
                                "source chunks must be contiguous"
                            );
                            let rows = chunk.x.rows();
                            seen += rows;
                            for i in 0..rows {
                                chunk.x.row_f64_into(i, &mut row);
                                res.push(&row, rng);
                            }
                            y_out.extend_from_slice(&chunk.y);
                        }
                        anyhow::ensure!(seen > 0, "source is empty");
                        res.finish()
                    }
                };
                Ok(SelectedCenters {
                    c,
                    indices,
                    d_weights: None,
                    scores: None,
                })
            }
            Centers::ApproxLeverage { sketch } => {
                // passes 0-1: pilot + Gram sketch (collects the targets)
                let (sk, n) = super::lscores::sketch_source(
                    engine,
                    source,
                    kern,
                    sigma,
                    lam,
                    *sketch,
                    rng,
                    Some(y_out),
                )?;
                match source.len_hint() {
                    Some(len) => {
                        debug_assert_eq!(len, n);
                        // pass 2: materialize the scores, then the same
                        // sample_by_scores draw as the in-memory path
                        retry.run("center scores: reset", || source.reset())?;
                        let mut scores: Vec<f64> = Vec::with_capacity(n);
                        while let Some(chunk) =
                            retry.run("center scores: next_chunk", || source.next_chunk())?
                        {
                            anyhow::ensure!(
                                chunk.start == scores.len(),
                                "source chunks must be contiguous"
                            );
                            scores.extend(sk.score_block(engine, &chunk.x)?);
                        }
                        anyhow::ensure!(
                            scores.len() == n,
                            "source yielded {} rows in the scoring pass, expected {n}",
                            scores.len()
                        );
                        let (indices, d_weights) = sample_by_scores(&scores, m, n, rng);
                        // pass 3: gather the drawn center rows
                        retry.run("center gather: reset", || source.reset())?;
                        let mut gather = CenterGather::new(&indices, d);
                        let mut seen = 0usize;
                        while let Some(chunk) =
                            retry.run("center gather: next_chunk", || source.next_chunk())?
                        {
                            anyhow::ensure!(
                                chunk.start == seen,
                                "source chunks must be contiguous"
                            );
                            seen += chunk.x.rows();
                            gather.offer_block(chunk.start, &chunk.x);
                        }
                        Ok(SelectedCenters {
                            c: gather.finish()?,
                            indices,
                            d_weights: Some(d_weights),
                            scores: Some(scores),
                        })
                    }
                    None => {
                        // pass 2: score each chunk and feed the weighted
                        // reservoir — no O(n) score vector is ever held
                        retry.run("center scores: reset", || source.reset())?;
                        let mut wr = WeightedReservoir::new(m.min(n).max(1), d);
                        let mut row = vec![0.0f64; d];
                        while let Some(chunk) =
                            retry.run("center scores: next_chunk", || source.next_chunk())?
                        {
                            anyhow::ensure!(
                                chunk.start == wr.seen(),
                                "source chunks must be contiguous"
                            );
                            let s = sk.score_block(engine, &chunk.x)?;
                            for (i, &si) in s.iter().enumerate() {
                                chunk.x.row_f64_into(i, &mut row);
                                wr.push(&row, si, rng);
                            }
                        }
                        anyhow::ensure!(
                            wr.seen() == n,
                            "source yielded {} rows in the scoring pass, expected {n}",
                            wr.seen()
                        );
                        let (c, indices, d_weights) = wr.finish();
                        Ok(SelectedCenters {
                            c,
                            indices,
                            d_weights: Some(d_weights),
                            scores: None,
                        })
                    }
                }
            }
        }
    }
}

/// Draw `m` *distinct* indices with probability ∝ score and compute the
/// Def. 2 weights D_jj = 1/sqrt(n p_j).
///
/// The paper's Alg. 2 samples with replacement and collapses duplicates
/// (so the realized M is random); we sample without replacement to keep M
/// exact — required by the static-shape artifact contract — which is the
/// standard practical variant (documented in DESIGN.md §3).
pub fn sample_by_scores(
    scores: &[f64],
    m: usize,
    n: usize,
    rng: &mut Rng,
) -> (Vec<usize>, Vec<f64>) {
    assert_eq!(scores.len(), n);
    let m = m.min(n);
    let total: f64 = scores.iter().sum();
    let probs: Vec<f64> = scores.iter().map(|s| (s / total).max(1e-300)).collect();

    let mut taken = vec![false; n];
    let mut indices = Vec::with_capacity(m);
    // successive weighted draws, skipping already-chosen indices
    let sampler = CategoricalSampler::new(&probs);
    let mut guard = 0usize;
    while indices.len() < m {
        let i = sampler.draw(rng);
        if !taken[i] {
            taken[i] = true;
            indices.push(i);
        }
        guard += 1;
        if guard > 50 * m + 1000 {
            // heavy-tailed scores: fill the remainder uniformly from the
            // untaken set to terminate deterministically
            for i in 0..n {
                if indices.len() >= m {
                    break;
                }
                if !taken[i] {
                    taken[i] = true;
                    indices.push(i);
                }
            }
        }
    }
    let d_weights = indices
        .iter()
        .map(|&i| 1.0 / (n as f64 * probs[i]).sqrt())
        .collect();
    (indices, d_weights)
}

// ---------------------------------------------------------------------
// streaming selection (the out-of-core path)
// ---------------------------------------------------------------------

/// Uniform reservoir sampler over a row stream (Algorithm R): after
/// pushing every row exactly once, the kept rows are a uniform sample of
/// size `min(m, rows seen)` — without knowing the stream length up
/// front. This is how the out-of-core path selects Nyström centers from
/// a source whose row count is unknown; sources with a known length use
/// [`CenterGather`] instead so the selected indices match the in-memory
/// fit exactly.
pub struct Reservoir {
    m: usize,
    rows: Mat,
    indices: Vec<usize>,
    seen: usize,
}

impl Reservoir {
    pub fn new(m: usize, d: usize) -> Reservoir {
        assert!(m > 0, "reservoir needs m > 0");
        Reservoir {
            m,
            rows: Mat::zeros(m, d),
            indices: Vec::with_capacity(m),
            seen: 0,
        }
    }

    /// Offer the next stream row (global index = rows pushed so far).
    pub fn push(&mut self, row: &[f64], rng: &mut Rng) {
        if self.indices.len() < self.m {
            let slot = self.indices.len();
            self.rows.row_mut(slot).copy_from_slice(row);
            self.indices.push(self.seen);
        } else {
            let j = rng.below(self.seen + 1);
            if j < self.m {
                self.rows.row_mut(j).copy_from_slice(row);
                self.indices[j] = self.seen;
            }
        }
        self.seen += 1;
    }

    /// Rows offered so far.
    pub fn seen(&self) -> usize {
        self.seen
    }

    /// The sampled rows and their global stream indices (trimmed if the
    /// stream had fewer than `m` rows).
    pub fn finish(self) -> (Mat, Vec<usize>) {
        let kept = self.indices.len();
        if kept < self.m {
            (self.rows.slice_rows(0, kept), self.indices)
        } else {
            (self.rows, self.indices)
        }
    }
}

/// Gather pre-drawn center indices from a single chunked pass: given the
/// index list (e.g. `rng.choose(n, m)` — the same draw the in-memory
/// [`Centers::Uniform`] makes), `offer` each contiguous chunk and
/// `finish` returns the centers **in index-list order**, so a streaming
/// fit selects bit-identical centers to the in-memory fit at equal seed.
pub struct CenterGather {
    /// (global row index, output slot), sorted by row index
    slots: Vec<(usize, usize)>,
    c: Mat,
    cursor: usize,
}

impl CenterGather {
    pub fn new(indices: &[usize], d: usize) -> CenterGather {
        let mut slots: Vec<(usize, usize)> = indices
            .iter()
            .copied()
            .enumerate()
            .map(|(slot, idx)| (idx, slot))
            .collect();
        slots.sort_unstable();
        CenterGather {
            slots,
            c: Mat::zeros(indices.len(), d),
            cursor: 0,
        }
    }

    /// Offer a chunk of rows starting at global row `start`. Chunks must
    /// arrive in stream order (contiguous, ascending).
    pub fn offer(&mut self, start: usize, x: &Mat) {
        self.offer_rows(start, x.rows, |i, out| out.copy_from_slice(x.row(i)));
    }

    /// [`CenterGather::offer`] for a chunk in either storage format: only
    /// the wanted rows are widened. The gathered centers stay `f64` — they
    /// are M×d coordinator state (K_MM, preconditioner), not streamed
    /// panel data, so the mixed-precision storage saving does not apply.
    pub fn offer_block(&mut self, start: usize, x: &XBlock) {
        self.offer_rows(start, x.rows(), |i, out| x.row_f64_into(i, out));
    }

    fn offer_rows(&mut self, start: usize, rows: usize, mut copy: impl FnMut(usize, &mut [f64])) {
        let end = start + rows;
        while self.cursor < self.slots.len() {
            let (idx, slot) = self.slots[self.cursor];
            if idx >= end {
                break;
            }
            assert!(
                idx >= start,
                "chunk starting at {start} skipped wanted row {idx} (chunks out of order?)"
            );
            copy(idx - start, self.c.row_mut(slot));
            self.cursor += 1;
        }
    }

    /// All gathered centers; errors if the stream ended before every
    /// requested row was seen.
    pub fn finish(self) -> Result<Mat> {
        anyhow::ensure!(
            self.cursor == self.slots.len(),
            "stream ended before all {} centers were gathered ({} found)",
            self.slots.len(),
            self.cursor
        );
        Ok(self.c)
    }
}

/// Heap entry of the [`WeightedReservoir`]: the A-Res key of a kept row
/// and its reservoir slot. Ordered by key (total order via `total_cmp`,
/// ties broken by slot) so a `Reverse`-wrapped binary heap pops the
/// smallest key first.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct HeapKey {
    key: u64,
    slot: usize,
}

impl HeapKey {
    fn new(key: f64, slot: usize) -> HeapKey {
        // map f64 to an order-preserving u64 so the heap entry is Eq/Ord
        // without float edge cases: flip the sign bit for positives,
        // all bits for negatives (keys here are ≤ 0, but keep it total)
        let bits = key.to_bits();
        let mapped = if bits >> 63 == 0 {
            bits | (1 << 63)
        } else {
            !bits
        };
        HeapKey { key: mapped, slot }
    }
}

impl Ord for HeapKey {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key.cmp(&other.key).then(self.slot.cmp(&other.slot))
    }
}

impl PartialOrd for HeapKey {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Weighted reservoir sampler (Efraimidis–Spirakis A-Res) over a row
/// stream: after pushing every row once with its weight (here the
/// approximate leverage score), the kept rows are an m-subset drawn
/// without replacement with inclusion probability increasing in weight —
/// the streaming counterpart of [`sample_by_scores`] for sources whose
/// length (and score vector) never fits in memory at once.
///
/// Each pushed row draws one key `ln(u)/w` (u uniform, the log-domain
/// A-Res key) and the m largest keys win, tracked by a min-heap keyed on
/// the smallest kept key. Exactly one rng draw happens per pushed row
/// regardless of keep/evict, so the selection is a deterministic
/// function of (stream order, weights, seed).
///
/// [`WeightedReservoir::finish`] also emits the Def. 2 reweighting
/// D_jj = 1/√(n·p_j) with p_j = w_j / Σw — the same formula
/// [`sample_by_scores`] uses, with the stream total standing in for the
/// in-memory score sum.
pub struct WeightedReservoir {
    m: usize,
    rows: Mat,
    indices: Vec<usize>,
    scores: Vec<f64>,
    heap: BinaryHeap<Reverse<HeapKey>>,
    seen: usize,
    total: f64,
}

impl WeightedReservoir {
    pub fn new(m: usize, d: usize) -> WeightedReservoir {
        assert!(m > 0, "weighted reservoir needs m > 0");
        WeightedReservoir {
            m,
            rows: Mat::zeros(m, d),
            indices: Vec::with_capacity(m),
            scores: Vec::with_capacity(m),
            heap: BinaryHeap::with_capacity(m),
            seen: 0,
            total: 0.0,
        }
    }

    /// Offer the next stream row with its sampling weight (global index =
    /// rows pushed so far). Non-finite or negative weights are clamped to
    /// zero: such a row only survives if the stream never offers m
    /// positive-weight rows.
    pub fn push(&mut self, row: &[f64], score: f64, rng: &mut Rng) {
        let w = if score.is_finite() { score.max(0.0) } else { 0.0 };
        self.total += w;
        // one rng draw per row, keep or not — determinism does not depend
        // on the heap state
        let u = rng.f64();
        let key = if w > 0.0 {
            // ln(u)/w with u in [0,1): ln(0) = -inf handles u == 0
            u.ln() / w
        } else {
            f64::NEG_INFINITY
        };
        if self.indices.len() < self.m {
            let slot = self.indices.len();
            self.rows.row_mut(slot).copy_from_slice(row);
            self.indices.push(self.seen);
            self.scores.push(w);
            self.heap.push(Reverse(HeapKey::new(key, slot)));
        } else if let Some(&Reverse(min)) = self.heap.peek() {
            if HeapKey::new(key, min.slot) > min {
                let slot = min.slot;
                self.heap.pop();
                self.rows.row_mut(slot).copy_from_slice(row);
                self.indices[slot] = self.seen;
                self.scores[slot] = w;
                self.heap.push(Reverse(HeapKey::new(key, slot)));
            }
        }
        self.seen += 1;
    }

    /// Rows offered so far.
    pub fn seen(&self) -> usize {
        self.seen
    }

    /// The sampled rows, their global stream indices, and the Def. 2
    /// weights D_jj = 1/√(n·p_j) (trimmed if the stream had fewer than
    /// `m` rows).
    pub fn finish(self) -> (Mat, Vec<usize>, Vec<f64>) {
        let n = self.seen as f64;
        let total = self.total;
        let d_weights: Vec<f64> = self
            .scores
            .iter()
            .map(|&s| {
                let p = if total > 0.0 {
                    (s / total).max(1e-300)
                } else {
                    1e-300
                };
                1.0 / (n * p).sqrt()
            })
            .collect();
        let kept = self.indices.len();
        let rows = if kept < self.m {
            self.rows.slice_rows(0, kept)
        } else {
            self.rows
        };
        (rows, self.indices, d_weights)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Kernel;

    #[test]
    fn uniform_selects_m_distinct_rows() {
        let mut rng = Rng::new(1);
        let x = Mat::from_vec(50, 3, rng.normals(150));
        let eng = Engine::rust();
        let sel = Centers::Uniform
            .select(&eng, &x, Kernel::Gaussian, 1.0, 1e-3, 10, &mut rng)
            .unwrap();
        assert_eq!(sel.c.rows, 10);
        assert!(sel.d_weights.is_none());
        let mut idx = sel.indices.clone();
        idx.sort_unstable();
        idx.dedup();
        assert_eq!(idx.len(), 10);
        // selected rows really come from x
        for (k, &i) in sel.indices.iter().enumerate() {
            assert_eq!(sel.c.row(k), x.row(i));
        }
    }

    #[test]
    fn score_sampling_prefers_high_scores() {
        let mut rng = Rng::new(2);
        let n = 200;
        let mut scores = vec![0.01; n];
        for s in scores.iter_mut().take(20) {
            *s = 10.0;
        }
        let mut hits = 0;
        for _ in 0..50 {
            let (idx, _) = sample_by_scores(&scores, 10, n, &mut rng);
            hits += idx.iter().filter(|&&i| i < 20).count();
        }
        // high-score block should dominate selections
        assert!(hits > 350, "hits {hits}");
    }

    #[test]
    fn score_sampling_exact_m_and_weights() {
        let mut rng = Rng::new(3);
        let scores: Vec<f64> = (1..=40).map(|i| i as f64).collect();
        let (idx, w) = sample_by_scores(&scores, 15, 40, &mut rng);
        assert_eq!(idx.len(), 15);
        assert_eq!(w.len(), 15);
        let total: f64 = scores.iter().sum();
        for (k, &i) in idx.iter().enumerate() {
            let p = scores[i] / total;
            assert!((w[k] - 1.0 / (40.0 * p).sqrt()).abs() < 1e-12);
        }
    }

    #[test]
    fn degenerate_scores_still_terminate() {
        let mut rng = Rng::new(4);
        let mut scores = vec![0.0; 30];
        scores[0] = 1.0; // all mass on one index
        let (idx, _) = sample_by_scores(&scores, 5, 30, &mut rng);
        assert_eq!(idx.len(), 5);
    }

    #[test]
    fn reservoir_keeps_exact_m_and_matches_stream_rows() {
        let mut rng = Rng::new(11);
        let n = 500;
        let x = Mat::from_vec(n, 3, rng.normals(n * 3));
        let mut res = Reservoir::new(20, 3);
        for i in 0..n {
            res.push(x.row(i), &mut rng);
        }
        assert_eq!(res.seen(), n);
        let (c, idx) = res.finish();
        assert_eq!(c.rows, 20);
        assert_eq!(idx.len(), 20);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20, "indices must be distinct");
        for (k, &i) in idx.iter().enumerate() {
            assert_eq!(c.row(k), x.row(i), "kept row {k} != stream row {i}");
        }
    }

    #[test]
    fn reservoir_short_stream_keeps_everything() {
        let mut rng = Rng::new(12);
        let x = Mat::from_vec(7, 2, rng.normals(14));
        let mut res = Reservoir::new(20, 2);
        for i in 0..7 {
            res.push(x.row(i), &mut rng);
        }
        let (c, idx) = res.finish();
        assert_eq!(c.rows, 7);
        assert_eq!(idx, (0..7).collect::<Vec<_>>());
        assert_eq!(c.data, x.data);
    }

    #[test]
    fn reservoir_is_roughly_uniform() {
        // every stream position should be kept with probability ~m/n
        let (n, m, reps) = (200usize, 10usize, 300usize);
        let mut hits = vec![0usize; n];
        for rep in 0..reps {
            let mut rng = Rng::new(1000 + rep as u64);
            let mut res = Reservoir::new(m, 1);
            for i in 0..n {
                res.push(&[i as f64], &mut rng);
            }
            let (_, idx) = res.finish();
            for i in idx {
                hits[i] += 1;
            }
        }
        let expect = reps as f64 * m as f64 / n as f64; // = 15
        // early, middle and late thirds all within a loose band
        for (lo, hi) in [(0, n / 3), (n / 3, 2 * n / 3), (2 * n / 3, n)] {
            let mean = hits[lo..hi].iter().sum::<usize>() as f64 / (hi - lo) as f64;
            assert!(
                (mean - expect).abs() < 0.35 * expect,
                "band {lo}..{hi}: mean {mean} vs {expect}"
            );
        }
    }

    #[test]
    fn gather_matches_select_rows_in_index_order() {
        let mut rng = Rng::new(13);
        let n = 300;
        let x = Mat::from_vec(n, 4, rng.normals(n * 4));
        let indices = rng.choose(n, 24);
        let want = x.select_rows(&indices);
        let mut g = CenterGather::new(&indices, 4);
        // ragged chunk sizes
        let mut start = 0;
        for step in [37usize, 100, 1, 95, 200] {
            let end = (start + step).min(n);
            g.offer(start, &x.slice_rows(start, end));
            start = end;
            if start == n {
                break;
            }
        }
        let got = g.finish().unwrap();
        assert_eq!(got.data, want.data, "gathered centers must be bitwise equal");
    }

    #[test]
    fn gather_errors_on_short_stream() {
        let g = CenterGather::new(&[5, 2], 2);
        assert!(g.finish().is_err());
        let mut g = CenterGather::new(&[5, 2], 2);
        g.offer(0, &Mat::zeros(3, 2));
        assert!(g.finish().is_err());
    }

    #[test]
    fn weighted_reservoir_exact_m_distinct_and_matches_stream_rows() {
        let mut rng = Rng::new(21);
        let n = 400;
        let x = Mat::from_vec(n, 3, rng.normals(n * 3));
        let mut wr = WeightedReservoir::new(25, 3);
        for i in 0..n {
            wr.push(x.row(i), 1.0 + (i % 7) as f64, &mut rng);
        }
        assert_eq!(wr.seen(), n);
        let (c, idx, w) = wr.finish();
        assert_eq!(c.rows, 25);
        assert_eq!(idx.len(), 25);
        assert_eq!(w.len(), 25);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 25, "indices must be distinct");
        for (k, &i) in idx.iter().enumerate() {
            assert_eq!(c.row(k), x.row(i), "kept row {k} != stream row {i}");
        }
    }

    #[test]
    fn weighted_reservoir_def2_weights_agree_with_sample_by_scores() {
        // the streamed sampler must emit the same D_jj = 1/sqrt(n p_j)
        // formula sample_by_scores computes from the in-memory score
        // vector (with the stream total standing in for the score sum)
        let mut rng = Rng::new(22);
        let n = 120;
        let scores: Vec<f64> = (0..n).map(|i| 0.5 + (i % 11) as f64).collect();
        let total: f64 = scores.iter().sum();
        let mut wr = WeightedReservoir::new(15, 1);
        for (i, &s) in scores.iter().enumerate() {
            wr.push(&[i as f64], s, &mut rng);
        }
        let (_, idx, w) = wr.finish();
        // reference: the exact per-index probs sample_by_scores derives
        let probs: Vec<f64> = scores.iter().map(|s| (s / total).max(1e-300)).collect();
        for (k, &i) in idx.iter().enumerate() {
            let want = 1.0 / (n as f64 * probs[i]).sqrt();
            assert!(
                (w[k] - want).abs() < 1e-12,
                "weight of index {i}: {} vs {}",
                w[k],
                want
            );
        }
        // and against sample_by_scores directly on a shared index
        let (idx2, w2) = sample_by_scores(&scores, 15, n, &mut rng);
        for (k, &i) in idx.iter().enumerate() {
            if let Some(k2) = idx2.iter().position(|&j| j == i) {
                assert!(
                    (w[k] - w2[k2]).abs() < 1e-12,
                    "index {i}: streamed {} vs in-memory {}",
                    w[k],
                    w2[k2]
                );
            }
        }
    }

    #[test]
    fn weighted_reservoir_prefers_high_scores() {
        // mirror of score_sampling_prefers_high_scores on the streamed
        // sampler: 20 high-score rows out of 200 should dominate
        let mut rng = Rng::new(23);
        let n = 200;
        let mut scores = vec![0.01; n];
        for s in scores.iter_mut().take(20) {
            *s = 10.0;
        }
        let mut hits = 0;
        for _ in 0..50 {
            let mut wr = WeightedReservoir::new(10, 1);
            for (i, &s) in scores.iter().enumerate() {
                wr.push(&[i as f64], s, &mut rng);
            }
            let (_, idx, _) = wr.finish();
            hits += idx.iter().filter(|&&i| i < 20).count();
        }
        assert!(hits > 350, "hits {hits}");
    }

    #[test]
    fn weighted_reservoir_short_stream_keeps_everything() {
        let mut rng = Rng::new(24);
        let x = Mat::from_vec(6, 2, rng.normals(12));
        let mut wr = WeightedReservoir::new(20, 2);
        for i in 0..6 {
            wr.push(x.row(i), 1.0, &mut rng);
        }
        let (c, idx, w) = wr.finish();
        assert_eq!(c.rows, 6);
        assert_eq!(idx, (0..6).collect::<Vec<_>>());
        assert_eq!(w.len(), 6);
        assert_eq!(c.data, x.data);
    }

    #[test]
    fn weighted_reservoir_degenerate_scores_still_fill() {
        // zero/negative/non-finite weights: rows still fill free slots,
        // the reservoir keeps exactly m, and the weights stay finite
        let mut rng = Rng::new(25);
        let n = 60;
        let mut wr = WeightedReservoir::new(8, 1);
        for i in 0..n {
            let s = match i % 4 {
                0 => 0.0,
                1 => -3.0,
                2 => f64::NAN,
                _ => 1.0,
            };
            wr.push(&[i as f64], s, &mut rng);
        }
        let (c, idx, w) = wr.finish();
        assert_eq!(c.rows, 8);
        assert_eq!(idx.len(), 8);
        for &v in &w {
            assert!(v.is_finite() && v > 0.0, "weight {v}");
        }
        // with positive-weight rows available, only those survive
        for &i in &idx {
            assert_eq!(i % 4, 3, "kept a zero-weight row {i}");
        }
    }
}

//! Nyström center selection — Sect. A of the paper: uniform sampling and
//! approximate-leverage-score sampling with the Def. 2 reweighting matrix D.

use crate::linalg::mat::Mat;
use crate::runtime::Engine;
use crate::util::rng::{CategoricalSampler, Rng};
use anyhow::Result;

/// Center-selection strategy.
#[derive(Debug, Clone)]
pub enum Centers {
    /// Uniform subsampling of the training set (Thm. 3 regime).
    Uniform,
    /// Approximate leverage scores (Def. 1 / Thm. 4-5 regime): a uniform
    /// pilot sketch of `sketch` columns estimates the ridge leverage
    /// scores at level `lam`, then centers are drawn ∝ l̂_i(λ).
    ApproxLeverage { sketch: usize },
}

/// Selected centers plus the Def. 2 diagonal reweighting (None ⇔ identity).
#[derive(Debug, Clone)]
pub struct SelectedCenters {
    pub c: Mat,
    pub indices: Vec<usize>,
    /// D_jj = 1/sqrt(n p_j) for leverage-score sampling (Def. 2)
    pub d_weights: Option<Vec<f64>>,
    /// the estimated leverage scores (diagnostics / benches)
    pub scores: Option<Vec<f64>>,
}

impl Centers {
    pub fn select(
        &self,
        engine: &Engine,
        x: &Mat,
        kern: crate::kernels::Kernel,
        sigma: f64,
        lam: f64,
        m: usize,
        rng: &mut Rng,
    ) -> Result<SelectedCenters> {
        match self {
            Centers::Uniform => {
                let indices = rng.choose(x.rows, m.min(x.rows));
                Ok(SelectedCenters {
                    c: x.select_rows(&indices),
                    indices,
                    d_weights: None,
                    scores: None,
                })
            }
            Centers::ApproxLeverage { sketch } => {
                let scores =
                    super::lscores::approx_leverage_scores(engine, x, kern, sigma, lam, *sketch, rng)?;
                let (indices, d_weights) = sample_by_scores(&scores, m, x.rows, rng);
                Ok(SelectedCenters {
                    c: x.select_rows(&indices),
                    indices,
                    d_weights: Some(d_weights),
                    scores: Some(scores),
                })
            }
        }
    }
}

/// Draw `m` *distinct* indices with probability ∝ score and compute the
/// Def. 2 weights D_jj = 1/sqrt(n p_j).
///
/// The paper's Alg. 2 samples with replacement and collapses duplicates
/// (so the realized M is random); we sample without replacement to keep M
/// exact — required by the static-shape artifact contract — which is the
/// standard practical variant (documented in DESIGN.md §3).
pub fn sample_by_scores(
    scores: &[f64],
    m: usize,
    n: usize,
    rng: &mut Rng,
) -> (Vec<usize>, Vec<f64>) {
    assert_eq!(scores.len(), n);
    let m = m.min(n);
    let total: f64 = scores.iter().sum();
    let probs: Vec<f64> = scores.iter().map(|s| (s / total).max(1e-300)).collect();

    let mut taken = vec![false; n];
    let mut indices = Vec::with_capacity(m);
    // successive weighted draws, skipping already-chosen indices
    let sampler = CategoricalSampler::new(&probs);
    let mut guard = 0usize;
    while indices.len() < m {
        let i = sampler.draw(rng);
        if !taken[i] {
            taken[i] = true;
            indices.push(i);
        }
        guard += 1;
        if guard > 50 * m + 1000 {
            // heavy-tailed scores: fill the remainder uniformly from the
            // untaken set to terminate deterministically
            for i in 0..n {
                if indices.len() >= m {
                    break;
                }
                if !taken[i] {
                    taken[i] = true;
                    indices.push(i);
                }
            }
        }
    }
    let d_weights = indices
        .iter()
        .map(|&i| 1.0 / (n as f64 * probs[i]).sqrt())
        .collect();
    (indices, d_weights)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Kernel;

    #[test]
    fn uniform_selects_m_distinct_rows() {
        let mut rng = Rng::new(1);
        let x = Mat::from_vec(50, 3, rng.normals(150));
        let eng = Engine::rust();
        let sel = Centers::Uniform
            .select(&eng, &x, Kernel::Gaussian, 1.0, 1e-3, 10, &mut rng)
            .unwrap();
        assert_eq!(sel.c.rows, 10);
        assert!(sel.d_weights.is_none());
        let mut idx = sel.indices.clone();
        idx.sort_unstable();
        idx.dedup();
        assert_eq!(idx.len(), 10);
        // selected rows really come from x
        for (k, &i) in sel.indices.iter().enumerate() {
            assert_eq!(sel.c.row(k), x.row(i));
        }
    }

    #[test]
    fn score_sampling_prefers_high_scores() {
        let mut rng = Rng::new(2);
        let n = 200;
        let mut scores = vec![0.01; n];
        for s in scores.iter_mut().take(20) {
            *s = 10.0;
        }
        let mut hits = 0;
        for _ in 0..50 {
            let (idx, _) = sample_by_scores(&scores, 10, n, &mut rng);
            hits += idx.iter().filter(|&&i| i < 20).count();
        }
        // high-score block should dominate selections
        assert!(hits > 350, "hits {hits}");
    }

    #[test]
    fn score_sampling_exact_m_and_weights() {
        let mut rng = Rng::new(3);
        let scores: Vec<f64> = (1..=40).map(|i| i as f64).collect();
        let (idx, w) = sample_by_scores(&scores, 15, 40, &mut rng);
        assert_eq!(idx.len(), 15);
        assert_eq!(w.len(), 15);
        let total: f64 = scores.iter().sum();
        for (k, &i) in idx.iter().enumerate() {
            let p = scores[i] / total;
            assert!((w[k] - 1.0 / (40.0 * p).sqrt()).abs() < 1e-12);
        }
    }

    #[test]
    fn degenerate_scores_still_terminate() {
        let mut rng = Rng::new(4);
        let mut scores = vec![0.0; 30];
        scores[0] = 1.0; // all mass on one index
        let (idx, _) = sample_by_scores(&scores, 5, 30, &mut rng);
        assert_eq!(idx.len(), 5);
    }
}

//! Conjugate gradient — Alg. 2's `conjgrad`, generic over the operator so
//! the same loop drives the preconditioned FALKON system, the
//! un-preconditioned ablation, and the baselines.
//!
//! All heavy per-iteration state lives inside the operator: the FALKON
//! `apply` runs over a prepared [`crate::runtime::MatvecPlan`] whose row
//! blocks, norms, Kr tile buffers and worker pool are built once per fit
//! (DESIGN.md §Perf) — this loop only touches M-length vectors.

use anyhow::Result;
use crate::linalg::vec_ops::{axpy, dot, norm2, xpby};

/// Outcome of a CG run.
#[derive(Debug, Clone)]
pub struct CgResult {
    pub beta: Vec<f64>,
    /// iterations actually executed
    pub iters: usize,
    /// ‖r_k‖ after each iteration (residual of the preconditioned system)
    pub residuals: Vec<f64>,
    /// true iff a tolerance was requested and reached before t_max
    pub converged: bool,
}

/// Options for a CG run. `tol = 0.0` reproduces the paper's fixed-`t`
/// behaviour exactly (no early exit).
#[derive(Debug, Clone, Copy)]
pub struct CgOptions {
    pub t_max: usize,
    /// stop when ‖r‖/‖b‖ ≤ tol (0.0 = never)
    pub tol: f64,
}

impl Default for CgOptions {
    fn default() -> Self {
        CgOptions { t_max: 20, tol: 0.0 }
    }
}

/// Run CG on `W β = b` where `apply(p)` computes `W p`.
/// `on_iter(k, beta)` is invoked after each iteration (1-based k) — used by
/// the convergence-study benches to trace test error per iteration.
pub fn conjgrad(
    mut apply: impl FnMut(&[f64]) -> Result<Vec<f64>>,
    b: &[f64],
    opts: CgOptions,
    mut on_iter: Option<&mut dyn FnMut(usize, &[f64])>,
) -> Result<CgResult> {
    let m = b.len();
    let mut beta = vec![0.0; m];
    let mut r = b.to_vec();
    let mut p = b.to_vec();
    let mut rsold = dot(&r, &r);
    let b_norm = norm2(b).max(1e-300);
    let mut residuals = Vec::with_capacity(opts.t_max);
    let mut converged = false;
    let mut iters = 0;

    for k in 1..=opts.t_max {
        if rsold == 0.0 {
            converged = true;
            break;
        }
        let ap = apply(&p)?;
        let pap = dot(&p, &ap);
        if pap <= 0.0 || !pap.is_finite() {
            // operator lost positive-definiteness numerically — stop with
            // the best iterate rather than diverging
            break;
        }
        let a = rsold / pap;
        axpy(a, &p, &mut beta);
        axpy(-a, &ap, &mut r);
        let rsnew = dot(&r, &r);
        let r_norm = rsnew.sqrt();
        iters = k;
        residuals.push(r_norm);
        if let Some(cb) = on_iter.as_deref_mut() {
            cb(k, &beta);
        }
        if opts.tol > 0.0 && r_norm / b_norm <= opts.tol {
            converged = true;
            break;
        }
        xpby(&r, rsnew / rsold, &mut p);
        rsold = rsnew;
    }

    Ok(CgResult {
        beta,
        iters,
        residuals,
        converged,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{gram_t, matvec};
    use crate::linalg::mat::Mat;
    use crate::util::ptest::check;

    #[test]
    fn solves_spd_system_exactly_in_m_iters() {
        check("CG solves SPD systems", 20, |g| {
            let m = g.usize_in(1, 12);
            let a = {
                let r = Mat::from_vec(m, m, g.normal_vec(m * m));
                let mut s = gram_t(&r);
                s.add_diag(m as f64);
                s
            };
            let b = g.normal_vec(m);
            let res = conjgrad(
                |p| Ok(matvec(&a, p)),
                &b,
                CgOptions {
                    t_max: 3 * m + 5,
                    tol: 1e-12,
                },
                None,
            )
            .unwrap();
            let back = matvec(&a, &res.beta);
            for i in 0..m {
                assert!((back[i] - b[i]).abs() < 1e-6, "{} vs {}", back[i], b[i]);
            }
            assert!(res.converged);
        });
    }

    #[test]
    fn identity_converges_in_one_iter() {
        let b = vec![3.0, -1.0, 2.0];
        let res = conjgrad(
            |p| Ok(p.to_vec()),
            &b,
            CgOptions { t_max: 10, tol: 1e-12 },
            None,
        )
        .unwrap();
        assert_eq!(res.iters, 1);
        assert!(res.converged);
        assert_eq!(res.beta, b);
    }

    #[test]
    fn fixed_t_runs_exactly_t() {
        let b = vec![1.0, 2.0, 3.0, 4.0];
        let a = {
            let mut m = Mat::eye(4);
            m[(0, 0)] = 3.0;
            m[(1, 1)] = 0.5;
            m
        };
        let res = conjgrad(
            |p| Ok(matvec(&a, p)),
            &b,
            CgOptions { t_max: 3, tol: 0.0 },
            None,
        )
        .unwrap();
        assert_eq!(res.iters, 3);
        assert_eq!(res.residuals.len(), 3);
    }

    #[test]
    fn callback_sees_every_iteration() {
        let b = vec![1.0, 1.0];
        let mut seen = Vec::new();
        conjgrad(
            |p| Ok(p.to_vec()),
            &b,
            CgOptions { t_max: 5, tol: 0.0 },
            Some(&mut |k, beta: &[f64]| seen.push((k, beta.to_vec()))),
        )
        .unwrap();
        assert_eq!(seen.len(), 1); // identity converges (rs becomes 0) after 1
        assert_eq!(seen[0].0, 1);
    }

    #[test]
    fn residuals_monotone_for_well_conditioned() {
        let mut gsrc = crate::util::rng::Rng::new(3);
        let m = 10;
        let a = {
            let r = Mat::from_vec(m, m, gsrc.normals(m * m));
            let mut s = gram_t(&r);
            s.add_diag(10.0 * m as f64); // well conditioned
            s
        };
        let b = gsrc.normals(m);
        let res = conjgrad(
            |p| Ok(matvec(&a, p)),
            &b,
            CgOptions { t_max: 8, tol: 0.0 },
            None,
        )
        .unwrap();
        for w in res.residuals.windows(2) {
            assert!(w[1] <= w[0] * 1.5, "{:?}", res.residuals);
        }
    }
}

//! Conjugate gradient — Alg. 2's `conjgrad`, generic over the operator so
//! the same loop drives the preconditioned FALKON system, the
//! un-preconditioned ablation, and the baselines. [`block_conjgrad`] is
//! the multi-RHS variant: K simultaneous CG recurrences sharing one
//! `apply_multi` per iteration, the solver side of the one-vs-all
//! panel-amortization path (DESIGN.md §Perf "Multi-RHS path").
//!
//! All heavy per-iteration state lives inside the operator: the FALKON
//! `apply` runs over a prepared [`crate::runtime::MatvecPlan`] whose row
//! blocks, norms, Kr tile buffers and worker pool are built once per fit
//! (DESIGN.md §Perf) — this loop only touches M-length vectors.

use crate::linalg::mat::Mat;
use crate::linalg::vec_ops::{axpy, dot, norm2, xpby};
use anyhow::Result;

/// Why a CG run stopped — surfaced so callers can distinguish a clean
/// convergence from a numerically lost operator instead of silently
/// accepting the best iterate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CgStop {
    /// residual reached the tolerance (or became exactly zero)
    Converged,
    /// ran the full iteration budget (the paper's fixed-`t` regime)
    MaxIter,
    /// ⟨p, Wp⟩ came back non-positive or non-finite — the operator lost
    /// positive-definiteness numerically; the best iterate so far is kept
    LostPd,
}

impl CgStop {
    pub fn name(self) -> &'static str {
        match self {
            CgStop::Converged => "converged",
            CgStop::MaxIter => "max-iter",
            CgStop::LostPd => "lost-pd",
        }
    }
}

/// Outcome of a CG run.
#[derive(Debug, Clone)]
pub struct CgResult {
    pub beta: Vec<f64>,
    /// iterations actually executed
    pub iters: usize,
    /// ‖r_k‖ after each iteration (residual of the preconditioned system)
    pub residuals: Vec<f64>,
    /// true iff a tolerance was requested and reached before t_max
    pub converged: bool,
    /// why the loop stopped (LostPd is worth logging at the call site)
    pub stop: CgStop,
}

/// Options for a CG run. `tol = 0.0` reproduces the paper's fixed-`t`
/// behaviour exactly (no early exit).
#[derive(Debug, Clone, Copy)]
pub struct CgOptions {
    pub t_max: usize,
    /// stop when ‖r‖/‖b‖ ≤ tol (0.0 = never)
    pub tol: f64,
}

impl Default for CgOptions {
    fn default() -> Self {
        CgOptions { t_max: 20, tol: 0.0 }
    }
}

/// Resumable CG loop state: a verbatim snapshot of the recurrence taken
/// at the **end** of iteration `iters` (`p` and the implied `rsold =
/// ⟨r,r⟩` already updated for the next step), so a run resumed from a
/// state replays iterations `iters+1..` bit-for-bit — the checkpoint
/// contract of `train --resume`.
#[derive(Debug, Clone)]
pub struct CgState {
    pub beta: Vec<f64>,
    pub r: Vec<f64>,
    pub p: Vec<f64>,
    /// iterations completed when the snapshot was taken
    pub iters: usize,
    /// full residual trace up to `iters`
    pub residuals: Vec<f64>,
}

/// Run CG on `W β = b` where `apply(p)` computes `W p`.
/// `on_iter(k, beta)` is invoked after each iteration (1-based k) — used by
/// the convergence-study benches to trace test error per iteration.
pub fn conjgrad(
    mut apply: impl FnMut(&[f64]) -> Result<Vec<f64>>,
    b: &[f64],
    opts: CgOptions,
    on_iter: Option<&mut dyn FnMut(usize, &[f64])>,
) -> Result<CgResult> {
    conjgrad_resumable(&mut apply, b, opts, None, on_iter, None)
}

/// [`conjgrad`] with checkpoint hooks: `init` resumes from a prior
/// [`CgState`] snapshot (bitwise-identical trajectory to the
/// uninterrupted run), and `on_state` observes the end-of-iteration
/// state whenever the loop is about to continue — the estimator's
/// checkpoint writer. No snapshot is emitted on a terminal iteration
/// (converged / budget exhausted / LostPd): the run is over and the
/// sidecar is about to be finalized or discarded.
pub fn conjgrad_resumable(
    apply: &mut dyn FnMut(&[f64]) -> Result<Vec<f64>>,
    b: &[f64],
    opts: CgOptions,
    init: Option<CgState>,
    mut on_iter: Option<&mut dyn FnMut(usize, &[f64])>,
    mut on_state: Option<&mut dyn FnMut(&CgState)>,
) -> Result<CgResult> {
    let m = b.len();
    let (mut beta, mut r, mut p, start_k, mut residuals) = match init {
        Some(st) => {
            anyhow::ensure!(
                st.beta.len() == m && st.r.len() == m && st.p.len() == m,
                "resume state dimension {} does not match rhs {}",
                st.beta.len(),
                m
            );
            anyhow::ensure!(
                st.residuals.len() == st.iters,
                "resume state residual trace is inconsistent"
            );
            (st.beta, st.r, st.p, st.iters, st.residuals)
        }
        None => (
            vec![0.0; m],
            b.to_vec(),
            b.to_vec(),
            0,
            Vec::with_capacity(opts.t_max),
        ),
    };
    let mut rsold = dot(&r, &r);
    let b_norm = norm2(b).max(1e-300);
    let mut converged = false;
    let mut iters = start_k;
    let mut stop = CgStop::MaxIter;

    for k in (start_k + 1)..=opts.t_max {
        if rsold == 0.0 {
            converged = true;
            stop = CgStop::Converged;
            break;
        }
        let ap = apply(&p)?;
        let pap = dot(&p, &ap);
        if pap <= 0.0 || !pap.is_finite() {
            // operator lost positive-definiteness numerically — stop with
            // the best iterate rather than diverging
            stop = CgStop::LostPd;
            break;
        }
        let a = rsold / pap;
        axpy(a, &p, &mut beta);
        axpy(-a, &ap, &mut r);
        let rsnew = dot(&r, &r);
        let r_norm = rsnew.sqrt();
        iters = k;
        residuals.push(r_norm);
        if let Some(cb) = on_iter.as_deref_mut() {
            cb(k, &beta);
        }
        if opts.tol > 0.0 && r_norm / b_norm <= opts.tol {
            converged = true;
            stop = CgStop::Converged;
            break;
        }
        xpby(&r, rsnew / rsold, &mut p);
        rsold = rsnew;
        if k == opts.t_max {
            break; // budget exhausted: terminal, no snapshot
        }
        if let Some(cb) = on_state.as_deref_mut() {
            cb(&CgState {
                beta: beta.clone(),
                r: r.clone(),
                p: p.clone(),
                iters: k,
                residuals: residuals.clone(),
            });
        }
    }

    Ok(CgResult {
        beta,
        iters,
        residuals,
        converged,
        stop,
    })
}

/// Outcome of a block CG run: per-column solutions plus per-column
/// iteration traces and stop reasons.
#[derive(Debug, Clone)]
pub struct BlockCgResult {
    /// M×K solution block (column k solves W β_k = b_k)
    pub beta: Mat,
    /// iterations actually executed, per column
    pub iters: Vec<usize>,
    /// per-column ‖r_k‖ after each iteration
    pub residuals: Vec<Vec<f64>>,
    /// true iff that column reached the tolerance (or a zero residual)
    pub converged: Vec<bool>,
    /// per-column stop reason
    pub stops: Vec<CgStop>,
}

impl BlockCgResult {
    /// Largest per-column iteration count — the number of `apply_multi`
    /// calls the block solve actually performed.
    pub fn max_iters(&self) -> usize {
        self.iters.iter().copied().max().unwrap_or(0)
    }
}

/// Per-column recurrence state of [`block_conjgrad`].
struct ColState {
    beta: Vec<f64>,
    r: Vec<f64>,
    p: Vec<f64>,
    rsold: f64,
    b_norm: f64,
    iters: usize,
    residuals: Vec<f64>,
    stop: Option<CgStop>,
    converged: bool,
}

/// Run K simultaneous CG recurrences on `W B = R` where `apply_multi(P)`
/// computes `W P` for an `M×K_active` direction block — **one** operator
/// application per iteration regardless of K, which is what lets the
/// multi-RHS matvec plan amortize its kernel panels across the columns.
///
/// Per-column α/β/residual recurrences are identical to [`conjgrad`]'s;
/// a column that converges (or loses positive-definiteness) is **frozen**:
/// its state stops updating and it is dropped from the direction block, so
/// the apply shrinks as columns finish. With `tol = 0.0` every column runs
/// the full `t_max` (the paper's fixed-`t` regime) and the block solve is
/// exactly K vector solves sharing their panel sweeps.
pub fn block_conjgrad(
    mut apply_multi: impl FnMut(&Mat) -> Result<Mat>,
    b: &Mat,
    opts: CgOptions,
) -> Result<BlockCgResult> {
    let m = b.rows;
    let k = b.cols;
    let mut cols: Vec<ColState> = (0..k)
        .map(|kc| {
            let bk: Vec<f64> = (0..m).map(|i| b[(i, kc)]).collect();
            let rsold = dot(&bk, &bk);
            ColState {
                beta: vec![0.0; m],
                r: bk.clone(),
                p: bk.clone(),
                rsold,
                b_norm: norm2(&bk).max(1e-300),
                iters: 0,
                residuals: Vec::with_capacity(opts.t_max),
                stop: None,
                converged: false,
            }
        })
        .collect();

    for k_iter in 1..=opts.t_max {
        // freeze columns whose residual is exactly zero (matches the
        // vector loop's top-of-iteration check), then gather the rest
        for st in cols.iter_mut() {
            if st.stop.is_none() && st.rsold == 0.0 {
                st.converged = true;
                st.stop = Some(CgStop::Converged);
            }
        }
        let active: Vec<usize> = (0..k).filter(|&kc| cols[kc].stop.is_none()).collect();
        if active.is_empty() {
            break;
        }
        // assemble the shrinking direction block and apply W once
        let mut pblk = Mat::zeros(m, active.len());
        for (slot, &kc) in active.iter().enumerate() {
            for i in 0..m {
                pblk[(i, slot)] = cols[kc].p[i];
            }
        }
        let apblk = apply_multi(&pblk)?;
        anyhow::ensure!(
            (apblk.rows, apblk.cols) == (m, active.len()),
            "apply_multi returned {}x{}, expected {}x{}",
            apblk.rows,
            apblk.cols,
            m,
            active.len()
        );
        let mut ap = vec![0.0; m];
        for (slot, &kc) in active.iter().enumerate() {
            let st = &mut cols[kc];
            for i in 0..m {
                ap[i] = apblk[(i, slot)];
            }
            let pap = dot(&st.p, &ap);
            if pap <= 0.0 || !pap.is_finite() {
                st.stop = Some(CgStop::LostPd);
                continue;
            }
            let a = st.rsold / pap;
            axpy(a, &st.p, &mut st.beta);
            axpy(-a, &ap, &mut st.r);
            let rsnew = dot(&st.r, &st.r);
            let r_norm = rsnew.sqrt();
            st.iters = k_iter;
            st.residuals.push(r_norm);
            if opts.tol > 0.0 && r_norm / st.b_norm <= opts.tol {
                st.converged = true;
                st.stop = Some(CgStop::Converged);
                continue;
            }
            xpby(&st.r, rsnew / st.rsold, &mut st.p);
            st.rsold = rsnew;
        }
    }

    let mut beta = Mat::zeros(m, k);
    let mut iters = Vec::with_capacity(k);
    let mut residuals = Vec::with_capacity(k);
    let mut converged = Vec::with_capacity(k);
    let mut stops = Vec::with_capacity(k);
    for (kc, st) in cols.into_iter().enumerate() {
        for i in 0..m {
            beta[(i, kc)] = st.beta[i];
        }
        iters.push(st.iters);
        residuals.push(st.residuals);
        converged.push(st.converged);
        stops.push(st.stop.unwrap_or(CgStop::MaxIter));
    }
    Ok(BlockCgResult {
        beta,
        iters,
        residuals,
        converged,
        stops,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{gram_t, matmul, matvec};
    use crate::util::ptest::check;

    #[test]
    fn solves_spd_system_exactly_in_m_iters() {
        check("CG solves SPD systems", 20, |g| {
            let m = g.usize_in(1, 12);
            let a = {
                let r = Mat::from_vec(m, m, g.normal_vec(m * m));
                let mut s = gram_t(&r);
                s.add_diag(m as f64);
                s
            };
            let b = g.normal_vec(m);
            let res = conjgrad(
                |p| Ok(matvec(&a, p)),
                &b,
                CgOptions {
                    t_max: 3 * m + 5,
                    tol: 1e-12,
                },
                None,
            )
            .unwrap();
            let back = matvec(&a, &res.beta);
            for i in 0..m {
                assert!((back[i] - b[i]).abs() < 1e-6, "{} vs {}", back[i], b[i]);
            }
            assert!(res.converged);
            assert_eq!(res.stop, CgStop::Converged);
        });
    }

    #[test]
    fn identity_converges_in_one_iter() {
        let b = vec![3.0, -1.0, 2.0];
        let res = conjgrad(
            |p| Ok(p.to_vec()),
            &b,
            CgOptions { t_max: 10, tol: 1e-12 },
            None,
        )
        .unwrap();
        assert_eq!(res.iters, 1);
        assert!(res.converged);
        assert_eq!(res.beta, b);
    }

    #[test]
    fn fixed_t_runs_exactly_t() {
        let b = vec![1.0, 2.0, 3.0, 4.0];
        let a = {
            let mut m = Mat::eye(4);
            m[(0, 0)] = 3.0;
            m[(1, 1)] = 0.5;
            m
        };
        let res = conjgrad(
            |p| Ok(matvec(&a, p)),
            &b,
            CgOptions { t_max: 3, tol: 0.0 },
            None,
        )
        .unwrap();
        assert_eq!(res.iters, 3);
        assert_eq!(res.residuals.len(), 3);
        assert_eq!(res.stop, CgStop::MaxIter);
    }

    #[test]
    fn callback_sees_every_iteration() {
        let b = vec![1.0, 1.0];
        let mut seen = Vec::new();
        conjgrad(
            |p| Ok(p.to_vec()),
            &b,
            CgOptions { t_max: 5, tol: 0.0 },
            Some(&mut |k, beta: &[f64]| seen.push((k, beta.to_vec()))),
        )
        .unwrap();
        assert_eq!(seen.len(), 1); // identity converges (rs becomes 0) after 1
        assert_eq!(seen[0].0, 1);
    }

    #[test]
    fn residuals_monotone_for_well_conditioned() {
        let mut gsrc = crate::util::rng::Rng::new(3);
        let m = 10;
        let a = {
            let r = Mat::from_vec(m, m, gsrc.normals(m * m));
            let mut s = gram_t(&r);
            s.add_diag(10.0 * m as f64); // well conditioned
            s
        };
        let b = gsrc.normals(m);
        let res = conjgrad(
            |p| Ok(matvec(&a, p)),
            &b,
            CgOptions { t_max: 8, tol: 0.0 },
            None,
        )
        .unwrap();
        for w in res.residuals.windows(2) {
            assert!(w[1] <= w[0] * 1.5, "{:?}", res.residuals);
        }
    }

    #[test]
    fn indefinite_operator_reports_lost_pd() {
        // W = -I: ⟨p, Wp⟩ < 0 on the first iteration
        let b = vec![1.0, 2.0];
        let res = conjgrad(
            |p| Ok(p.iter().map(|v| -v).collect()),
            &b,
            CgOptions { t_max: 5, tol: 0.0 },
            None,
        )
        .unwrap();
        assert_eq!(res.stop, CgStop::LostPd);
        assert!(!res.converged);
        assert_eq!(res.iters, 0);
        assert_eq!(res.beta, vec![0.0, 0.0]); // best (initial) iterate kept
    }

    #[test]
    fn resumed_run_is_bitwise_identical() {
        // snapshot mid-run via on_state, then resume from each snapshot:
        // the tail trajectory must reproduce the uninterrupted run exactly
        check("CG resume is bitwise", 10, |g| {
            let m = g.usize_in(2, 10);
            let a = {
                let r = Mat::from_vec(m, m, g.normal_vec(m * m));
                let mut s = gram_t(&r);
                s.add_diag(m as f64);
                s
            };
            let b = g.normal_vec(m);
            let opts = CgOptions { t_max: 9, tol: 0.0 };
            let mut snaps: Vec<CgState> = Vec::new();
            let full = conjgrad_resumable(
                &mut |p: &[f64]| Ok(matvec(&a, p)),
                &b,
                opts,
                None,
                None,
                Some(&mut |st: &CgState| snaps.push(st.clone())),
            )
            .unwrap();
            for snap in snaps {
                let resumed = conjgrad_resumable(
                    &mut |p: &[f64]| Ok(matvec(&a, p)),
                    &b,
                    opts,
                    Some(snap),
                    None,
                    None,
                )
                .unwrap();
                assert_eq!(resumed.beta, full.beta, "beta must match bitwise");
                assert_eq!(resumed.iters, full.iters);
                assert_eq!(resumed.residuals, full.residuals);
                assert_eq!(resumed.stop, full.stop);
            }
        });
    }

    #[test]
    fn resume_past_budget_returns_snapshot() {
        let snap = CgState {
            beta: vec![1.0, 2.0],
            r: vec![0.1, 0.2],
            p: vec![0.1, 0.2],
            iters: 5,
            residuals: vec![5.0, 4.0, 3.0, 2.0, 1.0],
        };
        let res = conjgrad_resumable(
            &mut |p: &[f64]| Ok(p.to_vec()),
            &[1.0, 1.0],
            CgOptions { t_max: 3, tol: 0.0 },
            Some(snap),
            None,
            None,
        )
        .unwrap();
        assert_eq!(res.iters, 5);
        assert_eq!(res.beta, vec![1.0, 2.0]);
        assert_eq!(res.stop, CgStop::MaxIter);
    }

    #[test]
    fn resume_rejects_mismatched_dimension() {
        let snap = CgState {
            beta: vec![0.0; 3],
            r: vec![0.0; 3],
            p: vec![0.0; 3],
            iters: 1,
            residuals: vec![1.0],
        };
        let err = conjgrad_resumable(
            &mut |p: &[f64]| Ok(p.to_vec()),
            &[1.0, 1.0],
            CgOptions::default(),
            Some(snap),
            None,
            None,
        )
        .unwrap_err();
        assert!(format!("{err}").contains("dimension"), "{err}");
    }

    // -- block CG ----------------------------------------------------------

    #[test]
    fn block_cg_matches_k_vector_runs() {
        // the acceptance contract: per column, block CG must reproduce the
        // vector solver's trajectory on random SPD systems — ragged K
        // (1..6) including the K = 1 degeneracy
        check("block_conjgrad = K × conjgrad", 20, |g| {
            let m = g.usize_in(1, 10);
            let k = g.usize_in(1, 6);
            let a = {
                let r = Mat::from_vec(m, m, g.normal_vec(m * m));
                let mut s = gram_t(&r);
                s.add_diag(m as f64);
                s
            };
            let b = Mat::from_vec(m, k, g.normal_vec(m * k));
            let opts = CgOptions {
                t_max: 2 * m + 3,
                tol: 1e-10,
            };
            // apply each column with the same matvec arithmetic the vector
            // solver uses, so per-column trajectories (and therefore the
            // tolerance-exit iteration counts) are exactly reproducible
            let colwise_apply = |p: &Mat| {
                let mut out = Mat::zeros(p.rows, p.cols);
                let mut col = vec![0.0; p.rows];
                for j in 0..p.cols {
                    for i in 0..p.rows {
                        col[i] = p[(i, j)];
                    }
                    let y = matvec(&a, &col);
                    for i in 0..p.rows {
                        out[(i, j)] = y[i];
                    }
                }
                Ok(out)
            };
            let blk = block_conjgrad(colwise_apply, &b, opts).unwrap();
            for kc in 0..k {
                let bk: Vec<f64> = (0..m).map(|i| b[(i, kc)]).collect();
                let want = conjgrad(|p| Ok(matvec(&a, p)), &bk, opts, None).unwrap();
                assert_eq!(blk.iters[kc], want.iters, "col {kc} iters");
                assert_eq!(blk.converged[kc], want.converged, "col {kc} converged");
                assert_eq!(blk.stops[kc], want.stop, "col {kc} stop");
                for i in 0..m {
                    assert!(
                        (blk.beta[(i, kc)] - want.beta[i]).abs() < 1e-8,
                        "col {kc} row {i}: {} vs {}",
                        blk.beta[(i, kc)],
                        want.beta[i]
                    );
                }
                assert_eq!(blk.residuals[kc].len(), want.residuals.len());
                for (rb, rv) in blk.residuals[kc].iter().zip(&want.residuals) {
                    assert!((rb - rv).abs() < 1e-8 * (1.0 + rv.abs()));
                }
            }
        });
    }

    #[test]
    fn block_cg_fixed_t_runs_all_columns_full() {
        let mut rng = crate::util::rng::Rng::new(5);
        let (m, k) = (8, 3);
        let a = {
            let r = Mat::from_vec(m, m, rng.normals(m * m));
            let mut s = gram_t(&r);
            s.add_diag(m as f64);
            s
        };
        let b = Mat::from_vec(m, k, rng.normals(m * k));
        let res = block_conjgrad(
            |p| Ok(matmul(&a, p)),
            &b,
            CgOptions { t_max: 4, tol: 0.0 },
        )
        .unwrap();
        assert_eq!(res.iters, vec![4, 4, 4]);
        assert_eq!(res.max_iters(), 4);
        for kc in 0..k {
            assert_eq!(res.stops[kc], CgStop::MaxIter);
            assert_eq!(res.residuals[kc].len(), 4);
        }
    }

    #[test]
    fn block_cg_freezes_converged_columns_and_shrinks_apply() {
        // column 0 is the zero RHS (converges at iteration 1 with rsold=0);
        // the remaining columns keep iterating — the apply width must drop
        let mut rng = crate::util::rng::Rng::new(6);
        let (m, k) = (6, 3);
        let a = {
            let r = Mat::from_vec(m, m, rng.normals(m * m));
            let mut s = gram_t(&r);
            s.add_diag(m as f64);
            s
        };
        let mut b = Mat::from_vec(m, k, rng.normals(m * k));
        for i in 0..m {
            b[(i, 0)] = 0.0;
        }
        let mut widths = Vec::new();
        let res = block_conjgrad(
            |p| {
                widths.push(p.cols);
                Ok(matmul(&a, p))
            },
            &b,
            CgOptions { t_max: 3, tol: 0.0 },
        )
        .unwrap();
        assert_eq!(widths, vec![2, 2, 2], "zero column never enters the apply");
        assert_eq!(res.iters[0], 0);
        assert!(res.converged[0]);
        assert_eq!(res.stops[0], CgStop::Converged);
        for i in 0..m {
            assert_eq!(res.beta[(i, 0)], 0.0);
        }
        assert_eq!(res.iters[1], 3);
        assert_eq!(res.iters[2], 3);
    }

    #[test]
    fn block_cg_shrinks_on_tolerance_exit() {
        // identity operator: every column converges after one iteration,
        // so with a tolerance the loop makes exactly one apply
        let b = Mat::from_vec(3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let mut applies = 0usize;
        let res = block_conjgrad(
            |p| {
                applies += 1;
                Ok(p.clone())
            },
            &b,
            CgOptions { t_max: 10, tol: 1e-12 },
        )
        .unwrap();
        assert_eq!(applies, 1);
        assert_eq!(res.iters, vec![1, 1]);
        assert!(res.converged.iter().all(|&c| c));
        assert_eq!(res.beta.data, b.data);
    }
}

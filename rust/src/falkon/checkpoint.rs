//! Checkpoint/resume for long CG solves (DESIGN.md §Fault tolerance).
//!
//! Every `every` CG iterations the estimator serializes the full
//! [`CgState`] snapshot to a JSON sidecar next to the model. The sidecar
//! carries a fingerprint of everything the trajectory depends on —
//! kernel, hyperparameters, data size, centers, preconditioner factors —
//! so `train --resume` refuses to splice a checkpoint into a different
//! run. Budget knobs (`t`, `tol`) are deliberately **excluded** from the
//! fingerprint: resuming an interrupted fit with a larger iteration
//! budget is legitimate and changes nothing about iterations already
//! done.
//!
//! The JSON number writer emits the shortest representation that parses
//! back to the same f64, so a resumed run replays the CG recurrence
//! bit-for-bit — the property `tests/fault_tolerance.rs` pins by killing
//! a streamed fit mid-CG and comparing against the uninterrupted model.

use crate::util::fault::{fingerprint_f64s, fingerprint_str, fingerprint_u64s, FaultError};
use crate::util::json::{self, Value};
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

use super::cg::CgState;
use super::estimator::{FitState, PrecondKind};

/// Sidecar format tag (bump on any incompatible layout change).
const FORMAT: &str = "falkon-checkpoint-v1";

/// Where and how often to checkpoint a fit.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CheckpointSpec {
    /// sidecar path (written atomically: tmp file + rename)
    pub path: PathBuf,
    /// snapshot every `every` CG iterations (0 disables writing)
    pub every: usize,
    /// load an existing compatible sidecar before solving
    pub resume: bool,
}

impl CheckpointSpec {
    pub fn new(path: impl Into<PathBuf>, every: usize, resume: bool) -> CheckpointSpec {
        CheckpointSpec {
            path: path.into(),
            every,
            resume,
        }
    }
}

/// Fingerprint of everything a CG trajectory depends on. Two prepared
/// states with equal fingerprints produce bitwise-identical CG
/// iterations, so a snapshot from one is valid for the other.
pub fn fingerprint(state: &FitState) -> u64 {
    let c = &state.config;
    let mut h = fingerprint_str(0xFA1C0, &format!("{:?}", c.kernel));
    h = fingerprint_f64s(h, &[c.sigma, c.lam, c.eps]);
    h = fingerprint_u64s(
        h,
        &[
            c.m as u64,
            c.seed,
            state.plan.n() as u64,
            match c.precond {
                PrecondKind::Chol => 0,
                PrecondKind::Eig => 1,
            },
            // the eig *fallback* also installs Q under PrecondKind::Chol,
            // so the actual factor shape is part of the identity
            state.q_factor.is_some() as u64,
        ],
    );
    h = fingerprint_f64s(h, &state.sel.c.data);
    h = fingerprint_f64s(h, &state.t_factor.data);
    h = fingerprint_f64s(h, &state.a_factor.data);
    if let Some(q) = &state.q_factor {
        h = fingerprint_f64s(h, &q.data);
    }
    h
}

fn nums(vals: &[f64]) -> Value {
    Value::Arr(vals.iter().map(|&v| Value::Num(v)).collect())
}

fn f64s(v: &Value, key: &str) -> Result<Vec<f64>> {
    v.get(key)
        .as_arr()
        .with_context(|| format!("checkpoint field '{key}' is not an array"))?
        .iter()
        .map(|x| {
            x.as_f64()
                .with_context(|| format!("checkpoint field '{key}' has a non-number entry"))
        })
        .collect()
}

/// Write a snapshot atomically (tmp + rename). Errors — including
/// non-finite state, which JSON cannot round-trip — are returned for the
/// caller to log; a failed snapshot must never kill the fit it protects.
pub fn save(path: &Path, fp: u64, s: &CgState) -> Result<()> {
    let finite = s.beta.iter().chain(&s.r).chain(&s.p).chain(&s.residuals);
    anyhow::ensure!(
        finite.clone().all(|v| v.is_finite()),
        "CG state holds non-finite values; skipping snapshot"
    );
    let v = Value::obj(vec![
        ("format", Value::str(FORMAT)),
        // hex string: u64 does not survive the f64 number type
        ("fingerprint", Value::str(format!("{fp:016x}"))),
        ("iters", Value::num(s.iters as f64)),
        ("beta", nums(&s.beta)),
        ("r", nums(&s.r)),
        ("p", nums(&s.p)),
        ("residuals", nums(&s.residuals)),
    ]);
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, v.to_string())
        .with_context(|| format!("writing checkpoint tmp {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming checkpoint into place at {}", path.display()))?;
    Ok(())
}

/// Load a snapshot for a run with fingerprint `fp`. `Ok(None)` when no
/// sidecar exists (fresh start); a corrupt or mismatched sidecar is a
/// **fatal** error — resuming from it would silently produce a model
/// from spliced trajectories, so the operator must delete it explicitly.
pub fn load(path: &Path, fp: u64) -> Result<Option<CgState>> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => {
            return Err(anyhow::Error::new(e)
                .context(format!("reading checkpoint {}", path.display())))
        }
    };
    let v = json::parse(&text).map_err(|e| {
        FaultError::fatal(format!(
            "checkpoint {} is corrupt ({e}); delete it to start fresh",
            path.display()
        ))
    })?;
    anyhow::ensure!(
        v.get("format").as_str() == Some(FORMAT),
        "checkpoint {} has unknown format {:?}; delete it to start fresh",
        path.display(),
        v.get("format").as_str()
    );
    let want = format!("{fp:016x}");
    let got = v.get("fingerprint").as_str().unwrap_or("");
    if got != want {
        return Err(FaultError::fatal(format!(
            "checkpoint {} was written by a different run \
             (fingerprint {got} != {want}); it cannot be resumed here — \
             delete it to start fresh",
            path.display()
        )));
    }
    let iters = v
        .get("iters")
        .as_usize()
        .context("checkpoint field 'iters' missing or invalid")?;
    let st = CgState {
        beta: f64s(&v, "beta")?,
        r: f64s(&v, "r")?,
        p: f64s(&v, "p")?,
        iters,
        residuals: f64s(&v, "residuals")?,
    };
    anyhow::ensure!(
        st.residuals.len() == st.iters,
        "checkpoint {} residual trace is inconsistent with its iteration count",
        path.display()
    );
    anyhow::ensure!(
        st.beta.len() == st.r.len() && st.r.len() == st.p.len(),
        "checkpoint {} state vectors have mismatched lengths",
        path.display()
    );
    Ok(Some(st))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("falkon_ckpt_{tag}_{}.json", std::process::id()))
    }

    fn state() -> CgState {
        CgState {
            beta: vec![0.125, -3.0, 1.0 / 3.0],
            r: vec![1e-300, 2.5e17, -0.75],
            p: vec![7.0, 0.0, 9.5e-8],
            iters: 2,
            residuals: vec![0.5, 0.25],
        }
    }

    #[test]
    fn roundtrip_is_bitwise_lossless() {
        let p = tmp("roundtrip");
        let s = state();
        save(&p, 0xDEAD_BEEF, &s).unwrap();
        let back = load(&p, 0xDEAD_BEEF).unwrap().unwrap();
        assert_eq!(
            s.beta.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            back.beta.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(
            s.r.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            back.r.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(s.p, back.p);
        assert_eq!(s.iters, back.iters);
        assert_eq!(s.residuals, back.residuals);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn missing_sidecar_is_a_fresh_start() {
        assert!(load(&tmp("missing_never_written"), 1).unwrap().is_none());
    }

    #[test]
    fn fingerprint_mismatch_is_fatal() {
        let p = tmp("mismatch");
        save(&p, 11, &state()).unwrap();
        let err = load(&p, 22).unwrap_err();
        assert_eq!(
            crate::util::fault::classify(&err),
            crate::util::fault::ErrorClass::Fatal
        );
        assert!(format!("{err:#}").contains("different run"), "{err:#}");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn corrupt_sidecar_is_fatal_with_advice() {
        let p = tmp("corrupt");
        std::fs::write(&p, "{not json").unwrap();
        let err = load(&p, 1).unwrap_err();
        assert!(format!("{err:#}").contains("delete it"), "{err:#}");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn non_finite_state_refuses_to_save() {
        let p = tmp("nonfinite");
        let mut s = state();
        s.r[0] = f64::NAN;
        assert!(save(&p, 1, &s).is_err());
        assert!(!p.exists(), "no partial sidecar may be left behind");
    }
}

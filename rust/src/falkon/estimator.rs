//! The FALKON estimator — the paper's Alg. 1/2 as a fit/predict API on top
//! of the engine: center selection → K_MM → preconditioner → blocked
//! preconditioned CG → Nyström coefficients.
//!
//! Multiclass problems (TIMIT/IMAGENET style) are trained one-vs-all with
//! the expensive per-fit state (centers, preconditioner, prepared matvec
//! plan) shared across the K subproblems — and the K right-hand sides are
//! solved **simultaneously** by [`super::cg::block_conjgrad`] over
//! [`crate::runtime::Bhb::apply_multi`], so every Kr panel of the O(nMt)
//! hot path is computed once per iteration instead of once per class
//! (DESIGN.md §Perf "Multi-RHS path"). The per-class loop survives as
//! [`fit_multiclass_looped`], the equivalence oracle the batched path is
//! benchmarked and tested against.

use crate::data::source::DataSource;
use crate::data::Dataset;
use crate::kernels::Kernel;
use crate::linalg::mat::Mat;
use crate::linalg::mat32::XBlock;
use crate::runtime::{Bhb, Engine, MatvecPlan};
use crate::util::rng::Rng;
use crate::util::timer::{Phases, Timer};
use anyhow::{Context, Result};

use super::centers::{Centers, SelectedCenters};
use super::cg::{
    block_conjgrad, conjgrad_resumable, BlockCgResult, CgOptions, CgResult, CgState, CgStop,
};
use super::checkpoint::CheckpointSpec;

/// One automatic step down the numerical degradation ladder — or a
/// recovery action — taken during a fit (DESIGN.md §Fault tolerance).
/// Every step is recorded in the [`FitReport`] so silent fallbacks are
/// auditable after the fact.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Degradation {
    /// the Cholesky preconditioner needed `rungs` jitter escalations
    /// (ε multiplied by 100 per rung) before factorizing
    JitterEscalation { rungs: usize },
    /// all jitter rungs failed; fell back to the rank-revealing eig
    /// preconditioner automatically
    EigFallback { reason: String },
    /// CG lost positive-definiteness and was warm-restarted from the
    /// best iterate after `at_iter` iterations
    CgWarmRestart { at_iter: usize },
    /// non-finite rows dropped by a skip-policy sanitizer during the
    /// streamed setup pass
    RowsSkipped { count: usize },
    /// the solve resumed from a checkpoint sidecar at `from_iter`
    Resumed { from_iter: usize },
}

impl std::fmt::Display for Degradation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Degradation::JitterEscalation { rungs } => {
                write!(f, "preconditioner needed {rungs} jitter escalation(s)")
            }
            Degradation::EigFallback { reason } => {
                write!(f, "fell back to eig preconditioner: {reason}")
            }
            Degradation::CgWarmRestart { at_iter } => {
                write!(f, "CG warm-restarted after iteration {at_iter} (lost PD)")
            }
            Degradation::RowsSkipped { count } => {
                write!(f, "skipped {count} non-finite row(s) per pass")
            }
            Degradation::Resumed { from_iter } => {
                write!(f, "resumed from checkpoint at iteration {from_iter}")
            }
        }
    }
}

/// Audit trail of a fit: every degradation-ladder step and recovery
/// action that happened, in order. A clean fit has an empty report.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FitReport {
    pub events: Vec<Degradation>,
}

impl FitReport {
    /// Record (and log) one event.
    pub fn record(&mut self, d: Degradation) {
        eprintln!("[falkon] degradation: {d}");
        self.events.push(d);
    }

    /// True iff the fit took no degradation/recovery steps.
    pub fn is_clean(&self) -> bool {
        self.events.is_empty()
    }

    /// Human-readable event lines for CLI/report output.
    pub fn lines(&self) -> Vec<String> {
        self.events.iter().map(|d| d.to_string()).collect()
    }
}

/// Which preconditioner factorization to use (Sect. A of the paper).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PrecondKind {
    /// Cholesky of K_MM + εMI (Alg. 1 / Example 1; the fast default)
    #[default]
    Chol,
    /// rank-revealing eigendecomposition (Example 2) — handles exactly
    /// singular K_MM without jitter; coordinator-side f64, O(M³)
    Eig,
}

/// Hyperparameters for one FALKON fit (paper notation).
#[derive(Debug, Clone)]
pub struct FalkonConfig {
    pub kernel: Kernel,
    /// kernel width σ (ignored by the linear kernel)
    pub sigma: f64,
    /// ridge parameter λ
    pub lam: f64,
    /// number of Nyström centers M
    pub m: usize,
    /// CG iterations t (the paper's log n regime: ~10-20)
    pub t: usize,
    /// center-selection strategy
    pub centers: Centers,
    /// jitter scale for chol(K_MM + eps·M·I)
    pub eps: f64,
    /// optional early-exit tolerance on the CG residual (0 = fixed t)
    pub tol: f64,
    /// preconditioner factorization route
    pub precond: PrecondKind,
    /// subtract mean(y) before solving and add it back at predict time
    /// (recommended for regression with offset targets; the expansion has
    /// no intercept term)
    pub center_y: bool,
    pub seed: u64,
    /// optional CG checkpoint/resume sidecar (`train --checkpoint`);
    /// None = no snapshots, never resumed
    pub checkpoint: Option<CheckpointSpec>,
}

impl Default for FalkonConfig {
    fn default() -> Self {
        FalkonConfig {
            kernel: Kernel::Gaussian,
            sigma: 1.0,
            lam: 1e-6,
            m: 1024,
            t: 20,
            centers: Centers::Uniform,
            eps: 1e-7,
            tol: 0.0,
            precond: PrecondKind::default(),
            center_y: true,
            seed: 0,
            checkpoint: None,
        }
    }
}

impl FalkonConfig {
    /// The paper's Thm. 3 defaults for a given n: λ = 1/√n,
    /// M = √n·log n (capped at n), t ≈ log n + 5.
    pub fn theoretical(n: usize) -> FalkonConfig {
        let nf = n as f64;
        FalkonConfig {
            lam: 1.0 / nf.sqrt(),
            m: ((nf.sqrt() * nf.ln()).ceil() as usize).min(n),
            t: (0.5 * nf.ln()).ceil() as usize + 5,
            ..Default::default()
        }
    }
}

/// A fitted model: Nyström coefficients over the selected centers.
#[derive(Debug, Clone)]
pub struct FalkonModel {
    pub config: FalkonConfig,
    pub centers: Mat,
    pub alpha: Vec<f64>,
    /// target mean removed before the solve and added back at predict
    /// time — the kernel expansion has no intercept, so offset targets
    /// (e.g. MillionSongs years) would otherwise be shrunk toward 0 and
    /// cost f32 precision in the artifacts
    pub y_offset: f64,
    /// per-phase wall-clock of the fit
    pub phases: Phases,
    pub cg_iters: usize,
    pub cg_residuals: Vec<f64>,
    /// why CG stopped (LostPd means the operator went numerically
    /// indefinite and the best iterate was kept — also logged at fit time)
    pub cg_stop: CgStop,
    /// audit trail of automatic degradation/recovery steps
    pub report: FitReport,
}

impl FalkonModel {
    /// Predict f(x_i) = y_offset + Σ_j α_j K(x_i, c_j) for each row of x.
    pub fn predict(&self, engine: &Engine, x: &Mat) -> Result<Vec<f64>> {
        let mut p = engine.predict(
            self.config.kernel,
            x,
            &self.centers,
            &self.alpha,
            self.config.sigma,
        )?;
        if self.y_offset != 0.0 {
            for v in &mut p {
                *v += self.y_offset;
            }
        }
        Ok(p)
    }

    /// [`FalkonModel::predict`] over a dtype-tagged row block: f64 blocks
    /// take the exact path, f32 blocks the mixed-precision panel tier
    /// (error within [`crate::kernels::tol::predict_bound`]). This is the
    /// per-chunk entry point of the bulk serving sweep, where the stream
    /// may yield either storage dtype.
    pub fn predict_block(&self, engine: &Engine, x: &XBlock) -> Result<Vec<f64>> {
        let mut p = engine.predict_block(
            self.config.kernel,
            x,
            &self.centers,
            &self.alpha,
            self.config.sigma,
        )?;
        if self.y_offset != 0.0 {
            for v in &mut p {
                *v += self.y_offset;
            }
        }
        Ok(p)
    }

    /// Streaming [`FalkonModel::predict`]: sweep a chunked
    /// [`DataSource`] once, so a larger-than-RAM dataset is scored with
    /// O(chunk) resident features
    /// ([`crate::serve::predict_source`] additionally returns the
    /// streamed targets for evaluation).
    pub fn predict_source(
        &self,
        engine: &Engine,
        source: &mut dyn DataSource,
    ) -> Result<Vec<f64>> {
        let mut p = engine.predict_source(
            self.config.kernel,
            source,
            &self.centers,
            &self.alpha,
            self.config.sigma,
        )?;
        if self.y_offset != 0.0 {
            for v in &mut p {
                *v += self.y_offset;
            }
        }
        Ok(p)
    }
}

/// Multiclass model: one-vs-all coefficient vectors over shared centers.
#[derive(Debug, Clone)]
pub struct FalkonMulticlass {
    pub config: FalkonConfig,
    pub centers: Mat,
    pub alphas: Vec<Vec<f64>>,
    pub phases: Phases,
    /// CG iterations executed per class (all equal to `t` when no
    /// tolerance is set; per-column early exit otherwise)
    pub cg_iters: Vec<usize>,
    /// per-class stop reason from the block CG
    pub cg_stops: Vec<CgStop>,
    /// audit trail of automatic degradation/recovery steps
    pub report: FitReport,
}

impl FalkonMulticlass {
    /// The K coefficient vectors stacked as the columns of an `M×K`
    /// block — the input shape of the batched predict path.
    pub fn alphas_mat(&self) -> Mat {
        let m = self.centers.rows;
        let k = self.alphas.len();
        let mut a = Mat::zeros(m, k);
        for (kc, alpha) in self.alphas.iter().enumerate() {
            a.set_col(kc, alpha);
        }
        a
    }

    /// Per-class scores as an `n×K` block (row i = all class scores of
    /// x_i), computed by the batched multi-output predict: one kernel
    /// panel per row tile serves every class.
    pub fn scores_mat(&self, engine: &Engine, x: &Mat) -> Result<Mat> {
        engine.predict_multi(
            self.config.kernel,
            x,
            &self.centers,
            &self.alphas_mat(),
            self.config.sigma,
        )
    }

    /// Per-class scores; scores[k][i] = f_k(x_i).
    pub fn scores(&self, engine: &Engine, x: &Mat) -> Result<Vec<Vec<f64>>> {
        let sm = self.scores_mat(engine, x)?;
        Ok((0..sm.cols).map(|kc| sm.col(kc)).collect())
    }

    /// Argmax class prediction per row (batched across classes).
    /// `total_cmp` keeps the argmax panic-free on NaN scores.
    pub fn predict_class(&self, engine: &Engine, x: &Mat) -> Result<Vec<usize>> {
        let sm = self.scores_mat(engine, x)?;
        Ok((0..sm.rows)
            .map(|i| {
                let row = sm.row(i);
                (0..row.len())
                    .max_by(|&a, &b| row[a].total_cmp(&row[b]))
                    .unwrap()
            })
            .collect())
    }
}

/// Per-fit shared state (exposed so benches can probe the operator). The
/// plan owns its sliced row blocks and worker pool, so the state no longer
/// borrows the training matrix.
pub struct FitState {
    pub sel: SelectedCenters,
    pub t_factor: Mat,
    pub a_factor: Mat,
    /// partial isometry from the eig preconditioner (None = chol path)
    pub q_factor: Option<Mat>,
    pub plan: MatvecPlan,
    pub phases: Phases,
    pub config: FalkonConfig,
    /// degradation/recovery events accumulated across prepare and solve
    pub report: FitReport,
}

impl FitState {
    pub fn bhb(&self) -> Bhb<'_> {
        Bhb {
            plan: &self.plan,
            t: &self.t_factor,
            a: &self.a_factor,
            lam: self.config.lam,
            d: self.sel.d_weights.as_deref(),
            q: self.q_factor.as_ref(),
        }
    }
}

/// Factor the preconditioner through the numerical degradation ladder
/// (DESIGN.md §Fault tolerance): the configured route first — Chol with
/// its built-in jitter escalation — and, if every jitter rung fails, an
/// automatic fallback to the rank-revealing eig factorization, which
/// handles exactly singular/indefinite K_MM. Each rung taken and the
/// fallback itself are recorded in `report`. Returns `(T, A, Q)` with
/// `Q = None` on the plain Cholesky path.
pub fn setup_precond(
    engine: &Engine,
    kmm: &Mat,
    config: &FalkonConfig,
    report: &mut FitReport,
) -> Result<(Mat, Mat, Option<Mat>)> {
    match config.precond {
        PrecondKind::Eig => {
            let (t, a, q) = super::precond::precond_eig(kmm, config.lam, config.eps)?;
            Ok((t, a, Some(q)))
        }
        PrecondKind::Chol => match engine.precond_traced(kmm, config.lam, config.eps) {
            Ok((t, a, rungs)) => {
                if rungs > 0 {
                    report.record(Degradation::JitterEscalation { rungs });
                }
                Ok((t, a, None))
            }
            Err(err) => {
                report.record(Degradation::EigFallback {
                    reason: format!("{err:#}"),
                });
                let (t, a, q) = super::precond::precond_eig(kmm, config.lam, config.eps)
                    .context("eig fallback after the jittered Cholesky ladder failed")?;
                Ok((t, a, Some(q)))
            }
        },
    }
}

/// Build everything up to (but not including) the CG solve: centers,
/// K_MM (+ D weighting), preconditioner factors, prepared matvec plan.
pub fn prepare(engine: &Engine, x: &Mat, config: &FalkonConfig) -> Result<FitState> {
    let mut phases = Phases::new();
    let mut report = FitReport::default();
    let mut rng = Rng::new(config.seed);

    let sel = phases.time("centers", || {
        config.centers.select(
            engine,
            x,
            config.kernel,
            config.sigma,
            config.lam,
            config.m,
            &mut rng,
        )
    })?;

    let (t_factor, a_factor, q_factor) =
        phases.time("precond", || -> Result<(Mat, Mat, Option<Mat>)> {
            let mut kmm = engine.kmm(config.kernel, &sel.c, config.sigma)?;
            if let Some(d) = &sel.d_weights {
                kmm.scale_sym_diag(d); // K_MM -> D K_MM D (Def. 3)
            }
            setup_precond(engine, &kmm, config, &mut report)
        })?;

    let plan = phases.time("plan", || {
        engine.matvec_plan(config.kernel, x, &sel.c, config.sigma)
    })?;

    Ok(FitState {
        sel,
        t_factor,
        a_factor,
        q_factor,
        plan,
        phases,
        config: config.clone(),
        report,
    })
}

/// Out-of-core [`prepare`]: build the fit state from a chunked
/// [`DataSource`] without ever materializing the `n×d` matrix. One
/// streaming pass selects the Nyström centers and collects the targets
/// (features are O(chunk) resident; the targets are O(n) — 8 bytes/row,
/// the same budget the paper's O(n) memory claim carries); K_MM and the
/// preconditioner then run on the M×M state as usual, and the returned
/// plan re-streams the source on every CG iteration
/// (DESIGN.md § "Out-of-core path").
///
/// Center selection runs via [`Centers::select_source`]: sources that
/// know their length (`len_hint`) make the **same rng draws as the
/// in-memory fit** at equal seed — uniform indices gathered during the
/// pass, or leverage scores streamed through the chunked sketch
/// (`lscores::sketch_source`) and fed to the same `sample_by_scores`
/// draw — so a streamed fit reproduces the in-memory fit (bit-for-bit
/// for uniform, ≤1e-8 for leverage where the Gram accumulation order
/// differs); unknown-length sources fall back to reservoir sampling
/// ([`super::centers::Reservoir`] uniform,
/// [`super::centers::WeightedReservoir`] score-proportional).
///
/// Returns the prepared state plus the collected targets.
pub fn prepare_source(
    engine: &Engine,
    mut source: Box<dyn DataSource>,
    config: &FalkonConfig,
) -> Result<(FitState, Vec<f64>)> {
    anyhow::ensure!(
        source.n_classes() <= 2,
        "streaming fits support regression/binary targets ({}-class source); \
         multiclass one-vs-all needs the in-memory fit",
        source.n_classes()
    );
    let mut phases = Phases::new();
    let mut report = FitReport::default();
    let mut rng = Rng::new(config.seed);
    let d = source.d();
    anyhow::ensure!(d > 0, "source has no features");

    let mut y: Vec<f64> = Vec::new();
    let sel = phases.time("centers", || -> Result<SelectedCenters> {
        config.centers.select_source(
            engine,
            source.as_mut(),
            config.kernel,
            config.sigma,
            config.lam,
            config.m,
            &mut rng,
            &mut y,
        )
    })?;
    let n = y.len();
    let skipped = source.skipped_rows();
    if skipped > 0 {
        report.record(Degradation::RowsSkipped { count: skipped });
    }

    let (t_factor, a_factor, q_factor) =
        phases.time("precond", || -> Result<(Mat, Mat, Option<Mat>)> {
            let mut kmm = engine.kmm(config.kernel, &sel.c, config.sigma)?;
            if let Some(dw) = &sel.d_weights {
                kmm.scale_sym_diag(dw); // K_MM -> D K_MM D (Def. 3)
            }
            setup_precond(engine, &kmm, config, &mut report)
        })?;

    let plan = phases.time("plan", || {
        engine.matvec_plan_source(config.kernel, source, &sel.c, config.sigma, n)
    })?;

    Ok((
        FitState {
            sel,
            t_factor,
            a_factor,
            q_factor,
            plan,
            phases,
            config: config.clone(),
            report,
        },
        y,
    ))
}

/// Solve one right-hand side on a prepared state, returning the Nyström
/// coefficients plus the full CG outcome (iterations, residual trace,
/// stop reason). `on_iter` (if given) receives (iteration, α at that
/// iteration) — used by convergence studies; computing α per iteration
/// costs two O(M²) solves.
pub fn solve(
    state: &mut FitState,
    y: &[f64],
    mut on_iter: Option<&mut dyn FnMut(usize, &[f64])>,
) -> Result<(Vec<f64>, CgResult)> {
    let config = state.config.clone();
    let ckpt = config.checkpoint.clone();
    // fingerprint before borrowing the operator pieces: it binds any
    // sidecar to this exact trajectory (kernel, hyperparameters, centers,
    // preconditioner factors)
    let fp = ckpt.as_ref().map(|_| super::checkpoint::fingerprint(state));
    let mut events: Vec<Degradation> = Vec::new();
    let bhb = Bhb {
        plan: &state.plan,
        t: &state.t_factor,
        a: &state.a_factor,
        lam: config.lam,
        d: state.sel.d_weights.as_deref(),
        q: state.q_factor.as_ref(),
    };
    let timer = Timer::start();
    let bhb = &bhb;
    let r = bhb.rhs(y).context("building rhs")?;

    let mut init: Option<CgState> = None;
    if let (Some(c), Some(fpv)) = (&ckpt, fp) {
        if c.resume {
            if let Some(st) = super::checkpoint::load(&c.path, fpv)
                .context("loading checkpoint for resume")?
            {
                events.push(Degradation::Resumed { from_iter: st.iters });
                init = Some(st);
            }
        }
    }

    let mut alpha_cb = on_iter.as_deref_mut().map(|cb| {
        move |k: usize, beta: &[f64]| {
            let alpha = bhb.beta_to_alpha(beta);
            cb(k, &alpha);
        }
    });
    // periodic sidecar writer: a failed write is logged, never fatal —
    // the checkpoint protects the fit, not the other way round
    let mut snap = ckpt.as_ref().filter(|c| c.every > 0).map(|c| {
        let path = c.path.clone();
        let every = c.every;
        let fpv = fp.unwrap_or(0);
        move |s: &CgState| {
            if s.iters % every == 0 {
                if let Err(e) = super::checkpoint::save(&path, fpv, s) {
                    eprintln!("[falkon] checkpoint write failed (fit continues): {e:#}");
                }
            }
        }
    });
    let opts = CgOptions {
        t_max: config.t,
        tol: config.tol,
    };
    let cb: Option<&mut dyn FnMut(usize, &[f64])> = match alpha_cb.as_mut() {
        Some(f) => Some(f),
        None => None,
    };
    let sn: Option<&mut dyn FnMut(&CgState)> = match snap.as_mut() {
        Some(f) => Some(f),
        None => None,
    };
    let mut cg = conjgrad_resumable(&mut |p| bhb.apply(p), &r, opts, init, cb, sn)?;

    // degradation ladder, CG rung: a LostPd exit means ⟨p, Wp⟩ went
    // non-positive — the Fletcher–Reeves direction is poisoned, but the
    // best iterate is still valid. Discard the direction and warm-restart
    // steepest-descent (p = true residual at β) from that iterate.
    let mut restarts = 0usize;
    while cg.stop == CgStop::LostPd && restarts < 2 && cg.iters < config.t {
        let before = cg.iters;
        let w = bhb.apply(&cg.beta)?;
        let r2: Vec<f64> = r.iter().zip(&w).map(|(bi, wi)| bi - wi).collect();
        events.push(Degradation::CgWarmRestart { at_iter: before });
        let st = CgState {
            beta: cg.beta.clone(),
            r: r2.clone(),
            p: r2,
            iters: before,
            residuals: cg.residuals.clone(),
        };
        let cb: Option<&mut dyn FnMut(usize, &[f64])> = match alpha_cb.as_mut() {
            Some(f) => Some(f),
            None => None,
        };
        let sn: Option<&mut dyn FnMut(&CgState)> = match snap.as_mut() {
            Some(f) => Some(f),
            None => None,
        };
        cg = conjgrad_resumable(&mut |p| bhb.apply(p), &r, opts, Some(st), cb, sn)?;
        restarts += 1;
        if cg.iters == before {
            break; // no progress even from a fresh direction: genuinely indefinite
        }
    }
    if cg.stop == CgStop::LostPd {
        // don't drop the stop reason on the floor: a LostPd exit means the
        // preconditioned operator went numerically indefinite and the
        // returned α is the best iterate, not a converged solution
        eprintln!(
            "[falkon] CG stopped after {} iteration(s): {} \
             (operator lost positive-definiteness; keeping best iterate)",
            cg.iters,
            cg.stop.name()
        );
    }
    let alpha = bhb.beta_to_alpha(&cg.beta);
    if let Some(c) = &ckpt {
        // the solve completed — a stale sidecar would only confuse (or be
        // rejected by) a later run
        let _ = std::fs::remove_file(&c.path);
    }
    state.phases.add("cg", timer.elapsed_s());
    for e in events {
        state.report.record(e);
    }
    Ok((alpha, cg))
}

/// Solve K right-hand sides simultaneously on a prepared state: one
/// [`block_conjgrad`] run over [`Bhb::apply_multi`], so each CG iteration
/// pays a single pass over the kernel panels for all K columns. `y` is
/// `n×K` (column k = targets of subproblem k); returns the `M×K`
/// coefficient block and the per-column CG outcome.
pub fn solve_multi(state: &mut FitState, y: &Mat) -> Result<(Mat, BlockCgResult)> {
    let config = state.config.clone();
    anyhow::ensure!(y.rows == state.plan.n(), "y rows {} != n {}", y.rows, state.plan.n());
    let bhb = Bhb {
        plan: &state.plan,
        t: &state.t_factor,
        a: &state.a_factor,
        lam: config.lam,
        d: state.sel.d_weights.as_deref(),
        q: state.q_factor.as_ref(),
    };
    let timer = Timer::start();
    let r = bhb.rhs_multi(y).context("building multi-RHS")?;
    let cg = block_conjgrad(
        |p| bhb.apply_multi(p),
        &r,
        CgOptions {
            t_max: config.t,
            tol: config.tol,
        },
    )?;
    for (kc, &stop) in cg.stops.iter().enumerate() {
        if stop == CgStop::LostPd {
            eprintln!(
                "[falkon] block CG column {kc} stopped after {} iteration(s): {} \
                 (operator lost positive-definiteness; keeping best iterate)",
                cg.iters[kc],
                stop.name()
            );
        }
    }
    let alphas = bhb.beta_to_alpha_multi(&cg.beta);
    state.phases.add("cg", timer.elapsed_s());
    Ok((alphas, cg))
}

/// Fit FALKON on a regression / binary (-1, +1) problem.
///
/// ```
/// use falkon::data::synth;
/// use falkon::falkon::{fit, FalkonConfig};
/// use falkon::runtime::Engine;
/// use falkon::util::rng::Rng;
///
/// let mut rng = Rng::new(1);
/// let data = synth::smooth_regression(&mut rng, 400, 3, 0.05);
/// let engine = Engine::rust();
/// let config = FalkonConfig { sigma: 1.5, lam: 1e-4, m: 48, t: 10, ..Default::default() };
/// let model = fit(&engine, &data.x, &data.y, &config).unwrap();
/// let preds = model.predict(&engine, &data.x).unwrap();
/// let mse = falkon::metrics::mse(&preds, &data.y);
/// let var = falkon::linalg::vec_ops::variance(&data.y);
/// assert!(mse < 0.5 * var, "mse {mse} vs var {var}");
/// ```
pub fn fit(engine: &Engine, x: &Mat, y: &[f64], config: &FalkonConfig) -> Result<FalkonModel> {
    fit_with_callback(engine, x, y, config, None)
}

/// Fit with a per-CG-iteration callback receiving (iter, α). Note the
/// callback's α solves the *centered* problem (targets y − mean(y));
/// manual predictions from it must add `FalkonModel::y_offset` back.
pub fn fit_with_callback(
    engine: &Engine,
    x: &Mat,
    y: &[f64],
    config: &FalkonConfig,
    on_iter: Option<&mut dyn FnMut(usize, &[f64])>,
) -> Result<FalkonModel> {
    anyhow::ensure!(x.rows == y.len(), "x rows {} != y len {}", x.rows, y.len());
    let mut state = prepare(engine, x, config)?;
    let y_offset = if config.center_y {
        crate::linalg::vec_ops::mean(y)
    } else {
        0.0
    };
    let yc: Vec<f64> = y.iter().map(|v| v - y_offset).collect();
    let (alpha, cg) = solve(&mut state, &yc, on_iter)?;
    Ok(FalkonModel {
        config: config.clone(),
        centers: state.sel.c,
        alpha,
        y_offset,
        phases: state.phases,
        cg_iters: cg.iters,
        cg_residuals: cg.residuals,
        cg_stop: cg.stop,
        report: state.report,
    })
}

/// Out-of-core fit: FALKON from a chunked [`DataSource`], so a dataset
/// larger than RAM streams through training with O(M² + chunk) working
/// memory for features (targets stay O(n); see [`prepare_source`]).
/// Regression and ±1 binary labels ride the `y` channel.
///
/// For a source with a known length this is **bit-identical** to the
/// in-memory [`fit`] on the same data, seed and (serial) engine — the
/// end-to-end property the out-of-core tests pin. Leverage-score center
/// selection ([`Centers::ApproxLeverage`]) streams too: the pilot/Gram/
/// scoring passes run chunked with O(sketch² + chunk) working memory
/// (see [`crate::falkon::lscores::approx_leverage_scores_source`]).
///
/// ```
/// use falkon::data::{synth, MemSource};
/// use falkon::falkon::{fit_source, FalkonConfig};
/// use falkon::runtime::Engine;
/// use falkon::util::rng::Rng;
///
/// let mut rng = Rng::new(0);
/// let data = synth::smooth_regression(&mut rng, 300, 3, 0.05);
/// let x = data.x.clone();
/// let y = data.y.clone();
/// // 64-row chunks: only ~64×3 feature values resident per sweep
/// let source = Box::new(MemSource::new(data, 64));
/// let engine = Engine::rust();
/// let config = FalkonConfig { sigma: 1.5, lam: 1e-4, m: 40, t: 10, ..Default::default() };
/// let model = fit_source(&engine, source, &config).unwrap();
/// let preds = model.predict(&engine, &x).unwrap();
/// let mse = falkon::metrics::mse(&preds, &y);
/// assert!(mse < falkon::linalg::vec_ops::variance(&y));
/// ```
pub fn fit_source(
    engine: &Engine,
    source: Box<dyn DataSource>,
    config: &FalkonConfig,
) -> Result<FalkonModel> {
    let (mut state, y) = prepare_source(engine, source, config)?;
    let y_offset = if config.center_y {
        crate::linalg::vec_ops::mean(&y)
    } else {
        0.0
    };
    let yc: Vec<f64> = y.iter().map(|v| v - y_offset).collect();
    let (alpha, cg) = solve(&mut state, &yc, None)?;
    Ok(FalkonModel {
        config: config.clone(),
        centers: state.sel.c,
        alpha,
        y_offset,
        phases: state.phases,
        cg_iters: cg.iters,
        cg_residuals: cg.residuals,
        cg_stop: cg.stop,
        report: state.report,
    })
}

/// One-vs-all targets stacked as an `n×K` block (column k =
/// `label_targets(k)`), the input shape of [`solve_multi`].
fn target_block(data: &Dataset) -> Mat {
    let n = data.n();
    let k = data.n_classes;
    let mut y = Mat::zeros(n, k);
    for kc in 0..k {
        y.set_col(kc, &data.label_targets(kc));
    }
    y
}

/// One-vs-all multiclass fit sharing centers/preconditioner/plan, with
/// all K subproblems solved **simultaneously**: one block CG whose per
/// iteration cost is a single multi-RHS pass over the kernel panels
/// (DESIGN.md §Perf "Multi-RHS path") instead of K vector passes.
pub fn fit_multiclass(
    engine: &Engine,
    data: &Dataset,
    config: &FalkonConfig,
) -> Result<FalkonMulticlass> {
    anyhow::ensure!(data.is_multiclass(), "dataset is not multiclass");
    let mut state = prepare(engine, &data.x, config)?;
    let y = target_block(data);
    let (alphas_mat, cg) = solve_multi(&mut state, &y)?;
    let alphas: Vec<Vec<f64>> = (0..data.n_classes).map(|kc| alphas_mat.col(kc)).collect();
    Ok(FalkonMulticlass {
        config: config.clone(),
        centers: state.sel.c,
        alphas,
        phases: state.phases,
        cg_iters: cg.iters,
        cg_stops: cg.stops,
        report: state.report,
    })
}

/// The pre-batching one-vs-all loop: one vector CG per class over the
/// shared plan, recomputing every Kr panel K times per iteration. Kept as
/// the equivalence oracle and the baseline the multiclass bench reports
/// its batched-vs-looped speedup against.
pub fn fit_multiclass_looped(
    engine: &Engine,
    data: &Dataset,
    config: &FalkonConfig,
) -> Result<FalkonMulticlass> {
    anyhow::ensure!(data.is_multiclass(), "dataset is not multiclass");
    let mut state = prepare(engine, &data.x, config)?;
    let mut alphas = Vec::with_capacity(data.n_classes);
    let mut cg_iters = Vec::with_capacity(data.n_classes);
    let mut cg_stops = Vec::with_capacity(data.n_classes);
    for k in 0..data.n_classes {
        let yk = data.label_targets(k);
        let (alpha, cg) = solve(&mut state, &yk, None)?;
        alphas.push(alpha);
        cg_iters.push(cg.iters);
        cg_stops.push(cg.stop);
    }
    Ok(FalkonMulticlass {
        config: config.clone(),
        centers: state.sel.c,
        alphas,
        phases: state.phases,
        cg_iters,
        cg_stops,
        report: state.report,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::metrics;

    fn small_config(m: usize, t: usize) -> FalkonConfig {
        FalkonConfig {
            sigma: 2.0,
            lam: 1e-4,
            m,
            t,
            seed: 7,
            ..Default::default()
        }
    }

    #[test]
    fn learns_smooth_regression() {
        let mut rng = Rng::new(1);
        let data = synth::smooth_regression(&mut rng, 800, 4, 0.05);
        let (train, test) = data.split(0.25, &mut rng);
        let eng = Engine::rust();
        let model = fit(&eng, &train.x, &train.y, &small_config(120, 15)).unwrap();
        let preds = model.predict(&eng, &test.x).unwrap();
        let err = metrics::mse(&preds, &test.y);
        let var = crate::linalg::vec_ops::variance(&test.y);
        assert!(err < 0.35 * var, "mse {err} vs var {var}");
    }

    #[test]
    fn converges_to_exact_nystrom_solution() {
        // Lemma 5: FALKON → exact Nyström estimator as t grows.
        let mut rng = Rng::new(2);
        let data = synth::smooth_regression(&mut rng, 300, 3, 0.05);
        let eng = Engine::rust();
        let cfg = FalkonConfig {
            sigma: 1.5,
            lam: 1e-3,
            m: 40,
            t: 60,
            seed: 3,
            eps: 1e-12, // f64 engine: keep the jitter's O(epsM/lam) bias tiny
            center_y: false, // reference below solves the uncentered system
            ..Default::default()
        };
        let model = fit(&eng, &data.x, &data.y, &cfg).unwrap();

        // exact Nyström (Eq. 8) with the same centers
        let mut rng2 = Rng::new(3);
        let idx = rng2.choose(data.x.rows, 40);
        let c = data.x.select_rows(&idx);
        assert_eq!(c.data, model.centers.data, "same seed -> same centers");
        let knm = crate::kernels::kernel_block(Kernel::Gaussian, &data.x, &c, 1.5);
        let kmm = crate::kernels::kmm(Kernel::Gaussian, &c, 1.5);
        let mut h = crate::linalg::gemm::matmul(&knm.t(), &knm);
        for i in 0..40 {
            for j in 0..40 {
                h[(i, j)] += cfg.lam * data.x.rows as f64 * kmm[(i, j)];
            }
        }
        h.add_diag(1e-10);
        let z = crate::linalg::gemm::matvec_t(&knm, &data.y);
        let alpha_exact = crate::linalg::chol::solve_spd(&h, &z).unwrap();
        // compare in prediction space
        let p1 = crate::kernels::predict(Kernel::Gaussian, &data.x, &c, &model.alpha, 1.5);
        let p2 = crate::kernels::predict(Kernel::Gaussian, &data.x, &c, &alpha_exact, 1.5);
        let rel = crate::linalg::vec_ops::rel_diff(&p1, &p2);
        assert!(rel < 1e-5, "rel {rel}");
    }

    #[test]
    fn early_stopping_tolerance() {
        let mut rng = Rng::new(4);
        let data = synth::smooth_regression(&mut rng, 400, 3, 0.05);
        let eng = Engine::rust();
        let mut cfg = small_config(60, 200);
        cfg.lam = 1.0 / (400f64).sqrt(); // preconditioned regime
        cfg.tol = 1e-8;
        let model = fit(&eng, &data.x, &data.y, &cfg).unwrap();
        assert!(model.cg_iters < 60, "cg took {}", model.cg_iters);
    }

    #[test]
    fn callback_traces_iterations() {
        let mut rng = Rng::new(5);
        let data = synth::smooth_regression(&mut rng, 200, 3, 0.05);
        let eng = Engine::rust();
        let mut iters = Vec::new();
        let mut cb = |k: usize, alpha: &[f64]| {
            assert_eq!(alpha.len(), 30);
            iters.push(k);
        };
        let cfg = small_config(30, 7);
        fit_with_callback(&eng, &data.x, &data.y, &cfg, Some(&mut cb)).unwrap();
        assert_eq!(iters, (1..=7).collect::<Vec<_>>());
    }

    #[test]
    fn multiclass_shares_centers() {
        // separable 5-class problem in d=10 — exercises the shared
        // centers/precond/plan machinery (the timit/imagenet analogues'
        // difficulty is asserted at scale in the table benches)
        let mut rng = Rng::new(6);
        let k = 5;
        let n = 900;
        let d = 10;
        let mut x = crate::linalg::mat::Mat::zeros(n, d);
        let mut labels = vec![0usize; n];
        let centers = crate::linalg::mat::Mat::from_vec(k, d, rng.normals(k * d));
        for i in 0..n {
            let c = rng.below(k);
            labels[i] = c;
            for j in 0..d {
                x[(i, j)] = 3.0 * centers[(c, j)] + 0.8 * rng.normal();
            }
        }
        let data = crate::data::Dataset::new_multiclass("mc", x, labels, k);
        let (train, test) = data.split(0.25, &mut rng);
        let eng = Engine::rust();
        let cfg = FalkonConfig {
            sigma: 4.0,
            lam: 1e-5,
            m: 80,
            t: 12,
            seed: 8,
            ..Default::default()
        };
        let model = fit_multiclass(&eng, &train, &cfg).unwrap();
        assert_eq!(model.alphas.len(), k);
        let pred = model.predict_class(&eng, &test.x).unwrap();
        let labels = test.labels.as_ref().unwrap();
        let err = pred
            .iter()
            .zip(labels)
            .filter(|(p, l)| p != l)
            .count() as f64
            / pred.len() as f64;
        assert!(err < 0.05, "c-err {err} on separable classes");
    }

    /// Separable k-class blob problem shared by the multiclass tests.
    fn blob_dataset(seed: u64, n: usize, d: usize, k: usize) -> crate::data::Dataset {
        synth::blobs(&mut Rng::new(seed), n, d, k)
    }

    #[test]
    fn batched_multiclass_matches_looped() {
        // the batched block-CG fit must reproduce the per-class loop's
        // coefficients (same shared state, same recurrences — only the
        // panel amortization differs) to well below prediction noise
        let data = blob_dataset(16, 600, 6, 4);
        let (train, test) = data.split(0.25, &mut Rng::new(17));
        let eng = Engine::rust();
        let cfg = FalkonConfig {
            sigma: 4.0,
            lam: 1e-5,
            m: 60,
            t: 12,
            seed: 8,
            ..Default::default()
        };
        let batched = fit_multiclass(&eng, &train, &cfg).unwrap();
        let looped = fit_multiclass_looped(&eng, &train, &cfg).unwrap();
        assert_eq!(batched.alphas.len(), looped.alphas.len());
        assert_eq!(batched.centers.data, looped.centers.data);
        assert_eq!(batched.cg_iters, looped.cg_iters);
        // predictions agree far inside the acceptance budget (1e-8)
        let sb = batched.scores_mat(&eng, &test.x).unwrap();
        let sl = looped.scores_mat(&eng, &test.x).unwrap();
        let diff = sb.max_abs_diff(&sl);
        assert!(diff < 1e-8, "batched vs looped score diff {diff}");
        assert_eq!(
            batched.predict_class(&eng, &test.x).unwrap(),
            looped.predict_class(&eng, &test.x).unwrap()
        );
    }

    #[test]
    fn batched_multiclass_matches_looped_pooled_engine() {
        // same contract through the worker pool (pooled apply_multi)
        let data = blob_dataset(26, 900, 5, 3);
        let eng = Engine::rust_with(crate::runtime::EngineOptions {
            workers: 4,
            ..Default::default()
        });
        let cfg = FalkonConfig {
            sigma: 4.0,
            lam: 1e-5,
            m: 48,
            t: 10,
            seed: 4,
            ..Default::default()
        };
        let batched = fit_multiclass(&eng, &data, &cfg).unwrap();
        let looped = fit_multiclass_looped(&eng, &data, &cfg).unwrap();
        let sb = batched.scores_mat(&eng, &data.x).unwrap();
        let sl = looped.scores_mat(&eng, &data.x).unwrap();
        assert!(sb.max_abs_diff(&sl) < 1e-8);
    }

    #[test]
    fn multiclass_tolerance_freezes_columns_independently() {
        // with an early-exit tolerance each column may stop at its own
        // iteration; every column must report a Converged stop and an
        // iteration count within budget
        let data = blob_dataset(36, 700, 5, 4);
        let eng = Engine::rust();
        let cfg = FalkonConfig {
            sigma: 4.0,
            lam: 1.0 / (700f64).sqrt(),
            m: 64,
            t: 200,
            tol: 1e-8,
            seed: 5,
            ..Default::default()
        };
        let model = fit_multiclass(&eng, &data, &cfg).unwrap();
        for (kc, (&iters, &stop)) in model.cg_iters.iter().zip(&model.cg_stops).enumerate() {
            assert!(iters < 64, "col {kc} took {iters}");
            assert_eq!(stop, crate::falkon::CgStop::Converged, "col {kc}");
        }
    }

    #[test]
    fn leverage_scores_path_runs() {
        let mut rng = Rng::new(7);
        let data = synth::low_effective_dim(&mut rng, 500, 10, 3);
        let eng = Engine::rust();
        let cfg = FalkonConfig {
            sigma: 1.0,
            lam: 1e-3,
            m: 50,
            t: 15,
            centers: Centers::ApproxLeverage { sketch: 64 },
            seed: 9,
            ..Default::default()
        };
        let model = fit(&eng, &data.x, &data.y, &cfg).unwrap();
        let preds = model.predict(&eng, &data.x).unwrap();
        let err = metrics::mse(&preds, &data.y);
        let var = crate::linalg::vec_ops::variance(&data.y);
        assert!(err < 0.5 * var, "mse {err} var {var}");
    }

    #[test]
    fn theoretical_config_scales() {
        let c = FalkonConfig::theoretical(10_000);
        assert!((c.lam - 0.01).abs() < 1e-12);
        assert!(c.m >= 900 && c.m <= 1000, "{}", c.m);
        assert!(c.t >= 9 && c.t <= 11);
    }

    // -- out-of-core fits ----------------------------------------------

    use crate::data::source::{Chunk, DataSource, MemSource};

    #[test]
    fn streaming_fit_is_bitwise_equal_to_in_memory_fit() {
        // known-length source + equal seed => same center indices, same
        // per-row accumulation order => identical model (serial engine)
        let mut rng = Rng::new(41);
        let data = synth::smooth_regression(&mut rng, 1700, 5, 0.05);
        let eng = Engine::rust();
        let cfg = small_config(48, 12);
        let mem = fit(&eng, &data.x, &data.y, &cfg).unwrap();
        for chunk_rows in [300usize, 1024] {
            let src = Box::new(MemSource::new(data.clone(), chunk_rows));
            let ooc = crate::falkon::fit_source(&eng, src, &cfg).unwrap();
            assert_eq!(ooc.centers.data, mem.centers.data, "chunk {chunk_rows}");
            assert_eq!(ooc.alpha, mem.alpha, "chunk {chunk_rows}");
            assert_eq!(ooc.y_offset, mem.y_offset);
            assert_eq!(ooc.cg_iters, mem.cg_iters);
        }
    }

    #[test]
    fn streaming_fit_pooled_close_to_in_memory() {
        let mut rng = Rng::new(42);
        let data = synth::smooth_regression(&mut rng, 1400, 4, 0.05);
        let eng = Engine::rust_with(crate::runtime::EngineOptions {
            workers: 4,
            ..Default::default()
        });
        let cfg = small_config(40, 10);
        let mem = fit(&eng, &data.x, &data.y, &cfg).unwrap();
        let src = Box::new(MemSource::new(data.clone(), 250));
        let ooc = crate::falkon::fit_source(&eng, src, &cfg).unwrap();
        assert_eq!(ooc.centers.data, mem.centers.data);
        let pm = mem.predict(&eng, &data.x).unwrap();
        let po = ooc.predict(&eng, &data.x).unwrap();
        let diff = crate::linalg::vec_ops::max_abs_diff(&pm, &po);
        assert!(diff < 1e-8, "pooled streaming vs in-memory: {diff}");
    }

    /// Test double: a source that hides its length, forcing the
    /// reservoir-sampling selection path.
    struct HiddenLen(MemSource);

    impl DataSource for HiddenLen {
        fn d(&self) -> usize {
            self.0.d()
        }
        fn len_hint(&self) -> Option<usize> {
            None
        }
        fn reset(&mut self) -> anyhow::Result<()> {
            self.0.reset()
        }
        fn next_chunk(&mut self) -> anyhow::Result<Option<Chunk>> {
            self.0.next_chunk()
        }
        fn chunk_rows(&self) -> usize {
            self.0.chunk_rows()
        }
    }

    #[test]
    fn unknown_length_source_fits_via_reservoir() {
        let mut rng = Rng::new(43);
        let data = synth::smooth_regression(&mut rng, 900, 4, 0.05);
        let eng = Engine::rust();
        let cfg = small_config(48, 12);
        let src = Box::new(HiddenLen(MemSource::new(data.clone(), 128)));
        let model = crate::falkon::fit_source(&eng, src, &cfg).unwrap();
        assert_eq!(model.centers.rows, 48);
        let preds = model.predict(&eng, &data.x).unwrap();
        let err = metrics::mse(&preds, &data.y);
        let var = crate::linalg::vec_ops::variance(&data.y);
        assert!(err < 0.35 * var, "mse {err} vs var {var}");
    }

    #[test]
    fn f32_storage_fit_matches_f64_fit_accuracy() {
        // e2e mixed-precision: a fit whose row blocks (in-memory plan)
        // or chunks (streamed source) are stored as f32 must reproduce
        // the f64 fit's held-out RMSE. Storage rounding perturbs each
        // kernel entry by ~eps32 relative; through the regularized,
        // preconditioned solve that stays orders of magnitude below the
        // noise floor, so the two RMSEs agree to ~1% with generous slack.
        use crate::linalg::mat32::Dtype;
        let mut rng = Rng::new(45);
        let data = synth::smooth_regression(&mut rng, 1500, 5, 0.05);
        let (train, test) = data.split(0.25, &mut rng);
        let eng64 = Engine::rust();
        let eng32 = Engine::rust_with(crate::runtime::EngineOptions {
            dtype: Dtype::F32,
            ..Default::default()
        });
        let cfg = small_config(64, 15);
        let m64 = fit(&eng64, &train.x, &train.y, &cfg).unwrap();
        let m32 = fit(&eng32, &train.x, &train.y, &cfg).unwrap();
        // same seed => identical center selection; only the plan's block
        // storage differs (centers are f64 coordinator state)
        assert_eq!(m64.centers.data, m32.centers.data);
        assert_eq!(m64.cg_iters, m32.cg_iters, "fixed t: same iteration count");
        let r64 = metrics::rmse(&m64.predict(&eng64, &test.x).unwrap(), &test.y);
        let r32 = metrics::rmse(&m32.predict(&eng32, &test.x).unwrap(), &test.y);
        assert!(
            (r32 - r64).abs() <= 0.01 * r64 + 1e-3,
            "f32 fit RMSE {r32} vs f64 {r64}"
        );
        // both beat the same quality bar the f64 path is held to
        let var = crate::linalg::vec_ops::variance(&test.y);
        assert!(r32 * r32 < 0.35 * var, "mse {} vs var {var}", r32 * r32);

        // streamed f32 storage (4-byte resident chunks) lands in the
        // same place
        let src = Box::new(MemSource::with_dtype(train.clone(), 300, Dtype::F32));
        let ooc = crate::falkon::fit_source(&eng32, src, &cfg).unwrap();
        // the gather copies center rows out of rounded f32 chunks, so the
        // streamed centers are the f64 centers rounded once (same rows)
        assert_eq!(ooc.centers.rows, m64.centers.rows);
        for (a, b) in ooc.centers.data.iter().zip(&m64.centers.data) {
            assert_eq!(*a, (*b as f32) as f64, "center rows rounded exactly once");
        }
        let ro = metrics::rmse(&ooc.predict(&eng32, &test.x).unwrap(), &test.y);
        assert!(
            (ro - r64).abs() <= 0.01 * r64 + 1e-3,
            "streamed f32 fit RMSE {ro} vs f64 {r64}"
        );
    }

    #[test]
    fn streaming_fit_leverage_matches_in_memory() {
        // known-length source + equal seed => same pilot draw, same
        // sample_by_scores draw => same centers and Def. 2 weights; only
        // the Gram accumulation order differs across chunkings, so the
        // models agree to <=1e-8 (bitwise when one chunk covers the set)
        let mut rng = Rng::new(44);
        let data = synth::smooth_regression(&mut rng, 600, 4, 0.05);
        let eng = Engine::rust();
        let cfg = FalkonConfig {
            centers: Centers::ApproxLeverage { sketch: 96 },
            ..small_config(32, 10)
        };
        let mem = fit(&eng, &data.x, &data.y, &cfg).unwrap();
        for chunk_rows in [128usize, 600, 2048] {
            let src = Box::new(MemSource::new(data.clone(), chunk_rows));
            let ooc = crate::falkon::fit_source(&eng, src, &cfg).unwrap();
            assert_eq!(
                ooc.centers.data, mem.centers.data,
                "chunk {chunk_rows}: same draws => same center rows"
            );
            let pm = mem.predict(&eng, &data.x).unwrap();
            let po = ooc.predict(&eng, &data.x).unwrap();
            let diff = crate::linalg::vec_ops::max_abs_diff(&pm, &po);
            assert!(diff <= 1e-8, "chunk {chunk_rows}: streamed leverage vs in-memory {diff}");
        }
    }

    #[test]
    fn unknown_length_source_fits_via_weighted_reservoir() {
        // no len_hint => the scores feed the A-Res weighted reservoir;
        // the model must still carry Def. 2 weights and learn the task
        let mut rng = Rng::new(46);
        let data = synth::smooth_regression(&mut rng, 900, 4, 0.05);
        let eng = Engine::rust();
        let cfg = FalkonConfig {
            centers: Centers::ApproxLeverage { sketch: 64 },
            ..small_config(48, 12)
        };
        let src = Box::new(HiddenLen(MemSource::new(data.clone(), 128)));
        let (state, y) = prepare_source(&eng, src, &cfg).unwrap();
        assert_eq!(state.sel.c.rows, 48);
        assert_eq!(y.len(), 900);
        let dw = state.sel.d_weights.as_ref().expect("leverage => weights");
        assert_eq!(dw.len(), 48);
        assert!(dw.iter().all(|v| v.is_finite() && *v > 0.0));
        assert!(state.sel.scores.is_none(), "unknown length holds no O(n) scores");
        let src = Box::new(HiddenLen(MemSource::new(data.clone(), 128)));
        let model = crate::falkon::fit_source(&eng, src, &cfg).unwrap();
        let preds = model.predict(&eng, &data.x).unwrap();
        let err = metrics::mse(&preds, &data.y);
        let var = crate::linalg::vec_ops::variance(&data.y);
        assert!(err < 0.35 * var, "mse {err} vs var {var}");
    }
}

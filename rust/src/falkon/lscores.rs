//! Approximate ridge leverage scores (Def. 1, Sect. 4.2).
//!
//! We estimate l_i(λ) = (K_nn (K_nn + λnI)⁻¹)_ii with the standard
//! Nyström sketch: a uniform pilot subset J (|J| = j) defines the feature
//! map Φ = K_nJ T_J⁻¹ (T_JᵀT_J = K_JJ, so ΦΦᵀ = K_nJ K_JJ⁻¹ K_Jn ≈ K_nn),
//! and the scores of the approximated kernel are
//!
//! ```text
//! l̂_i(λ) = φ_iᵀ (ΦᵀΦ + λn I)⁻¹ φ_i
//! ```
//!
//! This is the [12, 30]-style q-approximation the paper's Thm. 4-5 accept.
//! Data is touched only through kernel blocks (the engine streams them via
//! the same `kernel_block` artifacts as prediction), in two passes so the
//! coordinator never holds more than O(block·j) state.

use crate::kernels::Kernel;
use crate::linalg::mat::Mat;
use crate::linalg::{chol, tri};
use crate::runtime::Engine;
use crate::util::rng::Rng;
use anyhow::{Context, Result};

/// Estimate approximate leverage scores at level `lam` using a uniform
/// pilot sketch of `sketch` points. Returns one score per training row.
pub fn approx_leverage_scores(
    engine: &Engine,
    x: &Mat,
    kern: Kernel,
    sigma: f64,
    lam: f64,
    sketch: usize,
    rng: &mut Rng,
) -> Result<Vec<f64>> {
    let n = x.rows;
    let j = sketch.min(n);
    let mu = lam * n as f64;

    // pilot subset and its factor
    let jdx = rng.choose(n, j);
    let cj = x.select_rows(&jdx);
    let kjj = engine.kmm(kern, &cj, sigma).context("lscores: K_JJ")?;
    let (tj, _) = engine
        .precond(&kjj, 1.0, 1e-9) // reuse the jittered chol path; A unused
        .context("lscores: chol(K_JJ)")?;

    // pass 1: G = ΦᵀΦ + μI accumulated over row blocks
    let block = 2048usize;
    let mut g = Mat::zeros(j, j);
    let mut start = 0;
    while start < n {
        let end = (start + block).min(n);
        let xb = x.slice_rows(start, end);
        let knj = engine.kernel_block(kern, &xb, &cj, sigma)?;
        // φ_i = T_Jᵀ \ k_i for each row
        for i in 0..knj.rows {
            let phi = tri::solve_lower_t(&tj, knj.row(i));
            for a in 0..j {
                if phi[a] == 0.0 {
                    continue;
                }
                let grow = g.row_mut(a);
                for b in 0..j {
                    grow[b] += phi[a] * phi[b];
                }
            }
        }
        start = end;
    }
    g.add_diag(mu);
    let gr = chol::cholesky_upper(&g).context("lscores: chol(G)")?;

    // pass 2: l̂_i = ‖G^{-1/2} φ_i‖² = ‖gr^{-T} φ_i‖²
    let mut scores = vec![0.0f64; n];
    let mut start = 0;
    while start < n {
        let end = (start + block).min(n);
        let xb = x.slice_rows(start, end);
        let knj = engine.kernel_block(kern, &xb, &cj, sigma)?;
        for i in 0..knj.rows {
            let phi = tri::solve_lower_t(&tj, knj.row(i));
            let z = tri::solve_lower_t(&gr, &phi);
            scores[start + i] = crate::linalg::vec_ops::dot(&z, &z).max(1e-300);
        }
        start = end;
    }
    Ok(scores)
}

/// Exact ridge leverage scores by dense factorization — O(n³), test/bench
/// oracle only.
pub fn exact_leverage_scores(
    x: &Mat,
    kern: Kernel,
    sigma: f64,
    lam: f64,
) -> Result<Vec<f64>> {
    let n = x.rows;
    let knn = crate::kernels::kernel_block(kern, x, x, sigma);
    let mut kl = knn.clone();
    kl.add_diag(lam * n as f64);
    // columns of (K + λnI)⁻¹ K
    let sol = chol::solve_spd_mat(&kl, &knn)?;
    Ok((0..n).map(|i| sol[(i, i)].max(0.0)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A design where a few points sit far from the bulk: their leverage
    /// scores must be large relative to bulk points.
    fn spiky_design(rng: &mut Rng, n: usize) -> Mat {
        let mut x = Mat::zeros(n, 3);
        for i in 0..n {
            let row = x.row_mut(i);
            if i < 5 {
                for v in row.iter_mut() {
                    *v = 10.0 + rng.normal(); // outliers
                }
            } else {
                for v in row.iter_mut() {
                    *v = 0.3 * rng.normal(); // bulk
                }
            }
        }
        x
    }

    #[test]
    fn exact_scores_in_unit_interval_and_sum_to_dof() {
        let mut rng = Rng::new(1);
        let x = Mat::from_vec(30, 2, rng.normals(60));
        let s = exact_leverage_scores(&x, Kernel::Gaussian, 1.0, 1e-2).unwrap();
        for &v in &s {
            assert!((0.0..=1.0 + 1e-9).contains(&v), "{v}");
        }
        // sum = effective dimension, strictly between 0 and n
        let dof: f64 = s.iter().sum();
        assert!(dof > 0.5 && dof < 30.0, "{dof}");
    }

    #[test]
    fn approx_tracks_exact_on_spiky_design() {
        let mut rng = Rng::new(2);
        let n = 120;
        let x = spiky_design(&mut rng, n);
        let lam = 1e-3;
        let exact = exact_leverage_scores(&x, Kernel::Gaussian, 1.0, lam).unwrap();
        let eng = Engine::rust();
        let approx =
            approx_leverage_scores(&eng, &x, Kernel::Gaussian, 1.0, lam, 60, &mut rng).unwrap();
        // outliers should rank in the top scores under both
        let mut rank: Vec<usize> = (0..n).collect();
        rank.sort_by(|&a, &b| approx[b].partial_cmp(&approx[a]).unwrap());
        let top: Vec<usize> = rank[..10].to_vec();
        // a uniform pilot can miss an outlier direction entirely (its
        // approximate score is then underestimated); most must still rank top
        let outliers_in_top = (0..5).filter(|i| top.contains(i)).count();
        assert!(outliers_in_top >= 3, "top10 {top:?}");
        // and the q-approximation factor should be moderate on the *bulk*
        // (outlier directions absent from the pilot have no guarantee)
        let mut max_q: f64 = 0.0;
        for i in 5..n {
            if exact[i] > 1e-6 {
                let q = (approx[i] / exact[i]).max(exact[i] / approx[i]);
                max_q = max_q.max(q);
            }
        }
        assert!(max_q < 25.0, "bulk q-factor {max_q}");
    }

    #[test]
    fn full_sketch_matches_exact() {
        // with J = all points, the Nyström approximation is exact
        let mut rng = Rng::new(3);
        let n = 40;
        let x = Mat::from_vec(n, 2, rng.normals(2 * n));
        let lam = 1e-2;
        let exact = exact_leverage_scores(&x, Kernel::Gaussian, 1.0, lam).unwrap();
        let eng = Engine::rust();
        let approx =
            approx_leverage_scores(&eng, &x, Kernel::Gaussian, 1.0, lam, n, &mut rng).unwrap();
        for i in 0..n {
            assert!(
                (approx[i] - exact[i]).abs() < 2e-2 * exact[i].max(0.05),
                "i={i}: {} vs {}",
                approx[i],
                exact[i]
            );
        }
    }
}

//! Approximate ridge leverage scores (Def. 1, Sect. 4.2).
//!
//! We estimate l_i(λ) = (K_nn (K_nn + λnI)⁻¹)_ii with the standard
//! Nyström sketch: a uniform pilot subset J (|J| = j) defines the feature
//! map Φ = K_nJ T_J⁻¹ (T_JᵀT_J = K_JJ, so ΦΦᵀ = K_nJ K_JJ⁻¹ K_Jn ≈ K_nn),
//! and the scores of the approximated kernel are
//!
//! ```text
//! l̂_i(λ) = φ_iᵀ (ΦᵀΦ + λn I)⁻¹ φ_i
//! ```
//!
//! This is the [12, 30]-style q-approximation the paper's Thm. 4-5 accept.
//! Data is touched only through kernel blocks, with all per-block math on
//! matrix panels ([`SketchState`]): K_nJ panels from the engine's pooled
//! kernel-block path (or the mixed-precision tier for f32 chunks), a
//! multi-RHS TRSM for Φᵀ, and a pooled SYRK for the Gram accumulation —
//! so the coordinator never holds more than O(block·j + j²) state and the
//! same core serves the in-memory matrix and any rewindable
//! [`DataSource`] ([`approx_leverage_scores_source`]).

use crate::data::source::DataSource;
use crate::kernels::Kernel;
use crate::linalg::mat::Mat;
use crate::linalg::mat32::{MatF32, XBlock};
use crate::linalg::{chol, gemm, tri};
use crate::runtime::Engine;
use crate::util::rng::Rng;
use anyhow::{Context, Result};

use super::centers::{CenterGather, Reservoir};

/// Row-block budget of the in-memory scoring passes (the streamed passes
/// use the source's own chunk size instead).
const SCORE_BLOCK: usize = 2048;

/// Resolve the CLI `--sketch` convention: 0 means "as many pilot columns
/// as centers M" (the cheapest sketch the Thm. 4-5 bounds accept).
pub fn effective_sketch(sketch: usize, m: usize) -> usize {
    if sketch == 0 {
        m
    } else {
        sketch
    }
}

/// The factored Nyström sketch behind the leverage-score estimate — the
/// shared per-block core of the in-memory and streamed pipelines.
///
/// Built from the pilot rows C_J, it accumulates G = ΦᵀΦ over row blocks
/// ([`SketchState::accumulate`]), factors G + μI once
/// ([`SketchState::factor`]), then scores any row block
/// ([`SketchState::score_block`]). Every pooled stage (kernel panels,
/// SYRK) sums partials in job order, so pooled results are bitwise equal
/// to serial; the TRSMs are serial coordinator math. Blocks in f32
/// storage take the mixed-precision panel tier against a once-rounded
/// copy of the pilot.
pub struct SketchState {
    kern: Kernel,
    param: f64,
    /// ridge level μ = λ·n added to G before factoring
    mu: f64,
    cj: Mat,
    /// rounded-once f32 tier of the pilot (f32 chunks only)
    cj32: MatF32,
    /// T_JᵀT_J = K_JJ (+ jitter)
    tj: Mat,
    g: Mat,
    /// upper Cholesky factor of G + μI (set by [`SketchState::factor`])
    gr: Option<Mat>,
}

impl SketchState {
    /// Factor the pilot block: K_JJ via the engine's pooled `kmm`, then
    /// the jittered Cholesky path (`A` unused at λ=1).
    pub fn new(engine: &Engine, cj: Mat, kern: Kernel, sigma: f64, mu: f64) -> Result<SketchState> {
        anyhow::ensure!(cj.rows > 0, "lscores: empty pilot sketch");
        let kjj = engine.kmm(kern, &cj, sigma).context("lscores: K_JJ")?;
        let (tj, _) = engine
            .precond(&kjj, 1.0, 1e-9) // reuse the jittered chol path; A unused
            .context("lscores: chol(K_JJ)")?;
        let cj32 = MatF32::from_mat(&cj);
        let j = cj.rows;
        Ok(SketchState {
            kern,
            param: sigma,
            mu,
            cj,
            cj32,
            tj,
            g: Mat::zeros(j, j),
            gr: None,
        })
    }

    /// Pilot size j = |J|.
    pub fn j(&self) -> usize {
        self.cj.rows
    }

    /// Φᵀ panel of a row block: column i = φ_i = T_Jᵀ \ k(x_i, C_J).
    /// The kernel panel takes the dtype-matching tier; each output column
    /// depends only on its own row, so the panel is invariant to how the
    /// stream is chunked.
    fn phi_t(&self, engine: &Engine, x: &XBlock) -> Result<Mat> {
        let knj = match x {
            XBlock::F64(xm) => engine.kernel_block(self.kern, xm, &self.cj, self.param)?,
            XBlock::F32(xm) => {
                crate::kernels::mixed::kernel_block_f32(self.kern, xm, &self.cj32, self.param)
                    .to_mat()
            }
        };
        Ok(tri::solve_lower_t_mat(&self.tj, &knj.t()))
    }

    /// Accumulate one row block into G += ΦᵀΦ (pooled SYRK over the Φᵀ
    /// panel).
    pub fn accumulate(&mut self, engine: &Engine, x: &XBlock) -> Result<()> {
        anyhow::ensure!(self.gr.is_none(), "lscores: accumulate after factor");
        if x.rows() == 0 {
            return Ok(());
        }
        let phi_t = self.phi_t(engine, x)?;
        let part = gemm::syrk_t_par(&phi_t, engine.pool());
        self.g.add(&part);
        Ok(())
    }

    /// Factor G + μI after the accumulation pass.
    pub fn factor(&mut self) -> Result<()> {
        anyhow::ensure!(self.gr.is_none(), "lscores: factor called twice");
        self.g.add_diag(self.mu);
        self.gr = Some(chol::cholesky_upper(&self.g).context("lscores: chol(G)")?);
        Ok(())
    }

    /// Score one row block: l̂_i = ‖gr^{-T} φ_i‖², floored at 1e-300 so a
    /// numerically-zero score still defines a sampling probability.
    pub fn score_block(&self, engine: &Engine, x: &XBlock) -> Result<Vec<f64>> {
        let gr = self
            .gr
            .as_ref()
            .context("lscores: score_block before factor")?;
        let rows = x.rows();
        if rows == 0 {
            return Ok(Vec::new());
        }
        let phi_t = self.phi_t(engine, x)?;
        let z = tri::solve_lower_t_mat(gr, &phi_t);
        // column squared norms accumulated in fixed a = 0..j order
        let mut scores = vec![0.0f64; rows];
        for a in 0..z.rows {
            for (s, &v) in scores.iter_mut().zip(z.row(a)) {
                *s += v * v;
            }
        }
        for s in &mut scores {
            *s = s.max(1e-300);
        }
        Ok(scores)
    }
}

/// Estimate approximate leverage scores at level `lam` using a uniform
/// pilot sketch of `sketch` points. Returns one score per training row.
pub fn approx_leverage_scores(
    engine: &Engine,
    x: &Mat,
    kern: Kernel,
    sigma: f64,
    lam: f64,
    sketch: usize,
    rng: &mut Rng,
) -> Result<Vec<f64>> {
    let n = x.rows;
    let j = sketch.min(n);
    let mu = lam * n as f64;

    // pilot subset and its factor
    let jdx = rng.choose(n, j);
    let cj = x.select_rows(&jdx);
    let mut sk = SketchState::new(engine, cj, kern, sigma, mu)?;

    // pass 1: G = ΦᵀΦ accumulated over row blocks
    let mut start = 0;
    while start < n {
        let end = (start + SCORE_BLOCK).min(n);
        sk.accumulate(engine, &XBlock::F64(x.slice_rows(start, end)))?;
        start = end;
    }
    sk.factor()?;

    // pass 2: l̂_i = ‖G^{-1/2} φ_i‖² = ‖gr^{-T} φ_i‖²
    let mut scores = Vec::with_capacity(n);
    let mut start = 0;
    while start < n {
        let end = (start + SCORE_BLOCK).min(n);
        scores.extend(sk.score_block(engine, &XBlock::F64(x.slice_rows(start, end)))?);
        start = end;
    }
    Ok(scores)
}

/// Pilot + Gram passes over a rewindable source: pass 0 draws the uniform
/// pilot — `CenterGather` over the *same* `rng.choose(n, j)` draw the
/// in-memory path makes for a known-length source, [`Reservoir`]
/// otherwise — and (optionally) collects the targets; pass 1 accumulates
/// G = ΦᵀΦ chunk by chunk and factors G + μI. Both passes run under the
/// engine's [`crate::util::fault::RetryPolicy`]. Returns the factored
/// sketch plus the stream length.
#[allow(clippy::too_many_arguments)]
pub(crate) fn sketch_source(
    engine: &Engine,
    source: &mut dyn DataSource,
    kern: Kernel,
    sigma: f64,
    lam: f64,
    sketch: usize,
    rng: &mut Rng,
    mut y_out: Option<&mut Vec<f64>>,
) -> Result<(SketchState, usize)> {
    let retry = engine.opts().retry;
    let d = source.d();
    anyhow::ensure!(d > 0, "source has no features");
    anyhow::ensure!(sketch > 0, "lscores: sketch must be > 0");

    // pass 0: uniform pilot (+ target collection)
    retry.run("lscores pilot: reset", || source.reset())?;
    let (cj, n) = match source.len_hint() {
        Some(n) => {
            anyhow::ensure!(n > 0, "source is empty");
            // same draw as the in-memory approx_leverage_scores
            let jdx = rng.choose(n, sketch.min(n));
            let mut gather = CenterGather::new(&jdx, d);
            let mut seen = 0usize;
            while let Some(chunk) =
                retry.run("lscores pilot: next_chunk", || source.next_chunk())?
            {
                anyhow::ensure!(chunk.start == seen, "source chunks must be contiguous");
                seen += chunk.x.rows();
                gather.offer_block(chunk.start, &chunk.x);
                if let Some(y) = y_out.as_deref_mut() {
                    y.extend_from_slice(&chunk.y);
                }
            }
            anyhow::ensure!(seen == n, "source yielded {seen} rows, len_hint said {n}");
            (gather.finish()?, n)
        }
        None => {
            let mut res = Reservoir::new(sketch, d);
            let mut seen = 0usize;
            let mut row = vec![0.0f64; d];
            while let Some(chunk) =
                retry.run("lscores pilot: next_chunk", || source.next_chunk())?
            {
                anyhow::ensure!(chunk.start == seen, "source chunks must be contiguous");
                let rows = chunk.x.rows();
                seen += rows;
                for i in 0..rows {
                    chunk.x.row_f64_into(i, &mut row);
                    res.push(&row, rng);
                }
                if let Some(y) = y_out.as_deref_mut() {
                    y.extend_from_slice(&chunk.y);
                }
            }
            anyhow::ensure!(seen > 0, "source is empty");
            let (c, _) = res.finish();
            (c, seen)
        }
    };

    // pass 1: G = ΦᵀΦ
    let mut sk = SketchState::new(engine, cj, kern, sigma, lam * n as f64)?;
    retry.run("lscores gram: reset", || source.reset())?;
    let mut seen = 0usize;
    while let Some(chunk) = retry.run("lscores gram: next_chunk", || source.next_chunk())? {
        anyhow::ensure!(chunk.start == seen, "source chunks must be contiguous");
        seen += chunk.x.rows();
        sk.accumulate(engine, &chunk.x)?;
    }
    anyhow::ensure!(seen == n, "source yielded {seen} rows in the Gram pass, expected {n}");
    sk.factor()?;
    Ok((sk, n))
}

/// Streamed [`approx_leverage_scores`]: the same estimate over any
/// rewindable [`DataSource`] in three chunked passes (pilot, Gram,
/// scoring) with O(sketch² + chunk) working memory — the scores
/// themselves are O(n), the same budget as the targets. For a
/// known-length source at equal seed this reproduces the in-memory
/// scores up to chunk-boundary summation (≤1e-8; the property tests pin
/// it), because the pilot draw consumes the rng identically and every
/// per-row panel/TRSM column is invariant to the chunking.
pub fn approx_leverage_scores_source(
    engine: &Engine,
    source: &mut dyn DataSource,
    kern: Kernel,
    sigma: f64,
    lam: f64,
    sketch: usize,
    rng: &mut Rng,
) -> Result<Vec<f64>> {
    let (sk, n) = sketch_source(engine, source, kern, sigma, lam, sketch, rng, None)?;
    let retry = engine.opts().retry;
    retry.run("lscores score: reset", || source.reset())?;
    let mut scores = Vec::with_capacity(n);
    while let Some(chunk) = retry.run("lscores score: next_chunk", || source.next_chunk())? {
        anyhow::ensure!(chunk.start == scores.len(), "source chunks must be contiguous");
        scores.extend(sk.score_block(engine, &chunk.x)?);
    }
    anyhow::ensure!(
        scores.len() == n,
        "source yielded {} rows in the scoring pass, expected {n}",
        scores.len()
    );
    Ok(scores)
}

/// Exact ridge leverage scores by dense factorization — O(n³), test/bench
/// oracle only.
pub fn exact_leverage_scores(
    x: &Mat,
    kern: Kernel,
    sigma: f64,
    lam: f64,
) -> Result<Vec<f64>> {
    let n = x.rows;
    let knn = crate::kernels::kernel_block(kern, x, x, sigma);
    let mut kl = knn.clone();
    kl.add_diag(lam * n as f64);
    // columns of (K + λnI)⁻¹ K
    let sol = chol::solve_spd_mat(&kl, &knn)?;
    Ok((0..n).map(|i| sol[(i, i)].max(0.0)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::source::MemSource;
    use crate::data::{synth, Dataset};
    use crate::linalg::mat32::Dtype;
    use crate::linalg::vec_ops::max_abs_diff;
    use crate::runtime::EngineOptions;

    /// A design where a few points sit far from the bulk: their leverage
    /// scores must be large relative to bulk points.
    fn spiky_design(rng: &mut Rng, n: usize) -> Mat {
        let mut x = Mat::zeros(n, 3);
        for i in 0..n {
            let row = x.row_mut(i);
            if i < 5 {
                for v in row.iter_mut() {
                    *v = 10.0 + rng.normal(); // outliers
                }
            } else {
                for v in row.iter_mut() {
                    *v = 0.3 * rng.normal(); // bulk
                }
            }
        }
        x
    }

    #[test]
    fn exact_scores_in_unit_interval_and_sum_to_dof() {
        let mut rng = Rng::new(1);
        let x = Mat::from_vec(30, 2, rng.normals(60));
        let s = exact_leverage_scores(&x, Kernel::Gaussian, 1.0, 1e-2).unwrap();
        for &v in &s {
            assert!((0.0..=1.0 + 1e-9).contains(&v), "{v}");
        }
        // sum = effective dimension, strictly between 0 and n
        let dof: f64 = s.iter().sum();
        assert!(dof > 0.5 && dof < 30.0, "{dof}");
    }

    #[test]
    fn approx_tracks_exact_on_spiky_design() {
        let mut rng = Rng::new(2);
        let n = 120;
        let x = spiky_design(&mut rng, n);
        let lam = 1e-3;
        let exact = exact_leverage_scores(&x, Kernel::Gaussian, 1.0, lam).unwrap();
        let eng = Engine::rust();
        let approx =
            approx_leverage_scores(&eng, &x, Kernel::Gaussian, 1.0, lam, 60, &mut rng).unwrap();
        // outliers should rank in the top scores under both
        let mut rank: Vec<usize> = (0..n).collect();
        rank.sort_by(|&a, &b| approx[b].partial_cmp(&approx[a]).unwrap());
        let top: Vec<usize> = rank[..10].to_vec();
        // a uniform pilot can miss an outlier direction entirely (its
        // approximate score is then underestimated); most must still rank top
        let outliers_in_top = (0..5).filter(|i| top.contains(i)).count();
        assert!(outliers_in_top >= 3, "top10 {top:?}");
        // and the q-approximation factor should be moderate on the *bulk*
        // (outlier directions absent from the pilot have no guarantee)
        let mut max_q: f64 = 0.0;
        for i in 5..n {
            if exact[i] > 1e-6 {
                let q = (approx[i] / exact[i]).max(exact[i] / approx[i]);
                max_q = max_q.max(q);
            }
        }
        assert!(max_q < 25.0, "bulk q-factor {max_q}");
    }

    #[test]
    fn full_sketch_matches_exact() {
        // with J = all points, the Nyström approximation is exact
        let mut rng = Rng::new(3);
        let n = 40;
        let x = Mat::from_vec(n, 2, rng.normals(2 * n));
        let lam = 1e-2;
        let exact = exact_leverage_scores(&x, Kernel::Gaussian, 1.0, lam).unwrap();
        let eng = Engine::rust();
        let approx =
            approx_leverage_scores(&eng, &x, Kernel::Gaussian, 1.0, lam, n, &mut rng).unwrap();
        for i in 0..n {
            assert!(
                (approx[i] - exact[i]).abs() < 2e-2 * exact[i].max(0.05),
                "i={i}: {} vs {}",
                approx[i],
                exact[i]
            );
        }
    }

    #[test]
    fn effective_sketch_defaults_to_m() {
        assert_eq!(effective_sketch(0, 256), 256);
        assert_eq!(effective_sketch(128, 256), 128);
        assert_eq!(effective_sketch(512, 64), 512);
    }

    /// Shared fixture of the streamed-vs-in-memory property battery.
    fn battery_data(n: usize) -> Dataset {
        let mut rng = Rng::new(20);
        synth::smooth_regression(&mut rng, n, 4, 0.05)
    }

    #[test]
    fn streamed_scores_match_in_memory_across_ragged_chunkings() {
        // the satellite contract: streamed == in-memory to ≤1e-8 at equal
        // seed, for chunk ≪ n through chunk > n (ragged boundaries)
        let n = 350;
        let data = battery_data(n);
        let (kern, sigma, lam, sketch, seed) = (Kernel::Gaussian, 1.0, 1e-3, 64, 5u64);
        let eng = Engine::rust();
        let mem = approx_leverage_scores(
            &eng,
            &data.x,
            kern,
            sigma,
            lam,
            sketch,
            &mut Rng::new(seed),
        )
        .unwrap();
        assert_eq!(mem.len(), n);
        for chunk_rows in [17usize, 100, 350, 1000] {
            let mut src = MemSource::new(data.clone(), chunk_rows);
            let streamed = approx_leverage_scores_source(
                &eng,
                &mut src,
                kern,
                sigma,
                lam,
                sketch,
                &mut Rng::new(seed),
            )
            .unwrap();
            assert_eq!(streamed.len(), n);
            let diff = max_abs_diff(&mem, &streamed);
            assert!(diff <= 1e-8, "chunk {chunk_rows}: streamed vs in-memory {diff}");
        }
    }

    #[test]
    fn streamed_scores_f32_consistent_across_chunkings_and_track_f64() {
        // f32 chunks: ragged chunkings must agree with the chunk > n f32
        // stream to ≤1e-8 (the dtype's own whole-stream oracle) and the
        // whole f32 estimate must track the f64 scores (storage rounding
        // + f32 exponential only perturb at the mixed-precision tier)
        let n = 350;
        let data = battery_data(n);
        let (kern, sigma, lam, sketch, seed) = (Kernel::Gaussian, 1.0, 1e-3, 64, 5u64);
        let eng = Engine::rust();
        let mem64 = approx_leverage_scores(
            &eng,
            &data.x,
            kern,
            sigma,
            lam,
            sketch,
            &mut Rng::new(seed),
        )
        .unwrap();
        let mut oracle_src = MemSource::with_dtype(data.clone(), 1000, Dtype::F32);
        let oracle = approx_leverage_scores_source(
            &eng,
            &mut oracle_src,
            kern,
            sigma,
            lam,
            sketch,
            &mut Rng::new(seed),
        )
        .unwrap();
        for chunk_rows in [17usize, 100] {
            let mut src = MemSource::with_dtype(data.clone(), chunk_rows, Dtype::F32);
            let streamed = approx_leverage_scores_source(
                &eng,
                &mut src,
                kern,
                sigma,
                lam,
                sketch,
                &mut Rng::new(seed),
            )
            .unwrap();
            let diff = max_abs_diff(&oracle, &streamed);
            assert!(diff <= 1e-8, "f32 chunk {chunk_rows}: vs whole-stream f32 {diff}");
        }
        let drift = max_abs_diff(&oracle, &mem64);
        assert!(drift <= 1e-3, "f32 vs f64 scores drift {drift}");
    }

    #[test]
    fn streamed_scores_pooled_bitwise_equal_serial() {
        // within a path, pooled == serial bitwise: every pooled stage
        // (kernel panels, kmm, SYRK, blocked chol) reduces partials in
        // job order, and the TRSMs are serial coordinator math
        let n = 350;
        let data = battery_data(n);
        let (kern, sigma, lam, sketch, seed) = (Kernel::Gaussian, 1.0, 1e-3, 64, 5u64);
        let serial = Engine::rust();
        let pooled = Engine::rust_with(EngineOptions {
            workers: 4,
            ..Default::default()
        });
        for dtype in [Dtype::F64, Dtype::F32] {
            let mut src_s = MemSource::with_dtype(data.clone(), 100, dtype);
            let mut src_p = MemSource::with_dtype(data.clone(), 100, dtype);
            let s = approx_leverage_scores_source(
                &serial,
                &mut src_s,
                kern,
                sigma,
                lam,
                sketch,
                &mut Rng::new(seed),
            )
            .unwrap();
            let p = approx_leverage_scores_source(
                &pooled,
                &mut src_p,
                kern,
                sigma,
                lam,
                sketch,
                &mut Rng::new(seed),
            )
            .unwrap();
            assert_eq!(s, p, "pooled vs serial ({dtype:?}) must be bitwise equal");
        }
        // in-memory path too
        let s = approx_leverage_scores(
            &serial,
            &data.x,
            kern,
            sigma,
            lam,
            sketch,
            &mut Rng::new(seed),
        )
        .unwrap();
        let p = approx_leverage_scores(
            &pooled,
            &data.x,
            kern,
            sigma,
            lam,
            sketch,
            &mut Rng::new(seed),
        )
        .unwrap();
        assert_eq!(s, p, "in-memory pooled vs serial must be bitwise equal");
    }
}

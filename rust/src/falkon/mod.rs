//! The FALKON algorithm (the paper's contribution): Nyström center
//! selection (uniform + approximate leverage scores), the Nyström-based
//! preconditioner, and conjugate gradient over the blocked kernel matvec.
pub mod centers;
pub mod cg;
pub mod estimator;
pub mod lscores;
pub mod model_io;
pub mod precond;
pub mod tune;

pub use centers::{Centers, SelectedCenters};
pub use cg::{block_conjgrad, conjgrad, BlockCgResult, CgOptions, CgResult, CgStop};
pub use estimator::{
    fit, fit_multiclass, fit_multiclass_looped, fit_with_callback, prepare, solve, solve_multi,
    FalkonConfig, FalkonModel, FalkonMulticlass, FitState, PrecondKind,
};

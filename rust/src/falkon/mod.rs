//! The FALKON algorithm (the paper's contribution): Nyström center
//! selection (uniform + approximate leverage scores), the Nyström-based
//! preconditioner, and conjugate gradient over the blocked kernel matvec.
pub mod centers;
pub mod cg;
pub mod estimator;
pub mod lscores;
pub mod model_io;
pub mod precond;
pub mod tune;

pub use centers::{Centers, SelectedCenters};
pub use cg::{conjgrad, CgOptions, CgResult};
pub use estimator::{
    fit, fit_multiclass, fit_with_callback, prepare, solve, FalkonConfig, FalkonModel,
    FalkonMulticlass, FitState, PrecondKind,
};

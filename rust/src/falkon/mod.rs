//! The FALKON algorithm (the paper's contribution): Nyström center
//! selection (uniform + approximate leverage scores), the Nyström-based
//! preconditioner, and conjugate gradient over the blocked kernel matvec.
//!
//! Entry points: [`fit`] (regression / ±1 binary), [`fit_multiclass`]
//! (one-vs-all with a shared plan and a batched multi-RHS solve), and
//! [`fit_source`] (out-of-core: train from a chunked
//! [`crate::data::DataSource`] with O(chunk) resident features).
//!
//! # Example: multiclass blobs
//!
//! ```
//! use falkon::data::synth;
//! use falkon::falkon::{fit_multiclass, FalkonConfig};
//! use falkon::runtime::Engine;
//! use falkon::util::rng::Rng;
//!
//! let mut rng = Rng::new(1);
//! let data = synth::blobs(&mut rng, 400, 4, 3); // separable 3-class blobs
//! let engine = Engine::rust();
//! let config = FalkonConfig {
//!     sigma: 4.0,
//!     lam: 1e-5,
//!     m: 40,
//!     t: 8,
//!     ..Default::default()
//! };
//! let model = fit_multiclass(&engine, &data, &config).unwrap();
//! let pred = model.predict_class(&engine, &data.x).unwrap();
//! let labels = data.labels.as_ref().unwrap();
//! let errs = pred.iter().zip(labels).filter(|(p, l)| p != l).count();
//! assert!(errs as f64 / pred.len() as f64 < 0.05, "{errs} errors");
//! ```
pub mod centers;
pub mod cg;
pub mod checkpoint;
pub mod estimator;
pub mod lscores;
pub mod model_io;
pub mod precond;
pub mod tune;

pub use centers::{CenterGather, Centers, Reservoir, SelectedCenters, WeightedReservoir};
pub use cg::{
    block_conjgrad, conjgrad, conjgrad_resumable, BlockCgResult, CgOptions, CgResult, CgState,
    CgStop,
};
pub use checkpoint::CheckpointSpec;
pub use estimator::{
    fit, fit_multiclass, fit_multiclass_looped, fit_source, fit_with_callback, prepare,
    prepare_source, setup_precond, solve, solve_multi, Degradation, FalkonConfig, FalkonModel,
    FalkonMulticlass, FitReport, FitState, PrecondKind,
};

//! Fitted-model persistence (JSON): the launcher's `train --out` writes a
//! model file; `predict` / `serve` load it. Self-contained — centers and
//! coefficients are embedded so serving needs no training data. Both
//! model kinds round-trip: regression ([`FalkonModel`], format
//! `"falkon-model"`) and one-vs-all multiclass ([`FalkonMulticlass`],
//! format `"falkon-multiclass"`); the serving registry
//! ([`crate::serve::registry::load_served`]) dispatches on the tag.

use super::estimator::{FalkonConfig, FalkonModel, FalkonMulticlass};
use crate::kernels::Kernel;
use crate::linalg::mat::Mat;
use crate::util::json::{self, Value};
use anyhow::{anyhow, Result};

/// `format` tag of regression model files.
pub const FORMAT_REGRESSION: &str = "falkon-model";
/// `format` tag of one-vs-all multiclass model files.
pub const FORMAT_MULTICLASS: &str = "falkon-multiclass";

fn vec_to_json(v: &[f64]) -> Value {
    Value::Arr(v.iter().map(|&x| Value::Num(x)).collect())
}

fn vec_from_json(v: &Value, what: &str) -> Result<Vec<f64>> {
    v.as_arr()
        .ok_or_else(|| anyhow!("{what}: expected array"))?
        .iter()
        .map(|x| x.as_f64().ok_or_else(|| anyhow!("{what}: expected number")))
        .collect()
}

pub fn model_to_json(m: &FalkonModel) -> Value {
    Value::obj(vec![
        ("format", Value::str(FORMAT_REGRESSION)),
        ("version", Value::num(1.0)),
        ("kernel", Value::str(m.config.kernel.name())),
        ("sigma", Value::num(m.config.sigma)),
        ("lam", Value::num(m.config.lam)),
        ("m", Value::num(m.centers.rows as f64)),
        ("d", Value::num(m.centers.cols as f64)),
        ("y_offset", Value::num(m.y_offset)),
        ("centers", vec_to_json(&m.centers.data)),
        ("alpha", vec_to_json(&m.alpha)),
    ])
}

pub fn model_from_json(v: &Value) -> Result<FalkonModel> {
    if v.get("format").as_str() != Some(FORMAT_REGRESSION) {
        return Err(anyhow!("not a falkon model file"));
    }
    let kern = v
        .get("kernel")
        .as_str()
        .and_then(Kernel::parse)
        .ok_or_else(|| anyhow!("bad kernel"))?;
    let m = v.get("m").as_usize().ok_or_else(|| anyhow!("bad m"))?;
    let d = v.get("d").as_usize().ok_or_else(|| anyhow!("bad d"))?;
    let centers = Mat::from_vec(m, d, vec_from_json(v.get("centers"), "centers")?);
    let alpha = vec_from_json(v.get("alpha"), "alpha")?;
    anyhow::ensure!(alpha.len() == m, "alpha/centers mismatch");
    let config = FalkonConfig {
        kernel: kern,
        sigma: v.get("sigma").as_f64().unwrap_or(1.0),
        lam: v.get("lam").as_f64().unwrap_or(0.0),
        m,
        ..Default::default()
    };
    Ok(FalkonModel {
        config,
        centers,
        alpha,
        y_offset: v.get("y_offset").as_f64().unwrap_or(0.0),
        phases: Default::default(),
        cg_iters: 0,
        cg_residuals: Vec::new(),
        cg_stop: crate::falkon::CgStop::MaxIter,
        report: Default::default(),
    })
}

pub fn multiclass_to_json(m: &FalkonMulticlass) -> Value {
    Value::obj(vec![
        ("format", Value::str(FORMAT_MULTICLASS)),
        ("version", Value::num(1.0)),
        ("kernel", Value::str(m.config.kernel.name())),
        ("sigma", Value::num(m.config.sigma)),
        ("lam", Value::num(m.config.lam)),
        ("m", Value::num(m.centers.rows as f64)),
        ("d", Value::num(m.centers.cols as f64)),
        ("k", Value::num(m.alphas.len() as f64)),
        ("centers", vec_to_json(&m.centers.data)),
        (
            "alphas",
            Value::Arr(m.alphas.iter().map(|a| vec_to_json(a)).collect()),
        ),
    ])
}

pub fn multiclass_from_json(v: &Value) -> Result<FalkonMulticlass> {
    if v.get("format").as_str() != Some(FORMAT_MULTICLASS) {
        return Err(anyhow!("not a falkon multiclass model file"));
    }
    let kern = v
        .get("kernel")
        .as_str()
        .and_then(Kernel::parse)
        .ok_or_else(|| anyhow!("bad kernel"))?;
    let m = v.get("m").as_usize().ok_or_else(|| anyhow!("bad m"))?;
    let d = v.get("d").as_usize().ok_or_else(|| anyhow!("bad d"))?;
    let k = v.get("k").as_usize().ok_or_else(|| anyhow!("bad k"))?;
    let centers = Mat::from_vec(m, d, vec_from_json(v.get("centers"), "centers")?);
    let alphas: Vec<Vec<f64>> = v
        .get("alphas")
        .as_arr()
        .ok_or_else(|| anyhow!("alphas: expected array"))?
        .iter()
        .map(|a| vec_from_json(a, "alphas"))
        .collect::<Result<_>>()?;
    anyhow::ensure!(alphas.len() == k, "alphas/k mismatch");
    for a in &alphas {
        anyhow::ensure!(a.len() == m, "alpha/centers mismatch");
    }
    let config = FalkonConfig {
        kernel: kern,
        sigma: v.get("sigma").as_f64().unwrap_or(1.0),
        lam: v.get("lam").as_f64().unwrap_or(0.0),
        m,
        ..Default::default()
    };
    Ok(FalkonMulticlass {
        config,
        centers,
        alphas,
        phases: Default::default(),
        cg_iters: Vec::new(),
        cg_stops: Vec::new(),
        report: Default::default(),
    })
}

pub fn save(m: &FalkonModel, path: &str) -> Result<()> {
    std::fs::write(path, model_to_json(m).to_string_pretty())?;
    Ok(())
}

pub fn load(path: &str) -> Result<FalkonModel> {
    let text = std::fs::read_to_string(path)?;
    let v = json::parse(&text).map_err(|e| anyhow!("parsing {path}: {e}"))?;
    model_from_json(&v)
}

pub fn save_multiclass(m: &FalkonMulticlass, path: &str) -> Result<()> {
    std::fs::write(path, multiclass_to_json(m).to_string_pretty())?;
    Ok(())
}

pub fn load_multiclass(path: &str) -> Result<FalkonMulticlass> {
    let text = std::fs::read_to_string(path)?;
    let v = json::parse(&text).map_err(|e| anyhow!("parsing {path}: {e}"))?;
    multiclass_from_json(&v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::runtime::Engine;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_preserves_predictions() {
        let mut rng = Rng::new(1);
        let data = synth::smooth_regression(&mut rng, 200, 3, 0.05);
        let eng = Engine::rust();
        let cfg = FalkonConfig {
            sigma: 1.5,
            lam: 1e-4,
            m: 24,
            t: 10,
            ..Default::default()
        };
        let model = crate::falkon::fit(&eng, &data.x, &data.y, &cfg).unwrap();
        let path = std::env::temp_dir().join("falkon_model_test.json");
        save(&model, path.to_str().unwrap()).unwrap();
        let back = load(path.to_str().unwrap()).unwrap();
        let p1 = model.predict(&eng, &data.x).unwrap();
        let p2 = back.predict(&eng, &data.x).unwrap();
        assert_eq!(p1, p2);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn rejects_wrong_format() {
        let v = json::parse(r#"{"format": "other"}"#).unwrap();
        assert!(model_from_json(&v).is_err());
        assert!(multiclass_from_json(&v).is_err());
    }

    #[test]
    fn multiclass_roundtrip_preserves_predictions() {
        let mut rng = Rng::new(9);
        let data = synth::blobs(&mut rng, 300, 4, 3);
        let eng = Engine::rust();
        let cfg = FalkonConfig {
            sigma: 4.0,
            lam: 1e-5,
            m: 32,
            t: 8,
            seed: 3,
            ..Default::default()
        };
        let model = crate::falkon::fit_multiclass(&eng, &data, &cfg).unwrap();
        let path = std::env::temp_dir().join("falkon_mc_model_test.json");
        let path = path.to_str().unwrap();
        save_multiclass(&model, path).unwrap();
        let back = load_multiclass(path).unwrap();
        let p1 = model.predict_class(&eng, &data.x).unwrap();
        let p2 = back.predict_class(&eng, &data.x).unwrap();
        assert_eq!(p1, p2);
        let s1 = model.scores_mat(&eng, &data.x).unwrap();
        let s2 = back.scores_mat(&eng, &data.x).unwrap();
        assert_eq!(s1.data, s2.data);
        let _ = std::fs::remove_file(path);
    }
}

//! The Example 2 preconditioner (appendix A): rank-revealing
//! eigendecomposition of D·K_MM·D instead of Cholesky, handling exactly
//! singular K_MM (duplicate centers, linear kernel with M > d) without
//! jitter.
//!
//! With D·K_MM·D = V diag(λ) Vᵀ and rank q (λ_i > tol·λ_1):
//!
//! ```text
//! Q = V[:, :q]               (M×q partial isometry)
//! T = diag(√λ_1 … √λ_q)      (q×q)
//! A = chol(TTᵀ/M + λI) = diag(√(λ_i/M + λ))
//! ```
//!
//! satisfying Def. 3: Q·TᵀT·Qᵀ = D·K_MM·D, AᵀA = TTᵀ/M + λI.
//! Runs on the coordinator in f64 (once per fit, O(M³)).

use crate::linalg::eig::sym_eig;
use crate::linalg::mat::Mat;
use anyhow::{ensure, Result};

/// Build (T, A, Q) per Example 2. `rank_tol` (the config's `eps` is
/// reused) discards eigenvalues below `rank_tol·M·λ_max`.
pub fn precond_eig(kmm: &Mat, lam: f64, rank_tol: f64) -> Result<(Mat, Mat, Mat)> {
    ensure!(kmm.rows == kmm.cols, "K_MM not square");
    let m = kmm.rows;
    let e = sym_eig(kmm);
    let lmax = e.values.first().copied().unwrap_or(0.0).max(1e-300);
    let cut = rank_tol.max(1e-14) * m as f64 * lmax;
    let q_rank = e.values.iter().take_while(|&&v| v > cut).count().max(1);

    let mut t = Mat::zeros(q_rank, q_rank);
    let mut a = Mat::zeros(q_rank, q_rank);
    for i in 0..q_rank {
        let li = e.values[i].max(0.0);
        t[(i, i)] = li.sqrt();
        a[(i, i)] = (li / m as f64 + lam).sqrt();
    }
    let mut q = Mat::zeros(m, q_rank);
    for i in 0..m {
        q.row_mut(i).copy_from_slice(&e.vectors.row(i)[..q_rank]);
    }
    Ok((t, a, q))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::falkon::{fit, FalkonConfig, PrecondKind};
    use crate::kernels::{self, Kernel};
    use crate::linalg::gemm::matmul;
    use crate::metrics;
    use crate::runtime::Engine;
    use crate::util::rng::Rng;

    #[test]
    fn factors_satisfy_def3() {
        let mut rng = Rng::new(1);
        let c = Mat::from_vec(12, 4, rng.normals(48));
        let kmm = kernels::kmm(Kernel::Gaussian, &c, 1.0);
        let (t, a, q) = precond_eig(&kmm, 1e-3, 1e-12).unwrap();
        // Q TᵀT Qᵀ = K_MM
        let tt = matmul(&t.t(), &t);
        let qt = matmul(&q, &tt);
        let back = matmul(&qt, &q.t());
        assert!(back.max_abs_diff(&kmm) < 1e-8, "{}", back.max_abs_diff(&kmm));
        // QᵀQ = I
        let qq = matmul(&q.t(), &q);
        assert!(qq.max_abs_diff(&Mat::eye(q.cols)) < 1e-9);
        // AᵀA = TTᵀ/M + λI
        let mut want = matmul(&t, &t.t());
        want.scale(1.0 / 12.0);
        want.add_diag(1e-3);
        assert!(matmul(&a.t(), &a).max_abs_diff(&want) < 1e-10);
    }

    #[test]
    fn truncates_singular_kmm() {
        // linear kernel with M > d: rank(K_MM) <= d
        let mut rng = Rng::new(2);
        let c = Mat::from_vec(10, 3, rng.normals(30));
        let kmm = kernels::kmm(Kernel::Linear, &c, 1.0);
        let (t, _, q) = precond_eig(&kmm, 1e-3, 1e-10).unwrap();
        assert!(t.rows <= 3, "rank {}", t.rows);
        assert_eq!(q.cols, t.rows);
    }

    #[test]
    fn eig_path_matches_chol_path_predictions() {
        let mut rng = Rng::new(3);
        let data = synth::smooth_regression(&mut rng, 400, 3, 0.05);
        let eng = Engine::rust();
        let base = FalkonConfig {
            sigma: 1.5,
            lam: 1e-3,
            m: 40,
            t: 40,
            seed: 5,
            eps: 1e-12,
            ..Default::default()
        };
        let chol = fit(&eng, &data.x, &data.y, &base).unwrap();
        let eig = fit(
            &eng,
            &data.x,
            &data.y,
            &FalkonConfig {
                precond: PrecondKind::Eig,
                ..base
            },
        )
        .unwrap();
        let p1 = chol.predict(&eng, &data.x).unwrap();
        let p2 = eig.predict(&eng, &data.x).unwrap();
        let rel = crate::linalg::vec_ops::rel_diff(&p2, &p1);
        assert!(rel < 1e-6, "rel {rel}");
    }

    #[test]
    fn eig_path_survives_duplicate_centers_linear_kernel() {
        // rank-deficient K_MM end-to-end: linear kernel, M=30 >> d=4
        let mut rng = Rng::new(4);
        let n = 400;
        let x = Mat::from_vec(n, 4, rng.normals(4 * n));
        let w0 = [1.0, -2.0, 0.5, 3.0];
        let y: Vec<f64> = (0..n)
            .map(|i| {
                crate::linalg::vec_ops::dot(x.row(i), &w0) + 0.05 * rng.normal()
            })
            .collect();
        let eng = Engine::rust();
        let cfg = FalkonConfig {
            kernel: Kernel::Linear,
            sigma: 1.0,
            lam: 1e-6,
            m: 30,
            t: 30,
            seed: 6,
            precond: PrecondKind::Eig,
            // the target is exactly linear (zero intercept): centering
            // would inject an unrepresentable constant into the span
            center_y: false,
            ..Default::default()
        };
        let model = fit(&eng, &x, &y, &cfg).unwrap();
        let preds = model.predict(&eng, &x).unwrap();
        let err = metrics::mse(&preds, &y);
        assert!(err < 0.01, "mse {err}");
    }
}

//! Hyperparameter search over (σ, λ) with a holdout split — the model
//! selection loop a practitioner runs around FALKON (the paper tunes σ/λ
//! per dataset, e.g. "diagonal matrix width learned with cross validation"
//! for HIGGS).
//!
//! The search exploits the fit's structure: for a fixed σ the prepared
//! matvec plan and centers are **independent of λ**, so a λ sweep re-runs
//! only the preconditioner factorization (O(M³)) and the CG solve — not
//! the center selection or block preparation.

use crate::kernels::Kernel;
use crate::linalg::mat::Mat;
use crate::metrics;
use crate::runtime::{Bhb, Engine};
use crate::util::timer::Timer;
use anyhow::Result;

use super::cg::{conjgrad, CgOptions};
use super::estimator::FalkonConfig;

/// What to minimize on the holdout.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    Mse,
    /// binary classification error on ±1 labels
    BinaryError,
}

#[derive(Debug, Clone)]
pub struct TuneResult {
    pub sigma: f64,
    pub lam: f64,
    pub score: f64,
    /// all evaluated (sigma, lam, score) triples
    pub trace: Vec<(f64, f64, f64)>,
    pub secs: f64,
}

/// Grid search over `sigmas × lams`, fitting on (x, y) and scoring on
/// (xv, yv). Returns the best configuration (ties → smaller λ).
#[allow(clippy::too_many_arguments)]
pub fn grid_search(
    engine: &Engine,
    x: &Mat,
    y: &[f64],
    xv: &Mat,
    yv: &[f64],
    base: &FalkonConfig,
    sigmas: &[f64],
    lams: &[f64],
    objective: Objective,
) -> Result<TuneResult> {
    assert!(!sigmas.is_empty() && !lams.is_empty());
    let timer = Timer::start();
    let mut trace = Vec::new();
    let mut best: Option<(f64, f64, f64)> = None;

    for &sigma in sigmas {
        // σ fixed: prepare centers + plan + K_MM once
        let mut cfg = base.clone();
        cfg.sigma = sigma;
        cfg.kernel = base.kernel;
        let mut rng = crate::util::rng::Rng::new(cfg.seed);
        let sel = cfg.centers.select(
            engine, x, cfg.kernel, sigma, cfg.lam, cfg.m, &mut rng,
        )?;
        let mut kmm = engine.kmm(cfg.kernel, &sel.c, sigma)?;
        if let Some(d) = &sel.d_weights {
            kmm.scale_sym_diag(d); // K_MM -> D K_MM D (Def. 3)
        }
        let plan = engine.matvec_plan(cfg.kernel, x, &sel.c, sigma)?;

        for &lam in lams {
            // λ sweep: only refactorize + resolve
            let (t_f, a_f) = engine.precond(&kmm, lam, cfg.eps)?;
            let bhb = Bhb {
                plan: &plan,
                t: &t_f,
                a: &a_f,
                lam,
                d: sel.d_weights.as_deref(),
                q: None,
            };
            let r = bhb.rhs(y)?;
            let cg = conjgrad(
                |p| bhb.apply(p),
                &r,
                CgOptions {
                    t_max: cfg.t,
                    tol: cfg.tol,
                },
                None,
            )?;
            let alpha = bhb.beta_to_alpha(&cg.beta);
            let preds = engine.predict(cfg.kernel, xv, &sel.c, &alpha, sigma)?;
            let score = match objective {
                Objective::Mse => metrics::mse(&preds, yv),
                Objective::BinaryError => metrics::binary_error(&preds, yv),
            };
            trace.push((sigma, lam, score));
            let better = match best {
                None => true,
                Some((_, _, s)) => score < s,
            };
            if better {
                best = Some((sigma, lam, score));
            }
        }
    }
    let (sigma, lam, score) = best.unwrap();
    Ok(TuneResult {
        sigma,
        lam,
        score,
        trace,
        secs: timer.elapsed_s(),
    })
}

/// Log-spaced grid helper: `count` points from `lo` to `hi` inclusive.
pub fn log_grid(lo: f64, hi: f64, count: usize) -> Vec<f64> {
    assert!(lo > 0.0 && hi > lo && count >= 2);
    let (a, b) = (lo.ln(), hi.ln());
    (0..count)
        .map(|i| (a + (b - a) * i as f64 / (count - 1) as f64).exp())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::rng::Rng;

    #[test]
    fn log_grid_endpoints() {
        let g = log_grid(1e-6, 1e-2, 5);
        assert_eq!(g.len(), 5);
        assert!((g[0] - 1e-6).abs() < 1e-18);
        assert!((g[4] - 1e-2).abs() < 1e-8);
        for w in g.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn picks_sane_hyperparameters() {
        // target generated with width-2 bumps: σ≈2 should win over σ=0.2
        // and over a massively over-regularized λ
        let mut rng = Rng::new(1);
        let data = synth::smooth_regression(&mut rng, 900, 4, 0.05);
        let (train, valid) = data.split(0.3, &mut rng);
        let eng = Engine::rust();
        let base = FalkonConfig {
            m: 60,
            t: 25,
            seed: 3,
            ..Default::default()
        };
        let res = grid_search(
            &eng,
            &train.x,
            &train.y,
            &valid.x,
            &valid.y,
            &base,
            &[0.2, 2.0],
            &[1e-6, 1e-3, 10.0],
            Objective::Mse,
        )
        .unwrap();
        assert_eq!(res.trace.len(), 6);
        assert_eq!(res.sigma, 2.0, "trace: {:?}", res.trace);
        assert!(res.lam < 10.0);
        // the best score is the minimum of the trace
        let min = res
            .trace
            .iter()
            .map(|t| t.2)
            .fold(f64::INFINITY, f64::min);
        assert_eq!(res.score, min);
    }

    #[test]
    fn binary_objective_runs() {
        let mut rng = Rng::new(2);
        let data = synth::susy(&mut rng, 800);
        let (train, valid) = data.split(0.3, &mut rng);
        let eng = Engine::rust();
        let base = FalkonConfig {
            m: 50,
            t: 15,
            seed: 4,
            ..Default::default()
        };
        let res = grid_search(
            &eng,
            &train.x,
            &train.y,
            &valid.x,
            &valid.y,
            &base,
            &[3.0],
            &[1e-4, 1e-2],
            Objective::BinaryError,
        )
        .unwrap();
        assert!(res.score < 0.5, "error {}", res.score);
    }
}

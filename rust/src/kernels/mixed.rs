//! Mixed-precision kernel tier (DESIGN.md §"Precision model"): the same
//! tiled panel machinery as the parent module, reading **`f32` feature
//! storage** instead of `f64`. Every dot product, norm and panel
//! reduction widens to `f64` in registers — `f32` is a *storage* format
//! here, never an arithmetic one — so against the f64 tier on the same
//! (rounded) inputs the only new error sources are:
//!
//! 1. one rounding of each Kr entry's exponential argument (or linear
//!    dot) to `f32`,
//! 2. the [`fast_exp_f32`] polynomial, and
//! 3. one rounding of the stored Kr entry to `f32`,
//!
//! all bounded per-kernel by [`super::tol`]. The two fused stages of the
//! matvec/matmat keep their accumulators in `f64`
//! ([`vec_ops::dot_mixed`], [`vec_ops::axpy_f32`]), so CG recurrences,
//! `Bᵀ(...)B` applies and the preconditioner never see single precision.
//!
//! Products of two `f32` values are **exact** in `f64` (24 + 24 ≤ 53
//! mantissa bits), which is why the norm expansion ‖x‖²+‖c‖²−2x·c
//! computed here from `f64`-widened norms and dots carries only
//! `O(d·eps64)` accumulation error — negligible against the `eps32`-scale
//! terms above.

use crate::linalg::mat::Mat;
use crate::linalg::mat32::MatF32;
use crate::linalg::vec_ops::{self, fast_exp_f32};
use crate::util::pool::{chunk_ranges, fan_out, WorkerPool};

use super::simd::{self, Isa};
use super::{Kernel, TileScratch, DEFAULT_TILE};

/// Squared L2 norm of every row, accumulated in `f64` — the f32-storage
/// sibling of [`super::row_sq_norms`]. The returned norms are `f64` so
/// the Gaussian norm expansion is exact-to-double given the stored
/// values.
pub fn row_sq_norms_f32(x: &MatF32) -> Vec<f64> {
    (0..x.rows)
        .map(|i| {
            let r = x.row(i);
            vec_ops::dot_f32(r, r)
        })
        .collect()
}

/// Fill a panel of kernel values K(X_panel, C[j0..]) into the `f32` tile
/// `out` through the selected instruction-set arm — the mixed-precision
/// sibling of the parent module's `kernel_panel` dispatcher. Every arm
/// keeps the tier's precision contract: f32 storage widened to f64 for
/// all reductions, the exponential argument rounded once to f32.
#[allow(clippy::too_many_arguments)]
fn kernel_panel_f32(
    kern: Kernel,
    xb: &[f32],
    d: usize,
    rows: usize,
    xn: &[f64],
    c: &MatF32,
    cn: &[f64],
    j0: usize,
    param: f64,
    out: &mut [f32],
    ldo: usize,
    isa: Isa,
) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Isa::Avx2 is only produced by simd::resolve()/detect_best()
        // after runtime detection confirmed avx2+fma on this host.
        Isa::Avx2 => unsafe {
            simd::avx2::kernel_panel_f32_avx2(kern, xb, d, rows, xn, c, cn, j0, param, out, ldo)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is a baseline feature of every aarch64 target.
        Isa::Neon => unsafe {
            simd::neon::kernel_panel_f32_neon(kern, xb, d, rows, xn, c, cn, j0, param, out, ldo)
        },
        _ => kernel_panel_f32_scalar(kern, xb, d, rows, xn, c, cn, j0, param, out, ldo),
    }
}

/// Scalar arm of [`kernel_panel_f32`], same layout contract (`ldo`, `j0`)
/// as [`super::kernel_panel_scalar`]. The 1×4 register tile of dot
/// products accumulates in `f64`; the exponential argument (or linear
/// dot) is computed in `f64` and rounded **once** to `f32`, then the
/// exponential arms run a separate vectorizable [`fast_exp_f32`] pass
/// over the finished row.
#[allow(clippy::too_many_arguments)]
fn kernel_panel_f32_scalar(
    kern: Kernel,
    xb: &[f32],
    d: usize,
    rows: usize,
    xn: &[f64],
    c: &MatF32,
    cn: &[f64],
    j0: usize,
    param: f64,
    out: &mut [f32],
    ldo: usize,
) {
    let m = c.rows;
    let w = m - j0;
    debug_assert_eq!(xb.len(), rows * d);
    debug_assert_eq!(c.cols, d);
    debug_assert!(rows == 0 || out.len() >= (rows - 1) * ldo + w);
    debug_assert!(ldo >= w);
    match kern {
        Kernel::Gaussian => {
            debug_assert_eq!(xn.len(), rows);
            debug_assert_eq!(cn.len(), m);
            let inv = 1.0 / (2.0 * param * param);
            for i in 0..rows {
                let xr = &xb[i * d..(i + 1) * d];
                let xni = xn[i];
                let orow = &mut out[i * ldo..i * ldo + w];
                let mut j = j0;
                while j + 4 <= m {
                    let c0 = c.row(j);
                    let c1 = c.row(j + 1);
                    let c2 = c.row(j + 2);
                    let c3 = c.row(j + 3);
                    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
                    for k in 0..d {
                        let xv = xr[k] as f64;
                        a0 += xv * c0[k] as f64;
                        a1 += xv * c1[k] as f64;
                        a2 += xv * c2[k] as f64;
                        a3 += xv * c3[k] as f64;
                    }
                    orow[j - j0] = (-(xni + cn[j] - 2.0 * a0).max(0.0) * inv) as f32;
                    orow[j - j0 + 1] = (-(xni + cn[j + 1] - 2.0 * a1).max(0.0) * inv) as f32;
                    orow[j - j0 + 2] = (-(xni + cn[j + 2] - 2.0 * a2).max(0.0) * inv) as f32;
                    orow[j - j0 + 3] = (-(xni + cn[j + 3] - 2.0 * a3).max(0.0) * inv) as f32;
                    j += 4;
                }
                while j < m {
                    let dotv = vec_ops::dot_f32(xr, c.row(j));
                    orow[j - j0] = (-(xni + cn[j] - 2.0 * dotv).max(0.0) * inv) as f32;
                    j += 1;
                }
                for v in orow.iter_mut() {
                    *v = fast_exp_f32(*v);
                }
            }
        }
        Kernel::Laplacian => {
            let inv = 1.0 / param;
            for i in 0..rows {
                let xr = &xb[i * d..(i + 1) * d];
                let orow = &mut out[i * ldo..i * ldo + w];
                for j in j0..m {
                    let cr = c.row(j);
                    let mut l1 = 0.0f64;
                    for k in 0..d {
                        l1 += (xr[k] as f64 - cr[k] as f64).abs();
                    }
                    orow[j - j0] = (-l1 * inv) as f32;
                }
                for v in orow.iter_mut() {
                    *v = fast_exp_f32(*v);
                }
            }
        }
        Kernel::Linear => {
            for i in 0..rows {
                let xr = &xb[i * d..(i + 1) * d];
                let orow = &mut out[i * ldo..i * ldo + w];
                let mut j = j0;
                while j + 4 <= m {
                    let c0 = c.row(j);
                    let c1 = c.row(j + 1);
                    let c2 = c.row(j + 2);
                    let c3 = c.row(j + 3);
                    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
                    for k in 0..d {
                        let xv = xr[k] as f64;
                        a0 += xv * c0[k] as f64;
                        a1 += xv * c1[k] as f64;
                        a2 += xv * c2[k] as f64;
                        a3 += xv * c3[k] as f64;
                    }
                    orow[j - j0] = a0 as f32;
                    orow[j - j0 + 1] = a1 as f32;
                    orow[j - j0 + 2] = a2 as f32;
                    orow[j - j0 + 3] = a3 as f32;
                    j += 4;
                }
                while j < m {
                    orow[j - j0] = vec_ops::dot_f32(xr, c.row(j)) as f32;
                    j += 1;
                }
            }
        }
    }
}

/// Dense kernel block K(X, C) on the f32 panel machinery (serial) —
/// kernel values computed tile-by-tile straight into an `n × m` `f32`
/// matrix. Used by the property tests (entry-level pinning against the
/// f64 oracle) and by the panel-throughput leg of `perf_matvec`.
pub fn kernel_block_f32(kern: Kernel, x: &MatF32, c: &MatF32, param: f64) -> MatF32 {
    assert_eq!(x.cols, c.cols, "feature dims differ");
    let (n, m, d) = (x.rows, c.rows, x.cols);
    let mut out = MatF32::zeros(n, m);
    if n == 0 || m == 0 {
        return out;
    }
    let xn = match kern {
        Kernel::Gaussian => row_sq_norms_f32(x),
        _ => Vec::new(),
    };
    let cn = match kern {
        Kernel::Gaussian => row_sq_norms_f32(c),
        _ => Vec::new(),
    };
    let mut s = 0;
    while s < n {
        let rows = (n - s).min(DEFAULT_TILE);
        let xb = &x.data[s * d..(s + rows) * d];
        let xnr = match kern {
            Kernel::Gaussian => &xn[s..s + rows],
            _ => &[] as &[f64],
        };
        kernel_panel_f32(
            kern,
            xb,
            d,
            rows,
            xnr,
            c,
            &cn,
            0,
            param,
            &mut out.data[s * m..],
            m,
            Isa::global(),
        );
        s += rows;
    }
    out
}

/// Tiled/fused w += Krᵀ(mask ⊙ (Kr·u + v)) over the rows of an **f32**
/// `x` — the mixed-precision sibling of [`super::knm_matvec_blocked`]
/// with the identical mask/v/accumulate contract. Kr is staged in `f32`
/// (half the tile bytes), both fused stages accumulate in `f64`
/// ([`vec_ops::dot_mixed`] / [`vec_ops::axpy_f32`]), and `u`/`v`/`w`
/// stay `f64` — the CG coordinator never sees single precision.
#[allow(clippy::too_many_arguments)]
pub fn knm_matvec_blocked_f32(
    kern: Kernel,
    x: &MatF32,
    c: &MatF32,
    xn: &[f64],
    cn: &[f64],
    u: &[f64],
    v: Option<&[f64]>,
    mask: Option<&[f64]>,
    param: f64,
    scratch: &mut TileScratch,
    w: &mut [f64],
) {
    knm_matvec_ranged_f32(
        kern,
        x,
        c,
        xn,
        cn,
        u,
        v,
        mask,
        param,
        scratch,
        w,
        0,
        x.rows,
        Isa::global(),
    )
}

/// [`knm_matvec_blocked_f32`] restricted to rows `[start, end)` of `x` —
/// the mixed-precision sibling of [`super::knm_matvec_ranged`], same
/// pooled fan-out contract (each worker sweeps a disjoint row range of
/// the same resident chunk).
#[allow(clippy::too_many_arguments)]
pub fn knm_matvec_ranged_f32(
    kern: Kernel,
    x: &MatF32,
    c: &MatF32,
    xn: &[f64],
    cn: &[f64],
    u: &[f64],
    v: Option<&[f64]>,
    mask: Option<&[f64]>,
    param: f64,
    scratch: &mut TileScratch,
    w: &mut [f64],
    start: usize,
    end: usize,
    isa: Isa,
) {
    let (n, m, d) = (x.rows, c.rows, x.cols);
    assert_eq!(c.cols, d, "feature dims differ");
    assert!(start <= end && end <= n, "row range {start}..{end} of {n}");
    assert_eq!(u.len(), m);
    assert_eq!(w.len(), m);
    assert_eq!(xn.len(), n);
    assert_eq!(cn.len(), m);
    if let Some(v) = v {
        assert_eq!(v.len(), n);
    }
    if let Some(mk) = mask {
        assert_eq!(mk.len(), n);
    }
    scratch.ensure32(m);
    let tile = scratch.tile;
    let mut s = start;
    while s < end {
        let rows = (end - s).min(tile);
        let kr = &mut scratch.kr32[..rows * m];
        let xb = &x.data[s * d..(s + rows) * d];
        kernel_panel_f32(kern, xb, d, rows, &xn[s..s + rows], c, cn, 0, param, kr, m, isa);
        // fused stage 1: y = mask ⊙ (Kr·u + v), f64 accumulators
        for i in 0..rows {
            let gi = s + i;
            let mi = mask.map(|mk| mk[gi]).unwrap_or(1.0);
            if mi == 0.0 {
                scratch.y[i] = 0.0;
                continue;
            }
            let dotu = vec_ops::dot_mixed(&kr[i * m..(i + 1) * m], u);
            let vi = v.map(|vf| vf[gi]).unwrap_or(0.0);
            scratch.y[i] = mi * (dotu + vi);
        }
        // fused stage 2: w += Krᵀ·y (masked / zero-weight rows skipped)
        for i in 0..rows {
            let yi = scratch.y[i];
            if yi != 0.0 {
                vec_ops::axpy_f32(yi, &kr[i * m..(i + 1) * m], w);
            }
        }
        s += rows;
    }
}

/// `out[i·K .. (i+1)·K] += Kr[i,:]·U` for every f32 panel row — the
/// mixed-precision sibling of [`super::panel_times_mat`]: four `f32` Kr
/// entries widen to `f64` and scale contiguous K-rows of `U` into the
/// `f64` accumulator.
fn panel_times_mat_f32(kr: &[f32], rows: usize, m: usize, u: &Mat, out: &mut [f64]) {
    let k = u.cols;
    debug_assert_eq!(u.rows, m);
    debug_assert!(out.len() >= rows * k);
    for i in 0..rows {
        let kri = &kr[i * m..(i + 1) * m];
        let orow = &mut out[i * k..(i + 1) * k];
        let mut j = 0;
        while j + 4 <= m {
            let (a0, a1, a2, a3) = (
                kri[j] as f64,
                kri[j + 1] as f64,
                kri[j + 2] as f64,
                kri[j + 3] as f64,
            );
            let u0 = u.row(j);
            let u1 = u.row(j + 1);
            let u2 = u.row(j + 2);
            let u3 = u.row(j + 3);
            for t in 0..k {
                orow[t] += a0 * u0[t] + a1 * u1[t] + a2 * u2[t] + a3 * u3[t];
            }
            j += 4;
        }
        while j < m {
            vec_ops::axpy(kri[j] as f64, u.row(j), orow);
            j += 1;
        }
    }
}

/// Tiled/fused W += Krᵀ(mask ⊙ (Kr·U + V)) over the rows of an **f32**
/// `x` — the mixed-precision sibling of [`super::knm_matmat_blocked`]
/// (multi-RHS: one f32 Kr panel serves all K right-hand sides; U, V, W
/// and the fused Y stay `f64`).
#[allow(clippy::too_many_arguments)]
pub fn knm_matmat_blocked_f32(
    kern: Kernel,
    x: &MatF32,
    c: &MatF32,
    xn: &[f64],
    cn: &[f64],
    u: &Mat,
    v: Option<&[f64]>,
    mask: Option<&[f64]>,
    param: f64,
    scratch: &mut TileScratch,
    w: &mut Mat,
) {
    knm_matmat_ranged_f32(
        kern,
        x,
        c,
        xn,
        cn,
        u,
        v,
        mask,
        param,
        scratch,
        w,
        0,
        x.rows,
        Isa::global(),
    )
}

/// [`knm_matmat_blocked_f32`] restricted to rows `[start, end)` of `x` —
/// the mixed-precision sibling of [`super::knm_matmat_ranged`].
#[allow(clippy::too_many_arguments)]
pub fn knm_matmat_ranged_f32(
    kern: Kernel,
    x: &MatF32,
    c: &MatF32,
    xn: &[f64],
    cn: &[f64],
    u: &Mat,
    v: Option<&[f64]>,
    mask: Option<&[f64]>,
    param: f64,
    scratch: &mut TileScratch,
    w: &mut Mat,
    start: usize,
    end: usize,
    isa: Isa,
) {
    let (n, m, d) = (x.rows, c.rows, x.cols);
    let k = u.cols;
    assert_eq!(c.cols, d, "feature dims differ");
    assert!(start <= end && end <= n, "row range {start}..{end} of {n}");
    assert_eq!(u.rows, m, "u rows != centers");
    assert_eq!((w.rows, w.cols), (m, k), "w shape");
    assert_eq!(xn.len(), n);
    assert_eq!(cn.len(), m);
    if let Some(v) = v {
        assert_eq!(v.len(), n * k, "v length != n·K");
    }
    if let Some(mk) = mask {
        assert_eq!(mk.len(), n);
    }
    if k == 0 {
        return;
    }
    scratch.ensure_multi32(m, k);
    let tile = scratch.tile;
    let TileScratch { kr32, y, .. } = scratch;
    let mut s = start;
    while s < end {
        let rows = (end - s).min(tile);
        let kr = &mut kr32[..rows * m];
        let xb = &x.data[s * d..(s + rows) * d];
        kernel_panel_f32(kern, xb, d, rows, &xn[s..s + rows], c, cn, 0, param, kr, m, isa);
        // fused stage 1: Y = mask ⊙ (Kr·U + V)   (rows × K, f64)
        let y = &mut y[..rows * k];
        for i in 0..rows {
            let gi = s + i;
            let yrow = &mut y[i * k..(i + 1) * k];
            let mi = mask.map(|mk| mk[gi]).unwrap_or(1.0);
            if mi == 0.0 {
                yrow.fill(0.0);
                continue;
            }
            match v {
                Some(vf) => yrow.copy_from_slice(&vf[gi * k..(gi + 1) * k]),
                None => yrow.fill(0.0),
            }
        }
        panel_times_mat_f32(kr, rows, m, u, y);
        // masked rows were initialized to zero, but stage 1 added Kr·U to
        // them too — re-zero them (and apply non-trivial mask weights) so
        // the accumulation pass honors the mask contract exactly.
        if let Some(mk) = mask {
            for i in 0..rows {
                let mi = mk[s + i];
                if mi != 1.0 {
                    let yrow = &mut y[i * k..(i + 1) * k];
                    if mi == 0.0 {
                        yrow.fill(0.0);
                    } else {
                        vec_ops::scale(mi, yrow);
                    }
                }
            }
        }
        // fused stage 2: W += Krᵀ·Y (masked / zero rows skipped)
        for i in 0..rows {
            let yrow = &y[i * k..(i + 1) * k];
            if yrow.iter().all(|&t| t == 0.0) {
                continue;
            }
            let kri = &kr[i * m..(i + 1) * m];
            for j in 0..m {
                vec_ops::axpy(kri[j] as f64, yrow, w.row_mut(j));
            }
        }
        s += rows;
    }
}

/// Tiled predictions f(x_i) = Σ_j α_j K(x_i, c_j) over **f32** storage —
/// the mixed-precision sibling of [`super::predict_blocked`]. α and the
/// returned scores are `f64`; each score is an f64-accumulated dot of an
/// f32 Kr row against α.
pub fn predict_blocked_f32(
    kern: Kernel,
    x: &MatF32,
    c: &MatF32,
    alpha: &[f64],
    param: f64,
) -> Vec<f64> {
    predict_blocked_pool_f32(kern, x, c, alpha, param, None, Isa::global())
}

/// [`predict_blocked_f32`] fanned out over the shared worker pool — the
/// f32 serving path. Each output row is written by exactly one task with
/// the same per-row arithmetic as the serial tiling, so pooled results
/// are bitwise identical to serial.
pub fn predict_blocked_pool_f32(
    kern: Kernel,
    x: &MatF32,
    c: &MatF32,
    alpha: &[f64],
    param: f64,
    pool: Option<&WorkerPool>,
    isa: Isa,
) -> Vec<f64> {
    let (n, m) = (x.rows, c.rows);
    assert_eq!(c.cols, x.cols, "feature dims differ");
    assert_eq!(alpha.len(), m);
    let cn = row_sq_norms_f32(c);
    let mut out = vec![0.0; n];
    if n == 0 {
        return out;
    }
    let workers = pool
        .map(|p| p.workers())
        .unwrap_or(1)
        .min(n.div_ceil(DEFAULT_TILE).max(1));
    let ranges = chunk_ranges(n, workers);
    let cn = cn.as_slice();
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(ranges.len());
    let mut rest = out.as_mut_slice();
    for &(lo, hi) in &ranges {
        let (chunk, tail) = rest.split_at_mut(hi - lo);
        rest = tail;
        tasks.push(Box::new(move || {
            predict_range_f32(kern, x, c, cn, alpha, param, lo, hi, chunk, isa);
        }));
    }
    fan_out(pool, tasks);
    out
}

/// Serial tiled f32 predict over rows [start, end) of `x`, writing into
/// `out` (length `end - start`).
#[allow(clippy::too_many_arguments)]
fn predict_range_f32(
    kern: Kernel,
    x: &MatF32,
    c: &MatF32,
    cn: &[f64],
    alpha: &[f64],
    param: f64,
    start: usize,
    end: usize,
    out: &mut [f64],
    isa: Isa,
) {
    let (m, d) = (c.rows, x.cols);
    debug_assert_eq!(out.len(), end - start);
    if start == end {
        return;
    }
    let mut scratch = TileScratch::new32(DEFAULT_TILE.min(end - start), m);
    let xn: Vec<f64> = (start..end)
        .map(|i| {
            let r = x.row(i);
            vec_ops::dot_f32(r, r)
        })
        .collect();
    let mut s = start;
    while s < end {
        let rows = (end - s).min(scratch.tile);
        let kr = &mut scratch.kr32[..rows * m];
        let xb = &x.data[s * d..(s + rows) * d];
        let xnr = &xn[s - start..s - start + rows];
        kernel_panel_f32(kern, xb, d, rows, xnr, c, cn, 0, param, kr, m, isa);
        for i in 0..rows {
            out[s - start + i] = vec_ops::dot_mixed(&kr[i * m..(i + 1) * m], alpha);
        }
        s += rows;
    }
}

#[cfg(test)]
mod tests {
    use super::super::tol;
    use super::super::{
        kernel_block, knm_matmat_blocked, knm_matvec_blocked, predict_blocked, row_sq_norms,
    };
    use super::*;
    use crate::util::ptest::check;

    const KERNELS: [Kernel; 3] = [Kernel::Gaussian, Kernel::Laplacian, Kernel::Linear];

    /// Round f64 data to f32 storage and hand back both the stored block
    /// and its exact f64 widening — the oracle input. Rounding happens
    /// once, here: both tiers then see the *same* values, so observed
    /// differences are purely compute-path error (the tol model), not
    /// storage error.
    fn round_pair(rows: usize, cols: usize, data: &[f64]) -> (MatF32, Mat) {
        let x32 = MatF32::from_f64s(rows, cols, data);
        let x64 = x32.to_mat();
        (x32, x64)
    }

    #[test]
    fn f32_row_norms_accumulate_in_f64() {
        let mut rng = crate::util::rng::Rng::new(71);
        let (n, d) = (37, 9);
        let (x32, x64) = round_pair(n, d, &rng.normals(n * d));
        let got = row_sq_norms_f32(&x32);
        let want = row_sq_norms(&x64);
        for i in 0..n {
            // products of f32s are exact in f64; only summation order may
            // differ between the two dot kernels
            assert!(
                (got[i] - want[i]).abs() <= 1e-12 * (1.0 + want[i].abs()),
                "row {i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }

    #[test]
    fn f32_panel_entries_stay_within_the_entry_bound() {
        // satellite: every kernel arm pinned entry-by-entry against the
        // f64 oracle on identical (rounded) inputs, asserting the
        // *documented* per-kernel bound from kernels::tol — no ad-hoc eps
        check("kernel_block_f32 entries within tol::entry_bound", 20, |g| {
            let (n, m, d) = (g.usize_in(1, 40), g.usize_in(1, 17), g.usize_in(1, 9));
            let (x32, x64) = round_pair(n, d, &g.normal_vec(n * d));
            let (c32, c64) = round_pair(m, d, &g.normal_vec(m * d));
            let p = g.f64_in(0.5, 3.0);
            for kern in KERNELS {
                let bound = tol::entry_bound(kern, &x32, &c32);
                let got = kernel_block_f32(kern, &x32, &c32, p);
                let want = kernel_block(kern, &x64, &c64, p);
                for i in 0..n {
                    for j in 0..m {
                        let diff = (got.row(i)[j] as f64 - want[(i, j)]).abs();
                        assert!(
                            diff <= bound,
                            "{kern:?} entry ({i},{j}): diff {diff:.3e} > bound {bound:.3e}"
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn f32_matvec_matches_f64_oracle_within_model() {
        check("knm_matvec_blocked_f32 within tol::matvec_bound", 20, |g| {
            let (n, m, d) = (g.usize_in(1, 60), g.usize_in(1, 14), g.usize_in(1, 7));
            let (x32, x64) = round_pair(n, d, &g.normal_vec(n * d));
            let (c32, c64) = round_pair(m, d, &g.normal_vec(m * d));
            let u = g.normal_vec(m);
            let v = g.normal_vec(n);
            let p = g.f64_in(0.5, 3.0);
            let xn64 = row_sq_norms(&x64);
            let cn64 = row_sq_norms(&c64);
            let xn32 = row_sq_norms_f32(&x32);
            let cn32 = row_sq_norms_f32(&c32);
            for kern in KERNELS {
                let mut want = vec![0.0; m];
                let mut scratch = TileScratch::new(DEFAULT_TILE, m);
                knm_matvec_blocked(
                    kern, &x64, &c64, &xn64, &cn64, &u, Some(&v), None, p, &mut scratch, &mut want,
                );
                let bound = tol::matvec_bound(kern, &x32, &c32, n, &u, Some(&v));
                // ragged tiles: 1, a middle size, larger-than-n
                for tile in [1usize, 3, 64] {
                    let mut got = vec![0.0; m];
                    let mut s32 = TileScratch::new32(tile, m);
                    knm_matvec_blocked_f32(
                        kern, &x32, &c32, &xn32, &cn32, &u, Some(&v), None, p, &mut s32, &mut got,
                    );
                    let diff = vec_ops::max_abs_diff(&got, &want);
                    assert!(
                        diff <= bound,
                        "{kern:?} tile={tile}: diff {diff:.3e} > bound {bound:.3e}"
                    );
                }
            }
        });
    }

    #[test]
    fn f32_ranged_sweeps_cover_the_blocked_sweep_bitwise() {
        // the pooled fan-out contract, f32 edition: disjoint row ranges
        // summed into one w must equal the full blocked sweep bitwise
        check("ranged_f32 = blocked_f32", 10, |g| {
            let (n, m, d) = (g.usize_in(1, 300), g.usize_in(1, 12), g.usize_in(1, 5));
            let k = g.usize_in(1, 4);
            let (x32, _) = round_pair(n, d, &g.normal_vec(n * d));
            let (c32, _) = round_pair(m, d, &g.normal_vec(m * d));
            let xn = row_sq_norms_f32(&x32);
            let cn = row_sq_norms_f32(&c32);
            let u = g.normal_vec(m);
            let v = g.normal_vec(n);
            let um = Mat::from_vec(m, k, g.normal_vec(m * k));
            let vm = g.normal_vec(n * k);
            let split = g.usize_in(0, n + 1);
            let p = g.f64_in(0.5, 2.5);
            for kern in KERNELS {
                let mut scratch = TileScratch::new32(DEFAULT_TILE, m);
                let mut want = vec![0.0; m];
                knm_matvec_blocked_f32(
                    kern, &x32, &c32, &xn, &cn, &u, Some(&v), None, p, &mut scratch, &mut want,
                );
                let mut got = vec![0.0; m];
                for (lo, hi) in [(0, split), (split, n)] {
                    knm_matvec_ranged_f32(
                        kern,
                        &x32,
                        &c32,
                        &xn,
                        &cn,
                        &u,
                        Some(&v),
                        None,
                        p,
                        &mut scratch,
                        &mut got,
                        lo,
                        hi,
                        Isa::global(),
                    );
                }
                assert_eq!(got, want, "{kern:?} vector split at {split}");

                let mut want_m = Mat::zeros(m, k);
                knm_matmat_blocked_f32(
                    kern, &x32, &c32, &xn, &cn, &um, Some(&vm), None, p, &mut scratch, &mut want_m,
                );
                let mut got_m = Mat::zeros(m, k);
                for (lo, hi) in [(0, split), (split, n)] {
                    knm_matmat_ranged_f32(
                        kern,
                        &x32,
                        &c32,
                        &xn,
                        &cn,
                        &um,
                        Some(&vm),
                        None,
                        p,
                        &mut scratch,
                        &mut got_m,
                        lo,
                        hi,
                        Isa::global(),
                    );
                }
                assert_eq!(got_m.data, want_m.data, "{kern:?} multi split at {split}");
            }
        });
    }

    #[test]
    fn f32_matvec_honors_mask_contract() {
        check("f32 matvec mask contract", 15, |g| {
            let (n, m, d) = (g.usize_in(2, 24), g.usize_in(1, 10), g.usize_in(1, 5));
            let (x32, x64) = round_pair(n, d, &g.normal_vec(n * d));
            let (c32, c64) = round_pair(m, d, &g.normal_vec(m * d));
            let u = g.normal_vec(m);
            let v = g.normal_vec(n);
            let mask: Vec<f64> = (0..n).map(|_| if g.bool() { 1.0 } else { 0.0 }).collect();
            let p = 1.1;
            let kern = *g.pick(&KERNELS);
            let xn64 = row_sq_norms(&x64);
            let cn64 = row_sq_norms(&c64);
            let mut want = vec![0.0; m];
            let mut scratch = TileScratch::new(4, m);
            knm_matvec_blocked(
                kern,
                &x64,
                &c64,
                &xn64,
                &cn64,
                &u,
                Some(&v),
                Some(&mask),
                p,
                &mut scratch,
                &mut want,
            );
            let xn32 = row_sq_norms_f32(&x32);
            let cn32 = row_sq_norms_f32(&c32);
            let mut got = vec![0.0; m];
            let mut s32 = TileScratch::new32(4, m);
            knm_matvec_blocked_f32(
                kern,
                &x32,
                &c32,
                &xn32,
                &cn32,
                &u,
                Some(&v),
                Some(&mask),
                p,
                &mut s32,
                &mut got,
            );
            let bound = tol::matvec_bound(kern, &x32, &c32, n, &u, Some(&v));
            let diff = vec_ops::max_abs_diff(&got, &want);
            assert!(diff <= bound, "{kern:?} diff {diff:.3e} > bound {bound:.3e}");
            // and the v = None path (the CG iteration shape)
            let mut want0 = vec![0.0; m];
            knm_matvec_blocked(
                kern,
                &x64,
                &c64,
                &xn64,
                &cn64,
                &u,
                None,
                Some(&mask),
                p,
                &mut scratch,
                &mut want0,
            );
            let mut got0 = vec![0.0; m];
            knm_matvec_blocked_f32(
                kern,
                &x32,
                &c32,
                &xn32,
                &cn32,
                &u,
                None,
                Some(&mask),
                p,
                &mut s32,
                &mut got0,
            );
            let bound0 = tol::matvec_bound(kern, &x32, &c32, n, &u, None);
            assert!(vec_ops::max_abs_diff(&got0, &want0) <= bound0);
        });
    }

    #[test]
    fn f32_matmat_matches_f64_oracle_within_model() {
        check("knm_matmat_blocked_f32 within tol::matmat_bound", 15, |g| {
            let (n, m, d) = (g.usize_in(1, 40), g.usize_in(1, 12), g.usize_in(1, 6));
            let k = *g.pick(&[1usize, 2, 3, 5, 8]);
            let (x32, x64) = round_pair(n, d, &g.normal_vec(n * d));
            let (c32, c64) = round_pair(m, d, &g.normal_vec(m * d));
            let u = Mat::from_vec(m, k, g.normal_vec(m * k));
            let v = g.normal_vec(n * k);
            let mask: Vec<f64> = (0..n).map(|_| if g.bool() { 1.0 } else { 0.0 }).collect();
            let p = g.f64_in(0.5, 3.0);
            let xn64 = row_sq_norms(&x64);
            let cn64 = row_sq_norms(&c64);
            let xn32 = row_sq_norms_f32(&x32);
            let cn32 = row_sq_norms_f32(&c32);
            for kern in KERNELS {
                for (vopt, maskopt) in [(Some(&v), None), (Some(&v), Some(&mask)), (None, None)] {
                    let mut want = Mat::zeros(m, k);
                    let mut scratch = TileScratch::new(DEFAULT_TILE, m);
                    knm_matmat_blocked(
                        kern,
                        &x64,
                        &c64,
                        &xn64,
                        &cn64,
                        &u,
                        vopt.map(|t| t.as_slice()),
                        maskopt.map(|t| t.as_slice()),
                        p,
                        &mut scratch,
                        &mut want,
                    );
                    let bound =
                        tol::matmat_bound(kern, &x32, &c32, n, &u, vopt.map(|t| t.as_slice()));
                    for tile in [1usize, 5, 64] {
                        let mut got = Mat::zeros(m, k);
                        let mut s32 = TileScratch::new32(tile, m);
                        knm_matmat_blocked_f32(
                            kern,
                            &x32,
                            &c32,
                            &xn32,
                            &cn32,
                            &u,
                            vopt.map(|t| t.as_slice()),
                            maskopt.map(|t| t.as_slice()),
                            p,
                            &mut s32,
                            &mut got,
                        );
                        let diff = got.max_abs_diff(&want);
                        assert!(
                            diff <= bound,
                            "{kern:?} k={k} tile={tile}: diff {diff:.3e} > bound {bound:.3e}"
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn f32_predict_matches_f64_oracle_within_model() {
        check("predict_blocked_f32 within tol::predict_bound", 20, |g| {
            let (n, m, d) = (g.usize_in(1, 30), g.usize_in(1, 12), g.usize_in(1, 6));
            let (x32, x64) = round_pair(n, d, &g.normal_vec(n * d));
            let (c32, c64) = round_pair(m, d, &g.normal_vec(m * d));
            let alpha = g.normal_vec(m);
            let p = g.f64_in(0.5, 3.0);
            for kern in KERNELS {
                let want = predict_blocked(kern, &x64, &c64, &alpha, p);
                let got = predict_blocked_f32(kern, &x32, &c32, &alpha, p);
                let bound = tol::predict_bound(kern, &x32, &c32, &alpha);
                let diff = vec_ops::max_abs_diff(&got, &want);
                assert!(diff <= bound, "{kern:?} diff {diff:.3e} > bound {bound:.3e}");
            }
        });
    }

    #[test]
    fn f32_predict_crosses_default_tile_and_pools_bitwise() {
        let pool = crate::util::pool::WorkerPool::new("test-mixed", 4).unwrap();
        let mut rng = crate::util::rng::Rng::new(83);
        let (n, m, d) = (3 * DEFAULT_TILE + 19, 29, 5);
        let (x32, x64) = round_pair(n, d, &rng.normals(n * d));
        let (c32, c64) = round_pair(m, d, &rng.normals(m * d));
        let alpha = rng.normals(m);
        for kern in KERNELS {
            let serial = predict_blocked_f32(kern, &x32, &c32, &alpha, 1.2);
            let pooled =
                predict_blocked_pool_f32(kern, &x32, &c32, &alpha, 1.2, Some(&pool), Isa::global());
            assert_eq!(serial, pooled, "{kern:?} pooled must be bitwise equal");
            let no_pool =
                predict_blocked_pool_f32(kern, &x32, &c32, &alpha, 1.2, None, Isa::global());
            assert_eq!(serial, no_pool, "{kern:?} inline");
            // and within the model against the f64 oracle across tiles
            let want = predict_blocked(kern, &x64, &c64, &alpha, 1.2);
            let bound = tol::predict_bound(kern, &x32, &c32, &alpha);
            let diff = vec_ops::max_abs_diff(&serial, &want);
            assert!(diff <= bound, "{kern:?} diff {diff:.3e} > bound {bound:.3e}");
        }
    }

    #[test]
    fn f32_matmat_matches_k1_vector_path() {
        // K = 1 degeneracy: the f32 multi-RHS tiling must agree with the
        // f32 vector hot path to f64-accumulation roundoff
        let mut rng = crate::util::rng::Rng::new(89);
        let (n, m, d) = (2 * DEFAULT_TILE + 13, 33, 7);
        let (x32, _) = round_pair(n, d, &rng.normals(n * d));
        let (c32, _) = round_pair(m, d, &rng.normals(m * d));
        let uv = rng.normals(m);
        let u = Mat::from_vec(m, 1, uv.clone());
        let vv = rng.normals(n);
        let xn = row_sq_norms_f32(&x32);
        let cn = row_sq_norms_f32(&c32);
        for kern in KERNELS {
            let mut scratch = TileScratch::new32(DEFAULT_TILE, m);
            let mut want = vec![0.0; m];
            knm_matvec_blocked_f32(
                kern, &x32, &c32, &xn, &cn, &uv, Some(&vv), None, 1.4, &mut scratch, &mut want,
            );
            let mut got = Mat::zeros(m, 1);
            knm_matmat_blocked_f32(
                kern, &x32, &c32, &xn, &cn, &u, Some(&vv), None, 1.4, &mut scratch, &mut got,
            );
            for j in 0..m {
                assert!(
                    (got[(j, 0)] - want[j]).abs() < 1e-9 * (1.0 + want[j].abs()),
                    "{kern:?} j={j}"
                );
            }
        }
    }

    // -- SIMD-vs-scalar arms, f32 tier -------------------------------------
    //
    // Same contract as the f64 tests in the parent module: detect_best()
    // (immune to FALKON_SIMD) pinned against an explicit Isa::Scalar.

    #[test]
    fn f32_simd_panels_match_scalar_within_tol_model() {
        let isa = Isa::detect_best();
        if isa == Isa::Scalar {
            eprintln!("[simd] no vector arm on this host; f32 SIMD panel test is vacuous");
        }
        check("f32 SIMD panels = scalar within tol", 20, |g| {
            let (n, m, d) = (g.usize_in(1, 40), g.usize_in(1, 17), g.usize_in(1, 9));
            let (x32, _) = round_pair(n, d, &g.normal_vec(n * d));
            let (c32, _) = round_pair(m, d, &g.normal_vec(m * d));
            let p = g.f64_in(0.5, 3.0);
            let xn = row_sq_norms_f32(&x32);
            let cn = row_sq_norms_f32(&c32);
            for kern in KERNELS {
                // drive the panel entry point directly through both arms
                // (whole block as one panel, j0 = 0, ldo = m) so the
                // 4-center groups, ragged tails and exp pass all run
                let run = |arm: Isa| {
                    let mut out = vec![0.0f32; n * m];
                    let xnr: &[f64] = match kern {
                        Kernel::Gaussian => &xn,
                        _ => &[],
                    };
                    kernel_panel_f32(
                        kern, &x32.data, d, n, xnr, &c32, &cn, 0, p, &mut out, m, arm,
                    );
                    out
                };
                let got = run(isa);
                let want = run(Isa::Scalar);
                let bound = tol::simd_entry_bound_f32(kern, &x32, &c32);
                for (i, (gv, wv)) in got.iter().zip(&want).enumerate() {
                    let diff = (*gv as f64 - *wv as f64).abs();
                    assert!(
                        diff <= bound,
                        "{kern:?} {isa:?} entry {i}: diff={diff:e} > bound={bound:e}"
                    );
                }
            }
        });
    }

    #[test]
    fn f32_simd_sweeps_and_predict_match_scalar_within_model() {
        let isa = Isa::detect_best();
        if isa == Isa::Scalar {
            eprintln!("[simd] no vector arm on this host; f32 SIMD sweep test is vacuous");
        }
        let pool = crate::util::pool::WorkerPool::new("test-mixed-simd", 4).unwrap();
        check("f32 SIMD sweeps = scalar within tol", 10, |g| {
            let (n, m, d) = (g.usize_in(1, 60), g.usize_in(1, 14), g.usize_in(1, 7));
            let k = g.usize_in(1, 4);
            let (x32, _) = round_pair(n, d, &g.normal_vec(n * d));
            let (c32, _) = round_pair(m, d, &g.normal_vec(m * d));
            let xn = row_sq_norms_f32(&x32);
            let cn = row_sq_norms_f32(&c32);
            let u = g.normal_vec(m);
            let v = g.normal_vec(n);
            let um = Mat::from_vec(m, k, g.normal_vec(m * k));
            let vm = g.normal_vec(n * k);
            let alpha = g.normal_vec(m);
            let p = g.f64_in(0.5, 2.5);
            let tile = *g.pick(&[1usize, 5, 7, DEFAULT_TILE]);
            for kern in KERNELS {
                // SIMD-f32 vs scalar-f32 differs by strictly less than
                // either differs from the f64 oracle, so the documented
                // f32-tier bounds are valid (conservative) here too
                let run_vec = |arm: Isa| {
                    let mut scratch = TileScratch::new32(tile, m);
                    let mut w = vec![0.0; m];
                    knm_matvec_ranged_f32(
                        kern,
                        &x32,
                        &c32,
                        &xn,
                        &cn,
                        &u,
                        Some(&v),
                        None,
                        p,
                        &mut scratch,
                        &mut w,
                        0,
                        n,
                        arm,
                    );
                    w
                };
                let bound = tol::matvec_bound(kern, &x32, &c32, n, &u, Some(&v));
                let diff = vec_ops::max_abs_diff(&run_vec(isa), &run_vec(Isa::Scalar));
                assert!(
                    diff <= bound,
                    "{kern:?} {isa:?} f32 matvec tile={tile}: diff={diff:e} > bound={bound:e}"
                );

                let run_mat = |arm: Isa| {
                    let mut scratch = TileScratch::new32(tile, m);
                    let mut w = Mat::zeros(m, k);
                    knm_matmat_ranged_f32(
                        kern,
                        &x32,
                        &c32,
                        &xn,
                        &cn,
                        &um,
                        Some(&vm),
                        None,
                        p,
                        &mut scratch,
                        &mut w,
                        0,
                        n,
                        arm,
                    );
                    w
                };
                let bound_m = tol::matmat_bound(kern, &x32, &c32, n, &um, Some(&vm));
                let diff_m = run_mat(isa).max_abs_diff(&run_mat(Isa::Scalar));
                assert!(
                    diff_m <= bound_m,
                    "{kern:?} {isa:?} f32 matmat tile={tile}: diff={diff_m:e} > bound={bound_m:e}"
                );

                // predict: pooled bitwise within the SIMD arm, tol-bounded
                // against the scalar arm
                let serial = predict_blocked_pool_f32(kern, &x32, &c32, &alpha, p, None, isa);
                let pooled =
                    predict_blocked_pool_f32(kern, &x32, &c32, &alpha, p, Some(&pool), isa);
                assert_eq!(serial, pooled, "{kern:?} pooled vs serial under {isa:?}");
                let scalar =
                    predict_blocked_pool_f32(kern, &x32, &c32, &alpha, p, None, Isa::Scalar);
                let bound_p = tol::predict_bound(kern, &x32, &c32, &alpha);
                let diff_p = vec_ops::max_abs_diff(&serial, &scalar);
                assert!(
                    diff_p <= bound_p,
                    "{kern:?} {isa:?} f32 predict: diff={diff_p:e} > bound={bound_p:e}"
                );
            }
        });
    }
}

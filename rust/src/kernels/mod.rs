//! Pure-Rust kernel function evaluation — the reference implementation the
//! XLA artifacts are cross-checked against, the compute engine of the
//! fallback [`crate::runtime::Engine::Rust`] path, and the "kernel computed
//! on the fly" baseline from the paper's Table 1 discussion.
//!
//! Two tiers live here (DESIGN.md §Perf):
//!
//! - **reference**: [`Kernel::eval`], [`kernel_block_ref`], [`knm_matvec`],
//!   [`knm_matmat`], [`predict`], [`predict_multi`] — row-at-a-time, libm
//!   `exp`, deliberately simple. These are the oracles the property tests
//!   pin everything else to.
//! - **tiled hot path**: [`knm_matvec_blocked`], [`knm_matmat_blocked`],
//!   [`predict_blocked`], [`predict_multi_blocked`],
//!   [`kernel_block`], [`kmm`] — panel-of-rows tiles with the
//!   ‖x‖²+‖c‖²−2x·c norm expansion (the inner loop is a 1×4 register tile
//!   of dot products, same structure as the Pallas tile), a reusable Kr
//!   tile buffer ([`TileScratch`]) and the vectorizable
//!   [`crate::linalg::vec_ops::fast_exp`] in *every* kernel family's
//!   exponential arm. The runtime's `MatvecPlan` drives the fused matvec
//!   every CG iteration; dense blocks (`kernel_block`, `kmm`) write
//!   panels straight into the output matrix, fan row blocks out over the
//!   shared [`WorkerPool`], and `kmm` computes only the upper triangle of
//!   the symmetric K_MM then mirrors it (DESIGN.md §Perf "Setup path").
//!
//! The `*_matmat` / `*_multi` variants are the multi-RHS generalization
//! (DESIGN.md §Perf "Multi-RHS path"): the one-vs-all multiclass solve
//! runs K right-hand sides against the *same* Kr panels, so each panel
//! is computed once per tile and streamed through a K-column GEMM
//! (`Y = Kr·U + V`, `W += Krᵀ·Y`) instead of K separate GEMV sweeps —
//! K·t panel sweeps per fit become t.
//!
//! The **mixed-precision tier** lives in [`mixed`]: the same tilings
//! reading `f32` feature storage with every reduction accumulated in
//! `f64`, under the documented error model of [`tol`] (DESIGN.md
//! §"Precision model"). This module stays the property-test oracle.

use crate::linalg::mat::Mat;
use crate::linalg::vec_ops::{self, fast_exp};
use crate::util::pool::{chunk_ranges, chunk_ranges_weighted, fan_out, WorkerPool};

pub mod mixed;
pub mod simd;
pub mod tile;
pub mod tol;

pub use tile::{TileScratch, DEFAULT_TILE};

use simd::Isa;

/// Kernel families supported end-to-end (python oracle, Pallas kernels,
/// artifacts and this module must stay in sync — tested both sides).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Kernel {
    /// K(x,c) = exp(-‖x-c‖² / 2σ²) — the paper's main kernel (Sect. 5).
    Gaussian,
    /// K(x,c) = exp(-‖x-c‖₁ / σ).
    Laplacian,
    /// K(x,c) = ⟨x,c⟩ — used for the YELP experiment.
    Linear,
}

impl Kernel {
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Gaussian => "gaussian",
            Kernel::Laplacian => "laplacian",
            Kernel::Linear => "linear",
        }
    }

    pub fn parse(s: &str) -> Option<Kernel> {
        match s {
            "gaussian" | "rbf" => Some(Kernel::Gaussian),
            "laplacian" => Some(Kernel::Laplacian),
            "linear" => Some(Kernel::Linear),
            _ => None,
        }
    }

    /// Upper bound κ² on K(x,x) (paper's boundedness assumption). For the
    /// linear kernel it depends on the data, so None.
    pub fn kappa_sq(self) -> Option<f64> {
        match self {
            Kernel::Gaussian | Kernel::Laplacian => Some(1.0),
            Kernel::Linear => None,
        }
    }

    /// Evaluate K(x, c) for two points (reference path).
    #[inline]
    pub fn eval(self, x: &[f64], c: &[f64], param: f64) -> f64 {
        debug_assert_eq!(x.len(), c.len());
        match self {
            Kernel::Gaussian => {
                let mut sq = 0.0;
                for i in 0..x.len() {
                    let d = x[i] - c[i];
                    sq += d * d;
                }
                (-sq / (2.0 * param * param)).exp()
            }
            Kernel::Laplacian => {
                let mut l1 = 0.0;
                for i in 0..x.len() {
                    l1 += (x[i] - c[i]).abs();
                }
                (-l1 / param).exp()
            }
            Kernel::Linear => {
                let mut d = 0.0;
                for i in 0..x.len() {
                    d += x[i] * c[i];
                }
                d
            }
        }
    }
}

/// Squared L2 norm of every row — precomputed once per plan/block so the
/// Gaussian panels never recompute them inside the apply loop.
pub fn row_sq_norms(x: &Mat) -> Vec<f64> {
    (0..x.rows)
        .map(|i| {
            let r = x.row(i);
            vec_ops::dot(r, r)
        })
        .collect()
}

/// Dense kernel block K(X, C) -> (X.rows × C.rows) — **reference** path
/// (libm `exp` via [`Kernel::eval`] for the non-Gaussian arms), the
/// oracle the tiled [`kernel_block`] is property-tested against.
pub fn kernel_block_ref(kern: Kernel, x: &Mat, c: &Mat, param: f64) -> Mat {
    assert_eq!(x.cols, c.cols, "feature dims differ");
    let mut out = Mat::zeros(x.rows, c.rows);
    match kern {
        Kernel::Gaussian => {
            let xn = row_sq_norms(x);
            let cn = row_sq_norms(c);
            let inv = 1.0 / (2.0 * param * param);
            for i in 0..x.rows {
                let xr = x.row(i);
                let orow = out.row_mut(i);
                for j in 0..c.rows {
                    let dot = vec_ops::dot(xr, c.row(j));
                    let sq = (xn[i] + cn[j] - 2.0 * dot).max(0.0);
                    orow[j] = (-sq * inv).exp();
                }
            }
        }
        _ => {
            for i in 0..x.rows {
                let xr = x.row(i);
                let orow = out.row_mut(i);
                for j in 0..c.rows {
                    orow[j] = kern.eval(xr, c.row(j), param);
                }
            }
        }
    }
    out
}

/// Dense kernel block K(X, C) on the tiled panel machinery (serial,
/// process-default ISA).
pub fn kernel_block(kern: Kernel, x: &Mat, c: &Mat, param: f64) -> Mat {
    kernel_block_par(kern, x, c, param, None, Isa::global())
}

/// [`kernel_block`] with row blocks fanned out over the shared worker
/// pool. Panels are written straight into the output matrix (no Kr
/// staging buffer), every exponential arm goes through `fast_exp`, and
/// each output row is produced by exactly one task — pooled results are
/// bitwise equal to serial.
pub fn kernel_block_par(
    kern: Kernel,
    x: &Mat,
    c: &Mat,
    param: f64,
    pool: Option<&WorkerPool>,
    isa: Isa,
) -> Mat {
    assert_eq!(x.cols, c.cols, "feature dims differ");
    let (n, m, d) = (x.rows, c.rows, x.cols);
    let mut out = Mat::zeros(n, m);
    if n == 0 || m == 0 {
        return out;
    }
    let cn = match kern {
        Kernel::Gaussian => row_sq_norms(c),
        _ => Vec::new(),
    };
    let xn = match kern {
        Kernel::Gaussian => row_sq_norms(x),
        _ => Vec::new(),
    };
    let workers = pool.map(|p| p.workers()).unwrap_or(1);
    let ranges = chunk_ranges(n, workers);
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(ranges.len());
    let mut rest = out.data.as_mut_slice();
    let (cn, xn) = (cn.as_slice(), xn.as_slice());
    for &(lo, hi) in &ranges {
        let (chunk, tail) = rest.split_at_mut((hi - lo) * m);
        rest = tail;
        tasks.push(Box::new(move || {
            let mut s = lo;
            while s < hi {
                let rows = (hi - s).min(DEFAULT_TILE);
                let xb = &x.data[s * d..(s + rows) * d];
                let xnr = match kern {
                    Kernel::Gaussian => &xn[s..s + rows],
                    _ => &[] as &[f64],
                };
                kernel_panel(
                    kern,
                    xb,
                    d,
                    rows,
                    xnr,
                    c,
                    cn,
                    0,
                    param,
                    &mut chunk[(s - lo) * m..],
                    m,
                    isa,
                );
                s += rows;
            }
        }));
    }
    fan_out(pool, tasks);
    out
}

/// K_MM over the centers (tiled, serial, process-default ISA).
pub fn kmm(kern: Kernel, c: &Mat, param: f64) -> Mat {
    kmm_par(kern, c, param, None, Isa::global())
}

/// K_MM on the panel machinery, exploiting symmetry: each row block
/// computes only columns j ≥ block start (the upper triangle plus a
/// ≤TILE-wide sliver below the diagonal), then the strict lower triangle
/// is mirrored from the upper. Row blocks fan out over the pool; the
/// mirror pass makes K_MM exactly symmetric, which the reference
/// (computing both sides independently) only is to rounding.
pub fn kmm_par(kern: Kernel, c: &Mat, param: f64, pool: Option<&WorkerPool>, isa: Isa) -> Mat {
    let (m, d) = (c.rows, c.cols);
    let mut out = Mat::zeros(m, m);
    if m == 0 {
        return out;
    }
    let cn = match kern {
        Kernel::Gaussian => row_sq_norms(c),
        _ => Vec::new(),
    };
    let cn = cn.as_slice();
    let workers = pool.map(|p| p.workers()).unwrap_or(1);
    // chunk by panel so a task's panels start at its first row: columns
    // [panel start, m) then cover everything on/right of the diagonal.
    // Panel p evaluates ~TILE·(m - p·TILE) kernels, so chunks are
    // weighted by triangle area rather than panel count.
    let npanels = m.div_ceil(DEFAULT_TILE);
    let ranges = chunk_ranges_weighted(npanels, workers, |p| (m - p * DEFAULT_TILE) as u64);
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(ranges.len());
    let mut rest = out.data.as_mut_slice();
    let mut consumed = 0usize;
    for &(plo, phi) in &ranges {
        let (rlo, rhi) = ((plo * DEFAULT_TILE).min(m), (phi * DEFAULT_TILE).min(m));
        let (chunk, tail) = rest.split_at_mut((rhi - rlo) * m);
        rest = tail;
        debug_assert_eq!(consumed, rlo * m);
        consumed += chunk.len();
        tasks.push(Box::new(move || {
            let mut s = rlo;
            while s < rhi {
                let rows = (rhi - s).min(DEFAULT_TILE);
                let xb = &c.data[s * d..(s + rows) * d];
                let xn = match kern {
                    Kernel::Gaussian => &cn[s..s + rows],
                    _ => &[] as &[f64],
                };
                // row i of the panel writes columns [s, m) at offset
                // (i - rlo)·m + s inside the chunk
                kernel_panel(
                    kern,
                    xb,
                    d,
                    rows,
                    xn,
                    c,
                    cn,
                    s,
                    param,
                    &mut chunk[(s - rlo) * m + s..],
                    m,
                    isa,
                );
                s += rows;
            }
        }));
    }
    fan_out(pool, tasks);
    out.mirror_upper();
    out
}

/// The FALKON block op w = Krᵀ(mask ⊙ (Kr·u + v)) computed on the fly
/// without materializing Kr (row-at-a-time) — the **reference** the tiled
/// [`knm_matvec_blocked`] is property-tested against, including the mask
/// contract (masked rows are skipped entirely, not multiplied by zero).
pub fn knm_matvec(
    kern: Kernel,
    x: &Mat,
    c: &Mat,
    u: &[f64],
    v: &[f64],
    mask: Option<&[f64]>,
    param: f64,
) -> Vec<f64> {
    assert_eq!(u.len(), c.rows);
    assert_eq!(v.len(), x.rows);
    let mut w = vec![0.0; c.rows];
    let mut krow = vec![0.0; c.rows];
    for i in 0..x.rows {
        let mi = mask.map(|m| m[i]).unwrap_or(1.0);
        if mi == 0.0 {
            continue;
        }
        let xr = x.row(i);
        for j in 0..c.rows {
            krow[j] = kern.eval(xr, c.row(j), param);
        }
        let yi = mi * (vec_ops::dot(&krow, u) + v[i]);
        vec_ops::axpy(yi, &krow, &mut w);
    }
    w
}

/// Multi-RHS generalization of [`knm_matvec`]: W = Krᵀ(mask ⊙ (Kr·U + V))
/// with U an `M×K` coefficient block, V an `n×K` offset block and W the
/// `M×K` result — **reference** path (row-at-a-time, libm `exp`), the
/// oracle [`knm_matmat_blocked`] is property-tested against. The mask
/// contract matches the vector version: masked rows contribute nothing.
pub fn knm_matmat(
    kern: Kernel,
    x: &Mat,
    c: &Mat,
    u: &Mat,
    v: Option<&Mat>,
    mask: Option<&[f64]>,
    param: f64,
) -> Mat {
    let (n, m) = (x.rows, c.rows);
    let k = u.cols;
    assert_eq!(u.rows, m, "u rows != centers");
    if let Some(v) = v {
        assert_eq!(v.rows, n, "v rows != x rows");
        assert_eq!(v.cols, k, "v cols != u cols");
    }
    let mut w = Mat::zeros(m, k);
    let mut krow = vec![0.0; m];
    let mut yrow = vec![0.0; k];
    for i in 0..n {
        let mi = mask.map(|mk| mk[i]).unwrap_or(1.0);
        if mi == 0.0 {
            continue;
        }
        let xr = x.row(i);
        for j in 0..m {
            krow[j] = kern.eval(xr, c.row(j), param);
        }
        // yrow = mi * (krowᵀ·U + v_i)
        match v {
            Some(v) => yrow.copy_from_slice(v.row(i)),
            None => yrow.fill(0.0),
        }
        for j in 0..m {
            vec_ops::axpy(krow[j], u.row(j), &mut yrow);
        }
        if mi != 1.0 {
            vec_ops::scale(mi, &mut yrow);
        }
        // W += krow ⊗ yrow
        for j in 0..m {
            vec_ops::axpy(krow[j], &yrow, w.row_mut(j));
        }
    }
    w
}

/// Predictions f(x_i) = Σ_j α_j K(x_i, c_j) for a block of rows —
/// **reference** path for [`predict_blocked`].
pub fn predict(kern: Kernel, x: &Mat, c: &Mat, alpha: &[f64], param: f64) -> Vec<f64> {
    assert_eq!(alpha.len(), c.rows);
    let mut out = vec![0.0; x.rows];
    for i in 0..x.rows {
        let xr = x.row(i);
        let mut acc = 0.0;
        for j in 0..c.rows {
            acc += alpha[j] * kern.eval(xr, c.row(j), param);
        }
        out[i] = acc;
    }
    out
}

/// Multi-output predictions F = Kr·A for an `M×K` coefficient block
/// (column k = class k's α) — **reference** path for
/// [`predict_multi_blocked`]. Returns `n×K`.
pub fn predict_multi(kern: Kernel, x: &Mat, c: &Mat, alpha: &Mat, param: f64) -> Mat {
    assert_eq!(alpha.rows, c.rows, "alpha rows != centers");
    let k = alpha.cols;
    let mut out = Mat::zeros(x.rows, k);
    for i in 0..x.rows {
        let xr = x.row(i);
        for j in 0..c.rows {
            let kv = kern.eval(xr, c.row(j), param);
            vec_ops::axpy(kv, alpha.row(j), out.row_mut(i));
        }
    }
    out
}

// ---------------------------------------------------------------------
// tiled hot path
// ---------------------------------------------------------------------

/// Fill a panel of kernel values K(X_panel, C[j0..]) into `out` through
/// the selected instruction-set arm. The tiling geometry and the layout
/// contract (`j0`, `ldo`, see [`kernel_panel_scalar`]) are identical on
/// every arm; the SIMD arms differ from scalar only by FMA contraction
/// and lane-order reassociation in the dot products ([`tol`]'s SIMD
/// bounds), while their exponential lanes stay bitwise equal to
/// `fast_exp`.
#[allow(clippy::too_many_arguments)]
fn kernel_panel(
    kern: Kernel,
    xb: &[f64],
    d: usize,
    rows: usize,
    xn: &[f64],
    c: &Mat,
    cn: &[f64],
    j0: usize,
    param: f64,
    out: &mut [f64],
    ldo: usize,
    isa: Isa,
) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Isa::Avx2 is only produced by simd::resolve()/detect_best()
        // after runtime detection confirmed avx2+fma on this host.
        Isa::Avx2 => unsafe {
            simd::avx2::kernel_panel_avx2(kern, xb, d, rows, xn, c, cn, j0, param, out, ldo)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is a baseline feature of every aarch64 target.
        Isa::Neon => unsafe {
            simd::neon::kernel_panel_neon(kern, xb, d, rows, xn, c, cn, j0, param, out, ldo)
        },
        _ => kernel_panel_scalar(kern, xb, d, rows, xn, c, cn, j0, param, out, ldo),
    }
}

/// Scalar arm of [`kernel_panel`] — and the oracle the SIMD arms are
/// property-tested against. `xb` is
/// the row-major `rows × d` panel, `xn`/`cn` the precomputed squared row
/// norms (only read by the Gaussian kernel). Row `i` of the panel is
/// written at `out[i*ldo .. i*ldo + (M - j0)]` — `ldo` lets callers
/// stream panels straight into a larger matrix (the dense `kernel_block`
/// / `kmm` paths) and `j0` restricts to columns on/after the diagonal
/// (the `kmm` symmetry trick). The Gaussian/linear inner loop is a 1×4
/// register tile of dot products over four center rows; the exponentials
/// run in a separate branch-free pass over the finished row so LLVM can
/// vectorize them (`fast_exp`).
#[allow(clippy::too_many_arguments)]
fn kernel_panel_scalar(
    kern: Kernel,
    xb: &[f64],
    d: usize,
    rows: usize,
    xn: &[f64],
    c: &Mat,
    cn: &[f64],
    j0: usize,
    param: f64,
    out: &mut [f64],
    ldo: usize,
) {
    let m = c.rows;
    let w = m - j0;
    debug_assert_eq!(xb.len(), rows * d);
    debug_assert_eq!(c.cols, d);
    debug_assert!(rows == 0 || out.len() >= (rows - 1) * ldo + w);
    debug_assert!(ldo >= w);
    match kern {
        Kernel::Gaussian => {
            debug_assert_eq!(xn.len(), rows);
            debug_assert_eq!(cn.len(), m);
            let inv = 1.0 / (2.0 * param * param);
            for i in 0..rows {
                let xr = &xb[i * d..(i + 1) * d];
                let xni = xn[i];
                let orow = &mut out[i * ldo..i * ldo + w];
                let mut j = j0;
                while j + 4 <= m {
                    let c0 = c.row(j);
                    let c1 = c.row(j + 1);
                    let c2 = c.row(j + 2);
                    let c3 = c.row(j + 3);
                    let (mut a0, mut a1, mut a2, mut a3) = (0.0, 0.0, 0.0, 0.0);
                    for k in 0..d {
                        let xv = xr[k];
                        a0 += xv * c0[k];
                        a1 += xv * c1[k];
                        a2 += xv * c2[k];
                        a3 += xv * c3[k];
                    }
                    orow[j - j0] = (xni + cn[j] - 2.0 * a0).max(0.0);
                    orow[j - j0 + 1] = (xni + cn[j + 1] - 2.0 * a1).max(0.0);
                    orow[j - j0 + 2] = (xni + cn[j + 2] - 2.0 * a2).max(0.0);
                    orow[j - j0 + 3] = (xni + cn[j + 3] - 2.0 * a3).max(0.0);
                    j += 4;
                }
                while j < m {
                    let dotv = vec_ops::dot(xr, c.row(j));
                    orow[j - j0] = (xni + cn[j] - 2.0 * dotv).max(0.0);
                    j += 1;
                }
                for v in orow.iter_mut() {
                    *v = fast_exp(-*v * inv);
                }
            }
        }
        Kernel::Laplacian => {
            let inv = 1.0 / param;
            for i in 0..rows {
                let xr = &xb[i * d..(i + 1) * d];
                let orow = &mut out[i * ldo..i * ldo + w];
                for j in j0..m {
                    let cr = c.row(j);
                    let mut l1 = 0.0;
                    for k in 0..d {
                        l1 += (xr[k] - cr[k]).abs();
                    }
                    orow[j - j0] = -l1 * inv;
                }
                for v in orow.iter_mut() {
                    *v = fast_exp(*v);
                }
            }
        }
        Kernel::Linear => {
            for i in 0..rows {
                let xr = &xb[i * d..(i + 1) * d];
                let orow = &mut out[i * ldo..i * ldo + w];
                let mut j = j0;
                while j + 4 <= m {
                    let c0 = c.row(j);
                    let c1 = c.row(j + 1);
                    let c2 = c.row(j + 2);
                    let c3 = c.row(j + 3);
                    let (mut a0, mut a1, mut a2, mut a3) = (0.0, 0.0, 0.0, 0.0);
                    for k in 0..d {
                        let xv = xr[k];
                        a0 += xv * c0[k];
                        a1 += xv * c1[k];
                        a2 += xv * c2[k];
                        a3 += xv * c3[k];
                    }
                    orow[j - j0] = a0;
                    orow[j - j0 + 1] = a1;
                    orow[j - j0 + 2] = a2;
                    orow[j - j0 + 3] = a3;
                    j += 4;
                }
                while j < m {
                    orow[j - j0] = vec_ops::dot(xr, c.row(j));
                    j += 1;
                }
            }
        }
    }
}

/// Tiled/fused w += Krᵀ(mask ⊙ (Kr·u + v)) over the rows of `x`.
///
/// Accumulates into `w` (callers zero it; the plan sums several blocks
/// into one output). `xn`/`cn` are precomputed squared row norms of
/// `x`/`c`. `v`/`mask` are indexed by local row (same length as `x.rows`).
/// Rows whose fused weight y_i is exactly zero — in particular every
/// masked row — are skipped in the accumulation pass, matching the
/// reference mask contract. No heap allocation happens here: the Kr tile
/// and y live in `scratch`.
#[allow(clippy::too_many_arguments)]
pub fn knm_matvec_blocked(
    kern: Kernel,
    x: &Mat,
    c: &Mat,
    xn: &[f64],
    cn: &[f64],
    u: &[f64],
    v: Option<&[f64]>,
    mask: Option<&[f64]>,
    param: f64,
    scratch: &mut TileScratch,
    w: &mut [f64],
) {
    knm_matvec_ranged(
        kern,
        x,
        c,
        xn,
        cn,
        u,
        v,
        mask,
        param,
        scratch,
        w,
        0,
        x.rows,
        Isa::global(),
    )
}

/// [`knm_matvec_blocked`] restricted to rows `[start, end)` of `x`
/// (`xn`/`v`/`mask` stay indexed by full rows of `x`). This is how the
/// out-of-core plan fans one resident chunk out over the worker pool:
/// each worker sweeps a disjoint row range of the *same* chunk, so the
/// chunk is never copied per worker.
#[allow(clippy::too_many_arguments)]
pub fn knm_matvec_ranged(
    kern: Kernel,
    x: &Mat,
    c: &Mat,
    xn: &[f64],
    cn: &[f64],
    u: &[f64],
    v: Option<&[f64]>,
    mask: Option<&[f64]>,
    param: f64,
    scratch: &mut TileScratch,
    w: &mut [f64],
    start: usize,
    end: usize,
    isa: Isa,
) {
    let (n, m, d) = (x.rows, c.rows, x.cols);
    assert_eq!(c.cols, d, "feature dims differ");
    assert!(start <= end && end <= n, "row range {start}..{end} of {n}");
    assert_eq!(u.len(), m);
    assert_eq!(w.len(), m);
    assert_eq!(xn.len(), n);
    assert_eq!(cn.len(), m);
    if let Some(v) = v {
        assert_eq!(v.len(), n);
    }
    if let Some(mk) = mask {
        assert_eq!(mk.len(), n);
    }
    scratch.ensure(m);
    let tile = scratch.tile;
    let mut s = start;
    while s < end {
        let rows = (end - s).min(tile);
        let kr = &mut scratch.kr[..rows * m];
        let xb = &x.data[s * d..(s + rows) * d];
        kernel_panel(kern, xb, d, rows, &xn[s..s + rows], c, cn, 0, param, kr, m, isa);
        // fused stage 1: y = mask ⊙ (Kr·u + v)
        for i in 0..rows {
            let gi = s + i;
            let mi = mask.map(|mk| mk[gi]).unwrap_or(1.0);
            if mi == 0.0 {
                scratch.y[i] = 0.0;
                continue;
            }
            let dotu = vec_ops::dot(&kr[i * m..(i + 1) * m], u);
            let vi = v.map(|vf| vf[gi]).unwrap_or(0.0);
            scratch.y[i] = mi * (dotu + vi);
        }
        // fused stage 2: w += Krᵀ·y (masked / zero-weight rows skipped)
        for i in 0..rows {
            let yi = scratch.y[i];
            if yi != 0.0 {
                vec_ops::axpy(yi, &kr[i * m..(i + 1) * m], w);
            }
        }
        s += rows;
    }
}

/// `out[i·K .. (i+1)·K] += Kr[i,:]·U` for every panel row i — the shared
/// K-column GEMM of the multi-RHS stages ([`knm_matmat_blocked`] stage 1,
/// [`predict_multi_blocked`]). The inner loop is a 4-center register tile:
/// four Kr entries each scale a contiguous K-row of U into the K-wide
/// accumulator, so LLVM vectorizes across the K columns.
fn panel_times_mat(kr: &[f64], rows: usize, m: usize, u: &Mat, out: &mut [f64]) {
    let k = u.cols;
    debug_assert_eq!(u.rows, m);
    debug_assert!(out.len() >= rows * k);
    for i in 0..rows {
        let kri = &kr[i * m..(i + 1) * m];
        let orow = &mut out[i * k..(i + 1) * k];
        let mut j = 0;
        while j + 4 <= m {
            let (a0, a1, a2, a3) = (kri[j], kri[j + 1], kri[j + 2], kri[j + 3]);
            let u0 = u.row(j);
            let u1 = u.row(j + 1);
            let u2 = u.row(j + 2);
            let u3 = u.row(j + 3);
            for t in 0..k {
                orow[t] += a0 * u0[t] + a1 * u1[t] + a2 * u2[t] + a3 * u3[t];
            }
            j += 4;
        }
        while j < m {
            vec_ops::axpy(kri[j], u.row(j), orow);
            j += 1;
        }
    }
}

/// Tiled/fused W += Krᵀ(mask ⊙ (Kr·U + V)) over the rows of `x` — the
/// multi-RHS generalization of [`knm_matvec_blocked`]. Each Kr panel is
/// computed **once** and streamed through both K-column stages, so K
/// right-hand sides cost one panel sweep instead of K.
///
/// `u` is `M×K`; `v` (when present) is the row-major `x.rows × K` offset
/// block indexed by local row, matching the vector version's `v` contract;
/// `w` is `M×K` and accumulated into (callers zero it). Rows whose fused
/// Y-row is entirely zero — in particular every masked row — are skipped
/// in the accumulation pass.
#[allow(clippy::too_many_arguments)]
pub fn knm_matmat_blocked(
    kern: Kernel,
    x: &Mat,
    c: &Mat,
    xn: &[f64],
    cn: &[f64],
    u: &Mat,
    v: Option<&[f64]>,
    mask: Option<&[f64]>,
    param: f64,
    scratch: &mut TileScratch,
    w: &mut Mat,
) {
    knm_matmat_ranged(
        kern,
        x,
        c,
        xn,
        cn,
        u,
        v,
        mask,
        param,
        scratch,
        w,
        0,
        x.rows,
        Isa::global(),
    )
}

/// [`knm_matmat_blocked`] restricted to rows `[start, end)` of `x` — the
/// multi-RHS counterpart of [`knm_matvec_ranged`], used by the
/// out-of-core plan to fan a resident chunk over the pool without
/// per-worker copies. `xn`/`v`/`mask` stay indexed by full rows of `x`.
#[allow(clippy::too_many_arguments)]
pub fn knm_matmat_ranged(
    kern: Kernel,
    x: &Mat,
    c: &Mat,
    xn: &[f64],
    cn: &[f64],
    u: &Mat,
    v: Option<&[f64]>,
    mask: Option<&[f64]>,
    param: f64,
    scratch: &mut TileScratch,
    w: &mut Mat,
    start: usize,
    end: usize,
    isa: Isa,
) {
    let (n, m, d) = (x.rows, c.rows, x.cols);
    let k = u.cols;
    assert_eq!(c.cols, d, "feature dims differ");
    assert!(start <= end && end <= n, "row range {start}..{end} of {n}");
    assert_eq!(u.rows, m, "u rows != centers");
    assert_eq!((w.rows, w.cols), (m, k), "w shape");
    assert_eq!(xn.len(), n);
    assert_eq!(cn.len(), m);
    if let Some(v) = v {
        assert_eq!(v.len(), n * k, "v length != n·K");
    }
    if let Some(mk) = mask {
        assert_eq!(mk.len(), n);
    }
    if k == 0 {
        return;
    }
    scratch.ensure_multi(m, k);
    let tile = scratch.tile;
    let TileScratch { kr, y, .. } = scratch;
    let mut s = start;
    while s < end {
        let rows = (end - s).min(tile);
        let kr = &mut kr[..rows * m];
        let xb = &x.data[s * d..(s + rows) * d];
        kernel_panel(kern, xb, d, rows, &xn[s..s + rows], c, cn, 0, param, kr, m, isa);
        // fused stage 1: Y = mask ⊙ (Kr·U + V)   (rows × K)
        let y = &mut y[..rows * k];
        for i in 0..rows {
            let gi = s + i;
            let yrow = &mut y[i * k..(i + 1) * k];
            let mi = mask.map(|mk| mk[gi]).unwrap_or(1.0);
            if mi == 0.0 {
                yrow.fill(0.0);
                continue;
            }
            match v {
                Some(vf) => yrow.copy_from_slice(&vf[gi * k..(gi + 1) * k]),
                None => yrow.fill(0.0),
            }
        }
        panel_times_mat(kr, rows, m, u, y);
        // masked rows were initialized to zero, but stage 1 added Kr·U to
        // them too — re-zero them (and apply non-trivial mask weights) so
        // the accumulation pass honors the mask contract exactly.
        if let Some(mk) = mask {
            for i in 0..rows {
                let mi = mk[s + i];
                if mi != 1.0 {
                    let yrow = &mut y[i * k..(i + 1) * k];
                    if mi == 0.0 {
                        yrow.fill(0.0);
                    } else {
                        vec_ops::scale(mi, yrow);
                    }
                }
            }
        }
        // fused stage 2: W += Krᵀ·Y (masked / zero rows skipped)
        for i in 0..rows {
            let yrow = &y[i * k..(i + 1) * k];
            if yrow.iter().all(|&t| t == 0.0) {
                continue;
            }
            let kri = &kr[i * m..(i + 1) * m];
            for j in 0..m {
                vec_ops::axpy(kri[j], yrow, w.row_mut(j));
            }
        }
        s += rows;
    }
}

/// Tiled predictions f(x_i) = Σ_j α_j K(x_i, c_j): one kernel panel per
/// row tile, then a dot against α — the serving analogue of
/// [`knm_matvec_blocked`].
pub fn predict_blocked(kern: Kernel, x: &Mat, c: &Mat, alpha: &[f64], param: f64) -> Vec<f64> {
    predict_blocked_pool(kern, x, c, alpha, param, None, Isa::global())
}

/// [`predict_blocked`] fanned out over the shared worker pool — the
/// serving path (`Engine::predict`), so per-request latency pays zero
/// thread spawns. Each output row is written by exactly one task with
/// the same per-row arithmetic as the serial tiling, so results are
/// bitwise identical to [`predict_blocked`] regardless of the pool.
pub fn predict_blocked_pool(
    kern: Kernel,
    x: &Mat,
    c: &Mat,
    alpha: &[f64],
    param: f64,
    pool: Option<&WorkerPool>,
    isa: Isa,
) -> Vec<f64> {
    let (n, m) = (x.rows, c.rows);
    assert_eq!(c.cols, x.cols, "feature dims differ");
    assert_eq!(alpha.len(), m);
    let cn = row_sq_norms(c);
    let mut out = vec![0.0; n];
    if n == 0 {
        return out;
    }
    // no point fanning out fewer rows than one tile per worker
    let workers = pool
        .map(|p| p.workers())
        .unwrap_or(1)
        .min(n.div_ceil(DEFAULT_TILE).max(1));
    let ranges = chunk_ranges(n, workers);
    let cn = cn.as_slice();
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(ranges.len());
    let mut rest = out.as_mut_slice();
    for &(lo, hi) in &ranges {
        let (chunk, tail) = rest.split_at_mut(hi - lo);
        rest = tail;
        tasks.push(Box::new(move || {
            predict_range(kern, x, c, cn, alpha, param, lo, hi, chunk, isa);
        }));
    }
    fan_out(pool, tasks);
    out
}

/// Serial tiled predict over rows [start, end) of `x`, writing into `out`
/// (length `end - start`). The Kr tile is sized to the range, so small
/// serving batches don't allocate a full `DEFAULT_TILE × M` buffer.
#[allow(clippy::too_many_arguments)]
fn predict_range(
    kern: Kernel,
    x: &Mat,
    c: &Mat,
    cn: &[f64],
    alpha: &[f64],
    param: f64,
    start: usize,
    end: usize,
    out: &mut [f64],
    isa: Isa,
) {
    let (m, d) = (c.rows, x.cols);
    debug_assert_eq!(out.len(), end - start);
    if start == end {
        return;
    }
    let mut scratch = TileScratch::new(DEFAULT_TILE.min(end - start), m);
    let xn: Vec<f64> = (start..end)
        .map(|i| {
            let r = x.row(i);
            vec_ops::dot(r, r)
        })
        .collect();
    let mut s = start;
    while s < end {
        let rows = (end - s).min(scratch.tile);
        let kr = &mut scratch.kr[..rows * m];
        let xb = &x.data[s * d..(s + rows) * d];
        let xnr = &xn[s - start..s - start + rows];
        kernel_panel(kern, xb, d, rows, xnr, c, cn, 0, param, kr, m, isa);
        for i in 0..rows {
            out[s - start + i] = vec_ops::dot(&kr[i * m..(i + 1) * m], alpha);
        }
        s += rows;
    }
}

/// Tiled multi-output predictions F = Kr·A for an `M×K` coefficient block:
/// one kernel panel per row tile serves all K classes at once — the
/// serving analogue of [`knm_matmat_blocked`]. Returns `n×K`.
pub fn predict_multi_blocked(kern: Kernel, x: &Mat, c: &Mat, alpha: &Mat, param: f64) -> Mat {
    predict_multi_blocked_pool(kern, x, c, alpha, param, None, Isa::global())
}

/// [`predict_multi_blocked`] with row chunks fanned out over the shared
/// worker pool. Each output row is written by exactly one task with the
/// same per-row arithmetic as the serial tiling, so pooled results are
/// bitwise identical to serial regardless of the pool.
pub fn predict_multi_blocked_pool(
    kern: Kernel,
    x: &Mat,
    c: &Mat,
    alpha: &Mat,
    param: f64,
    pool: Option<&WorkerPool>,
    isa: Isa,
) -> Mat {
    let (n, m) = (x.rows, c.rows);
    let k = alpha.cols;
    assert_eq!(c.cols, x.cols, "feature dims differ");
    assert_eq!(alpha.rows, m, "alpha rows != centers");
    let mut out = Mat::zeros(n, k);
    if n == 0 || k == 0 {
        return out;
    }
    let cn = row_sq_norms(c);
    let workers = pool
        .map(|p| p.workers())
        .unwrap_or(1)
        .min(n.div_ceil(DEFAULT_TILE).max(1));
    let ranges = chunk_ranges(n, workers);
    let cn = cn.as_slice();
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(ranges.len());
    let mut rest = out.data.as_mut_slice();
    for &(lo, hi) in &ranges {
        let (chunk, tail) = rest.split_at_mut((hi - lo) * k);
        rest = tail;
        tasks.push(Box::new(move || {
            predict_multi_range(kern, x, c, cn, alpha, param, lo, hi, chunk, isa);
        }));
    }
    fan_out(pool, tasks);
    out
}

/// Serial tiled multi-output predict over rows [start, end) of `x`,
/// writing the row-major `(end-start) × K` block into `out`.
#[allow(clippy::too_many_arguments)]
fn predict_multi_range(
    kern: Kernel,
    x: &Mat,
    c: &Mat,
    cn: &[f64],
    alpha: &Mat,
    param: f64,
    start: usize,
    end: usize,
    out: &mut [f64],
    isa: Isa,
) {
    let (m, d) = (c.rows, x.cols);
    let k = alpha.cols;
    debug_assert_eq!(out.len(), (end - start) * k);
    if start == end {
        return;
    }
    let mut scratch = TileScratch::new(DEFAULT_TILE.min(end - start), m);
    let xn: Vec<f64> = (start..end)
        .map(|i| {
            let r = x.row(i);
            vec_ops::dot(r, r)
        })
        .collect();
    let mut s = start;
    while s < end {
        let rows = (end - s).min(scratch.tile);
        let kr = &mut scratch.kr[..rows * m];
        let xb = &x.data[s * d..(s + rows) * d];
        let xnr = &xn[s - start..s - start + rows];
        kernel_panel(kern, xb, d, rows, xnr, c, cn, 0, param, kr, m, isa);
        panel_times_mat(kr, rows, m, alpha, &mut out[(s - start) * k..]);
        s += rows;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest::check;

    const KERNELS: [Kernel; 3] = [Kernel::Gaussian, Kernel::Laplacian, Kernel::Linear];

    #[test]
    fn ranged_sweeps_cover_the_blocked_sweep() {
        // splitting a sweep into disjoint row ranges and summing must be
        // bitwise-equal to the full blocked sweep (the ranges partition
        // the rows and each row's contribution is computed identically) —
        // the contract the out-of-core plan's pooled fan-out relies on
        check("ranged = blocked", 10, |g| {
            let (n, m, d) = (g.usize_in(1, 400), g.usize_in(1, 12), g.usize_in(1, 5));
            let k = g.usize_in(1, 4);
            let x = Mat::from_vec(n, d, g.normal_vec(n * d));
            let c = Mat::from_vec(m, d, g.normal_vec(m * d));
            let xn = row_sq_norms(&x);
            let cn = row_sq_norms(&c);
            let u = g.normal_vec(m);
            let v = g.normal_vec(n);
            let um = Mat::from_vec(m, k, g.normal_vec(m * k));
            let vm = g.normal_vec(n * k);
            let split = g.usize_in(0, n + 1);
            let p = g.f64_in(0.5, 2.5);
            for kern in KERNELS {
                let mut scratch = TileScratch::new(DEFAULT_TILE, m);
                let mut want = vec![0.0; m];
                knm_matvec_blocked(
                    kern, &x, &c, &xn, &cn, &u, Some(&v), None, p, &mut scratch, &mut want,
                );
                let mut got = vec![0.0; m];
                for (lo, hi) in [(0, split), (split, n)] {
                    knm_matvec_ranged(
                        kern,
                        &x,
                        &c,
                        &xn,
                        &cn,
                        &u,
                        Some(&v),
                        None,
                        p,
                        &mut scratch,
                        &mut got,
                        lo,
                        hi,
                        Isa::global(),
                    );
                }
                assert_eq!(got, want, "{kern:?} vector split at {split}");

                let mut want_m = Mat::zeros(m, k);
                knm_matmat_blocked(
                    kern, &x, &c, &xn, &cn, &um, Some(&vm), None, p, &mut scratch, &mut want_m,
                );
                let mut got_m = Mat::zeros(m, k);
                for (lo, hi) in [(0, split), (split, n)] {
                    knm_matmat_ranged(
                        kern,
                        &x,
                        &c,
                        &xn,
                        &cn,
                        &um,
                        Some(&vm),
                        None,
                        p,
                        &mut scratch,
                        &mut got_m,
                        lo,
                        hi,
                        Isa::global(),
                    );
                }
                assert_eq!(got_m.data, want_m.data, "{kern:?} multi split at {split}");
            }
        });
    }

    #[test]
    fn gaussian_values() {
        let k = Kernel::Gaussian;
        assert!((k.eval(&[0.0, 0.0], &[0.0, 0.0], 1.0) - 1.0).abs() < 1e-15);
        // ||(3,4)||² = 25 -> exp(-12.5)
        assert!((k.eval(&[3.0, 4.0], &[0.0, 0.0], 1.0) - (-12.5f64).exp()).abs() < 1e-15);
    }

    #[test]
    fn laplacian_values() {
        let k = Kernel::Laplacian;
        assert!((k.eval(&[1.0, -2.0], &[0.0, 0.0], 2.0) - (-1.5f64).exp()).abs() < 1e-15);
    }

    #[test]
    fn linear_is_dot() {
        assert_eq!(Kernel::Linear.eval(&[1.0, 2.0], &[3.0, 4.0], 9.9), 11.0);
    }

    #[test]
    fn parse_names() {
        for k in KERNELS {
            assert_eq!(Kernel::parse(k.name()), Some(k));
        }
        assert_eq!(Kernel::parse("rbf"), Some(Kernel::Gaussian));
        assert_eq!(Kernel::parse("poly"), None);
    }

    #[test]
    fn block_matches_pointwise() {
        check("kernel_block = eval per entry", 15, |g| {
            let (b, m, d) = (g.usize_in(1, 8), g.usize_in(1, 8), g.usize_in(1, 6));
            let x = Mat::from_vec(b, d, g.normal_vec(b * d));
            let c = Mat::from_vec(m, d, g.normal_vec(m * d));
            let p = g.f64_in(0.5, 3.0);
            for kern in KERNELS {
                for blk in [kernel_block_ref(kern, &x, &c, p), kernel_block(kern, &x, &c, p)] {
                    for i in 0..b {
                        for j in 0..m {
                            let e = kern.eval(x.row(i), c.row(j), p);
                            assert!((blk[(i, j)] - e).abs() < 1e-10);
                        }
                    }
                }
            }
        });
    }

    #[test]
    fn tiled_block_matches_reference() {
        check("tiled kernel_block = reference", 25, |g| {
            let (b, m, d) = (g.usize_in(1, 30), g.usize_in(1, 16), g.usize_in(1, 7));
            let x = Mat::from_vec(b, d, g.normal_vec(b * d));
            let c = Mat::from_vec(m, d, g.normal_vec(m * d));
            let p = g.f64_in(0.5, 3.0);
            for kern in KERNELS {
                let want = kernel_block_ref(kern, &x, &c, p);
                let got = kernel_block(kern, &x, &c, p);
                assert!(got.max_abs_diff(&want) < 1e-10, "{kern:?}");
            }
        });
    }

    #[test]
    fn tiled_kmm_matches_reference_and_is_symmetric() {
        // sizes around the tile/unroll widths: 1, ragged, multiple tiles
        let mut rng = crate::util::rng::Rng::new(43);
        for m in [1usize, 3, 37, DEFAULT_TILE, 2 * DEFAULT_TILE + 11] {
            let d = 5;
            let c = Mat::from_vec(m, d, rng.normals(m * d));
            for kern in KERNELS {
                let want = kernel_block_ref(kern, &c, &c, 1.3);
                let got = kmm(kern, &c, 1.3);
                assert!(got.max_abs_diff(&want) < 1e-10, "{kern:?} m={m}");
                for i in 0..m {
                    for j in 0..m {
                        assert_eq!(got[(i, j)], got[(j, i)], "{kern:?} mirror at {i},{j}");
                    }
                }
            }
        }
    }

    #[test]
    fn pooled_dense_blocks_are_bitwise_equal_to_serial() {
        let pool = crate::util::pool::WorkerPool::new("test-kern", 4).unwrap();
        let mut rng = crate::util::rng::Rng::new(44);
        let (n, m, d) = (3 * DEFAULT_TILE + 7, 41, 6);
        let x = Mat::from_vec(n, d, rng.normals(n * d));
        let c = Mat::from_vec(m, d, rng.normals(m * d));
        for kern in KERNELS {
            let serial = kernel_block(kern, &x, &c, 1.1);
            let pooled = kernel_block_par(kern, &x, &c, 1.1, Some(&pool), Isa::global());
            assert_eq!(serial.data, pooled.data, "{kern:?} kernel_block");
        }
        let big_c = Mat::from_vec(n, d, rng.normals(n * d));
        for kern in KERNELS {
            let serial = kmm(kern, &big_c, 0.9);
            let pooled = kmm_par(kern, &big_c, 0.9, Some(&pool), Isa::global());
            assert_eq!(serial.data, pooled.data, "{kern:?} kmm");
        }
    }

    #[test]
    fn matvec_matches_dense() {
        check("knm_matvec = dense Krᵀ(mask(Kr u + v))", 15, |g| {
            let (b, m, d) = (g.usize_in(1, 10), g.usize_in(1, 10), g.usize_in(1, 5));
            let x = Mat::from_vec(b, d, g.normal_vec(b * d));
            let c = Mat::from_vec(m, d, g.normal_vec(m * d));
            let u = g.normal_vec(m);
            let v = g.normal_vec(b);
            let mask: Vec<f64> = (0..b).map(|_| if g.bool() { 1.0 } else { 0.0 }).collect();
            let p = 1.3;
            let kern = *g.pick(&KERNELS);
            let w = knm_matvec(kern, &x, &c, &u, &v, Some(&mask), p);

            let kr = kernel_block(kern, &x, &c, p);
            let mut y = crate::linalg::gemm::matvec(&kr, &u);
            for i in 0..b {
                y[i] = mask[i] * (y[i] + v[i]);
            }
            let want = crate::linalg::gemm::matvec_t(&kr, &y);
            for j in 0..m {
                assert!((w[j] - want[j]).abs() < 1e-9, "{} vs {}", w[j], want[j]);
            }
        });
    }

    #[test]
    fn predict_matches_block() {
        check("predict = Kr·α", 10, |g| {
            let (b, m, d) = (g.usize_in(1, 8), g.usize_in(1, 8), g.usize_in(1, 4));
            let x = Mat::from_vec(b, d, g.normal_vec(b * d));
            let c = Mat::from_vec(m, d, g.normal_vec(m * d));
            let alpha = g.normal_vec(m);
            let got = predict(Kernel::Gaussian, &x, &c, &alpha, 1.0);
            let kr = kernel_block(Kernel::Gaussian, &x, &c, 1.0);
            let want = crate::linalg::gemm::matvec(&kr, &alpha);
            for i in 0..b {
                assert!((got[i] - want[i]).abs() < 1e-10);
            }
        });
    }

    // -- tiled-vs-reference property tests (the acceptance contract) ------

    /// Run the tiled matvec with an explicit tile size so tiny problems
    /// still produce ragged final tiles.
    fn run_blocked(
        kern: Kernel,
        x: &Mat,
        c: &Mat,
        u: &[f64],
        v: Option<&[f64]>,
        mask: Option<&[f64]>,
        p: f64,
        tile: usize,
    ) -> Vec<f64> {
        let xn = row_sq_norms(x);
        let cn = row_sq_norms(c);
        let mut scratch = TileScratch::new(tile, c.rows);
        let mut w = vec![0.0; c.rows];
        knm_matvec_blocked(kern, x, c, &xn, &cn, u, v, mask, p, &mut scratch, &mut w);
        w
    }

    #[test]
    fn blocked_matvec_matches_reference_all_kernels() {
        check("knm_matvec_blocked = knm_matvec", 30, |g| {
            let (b, m, d) = (g.usize_in(1, 20), g.usize_in(1, 14), g.usize_in(1, 7));
            let x = Mat::from_vec(b, d, g.normal_vec(b * d));
            let c = Mat::from_vec(m, d, g.normal_vec(m * d));
            let u = g.normal_vec(m);
            let v = g.normal_vec(b);
            let p = g.f64_in(0.5, 3.0);
            for kern in KERNELS {
                let want = knm_matvec(kern, &x, &c, &u, &v, None, p);
                // tiles of 1, a ragged middle size, and larger-than-n
                for tile in [1usize, 3, 64] {
                    let got = run_blocked(kern, &x, &c, &u, Some(&v), None, p, tile);
                    let diff = vec_ops::max_abs_diff(&got, &want);
                    assert!(diff < 1e-10, "{kern:?} tile={tile} diff={diff}");
                }
            }
        });
    }

    #[test]
    fn blocked_matvec_honors_mask_contract() {
        check("blocked matvec mask contract", 20, |g| {
            let (b, m, d) = (g.usize_in(2, 16), g.usize_in(1, 10), g.usize_in(1, 5));
            let x = Mat::from_vec(b, d, g.normal_vec(b * d));
            let c = Mat::from_vec(m, d, g.normal_vec(m * d));
            let u = g.normal_vec(m);
            let v = g.normal_vec(b);
            let mask: Vec<f64> = (0..b).map(|_| if g.bool() { 1.0 } else { 0.0 }).collect();
            let p = 1.1;
            let kern = *g.pick(&KERNELS);
            let want = knm_matvec(kern, &x, &c, &u, &v, Some(&mask), p);
            let got = run_blocked(kern, &x, &c, &u, Some(&v), Some(&mask), p, 4);
            let diff = vec_ops::max_abs_diff(&got, &want);
            assert!(diff < 1e-10, "{kern:?} diff={diff}");
            // and the v = None path (the CG iteration shape)
            let zeros = vec![0.0; b];
            let want0 = knm_matvec(kern, &x, &c, &u, &zeros, Some(&mask), p);
            let got0 = run_blocked(kern, &x, &c, &u, None, Some(&mask), p, 4);
            assert!(vec_ops::max_abs_diff(&got0, &want0) < 1e-10);
        });
    }

    #[test]
    fn blocked_matvec_ragged_final_tile() {
        // n and M deliberately not multiples of the tile / unroll widths
        let mut rng = crate::util::rng::Rng::new(23);
        let (b, m, d) = (101, 37, 9);
        let x = Mat::from_vec(b, d, rng.normals(b * d));
        let c = Mat::from_vec(m, d, rng.normals(m * d));
        let u = rng.normals(m);
        let v = rng.normals(b);
        for kern in KERNELS {
            let want = knm_matvec(kern, &x, &c, &u, &v, None, 1.7);
            for tile in [7, 25, 101, 128] {
                let got = run_blocked(kern, &x, &c, &u, Some(&v), None, 1.7, tile);
                let diff = vec_ops::max_abs_diff(&got, &want);
                assert!(diff < 1e-10, "{kern:?} tile={tile} diff={diff}");
            }
        }
    }

    #[test]
    fn blocked_predict_matches_reference() {
        check("predict_blocked = predict", 25, |g| {
            let (b, m, d) = (g.usize_in(1, 24), g.usize_in(1, 12), g.usize_in(1, 6));
            let x = Mat::from_vec(b, d, g.normal_vec(b * d));
            let c = Mat::from_vec(m, d, g.normal_vec(m * d));
            let alpha = g.normal_vec(m);
            let p = g.f64_in(0.5, 3.0);
            for kern in KERNELS {
                let want = predict(kern, &x, &c, &alpha, p);
                let got = predict_blocked(kern, &x, &c, &alpha, p);
                let diff = vec_ops::max_abs_diff(&got, &want);
                assert!(diff < 1e-10, "{kern:?} diff={diff}");
            }
        });
    }

    #[test]
    fn blocked_predict_crosses_default_tile() {
        // more rows than DEFAULT_TILE so the shipped tile size itself is hit
        let mut rng = crate::util::rng::Rng::new(29);
        let (b, m, d) = (DEFAULT_TILE + 61, 19, 6);
        let x = Mat::from_vec(b, d, rng.normals(b * d));
        let c = Mat::from_vec(m, d, rng.normals(m * d));
        let alpha = rng.normals(m);
        for kern in KERNELS {
            let want = predict(kern, &x, &c, &alpha, 1.3);
            let got = predict_blocked(kern, &x, &c, &alpha, 1.3);
            assert!(vec_ops::max_abs_diff(&got, &want) < 1e-10, "{kern:?}");
        }
    }

    #[test]
    fn pooled_predict_matches_serial_bitwise() {
        let pool = crate::util::pool::WorkerPool::new("test-predict", 4).unwrap();
        let mut rng = crate::util::rng::Rng::new(47);
        let (b, m, d) = (3 * DEFAULT_TILE + 19, 29, 5);
        let x = Mat::from_vec(b, d, rng.normals(b * d));
        let c = Mat::from_vec(m, d, rng.normals(m * d));
        let alpha = rng.normals(m);
        for kern in KERNELS {
            let serial = predict_blocked(kern, &x, &c, &alpha, 1.2);
            let pooled = predict_blocked_pool(kern, &x, &c, &alpha, 1.2, Some(&pool), Isa::global());
            assert_eq!(serial, pooled, "{kern:?}");
            let no_pool = predict_blocked_pool(kern, &x, &c, &alpha, 1.2, None, Isa::global());
            assert_eq!(serial, no_pool, "{kern:?} inline");
        }
    }

    #[test]
    fn parallel_predict_matches_serial() {
        // big enough that the row chunks actually fan out (n > tile*workers)
        let mut rng = crate::util::rng::Rng::new(37);
        let (b, m, d) = (3 * DEFAULT_TILE + 11, 23, 5);
        let x = Mat::from_vec(b, d, rng.normals(b * d));
        let c = Mat::from_vec(m, d, rng.normals(m * d));
        let alpha = rng.normals(m);
        for kern in KERNELS {
            let serial = predict_blocked(kern, &x, &c, &alpha, 1.2);
            for workers in [2, 3, 8] {
                let pool = crate::util::pool::WorkerPool::new("test-predict", workers).unwrap();
                let par = predict_blocked_pool(kern, &x, &c, &alpha, 1.2, Some(&pool), Isa::global());
                assert_eq!(par, serial, "{kern:?} workers={workers} must be bitwise equal");
            }
        }
        // and against the row-at-a-time reference
        let want = predict(Kernel::Gaussian, &x, &c, &alpha, 1.2);
        let pool = crate::util::pool::WorkerPool::new("test-predict", 4).unwrap();
        let got = predict_blocked_pool(Kernel::Gaussian, &x, &c, &alpha, 1.2, Some(&pool), Isa::global());
        assert!(vec_ops::max_abs_diff(&got, &want) < 1e-10);
    }

    // -- multi-RHS path ----------------------------------------------------

    /// Run the tiled multi-RHS apply with an explicit tile size.
    #[allow(clippy::too_many_arguments)]
    fn run_matmat_blocked(
        kern: Kernel,
        x: &Mat,
        c: &Mat,
        u: &Mat,
        v: Option<&Mat>,
        mask: Option<&[f64]>,
        p: f64,
        tile: usize,
    ) -> Mat {
        let xn = row_sq_norms(x);
        let cn = row_sq_norms(c);
        let mut scratch = TileScratch::new(tile, c.rows);
        let mut w = Mat::zeros(c.rows, u.cols);
        knm_matmat_blocked(
            kern,
            x,
            c,
            &xn,
            &cn,
            u,
            v.map(|vm| vm.data.as_slice()),
            mask,
            p,
            &mut scratch,
            &mut w,
        );
        w
    }

    #[test]
    fn matmat_reference_matches_k_matvecs() {
        // column k of knm_matmat must equal knm_matvec on (u_k, v_k)
        check("knm_matmat = K × knm_matvec", 15, |g| {
            let (b, m, d, k) = (
                g.usize_in(1, 10),
                g.usize_in(1, 8),
                g.usize_in(1, 5),
                g.usize_in(1, 5),
            );
            let x = Mat::from_vec(b, d, g.normal_vec(b * d));
            let c = Mat::from_vec(m, d, g.normal_vec(m * d));
            let u = Mat::from_vec(m, k, g.normal_vec(m * k));
            let v = Mat::from_vec(b, k, g.normal_vec(b * k));
            let mask: Vec<f64> = (0..b).map(|_| if g.bool() { 1.0 } else { 0.0 }).collect();
            let kern = *g.pick(&KERNELS);
            let w = knm_matmat(kern, &x, &c, &u, Some(&v), Some(&mask), 1.2);
            for kc in 0..k {
                let uk: Vec<f64> = (0..m).map(|j| u[(j, kc)]).collect();
                let vk: Vec<f64> = (0..b).map(|i| v[(i, kc)]).collect();
                let want = knm_matvec(kern, &x, &c, &uk, &vk, Some(&mask), 1.2);
                for j in 0..m {
                    assert!((w[(j, kc)] - want[j]).abs() < 1e-9, "{kern:?} col {kc}");
                }
            }
        });
    }

    #[test]
    fn blocked_matmat_matches_reference_all_kernels() {
        check("knm_matmat_blocked = knm_matmat", 25, |g| {
            let (b, m, d) = (g.usize_in(1, 20), g.usize_in(1, 14), g.usize_in(1, 6));
            // ragged K around the register-tile widths, including K = 1
            let k = *g.pick(&[1usize, 2, 3, 5, 8]);
            let x = Mat::from_vec(b, d, g.normal_vec(b * d));
            let c = Mat::from_vec(m, d, g.normal_vec(m * d));
            let u = Mat::from_vec(m, k, g.normal_vec(m * k));
            let v = Mat::from_vec(b, k, g.normal_vec(b * k));
            let p = g.f64_in(0.5, 3.0);
            for kern in KERNELS {
                let want = knm_matmat(kern, &x, &c, &u, Some(&v), None, p);
                for tile in [1usize, 3, 64] {
                    let got = run_matmat_blocked(kern, &x, &c, &u, Some(&v), None, p, tile);
                    let diff = got.max_abs_diff(&want);
                    assert!(diff < 1e-10, "{kern:?} k={k} tile={tile} diff={diff}");
                }
                // and the v = None path (the CG iteration shape)
                let want0 = knm_matmat(kern, &x, &c, &u, None, None, p);
                let got0 = run_matmat_blocked(kern, &x, &c, &u, None, None, p, 4);
                assert!(got0.max_abs_diff(&want0) < 1e-10, "{kern:?} v=None");
            }
        });
    }

    #[test]
    fn blocked_matmat_honors_mask_contract() {
        check("blocked matmat mask contract", 15, |g| {
            let (b, m, d, k) = (
                g.usize_in(2, 14),
                g.usize_in(1, 9),
                g.usize_in(1, 5),
                g.usize_in(1, 4),
            );
            let x = Mat::from_vec(b, d, g.normal_vec(b * d));
            let c = Mat::from_vec(m, d, g.normal_vec(m * d));
            let u = Mat::from_vec(m, k, g.normal_vec(m * k));
            let v = Mat::from_vec(b, k, g.normal_vec(b * k));
            let mask: Vec<f64> = (0..b).map(|_| if g.bool() { 1.0 } else { 0.0 }).collect();
            let kern = *g.pick(&KERNELS);
            let want = knm_matmat(kern, &x, &c, &u, Some(&v), Some(&mask), 1.1);
            let got = run_matmat_blocked(kern, &x, &c, &u, Some(&v), Some(&mask), 1.1, 4);
            assert!(got.max_abs_diff(&want) < 1e-10, "{kern:?}");
        });
    }

    #[test]
    fn blocked_matmat_matches_k1_vector_path() {
        // K = 1 degeneracy: the multi-RHS tiling must agree with the
        // vector hot path on the same inputs
        let mut rng = crate::util::rng::Rng::new(61);
        let (b, m, d) = (2 * DEFAULT_TILE + 13, 33, 7);
        let x = Mat::from_vec(b, d, rng.normals(b * d));
        let c = Mat::from_vec(m, d, rng.normals(m * d));
        let uv = rng.normals(m);
        let u = Mat::from_vec(m, 1, uv.clone());
        let vv = rng.normals(b);
        let v = Mat::from_vec(b, 1, vv.clone());
        for kern in KERNELS {
            let got = run_matmat_blocked(kern, &x, &c, &u, Some(&v), None, 1.4, DEFAULT_TILE);
            let want = run_blocked(kern, &x, &c, &uv, Some(&vv), None, 1.4, DEFAULT_TILE);
            for j in 0..m {
                assert!((got[(j, 0)] - want[j]).abs() < 1e-10, "{kern:?} j={j}");
            }
        }
    }

    #[test]
    fn predict_multi_matches_per_column_predict() {
        check("predict_multi = K × predict", 15, |g| {
            let (b, m, d, k) = (
                g.usize_in(1, 12),
                g.usize_in(1, 9),
                g.usize_in(1, 5),
                g.usize_in(1, 5),
            );
            let x = Mat::from_vec(b, d, g.normal_vec(b * d));
            let c = Mat::from_vec(m, d, g.normal_vec(m * d));
            let a = Mat::from_vec(m, k, g.normal_vec(m * k));
            let p = g.f64_in(0.5, 3.0);
            for kern in KERNELS {
                let refm = predict_multi(kern, &x, &c, &a, p);
                let got = predict_multi_blocked(kern, &x, &c, &a, p);
                assert!(got.max_abs_diff(&refm) < 1e-10, "{kern:?} blocked vs ref");
                for kc in 0..k {
                    let ak: Vec<f64> = (0..m).map(|j| a[(j, kc)]).collect();
                    let want = predict(kern, &x, &c, &ak, p);
                    for i in 0..b {
                        assert!((refm[(i, kc)] - want[i]).abs() < 1e-10, "{kern:?}");
                    }
                }
            }
        });
    }

    #[test]
    fn pooled_predict_multi_is_bitwise_equal_to_serial() {
        let pool = crate::util::pool::WorkerPool::new("test-pmulti", 4).unwrap();
        let mut rng = crate::util::rng::Rng::new(67);
        let (b, m, d, k) = (3 * DEFAULT_TILE + 17, 27, 5, 6);
        let x = Mat::from_vec(b, d, rng.normals(b * d));
        let c = Mat::from_vec(m, d, rng.normals(m * d));
        let a = Mat::from_vec(m, k, rng.normals(m * k));
        for kern in KERNELS {
            let serial = predict_multi_blocked(kern, &x, &c, &a, 1.2);
            let pooled = predict_multi_blocked_pool(kern, &x, &c, &a, 1.2, Some(&pool), Isa::global());
            assert_eq!(serial.data, pooled.data, "{kern:?}");
        }
    }

    #[test]
    fn row_sq_norms_match_eval() {
        let mut rng = crate::util::rng::Rng::new(31);
        let x = Mat::from_vec(5, 4, rng.normals(20));
        let n = row_sq_norms(&x);
        for i in 0..5 {
            let want: f64 = x.row(i).iter().map(|v| v * v).sum();
            assert!((n[i] - want).abs() < 1e-12);
        }
    }

    // -- SIMD-vs-scalar arms (the runtime-dispatch acceptance contract) ----
    //
    // Every test pins Isa::detect_best() (pure feature detection, immune
    // to FALKON_SIMD) against an explicit Isa::Scalar, so the default and
    // FALKON_SIMD=scalar CI legs run identical arithmetic. On a host with
    // no vector arm the comparisons are scalar-vs-scalar and vacuous.

    #[test]
    fn simd_panels_match_scalar_within_tol_model() {
        let isa = Isa::detect_best();
        if isa == Isa::Scalar {
            eprintln!("[simd] no vector arm on this host; SIMD panel test is vacuous");
        }
        check("SIMD kernel_block = scalar within tol", 20, |g| {
            let (b, m, d) = (g.usize_in(1, 40), g.usize_in(1, 20), g.usize_in(1, 12));
            let x = Mat::from_vec(b, d, g.normal_vec(b * d));
            let c = Mat::from_vec(m, d, g.normal_vec(m * d));
            let p = g.f64_in(0.5, 3.0);
            for kern in KERNELS {
                let simd_blk = kernel_block_par(kern, &x, &c, p, None, isa);
                let scal_blk = kernel_block_par(kern, &x, &c, p, None, Isa::Scalar);
                let bound = tol::simd_entry_bound(kern, &x, &c, p);
                let diff = simd_blk.max_abs_diff(&scal_blk);
                assert!(
                    diff <= bound,
                    "{kern:?} {isa:?} b={b} m={m} d={d}: diff={diff:e} > bound={bound:e}"
                );
            }
        });
    }

    #[test]
    fn simd_ranged_sweeps_match_scalar_within_tol_model() {
        let isa = Isa::detect_best();
        if isa == Isa::Scalar {
            eprintln!("[simd] no vector arm on this host; SIMD sweep test is vacuous");
        }
        check("SIMD matvec/matmat = scalar within tol", 15, |g| {
            let (n, m, d) = (g.usize_in(1, 60), g.usize_in(1, 16), g.usize_in(1, 9));
            let k = g.usize_in(1, 4);
            let x = Mat::from_vec(n, d, g.normal_vec(n * d));
            let c = Mat::from_vec(m, d, g.normal_vec(m * d));
            let xn = row_sq_norms(&x);
            let cn = row_sq_norms(&c);
            let u = g.normal_vec(m);
            let v = g.normal_vec(n);
            let um = Mat::from_vec(m, k, g.normal_vec(m * k));
            let vm = g.normal_vec(n * k);
            let p = g.f64_in(0.5, 2.5);
            // ragged tile so vector groups, tails and tile seams all run
            let tile = *g.pick(&[1usize, 5, 7, DEFAULT_TILE]);
            for kern in KERNELS {
                let run_vec = |arm: Isa| {
                    let mut scratch = TileScratch::new(tile, m);
                    let mut w = vec![0.0; m];
                    knm_matvec_ranged(
                        kern,
                        &x,
                        &c,
                        &xn,
                        &cn,
                        &u,
                        Some(&v),
                        None,
                        p,
                        &mut scratch,
                        &mut w,
                        0,
                        n,
                        arm,
                    );
                    w
                };
                let got = run_vec(isa);
                let want = run_vec(Isa::Scalar);
                let bound = tol::simd_matvec_bound(kern, &x, &c, p, &u, Some(&v));
                let diff = vec_ops::max_abs_diff(&got, &want);
                assert!(
                    diff <= bound,
                    "{kern:?} {isa:?} matvec tile={tile}: diff={diff:e} > bound={bound:e}"
                );

                let run_mat = |arm: Isa| {
                    let mut scratch = TileScratch::new(tile, m);
                    let mut w = Mat::zeros(m, k);
                    knm_matmat_ranged(
                        kern,
                        &x,
                        &c,
                        &xn,
                        &cn,
                        &um,
                        Some(&vm),
                        None,
                        p,
                        &mut scratch,
                        &mut w,
                        0,
                        n,
                        arm,
                    );
                    w
                };
                let got_m = run_mat(isa);
                let want_m = run_mat(Isa::Scalar);
                let bound_m = tol::simd_matmat_bound(kern, &x, &c, p, &um, Some(&vm));
                let diff_m = got_m.max_abs_diff(&want_m);
                assert!(
                    diff_m <= bound_m,
                    "{kern:?} {isa:?} matmat tile={tile}: diff={diff_m:e} > bound={bound_m:e}"
                );
            }
        });
    }

    #[test]
    fn simd_predict_is_pooled_deterministic_and_tol_close_to_scalar() {
        let isa = Isa::detect_best();
        if isa == Isa::Scalar {
            eprintln!("[simd] no vector arm on this host; SIMD predict test is vacuous");
        }
        let pool = crate::util::pool::WorkerPool::new("test-simd-predict", 4).unwrap();
        let mut rng = crate::util::rng::Rng::new(83);
        let (b, m, d, k) = (2 * DEFAULT_TILE + 31, 29, 7, 3);
        let x = Mat::from_vec(b, d, rng.normals(b * d));
        let c = Mat::from_vec(m, d, rng.normals(m * d));
        let alpha = rng.normals(m);
        let am = Mat::from_vec(m, k, rng.normals(m * k));
        for kern in KERNELS {
            // within one arm, pooled must stay bitwise equal to serial —
            // the ISA is picked once, never per task
            let serial = predict_blocked_pool(kern, &x, &c, &alpha, 1.2, None, isa);
            let pooled = predict_blocked_pool(kern, &x, &c, &alpha, 1.2, Some(&pool), isa);
            assert_eq!(serial, pooled, "{kern:?} pooled vs serial under {isa:?}");
            // across arms, tol-bounded
            let scalar = predict_blocked_pool(kern, &x, &c, &alpha, 1.2, None, Isa::Scalar);
            let bound = tol::simd_predict_bound(kern, &x, &c, 1.2, &alpha);
            let diff = vec_ops::max_abs_diff(&serial, &scalar);
            assert!(
                diff <= bound,
                "{kern:?} {isa:?} predict: diff={diff:e} > bound={bound:e}"
            );

            let serial_m = predict_multi_blocked_pool(kern, &x, &c, &am, 1.2, None, isa);
            let pooled_m = predict_multi_blocked_pool(kern, &x, &c, &am, 1.2, Some(&pool), isa);
            assert_eq!(
                serial_m.data, pooled_m.data,
                "{kern:?} pooled multi vs serial under {isa:?}"
            );
            let scalar_m = predict_multi_blocked_pool(kern, &x, &c, &am, 1.2, None, Isa::Scalar);
            // ‖α‖₁ over the whole block upper-bounds every column's ‖·‖₁
            let bound_m = tol::simd_predict_bound(kern, &x, &c, 1.2, &am.data);
            let diff_m = serial_m.max_abs_diff(&scalar_m);
            assert!(
                diff_m <= bound_m,
                "{kern:?} {isa:?} predict_multi: diff={diff_m:e} > bound={bound_m:e}"
            );
        }
    }

    #[test]
    fn simd_kmm_is_symmetric_and_tol_close_to_scalar() {
        let isa = Isa::detect_best();
        if isa == Isa::Scalar {
            eprintln!("[simd] no vector arm on this host; SIMD kmm test is vacuous");
        }
        let mut rng = crate::util::rng::Rng::new(89);
        for m in [1usize, 5, 37, DEFAULT_TILE + 9] {
            let d = 6;
            let c = Mat::from_vec(m, d, rng.normals(m * d));
            for kern in KERNELS {
                let got = kmm_par(kern, &c, 1.3, None, isa);
                for i in 0..m {
                    for j in 0..m {
                        assert_eq!(got[(i, j)], got[(j, i)], "{kern:?} mirror at {i},{j}");
                    }
                }
                let want = kmm_par(kern, &c, 1.3, None, Isa::Scalar);
                let bound = tol::simd_entry_bound(kern, &c, &c, 1.3);
                let diff = got.max_abs_diff(&want);
                assert!(
                    diff <= bound,
                    "{kern:?} {isa:?} kmm m={m}: diff={diff:e} > bound={bound:e}"
                );
            }
        }
    }
}

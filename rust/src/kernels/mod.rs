//! Pure-Rust kernel function evaluation — the reference implementation the
//! XLA artifacts are cross-checked against, the compute engine of the
//! fallback [`crate::runtime::RustBackend`], and the "kernel computed on
//! the fly" baseline from the paper's Table 1 discussion.

use crate::linalg::mat::Mat;

/// Kernel families supported end-to-end (python oracle, Pallas kernels,
/// artifacts and this module must stay in sync — tested both sides).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Kernel {
    /// K(x,c) = exp(-‖x-c‖² / 2σ²) — the paper's main kernel (Sect. 5).
    Gaussian,
    /// K(x,c) = exp(-‖x-c‖₁ / σ).
    Laplacian,
    /// K(x,c) = ⟨x,c⟩ — used for the YELP experiment.
    Linear,
}

impl Kernel {
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Gaussian => "gaussian",
            Kernel::Laplacian => "laplacian",
            Kernel::Linear => "linear",
        }
    }

    pub fn parse(s: &str) -> Option<Kernel> {
        match s {
            "gaussian" | "rbf" => Some(Kernel::Gaussian),
            "laplacian" => Some(Kernel::Laplacian),
            "linear" => Some(Kernel::Linear),
            _ => None,
        }
    }

    /// Upper bound κ² on K(x,x) (paper's boundedness assumption). For the
    /// linear kernel it depends on the data, so None.
    pub fn kappa_sq(self) -> Option<f64> {
        match self {
            Kernel::Gaussian | Kernel::Laplacian => Some(1.0),
            Kernel::Linear => None,
        }
    }

    /// Evaluate K(x, c) for two points.
    #[inline]
    pub fn eval(self, x: &[f64], c: &[f64], param: f64) -> f64 {
        debug_assert_eq!(x.len(), c.len());
        match self {
            Kernel::Gaussian => {
                let mut sq = 0.0;
                for i in 0..x.len() {
                    let d = x[i] - c[i];
                    sq += d * d;
                }
                (-sq / (2.0 * param * param)).exp()
            }
            Kernel::Laplacian => {
                let mut l1 = 0.0;
                for i in 0..x.len() {
                    l1 += (x[i] - c[i]).abs();
                }
                (-l1 / param).exp()
            }
            Kernel::Linear => {
                let mut d = 0.0;
                for i in 0..x.len() {
                    d += x[i] * c[i];
                }
                d
            }
        }
    }
}

/// Dense kernel block K(X, C) -> (X.rows × C.rows).
///
/// For the Gaussian kernel this uses the ‖x‖²+‖c‖²−2x·c expansion so the
/// inner loop is a dot product (same structure as the Pallas tile).
pub fn kernel_block(kern: Kernel, x: &Mat, c: &Mat, param: f64) -> Mat {
    assert_eq!(x.cols, c.cols, "feature dims differ");
    let mut out = Mat::zeros(x.rows, c.rows);
    match kern {
        Kernel::Gaussian => {
            let xn: Vec<f64> = (0..x.rows)
                .map(|i| x.row(i).iter().map(|v| v * v).sum())
                .collect();
            let cn: Vec<f64> = (0..c.rows)
                .map(|j| c.row(j).iter().map(|v| v * v).sum())
                .collect();
            let inv = 1.0 / (2.0 * param * param);
            for i in 0..x.rows {
                let xr = x.row(i);
                let orow = out.row_mut(i);
                for j in 0..c.rows {
                    let dot = crate::linalg::vec_ops::dot(xr, c.row(j));
                    let sq = (xn[i] + cn[j] - 2.0 * dot).max(0.0);
                    orow[j] = (-sq * inv).exp();
                }
            }
        }
        _ => {
            for i in 0..x.rows {
                let xr = x.row(i);
                let orow = out.row_mut(i);
                for j in 0..c.rows {
                    orow[j] = kern.eval(xr, c.row(j), param);
                }
            }
        }
    }
    out
}

/// K_MM over the centers.
pub fn kmm(kern: Kernel, c: &Mat, param: f64) -> Mat {
    kernel_block(kern, c, c, param)
}

/// The FALKON block op w = Krᵀ(mask ⊙ (Kr·u + v)) computed on the fly
/// without materializing Kr (row-at-a-time) — mirrors the artifact
/// semantics exactly, including the mask contract.
pub fn knm_matvec(
    kern: Kernel,
    x: &Mat,
    c: &Mat,
    u: &[f64],
    v: &[f64],
    mask: Option<&[f64]>,
    param: f64,
) -> Vec<f64> {
    assert_eq!(u.len(), c.rows);
    assert_eq!(v.len(), x.rows);
    let mut w = vec![0.0; c.rows];
    let mut krow = vec![0.0; c.rows];
    for i in 0..x.rows {
        let mi = mask.map(|m| m[i]).unwrap_or(1.0);
        if mi == 0.0 {
            continue;
        }
        let xr = x.row(i);
        for j in 0..c.rows {
            krow[j] = kern.eval(xr, c.row(j), param);
        }
        let yi = mi * (crate::linalg::vec_ops::dot(&krow, u) + v[i]);
        crate::linalg::vec_ops::axpy(yi, &krow, &mut w);
    }
    w
}

/// Predictions f(x_i) = Σ_j α_j K(x_i, c_j) for a block of rows.
pub fn predict(kern: Kernel, x: &Mat, c: &Mat, alpha: &[f64], param: f64) -> Vec<f64> {
    assert_eq!(alpha.len(), c.rows);
    let mut out = vec![0.0; x.rows];
    for i in 0..x.rows {
        let xr = x.row(i);
        let mut acc = 0.0;
        for j in 0..c.rows {
            acc += alpha[j] * kern.eval(xr, c.row(j), param);
        }
        out[i] = acc;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest::check;

    #[test]
    fn gaussian_values() {
        let k = Kernel::Gaussian;
        assert!((k.eval(&[0.0, 0.0], &[0.0, 0.0], 1.0) - 1.0).abs() < 1e-15);
        // ||(3,4)||² = 25 -> exp(-12.5)
        assert!((k.eval(&[3.0, 4.0], &[0.0, 0.0], 1.0) - (-12.5f64).exp()).abs() < 1e-15);
    }

    #[test]
    fn laplacian_values() {
        let k = Kernel::Laplacian;
        assert!((k.eval(&[1.0, -2.0], &[0.0, 0.0], 2.0) - (-1.5f64).exp()).abs() < 1e-15);
    }

    #[test]
    fn linear_is_dot() {
        assert_eq!(Kernel::Linear.eval(&[1.0, 2.0], &[3.0, 4.0], 9.9), 11.0);
    }

    #[test]
    fn parse_names() {
        for k in [Kernel::Gaussian, Kernel::Laplacian, Kernel::Linear] {
            assert_eq!(Kernel::parse(k.name()), Some(k));
        }
        assert_eq!(Kernel::parse("rbf"), Some(Kernel::Gaussian));
        assert_eq!(Kernel::parse("poly"), None);
    }

    #[test]
    fn block_matches_pointwise() {
        check("kernel_block = eval per entry", 15, |g| {
            let (b, m, d) = (g.usize_in(1, 8), g.usize_in(1, 8), g.usize_in(1, 6));
            let x = Mat::from_vec(b, d, g.normal_vec(b * d));
            let c = Mat::from_vec(m, d, g.normal_vec(m * d));
            let p = g.f64_in(0.5, 3.0);
            for kern in [Kernel::Gaussian, Kernel::Laplacian, Kernel::Linear] {
                let blk = kernel_block(kern, &x, &c, p);
                for i in 0..b {
                    for j in 0..m {
                        let e = kern.eval(x.row(i), c.row(j), p);
                        assert!((blk[(i, j)] - e).abs() < 1e-10);
                    }
                }
            }
        });
    }

    #[test]
    fn matvec_matches_dense() {
        check("knm_matvec = dense Krᵀ(mask(Kr u + v))", 15, |g| {
            let (b, m, d) = (g.usize_in(1, 10), g.usize_in(1, 10), g.usize_in(1, 5));
            let x = Mat::from_vec(b, d, g.normal_vec(b * d));
            let c = Mat::from_vec(m, d, g.normal_vec(m * d));
            let u = g.normal_vec(m);
            let v = g.normal_vec(b);
            let mask: Vec<f64> = (0..b).map(|_| if g.bool() { 1.0 } else { 0.0 }).collect();
            let p = 1.3;
            let kern = *g.pick(&[Kernel::Gaussian, Kernel::Laplacian, Kernel::Linear]);
            let w = knm_matvec(kern, &x, &c, &u, &v, Some(&mask), p);

            let kr = kernel_block(kern, &x, &c, p);
            let mut y = crate::linalg::gemm::matvec(&kr, &u);
            for i in 0..b {
                y[i] = mask[i] * (y[i] + v[i]);
            }
            let want = crate::linalg::gemm::matvec_t(&kr, &y);
            for j in 0..m {
                assert!((w[j] - want[j]).abs() < 1e-9, "{} vs {}", w[j], want[j]);
            }
        });
    }

    #[test]
    fn predict_matches_block() {
        check("predict = Kr·α", 10, |g| {
            let (b, m, d) = (g.usize_in(1, 8), g.usize_in(1, 8), g.usize_in(1, 4));
            let x = Mat::from_vec(b, d, g.normal_vec(b * d));
            let c = Mat::from_vec(m, d, g.normal_vec(m * d));
            let alpha = g.normal_vec(m);
            let got = predict(Kernel::Gaussian, &x, &c, &alpha, 1.0);
            let kr = kernel_block(Kernel::Gaussian, &x, &c, 1.0);
            let want = crate::linalg::gemm::matvec(&kr, &alpha);
            for i in 0..b {
                assert!((got[i] - want[i]).abs() < 1e-10);
            }
        });
    }
}

//! AVX2/FMA arm of the kernel panel engine: the register-tiled dot
//! products and norm-expansion staging of `kernel_panel` /
//! `mixed::kernel_panel_f32`, hand-vectorized with `std::arch`.
//!
//! Structure mirrors the scalar tiles exactly — same `j0`-aligned
//! groups of four centers, same staging expressions, same separate
//! exponential pass — so the only numerical differences from the scalar
//! arm are FMA contraction and lane-order reassociation inside the dot
//! products, bounded by the `tol::simd_*` model. The f32 panels widen
//! storage to f64 lanes (`_mm256_cvtps_pd`) and accumulate in double,
//! preserving the PR 7 precision model: products of two f32s are exact
//! in f64, so FMA is even *exact* there. Exponentials go through the
//! bitwise-pinned lanes of [`super::exp`].

use std::arch::x86_64::*;

use crate::kernels::Kernel;
use crate::linalg::mat::Mat;
use crate::linalg::mat32::MatF32;
use crate::linalg::vec_ops;

use super::exp;

/// Four simultaneous dot products xr·c0..c3 in f64 lanes: one shared
/// load of xr per step, four FMA accumulators, horizontal combine, and
/// a scalar k-tail added per center.
#[target_feature(enable = "avx2,fma")]
unsafe fn dot4(xr: &[f64], c0: &[f64], c1: &[f64], c2: &[f64], c3: &[f64]) -> __m256d {
    let d = xr.len();
    let (mut a0, mut a1, mut a2, mut a3) = (
        _mm256_setzero_pd(),
        _mm256_setzero_pd(),
        _mm256_setzero_pd(),
        _mm256_setzero_pd(),
    );
    let mut k = 0;
    while k + 4 <= d {
        let vx = _mm256_loadu_pd(xr.as_ptr().add(k));
        a0 = _mm256_fmadd_pd(vx, _mm256_loadu_pd(c0.as_ptr().add(k)), a0);
        a1 = _mm256_fmadd_pd(vx, _mm256_loadu_pd(c1.as_ptr().add(k)), a1);
        a2 = _mm256_fmadd_pd(vx, _mm256_loadu_pd(c2.as_ptr().add(k)), a2);
        a3 = _mm256_fmadd_pd(vx, _mm256_loadu_pd(c3.as_ptr().add(k)), a3);
        k += 4;
    }
    // hadd pairs lanes within each 128-bit half; the two permutes gather
    // the low/high halves so the sum lands as [Σa0, Σa1, Σa2, Σa3]
    let t0 = _mm256_hadd_pd(a0, a1);
    let t1 = _mm256_hadd_pd(a2, a3);
    let mut dots = _mm256_add_pd(
        _mm256_permute2f128_pd::<0x20>(t0, t1),
        _mm256_permute2f128_pd::<0x31>(t0, t1),
    );
    if k < d {
        let mut t = [0.0f64; 4];
        _mm256_storeu_pd(t.as_mut_ptr(), dots);
        while k < d {
            let xv = xr[k];
            t[0] += xv * c0[k];
            t[1] += xv * c1[k];
            t[2] += xv * c2[k];
            t[3] += xv * c3[k];
            k += 1;
        }
        dots = _mm256_loadu_pd(t.as_ptr());
    }
    dots
}

/// [`dot4`] over f32 storage: each step widens four f32s of every
/// operand to f64 lanes before the FMA, so the accumulation is pure
/// f64 (and exact per product — 24+24 ≤ 53 mantissa bits).
#[target_feature(enable = "avx2,fma")]
unsafe fn dot4_f32(xr: &[f32], c0: &[f32], c1: &[f32], c2: &[f32], c3: &[f32]) -> __m256d {
    let d = xr.len();
    let (mut a0, mut a1, mut a2, mut a3) = (
        _mm256_setzero_pd(),
        _mm256_setzero_pd(),
        _mm256_setzero_pd(),
        _mm256_setzero_pd(),
    );
    let mut k = 0;
    while k + 4 <= d {
        let vx = _mm256_cvtps_pd(_mm_loadu_ps(xr.as_ptr().add(k)));
        a0 = _mm256_fmadd_pd(vx, _mm256_cvtps_pd(_mm_loadu_ps(c0.as_ptr().add(k))), a0);
        a1 = _mm256_fmadd_pd(vx, _mm256_cvtps_pd(_mm_loadu_ps(c1.as_ptr().add(k))), a1);
        a2 = _mm256_fmadd_pd(vx, _mm256_cvtps_pd(_mm_loadu_ps(c2.as_ptr().add(k))), a2);
        a3 = _mm256_fmadd_pd(vx, _mm256_cvtps_pd(_mm_loadu_ps(c3.as_ptr().add(k))), a3);
        k += 4;
    }
    let t0 = _mm256_hadd_pd(a0, a1);
    let t1 = _mm256_hadd_pd(a2, a3);
    let mut dots = _mm256_add_pd(
        _mm256_permute2f128_pd::<0x20>(t0, t1),
        _mm256_permute2f128_pd::<0x31>(t0, t1),
    );
    if k < d {
        let mut t = [0.0f64; 4];
        _mm256_storeu_pd(t.as_mut_ptr(), dots);
        while k < d {
            let xv = xr[k] as f64;
            t[0] += xv * c0[k] as f64;
            t[1] += xv * c1[k] as f64;
            t[2] += xv * c2[k] as f64;
            t[3] += xv * c3[k] as f64;
            k += 1;
        }
        dots = _mm256_loadu_pd(t.as_ptr());
    }
    dots
}

/// Horizontal sum of a 4-lane f64 accumulator.
#[target_feature(enable = "avx2,fma")]
unsafe fn hsum4(v: __m256d) -> f64 {
    let s = _mm_add_pd(_mm256_castpd256_pd128(v), _mm256_extractf128_pd::<1>(v));
    _mm_cvtsd_f64(_mm_add_sd(s, _mm_unpackhi_pd(s, s)))
}

/// AVX2 arm of `kernel_panel`: same layout contract (`j0`, `ldo`), same
/// tiling, vectorized dots/staging/exp.
///
/// # Safety
/// Caller must ensure avx2 and fma are available on this CPU.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2,fma")]
pub unsafe fn kernel_panel_avx2(
    kern: Kernel,
    xb: &[f64],
    d: usize,
    rows: usize,
    xn: &[f64],
    c: &Mat,
    cn: &[f64],
    j0: usize,
    param: f64,
    out: &mut [f64],
    ldo: usize,
) {
    let m = c.rows;
    let w = m - j0;
    debug_assert_eq!(xb.len(), rows * d);
    debug_assert_eq!(c.cols, d);
    debug_assert!(rows == 0 || out.len() >= (rows - 1) * ldo + w);
    debug_assert!(ldo >= w);
    match kern {
        Kernel::Gaussian => {
            debug_assert_eq!(xn.len(), rows);
            debug_assert_eq!(cn.len(), m);
            let inv = 1.0 / (2.0 * param * param);
            let two = _mm256_set1_pd(2.0);
            let zero = _mm256_setzero_pd();
            for i in 0..rows {
                let xr = &xb[i * d..(i + 1) * d];
                let xni = xn[i];
                let xniv = _mm256_set1_pd(xni);
                let orow = &mut out[i * ldo..i * ldo + w];
                let mut j = j0;
                while j + 4 <= m {
                    let dots = dot4(xr, c.row(j), c.row(j + 1), c.row(j + 2), c.row(j + 3));
                    let cnv = _mm256_loadu_pd(cn.as_ptr().add(j));
                    // (xni + cn[j] - 2·dot).max(0): max_pd returns its
                    // second operand on NaN, matching scalar f64::max
                    let sq = _mm256_max_pd(
                        _mm256_sub_pd(_mm256_add_pd(xniv, cnv), _mm256_mul_pd(two, dots)),
                        zero,
                    );
                    _mm256_storeu_pd(orow.as_mut_ptr().add(j - j0), sq);
                    j += 4;
                }
                while j < m {
                    let dotv = vec_ops::dot(xr, c.row(j));
                    orow[j - j0] = (xni + cn[j] - 2.0 * dotv).max(0.0);
                    j += 1;
                }
                exp::fast_exp_neg_scale_slice_avx2(orow, inv);
            }
        }
        Kernel::Laplacian => {
            let inv = 1.0 / param;
            let neg0 = _mm256_set1_pd(-0.0);
            for i in 0..rows {
                let xr = &xb[i * d..(i + 1) * d];
                let orow = &mut out[i * ldo..i * ldo + w];
                for j in j0..m {
                    let cr = c.row(j);
                    let mut acc = _mm256_setzero_pd();
                    let mut k = 0;
                    while k + 4 <= d {
                        let diff = _mm256_sub_pd(
                            _mm256_loadu_pd(xr.as_ptr().add(k)),
                            _mm256_loadu_pd(cr.as_ptr().add(k)),
                        );
                        acc = _mm256_add_pd(acc, _mm256_andnot_pd(neg0, diff));
                        k += 4;
                    }
                    let mut l1 = hsum4(acc);
                    while k < d {
                        l1 += (xr[k] - cr[k]).abs();
                        k += 1;
                    }
                    orow[j - j0] = -l1 * inv;
                }
                exp::fast_exp_slice_avx2(orow);
            }
        }
        Kernel::Linear => {
            for i in 0..rows {
                let xr = &xb[i * d..(i + 1) * d];
                let orow = &mut out[i * ldo..i * ldo + w];
                let mut j = j0;
                while j + 4 <= m {
                    let dots = dot4(xr, c.row(j), c.row(j + 1), c.row(j + 2), c.row(j + 3));
                    _mm256_storeu_pd(orow.as_mut_ptr().add(j - j0), dots);
                    j += 4;
                }
                while j < m {
                    orow[j - j0] = vec_ops::dot(xr, c.row(j));
                    j += 1;
                }
            }
        }
    }
}

/// AVX2 arm of `mixed::kernel_panel_f32`: f32 storage widened to f64
/// lanes, exponential argument rounded once to f32 (the
/// `_mm256_cvtpd_ps` narrowing rounds to nearest, exactly like `as
/// f32`), then the 8-lane f32 exp pass.
///
/// # Safety
/// Caller must ensure avx2 and fma are available on this CPU.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2,fma")]
pub unsafe fn kernel_panel_f32_avx2(
    kern: Kernel,
    xb: &[f32],
    d: usize,
    rows: usize,
    xn: &[f64],
    c: &MatF32,
    cn: &[f64],
    j0: usize,
    param: f64,
    out: &mut [f32],
    ldo: usize,
) {
    let m = c.rows;
    let w = m - j0;
    debug_assert_eq!(xb.len(), rows * d);
    debug_assert_eq!(c.cols, d);
    debug_assert!(rows == 0 || out.len() >= (rows - 1) * ldo + w);
    debug_assert!(ldo >= w);
    match kern {
        Kernel::Gaussian => {
            debug_assert_eq!(xn.len(), rows);
            debug_assert_eq!(cn.len(), m);
            let inv = 1.0 / (2.0 * param * param);
            let invv = _mm256_set1_pd(inv);
            let neg0 = _mm256_set1_pd(-0.0);
            let two = _mm256_set1_pd(2.0);
            let zero = _mm256_setzero_pd();
            for i in 0..rows {
                let xr = &xb[i * d..(i + 1) * d];
                let xni = xn[i];
                let xniv = _mm256_set1_pd(xni);
                let orow = &mut out[i * ldo..i * ldo + w];
                let mut j = j0;
                while j + 4 <= m {
                    let dots = dot4_f32(xr, c.row(j), c.row(j + 1), c.row(j + 2), c.row(j + 3));
                    let cnv = _mm256_loadu_pd(cn.as_ptr().add(j));
                    let sq = _mm256_max_pd(
                        _mm256_sub_pd(_mm256_add_pd(xniv, cnv), _mm256_mul_pd(two, dots)),
                        zero,
                    );
                    let arg = _mm256_mul_pd(_mm256_xor_pd(sq, neg0), invv);
                    _mm_storeu_ps(orow.as_mut_ptr().add(j - j0), _mm256_cvtpd_ps(arg));
                    j += 4;
                }
                while j < m {
                    let dotv = vec_ops::dot_f32(xr, c.row(j));
                    orow[j - j0] = (-(xni + cn[j] - 2.0 * dotv).max(0.0) * inv) as f32;
                    j += 1;
                }
                exp::fast_exp_slice_f32_avx2(orow);
            }
        }
        Kernel::Laplacian => {
            let inv = 1.0 / param;
            let neg0 = _mm256_set1_pd(-0.0);
            for i in 0..rows {
                let xr = &xb[i * d..(i + 1) * d];
                let orow = &mut out[i * ldo..i * ldo + w];
                for j in j0..m {
                    let cr = c.row(j);
                    let mut acc = _mm256_setzero_pd();
                    let mut k = 0;
                    while k + 4 <= d {
                        let diff = _mm256_sub_pd(
                            _mm256_cvtps_pd(_mm_loadu_ps(xr.as_ptr().add(k))),
                            _mm256_cvtps_pd(_mm_loadu_ps(cr.as_ptr().add(k))),
                        );
                        acc = _mm256_add_pd(acc, _mm256_andnot_pd(neg0, diff));
                        k += 4;
                    }
                    let mut l1 = hsum4(acc);
                    while k < d {
                        l1 += (xr[k] as f64 - cr[k] as f64).abs();
                        k += 1;
                    }
                    orow[j - j0] = (-l1 * inv) as f32;
                }
                exp::fast_exp_slice_f32_avx2(orow);
            }
        }
        Kernel::Linear => {
            for i in 0..rows {
                let xr = &xb[i * d..(i + 1) * d];
                let orow = &mut out[i * ldo..i * ldo + w];
                let mut j = j0;
                while j + 4 <= m {
                    let dots = dot4_f32(xr, c.row(j), c.row(j + 1), c.row(j + 2), c.row(j + 3));
                    _mm_storeu_ps(orow.as_mut_ptr().add(j - j0), _mm256_cvtpd_ps(dots));
                    j += 4;
                }
                while j < m {
                    orow[j - j0] = vec_ops::dot_f32(xr, c.row(j)) as f32;
                    j += 1;
                }
            }
        }
    }
}

//! AVX2 lanes of [`fast_exp`] / [`fast_exp_f32`] — 4-wide f64 and
//! 8-wide f32 evaluations of the *identical* constant and operation
//! sequence as the scalar routines (`FAST_EXP_*` constants hoisted in
//! `linalg::vec_ops`), so every non-NaN lane is **bitwise equal** to the
//! scalar result:
//!
//! - clamp, `floor(x·log2e + 0.5)` range reduction, split-ln2
//!   remainder, Horner from the top coefficient — all as separate
//!   mul/add pairs. No FMA inside the polynomial: contracting
//!   `c + r·p` would round differently from the scalar chain and break
//!   the bitwise pin (FMA is reserved for the panel dot products, which
//!   are tol-bounded, not bitwise).
//! - `2^k` assembled in the exponent field via integer lanes
//!   (`cvt → +bias → shift`), exactly like the scalar
//!   `f64::from_bits` path; the conversions round-to-nearest, which is
//!   exact on the integral `kf`.
//! - tails as blends: `x < lo → 0`, `x > hi → +inf`, and an unordered
//!   self-compare restores NaN inputs — `_mm256_min_pd`/`_mm256_max_pd`
//!   return their *second* operand on NaN, so the clamp mangles NaN
//!   lanes and the explicit blend is load-bearing. The restored NaN is
//!   the input value, so only the payload may differ from the scalar
//!   arm's propagated NaN (the property tests compare `is_nan`, not
//!   bits, on NaN lanes).
//!
//! [`fast_exp`]: crate::linalg::vec_ops::fast_exp
//! [`fast_exp_f32`]: crate::linalg::vec_ops::fast_exp_f32

use std::arch::x86_64::*;

use crate::linalg::vec_ops::{
    self, FAST_EXP_COEFFS, FAST_EXP_F32_COEFFS, FAST_EXP_F32_LN2_HI, FAST_EXP_F32_LN2_LO,
    FAST_EXP_F32_LOG2E, FAST_EXP_F32_NEG_CUTOFF, FAST_EXP_F32_POS_CUTOFF, FAST_EXP_LN2_HI,
    FAST_EXP_LN2_LO, FAST_EXP_LOG2E,
};

/// 4 × f64 `fast_exp`, bitwise equal to the scalar on non-NaN lanes.
///
/// # Safety
/// Caller must ensure avx2 and fma are available on this CPU.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn fast_exp4(x: __m256d) -> __m256d {
    let lo = _mm256_set1_pd(-709.0);
    let hi = _mm256_set1_pd(708.0);
    let clamped = _mm256_max_pd(_mm256_min_pd(x, hi), lo);
    let kf = _mm256_floor_pd(_mm256_add_pd(
        _mm256_mul_pd(clamped, _mm256_set1_pd(FAST_EXP_LOG2E)),
        _mm256_set1_pd(0.5),
    ));
    let r = _mm256_sub_pd(
        _mm256_sub_pd(clamped, _mm256_mul_pd(kf, _mm256_set1_pd(FAST_EXP_LN2_HI))),
        _mm256_mul_pd(kf, _mm256_set1_pd(FAST_EXP_LN2_LO)),
    );
    let mut p = _mm256_set1_pd(FAST_EXP_COEFFS[FAST_EXP_COEFFS.len() - 1]);
    let mut i = FAST_EXP_COEFFS.len() - 1;
    while i > 0 {
        i -= 1;
        p = _mm256_add_pd(_mm256_set1_pd(FAST_EXP_COEFFS[i]), _mm256_mul_pd(r, p));
    }
    // 2^k via the exponent field; kf ∈ [-1023, 1021] after the clamp, so
    // the i32 conversion is exact and the biased exponent fits
    let ki = _mm256_cvtepi32_epi64(_mm256_cvtpd_epi32(kf));
    let scale = _mm256_castsi256_pd(_mm256_slli_epi64::<52>(_mm256_add_epi64(
        ki,
        _mm256_set1_epi64x(1023),
    )));
    let out = _mm256_mul_pd(p, scale);
    let neg_tail = _mm256_cmp_pd::<_CMP_LT_OQ>(x, lo);
    let pos_tail = _mm256_cmp_pd::<_CMP_GT_OQ>(x, hi);
    let nan = _mm256_cmp_pd::<_CMP_UNORD_Q>(x, x);
    let out = _mm256_blendv_pd(out, _mm256_setzero_pd(), neg_tail);
    let out = _mm256_blendv_pd(out, _mm256_set1_pd(f64::INFINITY), pos_tail);
    _mm256_blendv_pd(out, x, nan)
}

/// 8 × f32 `fast_exp_f32`, bitwise equal to the scalar on non-NaN lanes.
///
/// # Safety
/// Caller must ensure avx2 and fma are available on this CPU.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn fast_exp8(x: __m256) -> __m256 {
    let lo = _mm256_set1_ps(FAST_EXP_F32_NEG_CUTOFF);
    let hi = _mm256_set1_ps(FAST_EXP_F32_POS_CUTOFF);
    let clamped = _mm256_max_ps(_mm256_min_ps(x, hi), lo);
    let kf = _mm256_floor_ps(_mm256_add_ps(
        _mm256_mul_ps(clamped, _mm256_set1_ps(FAST_EXP_F32_LOG2E)),
        _mm256_set1_ps(0.5),
    ));
    let r = _mm256_sub_ps(
        _mm256_sub_ps(clamped, _mm256_mul_ps(kf, _mm256_set1_ps(FAST_EXP_F32_LN2_HI))),
        _mm256_mul_ps(kf, _mm256_set1_ps(FAST_EXP_F32_LN2_LO)),
    );
    let mut p = _mm256_set1_ps(FAST_EXP_F32_COEFFS[FAST_EXP_F32_COEFFS.len() - 1]);
    let mut i = FAST_EXP_F32_COEFFS.len() - 1;
    while i > 0 {
        i -= 1;
        p = _mm256_add_ps(_mm256_set1_ps(FAST_EXP_F32_COEFFS[i]), _mm256_mul_ps(r, p));
    }
    // 2^k via the exponent field; kf ∈ [-126, 127] by the clamp
    let ki = _mm256_cvtps_epi32(kf);
    let scale = _mm256_castsi256_ps(_mm256_slli_epi32::<23>(_mm256_add_epi32(
        ki,
        _mm256_set1_epi32(127),
    )));
    let out = _mm256_mul_ps(p, scale);
    let neg_tail = _mm256_cmp_ps::<_CMP_LT_OQ>(x, lo);
    let pos_tail = _mm256_cmp_ps::<_CMP_GT_OQ>(x, hi);
    let nan = _mm256_cmp_ps::<_CMP_UNORD_Q>(x, x);
    let out = _mm256_blendv_ps(out, _mm256_setzero_ps(), neg_tail);
    let out = _mm256_blendv_ps(out, _mm256_set1_ps(f32::INFINITY), pos_tail);
    _mm256_blendv_ps(out, x, nan)
}

/// In-place `xs[i] = fast_exp(xs[i])`: 4-lane body, scalar tail (the
/// scalar routine is bitwise identical to a lane, so tail entries are
/// indistinguishable from vectorized ones).
///
/// # Safety
/// Caller must ensure avx2 and fma are available on this CPU.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn fast_exp_slice_avx2(xs: &mut [f64]) {
    let n = xs.len();
    let mut i = 0;
    while i + 4 <= n {
        let v = _mm256_loadu_pd(xs.as_ptr().add(i));
        _mm256_storeu_pd(xs.as_mut_ptr().add(i), fast_exp4(v));
        i += 4;
    }
    while i < n {
        xs[i] = vec_ops::fast_exp(xs[i]);
        i += 1;
    }
}

/// In-place `xs[i] = fast_exp(-xs[i] * inv)` — the Gaussian panel pass.
/// The sign flip is an exact xor with the sign bit and the scale a
/// single multiply, matching the scalar `-v * inv` bit for bit.
///
/// # Safety
/// Caller must ensure avx2 and fma are available on this CPU.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn fast_exp_neg_scale_slice_avx2(xs: &mut [f64], inv: f64) {
    let invv = _mm256_set1_pd(inv);
    let neg0 = _mm256_set1_pd(-0.0);
    let n = xs.len();
    let mut i = 0;
    while i + 4 <= n {
        let v = _mm256_loadu_pd(xs.as_ptr().add(i));
        let arg = _mm256_mul_pd(_mm256_xor_pd(v, neg0), invv);
        _mm256_storeu_pd(xs.as_mut_ptr().add(i), fast_exp4(arg));
        i += 4;
    }
    while i < n {
        xs[i] = vec_ops::fast_exp(-xs[i] * inv);
        i += 1;
    }
}

/// In-place `xs[i] = fast_exp_f32(xs[i])`: 8-lane body, scalar tail.
///
/// # Safety
/// Caller must ensure avx2 and fma are available on this CPU.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn fast_exp_slice_f32_avx2(xs: &mut [f32]) {
    let n = xs.len();
    let mut i = 0;
    while i + 8 <= n {
        let v = _mm256_loadu_ps(xs.as_ptr().add(i));
        _mm256_storeu_ps(xs.as_mut_ptr().add(i), fast_exp8(v));
        i += 8;
    }
    while i < n {
        xs[i] = vec_ops::fast_exp_f32(xs[i]);
        i += 1;
    }
}

//! Runtime ISA dispatch for the kernel panel engine (DESIGN.md §Perf
//! "SIMD panels") — the pure-Rust analogue of the artifact registry in
//! `runtime/spec.rs`: detect the fastest admissible instruction set once
//! at plan/engine construction, then run every panel sweep through that
//! arm for the lifetime of the plan.
//!
//! Three arms exist:
//!
//! - **scalar** — the autovectorizer-friendly tiles in [`super`] and
//!   [`super::mixed`]; always available, and the oracle every SIMD arm
//!   is property-tested against.
//! - **avx2** (`simd::avx2`, x86_64 only) — explicit AVX2/FMA panels: 4
//!   centers per register group, FMA dot products, and 4-lane (f64) /
//!   8-lane (f32) polynomial `exp` (`simd::exp`).
//! - **neon** (`simd::neon`, aarch64 only) — 2-lane f64 / 4-lane f32
//!   NEON panels with the same structure.
//!
//! Determinism contract: within one arm, pooled results stay bitwise
//! equal to serial (job order and per-row arithmetic are unchanged —
//! the ISA is picked once, not per task). *Across* arms, panel values
//! differ by the documented [`super::tol`] SIMD bounds (FMA contraction
//! and lane-order reassociation in the dot products); the vectorized
//! `exp` itself is pinned **bitwise** to the scalar [`fast_exp`] /
//! [`fast_exp_f32`] on every non-NaN input, because both evaluate the
//! identical constant/operation sequence (`linalg::vec_ops`'s hoisted
//! `FAST_EXP_*` constants, no FMA inside the polynomial).
//!
//! Selection precedence: explicit [`SimdMode`] on `EngineOptions` (CLI
//! `--simd`) > the `FALKON_SIMD` environment variable > auto-detection.
//! A forced arm that the host cannot run degrades loudly to scalar.

use crate::linalg::vec_ops::{fast_exp, fast_exp_f32};

#[cfg(target_arch = "x86_64")]
pub mod avx2;
#[cfg(target_arch = "x86_64")]
pub mod exp;
#[cfg(target_arch = "aarch64")]
pub mod neon;

/// The instruction-set arm a plan's panel sweeps run on. Resolved once
/// (from a [`SimdMode`]) and threaded through `RustPlan` / `StreamPlan`
/// / the predict fan-outs; `Copy` so pooled closures capture it freely.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// Autovectorized scalar tiles — always available.
    Scalar,
    /// AVX2 + FMA panels (x86_64, runtime-detected).
    Avx2,
    /// NEON panels (aarch64; baseline feature of the target).
    Neon,
}

#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_available() -> bool {
    false
}

fn neon_available() -> bool {
    cfg!(target_arch = "aarch64")
}

impl Isa {
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    /// Pure feature detection, ignoring `FALKON_SIMD`: the best arm this
    /// host can run. The SIMD-vs-scalar property tests pin this arm
    /// against [`Isa::Scalar`] so both CI legs (default and
    /// `FALKON_SIMD=scalar`) exercise identical arithmetic.
    pub fn detect_best() -> Isa {
        if avx2_available() {
            Isa::Avx2
        } else if neon_available() {
            Isa::Neon
        } else {
            Isa::Scalar
        }
    }

    /// The process-wide default arm: `FALKON_SIMD` (if set) resolved
    /// once, else [`Isa::detect_best`]. Used by the serial convenience
    /// entry points (`kernel_block`, `kmm`, `predict_blocked`, …) that
    /// don't belong to a plan carrying an explicit choice.
    pub fn global() -> Isa {
        use std::sync::OnceLock;
        static GLOBAL: OnceLock<Isa> = OnceLock::new();
        *GLOBAL.get_or_init(|| resolve(SimdMode::from_env()))
    }
}

/// User-facing dispatch override: `FALKON_SIMD=auto|scalar|avx2|neon`,
/// also settable per engine via `EngineOptions::simd` / CLI `--simd`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdMode {
    /// Pick the fastest available arm at construction (the default).
    Auto,
    /// Force the scalar tiles (the CI fallback leg).
    Scalar,
    /// Force AVX2/FMA; degrades loudly to scalar if unavailable.
    Avx2,
    /// Force NEON; degrades loudly to scalar if unavailable.
    Neon,
}

impl SimdMode {
    pub fn name(self) -> &'static str {
        match self {
            SimdMode::Auto => "auto",
            SimdMode::Scalar => "scalar",
            SimdMode::Avx2 => "avx2",
            SimdMode::Neon => "neon",
        }
    }

    pub fn parse(s: &str) -> Option<SimdMode> {
        match s {
            "auto" => Some(SimdMode::Auto),
            "scalar" => Some(SimdMode::Scalar),
            "avx2" => Some(SimdMode::Avx2),
            "neon" => Some(SimdMode::Neon),
            _ => None,
        }
    }

    /// Read `FALKON_SIMD`; unknown values warn and fall back to auto so
    /// a typo never silently changes numerics *and* never aborts a fit.
    pub fn from_env() -> SimdMode {
        match std::env::var("FALKON_SIMD") {
            Ok(s) => SimdMode::parse(&s).unwrap_or_else(|| {
                eprintln!(
                    "[simd] unknown FALKON_SIMD={s:?} (expected auto|scalar|avx2|neon); using auto"
                );
                SimdMode::Auto
            }),
            Err(_) => SimdMode::Auto,
        }
    }
}

/// Resolve a requested mode against what the host supports. A forced
/// arm the host cannot run degrades to scalar with a `[simd]` line —
/// same policy as the engine's `[degraded]` fallbacks: never wrong,
/// never silent.
pub fn resolve(mode: SimdMode) -> Isa {
    match mode {
        SimdMode::Auto => Isa::detect_best(),
        SimdMode::Scalar => Isa::Scalar,
        SimdMode::Avx2 if avx2_available() => Isa::Avx2,
        SimdMode::Neon if neon_available() => Isa::Neon,
        forced => {
            eprintln!(
                "[simd] {} requested but unavailable on this host; using scalar tiles",
                forced.name()
            );
            Isa::Scalar
        }
    }
}

/// [`resolve`] plus a one-time log line recording which arm the process
/// dispatched — so bench JSONs and CI logs show what actually ran.
pub fn resolve_logged(mode: SimdMode) -> Isa {
    use std::sync::Once;
    static LOGGED: Once = Once::new();
    let isa = resolve(mode);
    LOGGED.call_once(|| {
        eprintln!(
            "[simd] kernel panels: {} (override with FALKON_SIMD=auto|scalar|avx2|neon)",
            isa.name()
        );
    });
    isa
}

/// `xs[i] = fast_exp(xs[i])` through the selected arm (the Laplacian
/// panel pass). Lanes are bitwise identical to the scalar loop on
/// non-NaN inputs; NaN lanes stay NaN (payload may differ).
pub fn fast_exp_slice(isa: Isa, xs: &mut [f64]) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Isa::Avx2 is only handed out by resolve()/detect_best()
        // after is_x86_feature_detected! confirmed avx2+fma on this host.
        Isa::Avx2 => unsafe { exp::fast_exp_slice_avx2(xs) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is a baseline feature of every aarch64 target.
        Isa::Neon => unsafe { neon::fast_exp_slice_neon(xs) },
        _ => {
            for v in xs.iter_mut() {
                *v = fast_exp(*v);
            }
        }
    }
}

/// `xs[i] = fast_exp(-xs[i] * inv)` through the selected arm (the
/// Gaussian panel pass over staged squared distances). The negate-scale
/// prologue is exact (sign-bit flip + one multiply, identical to the
/// scalar expression), so the bitwise-lane contract of
/// [`fast_exp_slice`] carries over.
pub fn fast_exp_neg_scale_slice(isa: Isa, xs: &mut [f64], inv: f64) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see fast_exp_slice.
        Isa::Avx2 => unsafe { exp::fast_exp_neg_scale_slice_avx2(xs, inv) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is a baseline feature of every aarch64 target.
        Isa::Neon => unsafe { neon::fast_exp_neg_scale_slice_neon(xs, inv) },
        _ => {
            for v in xs.iter_mut() {
                *v = fast_exp(-*v * inv);
            }
        }
    }
}

/// `xs[i] = fast_exp_f32(xs[i])` through the selected arm — the f32
/// panel pass ([`super::mixed`] stages exponential arguments in f64 and
/// rounds once to f32 before this call, so a plain f32 exp suffices).
pub fn fast_exp_slice_f32(isa: Isa, xs: &mut [f32]) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see fast_exp_slice.
        Isa::Avx2 => unsafe { exp::fast_exp_slice_f32_avx2(xs) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is a baseline feature of every aarch64 target.
        Isa::Neon => unsafe { neon::fast_exp_slice_f32_neon(xs) },
        _ => {
            for v in xs.iter_mut() {
                *v = fast_exp_f32(*v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest::check;

    const MODES: [SimdMode; 4] = [
        SimdMode::Auto,
        SimdMode::Scalar,
        SimdMode::Avx2,
        SimdMode::Neon,
    ];

    #[test]
    fn mode_names_roundtrip() {
        for m in MODES {
            assert_eq!(SimdMode::parse(m.name()), Some(m));
        }
        assert_eq!(SimdMode::parse("avx512"), None);
        assert_eq!(SimdMode::parse(""), None);
        for isa in [Isa::Scalar, Isa::Avx2, Isa::Neon] {
            assert!(!isa.name().is_empty());
        }
    }

    #[test]
    fn resolve_is_total_and_scalar_is_always_honored() {
        // every mode resolves to *something* runnable on this host
        for m in MODES {
            let isa = resolve(m);
            match isa {
                Isa::Scalar => {}
                Isa::Avx2 => assert!(cfg!(target_arch = "x86_64")),
                Isa::Neon => assert!(cfg!(target_arch = "aarch64")),
            }
        }
        assert_eq!(resolve(SimdMode::Scalar), Isa::Scalar);
        // auto resolves to the detected best
        assert_eq!(resolve(SimdMode::Auto), Isa::detect_best());
        // global() is stable across calls (OnceLock)
        assert_eq!(Isa::global(), Isa::global());
    }

    /// The saturation/edge lattice of the satellite task: both tails,
    /// both boundaries, denormal inputs, ±inf and NaN, for f64 and f32.
    fn edge_lattice_f64() -> Vec<f64> {
        vec![
            0.0,
            -0.0,
            1.0,
            -1.0,
            -45.3,
            -300.0,
            700.0,
            708.0,
            708.5,
            709.0,
            709.5,
            1000.0,
            -708.0,
            -708.4,
            -708.9,
            -709.0,
            -709.5,
            -710.0,
            -1000.0,
            1e-320,
            -1e-320,
            f64::MIN_POSITIVE,
            f64::MAX,
            f64::MIN,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::NAN,
        ]
    }

    fn edge_lattice_f32() -> Vec<f32> {
        vec![
            0.0,
            -0.0,
            1.0,
            -1.0,
            -40.5,
            86.0,
            88.0,
            88.5,
            89.0,
            200.0,
            -87.0,
            -87.3,
            -87.4,
            -88.0,
            -200.0,
            1e-44,
            -1e-44,
            f32::MIN_POSITIVE,
            f32::MAX,
            f32::MIN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
        ]
    }

    fn assert_bitwise_f64(got: &[f64], want: &[f64], tag: &str) {
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            if w.is_nan() {
                // NaN lanes stay NaN; the payload may differ between the
                // scalar polynomial and the blend-restored input
                assert!(g.is_nan(), "{tag}[{i}]: expected NaN, got {g:e}");
            } else {
                assert_eq!(
                    g.to_bits(),
                    w.to_bits(),
                    "{tag}[{i}]: {g:e} vs {w:e} (not bitwise)"
                );
            }
        }
    }

    fn assert_bitwise_f32(got: &[f32], want: &[f32], tag: &str) {
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            if w.is_nan() {
                assert!(g.is_nan(), "{tag}[{i}]: expected NaN, got {g:e}");
            } else {
                assert_eq!(
                    g.to_bits(),
                    w.to_bits(),
                    "{tag}[{i}]: {g:e} vs {w:e} (not bitwise)"
                );
            }
        }
    }

    #[test]
    fn simd_exp_lanes_are_bitwise_scalar_on_the_edge_lattice() {
        let isa = Isa::detect_best();
        if isa == Isa::Scalar {
            eprintln!("[simd] no vector arm on this host; edge-lattice test is vacuous");
        }
        // f64: lattice + ragged tails (lengths not multiples of the lane
        // width) so both the vector groups and the scalar tail run
        let lattice = edge_lattice_f64();
        for len in [1usize, 3, 4, 5, 7, lattice.len()] {
            let base: Vec<f64> = lattice.iter().cycle().take(len).copied().collect();
            let mut got = base.clone();
            fast_exp_slice(isa, &mut got);
            let want: Vec<f64> = base.iter().map(|&x| fast_exp(x)).collect();
            assert_bitwise_f64(&got, &want, "exp64");
        }
        let lattice = edge_lattice_f32();
        for len in [1usize, 5, 8, 9, 11, lattice.len()] {
            let base: Vec<f32> = lattice.iter().cycle().take(len).copied().collect();
            let mut got = base.clone();
            fast_exp_slice_f32(isa, &mut got);
            let want: Vec<f32> = base.iter().map(|&x| fast_exp_f32(x)).collect();
            assert_bitwise_f32(&got, &want, "exp32");
        }
    }

    #[test]
    fn simd_exp_lanes_are_bitwise_scalar_on_random_slices() {
        let isa = Isa::detect_best();
        check("simd exp = scalar exp (bitwise)", 25, |g| {
            let n = g.usize_in(1, 40);
            let base: Vec<f64> = (0..n).map(|_| g.f64_in(-750.0, 750.0)).collect();
            let mut got = base.clone();
            fast_exp_slice(isa, &mut got);
            let want: Vec<f64> = base.iter().map(|&x| fast_exp(x)).collect();
            assert_bitwise_f64(&got, &want, "exp64");

            // the Gaussian pass shape: nonnegative squared distances
            let sq: Vec<f64> = (0..n).map(|_| g.f64_in(0.0, 200.0)).collect();
            let inv = g.f64_in(0.01, 4.0);
            let mut got = sq.clone();
            fast_exp_neg_scale_slice(isa, &mut got, inv);
            let want: Vec<f64> = sq.iter().map(|&v| fast_exp(-v * inv)).collect();
            assert_bitwise_f64(&got, &want, "neg-scale");

            let base32: Vec<f32> = (0..n).map(|_| g.f64_in(-100.0, 100.0) as f32).collect();
            let mut got = base32.clone();
            fast_exp_slice_f32(isa, &mut got);
            let want: Vec<f32> = base32.iter().map(|&x| fast_exp_f32(x)).collect();
            assert_bitwise_f32(&got, &want, "exp32");
        });
    }

    #[test]
    fn forced_scalar_slices_match_direct_scalar() {
        // the FALKON_SIMD=scalar leg: dispatching Scalar must be the
        // plain loop, bit for bit, on every edge input
        let mut a = edge_lattice_f64();
        let want: Vec<f64> = a.iter().map(|&x| fast_exp(x)).collect();
        fast_exp_slice(Isa::Scalar, &mut a);
        assert_bitwise_f64(&a, &want, "scalar64");
        let mut b = edge_lattice_f32();
        let want: Vec<f32> = b.iter().map(|&x| fast_exp_f32(x)).collect();
        fast_exp_slice_f32(Isa::Scalar, &mut b);
        assert_bitwise_f32(&b, &want, "scalar32");
    }
}

//! NEON arm of the kernel panel engine (aarch64): 2-lane f64 / 4-lane
//! f32 versions of the panel dot products, norm-expansion staging and
//! polynomial `exp`. Same contracts as the AVX2 arm in `simd::avx2` /
//! `simd::exp`: panel values differ from scalar only by FMA contraction
//! and lane reassociation in the dots (tol-bounded), while the `exp`
//! lanes evaluate the identical `FAST_EXP_*` constant/operation
//! sequence and stay bitwise equal to the scalar on non-NaN inputs. One
//! NaN wrinkle differs from x86: NEON `FMIN`/`FMAX` *propagate* NaN, so
//! the clamp keeps NaN lanes NaN and no explicit unordered blend is
//! needed (the payload may still differ from the scalar arm's — tests
//! compare `is_nan`, not bits, on NaN lanes).

use std::arch::aarch64::*;

use crate::kernels::Kernel;
use crate::linalg::mat::Mat;
use crate::linalg::mat32::MatF32;
use crate::linalg::vec_ops;
use crate::linalg::vec_ops::{
    FAST_EXP_COEFFS, FAST_EXP_F32_COEFFS, FAST_EXP_F32_LN2_HI, FAST_EXP_F32_LN2_LO,
    FAST_EXP_F32_LOG2E, FAST_EXP_F32_NEG_CUTOFF, FAST_EXP_F32_POS_CUTOFF, FAST_EXP_LN2_HI,
    FAST_EXP_LN2_LO, FAST_EXP_LOG2E,
};

/// 2 × f64 `fast_exp`, bitwise equal to the scalar on non-NaN lanes.
///
/// # Safety
/// NEON is a baseline feature of every aarch64 target; callers reach
/// this only through an [`super::Isa::Neon`] dispatch.
#[target_feature(enable = "neon")]
unsafe fn fast_exp2(x: float64x2_t) -> float64x2_t {
    let lo = vdupq_n_f64(-709.0);
    let hi = vdupq_n_f64(708.0);
    // FMIN/FMAX propagate NaN, so NaN lanes flow through untouched
    let clamped = vmaxq_f64(vminq_f64(x, hi), lo);
    let kf = vrndmq_f64(vaddq_f64(
        vmulq_f64(clamped, vdupq_n_f64(FAST_EXP_LOG2E)),
        vdupq_n_f64(0.5),
    ));
    let r = vsubq_f64(
        vsubq_f64(clamped, vmulq_f64(kf, vdupq_n_f64(FAST_EXP_LN2_HI))),
        vmulq_f64(kf, vdupq_n_f64(FAST_EXP_LN2_LO)),
    );
    let mut p = vdupq_n_f64(FAST_EXP_COEFFS[FAST_EXP_COEFFS.len() - 1]);
    let mut i = FAST_EXP_COEFFS.len() - 1;
    while i > 0 {
        i -= 1;
        p = vaddq_f64(vdupq_n_f64(FAST_EXP_COEFFS[i]), vmulq_f64(r, p));
    }
    // 2^k through the exponent field; the truncating convert is exact on
    // the integral kf ∈ [-1023, 1021] (NaN lanes convert to 0 — their
    // polynomial value is already NaN, so the scale is irrelevant)
    let ki = vcvtq_s64_f64(kf);
    let scale = vreinterpretq_f64_s64(vshlq_n_s64::<52>(vaddq_s64(ki, vdupq_n_s64(1023))));
    let out = vmulq_f64(p, scale);
    let neg_tail = vcltq_f64(x, lo);
    let pos_tail = vcgtq_f64(x, hi);
    let out = vbslq_f64(neg_tail, vdupq_n_f64(0.0), out);
    vbslq_f64(pos_tail, vdupq_n_f64(f64::INFINITY), out)
}

/// 4 × f32 `fast_exp_f32`, bitwise equal to the scalar on non-NaN lanes.
///
/// # Safety
/// NEON is a baseline feature of every aarch64 target.
#[target_feature(enable = "neon")]
unsafe fn fast_exp4_f32(x: float32x4_t) -> float32x4_t {
    let lo = vdupq_n_f32(FAST_EXP_F32_NEG_CUTOFF);
    let hi = vdupq_n_f32(FAST_EXP_F32_POS_CUTOFF);
    let clamped = vmaxq_f32(vminq_f32(x, hi), lo);
    let kf = vrndmq_f32(vaddq_f32(
        vmulq_f32(clamped, vdupq_n_f32(FAST_EXP_F32_LOG2E)),
        vdupq_n_f32(0.5),
    ));
    let r = vsubq_f32(
        vsubq_f32(clamped, vmulq_f32(kf, vdupq_n_f32(FAST_EXP_F32_LN2_HI))),
        vmulq_f32(kf, vdupq_n_f32(FAST_EXP_F32_LN2_LO)),
    );
    let mut p = vdupq_n_f32(FAST_EXP_F32_COEFFS[FAST_EXP_F32_COEFFS.len() - 1]);
    let mut i = FAST_EXP_F32_COEFFS.len() - 1;
    while i > 0 {
        i -= 1;
        p = vaddq_f32(vdupq_n_f32(FAST_EXP_F32_COEFFS[i]), vmulq_f32(r, p));
    }
    let ki = vcvtq_s32_f32(kf);
    let scale = vreinterpretq_f32_s32(vshlq_n_s32::<23>(vaddq_s32(ki, vdupq_n_s32(127))));
    let out = vmulq_f32(p, scale);
    let neg_tail = vcltq_f32(x, lo);
    let pos_tail = vcgtq_f32(x, hi);
    let out = vbslq_f32(neg_tail, vdupq_n_f32(0.0), out);
    vbslq_f32(pos_tail, vdupq_n_f32(f32::INFINITY), out)
}

/// In-place `xs[i] = fast_exp(xs[i])`: 2-lane body, scalar tail.
///
/// # Safety
/// NEON is a baseline feature of every aarch64 target.
#[target_feature(enable = "neon")]
pub unsafe fn fast_exp_slice_neon(xs: &mut [f64]) {
    let n = xs.len();
    let mut i = 0;
    while i + 2 <= n {
        let v = vld1q_f64(xs.as_ptr().add(i));
        vst1q_f64(xs.as_mut_ptr().add(i), fast_exp2(v));
        i += 2;
    }
    while i < n {
        xs[i] = vec_ops::fast_exp(xs[i]);
        i += 1;
    }
}

/// In-place `xs[i] = fast_exp(-xs[i] * inv)` — the Gaussian panel pass.
///
/// # Safety
/// NEON is a baseline feature of every aarch64 target.
#[target_feature(enable = "neon")]
pub unsafe fn fast_exp_neg_scale_slice_neon(xs: &mut [f64], inv: f64) {
    let invv = vdupq_n_f64(inv);
    let n = xs.len();
    let mut i = 0;
    while i + 2 <= n {
        let v = vld1q_f64(xs.as_ptr().add(i));
        let arg = vmulq_f64(vnegq_f64(v), invv);
        vst1q_f64(xs.as_mut_ptr().add(i), fast_exp2(arg));
        i += 2;
    }
    while i < n {
        xs[i] = vec_ops::fast_exp(-xs[i] * inv);
        i += 1;
    }
}

/// In-place `xs[i] = fast_exp_f32(xs[i])`: 4-lane body, scalar tail.
///
/// # Safety
/// NEON is a baseline feature of every aarch64 target.
#[target_feature(enable = "neon")]
pub unsafe fn fast_exp_slice_f32_neon(xs: &mut [f32]) {
    let n = xs.len();
    let mut i = 0;
    while i + 4 <= n {
        let v = vld1q_f32(xs.as_ptr().add(i));
        vst1q_f32(xs.as_mut_ptr().add(i), fast_exp4_f32(v));
        i += 4;
    }
    while i < n {
        xs[i] = vec_ops::fast_exp_f32(xs[i]);
        i += 1;
    }
}

/// f64 dot product with 2-lane FMA accumulation and a scalar tail.
///
/// # Safety
/// NEON is a baseline feature of every aarch64 target.
#[target_feature(enable = "neon")]
unsafe fn dot_neon(a: &[f64], b: &[f64]) -> f64 {
    let d = a.len();
    let mut acc = vdupq_n_f64(0.0);
    let mut k = 0;
    while k + 2 <= d {
        acc = vfmaq_f64(acc, vld1q_f64(a.as_ptr().add(k)), vld1q_f64(b.as_ptr().add(k)));
        k += 2;
    }
    let mut s = vaddvq_f64(acc);
    while k < d {
        s += a[k] * b[k];
        k += 1;
    }
    s
}

/// [`dot_neon`] over f32 storage: lanes widened to f64 before the FMA,
/// so accumulation is pure f64 and each product is exact.
///
/// # Safety
/// NEON is a baseline feature of every aarch64 target.
#[target_feature(enable = "neon")]
unsafe fn dot_neon_f32(a: &[f32], b: &[f32]) -> f64 {
    let d = a.len();
    let mut acc = vdupq_n_f64(0.0);
    let mut k = 0;
    while k + 2 <= d {
        acc = vfmaq_f64(
            acc,
            vcvt_f64_f32(vld1_f32(a.as_ptr().add(k))),
            vcvt_f64_f32(vld1_f32(b.as_ptr().add(k))),
        );
        k += 2;
    }
    let mut s = vaddvq_f64(acc);
    while k < d {
        s += a[k] as f64 * b[k] as f64;
        k += 1;
    }
    s
}

/// f64 L1 distance with 2-lane abs accumulation and a scalar tail.
///
/// # Safety
/// NEON is a baseline feature of every aarch64 target.
#[target_feature(enable = "neon")]
unsafe fn l1_neon(a: &[f64], b: &[f64]) -> f64 {
    let d = a.len();
    let mut acc = vdupq_n_f64(0.0);
    let mut k = 0;
    while k + 2 <= d {
        let diff = vsubq_f64(vld1q_f64(a.as_ptr().add(k)), vld1q_f64(b.as_ptr().add(k)));
        acc = vaddq_f64(acc, vabsq_f64(diff));
        k += 2;
    }
    let mut s = vaddvq_f64(acc);
    while k < d {
        s += (a[k] - b[k]).abs();
        k += 1;
    }
    s
}

/// NEON arm of `kernel_panel`: same layout contract (`j0`, `ldo`) and
/// staging expressions as the scalar tiles, with the dot/L1 inner loops
/// vectorized and the exponential pass through the NEON lanes.
///
/// # Safety
/// NEON is a baseline feature of every aarch64 target.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "neon")]
pub unsafe fn kernel_panel_neon(
    kern: Kernel,
    xb: &[f64],
    d: usize,
    rows: usize,
    xn: &[f64],
    c: &Mat,
    cn: &[f64],
    j0: usize,
    param: f64,
    out: &mut [f64],
    ldo: usize,
) {
    let m = c.rows;
    let w = m - j0;
    debug_assert_eq!(xb.len(), rows * d);
    debug_assert_eq!(c.cols, d);
    debug_assert!(rows == 0 || out.len() >= (rows - 1) * ldo + w);
    debug_assert!(ldo >= w);
    match kern {
        Kernel::Gaussian => {
            debug_assert_eq!(xn.len(), rows);
            debug_assert_eq!(cn.len(), m);
            let inv = 1.0 / (2.0 * param * param);
            for i in 0..rows {
                let xr = &xb[i * d..(i + 1) * d];
                let xni = xn[i];
                let orow = &mut out[i * ldo..i * ldo + w];
                for j in j0..m {
                    let dotv = dot_neon(xr, c.row(j));
                    orow[j - j0] = (xni + cn[j] - 2.0 * dotv).max(0.0);
                }
                fast_exp_neg_scale_slice_neon(orow, inv);
            }
        }
        Kernel::Laplacian => {
            let inv = 1.0 / param;
            for i in 0..rows {
                let xr = &xb[i * d..(i + 1) * d];
                let orow = &mut out[i * ldo..i * ldo + w];
                for j in j0..m {
                    orow[j - j0] = -l1_neon(xr, c.row(j)) * inv;
                }
                fast_exp_slice_neon(orow);
            }
        }
        Kernel::Linear => {
            for i in 0..rows {
                let xr = &xb[i * d..(i + 1) * d];
                let orow = &mut out[i * ldo..i * ldo + w];
                for j in j0..m {
                    orow[j - j0] = dot_neon(xr, c.row(j));
                }
            }
        }
    }
}

/// NEON arm of `mixed::kernel_panel_f32`: f32 storage widened to f64
/// lanes, staged in f64, rounded once to f32, then the 4-lane f32 exp.
///
/// # Safety
/// NEON is a baseline feature of every aarch64 target.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "neon")]
pub unsafe fn kernel_panel_f32_neon(
    kern: Kernel,
    xb: &[f32],
    d: usize,
    rows: usize,
    xn: &[f64],
    c: &MatF32,
    cn: &[f64],
    j0: usize,
    param: f64,
    out: &mut [f32],
    ldo: usize,
) {
    let m = c.rows;
    let w = m - j0;
    debug_assert_eq!(xb.len(), rows * d);
    debug_assert_eq!(c.cols, d);
    debug_assert!(rows == 0 || out.len() >= (rows - 1) * ldo + w);
    debug_assert!(ldo >= w);
    match kern {
        Kernel::Gaussian => {
            debug_assert_eq!(xn.len(), rows);
            debug_assert_eq!(cn.len(), m);
            let inv = 1.0 / (2.0 * param * param);
            for i in 0..rows {
                let xr = &xb[i * d..(i + 1) * d];
                let xni = xn[i];
                let orow = &mut out[i * ldo..i * ldo + w];
                for j in j0..m {
                    let dotv = dot_neon_f32(xr, c.row(j));
                    orow[j - j0] = (-(xni + cn[j] - 2.0 * dotv).max(0.0) * inv) as f32;
                }
                fast_exp_slice_f32_neon(orow);
            }
        }
        Kernel::Laplacian => {
            let inv = 1.0 / param;
            for i in 0..rows {
                let xr = &xb[i * d..(i + 1) * d];
                let orow = &mut out[i * ldo..i * ldo + w];
                for j in j0..m {
                    let mut l1 = 0.0f64;
                    let mut k = 0;
                    let mut acc = vdupq_n_f64(0.0);
                    let cr = c.row(j);
                    while k + 2 <= d {
                        let diff = vsubq_f64(
                            vcvt_f64_f32(vld1_f32(xr.as_ptr().add(k))),
                            vcvt_f64_f32(vld1_f32(cr.as_ptr().add(k))),
                        );
                        acc = vaddq_f64(acc, vabsq_f64(diff));
                        k += 2;
                    }
                    l1 += vaddvq_f64(acc);
                    while k < d {
                        l1 += (xr[k] as f64 - cr[k] as f64).abs();
                        k += 1;
                    }
                    orow[j - j0] = (-l1 * inv) as f32;
                }
                fast_exp_slice_f32_neon(orow);
            }
        }
        Kernel::Linear => {
            for i in 0..rows {
                let xr = &xb[i * d..(i + 1) * d];
                let orow = &mut out[i * ldo..i * ldo + w];
                for j in j0..m {
                    orow[j - j0] = dot_neon_f32(xr, c.row(j)) as f32;
                }
            }
        }
    }
}

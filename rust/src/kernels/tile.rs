//! Shared panel tiling geometry for the scalar, SIMD and mixed-precision
//! kernel arms: the row tile height and the reusable Kr/Y scratch layout
//! that every tiled sweep ([`super::knm_matvec_blocked`],
//! [`super::knm_matmat_blocked`], the `_f32` twins in [`super::mixed`],
//! and the `kernels/simd` panels) consumes. Keeping the geometry in one
//! place guarantees the SIMD and scalar arms tile identically — the
//! SIMD-vs-scalar property tests compare sweeps panel-for-panel, which
//! is only meaningful if both sides cut the same panels.

/// Row tile height of the fused matvec: one Kr panel is `TILE × M` f64s
/// (1 MiB at M = 1024), sized to stay L2-resident across its two passes.
pub const DEFAULT_TILE: usize = 128;

/// Reusable per-thread buffers for the tiled kernels: one Kr tile
/// (`tile × M`) plus the fused intermediate Y (`tile × K`; K = 1 on the
/// vector path). Built once per plan/worker; the apply loop performs no
/// X-block heap allocation.
pub struct TileScratch {
    pub(crate) tile: usize,
    pub(crate) kr: Vec<f64>,
    /// f32 Kr tile for the mixed-precision panels ([`super::mixed`]);
    /// empty until the first f32 apply so f64-only plans allocate nothing
    /// extra. The fused Y stays `f64` for both tiers (stage-1 results
    /// accumulate in double).
    pub(crate) kr32: Vec<f32>,
    pub(crate) y: Vec<f64>,
}

impl TileScratch {
    pub fn new(tile: usize, m: usize) -> TileScratch {
        let tile = tile.max(1);
        TileScratch {
            tile,
            kr: vec![0.0; tile * m],
            kr32: Vec::new(),
            y: vec![0.0; tile],
        }
    }

    /// [`TileScratch::new`] for the mixed-precision tier: allocates the
    /// f32 Kr tile up front and leaves the f64 one empty (it grows on
    /// demand if the same scratch later serves an f64 sweep).
    pub(crate) fn new32(tile: usize, m: usize) -> TileScratch {
        let tile = tile.max(1);
        TileScratch {
            tile,
            kr: Vec::new(),
            kr32: vec![0.0; tile * m],
            y: vec![0.0; tile],
        }
    }

    pub fn tile(&self) -> usize {
        self.tile
    }

    /// Grow the Kr buffer if a caller re-uses the scratch with a larger M.
    pub(crate) fn ensure(&mut self, m: usize) {
        self.ensure_multi(m, 1);
    }

    /// Grow both buffers for a multi-RHS apply: Kr to `tile × M`, Y to
    /// `tile × K`. A pool worker's scratch is sized to the widest K it has
    /// served — a later plan with more classes grows it once, in place.
    pub(crate) fn ensure_multi(&mut self, m: usize, k: usize) {
        if self.kr.len() < self.tile * m {
            self.kr.resize(self.tile * m, 0.0);
        }
        if self.y.len() < self.tile * k {
            self.y.resize(self.tile * k, 0.0);
        }
    }

    /// [`TileScratch::ensure`] for the f32 Kr tile.
    pub(crate) fn ensure32(&mut self, m: usize) {
        self.ensure_multi32(m, 1);
    }

    /// [`TileScratch::ensure_multi`] for the f32 Kr tile (Y is shared —
    /// stage-1 results are `f64` on both tiers).
    pub(crate) fn ensure_multi32(&mut self, m: usize, k: usize) {
        if self.kr32.len() < self.tile * m {
            self.kr32.resize(self.tile * m, 0.0);
        }
        if self.y.len() < self.tile * k {
            self.y.resize(self.tile * k, 0.0);
        }
    }
}

//! Error model of the mixed-precision tier ([`super::mixed`]) — the
//! *documented* per-kernel bounds the property tests assert, instead of
//! ad-hoc epsilons (DESIGN.md §"Precision model").
//!
//! All bounds compare the f32 path against the **f64 oracle on the same
//! rounded inputs** (both tiers read identical `f32`-representable
//! values, so storage rounding is not part of these bounds — it is the
//! separate, data-dependent term the e2e accuracy tests measure as RMSE
//! drift). Because every reduction accumulates in `f64` and products of
//! two `f32`s are exact in `f64`, the only `eps32`-scale error sources
//! per Kr entry are:
//!
//! - one rounding of the exponential argument a (or linear dot) to
//!   `f32`: relative error ≤ eps32/2;
//! - the [`crate::linalg::vec_ops::fast_exp_f32`] polynomial: relative
//!   error ≤ [`EXP32_RELERR`];
//! - one rounding of the stored entry to `f32`: ≤ eps32/2 for the
//!   exponential kernels (K ≤ 1).
//!
//! **Exponential kernels** (Gaussian, Laplacian): an argument
//! perturbation δa changes exp(−a) by exp(−a)·δa ≤ exp(−a)·a·eps32/2,
//! and a·exp(−a) ≤ 1/e over a ≥ 0 — so the entry error is bounded by
//! `(1/e + 1/2)·eps32 + EXP32_RELERR ≤ EPS32 + EXP32_RELERR`
//! *independent of the data and bandwidth*.
//!
//! **Linear kernel**: the single rounding of the f64 dot gives
//! `|δK| ≤ |x·c|·eps32/2 ≤ Rx·Rc·eps32/2` with `Rx`, `Rc` the largest
//! row norms of the two operands.
//!
//! Entry bounds then propagate through the fused stages (all-`f64`
//! accumulation, so no further `eps32` terms):
//!
//! - matvec  w = Krᵀ(Kr·u + v):  `|δw|∞ ≤ n·δ·(2·kmax·‖u‖₁ + ‖v‖∞)`,
//!   where kmax bounds |K| entries (1 for the exponential kernels,
//!   Rx·Rc for linear);
//! - matmat: the matvec bound with the worst column's ‖u_col‖₁ and the
//!   global `‖V‖max`;
//! - predict f = Kr·α:  `|δf|∞ ≤ δ·‖α‖₁`.
//!
//! Every bound carries a [`SAFETY`] factor of 4 so it is robust to the
//! worst-case alignment of independent roundings while staying ~2–3
//! orders of magnitude below what an (incorrect) f32-accumulated path
//! would produce — tight enough to catch a missing widening.

use crate::linalg::mat::Mat;
use crate::linalg::mat32::MatF32;

use super::mixed::row_sq_norms_f32;
use super::Kernel;

/// `f32` machine epsilon, widened (2⁻²³ ≈ 1.19e-7).
pub const EPS32: f64 = f32::EPSILON as f64;

/// Relative error bound of [`crate::linalg::vec_ops::fast_exp_f32`] on
/// the non-saturated domain (measured max ≈ 1.0e-7; documented with 3×
/// headroom).
pub const EXP32_RELERR: f64 = 3.0e-7;

/// Worst-case-alignment headroom applied to every bound.
pub const SAFETY: f64 = 4.0;

/// Largest row L2 norm of an f32 block, accumulated in f64.
fn max_row_norm(x: &MatF32) -> f64 {
    row_sq_norms_f32(x)
        .into_iter()
        .fold(0.0f64, f64::max)
        .sqrt()
}

/// Bound on |K(x,c)| over the data: 1 for the exponential kernels, the
/// Cauchy–Schwarz bound Rx·Rc for linear.
pub fn kmax(kern: Kernel, x: &MatF32, c: &MatF32) -> f64 {
    match kern {
        Kernel::Gaussian | Kernel::Laplacian => 1.0,
        Kernel::Linear => max_row_norm(x) * max_row_norm(c),
    }
}

/// Per-entry bound |K32(x,c) − K64(x,c)| on identical (rounded) inputs —
/// see the module docs for the derivation. Bandwidth-independent for the
/// exponential kernels; `SAFETY·Rx·Rc·EPS32/2` for linear.
pub fn entry_bound(kern: Kernel, x: &MatF32, c: &MatF32) -> f64 {
    match kern {
        Kernel::Gaussian | Kernel::Laplacian => SAFETY * (EPS32 + EXP32_RELERR),
        Kernel::Linear => SAFETY * max_row_norm(x) * max_row_norm(c) * 0.5 * EPS32,
    }
}

/// Bound on `|δw|∞` for the fused w = Krᵀ(mask ⊙ (Kr·u + v)) over
/// `rows` rows of `x` (pass the sweep's total row count when summing
/// several blocks/chunks into one `w`). Masks only shrink the error, so
/// the unmasked bound is used.
pub fn matvec_bound(
    kern: Kernel,
    x: &MatF32,
    c: &MatF32,
    rows: usize,
    u: &[f64],
    v: Option<&[f64]>,
) -> f64 {
    let u_l1: f64 = u.iter().map(|t| t.abs()).sum();
    let v_inf = v
        .map(|vf| vf.iter().fold(0.0f64, |a, t| a.max(t.abs())))
        .unwrap_or(0.0);
    let delta = entry_bound(kern, x, c);
    let km = kmax(kern, x, c);
    (rows as f64) * delta * (2.0 * km * u_l1 + v_inf)
}

/// Multi-RHS [`matvec_bound`]: the worst column's ‖u_col‖₁ against the
/// global max |V| (v is the row-major `rows × K` offset block).
pub fn matmat_bound(
    kern: Kernel,
    x: &MatF32,
    c: &MatF32,
    rows: usize,
    u: &Mat,
    v: Option<&[f64]>,
) -> f64 {
    let mut u_l1 = 0.0f64;
    for kc in 0..u.cols {
        let col: f64 = (0..u.rows).map(|j| u[(j, kc)].abs()).sum();
        u_l1 = u_l1.max(col);
    }
    let v_inf = v
        .map(|vf| vf.iter().fold(0.0f64, |a, t| a.max(t.abs())))
        .unwrap_or(0.0);
    let delta = entry_bound(kern, x, c);
    let km = kmax(kern, x, c);
    (rows as f64) * delta * (2.0 * km * u_l1 + v_inf)
}

/// Bound on `|δf|∞` for predictions f = Kr·α.
pub fn predict_bound(kern: Kernel, x: &MatF32, c: &MatF32, alpha: &[f64]) -> f64 {
    let a_l1: f64 = alpha.iter().map(|t| t.abs()).sum();
    entry_bound(kern, x, c) * a_l1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exponential_entry_bound_is_data_independent() {
        let small = MatF32::from_f64s(1, 1, &[0.1]);
        let big = MatF32::from_f64s(2, 1, &[100.0, -250.0]);
        for kern in [Kernel::Gaussian, Kernel::Laplacian] {
            assert_eq!(
                entry_bound(kern, &small, &small),
                entry_bound(kern, &big, &big),
                "{kern:?}"
            );
            assert!(entry_bound(kern, &small, &small) < 2e-6);
            assert_eq!(kmax(kern, &big, &big), 1.0);
        }
        // linear scales with the data
        assert!(
            entry_bound(Kernel::Linear, &big, &big) > entry_bound(Kernel::Linear, &small, &small)
        );
        let rmax = (100.0f64 * 100.0 + 0.0).sqrt().max(250.0);
        assert!((kmax(Kernel::Linear, &big, &big) - rmax * rmax).abs() < 1e-9);
    }

    #[test]
    fn propagation_bounds_scale_with_the_sweep() {
        let x = MatF32::from_f64s(2, 2, &[0.5, -1.0, 2.0, 0.25]);
        let c = MatF32::from_f64s(1, 2, &[1.0, 1.0]);
        let u = [2.0, -3.0];
        let b1 = matvec_bound(Kernel::Gaussian, &x, &c, 10, &u, None);
        let b2 = matvec_bound(Kernel::Gaussian, &x, &c, 20, &u, None);
        assert!((b2 - 2.0 * b1).abs() < 1e-18);
        // a v offset only adds error
        assert!(matvec_bound(Kernel::Gaussian, &x, &c, 10, &u, Some(&[5.0, -1.0])) > b1);
        // predict bound is row-count free and ‖α‖₁-linear
        let p1 = predict_bound(Kernel::Gaussian, &x, &c, &[1.0]);
        let p2 = predict_bound(Kernel::Gaussian, &x, &c, &[1.0, -1.0]);
        assert!((p2 - 2.0 * p1).abs() < 1e-18);
    }
}

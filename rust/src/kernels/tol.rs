//! Error model of the mixed-precision tier ([`super::mixed`]) — the
//! *documented* per-kernel bounds the property tests assert, instead of
//! ad-hoc epsilons (DESIGN.md §"Precision model").
//!
//! All bounds compare the f32 path against the **f64 oracle on the same
//! rounded inputs** (both tiers read identical `f32`-representable
//! values, so storage rounding is not part of these bounds — it is the
//! separate, data-dependent term the e2e accuracy tests measure as RMSE
//! drift). Because every reduction accumulates in `f64` and products of
//! two `f32`s are exact in `f64`, the only `eps32`-scale error sources
//! per Kr entry are:
//!
//! - one rounding of the exponential argument a (or linear dot) to
//!   `f32`: relative error ≤ eps32/2;
//! - the [`crate::linalg::vec_ops::fast_exp_f32`] polynomial: relative
//!   error ≤ [`EXP32_RELERR`];
//! - one rounding of the stored entry to `f32`: ≤ eps32/2 for the
//!   exponential kernels (K ≤ 1).
//!
//! **Exponential kernels** (Gaussian, Laplacian): an argument
//! perturbation δa changes exp(−a) by exp(−a)·δa ≤ exp(−a)·a·eps32/2,
//! and a·exp(−a) ≤ 1/e over a ≥ 0 — so the entry error is bounded by
//! `(1/e + 1/2)·eps32 + EXP32_RELERR ≤ EPS32 + EXP32_RELERR`
//! *independent of the data and bandwidth*.
//!
//! **Linear kernel**: the single rounding of the f64 dot gives
//! `|δK| ≤ |x·c|·eps32/2 ≤ Rx·Rc·eps32/2` with `Rx`, `Rc` the largest
//! row norms of the two operands.
//!
//! Entry bounds then propagate through the fused stages (all-`f64`
//! accumulation, so no further `eps32` terms):
//!
//! - matvec  w = Krᵀ(Kr·u + v):  `|δw|∞ ≤ n·δ·(2·kmax·‖u‖₁ + ‖v‖∞)`,
//!   where kmax bounds |K| entries (1 for the exponential kernels,
//!   Rx·Rc for linear);
//! - matmat: the matvec bound with the worst column's ‖u_col‖₁ and the
//!   global `‖V‖max`;
//! - predict f = Kr·α:  `|δf|∞ ≤ δ·‖α‖₁`.
//!
//! Every bound carries a [`SAFETY`] factor of 4 so it is robust to the
//! worst-case alignment of independent roundings while staying ~2–3
//! orders of magnitude below what an (incorrect) f32-accumulated path
//! would produce — tight enough to catch a missing widening.

use crate::linalg::mat::Mat;
use crate::linalg::mat32::MatF32;

use super::mixed::row_sq_norms_f32;
use super::{row_sq_norms, Kernel};

/// `f32` machine epsilon, widened (2⁻²³ ≈ 1.19e-7).
pub const EPS32: f64 = f32::EPSILON as f64;

/// Relative error bound of [`crate::linalg::vec_ops::fast_exp_f32`] on
/// the non-saturated domain (measured max ≈ 1.0e-7; documented with 3×
/// headroom).
pub const EXP32_RELERR: f64 = 3.0e-7;

/// Worst-case-alignment headroom applied to every bound.
pub const SAFETY: f64 = 4.0;

/// Largest row L2 norm of an f32 block, accumulated in f64.
fn max_row_norm(x: &MatF32) -> f64 {
    row_sq_norms_f32(x)
        .into_iter()
        .fold(0.0f64, f64::max)
        .sqrt()
}

/// Bound on |K(x,c)| over the data: 1 for the exponential kernels, the
/// Cauchy–Schwarz bound Rx·Rc for linear.
pub fn kmax(kern: Kernel, x: &MatF32, c: &MatF32) -> f64 {
    match kern {
        Kernel::Gaussian | Kernel::Laplacian => 1.0,
        Kernel::Linear => max_row_norm(x) * max_row_norm(c),
    }
}

/// Per-entry bound |K32(x,c) − K64(x,c)| on identical (rounded) inputs —
/// see the module docs for the derivation. Bandwidth-independent for the
/// exponential kernels; `SAFETY·Rx·Rc·EPS32/2` for linear.
pub fn entry_bound(kern: Kernel, x: &MatF32, c: &MatF32) -> f64 {
    match kern {
        Kernel::Gaussian | Kernel::Laplacian => SAFETY * (EPS32 + EXP32_RELERR),
        Kernel::Linear => SAFETY * max_row_norm(x) * max_row_norm(c) * 0.5 * EPS32,
    }
}

/// Bound on `|δw|∞` for the fused w = Krᵀ(mask ⊙ (Kr·u + v)) over
/// `rows` rows of `x` (pass the sweep's total row count when summing
/// several blocks/chunks into one `w`). Masks only shrink the error, so
/// the unmasked bound is used.
pub fn matvec_bound(
    kern: Kernel,
    x: &MatF32,
    c: &MatF32,
    rows: usize,
    u: &[f64],
    v: Option<&[f64]>,
) -> f64 {
    let u_l1: f64 = u.iter().map(|t| t.abs()).sum();
    let v_inf = v
        .map(|vf| vf.iter().fold(0.0f64, |a, t| a.max(t.abs())))
        .unwrap_or(0.0);
    let delta = entry_bound(kern, x, c);
    let km = kmax(kern, x, c);
    (rows as f64) * delta * (2.0 * km * u_l1 + v_inf)
}

/// Multi-RHS [`matvec_bound`]: the worst column's ‖u_col‖₁ against the
/// global max |V| (v is the row-major `rows × K` offset block).
pub fn matmat_bound(
    kern: Kernel,
    x: &MatF32,
    c: &MatF32,
    rows: usize,
    u: &Mat,
    v: Option<&[f64]>,
) -> f64 {
    let mut u_l1 = 0.0f64;
    for kc in 0..u.cols {
        let col: f64 = (0..u.rows).map(|j| u[(j, kc)].abs()).sum();
        u_l1 = u_l1.max(col);
    }
    let v_inf = v
        .map(|vf| vf.iter().fold(0.0f64, |a, t| a.max(t.abs())))
        .unwrap_or(0.0);
    let delta = entry_bound(kern, x, c);
    let km = kmax(kern, x, c);
    (rows as f64) * delta * (2.0 * km * u_l1 + v_inf)
}

/// Bound on `|δf|∞` for predictions f = Kr·α.
pub fn predict_bound(kern: Kernel, x: &MatF32, c: &MatF32, alpha: &[f64]) -> f64 {
    let a_l1: f64 = alpha.iter().map(|t| t.abs()).sum();
    entry_bound(kern, x, c) * a_l1
}

// --------------------------------------------------------------------------
// SIMD-vs-scalar model (f64 tier)
// --------------------------------------------------------------------------
//
// The SIMD panel arms (kernels::simd) change *only* the association order
// of the f64 dot/L1 reductions — the staging expressions and the
// exponential pass are operation-for-operation identical to the scalar
// arm (the exp lanes are bitwise-pinned by the simd module's own tests).
// So the SIMD-vs-scalar entry difference is two independently-rounded
// f64 reductions of the same data feeding an exp whose *argument* moved:
//
// - an f64 dot of length d carries |fl(x·c) − x·c| ≤ γ_d·|x|·|c| with
//   γ_d ≈ d·eps64; two arms differ by ≤ 2·d·eps64·Rx·Rc.
// - the Gaussian norm expansion ‖x‖² + ‖c‖² − 2x·c adds a handful of
//   roundings at magnitude (Rx+Rc)², and the argument is scaled by
//   inv = 1/(2p²); exp(−a)·δa ≤ δa since a ≥ 0.
// - the Laplacian L1 sum of length d (2d − 1 adds plus d abs/subs, each
//   exact-or-one-rounding) differs across arms by
//   ≤ (2d+2)·eps64·Σ|x−c| ≤ (2d+2)·eps64·√d·(Rx+Rc), scaled by 1/p.
// - [`EXP64_RELERR`] is added as slack for the exponential kernels even
//   though the lanes are bitwise, so the bound stays valid if a future
//   arm relaxes the pin to "within the measured polynomial error".
//
// Each carries the same [`SAFETY`] factor and propagates through the
// fused sweeps exactly like the f32-tier bounds above.

/// `f64` machine epsilon (2⁻⁵² ≈ 2.22e-16).
pub const EPS64: f64 = f64::EPSILON;

/// Relative error bound of [`crate::linalg::vec_ops::fast_exp`] against
/// libm on the non-saturated domain (measured max ≈ 4e-14 in the
/// `fast_exp_matches_libm` property test; documented with headroom).
/// SIMD lanes are bitwise equal to the scalar polynomial, so this enters
/// the SIMD-vs-scalar bounds only as slack — see the module docs.
pub const EXP64_RELERR: f64 = 1.0e-13;

/// Largest row L2 norm of an f64 block.
fn max_row_norm_f64(x: &Mat) -> f64 {
    row_sq_norms(x).into_iter().fold(0.0f64, f64::max).sqrt()
}

/// Bound on |K(x,c)| over f64 data: 1 for the exponential kernels,
/// Cauchy–Schwarz Rx·Rc for linear.
fn kmax_f64(kern: Kernel, x: &Mat, c: &Mat) -> f64 {
    match kern {
        Kernel::Gaussian | Kernel::Laplacian => 1.0,
        Kernel::Linear => max_row_norm_f64(x) * max_row_norm_f64(c),
    }
}

/// Per-entry bound |K_simd(x,c) − K_scalar(x,c)| for the f64 panel arms
/// — reassociation of the f64 reductions only; see the section comment
/// for the derivation.
pub fn simd_entry_bound(kern: Kernel, x: &Mat, c: &Mat, param: f64) -> f64 {
    let d = x.cols as f64;
    let rx = max_row_norm_f64(x);
    let rc = max_row_norm_f64(c);
    match kern {
        Kernel::Gaussian => {
            let inv = 1.0 / (2.0 * param * param);
            let cancel = 4.0 * d * rx * rc + 2.0 * (rx + rc) * (rx + rc);
            SAFETY * (inv * EPS64 * cancel + EXP64_RELERR)
        }
        Kernel::Laplacian => {
            let l1 = (2.0 * d + 2.0) * d.sqrt() * (rx + rc);
            SAFETY * ((1.0 / param) * EPS64 * l1 + EXP64_RELERR)
        }
        Kernel::Linear => SAFETY * (2.0 * d + 2.0) * EPS64 * rx * rc,
    }
}

/// SIMD-vs-scalar `|δw|∞` bound for the fused f64 matvec
/// w = Krᵀ(Kr·u + v) over all of `x`'s rows — the entry bound propagated
/// exactly like [`matvec_bound`].
pub fn simd_matvec_bound(
    kern: Kernel,
    x: &Mat,
    c: &Mat,
    param: f64,
    u: &[f64],
    v: Option<&[f64]>,
) -> f64 {
    let u_l1: f64 = u.iter().map(|t| t.abs()).sum();
    let v_inf = v
        .map(|vf| vf.iter().fold(0.0f64, |a, t| a.max(t.abs())))
        .unwrap_or(0.0);
    let delta = simd_entry_bound(kern, x, c, param);
    let km = kmax_f64(kern, x, c);
    (x.rows as f64) * delta * (2.0 * km * u_l1 + v_inf)
}

/// Multi-RHS [`simd_matvec_bound`]: worst column ‖u_col‖₁ against the
/// global max |V|.
pub fn simd_matmat_bound(
    kern: Kernel,
    x: &Mat,
    c: &Mat,
    param: f64,
    u: &Mat,
    v: Option<&[f64]>,
) -> f64 {
    let mut u_l1 = 0.0f64;
    for kc in 0..u.cols {
        let col: f64 = (0..u.rows).map(|j| u[(j, kc)].abs()).sum();
        u_l1 = u_l1.max(col);
    }
    let v_inf = v
        .map(|vf| vf.iter().fold(0.0f64, |a, t| a.max(t.abs())))
        .unwrap_or(0.0);
    let delta = simd_entry_bound(kern, x, c, param);
    let km = kmax_f64(kern, x, c);
    (x.rows as f64) * delta * (2.0 * km * u_l1 + v_inf)
}

/// SIMD-vs-scalar `|δf|∞` bound for predictions f = Kr·α (per output:
/// passing a flattened multi-output α is a conservative upper bound for
/// every column).
pub fn simd_predict_bound(kern: Kernel, x: &Mat, c: &Mat, param: f64, alpha: &[f64]) -> f64 {
    let a_l1: f64 = alpha.iter().map(|t| t.abs()).sum();
    simd_entry_bound(kern, x, c, param) * a_l1
}

/// SIMD-vs-scalar per-entry bound for the **f32** panel arms. Both arms
/// accumulate in f64 and round the staged argument (or linear dot) to
/// `f32` once, so the eps64-scale reassociation drift can flip at most
/// the last bit of each of the two f32 roundings: the exponential
/// kernels stay at the data-independent `EPS32 + EXP32_RELERR` scale and
/// the linear kernel at `Rx·Rc·EPS32` (one full ulp32 to cover both
/// arms' independent roundings).
pub fn simd_entry_bound_f32(kern: Kernel, x: &MatF32, c: &MatF32) -> f64 {
    match kern {
        Kernel::Gaussian | Kernel::Laplacian => SAFETY * (EPS32 + EXP32_RELERR),
        Kernel::Linear => SAFETY * max_row_norm(x) * max_row_norm(c) * EPS32,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exponential_entry_bound_is_data_independent() {
        let small = MatF32::from_f64s(1, 1, &[0.1]);
        let big = MatF32::from_f64s(2, 1, &[100.0, -250.0]);
        for kern in [Kernel::Gaussian, Kernel::Laplacian] {
            assert_eq!(
                entry_bound(kern, &small, &small),
                entry_bound(kern, &big, &big),
                "{kern:?}"
            );
            assert!(entry_bound(kern, &small, &small) < 2e-6);
            assert_eq!(kmax(kern, &big, &big), 1.0);
        }
        // linear scales with the data
        assert!(
            entry_bound(Kernel::Linear, &big, &big) > entry_bound(Kernel::Linear, &small, &small)
        );
        let rmax = (100.0f64 * 100.0 + 0.0).sqrt().max(250.0);
        assert!((kmax(Kernel::Linear, &big, &big) - rmax * rmax).abs() < 1e-9);
    }

    #[test]
    fn propagation_bounds_scale_with_the_sweep() {
        let x = MatF32::from_f64s(2, 2, &[0.5, -1.0, 2.0, 0.25]);
        let c = MatF32::from_f64s(1, 2, &[1.0, 1.0]);
        let u = [2.0, -3.0];
        let b1 = matvec_bound(Kernel::Gaussian, &x, &c, 10, &u, None);
        let b2 = matvec_bound(Kernel::Gaussian, &x, &c, 20, &u, None);
        assert!((b2 - 2.0 * b1).abs() < 1e-18);
        // a v offset only adds error
        assert!(matvec_bound(Kernel::Gaussian, &x, &c, 10, &u, Some(&[5.0, -1.0])) > b1);
        // predict bound is row-count free and ‖α‖₁-linear
        let p1 = predict_bound(Kernel::Gaussian, &x, &c, &[1.0]);
        let p2 = predict_bound(Kernel::Gaussian, &x, &c, &[1.0, -1.0]);
        assert!((p2 - 2.0 * p1).abs() < 1e-18);
    }

    #[test]
    fn simd_bounds_are_positive_and_track_their_knobs() {
        let x = Mat::from_vec(3, 2, vec![0.5, -1.0, 2.0, 0.25, -0.75, 1.5]);
        let c = Mat::from_vec(2, 2, vec![1.0, 1.0, -0.5, 2.0]);
        for kern in [Kernel::Gaussian, Kernel::Laplacian, Kernel::Linear] {
            let b = simd_entry_bound(kern, &x, &c, 1.3);
            assert!(b > 0.0 && b < 1e-9, "{kern:?}: {b:e}");
        }
        // exponential bounds never fall below the EXP64_RELERR floor
        assert!(simd_entry_bound(Kernel::Gaussian, &x, &c, 1.3) >= SAFETY * EXP64_RELERR);
        // Gaussian bound tightens as the bandwidth grows (inv = 1/(2p²))
        assert!(
            simd_entry_bound(Kernel::Gaussian, &x, &c, 4.0)
                < simd_entry_bound(Kernel::Gaussian, &x, &c, 0.5)
        );
        // propagation scales with the sweep exactly like the f32 tier
        let u = [2.0, -3.0];
        let x2 = {
            let mut dat = x.data.clone();
            dat.extend_from_slice(&x.data);
            Mat::from_vec(6, 2, dat)
        };
        let b1 = simd_matvec_bound(Kernel::Laplacian, &x, &c, 1.3, &u, None);
        let b2 = simd_matvec_bound(Kernel::Laplacian, &x2, &c, 1.3, &u, None);
        assert!((b2 - 2.0 * b1).abs() < 1e-24);
        // predict is row-count free and ‖α‖₁-linear
        let p1 = simd_predict_bound(Kernel::Gaussian, &x, &c, 1.3, &[1.0]);
        let p2 = simd_predict_bound(Kernel::Gaussian, &x, &c, 1.3, &[1.0, -1.0]);
        assert!((p2 - 2.0 * p1).abs() < 1e-18);
        // the f32 arm bound dominates eps32-scale flips
        let x32 = MatF32::from_f64s(3, 2, &x.data);
        let c32 = MatF32::from_f64s(2, 2, &c.data);
        for kern in [Kernel::Gaussian, Kernel::Laplacian, Kernel::Linear] {
            assert!(simd_entry_bound_f32(kern, &x32, &c32) >= EPS32, "{kern:?}");
        }
    }
}

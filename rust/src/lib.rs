//! # FALKON — An Optimal Large Scale Kernel Method
//!
//! Production reproduction of Rudi, Carratino & Rosasco (NIPS 2017) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! - **L1/L2 (build time)**: Pallas kernels and the FALKON compute graph
//!   live in `python/compile/`, AOT-lowered to HLO text artifacts.
//! - **L3 (this crate)**: the coordinator — data pipeline, Nyström center
//!   selection, preconditioned conjugate gradient over blocked XLA
//!   matvecs, baselines, benchmarks and the CLI launcher. Python never
//!   runs at request time.
//!
//! Start with [`falkon::FalkonEstimator`] or `examples/quickstart.rs`.
pub mod data;
pub mod kernels;
pub mod linalg;
pub mod metrics;
pub mod util;
pub mod runtime;
pub mod falkon;
pub mod baselines;
pub mod bench;
pub mod cli;
pub mod config;
pub mod serve;

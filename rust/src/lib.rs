//! # FALKON — An Optimal Large Scale Kernel Method
//!
//! Production reproduction of Rudi, Carratino & Rosasco (NIPS 2017) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! - **L1/L2 (build time)**: Pallas kernels and the FALKON compute graph
//!   live in `python/compile/`, AOT-lowered to HLO text artifacts.
//! - **L3 (this crate)**: the coordinator — data pipeline, Nyström center
//!   selection, preconditioned conjugate gradient over blocked XLA
//!   matvecs, baselines, benchmarks and the CLI launcher. Python never
//!   runs at request time.
//!
//! # Layout
//!
//! | module | role |
//! |---|---|
//! | [`data`] | datasets, loaders, and the chunked out-of-core [`data::DataSource`] pipeline |
//! | [`kernels`] | tiled/fused Gaussian, Laplacian and linear kernel sweeps |
//! | [`falkon`] | the algorithm: centers, preconditioner, (block) CG, fit/predict |
//! | [`runtime`] | the [`runtime::Engine`]/[`runtime::MatvecPlan`] compute abstraction |
//! | [`serve`] | batched online serving + streamed offline bulk scoring |
//! | [`baselines`] | exact KRR and Nyström baselines for the paper's tables |
//! | [`linalg`], [`util`], [`bench`], [`cli`], [`config`], [`metrics`] | substrates |
//!
//! # Quickstart
//!
//! Fit and evaluate on an in-memory dataset ([`falkon::fit`]):
//!
//! ```
//! use falkon::data::synth;
//! use falkon::falkon::{fit, FalkonConfig};
//! use falkon::runtime::Engine;
//! use falkon::util::rng::Rng;
//!
//! let mut rng = Rng::new(0);
//! let data = synth::smooth_regression(&mut rng, 500, 4, 0.05);
//! let engine = Engine::rust(); // or Engine::xla(...) over the AOT artifacts
//! let config = FalkonConfig { sigma: 2.0, lam: 1e-4, m: 64, t: 10, ..Default::default() };
//! let model = fit(&engine, &data.x, &data.y, &config).unwrap();
//! let preds = model.predict(&engine, &data.x).unwrap();
//! assert_eq!(preds.len(), 500);
//! ```
//!
//! Datasets larger than RAM stream through [`falkon::fit_source`] /
//! [`serve::predict_source`] via a chunked [`data::DataSource`] (binary
//! shards, lazy libsvm/CSV) with O(chunk) resident features — see
//! `examples/outofcore_stream.rs` and DESIGN.md § "Out-of-core path".
//!
//! # Mixed precision (`--dtype f32`)
//!
//! Feature **storage** can be `f32` while every reduction accumulates in
//! `f64`: shards ([`data::shard`]), streamed chunks ([`data::Chunk`]
//! carries an [`linalg::mat32::XBlock`] of either dtype), and the rust
//! plan's resident row blocks ([`runtime::EngineOptions::dtype`]) — CG,
//! `Bhb` and the preconditioner stay f64. Precision is lost exactly once
//! at storage time; [`kernels::tol`] documents the per-kernel error
//! bounds the property tests assert. CLI: `convert --dtype f32` (half-
//! size shards), `train`/`predict --dtype f32` (half the resident
//! bytes). See DESIGN.md §Perf "Precision model".
//!
//! See also `examples/quickstart.rs` and the `falkon` CLI (`train`,
//! `predict`, `convert`, `serve`, `tune`, `lscores`, `info`).

// The `xla` feature gates the PJRT engine on the `xla` crate (xla-rs),
// which the offline build environment cannot fetch. This guard turns the
// otherwise-confusing "unresolved import `xla`" cascade into one clear
// instruction (tools that sweep `--all-features` hit it too).
#[cfg(feature = "xla")]
compile_error!(
    "the `xla` feature needs the `xla` crate: add it under [dependencies] \
     in rust/Cargo.toml (see the [features] comment there) and delete this \
     guard in src/lib.rs"
);

pub mod data;
pub mod kernels;
pub mod linalg;
pub mod metrics;
pub mod util;
pub mod runtime;
pub mod falkon;
pub mod baselines;
pub mod bench;
pub mod cli;
pub mod config;
pub mod serve;

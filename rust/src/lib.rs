//! # FALKON — An Optimal Large Scale Kernel Method
//!
//! Production reproduction of Rudi, Carratino & Rosasco (NIPS 2017) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! - **L1/L2 (build time)**: Pallas kernels and the FALKON compute graph
//!   live in `python/compile/`, AOT-lowered to HLO text artifacts.
//! - **L3 (this crate)**: the coordinator — data pipeline, Nyström center
//!   selection, preconditioned conjugate gradient over blocked XLA
//!   matvecs, baselines, benchmarks and the CLI launcher. Python never
//!   runs at request time.
//!
//! Start with [`falkon::FalkonEstimator`] or `examples/quickstart.rs`.

// The `xla` feature gates the PJRT engine on the `xla` crate (xla-rs),
// which the offline build environment cannot fetch. This guard turns the
// otherwise-confusing "unresolved import `xla`" cascade into one clear
// instruction (tools that sweep `--all-features` hit it too).
#[cfg(feature = "xla")]
compile_error!(
    "the `xla` feature needs the `xla` crate: add it under [dependencies] \
     in rust/Cargo.toml (see the [features] comment there) and delete this \
     guard in src/lib.rs"
);

pub mod data;
pub mod kernels;
pub mod linalg;
pub mod metrics;
pub mod util;
pub mod runtime;
pub mod falkon;
pub mod baselines;
pub mod bench;
pub mod cli;
pub mod config;
pub mod serve;

//! Cholesky factorization (upper-triangular convention, matching MATLAB's
//! `chol` and therefore Alg. 1/2 of the paper line-for-line).
//!
//! The runtime normally gets its factors from the `precond` XLA artifact;
//! this implementation backs (a) the pure-Rust fallback backend, (b) the
//! exact-KRR / Nyström-direct baselines, and (c) cross-checks in tests.

use super::mat::Mat;

#[derive(Debug)]
pub enum CholError {
    NotSquare,
    /// leading minor index that failed positivity
    NotPositiveDefinite(usize),
}

impl std::fmt::Display for CholError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CholError::NotSquare => write!(f, "cholesky: matrix not square"),
            CholError::NotPositiveDefinite(i) => {
                write!(f, "cholesky: not positive definite at pivot {i}")
            }
        }
    }
}

impl std::error::Error for CholError {}

/// Upper-triangular R with RᵀR = A. A must be symmetric positive definite.
pub fn cholesky_upper(a: &Mat) -> Result<Mat, CholError> {
    if a.rows != a.cols {
        return Err(CholError::NotSquare);
    }
    let n = a.rows;
    let mut r = Mat::zeros(n, n);
    for i in 0..n {
        // diagonal pivot
        let mut s = a[(i, i)];
        for k in 0..i {
            s -= r[(k, i)] * r[(k, i)];
        }
        if s <= 0.0 || !s.is_finite() {
            return Err(CholError::NotPositiveDefinite(i));
        }
        let rii = s.sqrt();
        r[(i, i)] = rii;
        // row i of R (columns j > i)
        for j in (i + 1)..n {
            let mut s = a[(i, j)];
            for k in 0..i {
                s -= r[(k, i)] * r[(k, j)];
            }
            r[(i, j)] = s / rii;
        }
    }
    Ok(r)
}

/// Solve A x = b for symmetric positive definite A via Cholesky.
pub fn solve_spd(a: &Mat, b: &[f64]) -> Result<Vec<f64>, CholError> {
    let r = cholesky_upper(a)?;
    // A = RᵀR  =>  solve Rᵀ y = b (forward), then R x = y (backward)
    let y = super::tri::solve_lower_t(&r, b);
    Ok(super::tri::solve_upper(&r, &y))
}

/// Solve A X = B column-wise for SPD A.
pub fn solve_spd_mat(a: &Mat, b: &Mat) -> Result<Mat, CholError> {
    let r = cholesky_upper(a)?;
    let mut out = Mat::zeros(b.rows, b.cols);
    let mut col = vec![0.0; b.rows];
    for j in 0..b.cols {
        for i in 0..b.rows {
            col[i] = b[(i, j)];
        }
        let y = super::tri::solve_lower_t(&r, &col);
        let x = super::tri::solve_upper(&r, &y);
        for i in 0..b.rows {
            out[(i, j)] = x[i];
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{gram_t, matmul, matvec};
    use crate::util::ptest::check;

    fn random_spd(g: &mut crate::util::ptest::Gen, n: usize) -> Mat {
        // AᵀA + n·I is SPD
        let a = Mat::from_vec(n, n, g.normal_vec(n * n));
        let mut s = gram_t(&a);
        s.add_diag(n as f64);
        s
    }

    #[test]
    fn factor_reconstructs() {
        check("RᵀR = A", 25, |g| {
            let n = g.usize_in(1, 12);
            let a = random_spd(g, n);
            let r = cholesky_upper(&a).unwrap();
            // upper triangular?
            for i in 0..n {
                for j in 0..i {
                    assert_eq!(r[(i, j)], 0.0);
                }
            }
            let back = matmul(&r.t(), &r);
            assert!(back.max_abs_diff(&a) < 1e-8 * (n as f64));
        });
    }

    #[test]
    fn known_factor() {
        let a = Mat::from_rows(&[vec![4.0, 2.0], vec![2.0, 3.0]]);
        let r = cholesky_upper(&a).unwrap();
        assert!((r[(0, 0)] - 2.0).abs() < 1e-12);
        assert!((r[(0, 1)] - 1.0).abs() < 1e-12);
        assert!((r[(1, 1)] - (2.0f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]); // eigenvalues 3, -1
        assert!(matches!(
            cholesky_upper(&a),
            Err(CholError::NotPositiveDefinite(1))
        ));
    }

    #[test]
    fn rejects_non_square() {
        assert!(matches!(
            cholesky_upper(&Mat::zeros(2, 3)),
            Err(CholError::NotSquare)
        ));
    }

    #[test]
    fn solve_spd_matches_direct() {
        check("A·solve(A,b) = b", 25, |g| {
            let n = g.usize_in(1, 10);
            let a = random_spd(g, n);
            let b = g.normal_vec(n);
            let x = solve_spd(&a, &b).unwrap();
            let back = matvec(&a, &x);
            for i in 0..n {
                assert!((back[i] - b[i]).abs() < 1e-7, "{} vs {}", back[i], b[i]);
            }
        });
    }

    #[test]
    fn solve_spd_mat_matches_vector_solves() {
        check("matrix rhs solve", 10, |g| {
            let n = g.usize_in(1, 8);
            let a = random_spd(g, n);
            let b = Mat::from_vec(n, 3, g.normal_vec(n * 3));
            let x = solve_spd_mat(&a, &b).unwrap();
            let back = matmul(&a, &x);
            assert!(back.max_abs_diff(&b) < 1e-7);
        });
    }
}

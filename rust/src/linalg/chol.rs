//! Cholesky factorization (upper-triangular convention, matching MATLAB's
//! `chol` and therefore Alg. 1/2 of the paper line-for-line).
//!
//! Two tiers (DESIGN.md §Perf, "Setup path"):
//!
//! - [`cholesky_upper_ref`] — the seed's scalar loop, O(M³) with a
//!   column-strided inner product. Kept as the property-test oracle.
//! - [`cholesky_upper`] / [`cholesky_upper_blocked`] — right-looking
//!   blocked factorization: scalar factor of an `nb × nb` diagonal block,
//!   row-wise TRSM of the panel to its right, then a SYRK rank-`nb`
//!   update of the trailing matrix whose inner loop is a contiguous
//!   `axpy` and whose rows fan out over the shared [`WorkerPool`]. This
//!   is the per-fit O(M³) cost of the preconditioner at M = √n, so it
//!   gets the same tile/fuse/pool treatment as the matvec hot path.
//!
//! Pooled and serial runs are bitwise identical: every trailing row is
//! updated by exactly one task with the same fixed panel-row order.

use super::mat::Mat;
use super::vec_ops;
use crate::util::pool::{chunk_ranges_weighted, fan_out, WorkerPool};

/// Default diagonal-block size: the `nb × trailing` TRSM panel that the
/// SYRK stage re-reads stays L2-resident up to M = 4096 (64·4096 f64 =
/// 2 MiB).
pub const CHOL_BLOCK: usize = 64;

#[derive(Debug)]
pub enum CholError {
    NotSquare,
    /// leading minor index that failed positivity
    NotPositiveDefinite(usize),
}

impl std::fmt::Display for CholError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CholError::NotSquare => write!(f, "cholesky: matrix not square"),
            CholError::NotPositiveDefinite(i) => {
                write!(f, "cholesky: not positive definite at pivot {i}")
            }
        }
    }
}

impl std::error::Error for CholError {}

/// Upper-triangular R with RᵀR = A (blocked, serial).
pub fn cholesky_upper(a: &Mat) -> Result<Mat, CholError> {
    cholesky_upper_blocked(a, CHOL_BLOCK, None)
}

/// Right-looking blocked Cholesky with explicit block size and optional
/// worker pool for the trailing SYRK updates. The block size is exposed
/// so property tests exercise ragged edges (M not a multiple of `nb`,
/// M < `nb`, M = 1) that [`CHOL_BLOCK`] never hits at test scale.
pub fn cholesky_upper_blocked(
    a: &Mat,
    nb: usize,
    pool: Option<&WorkerPool>,
) -> Result<Mat, CholError> {
    if a.rows != a.cols {
        return Err(CholError::NotSquare);
    }
    let n = a.rows;
    let nb = nb.max(1);
    let mut r = Mat::zeros(n, n);
    for i in 0..n {
        r.row_mut(i)[i..].copy_from_slice(&a.row(i)[i..]);
    }
    let data = &mut r.data;
    let mut k0 = 0;
    while k0 < n {
        let k1 = (k0 + nb).min(n);

        // 1) scalar factor of the diagonal block: contributions from
        // earlier panels were already subtracted by their SYRK updates,
        // so only rows t in [k0, i) remain.
        for i in k0..k1 {
            let (head, tail) = data.split_at_mut(i * n);
            let ri = &mut tail[..n];
            let mut s = ri[i];
            for t in k0..i {
                let v = head[t * n + i];
                s -= v * v;
            }
            if s <= 0.0 || !s.is_finite() {
                return Err(CholError::NotPositiveDefinite(i));
            }
            let rii = s.sqrt();
            ri[i] = rii;
            for j in (i + 1)..k1 {
                let mut s = ri[j];
                for t in k0..i {
                    s -= head[t * n + i] * head[t * n + j];
                }
                ri[j] = s / rii;
            }
        }

        if k1 == n {
            break;
        }

        // 2) panel TRSM: R[k0..k1, k1..n] = R_diag⁻ᵀ · A'[k0..k1, k1..n],
        // row by row with a contiguous axpy inner loop.
        for i in k0..k1 {
            let (head, tail) = data.split_at_mut(i * n);
            let ri = &mut tail[..n];
            for t in k0..i {
                let c = head[t * n + i];
                vec_ops::axpy(-c, &head[t * n + k1..t * n + n], &mut ri[k1..]);
            }
            let inv = 1.0 / ri[i];
            for v in &mut ri[k1..] {
                *v *= inv;
            }
        }

        // 3) SYRK trailing update, rows fanned out over the pool:
        // R[i, i..n] -= Σ_t R[t, i] · R[t, i..n] for i in [k1, n).
        let (head, trail) = data.split_at_mut(k1 * n);
        let panel = &head[k0 * n..]; // rows k0..k1, stride n
        let nrows = n - k1;
        let workers = pool.map(|p| p.workers()).unwrap_or(1);
        // trailing row i costs ~(n - i): weight the chunks so workers
        // get equal flops, not equal row counts
        let ranges = chunk_ranges_weighted(nrows, workers, |li| (n - (k1 + li)) as u64);
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(ranges.len());
        let mut rest = trail;
        for &(lo, hi) in &ranges {
            let (chunk, tail_rest) = rest.split_at_mut((hi - lo) * n);
            rest = tail_rest;
            tasks.push(Box::new(move || {
                for li in 0..(hi - lo) {
                    let i = k1 + lo + li; // absolute row index
                    let row = &mut chunk[li * n + i..li * n + n];
                    for t in 0..(k1 - k0) {
                        let c = panel[t * n + i];
                        vec_ops::axpy(-c, &panel[t * n + i..t * n + n], row);
                    }
                }
            }));
        }
        fan_out(pool, tasks);

        k0 = k1;
    }
    Ok(r)
}

/// Reference scalar factorization — the seed's loop, kept as the oracle
/// the blocked path is property-tested against (pivot index included).
pub fn cholesky_upper_ref(a: &Mat) -> Result<Mat, CholError> {
    if a.rows != a.cols {
        return Err(CholError::NotSquare);
    }
    let n = a.rows;
    let mut r = Mat::zeros(n, n);
    for i in 0..n {
        // diagonal pivot
        let mut s = a[(i, i)];
        for k in 0..i {
            s -= r[(k, i)] * r[(k, i)];
        }
        if s <= 0.0 || !s.is_finite() {
            return Err(CholError::NotPositiveDefinite(i));
        }
        let rii = s.sqrt();
        r[(i, i)] = rii;
        // row i of R (columns j > i)
        for j in (i + 1)..n {
            let mut s = a[(i, j)];
            for k in 0..i {
                s -= r[(k, i)] * r[(k, j)];
            }
            r[(i, j)] = s / rii;
        }
    }
    Ok(r)
}

/// Solve A x = b for symmetric positive definite A via Cholesky.
pub fn solve_spd(a: &Mat, b: &[f64]) -> Result<Vec<f64>, CholError> {
    let r = cholesky_upper(a)?;
    // A = RᵀR  =>  solve Rᵀ y = b (forward), then R x = y (backward)
    let y = super::tri::solve_lower_t(&r, b);
    Ok(super::tri::solve_upper(&r, &y))
}

/// Solve A X = B for SPD A: blocked factorization + blocked multi-RHS
/// triangular solves (the seed gathered/scattered one column at a time).
pub fn solve_spd_mat(a: &Mat, b: &Mat) -> Result<Mat, CholError> {
    let r = cholesky_upper(a)?;
    let y = super::tri::solve_lower_t_mat(&r, b);
    Ok(super::tri::solve_upper_mat(&r, &y))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{gram_t, matmul, matvec};
    use crate::util::pool::WorkerPool;
    use crate::util::ptest::check;

    fn random_spd(g: &mut crate::util::ptest::Gen, n: usize) -> Mat {
        // AᵀA + n·I is SPD
        let a = Mat::from_vec(n, n, g.normal_vec(n * n));
        let mut s = gram_t(&a);
        s.add_diag(n as f64);
        s
    }

    #[test]
    fn factor_reconstructs() {
        check("RᵀR = A", 25, |g| {
            let n = g.usize_in(1, 12);
            let a = random_spd(g, n);
            let r = cholesky_upper(&a).unwrap();
            // upper triangular?
            for i in 0..n {
                for j in 0..i {
                    assert_eq!(r[(i, j)], 0.0);
                }
            }
            let back = matmul(&r.t(), &r);
            assert!(back.max_abs_diff(&a) < 1e-8 * (n as f64));
        });
    }

    #[test]
    fn blocked_matches_reference_ragged_sizes() {
        // block sizes around/below/above n exercise ragged final panels,
        // n < nb, and n = 1
        check("blocked chol = reference chol", 25, |g| {
            let n = g.usize_in(1, 24);
            let a = random_spd(g, n);
            let want = cholesky_upper_ref(&a).unwrap();
            for nb in [1usize, 2, 3, 5, 7, 64] {
                let got = cholesky_upper_blocked(&a, nb, None).unwrap();
                assert!(
                    got.max_abs_diff(&want) < 1e-10,
                    "n={n} nb={nb} diff={}",
                    got.max_abs_diff(&want)
                );
            }
        });
    }

    #[test]
    fn blocked_crosses_default_block() {
        // one deterministic case bigger than CHOL_BLOCK so the shipped
        // constant itself is exercised
        let mut rng = crate::util::rng::Rng::new(41);
        let n = CHOL_BLOCK + 37;
        let a = {
            let m = Mat::from_vec(n, n, rng.normals(n * n));
            let mut s = gram_t(&m);
            s.add_diag(n as f64);
            s
        };
        let want = cholesky_upper_ref(&a).unwrap();
        let got = cholesky_upper(&a).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn pooled_is_bitwise_equal_to_serial() {
        let mut rng = crate::util::rng::Rng::new(42);
        let n = 150;
        let a = {
            let m = Mat::from_vec(n, n, rng.normals(n * n));
            let mut s = gram_t(&m);
            s.add_diag(n as f64);
            s
        };
        let serial = cholesky_upper_blocked(&a, 32, None).unwrap();
        let pool = WorkerPool::new("test-chol", 4).unwrap();
        let pooled = cholesky_upper_blocked(&a, 32, Some(&pool)).unwrap();
        assert_eq!(
            serial.data, pooled.data,
            "pool-parallel trailing updates must be bitwise deterministic"
        );
    }

    #[test]
    fn blocked_agrees_on_pivot_index() {
        check("blocked chol pivot = reference pivot", 20, |g| {
            let n = g.usize_in(2, 18);
            let mut a = random_spd(g, n);
            // poison one pivot hard enough that rounding cannot flip it
            let p = g.usize_in(0, n);
            a[(p, p)] = -(10.0 * n as f64);
            let want = cholesky_upper_ref(&a);
            for nb in [1usize, 3, 4, 64] {
                let got = cholesky_upper_blocked(&a, nb, None);
                match (got, &want) {
                    (
                        Err(CholError::NotPositiveDefinite(i)),
                        Err(CholError::NotPositiveDefinite(j)),
                    ) => {
                        assert_eq!(i, *j, "n={n} nb={nb}");
                    }
                    other => panic!("expected matching pivot failures, got {other:?}"),
                }
            }
        });
    }

    #[test]
    fn known_factor() {
        let a = Mat::from_rows(&[vec![4.0, 2.0], vec![2.0, 3.0]]);
        let r = cholesky_upper(&a).unwrap();
        assert!((r[(0, 0)] - 2.0).abs() < 1e-12);
        assert!((r[(0, 1)] - 1.0).abs() < 1e-12);
        assert!((r[(1, 1)] - (2.0f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]); // eigenvalues 3, -1
        assert!(matches!(
            cholesky_upper(&a),
            Err(CholError::NotPositiveDefinite(1))
        ));
        assert!(matches!(
            cholesky_upper_ref(&a),
            Err(CholError::NotPositiveDefinite(1))
        ));
    }

    #[test]
    fn rejects_non_square() {
        assert!(matches!(
            cholesky_upper(&Mat::zeros(2, 3)),
            Err(CholError::NotSquare)
        ));
        assert!(matches!(
            cholesky_upper_ref(&Mat::zeros(2, 3)),
            Err(CholError::NotSquare)
        ));
    }

    #[test]
    fn solve_spd_matches_direct() {
        check("A·solve(A,b) = b", 25, |g| {
            let n = g.usize_in(1, 10);
            let a = random_spd(g, n);
            let b = g.normal_vec(n);
            let x = solve_spd(&a, &b).unwrap();
            let back = matvec(&a, &x);
            for i in 0..n {
                assert!((back[i] - b[i]).abs() < 1e-7, "{} vs {}", back[i], b[i]);
            }
        });
    }

    #[test]
    fn solve_spd_mat_matches_vector_solves() {
        check("matrix rhs solve", 10, |g| {
            let n = g.usize_in(1, 8);
            let a = random_spd(g, n);
            let b = Mat::from_vec(n, 3, g.normal_vec(n * 3));
            let x = solve_spd_mat(&a, &b).unwrap();
            let back = matmul(&a, &x);
            assert!(back.max_abs_diff(&b) < 1e-7);
        });
    }
}

//! Symmetric eigendecomposition (cyclic Jacobi) — the substrate behind the
//! paper's Example 2 preconditioner (the eigendecomposition route for
//! rank-deficient K_MM) and the exact condition-number diagnostics in the
//! ablation benches.
//!
//! Jacobi is O(M³) per sweep with excellent accuracy for symmetric
//! matrices; it runs on M×M coordinator-side state only.

use super::mat::Mat;

/// Eigen-decomposition A = V diag(w) Vᵀ of a symmetric matrix.
/// Eigenvalues are returned in *descending* order, V's columns matching.
pub struct SymEig {
    pub values: Vec<f64>,
    /// column j of `vectors` is the eigenvector for `values[j]`
    pub vectors: Mat,
}

/// Cyclic Jacobi with threshold sweeps. `a` must be symmetric.
pub fn sym_eig(a: &Mat) -> SymEig {
    assert_eq!(a.rows, a.cols, "sym_eig: not square");
    let n = a.rows;
    let mut m = a.clone();
    let mut v = Mat::eye(n);

    let off = |m: &Mat| -> f64 {
        let mut s = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                s += m[(i, j)] * m[(i, j)];
            }
        }
        s
    };

    let scale: f64 = (0..n).map(|i| a[(i, i)].abs()).fold(1e-300, f64::max);
    let tol = (1e-14 * scale) * (1e-14 * scale) * (n * n) as f64;
    for _sweep in 0..60 {
        if off(&m) <= tol {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() <= 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p, q of m
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                // accumulate eigenvectors
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| m[(j, j)].partial_cmp(&m[(i, i)]).unwrap());
    let values: Vec<f64> = order.iter().map(|&i| m[(i, i)]).collect();
    let mut vectors = Mat::zeros(n, n);
    for (newj, &oldj) in order.iter().enumerate() {
        for i in 0..n {
            vectors[(i, newj)] = v[(i, oldj)];
        }
    }
    SymEig { values, vectors }
}

/// Exact condition number of a symmetric PSD matrix (diagnostics).
pub fn cond_sym(a: &Mat) -> f64 {
    let e = sym_eig(a);
    let max = e.values.first().copied().unwrap_or(0.0);
    let min = e.values.last().copied().unwrap_or(0.0).max(1e-300);
    max / min
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{gram_t, matmul};
    use crate::util::ptest::check;

    #[test]
    fn diagonal_matrix() {
        let mut a = Mat::zeros(3, 3);
        a[(0, 0)] = 1.0;
        a[(1, 1)] = 5.0;
        a[(2, 2)] = 3.0;
        let e = sym_eig(&a);
        assert!((e.values[0] - 5.0).abs() < 1e-12);
        assert!((e.values[1] - 3.0).abs() < 1e-12);
        assert!((e.values[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn reconstructs_and_orthogonal() {
        check("V diag(w) Vᵀ = A, VᵀV = I", 15, |g| {
            let n = g.usize_in(1, 10);
            let r = Mat::from_vec(n, n, g.normal_vec(n * n));
            let a = gram_t(&r); // symmetric PSD
            let e = sym_eig(&a);
            // orthogonality
            let vtv = matmul(&e.vectors.t(), &e.vectors);
            assert!(vtv.max_abs_diff(&Mat::eye(n)) < 1e-9);
            // reconstruction
            let mut vd = e.vectors.clone();
            for i in 0..n {
                for j in 0..n {
                    vd[(i, j)] *= e.values[j];
                }
            }
            let back = matmul(&vd, &e.vectors.t());
            assert!(back.max_abs_diff(&a) < 1e-8 * (1.0 + n as f64));
            // descending order
            for w in e.values.windows(2) {
                assert!(w[0] >= w[1] - 1e-12);
            }
        });
    }

    #[test]
    fn eigenvalues_match_trace_and_det2x2() {
        let a = Mat::from_rows(&[vec![2.0, 1.0], vec![1.0, 2.0]]);
        let e = sym_eig(&a);
        assert!((e.values[0] - 3.0).abs() < 1e-12);
        assert!((e.values[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cond_of_identity_is_one() {
        assert!((cond_sym(&Mat::eye(5)) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn handles_rank_deficient() {
        // rank-1 PSD matrix: eigenvalues [‖v‖², 0, 0]
        let v = [1.0, 2.0, 2.0];
        let mut a = Mat::zeros(3, 3);
        for i in 0..3 {
            for j in 0..3 {
                a[(i, j)] = v[i] * v[j];
            }
        }
        let e = sym_eig(&a);
        assert!((e.values[0] - 9.0).abs() < 1e-10);
        assert!(e.values[1].abs() < 1e-10);
        assert!(e.values[2].abs() < 1e-10);
    }
}

//! Matrix products for the coordinator-side paths: baselines (exact KRR,
//! Nyström direct), leverage-score sketches and the pure-Rust fallback
//! backend. The i-k-j loop order keeps the inner loop contiguous in both
//! operands, which the compiler vectorizes; that is enough to make the
//! *XLA* path the bottleneck-of-interest, which is the point.

use super::mat::Mat;

/// C = A · B
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul {}x{} · {}x{}", a.rows, a.cols, b.rows, b.cols);
    let mut c = Mat::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for (k, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let brow = b.row(k);
            for j in 0..brow.len() {
                crow[j] += aik * brow[j];
            }
        }
    }
    c
}

/// C = Aᵀ · A  (Gram matrix, exploits symmetry: only the upper triangle is
/// computed then mirrored).
pub fn gram_t(a: &Mat) -> Mat {
    let n = a.cols;
    let mut c = Mat::zeros(n, n);
    for r in 0..a.rows {
        let row = a.row(r);
        for i in 0..n {
            let ri = row[i];
            if ri == 0.0 {
                continue;
            }
            let crow = c.row_mut(i);
            for j in i..n {
                crow[j] += ri * row[j];
            }
        }
    }
    for i in 0..n {
        for j in (i + 1)..n {
            c[(j, i)] = c[(i, j)];
        }
    }
    c
}

/// y = A · x
pub fn matvec(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols, x.len());
    let mut y = vec![0.0; a.rows];
    for i in 0..a.rows {
        y[i] = super::vec_ops::dot(a.row(i), x);
    }
    y
}

/// y = Aᵀ · x
pub fn matvec_t(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows, x.len());
    let mut y = vec![0.0; a.cols];
    for i in 0..a.rows {
        let xi = x[i];
        if xi == 0.0 {
            continue;
        }
        let row = a.row(i);
        for j in 0..a.cols {
            y[j] += xi * row[j];
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest::check;

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = matmul(&a, &b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn identity_is_neutral() {
        check("A·I = A", 20, |g| {
            let (r, c) = (g.usize_in(1, 8), g.usize_in(1, 8));
            let a = Mat::from_vec(r, c, g.normal_vec(r * c));
            assert!(matmul(&a, &Mat::eye(c)).max_abs_diff(&a) < 1e-12);
        });
    }

    #[test]
    fn gram_matches_matmul() {
        check("AᵀA = matmul(Aᵀ, A)", 20, |g| {
            let (r, c) = (g.usize_in(1, 10), g.usize_in(1, 10));
            let a = Mat::from_vec(r, c, g.normal_vec(r * c));
            let g1 = gram_t(&a);
            let g2 = matmul(&a.t(), &a);
            assert!(g1.max_abs_diff(&g2) < 1e-10);
        });
    }

    #[test]
    fn matvec_agrees_with_matmul() {
        check("A·x as column matmul", 20, |g| {
            let (r, c) = (g.usize_in(1, 9), g.usize_in(1, 9));
            let a = Mat::from_vec(r, c, g.normal_vec(r * c));
            let x = g.normal_vec(c);
            let y = matvec(&a, &x);
            let xm = Mat::from_vec(c, 1, x.clone());
            let ym = matmul(&a, &xm);
            for i in 0..r {
                assert!((y[i] - ym[(i, 0)]).abs() < 1e-10);
            }
        });
    }

    #[test]
    fn matvec_t_is_transpose() {
        check("Aᵀx = t(A)·x", 20, |g| {
            let (r, c) = (g.usize_in(1, 9), g.usize_in(1, 9));
            let a = Mat::from_vec(r, c, g.normal_vec(r * c));
            let x = g.normal_vec(r);
            let y1 = matvec_t(&a, &x);
            let y2 = matvec(&a.t(), &x);
            for i in 0..c {
                assert!((y1[i] - y2[i]).abs() < 1e-10);
            }
        });
    }
}

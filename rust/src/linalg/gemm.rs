//! Matrix products for the coordinator-side paths: baselines (exact KRR,
//! Nyström direct), leverage-score sketches, the M×M preconditioner
//! algebra in `falkon/precond.rs`, and the pure-Rust fallback backend.
//!
//! `matmul`/`gram_t` are cache-blocked (k/j panels sized so the streamed
//! operand stays in L2 while the output panel is revisited) with branch-free
//! inner loops the compiler vectorizes. The original streaming
//! implementations are retained as `matmul_ref`/`gram_t_ref` — the oracles
//! the blocked paths are property-tested against (DESIGN.md §Perf).

use super::mat::Mat;
use super::vec_ops;
use crate::util::pool::{chunk_ranges_weighted, fan_out, WorkerPool};

/// k-panel height: a KC×cols slice of B is revisited across all rows of A.
const KC: usize = 128;
/// j-panel width: bounds the C/B row segment touched by one inner loop.
const JC: usize = 512;
/// i-panel height for `gram_t`: rows of C kept hot while A streams by.
const IC: usize = 128;
/// i-panel height for `syrk_t`: the hot row block of A revisited while
/// every row j ≥ i0 streams by once per panel (32 rows × 4096 cols = 1 MiB).
const SYRK_IC: usize = 32;

/// C = A · B (cache-blocked).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    matmul_blocked(a, b, KC, JC)
}

/// Blocked i-k-j product with explicit panel sizes — exposed to the
/// property tests so tiny matrices still exercise ragged panel edges.
pub(crate) fn matmul_blocked(a: &Mat, b: &Mat, kc: usize, jc: usize) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul {}x{} · {}x{}", a.rows, a.cols, b.rows, b.cols);
    let (kc, jc) = (kc.max(1), jc.max(1));
    let mut c = Mat::zeros(a.rows, b.cols);
    let ncols = b.cols;
    let mut kk = 0;
    while kk < a.cols {
        let kend = (kk + kc).min(a.cols);
        let mut jj = 0;
        while jj < ncols {
            let jend = (jj + jc).min(ncols);
            for i in 0..a.rows {
                let arow = a.row(i);
                let crow = &mut c.row_mut(i)[jj..jend];
                for k in kk..kend {
                    let aik = arow[k];
                    let brow = &b.row(k)[jj..jend];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += aik * bv;
                    }
                }
            }
            jj = jend;
        }
        kk = kend;
    }
    c
}

/// Reference C = A · B — the seed's streaming i-k-j loop, kept as the
/// oracle for the blocked path's property tests.
pub fn matmul_ref(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul {}x{} · {}x{}", a.rows, a.cols, b.rows, b.cols);
    let mut c = Mat::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for (k, &aik) in arow.iter().enumerate() {
            let brow = b.row(k);
            for j in 0..brow.len() {
                crow[j] += aik * brow[j];
            }
        }
    }
    c
}

/// C = Aᵀ · A  (Gram matrix; cache-blocked over output row panels,
/// exploits symmetry: only the upper triangle is computed then mirrored).
pub fn gram_t(a: &Mat) -> Mat {
    gram_t_blocked(a, IC)
}

pub(crate) fn gram_t_blocked(a: &Mat, ic: usize) -> Mat {
    let n = a.cols;
    let ic = ic.max(1);
    let mut c = Mat::zeros(n, n);
    let mut ii = 0;
    while ii < n {
        let iend = (ii + ic).min(n);
        for r in 0..a.rows {
            let row = a.row(r);
            for i in ii..iend {
                let ri = row[i];
                let crow = &mut c.row_mut(i)[i..];
                let rtail = &row[i..];
                for (cv, &rv) in crow.iter_mut().zip(rtail) {
                    *cv += ri * rv;
                }
            }
        }
        ii = iend;
    }
    c.mirror_upper();
    c
}

/// Reference Gram matrix (the seed's single-pass rank-1 loop).
pub fn gram_t_ref(a: &Mat) -> Mat {
    let n = a.cols;
    let mut c = Mat::zeros(n, n);
    for r in 0..a.rows {
        let row = a.row(r);
        for i in 0..n {
            let ri = row[i];
            let crow = c.row_mut(i);
            for j in i..n {
                crow[j] += ri * row[j];
            }
        }
    }
    for i in 0..n {
        for j in (i + 1)..n {
            c[(j, i)] = c[(i, j)];
        }
    }
    c
}

/// C = A · Aᵀ (symmetric; upper triangle computed then mirrored). The
/// preconditioner's T·Tᵀ/M product sits on this — exactly half the
/// multiply count of `matmul(&t, &t.t())`, with both operands read as
/// contiguous rows of A.
pub fn syrk_t(a: &Mat) -> Mat {
    syrk_t_par(a, None)
}

/// [`syrk_t`] with the output row panels fanned out over the shared
/// worker pool. Each row of C is written by exactly one task with a fixed
/// dot-product order, so pooled results are bitwise equal to serial.
pub fn syrk_t_par(a: &Mat, pool: Option<&WorkerPool>) -> Mat {
    let n = a.rows;
    let mut c = Mat::zeros(n, n);
    let workers = pool.map(|p| p.workers()).unwrap_or(1);
    // row panels: tasks own disjoint row ranges of C; within a task the
    // SYRK_IC×cols block of A stays hot while rows j ≥ i stream through.
    // Row i computes n - i dots, so chunks are weighted by triangle area.
    let ranges = chunk_ranges_weighted(n, workers, |i| (n - i) as u64);
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(ranges.len());
    let mut rest = c.data.as_mut_slice();
    for &(lo, hi) in &ranges {
        let (chunk, tail) = rest.split_at_mut((hi - lo) * n);
        rest = tail;
        tasks.push(Box::new(move || {
            let mut i0 = lo;
            while i0 < hi {
                let i1 = (i0 + SYRK_IC).min(hi);
                for j in i0..n {
                    let aj = a.row(j);
                    for i in i0..i1.min(j + 1) {
                        chunk[(i - lo) * n + j] = vec_ops::dot(a.row(i), aj);
                    }
                }
                i0 = i1;
            }
        }));
    }
    fan_out(pool, tasks);
    c.mirror_upper();
    c
}

/// y = A · x
pub fn matvec(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols, x.len());
    let mut y = vec![0.0; a.rows];
    for i in 0..a.rows {
        y[i] = super::vec_ops::dot(a.row(i), x);
    }
    y
}

/// y = Aᵀ · x (branch-free: the old `x_i == 0` skip stalled the dense case
/// that dominates here).
pub fn matvec_t(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows, x.len());
    let mut y = vec![0.0; a.cols];
    for i in 0..a.rows {
        let xi = x[i];
        let row = a.row(i);
        for j in 0..a.cols {
            y[j] += xi * row[j];
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest::check;

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = matmul(&a, &b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn identity_is_neutral() {
        check("A·I = A", 20, |g| {
            let (r, c) = (g.usize_in(1, 8), g.usize_in(1, 8));
            let a = Mat::from_vec(r, c, g.normal_vec(r * c));
            assert!(matmul(&a, &Mat::eye(c)).max_abs_diff(&a) < 1e-12);
        });
    }

    #[test]
    fn blocked_matmul_matches_reference_ragged_panels() {
        // tiny panel sizes force ragged k/j edges the default constants
        // never hit at test scale
        check("blocked matmul = reference", 25, |g| {
            let (r, k, c) = (g.usize_in(1, 12), g.usize_in(1, 12), g.usize_in(1, 12));
            let a = Mat::from_vec(r, k, g.normal_vec(r * k));
            let b = Mat::from_vec(k, c, g.normal_vec(k * c));
            let want = matmul_ref(&a, &b);
            for (kc, jc) in [(1, 1), (3, 2), (4, 5), (7, 3), (64, 64)] {
                let got = matmul_blocked(&a, &b, kc, jc);
                assert!(got.max_abs_diff(&want) < 1e-10, "kc={kc} jc={jc}");
            }
        });
    }

    #[test]
    fn blocked_matmul_crosses_default_panels() {
        // one deterministic case bigger than KC/JC so the shipped constants
        // themselves are exercised
        let mut rng = crate::util::rng::Rng::new(17);
        let (r, k, c) = (20, 150, 530);
        let a = Mat::from_vec(r, k, rng.normals(r * k));
        let b = Mat::from_vec(k, c, rng.normals(k * c));
        let want = matmul_ref(&a, &b);
        assert!(matmul(&a, &b).max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn gram_matches_matmul() {
        check("AᵀA = matmul(Aᵀ, A)", 20, |g| {
            let (r, c) = (g.usize_in(1, 10), g.usize_in(1, 10));
            let a = Mat::from_vec(r, c, g.normal_vec(r * c));
            let g1 = gram_t(&a);
            let g2 = matmul(&a.t(), &a);
            assert!(g1.max_abs_diff(&g2) < 1e-10);
        });
    }

    #[test]
    fn blocked_gram_matches_reference_ragged_panels() {
        check("blocked gram = reference", 25, |g| {
            let (r, c) = (g.usize_in(1, 14), g.usize_in(1, 14));
            let a = Mat::from_vec(r, c, g.normal_vec(r * c));
            let want = gram_t_ref(&a);
            for ic in [1, 2, 3, 5, 64] {
                assert!(gram_t_blocked(&a, ic).max_abs_diff(&want) < 1e-10, "ic={ic}");
            }
        });
    }

    #[test]
    fn gram_crosses_default_panel() {
        let mut rng = crate::util::rng::Rng::new(18);
        let (r, c) = (40, 150);
        let a = Mat::from_vec(r, c, rng.normals(r * c));
        assert!(gram_t(&a).max_abs_diff(&gram_t_ref(&a)) < 1e-9);
    }

    #[test]
    fn syrk_matches_matmul_transpose() {
        check("A·Aᵀ = matmul(A, Aᵀ)", 25, |g| {
            let (r, c) = (g.usize_in(1, 14), g.usize_in(1, 14));
            let a = Mat::from_vec(r, c, g.normal_vec(r * c));
            let want = matmul_ref(&a, &a.t());
            let got = syrk_t(&a);
            assert!(got.max_abs_diff(&want) < 1e-10);
            // exactly symmetric by construction
            for i in 0..r {
                for j in 0..r {
                    assert_eq!(got[(i, j)], got[(j, i)]);
                }
            }
        });
    }

    #[test]
    fn syrk_pooled_is_bitwise_equal_to_serial() {
        let mut rng = crate::util::rng::Rng::new(19);
        let n = 97; // not a multiple of SYRK_IC or the worker count
        let a = Mat::from_vec(n, 33, rng.normals(n * 33));
        let serial = syrk_t(&a);
        let pool = crate::util::pool::WorkerPool::new("test-syrk", 4).unwrap();
        let pooled = syrk_t_par(&a, Some(&pool));
        assert_eq!(serial.data, pooled.data);
    }

    #[test]
    fn syrk_crosses_default_panel() {
        let mut rng = crate::util::rng::Rng::new(20);
        let n = 2 * SYRK_IC + 11;
        let a = Mat::from_vec(n, 40, rng.normals(n * 40));
        let want = matmul(&a, &a.t());
        assert!(syrk_t(&a).max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn matvec_agrees_with_matmul() {
        check("A·x as column matmul", 20, |g| {
            let (r, c) = (g.usize_in(1, 9), g.usize_in(1, 9));
            let a = Mat::from_vec(r, c, g.normal_vec(r * c));
            let x = g.normal_vec(c);
            let y = matvec(&a, &x);
            let xm = Mat::from_vec(c, 1, x.clone());
            let ym = matmul(&a, &xm);
            for i in 0..r {
                assert!((y[i] - ym[(i, 0)]).abs() < 1e-10);
            }
        });
    }

    #[test]
    fn matvec_t_is_transpose() {
        check("Aᵀx = t(A)·x", 20, |g| {
            let (r, c) = (g.usize_in(1, 9), g.usize_in(1, 9));
            let a = Mat::from_vec(r, c, g.normal_vec(r * c));
            let x = g.normal_vec(r);
            let y1 = matvec_t(&a, &x);
            let y2 = matvec(&a.t(), &x);
            for i in 0..c {
                assert!((y1[i] - y2[i]).abs() < 1e-10);
            }
        });
    }
}

//! Dense row-major matrix over f64 — the coordinator-side linear-algebra
//! container (preconditioner factors, leverage-score sketches, baselines).
//!
//! Heavy compute (kernel evaluations, the CG matvec) runs in the XLA
//! artifacts; this type only carries M×M-scale state, so clarity wins over
//! micro-optimization. The hot pieces (GEMM in baselines) live in gemm.rs.

use std::fmt;
use std::ops::{Index, IndexMut};

#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    /// Build from a row-major f32 buffer (artifact outputs).
    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat {
            rows,
            cols,
            data: data.iter().map(|&x| x as f64).collect(),
        }
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    pub fn scale(&mut self, s: f64) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    pub fn add_diag(&mut self, s: f64) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            self[(i, i)] += s;
        }
    }

    pub fn add(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// Copy the strict upper triangle below the diagonal, making the
    /// matrix exactly symmetric — the finishing pass of the
    /// upper-triangle-only products (`gram_t`, `syrk_t`, `kmm`).
    pub fn mirror_upper(&mut self) {
        assert_eq!(self.rows, self.cols, "mirror_upper: matrix not square");
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                self[(j, i)] = self[(i, j)];
            }
        }
    }

    /// Copy of column j (row-major storage makes columns strided; the
    /// multi-RHS callers gather one when they need vector-shaped access).
    pub fn col(&self, j: usize) -> Vec<f64> {
        assert!(j < self.cols);
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Overwrite column j from a slice.
    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert!(j < self.cols);
        assert_eq!(v.len(), self.rows, "set_col length");
        for i in 0..self.rows {
            self[(i, j)] = v[i];
        }
    }

    /// A → D·A for diagonal D given as a vector — row i scaled by d[i]
    /// (the Def. 2/3 reweighting applied to a multi-RHS block, one
    /// contiguous row at a time).
    pub fn scale_rows(&mut self, d: &[f64]) {
        assert_eq!(d.len(), self.rows, "scale_rows: diagonal length");
        for i in 0..self.rows {
            let di = d[i];
            for v in self.row_mut(i) {
                *v *= di;
            }
        }
    }

    /// A → D·A·D for diagonal D given as a vector — the Def. 3
    /// leverage-score reweighting K_MM → D·K_MM·D, applied one
    /// contiguous row at a time.
    pub fn scale_sym_diag(&mut self, d: &[f64]) {
        assert_eq!(self.rows, self.cols, "scale_sym_diag: matrix not square");
        assert_eq!(d.len(), self.rows, "scale_sym_diag: diagonal length");
        for i in 0..self.rows {
            let di = d[i];
            for (v, &dj) in self.row_mut(i).iter_mut().zip(d) {
                *v *= di * dj;
            }
        }
    }

    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Select a subset of rows (center selection).
    pub fn select_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (k, &i) in idx.iter().enumerate() {
            out.row_mut(k).copy_from_slice(self.row(i));
        }
        out
    }

    /// Rows [start, end).
    pub fn slice_rows(&self, start: usize, end: usize) -> Mat {
        assert!(start <= end && end <= self.rows);
        Mat {
            rows: end - start,
            cols: self.cols,
            data: self.data[start * self.cols..end * self.cols].to_vec(),
        }
    }

    /// Pad with zero columns up to `new_cols` (feature padding for the
    /// artifact contract — exact for all supported kernels).
    pub fn pad_cols(&self, new_cols: usize) -> Mat {
        assert!(new_cols >= self.cols);
        let mut out = Mat::zeros(self.rows, new_cols);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
        }
        out
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_and_rows() {
        let m = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(m[(0, 1)], 2.0);
        assert_eq!(m.row(1), &[3.0, 4.0]);
    }

    #[test]
    fn transpose() {
        let m = Mat::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        let t = m.t();
        assert_eq!((t.rows, t.cols), (3, 2));
        assert_eq!(t[(2, 1)], 6.0);
    }

    #[test]
    fn select_and_slice() {
        let m = Mat::from_rows(&[vec![1.0], vec![2.0], vec![3.0], vec![4.0]]);
        assert_eq!(m.select_rows(&[3, 0]).data, vec![4.0, 1.0]);
        assert_eq!(m.slice_rows(1, 3).data, vec![2.0, 3.0]);
    }

    #[test]
    fn pad_cols_zero_extends() {
        let m = Mat::from_rows(&[vec![1.0, 2.0]]);
        let p = m.pad_cols(4);
        assert_eq!(p.data, vec![1.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    fn f32_roundtrip() {
        let m = Mat::from_rows(&[vec![1.5, -2.25]]);
        let m2 = Mat::from_f32(1, 2, &m.to_f32());
        assert_eq!(m, m2);
    }

    #[test]
    fn eye_and_diag() {
        let mut m = Mat::eye(3);
        m.add_diag(2.0);
        assert_eq!(m[(1, 1)], 3.0);
        assert_eq!(m[(0, 1)], 0.0);
    }

    #[test]
    fn scale_sym_diag_is_dad() {
        let mut m = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        m.scale_sym_diag(&[2.0, 10.0]);
        assert_eq!(m.data, vec![4.0, 40.0, 60.0, 400.0]);
    }

    #[test]
    fn scale_rows_is_da() {
        let mut m = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        m.scale_rows(&[2.0, 10.0]);
        assert_eq!(m.data, vec![2.0, 4.0, 30.0, 40.0]);
    }

    #[test]
    fn col_roundtrip() {
        let mut m = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        assert_eq!(m.col(1), vec![2.0, 4.0, 6.0]);
        m.set_col(0, &[7.0, 8.0, 9.0]);
        assert_eq!(m.col(0), vec![7.0, 8.0, 9.0]);
        assert_eq!(m.col(1), vec![2.0, 4.0, 6.0]);
    }
}

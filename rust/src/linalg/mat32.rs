//! Single-precision row-major storage for the mixed-precision path
//! (DESIGN.md §"Precision model"): `MatF32` holds feature panels and
//! center blocks in `f32` — half the resident bytes of [`Mat`] — while
//! every reduction that reads them (kernel dots, panel sums, CG
//! recurrences) widens to `f64` before accumulating. `Dtype` is the tag
//! threaded through `Chunk`/`DataSource`/`EngineOptions` that selects
//! between the two storage formats.

use super::mat::Mat;

/// Element storage format of a feature block. `F64` is the default and
/// the property-test oracle; `F32` halves resident bytes and roughly
/// doubles panel throughput on memory-bound sweeps, with the per-kernel
/// error bounds of [`crate::kernels::tol`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Dtype {
    #[default]
    F64,
    F32,
}

impl Dtype {
    /// Bytes per stored feature element (8 or 4).
    pub fn size_of(self) -> usize {
        match self {
            Dtype::F64 => std::mem::size_of::<f64>(),
            Dtype::F32 => std::mem::size_of::<f32>(),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Dtype::F64 => "f64",
            Dtype::F32 => "f32",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Dtype> {
        match s {
            "f64" | "double" => Ok(Dtype::F64),
            "f32" | "float" | "single" => Ok(Dtype::F32),
            other => anyhow::bail!("unknown dtype {other:?} (expected f64|f32)"),
        }
    }
}

/// Dense row-major `f32` matrix — the storage-only sibling of [`Mat`].
/// It deliberately has no arithmetic of its own: consumers read rows and
/// widen to `f64` (see `kernels::kernel_panel_f32`), so precision is lost
/// exactly once, at storage time.
#[derive(Clone, PartialEq)]
pub struct MatF32 {
    pub rows: usize,
    pub cols: usize,
    /// row-major contiguous storage, `data[i*cols + j]`
    pub data: Vec<f32>,
}

impl MatF32 {
    pub fn zeros(rows: usize, cols: usize) -> MatF32 {
        MatF32 {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> MatF32 {
        assert_eq!(data.len(), rows * cols, "data length != rows*cols");
        MatF32 { rows, cols, data }
    }

    /// Round an `f64` matrix to `f32` storage (the one lossy step of the
    /// mixed-precision path).
    pub fn from_mat(m: &Mat) -> MatF32 {
        MatF32 {
            rows: m.rows,
            cols: m.cols,
            data: m.data.iter().map(|&v| v as f32).collect(),
        }
    }

    /// Round an `f64` buffer to `f32` storage.
    pub fn from_f64s(rows: usize, cols: usize, data: &[f64]) -> MatF32 {
        assert_eq!(data.len(), rows * cols, "data length != rows*cols");
        MatF32 {
            rows,
            cols,
            data: data.iter().map(|&v| v as f32).collect(),
        }
    }

    /// Widen back to `f64` (exact — every `f32` is representable).
    pub fn to_mat(&self) -> Mat {
        Mat::from_f32(self.rows, self.cols, &self.data)
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy of rows [a, b).
    pub fn slice_rows(&self, a: usize, b: usize) -> MatF32 {
        assert!(a <= b && b <= self.rows);
        MatF32 {
            rows: b - a,
            cols: self.cols,
            data: self.data[a * self.cols..b * self.cols].to_vec(),
        }
    }

    /// Gather a row subset (order given by `idx`).
    pub fn select_rows(&self, idx: &[usize]) -> MatF32 {
        let mut out = MatF32::zeros(idx.len(), self.cols);
        for (k, &i) in idx.iter().enumerate() {
            out.row_mut(k).copy_from_slice(self.row(i));
        }
        out
    }
}

impl std::fmt::Debug for MatF32 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "MatF32({}x{})", self.rows, self.cols)
    }
}

/// A feature row block in either storage format — the payload of
/// [`crate::data::source::Chunk`] and of the in-memory matvec plan's row
/// panels. Consumers on hot paths match on the variant and call the
/// dtype-specific kernels; everything else reads rows through the
/// widening accessors below.
#[derive(Debug, Clone)]
pub enum XBlock {
    F64(Mat),
    F32(MatF32),
}

impl XBlock {
    pub fn rows(&self) -> usize {
        match self {
            XBlock::F64(m) => m.rows,
            XBlock::F32(m) => m.rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            XBlock::F64(m) => m.cols,
            XBlock::F32(m) => m.cols,
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            XBlock::F64(_) => Dtype::F64,
            XBlock::F32(_) => Dtype::F32,
        }
    }

    /// Resident feature bytes — dtype-aware, so the out-of-core memory
    /// accounting reports what is actually held (4 bytes/element for f32).
    pub fn bytes(&self) -> usize {
        match self {
            XBlock::F64(m) => m.data.len() * std::mem::size_of::<f64>(),
            XBlock::F32(m) => m.data.len() * std::mem::size_of::<f32>(),
        }
    }

    /// Build a block from an `f64` matrix in the requested storage format
    /// (rounding once if `F32`).
    pub fn from_mat_dtype(m: Mat, dtype: Dtype) -> XBlock {
        match dtype {
            Dtype::F64 => XBlock::F64(m),
            Dtype::F32 => XBlock::F32(MatF32::from_mat(&m)),
        }
    }

    /// Convert to the requested storage format (identity when it already
    /// matches; widening f32→f64 is exact, narrowing rounds once).
    pub fn into_dtype(self, dtype: Dtype) -> XBlock {
        match (self, dtype) {
            (XBlock::F64(m), Dtype::F32) => XBlock::F32(MatF32::from_mat(&m)),
            (XBlock::F32(m), Dtype::F64) => XBlock::F64(m.to_mat()),
            (other, _) => other,
        }
    }

    /// Borrow as `f64` storage, if that is the variant (the hot f64 paths
    /// use this to avoid any copy).
    pub fn as_mat(&self) -> Option<&Mat> {
        match self {
            XBlock::F64(m) => Some(m),
            XBlock::F32(_) => None,
        }
    }

    /// Widen to an owned `f64` matrix (clone for f64, exact widening for
    /// f32) — the cold-path escape hatch.
    pub fn to_mat(&self) -> Mat {
        match self {
            XBlock::F64(m) => m.clone(),
            XBlock::F32(m) => m.to_mat(),
        }
    }

    pub fn element(&self, i: usize, j: usize) -> f64 {
        match self {
            XBlock::F64(m) => m[(i, j)],
            XBlock::F32(m) => m.row(i)[j] as f64,
        }
    }

    /// Copy row `i` into an `f64` buffer (widening if needed).
    pub fn row_f64_into(&self, i: usize, out: &mut [f64]) {
        match self {
            XBlock::F64(m) => out.copy_from_slice(m.row(i)),
            XBlock::F32(m) => {
                for (o, v) in out.iter_mut().zip(m.row(i)) {
                    *o = *v as f64;
                }
            }
        }
    }

    /// Append row-major `f64` values of all rows to `out` (widening).
    pub fn extend_f64(&self, out: &mut Vec<f64>) {
        match self {
            XBlock::F64(m) => out.extend_from_slice(&m.data),
            XBlock::F32(m) => out.extend(m.data.iter().map(|&v| v as f64)),
        }
    }

    pub fn row_is_finite(&self, i: usize) -> bool {
        match self {
            XBlock::F64(m) => m.row(i).iter().all(|v| v.is_finite()),
            XBlock::F32(m) => m.row(i).iter().all(|v| v.is_finite()),
        }
    }

    /// Overwrite every element of row `i` (fault-injection poison path).
    pub fn fill_row(&mut self, i: usize, v: f64) {
        match self {
            XBlock::F64(m) => m.row_mut(i).fill(v),
            XBlock::F32(m) => m.row_mut(i).fill(v as f32),
        }
    }

    /// Copy of rows [a, b), preserving the storage format.
    pub fn slice_rows(&self, a: usize, b: usize) -> XBlock {
        match self {
            XBlock::F64(m) => XBlock::F64(m.slice_rows(a, b)),
            XBlock::F32(m) => XBlock::F32(m.slice_rows(a, b)),
        }
    }

    /// Gather a row subset, preserving the storage format.
    pub fn select_rows(&self, idx: &[usize]) -> XBlock {
        match self {
            XBlock::F64(m) => XBlock::F64(m.select_rows(idx)),
            XBlock::F32(m) => XBlock::F32(m.select_rows(idx)),
        }
    }
}

impl From<Mat> for XBlock {
    fn from(m: Mat) -> XBlock {
        XBlock::F64(m)
    }
}

impl From<MatF32> for XBlock {
    fn from(m: MatF32) -> XBlock {
        XBlock::F32(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_parse_and_sizes() {
        assert_eq!(Dtype::parse("f64").unwrap(), Dtype::F64);
        assert_eq!(Dtype::parse("f32").unwrap(), Dtype::F32);
        assert_eq!(Dtype::parse("float").unwrap(), Dtype::F32);
        assert!(Dtype::parse("f16").is_err());
        assert_eq!(Dtype::F64.size_of(), 8);
        assert_eq!(Dtype::F32.size_of(), 4);
        assert_eq!(Dtype::default(), Dtype::F64);
        assert_eq!(Dtype::F32.name(), "f32");
    }

    #[test]
    fn roundtrip_is_exact_for_f32_values() {
        // f64 -> f32 -> f64 is the identity when the values are already
        // representable in f32 (the invariant the shard roundtrip relies on)
        let m = Mat::from_rows(&[vec![1.5, -2.25], vec![0.125, 3.0]]);
        let m32 = MatF32::from_mat(&m);
        assert_eq!(m32.to_mat().data, m.data);
        assert_eq!(m32.row(1), &[0.125f32, 3.0]);
    }

    #[test]
    fn rounding_is_nearest() {
        let v = 0.1f64; // not representable in f32
        let m32 = MatF32::from_f64s(1, 1, &[v]);
        let back = m32.to_mat().data[0];
        assert!(back != v);
        assert!((back - v).abs() <= v * f32::EPSILON as f64);
    }

    #[test]
    fn slice_and_select() {
        let m = MatF32::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let s = m.slice_rows(1, 3);
        assert_eq!(s.data, vec![3., 4., 5., 6.]);
        let g = m.select_rows(&[2, 0]);
        assert_eq!(g.data, vec![5., 6., 1., 2.]);
    }

    #[test]
    fn xblock_bytes_are_dtype_aware() {
        let m = Mat::zeros(10, 4);
        let b64: XBlock = m.clone().into();
        let b32 = XBlock::from_mat_dtype(m, Dtype::F32);
        assert_eq!(b64.bytes(), 10 * 4 * 8);
        assert_eq!(b32.bytes(), 10 * 4 * 4);
        assert_eq!(b32.bytes() * 2, b64.bytes(), "f32 halves resident bytes");
        assert_eq!(b64.dtype(), Dtype::F64);
        assert_eq!(b32.dtype(), Dtype::F32);
        assert_eq!(b32.rows(), 10);
        assert_eq!(b32.cols(), 4);
    }

    #[test]
    fn xblock_accessors_widen_consistently() {
        let m = Mat::from_rows(&[vec![1.5, -2.0], vec![0.25, 8.0]]);
        let b = XBlock::from_mat_dtype(m.clone(), Dtype::F32);
        assert_eq!(b.element(1, 0), 0.25);
        let mut row = vec![0.0; 2];
        b.row_f64_into(0, &mut row);
        assert_eq!(row, vec![1.5, -2.0]);
        let mut all = Vec::new();
        b.extend_f64(&mut all);
        assert_eq!(all, m.data, "f32-exact values widen losslessly");
        assert_eq!(b.to_mat().data, m.data);
        assert!(b.as_mat().is_none());
        assert!(XBlock::F64(m.clone()).as_mat().is_some());
        // round-trip through into_dtype
        let back = b.clone().into_dtype(Dtype::F64);
        assert_eq!(back.dtype(), Dtype::F64);
        assert_eq!(back.to_mat().data, m.data);
    }

    #[test]
    fn xblock_poison_and_finite_checks() {
        let m = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let mut b = XBlock::from_mat_dtype(m, Dtype::F32);
        assert!(b.row_is_finite(0));
        b.fill_row(0, f64::NAN);
        assert!(!b.row_is_finite(0));
        assert!(b.row_is_finite(1));
        let kept = b.select_rows(&[1]);
        assert_eq!(kept.rows(), 1);
        assert!(kept.row_is_finite(0));
        assert_eq!(kept.dtype(), Dtype::F32);
        let sl = b.slice_rows(1, 2);
        assert_eq!(sl.element(0, 1), 4.0);
    }
}

//! Coordinator-side dense linear algebra: the preconditioner application
//! (triangular solves), Cholesky for baselines/fallback, GEMM/GEMV and
//! vector kernels. Heavy data-touching compute runs in the XLA artifacts.
pub mod chol;
pub mod eig;
pub mod gemm;
pub mod mat;
pub mod mat32;
pub mod tri;
pub mod vec_ops;

pub use mat::Mat;
pub use mat32::{Dtype, MatF32, XBlock};

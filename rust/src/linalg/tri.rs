//! Triangular solves against the *upper* Cholesky factors (T, A) from the
//! preconditioner. The CG loop applies B = n^{-1/2} T⁻¹A⁻¹ and its
//! transpose through these four solves — cost O(M²) each, negligible next
//! to the O(nM) matvec, which is why they live on the Rust side instead of
//! being an artifact.
//!
//! Conventions (R always upper-triangular):
//!   solve_upper(R, b)    solves R x = b      (back substitution,  MATLAB `R\b`)
//!   solve_lower_t(R, b)  solves Rᵀ x = b     (forward substitution, MATLAB `R'\b`)
//!
//! The `*_mat` variants solve all right-hand sides at once with a blocked
//! row-panel sweep whose inner loop is a contiguous axpy over a whole RHS
//! row — the multi-RHS TRSM behind `solve_spd_mat` (the seed gathered and
//! scattered one strided column per RHS). The column-gather versions are
//! kept as `*_mat_ref`, the property-test oracles (DESIGN.md §Perf).

use super::mat::Mat;
use super::vec_ops;

/// Row-panel height of the blocked multi-RHS solves: the active X panel
/// (`nb × ncols`) stays cache-hot while prior rows stream through it once
/// per panel instead of once per row.
pub const TRSM_BLOCK: usize = 64;

/// Solve R x = b with R upper-triangular (back substitution).
pub fn solve_upper(r: &Mat, b: &[f64]) -> Vec<f64> {
    let n = r.rows;
    assert_eq!(r.cols, n);
    assert_eq!(b.len(), n);
    let mut x = b.to_vec();
    for i in (0..n).rev() {
        let row = r.row(i);
        let mut s = x[i];
        for j in (i + 1)..n {
            s -= row[j] * x[j];
        }
        x[i] = s / row[i];
    }
    x
}

/// Solve Rᵀ x = b with R upper-triangular (forward substitution).
pub fn solve_lower_t(r: &Mat, b: &[f64]) -> Vec<f64> {
    let n = r.rows;
    assert_eq!(r.cols, n);
    assert_eq!(b.len(), n);
    let mut x = b.to_vec();
    for i in 0..n {
        // Rᵀ[(i, j)] = R[(j, i)] for j < i — walk column i of R above the diag
        let mut s = x[i];
        for j in 0..i {
            s -= r[(j, i)] * x[j];
        }
        x[i] = s / r[(i, i)];
    }
    x
}

/// In-place variants reusing a caller-provided buffer (the CG hot loop
/// avoids per-iteration allocation with these).
pub fn solve_upper_into(r: &Mat, b: &[f64], out: &mut [f64]) {
    out.copy_from_slice(b);
    let n = r.rows;
    for i in (0..n).rev() {
        let row = r.row(i);
        let mut s = out[i];
        for j in (i + 1)..n {
            s -= row[j] * out[j];
        }
        out[i] = s / row[i];
    }
}

pub fn solve_lower_t_into(r: &Mat, b: &[f64], out: &mut [f64]) {
    out.copy_from_slice(b);
    let n = r.rows;
    for i in 0..n {
        let mut s = out[i];
        for j in 0..i {
            s -= r[(j, i)] * out[j];
        }
        out[i] = s / r[(i, i)];
    }
}

// ---------------------------------------------------------------------
// blocked multi-RHS solves
// ---------------------------------------------------------------------

/// Solve Rᵀ X = B for all columns of B at once (forward substitution,
/// blocked row panels).
pub fn solve_lower_t_mat(r: &Mat, b: &Mat) -> Mat {
    solve_lower_t_mat_blocked(r, b, TRSM_BLOCK)
}

pub(crate) fn solve_lower_t_mat_blocked(r: &Mat, b: &Mat, nb: usize) -> Mat {
    let n = r.rows;
    assert_eq!(r.cols, n);
    assert_eq!(b.rows, n);
    let w = b.cols;
    let nb = nb.max(1);
    let mut x = b.clone();
    if w == 0 {
        return x;
    }
    let data = &mut x.data;
    let mut k0 = 0;
    while k0 < n {
        let k1 = (k0 + nb).min(n);
        // rank update from rows [0, k0): X[i] -= R[t, i] · X[t]. t-outer
        // so each prior row streams through the hot panel exactly once.
        let (head, tail) = data.split_at_mut(k0 * w);
        for t in 0..k0 {
            let xt = &head[t * w..(t + 1) * w];
            let rt = r.row(t);
            for i in k0..k1 {
                vec_ops::axpy(-rt[i], xt, &mut tail[(i - k0) * w..(i - k0 + 1) * w]);
            }
        }
        // solve within the panel
        for i in k0..k1 {
            let li = i - k0;
            let (ph, pt) = tail.split_at_mut(li * w);
            let xi = &mut pt[..w];
            for t in k0..i {
                vec_ops::axpy(-r[(t, i)], &ph[(t - k0) * w..(t - k0 + 1) * w], xi);
            }
            let inv = 1.0 / r[(i, i)];
            for v in xi.iter_mut() {
                *v *= inv;
            }
        }
        k0 = k1;
    }
    x
}

/// Solve R X = B for all columns of B at once (back substitution, blocked
/// row panels).
pub fn solve_upper_mat(r: &Mat, b: &Mat) -> Mat {
    solve_upper_mat_blocked(r, b, TRSM_BLOCK)
}

pub(crate) fn solve_upper_mat_blocked(r: &Mat, b: &Mat, nb: usize) -> Mat {
    let n = r.rows;
    assert_eq!(r.cols, n);
    assert_eq!(b.rows, n);
    let w = b.cols;
    let nb = nb.max(1);
    let mut x = b.clone();
    if w == 0 {
        return x;
    }
    let data = &mut x.data;
    let mut k1 = n;
    while k1 > 0 {
        let k0 = k1.saturating_sub(nb);
        // rank update from rows [k1, n): X[i] -= R[i, t] · X[t]
        {
            let (head, tail) = data.split_at_mut(k1 * w);
            for t in k1..n {
                let xt = &tail[(t - k1) * w..(t - k1 + 1) * w];
                for i in k0..k1 {
                    vec_ops::axpy(-r[(i, t)], xt, &mut head[i * w..(i + 1) * w]);
                }
            }
        }
        // solve within the panel, bottom row up
        for i in (k0..k1).rev() {
            let (head, tail) = data.split_at_mut((i + 1) * w);
            let xi = &mut head[i * w..];
            let ri = r.row(i);
            for t in (i + 1)..k1 {
                vec_ops::axpy(-ri[t], &tail[(t - i - 1) * w..(t - i) * w], xi);
            }
            let inv = 1.0 / ri[i];
            for v in xi.iter_mut() {
                *v *= inv;
            }
        }
        k1 = k0;
    }
    x
}

/// Reference multi-RHS forward solve — the seed's per-column gather from
/// `solve_spd_mat`, kept as the blocked path's oracle.
pub fn solve_lower_t_mat_ref(r: &Mat, b: &Mat) -> Mat {
    let mut out = Mat::zeros(b.rows, b.cols);
    let mut col = vec![0.0; b.rows];
    for j in 0..b.cols {
        for i in 0..b.rows {
            col[i] = b[(i, j)];
        }
        let y = solve_lower_t(r, &col);
        for i in 0..b.rows {
            out[(i, j)] = y[i];
        }
    }
    out
}

/// Reference multi-RHS back solve (per-column gather).
pub fn solve_upper_mat_ref(r: &Mat, b: &Mat) -> Mat {
    let mut out = Mat::zeros(b.rows, b.cols);
    let mut col = vec![0.0; b.rows];
    for j in 0..b.cols {
        for i in 0..b.rows {
            col[i] = b[(i, j)];
        }
        let y = solve_upper(r, &col);
        for i in 0..b.rows {
            out[(i, j)] = y[i];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::chol::cholesky_upper;
    use crate::linalg::gemm::{gram_t, matvec};
    use crate::util::ptest::check;

    #[test]
    fn upper_solve_roundtrip() {
        check("R·solve_upper(R,b) = b", 25, |g| {
            let n = g.usize_in(1, 12);
            let a = {
                let m = Mat::from_vec(n, n, g.normal_vec(n * n));
                let mut s = gram_t(&m);
                s.add_diag(n as f64);
                s
            };
            let r = cholesky_upper(&a).unwrap();
            let b = g.normal_vec(n);
            let x = solve_upper(&r, &b);
            let back = matvec(&r, &x);
            for i in 0..n {
                assert!((back[i] - b[i]).abs() < 1e-8);
            }
        });
    }

    #[test]
    fn lower_t_solve_roundtrip() {
        check("Rᵀ·solve_lower_t(R,b) = b", 25, |g| {
            let n = g.usize_in(1, 12);
            let a = {
                let m = Mat::from_vec(n, n, g.normal_vec(n * n));
                let mut s = gram_t(&m);
                s.add_diag(n as f64);
                s
            };
            let r = cholesky_upper(&a).unwrap();
            let b = g.normal_vec(n);
            let x = solve_lower_t(&r, &b);
            let back = matvec(&r.t(), &x);
            for i in 0..n {
                assert!((back[i] - b[i]).abs() < 1e-8);
            }
        });
    }

    #[test]
    fn into_variants_match() {
        check("in-place solves match allocating solves", 15, |g| {
            let n = g.usize_in(1, 10);
            let a = {
                let m = Mat::from_vec(n, n, g.normal_vec(n * n));
                let mut s = gram_t(&m);
                s.add_diag(n as f64);
                s
            };
            let r = cholesky_upper(&a).unwrap();
            let b = g.normal_vec(n);
            let mut buf = vec![0.0; n];
            solve_upper_into(&r, &b, &mut buf);
            assert_eq!(buf, solve_upper(&r, &b));
            solve_lower_t_into(&r, &b, &mut buf);
            assert_eq!(buf, solve_lower_t(&r, &b));
        });
    }

    #[test]
    fn blocked_mat_solves_match_reference_ragged_sizes() {
        // panel sizes around/below/above n exercise ragged edges, n < nb,
        // and n = 1; ncols = 0 and 1 hit the degenerate RHS shapes
        check("blocked mat TRSM = per-column reference", 25, |g| {
            let n = g.usize_in(1, 20);
            let w = g.usize_in(0, 6);
            let a = {
                let m = Mat::from_vec(n, n, g.normal_vec(n * n));
                let mut s = gram_t(&m);
                s.add_diag(n as f64);
                s
            };
            let r = cholesky_upper(&a).unwrap();
            let b = Mat::from_vec(n, w, g.normal_vec(n * w));
            let want_f = solve_lower_t_mat_ref(&r, &b);
            let want_b = solve_upper_mat_ref(&r, &b);
            for nb in [1usize, 2, 3, 5, 7, 64] {
                let got_f = solve_lower_t_mat_blocked(&r, &b, nb);
                let got_b = solve_upper_mat_blocked(&r, &b, nb);
                assert!(got_f.max_abs_diff(&want_f) < 1e-10, "fwd n={n} w={w} nb={nb}");
                assert!(got_b.max_abs_diff(&want_b) < 1e-10, "bwd n={n} w={w} nb={nb}");
            }
        });
    }

    #[test]
    fn blocked_mat_solves_cross_default_panel() {
        // deterministic case bigger than TRSM_BLOCK so the shipped
        // constant itself is exercised, round-tripped through R·X
        let mut rng = crate::util::rng::Rng::new(51);
        let n = TRSM_BLOCK + 29;
        let a = {
            let m = Mat::from_vec(n, n, rng.normals(n * n));
            let mut s = gram_t(&m);
            s.add_diag(n as f64);
            s
        };
        let r = cholesky_upper(&a).unwrap();
        let b = Mat::from_vec(n, 9, rng.normals(n * 9));
        let x = solve_upper_mat(&r, &b);
        let back = crate::linalg::gemm::matmul(&r, &x);
        assert!(back.max_abs_diff(&b) < 1e-8);
        let y = solve_lower_t_mat(&r, &b);
        let back_t = crate::linalg::gemm::matmul(&r.t(), &y);
        assert!(back_t.max_abs_diff(&b) < 1e-8);
    }

    #[test]
    fn known_2x2() {
        // R = [[2, 1], [0, 3]]; R x = [4, 6] -> x = [1.5, 2] ... check: 2x+y=4, 3y=6 => y=2, x=1
        let r = Mat::from_rows(&[vec![2.0, 1.0], vec![0.0, 3.0]]);
        assert_eq!(solve_upper(&r, &[4.0, 6.0]), vec![1.0, 2.0]);
        // Rᵀ x = [2, 7]: 2x=2 => x=1; x+3y=7 => y=2
        assert_eq!(solve_lower_t(&r, &[2.0, 7.0]), vec![1.0, 2.0]);
    }
}

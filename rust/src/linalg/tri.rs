//! Triangular solves against the *upper* Cholesky factors (T, A) from the
//! preconditioner. The CG loop applies B = n^{-1/2} T⁻¹A⁻¹ and its
//! transpose through these four solves — cost O(M²) each, negligible next
//! to the O(nM) matvec, which is why they live on the Rust side instead of
//! being an artifact.
//!
//! Conventions (R always upper-triangular):
//!   solve_upper(R, b)    solves R x = b      (back substitution,  MATLAB `R\b`)
//!   solve_lower_t(R, b)  solves Rᵀ x = b     (forward substitution, MATLAB `R'\b`)

use super::mat::Mat;

/// Solve R x = b with R upper-triangular (back substitution).
pub fn solve_upper(r: &Mat, b: &[f64]) -> Vec<f64> {
    let n = r.rows;
    assert_eq!(r.cols, n);
    assert_eq!(b.len(), n);
    let mut x = b.to_vec();
    for i in (0..n).rev() {
        let row = r.row(i);
        let mut s = x[i];
        for j in (i + 1)..n {
            s -= row[j] * x[j];
        }
        x[i] = s / row[i];
    }
    x
}

/// Solve Rᵀ x = b with R upper-triangular (forward substitution).
pub fn solve_lower_t(r: &Mat, b: &[f64]) -> Vec<f64> {
    let n = r.rows;
    assert_eq!(r.cols, n);
    assert_eq!(b.len(), n);
    let mut x = b.to_vec();
    for i in 0..n {
        // Rᵀ[(i, j)] = R[(j, i)] for j < i — walk column i of R above the diag
        let mut s = x[i];
        for j in 0..i {
            s -= r[(j, i)] * x[j];
        }
        x[i] = s / r[(i, i)];
    }
    x
}

/// In-place variants reusing a caller-provided buffer (the CG hot loop
/// avoids per-iteration allocation with these).
pub fn solve_upper_into(r: &Mat, b: &[f64], out: &mut [f64]) {
    out.copy_from_slice(b);
    let n = r.rows;
    for i in (0..n).rev() {
        let row = r.row(i);
        let mut s = out[i];
        for j in (i + 1)..n {
            s -= row[j] * out[j];
        }
        out[i] = s / row[i];
    }
}

pub fn solve_lower_t_into(r: &Mat, b: &[f64], out: &mut [f64]) {
    out.copy_from_slice(b);
    let n = r.rows;
    for i in 0..n {
        let mut s = out[i];
        for j in 0..i {
            s -= r[(j, i)] * out[j];
        }
        out[i] = s / r[(i, i)];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::chol::cholesky_upper;
    use crate::linalg::gemm::{gram_t, matvec};
    use crate::util::ptest::check;

    #[test]
    fn upper_solve_roundtrip() {
        check("R·solve_upper(R,b) = b", 25, |g| {
            let n = g.usize_in(1, 12);
            let a = {
                let m = Mat::from_vec(n, n, g.normal_vec(n * n));
                let mut s = gram_t(&m);
                s.add_diag(n as f64);
                s
            };
            let r = cholesky_upper(&a).unwrap();
            let b = g.normal_vec(n);
            let x = solve_upper(&r, &b);
            let back = matvec(&r, &x);
            for i in 0..n {
                assert!((back[i] - b[i]).abs() < 1e-8);
            }
        });
    }

    #[test]
    fn lower_t_solve_roundtrip() {
        check("Rᵀ·solve_lower_t(R,b) = b", 25, |g| {
            let n = g.usize_in(1, 12);
            let a = {
                let m = Mat::from_vec(n, n, g.normal_vec(n * n));
                let mut s = gram_t(&m);
                s.add_diag(n as f64);
                s
            };
            let r = cholesky_upper(&a).unwrap();
            let b = g.normal_vec(n);
            let x = solve_lower_t(&r, &b);
            let back = matvec(&r.t(), &x);
            for i in 0..n {
                assert!((back[i] - b[i]).abs() < 1e-8);
            }
        });
    }

    #[test]
    fn into_variants_match() {
        check("in-place solves match allocating solves", 15, |g| {
            let n = g.usize_in(1, 10);
            let a = {
                let m = Mat::from_vec(n, n, g.normal_vec(n * n));
                let mut s = gram_t(&m);
                s.add_diag(n as f64);
                s
            };
            let r = cholesky_upper(&a).unwrap();
            let b = g.normal_vec(n);
            let mut buf = vec![0.0; n];
            solve_upper_into(&r, &b, &mut buf);
            assert_eq!(buf, solve_upper(&r, &b));
            solve_lower_t_into(&r, &b, &mut buf);
            assert_eq!(buf, solve_lower_t(&r, &b));
        });
    }

    #[test]
    fn known_2x2() {
        // R = [[2, 1], [0, 3]]; R x = [4, 6] -> x = [1.5, 2] ... check: 2x+y=4, 3y=6 => y=2, x=1
        let r = Mat::from_rows(&[vec![2.0, 1.0], vec![0.0, 3.0]]);
        assert_eq!(solve_upper(&r, &[4.0, 6.0]), vec![1.0, 2.0]);
        // Rᵀ x = [2, 7]: 2x=2 => x=1; x+3y=7 => y=2
        assert_eq!(solve_lower_t(&r, &[2.0, 7.0]), vec![1.0, 2.0]);
    }
}

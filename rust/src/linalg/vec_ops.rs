//! Vector kernels for the CG loop, the tiled kernel panels and metrics:
//! dot, axpy, norms, and a branch-free `fast_exp`. `dot` is the inner loop
//! of every kernel panel, so it is written with four independent
//! accumulators (the compiler turns each into a SIMD lane group); the rest
//! run on M-length vectors inside the coordinator and stay simple.

/// Four-accumulator dot product. The independent partial sums break the
/// loop-carried dependence so LLVM vectorizes and pipelines it; summation
/// order differs from the naive loop by O(n·eps), which every caller's
/// tolerance already absorbs.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let quads = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for q in 0..quads {
        let k = 4 * q;
        s0 += a[k] * b[k];
        s1 += a[k + 1] * b[k + 1];
        s2 += a[k + 2] * b[k + 2];
        s3 += a[k + 3] * b[k + 3];
    }
    let mut s = (s0 + s2) + (s1 + s3);
    for k in 4 * quads..n {
        s += a[k] * b[k];
    }
    s
}

/// Widening dot product over `f32` storage: every product is formed and
/// accumulated in `f64` (four independent accumulators, like [`dot`]), so
/// the only error vs. the f64 oracle is the one-time rounding of the
/// inputs to f32 — the core contract of the mixed-precision path
/// (DESIGN.md §"Precision model").
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let quads = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0, 0.0, 0.0);
    for q in 0..quads {
        let k = 4 * q;
        s0 += a[k] as f64 * b[k] as f64;
        s1 += a[k + 1] as f64 * b[k + 1] as f64;
        s2 += a[k + 2] as f64 * b[k + 2] as f64;
        s3 += a[k + 3] as f64 * b[k + 3] as f64;
    }
    let mut s = (s0 + s2) + (s1 + s3);
    for k in 4 * quads..n {
        s += a[k] as f64 * b[k] as f64;
    }
    s
}

/// Mixed dot: `f32` panel row against an `f64` coordinator vector,
/// accumulated in `f64` (stage 1 of the f32 streamed matvec).
#[inline]
pub fn dot_mixed(a: &[f32], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let quads = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0, 0.0, 0.0);
    for q in 0..quads {
        let k = 4 * q;
        s0 += a[k] as f64 * b[k];
        s1 += a[k + 1] as f64 * b[k + 1];
        s2 += a[k + 2] as f64 * b[k + 2];
        s3 += a[k + 3] as f64 * b[k + 3];
    }
    let mut s = (s0 + s2) + (s1 + s3);
    for k in 4 * quads..n {
        s += a[k] as f64 * b[k];
    }
    s
}

/// y += alpha * x with an `f32` x panel widened per element — the f64
/// accumulator (y) never loses the low bits (stage 2 of the f32 matvec).
#[inline]
pub fn axpy_f32(alpha: f64, x: &[f32], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i] as f64;
    }
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// y = x + beta * y  (CG direction update)
#[inline]
pub fn xpby(x: &[f64], beta: f64, y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] = x[i] + beta * y[i];
    }
}

#[inline]
pub fn scale(alpha: f64, x: &mut [f64]) {
    for v in x {
        *v *= alpha;
    }
}

#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

// Range-reduction constants of `fast_exp` / `fast_exp_f32`, shared with
// the explicit SIMD lanes in `kernels::simd`: the scalar and vectorized
// arms must read the *same* constants (and apply them in the same
// operation order) so every non-NaN lane agrees bitwise across arms.
pub(crate) const FAST_EXP_LOG2E: f64 = std::f64::consts::LOG2_E;
// ln(2) split hi/lo so `x - k*ln2` keeps full precision
pub(crate) const FAST_EXP_LN2_HI: f64 = 6.931471803691238165e-1;
pub(crate) const FAST_EXP_LN2_LO: f64 = 1.908214929270587700e-10;
/// Degree-12 Taylor coefficients of exp, lowest order first — Horner
/// evaluation from the top (`p = c[i] + r·p`) reproduces the nested
/// expression in [`fast_exp`] operation for operation.
pub(crate) const FAST_EXP_COEFFS: [f64; 13] = [
    1.0,
    1.0,
    0.5,
    1.0 / 6.0,
    1.0 / 24.0,
    1.0 / 120.0,
    1.0 / 720.0,
    1.0 / 5040.0,
    1.0 / 40320.0,
    1.0 / 362880.0,
    1.0 / 3628800.0,
    1.0 / 39916800.0,
    1.0 / 479001600.0,
];
pub(crate) const FAST_EXP_F32_LOG2E: f32 = std::f32::consts::LOG2_E;
// ln(2) split hi/lo (cephes pair): hi is exact in f32, lo restores the
// remaining bits of x - k*ln2
pub(crate) const FAST_EXP_F32_LN2_HI: f32 = 0.693_359_375;
pub(crate) const FAST_EXP_F32_LN2_LO: f32 = -2.121_944_4e-4;
/// Degree-7 Taylor coefficients of the f32 twin, lowest order first.
pub(crate) const FAST_EXP_F32_COEFFS: [f32; 8] = [
    1.0,
    1.0,
    0.5,
    1.0 / 6.0,
    1.0 / 24.0,
    1.0 / 120.0,
    1.0 / 720.0,
    1.0 / 5040.0,
];

/// Branch-free exp for the tiled kernel panels (DESIGN.md §Perf).
///
/// libm's `exp` is an opaque call, so a panel of kernel values cannot be
/// SIMD-vectorized through it; this routine is straight-line arithmetic
/// (clamp, floor-based range reduction, degree-12 Horner, exponent-bit
/// scaling), which LLVM auto-vectorizes across a row of the Kr tile.
/// `kernels::simd` additionally carries hand-vectorized AVX2/NEON lanes
/// of the same sequence, pinned bitwise to this scalar arm.
///
/// Accuracy: |rel err| < ~5e-15 on [-708, 708] — far inside the 1e-10
/// agreement budget the property tests enforce against the libm-based
/// reference kernels. Both overflow tails are handled branch-free (two
/// selects on the way out, so the panel loop still vectorizes):
///
/// - x < -709: returns exact 0 (the true value is denormal, < 1e-307)
/// - x > 708: returns +inf (true overflow is at ~709.78; the sliver
///   (708, 709.78] saturates to +inf rather than silently returning a
///   wrong finite value — the crate's kernel arms only ever pass x ≤ 0,
///   so this tail is reachable only on pathological inputs)
/// - NaN passes through as NaN
#[inline]
pub fn fast_exp(x: f64) -> f64 {
    let clamped = x.clamp(-709.0, 708.0);
    // k = round(x / ln 2) via floor (floor lowers to a single SIMD op)
    let kf = (clamped * FAST_EXP_LOG2E + 0.5).floor();
    let r = (clamped - kf * FAST_EXP_LN2_HI) - kf * FAST_EXP_LN2_LO; // |r| <= ~0.3466
    // exp(r) by degree-12 Taylor/Horner: truncation < 2e-16 relative
    let p = 1.0
        + r * (1.0
            + r * (0.5
                + r * (1.0 / 6.0
                    + r * (1.0 / 24.0
                        + r * (1.0 / 120.0
                            + r * (1.0 / 720.0
                                + r * (1.0 / 5040.0
                                    + r * (1.0 / 40320.0
                                        + r * (1.0 / 362880.0
                                            + r * (1.0 / 3628800.0
                                                + r * (1.0 / 39916800.0
                                                    + r * (1.0 / 479001600.0))))))))))));
    // 2^k assembled directly in the exponent field (k in [-1022, 1022]);
    // NaN inputs reach here with kf = NaN, which casts to 0 -> scale = 1,
    // so out stays NaN and falls through both selects below
    let scale = f64::from_bits(((1023i64 + kf as i64) as u64) << 52);
    let out = p * scale;
    // true underflow: exp(x) < 2^-1022 for x < -708.39; report exact 0.
    // positive overflow: saturate to +inf instead of exp(708) ≈ 3e307
    // (both comparisons are false for NaN, preserving passthrough)
    if x < -709.0 {
        0.0
    } else if x > 708.0 {
        f64::INFINITY
    } else {
        out
    }
}

/// Negative-saturation threshold of [`fast_exp_f32`]: below this, exp(x)
/// is subnormal in f32 and the routine reports exact 0.0 (the f32 twin of
/// fast_exp's -709 cutoff).
pub const FAST_EXP_F32_NEG_CUTOFF: f32 = -87.3;
/// Positive clamp of [`fast_exp_f32`]; above it the result saturates to
/// +inf (true f32 overflow is at ~88.72).
pub const FAST_EXP_F32_POS_CUTOFF: f32 = 88.0;

/// Single-precision twin of [`fast_exp`] for the f32 kernel panels: same
/// branch-free shape (clamp, floor range reduction with a split ln2,
/// Horner, exponent-bit scaling) but in f32 arithmetic with a degree-7
/// polynomial — f32 only carries 24 bits, so the shorter Horner chain is
/// both sufficient (truncation < 6e-9 relative on |r| ≤ ln2/2) and
/// meaningfully cheaper than the f64 degree-12 chain.
///
/// Accuracy: |rel err| < ~3e-7 on the clamp range — inside the EPS32
/// tolerance model of `kernels::tol`. Tails mirror [`fast_exp`]:
///
/// - x < [`FAST_EXP_F32_NEG_CUTOFF`]: exact 0.0 (true value subnormal),
///   never subnormal garbage
/// - x > [`FAST_EXP_F32_POS_CUTOFF`]: +inf (kernel arms only pass x ≤ 0,
///   so this tail is reachable only on pathological inputs)
/// - NaN passes through as NaN
#[inline]
pub fn fast_exp_f32(x: f32) -> f32 {
    let clamped = x.clamp(FAST_EXP_F32_NEG_CUTOFF, FAST_EXP_F32_POS_CUTOFF);
    let kf = (clamped * FAST_EXP_F32_LOG2E + 0.5).floor();
    let r = (clamped - kf * FAST_EXP_F32_LN2_HI) - kf * FAST_EXP_F32_LN2_LO; // |r| <= ~0.3466
    // exp(r) by degree-7 Taylor/Horner
    let p = 1.0
        + r * (1.0
            + r * (0.5
                + r * (1.0 / 6.0
                    + r * (1.0 / 24.0
                        + r * (1.0 / 120.0 + r * (1.0 / 720.0 + r * (1.0 / 5040.0)))))));
    // 2^k via the exponent field; k in [-126, 127] by the clamp. NaN
    // reaches here as kf = NaN -> cast 0 -> scale = 1, p stays NaN.
    let scale = f32::from_bits(((127i32 + kf as i32) as u32) << 23);
    let out = p * scale;
    if x < FAST_EXP_F32_NEG_CUTOFF {
        0.0
    } else if x > FAST_EXP_F32_POS_CUTOFF {
        f32::INFINITY
    } else {
        out
    }
}

pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

/// Relative L2 difference ||a-b|| / max(||b||, eps).
pub fn rel_diff(a: &[f64], b: &[f64]) -> f64 {
    let num: f64 = a
        .iter()
        .zip(b)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .sqrt();
    num / norm2(b).max(1e-30)
}

pub fn mean(x: &[f64]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    x.iter().sum::<f64>() / x.len() as f64
}

pub fn variance(x: &[f64]) -> f64 {
    if x.len() < 2 {
        return 0.0;
    }
    let m = mean(x);
    x.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / x.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest::check;

    #[test]
    fn dot_axpy() {
        let a = [1.0, 2.0, 3.0];
        let mut b = [4.0, 5.0, 6.0];
        assert_eq!(dot(&a, &b), 32.0);
        axpy(2.0, &a, &mut b);
        assert_eq!(b, [6.0, 9.0, 12.0]);
    }

    #[test]
    fn dot_matches_naive_all_lengths() {
        check("unrolled dot = naive dot", 30, |g| {
            let n = g.usize_in(1, 40);
            let a = g.normal_vec(n);
            let b = g.normal_vec(n);
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-12, "n={n}");
        });
    }

    #[test]
    fn xpby_matches_formula() {
        let x = [1.0, 1.0];
        let mut y = [2.0, 4.0];
        xpby(&x, 0.5, &mut y);
        assert_eq!(y, [2.0, 3.0]);
    }

    #[test]
    fn norms_and_diffs() {
        assert_eq!(norm2(&[3.0, 4.0]), 5.0);
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 2.0]), 0.5);
        assert!(rel_diff(&[1.0, 0.0], &[1.0, 0.0]) < 1e-15);
    }

    #[test]
    fn fast_exp_matches_libm() {
        check("fast_exp ≈ exp", 60, |g| {
            let x = g.f64_in(-45.0, 4.0);
            let want = x.exp();
            let got = fast_exp(x);
            let rel = (got - want).abs() / want.max(1e-300);
            assert!(rel < 1e-13, "x={x}: {got} vs {want} (rel {rel})");
        });
    }

    #[test]
    fn fast_exp_edge_cases() {
        assert_eq!(fast_exp(0.0), 1.0);
        assert!((fast_exp(1.0) - std::f64::consts::E).abs() < 1e-14);
        // deep negative tail: exact or denormal-level agreement
        assert_eq!(fast_exp(-1000.0), 0.0);
        assert_eq!(fast_exp(-710.0), 0.0);
        let near = fast_exp(-700.0);
        let want = (-700.0f64).exp();
        assert!((near - want).abs() / want < 1e-12, "{near} vs {want}");
        // the kernel range [-40, 0] must be essentially exact
        for i in 0..400 {
            let x = -0.1 * i as f64;
            let (got, want) = (fast_exp(x), x.exp());
            assert!((got - want).abs() < 1e-13 * want.max(1e-30) + 1e-300, "x={x}");
        }
    }

    #[test]
    fn fast_exp_positive_overflow_saturates() {
        // x ≥ 710 overflows f64 — must report +inf, not a silently wrong
        // finite value (the pre-fix clamp returned exp(708) ≈ 3e307)
        assert_eq!(fast_exp(710.0), f64::INFINITY);
        assert_eq!(fast_exp(1000.0), f64::INFINITY);
        assert_eq!(fast_exp(f64::INFINITY), f64::INFINITY);
        assert_eq!(fast_exp(f64::MAX), f64::INFINITY);
        // the accurate range still ends cleanly at the clamp boundary
        let near = fast_exp(700.0);
        let want = (700.0f64).exp();
        assert!((near - want).abs() / want < 1e-12, "{near} vs {want}");
        assert!(fast_exp(708.0).is_finite());
        // negative tail unchanged
        assert_eq!(fast_exp(f64::NEG_INFINITY), 0.0);
    }

    #[test]
    fn fast_exp_nan_passthrough() {
        assert!(fast_exp(f64::NAN).is_nan());
        assert!(fast_exp(-f64::NAN).is_nan());
    }

    #[test]
    fn fast_exp_negative_saturation_is_exact_zero() {
        // every x below the -709 cutoff must report bit-exact +0.0 — no
        // subnormal garbage from the exponent-bit assembly wrapping around
        for i in 0..200 {
            let x = -709.001 - 2.3 * i as f64;
            let got = fast_exp(x);
            assert_eq!(got.to_bits(), 0.0f64.to_bits(), "x={x}: got {got:e}");
        }
        // and the live side near the boundary stays positive and normal
        // (below ≈ -708.4 the exponent-bit assembly pins scale to zero,
        // so probe at -708.0 where 2^kf is still representable)
        let near = fast_exp(-708.0);
        assert!(near > 0.0 && near.is_normal(), "{near:e}");
    }

    #[test]
    fn fast_exp_f32_negative_saturation_is_exact_zero() {
        // same contract as the f64 arm, at the f32 subnormal boundary
        for i in 0..200 {
            let x = FAST_EXP_F32_NEG_CUTOFF - 0.001 - 0.7 * i as f32;
            let got = fast_exp_f32(x);
            assert_eq!(got.to_bits(), 0.0f32.to_bits(), "x={x}: got {got:e}");
        }
        let near = fast_exp_f32(FAST_EXP_F32_NEG_CUTOFF + 0.1);
        assert!(near > 0.0 && near.is_normal(), "{near:e}");
        assert_eq!(fast_exp_f32(f32::NEG_INFINITY), 0.0);
    }

    #[test]
    fn fast_exp_f32_matches_libm() {
        check("fast_exp_f32 ≈ exp", 60, |g| {
            let x = g.f64_in(-80.0, 4.0) as f32;
            let want = (x as f64).exp();
            let got = fast_exp_f32(x) as f64;
            let rel = (got - want).abs() / want.max(1e-300);
            assert!(rel < 1e-6, "x={x}: {got} vs {want} (rel {rel})");
        });
        // kernel range dense sweep
        for i in 0..400 {
            let x = -0.1 * i as f32;
            let (got, want) = (fast_exp_f32(x) as f64, (x as f64).exp());
            assert!(
                (got - want).abs() < 1e-6 * want.max(1e-30) + 1e-45,
                "x={x}: {got} vs {want}"
            );
        }
        assert_eq!(fast_exp_f32(0.0), 1.0);
    }

    #[test]
    fn fast_exp_f32_tails() {
        assert_eq!(fast_exp_f32(89.0), f32::INFINITY);
        assert_eq!(fast_exp_f32(f32::INFINITY), f32::INFINITY);
        assert_eq!(fast_exp_f32(f32::MAX), f32::INFINITY);
        assert!(fast_exp_f32(f32::NAN).is_nan());
        assert!(fast_exp_f32(-f32::NAN).is_nan());
        let near = fast_exp_f32(87.0) as f64;
        let want = 87.0f64.exp();
        assert!((near - want).abs() / want < 1e-6, "{near} vs {want}");
    }

    #[test]
    fn coeff_array_horner_is_bitwise_the_nested_expression() {
        // the SIMD arms evaluate the polynomial from FAST_EXP_COEFFS with
        // `p = c[i] + r·p`; that must reproduce the nested scalar Horner
        // bit for bit, or the bitwise SIMD-vs-scalar exp pin is vacuous
        check("array Horner = nested Horner", 40, |g| {
            let x = g.f64_in(-700.0, 700.0);
            let clamped = x.clamp(-709.0, 708.0);
            let kf = (clamped * FAST_EXP_LOG2E + 0.5).floor();
            let r = (clamped - kf * FAST_EXP_LN2_HI) - kf * FAST_EXP_LN2_LO;
            let mut p = FAST_EXP_COEFFS[FAST_EXP_COEFFS.len() - 1];
            for i in (0..FAST_EXP_COEFFS.len() - 1).rev() {
                p = FAST_EXP_COEFFS[i] + r * p;
            }
            let scale = f64::from_bits(((1023i64 + kf as i64) as u64) << 52);
            assert_eq!((p * scale).to_bits(), fast_exp(x).to_bits(), "x={x}");

            let x32 = g.f64_in(-85.0, 85.0) as f32;
            let clamped = x32.clamp(FAST_EXP_F32_NEG_CUTOFF, FAST_EXP_F32_POS_CUTOFF);
            let kf = (clamped * FAST_EXP_F32_LOG2E + 0.5).floor();
            let r = (clamped - kf * FAST_EXP_F32_LN2_HI) - kf * FAST_EXP_F32_LN2_LO;
            let mut p = FAST_EXP_F32_COEFFS[FAST_EXP_F32_COEFFS.len() - 1];
            for i in (0..FAST_EXP_F32_COEFFS.len() - 1).rev() {
                p = FAST_EXP_F32_COEFFS[i] + r * p;
            }
            let scale = f32::from_bits(((127i32 + kf as i32) as u32) << 23);
            assert_eq!((p * scale).to_bits(), fast_exp_f32(x32).to_bits(), "x={x32}");
        });
    }

    #[test]
    fn f32_dots_and_axpy_accumulate_in_f64() {
        check("dot_f32/dot_mixed = f64 dot of widened inputs", 30, |g| {
            let n = g.usize_in(1, 64);
            let a64 = g.normal_vec(n);
            let b64 = g.normal_vec(n);
            let a32: Vec<f32> = a64.iter().map(|&v| v as f32).collect();
            let b32: Vec<f32> = b64.iter().map(|&v| v as f32).collect();
            // oracle: exact f64 dot of the *rounded* values — the widening
            // dot must introduce no accumulation error of its own
            let aw: Vec<f64> = a32.iter().map(|&v| v as f64).collect();
            let bw: Vec<f64> = b32.iter().map(|&v| v as f64).collect();
            let want = dot(&aw, &bw);
            assert!((dot_f32(&a32, &b32) - want).abs() < 1e-12, "n={n}");
            assert!((dot_mixed(&a32, &bw) - want).abs() < 1e-12, "n={n}");
            let mut y1 = vec![0.0; n];
            let mut y2 = vec![0.0; n];
            axpy_f32(1.5, &a32, &mut y1);
            axpy(1.5, &aw, &mut y2);
            assert_eq!(y1, y2, "axpy_f32 must equal axpy on widened x");
        });
    }

    #[test]
    fn stats() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((variance(&[1.0, 2.0, 3.0]) - 2.0 / 3.0).abs() < 1e-12);
    }
}

//! Vector kernels for the CG loop and metrics: dot, axpy, norms. These run
//! on M-length vectors inside the coordinator, so they are written as
//! straightforward loops the compiler auto-vectorizes.

#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// y = x + beta * y  (CG direction update)
#[inline]
pub fn xpby(x: &[f64], beta: f64, y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] = x[i] + beta * y[i];
    }
}

#[inline]
pub fn scale(alpha: f64, x: &mut [f64]) {
    for v in x {
        *v *= alpha;
    }
}

#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

/// Relative L2 difference ||a-b|| / max(||b||, eps).
pub fn rel_diff(a: &[f64], b: &[f64]) -> f64 {
    let num: f64 = a
        .iter()
        .zip(b)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .sqrt();
    num / norm2(b).max(1e-30)
}

pub fn mean(x: &[f64]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    x.iter().sum::<f64>() / x.len() as f64
}

pub fn variance(x: &[f64]) -> f64 {
    if x.len() < 2 {
        return 0.0;
    }
    let m = mean(x);
    x.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / x.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_axpy() {
        let a = [1.0, 2.0, 3.0];
        let mut b = [4.0, 5.0, 6.0];
        assert_eq!(dot(&a, &b), 32.0);
        axpy(2.0, &a, &mut b);
        assert_eq!(b, [6.0, 9.0, 12.0]);
    }

    #[test]
    fn xpby_matches_formula() {
        let x = [1.0, 1.0];
        let mut y = [2.0, 4.0];
        xpby(&x, 0.5, &mut y);
        assert_eq!(y, [2.0, 3.0]);
    }

    #[test]
    fn norms_and_diffs() {
        assert_eq!(norm2(&[3.0, 4.0]), 5.0);
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 2.0]), 0.5);
        assert!(rel_diff(&[1.0, 0.0], &[1.0, 0.0]) < 1e-15);
    }

    #[test]
    fn stats() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((variance(&[1.0, 2.0, 3.0]) - 2.0 / 3.0).abs() < 1e-12);
    }
}

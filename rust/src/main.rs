//! `falkon` — the launcher. Subcommands:
//!
//!   train     fit FALKON on a dataset (synthetic analogue or file);
//!             --stream trains out-of-core from a chunked source
//!   predict   evaluate a saved model on a dataset (.shard inputs stream)
//!   convert   convert a dataset to the chunked binary shard format
//!   serve     prediction server: TCP front door (--addr) or request storm
//!   lscores   estimate approximate leverage scores and print a summary
//!   info      show the artifact registry / engine status
//!
//! Benchmarks (Tables 1-3 + ablations) live under `cargo bench`.

use anyhow::{anyhow, bail, Result};
use falkon::cli::Command;
use falkon::config::ExperimentConfig;
use falkon::data::shard::ShardSource;
use falkon::data::stream_text::{CsvSource, LibsvmSource};
use falkon::data::{
    synth, CastSource, DataSource, Dataset, MemSource, NanPolicy, SanitizeSource, ZScore,
    ZScoreSource,
};
use falkon::falkon::{
    fit, fit_multiclass, fit_source, model_io, Centers, CheckpointSpec, FalkonConfig,
};
use falkon::kernels::Kernel;
use falkon::linalg::mat32::{Dtype, XBlock};
use falkon::metrics;
use falkon::runtime::{Engine, SimdMode};
use falkon::util::rng::Rng;
use falkon::util::timer::Timer;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("{e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        bail!(top_usage());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "train" => cmd_train(rest),
        "predict" => cmd_predict(rest),
        "convert" => cmd_convert(rest),
        "serve" => cmd_serve(rest),
        "lscores" => cmd_lscores(rest),
        "tune" => cmd_tune(rest),
        "info" => cmd_info(rest),
        "--help" | "-h" | "help" => {
            println!("{}", top_usage());
            Ok(())
        }
        other => bail!("unknown command {other:?}\n\n{}", top_usage()),
    }
}

fn top_usage() -> String {
    "falkon — An Optimal Large Scale Kernel Method (NIPS 2017), rust+JAX+Pallas\n\n\
     usage: falkon <command> [--help]\n\n\
     commands:\n\
       train     fit FALKON on a dataset (--stream = out-of-core)\n\
       predict   evaluate a saved model (.shard inputs stream)\n\
       convert   convert a dataset to the binary shard format\n\
       serve     prediction server (TCP with --addr, demo without)\n\
       lscores   approximate leverage scores summary\n\
       tune      grid-search sigma/lambda on a holdout\n\
       info      artifact registry / engine status\n"
        .to_string()
}

/// Load a dataset: synthetic analogue by name, or a file path
/// (.libsvm/.svm, .csv or .shard).
fn load_dataset(name: &str, n: usize, seed: u64) -> Result<Dataset> {
    let mut rng = Rng::new(seed ^ 0xDA7A);
    if let Some(d) = synth::by_name(name, &mut rng, n) {
        return Ok(d);
    }
    if name.ends_with(".shard") {
        return falkon::data::shard::load(name);
    }
    if name.ends_with(".csv") {
        return falkon::data::csv::load_regression(name, true);
    }
    if name.ends_with(".libsvm") || name.ends_with(".svm") || name.ends_with(".txt") {
        return falkon::data::libsvm::load_regression(name, None);
    }
    bail!(
        "unknown dataset {name:?} — synthetic: songs yelp timit susy higgs \
         imagenet smooth, or a .csv/.libsvm/.shard path"
    )
}

/// Open a dataset as a chunked [`DataSource`] (the out-of-core path).
/// Synthetic analogues are generated in memory and wrapped, so every
/// dataset name the in-memory path accepts also streams.
fn open_source(name: &str, n: usize, seed: u64, chunk_rows: usize) -> Result<Box<dyn DataSource>> {
    let mut rng = Rng::new(seed ^ 0xDA7A);
    if let Some(d) = synth::by_name(name, &mut rng, n) {
        return Ok(Box::new(MemSource::new(d, chunk_rows)));
    }
    if name.ends_with(".shard") {
        return Ok(Box::new(ShardSource::open(name, chunk_rows)?));
    }
    if name.ends_with(".csv") {
        return Ok(Box::new(CsvSource::open(name, true, chunk_rows)?));
    }
    if name.ends_with(".libsvm") || name.ends_with(".svm") || name.ends_with(".txt") {
        return Ok(Box::new(LibsvmSource::open(name, None, chunk_rows)?));
    }
    bail!(
        "unknown dataset {name:?} — synthetic: songs yelp timit susy higgs \
         imagenet smooth, or a .csv/.libsvm/.shard path"
    )
}

fn train_spec() -> Command {
    Command::new("train", "fit FALKON and report test metrics")
        .opt("dataset", "susy", "dataset name or file path")
        .opt("n", "20000", "rows for synthetic datasets")
        .opt("m", "1024", "Nyström centers M (must be compiled; see info)")
        .opt("sigma", "4.0", "gaussian/laplacian width σ")
        .opt("lam", "1e-6", "ridge λ")
        .opt("t", "20", "CG iterations")
        .opt("kernel", "gaussian", "gaussian | laplacian | linear")
        .opt("engine", Engine::default_name(), "xla | xla-jnp | rust")
        .opt("centers", "uniform", "uniform | leverage")
        .opt("sketch", "0", "leverage-score sketch size (0 = M)")
        .opt("seed", "0", "rng seed")
        .opt("workers", "1", "rust-engine worker threads")
        .opt("config", "", "JSON config file (overrides all other flags)")
        .opt("out", "", "save fitted model JSON here")
        .switch("no-normalize", "skip z-score normalization")
        .switch("stream", "out-of-core: fit from a chunked source (O(chunk) resident features)")
        .opt("chunk-rows", "8192", "rows per resident chunk on the streaming path")
        .opt("checkpoint", "", "CG checkpoint sidecar path (enables periodic snapshots)")
        .opt("checkpoint-every", "5", "snapshot the CG state every k iterations")
        .switch("resume", "resume from an existing compatible --checkpoint sidecar")
        .opt("nan-policy", "fail", "streamed rows with NaN/Inf: fail | skip")
        .opt(
            "dtype",
            "f64",
            "feature storage: f32 halves resident row-block/chunk bytes \
             (kernel panels still accumulate in f64; DESIGN.md §Precision model)",
        )
        .opt(
            "simd",
            "auto",
            "kernel panel ISA: auto | scalar | avx2 | neon (auto defers to \
             FALKON_SIMD, then runtime detection; rust engine)",
        )
}

/// Parse the `--simd` flag (an explicit flag beats `FALKON_SIMD`;
/// `auto` defers to it).
fn parse_simd(p: &falkon::cli::Parsed) -> Result<SimdMode> {
    SimdMode::parse(p.str("simd")).ok_or_else(|| {
        anyhow!(
            "unknown --simd {:?} (expected auto | scalar | avx2 | neon)",
            p.str("simd")
        )
    })
}

fn config_from_flags(p: &falkon::cli::Parsed) -> Result<ExperimentConfig> {
    if !p.str("config").is_empty() {
        return ExperimentConfig::load(p.str("config"));
    }
    let sketch = p.usize("sketch")?;
    let m = p.usize("m")?;
    Ok(ExperimentConfig {
        dataset: p.str("dataset").to_string(),
        n: p.usize("n")?,
        test_frac: 0.2,
        normalize: !p.flag("no-normalize"),
        engine: p.str("engine").to_string(),
        workers: p.usize("workers")?,
        falkon: FalkonConfig {
            kernel: Kernel::parse(p.str("kernel"))
                .ok_or_else(|| anyhow!("unknown kernel {}", p.str("kernel")))?,
            sigma: p.f64("sigma")?,
            lam: p.f64("lam")?,
            m,
            t: p.usize("t")?,
            centers: match p.str("centers") {
                "uniform" => Centers::Uniform,
                "leverage" => Centers::ApproxLeverage {
                    sketch: falkon::falkon::lscores::effective_sketch(sketch, m),
                },
                other => bail!("unknown centers {other:?}"),
            },
            seed: p.u64("seed")?,
            ..Default::default()
        },
    })
}

fn prepare_data(cfg: &ExperimentConfig) -> Result<(Dataset, Dataset)> {
    let data = load_dataset(&cfg.dataset, cfg.n, cfg.falkon.seed)?;
    let mut rng = Rng::new(cfg.falkon.seed ^ 0x5917);
    let (mut train, mut test) = data.split(cfg.test_frac, &mut rng);
    // paper protocol: z-score except YELP (binary n-grams) and IMAGENET
    if cfg.normalize && cfg.dataset != "yelp" && cfg.dataset != "imagenet" {
        ZScore::normalize(&mut train, &mut test);
    }
    Ok((train, test))
}

/// Out-of-core training: one streaming z-score pass (optional), a
/// streaming fit, and a streaming scoring sweep — the dataset is never
/// materialized. The streaming path has no in-memory holdout split, so
/// the reported metrics are training metrics.
fn train_stream(p: &falkon::cli::Parsed, cfg: &ExperimentConfig, engine: &Engine) -> Result<()> {
    let chunk_rows = p.usize("chunk-rows")?.max(1);
    let nan_policy = NanPolicy::parse(p.str("nan-policy"))?;
    let dtype = Dtype::parse(p.str("dtype"))?;
    // sanitize innermost so NaN/Inf rows never reach the z-score stats
    // pass or the fit (DESIGN.md § Fault tolerance). `--dtype f32` casts
    // right above the backend, so every downstream stage (stats pass,
    // z-score, the fit's sweeps) holds 4-byte chunks; the default leaves
    // chunks in the stream's native format (an f32 shard stays f32).
    let open = || -> Result<Box<dyn DataSource>> {
        let mut src = open_source(&cfg.dataset, cfg.n, cfg.falkon.seed, chunk_rows)?;
        if dtype == Dtype::F32 {
            src = Box::new(CastSource::new(src, dtype));
        }
        Ok(Box::new(SanitizeSource::new(src, nan_policy)))
    };
    // reject unsupported tasks before any data sweep (the z-score pass
    // below reads the whole stream)
    let mut first = open()?;
    anyhow::ensure!(
        first.n_classes() <= 2,
        "--stream supports regression/binary tasks (dataset {} has {} classes); \
         use the in-memory path for one-vs-all multiclass",
        cfg.dataset,
        first.n_classes()
    );
    // paper protocol: z-score except YELP (binary n-grams) and IMAGENET
    let z = if cfg.normalize && cfg.dataset != "yelp" && cfg.dataset != "imagenet" {
        Some(ZScore::fit_source(first.as_mut())?)
    } else {
        None
    };
    let wrap = |s: Box<dyn DataSource>| -> Box<dyn DataSource> {
        match &z {
            Some(z) => Box::new(ZScoreSource::new(s, z.clone())),
            None => s,
        }
    };
    // sources are rewindable: reuse the already-scanned one for the fit
    let source = wrap(first);
    println!(
        "dataset={} n={:?} d={} chunk_rows={chunk_rows} | engine={} kernel={:?} σ={} λ={:.2e} M={} t={} [stream]",
        cfg.dataset,
        source.len_hint(),
        source.d(),
        engine.name(),
        cfg.falkon.kernel,
        cfg.falkon.sigma,
        cfg.falkon.lam,
        cfg.falkon.m,
        cfg.falkon.t
    );
    let timer = Timer::start();
    let model = fit_source(engine, source, &cfg.falkon)?;
    let fit_s = timer.elapsed_s();
    println!("fit: {fit_s:.2}s (cg iters: {})\n{}", model.cg_iters, model.phases.report());
    for line in model.report.lines() {
        println!("  [degraded] {line}");
    }
    let mut eval = wrap(open()?);
    let (score, secs) = falkon::util::timer::timed(|| {
        falkon::serve::predict_source(&model, engine, eval.as_mut())
    });
    let score = score?;
    println!(
        "scored {} rows in {secs:.2}s ({:.0} rows/s, peak chunk {} KiB)",
        score.rows,
        score.rows as f64 / secs.max(1e-9),
        score.max_chunk_bytes / 1024
    );
    if score.skipped_rows > 0 {
        println!("  skipped {} non-finite rows (--nan-policy skip)", score.skipped_rows);
    }
    println!(
        "train MSE = {:.4}  RMSE = {:.4} (streaming path: no holdout split)",
        metrics::mse(&score.preds, &score.targets),
        metrics::rmse(&score.preds, &score.targets)
    );
    if !p.str("out").is_empty() {
        model_io::save(&model, p.str("out"))?;
        println!("model saved to {}", p.str("out"));
    }
    Ok(())
}

fn cmd_train(args: &[String]) -> Result<()> {
    let p = train_spec().parse(args)?;
    let mut cfg = config_from_flags(&p)?;
    if !p.str("checkpoint").is_empty() {
        cfg.falkon.checkpoint = Some(CheckpointSpec::new(
            p.str("checkpoint"),
            p.usize("checkpoint-every")?.max(1),
            p.flag("resume"),
        ));
    } else if p.flag("resume") {
        bail!("--resume needs --checkpoint <path> to know which sidecar to load");
    }
    // `--dtype f32` makes the rust plan slice its resident row blocks as
    // f32 (the XLA engine ignores the knob and stays f64); `--simd`
    // pins the panel ISA for the whole fit
    let engine = Engine::by_name_dtype(
        &cfg.engine,
        cfg.workers,
        Dtype::parse(p.str("dtype"))?,
        parse_simd(&p)?,
    )?;
    if p.flag("stream") {
        return train_stream(&p, &cfg, &engine);
    }
    let (train, test) = prepare_data(&cfg)?;
    println!(
        "dataset={} n_train={} n_test={} d={} | engine={} kernel={:?} σ={} λ={:.2e} M={} t={}",
        cfg.dataset,
        train.n(),
        test.n(),
        train.d(),
        engine.name(),
        cfg.falkon.kernel,
        cfg.falkon.sigma,
        cfg.falkon.lam,
        cfg.falkon.m,
        cfg.falkon.t
    );

    let timer = Timer::start();
    if train.is_multiclass() {
        let model = fit_multiclass(&engine, &train, &cfg.falkon)?;
        let fit_s = timer.elapsed_s();
        let pred = model.predict_class(&engine, &test.x)?;
        let labels = test.labels.as_ref().unwrap();
        let cerr =
            pred.iter().zip(labels).filter(|(a, b)| a != b).count() as f64 / pred.len() as f64;
        println!("fit: {fit_s:.2}s\n{}", model.phases.report());
        println!("c-err = {:.2}%", 100.0 * cerr);
    } else {
        let model = fit(&engine, &train.x, &train.y, &cfg.falkon)?;
        let fit_s = timer.elapsed_s();
        let preds = model.predict(&engine, &test.x)?;
        println!("fit: {fit_s:.2}s (cg iters: {})", model.cg_iters);
        println!("{}", model.phases.report());
        for line in model.report.lines() {
            println!("  [degraded] {line}");
        }
        if train.n_classes == 2 {
            println!(
                "c-err = {:.2}%  AUC = {:.4}",
                100.0 * metrics::binary_error(&preds, &test.y),
                metrics::auc(&preds, &test.y)
            );
        } else {
            println!(
                "MSE = {:.4}  RMSE = {:.4}  rel.err = {:.3e}",
                metrics::mse(&preds, &test.y),
                metrics::rmse(&preds, &test.y),
                metrics::relative_error(&preds, &test.y)
            );
        }
        if !p.str("out").is_empty() {
            model_io::save(&model, p.str("out"))?;
            println!("model saved to {}", p.str("out"));
        }
    }
    Ok(())
}

fn cmd_predict(args: &[String]) -> Result<()> {
    let spec = Command::new("predict", "evaluate a saved model on a dataset")
        .req("model", "model JSON from `train --out`")
        .opt("dataset", "susy", "dataset name or file path")
        .opt("n", "20000", "rows for synthetic datasets")
        .opt("engine", Engine::default_name(), "xla | xla-jnp | rust")
        .opt("workers", "1", "rust-engine worker threads")
        .opt("chunk-rows", "8192", "rows per resident chunk for .shard inputs")
        .switch("no-normalize", "skip z-score normalization")
        .opt("nan-policy", "fail", "streamed rows with NaN/Inf: fail | skip")
        .opt(
            "dtype",
            "f64",
            "feature storage for scoring: f32 halves resident chunk bytes \
             (predictions stay within the documented tolerance model)",
        )
        .opt(
            "simd",
            "auto",
            "kernel panel ISA: auto | scalar | avx2 | neon (rust engine)",
        )
        .opt("seed", "0", "rng seed (dataset generation + split)");
    let p = spec.parse(args)?;
    let model = model_io::load(p.str("model"))?;
    let dtype = Dtype::parse(p.str("dtype"))?;
    let engine = Engine::by_name_dtype(
        p.str("engine"),
        p.usize("workers")?,
        Dtype::F64,
        parse_simd(&p)?,
    )?;
    if p.str("dataset").ends_with(".shard") {
        // out-of-core scoring: stream the shard, never materialize it.
        // Like the in-memory path (prepare_data), features are z-scored
        // by default — a streaming stats pass here — so a model trained
        // on normalized data isn't silently fed raw features.
        // `--dtype f32` casts innermost, so the stats pass and the
        // scoring sweep both hold 4-byte chunks; native f32 shards
        // stream as f32 either way (per-chunk dtype dispatch).
        let mut inner: Box<dyn DataSource> =
            Box::new(ShardSource::open(p.str("dataset"), p.usize("chunk-rows")?.max(1))?);
        if dtype == Dtype::F32 {
            inner = Box::new(CastSource::new(inner, dtype));
        }
        let mut src: Box<dyn DataSource> = Box::new(SanitizeSource::new(
            inner,
            NanPolicy::parse(p.str("nan-policy"))?,
        ));
        anyhow::ensure!(
            src.d() == model.centers.cols,
            "model d={} vs shard d={}",
            model.centers.cols,
            src.d()
        );
        if !p.flag("no-normalize") {
            let z = ZScore::fit_source(src.as_mut())?;
            src = Box::new(ZScoreSource::new(src, z));
        }
        let (score, secs) = falkon::util::timer::timed(|| {
            falkon::serve::predict_source(&model, &engine, src.as_mut())
        });
        let score = score?;
        println!(
            "n={} in {secs:.3}s ({:.0} rows/s, peak chunk {} KiB) [stream]",
            score.rows,
            score.rows as f64 / secs.max(1e-9),
            score.max_chunk_bytes / 1024
        );
        if score.skipped_rows > 0 {
            println!("  skipped {} non-finite rows (--nan-policy skip)", score.skipped_rows);
        }
        println!(
            "MSE = {:.4}  AUC = {:.4}",
            metrics::mse(&score.preds, &score.targets),
            metrics::auc(&score.preds, &score.targets)
        );
        return Ok(());
    }
    let cfg = ExperimentConfig {
        dataset: p.str("dataset").to_string(),
        n: p.usize("n")?,
        falkon: FalkonConfig {
            seed: p.u64("seed")?,
            ..Default::default()
        },
        ..Default::default()
    };
    let (_, test) = prepare_data(&cfg)?;
    anyhow::ensure!(
        test.d() == model.centers.cols,
        "model d={} vs dataset d={}",
        model.centers.cols,
        test.d()
    );
    let (preds, secs) = falkon::util::timer::timed(|| match dtype {
        Dtype::F64 => model.predict(&engine, &test.x),
        // round the features once and score through the mixed tier
        Dtype::F32 => {
            model.predict_block(&engine, &XBlock::from_mat_dtype(test.x.clone(), dtype))
        }
    });
    let preds = preds?;
    println!(
        "n={} in {:.3}s ({:.0} rows/s)",
        test.n(),
        secs,
        test.n() as f64 / secs
    );
    println!(
        "MSE = {:.4}  AUC = {:.4}",
        metrics::mse(&preds, &test.y),
        metrics::auc(&preds, &test.y)
    );
    Ok(())
}

/// Stream-convert a dataset into the chunked binary shard format
/// (`data::shard`): text inputs are parsed lazily and written record by
/// record, so a file larger than RAM converts in O(chunk) memory.
fn cmd_convert(args: &[String]) -> Result<()> {
    let spec = Command::new("convert", "convert a dataset to the chunked binary shard format")
        .req("input", "input path (.csv/.libsvm/.svm/.txt) or synthetic dataset name")
        .req("output", "output .shard path")
        .opt("n", "20000", "rows for synthetic datasets")
        .opt("chunk-rows", "8192", "rows per streamed record")
        .opt("dim", "0", "pin the libsvm feature dim (0 = infer from the data)")
        .switch("no-header", "csv input has no header row")
        .opt(
            "dtype",
            "f64",
            "shard feature storage: f32 writes half-size shards \
             (each value rounded exactly once)",
        )
        .opt("seed", "0", "rng seed for synthetic datasets");
    let p = spec.parse(args)?;
    let input = p.str("input");
    let output = p.str("output");
    let chunk_rows = p.usize("chunk-rows")?.max(1);
    let dtype = Dtype::parse(p.str("dtype"))?;
    let timer = Timer::start();
    let rows = if let Some(data) =
        synth::by_name(input, &mut Rng::new(p.u64("seed")? ^ 0xDA7A), p.usize("n")?)
    {
        let n_rows = data.n();
        match dtype {
            // single record: lets the reader re-chunk at any budget
            Dtype::F64 => falkon::data::shard::write_dataset(output, &data)?,
            Dtype::F32 => {
                let mut src = MemSource::new(data, n_rows.max(1));
                falkon::data::shard::write_source_dtype(output, &mut src, dtype)?;
            }
        };
        n_rows
    } else if input.ends_with(".csv") {
        let mut src = CsvSource::open(input, !p.flag("no-header"), chunk_rows)?;
        falkon::data::shard::write_source_dtype(output, &mut src, dtype)?
    } else if input.ends_with(".libsvm") || input.ends_with(".svm") || input.ends_with(".txt") {
        let dim = match p.usize("dim")? {
            0 => None,
            d => Some(d),
        };
        let mut src = LibsvmSource::open(input, dim, chunk_rows)?;
        falkon::data::shard::write_source_dtype(output, &mut src, dtype)?
    } else {
        bail!("unknown input {input:?} — a .csv/.libsvm path or a synthetic dataset name")
    };
    println!(
        "wrote {rows} rows ({}) to {output} in {:.2}s",
        dtype.name(),
        timer.elapsed_s()
    );
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let spec = Command::new("serve", "prediction server: network front door or request-storm demo")
        .req(
            "model",
            "model JSON from `train --out`; with --addr, a comma list of name=path pairs \
             registers several models (a bare path serves as \"default\")",
        )
        .opt(
            "addr",
            "",
            "listen address (e.g. 127.0.0.1:7878; port 0 = ephemeral). \
             Empty = in-process request-storm demo",
        )
        .opt("requests", "2000", "demo mode: number of synthetic requests")
        .opt("clients", "8", "demo mode: concurrent client threads")
        .opt("max-batch", "64", "admission budget in rows per batch")
        .opt("max-wait-ms", "2", "batch linger")
        .opt("engine", Engine::default_name(), "xla | xla-jnp | rust")
        .opt("workers", "1", "rust-engine worker threads");
    let p = spec.parse(args)?;
    let cfg = falkon::serve::ServeConfig {
        max_batch: p.usize("max-batch")?,
        max_wait: std::time::Duration::from_millis(p.u64("max-wait-ms")?),
        engine: p.str("engine").to_string(),
        workers: p.usize("workers")?,
    };
    if !p.str("addr").is_empty() {
        return serve_net(p.str("model"), p.str("addr"), cfg);
    }
    let model = model_io::load(p.str("model"))?;
    let d = model.centers.cols;
    let server = falkon::serve::Server::start(model, cfg)?;
    let total = p.usize("requests")?;
    let clients = p.usize("clients")?.max(1);
    let timer = Timer::start();
    let lat_all: Vec<f64> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let h = server.handle();
                s.spawn(move || {
                    let mut rng = Rng::new(c as u64 + 100);
                    let mut lats = Vec::new();
                    for _ in 0..total / clients {
                        let x = rng.normals(d);
                        let t = Timer::start();
                        h.predict(x).unwrap();
                        lats.push(t.elapsed_s());
                    }
                    lats
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    let wall = timer.elapsed_s();
    let stats = server.stop();
    let mut lats = lat_all;
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |q: f64| lats[((lats.len() as f64 - 1.0) * q) as usize] * 1e3;
    println!(
        "served {} requests in {:.2}s  ({:.0} req/s)  batches={} mean_batch={:.1}",
        stats.requests,
        wall,
        stats.requests as f64 / wall,
        stats.batches,
        stats.mean_batch
    );
    println!(
        "latency ms: p50={:.2} p90={:.2} p99={:.2}",
        pct(0.5),
        pct(0.9),
        pct(0.99)
    );
    Ok(())
}

/// Network serving mode: register the named models, bind the TCP front
/// door, and serve until stdin closes or a line is entered (so it runs
/// interactively and under a supervisor alike).
fn serve_net(models: &str, addr: &str, cfg: falkon::serve::ServeConfig) -> Result<()> {
    let registry = std::sync::Arc::new(falkon::serve::registry::ModelRegistry::new());
    for entry in models.split(',') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let (name, path) = match entry.split_once('=') {
            Some((n, p)) => (n.trim(), p.trim()),
            None => ("default", entry),
        };
        registry.load_file(name, path)?;
        println!("registered {name:?} from {path}");
    }
    let server = falkon::serve::net::NetServer::start(registry, cfg, addr)?;
    // the bound address on its own line so scripts using port 0 can
    // scrape the ephemeral port
    println!("listening on {}", server.addr());
    println!(
        "serving {:?}; close stdin or press Enter to stop",
        server.registry().names()
    );
    let mut line = String::new();
    match std::io::stdin().read_line(&mut line) {
        // EOF (daemonized with stdin at /dev/null): serve until killed
        Ok(0) => loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        },
        Ok(_) | Err(_) => {}
    }
    for (name, stats) in server.stop() {
        println!(
            "{name}: {} requests ({} rejected) in {} batches, mean_batch={:.1}",
            stats.requests, stats.rejected, stats.batches, stats.mean_batch
        );
    }
    Ok(())
}

fn cmd_lscores(args: &[String]) -> Result<()> {
    let spec = Command::new("lscores", "approximate leverage scores summary")
        .opt("dataset", "smooth", "dataset name or path")
        .opt("n", "2000", "rows")
        .opt("lam", "1e-3", "level λ")
        .opt("sigma", "1.0", "kernel width")
        .opt("m", "256", "centers M the sketch default derives from")
        .opt("sketch", "0", "pilot sketch size (0 = M)")
        .opt("engine", "rust", "xla | rust")
        .opt("seed", "0", "rng seed")
        .switch("stream", "chunked DataSource passes instead of an eager load")
        .opt("chunk-rows", "8192", "rows per chunk with --stream");
    let p = spec.parse(args)?;
    let sketch = falkon::falkon::lscores::effective_sketch(p.usize("sketch")?, p.usize("m")?);
    let engine = Engine::by_name(p.str("engine"), 1)?;
    let mut rng = Rng::new(p.u64("seed")?);
    let scores = if p.flag("stream") {
        // shards bigger than RAM: never materialize the n×d matrix
        let mut source = open_source(
            p.str("dataset"),
            p.usize("n")?,
            p.u64("seed")?,
            p.usize("chunk-rows")?,
        )?;
        falkon::falkon::lscores::approx_leverage_scores_source(
            &engine,
            source.as_mut(),
            Kernel::Gaussian,
            p.f64("sigma")?,
            p.f64("lam")?,
            sketch,
            &mut rng,
        )?
    } else {
        let data = load_dataset(p.str("dataset"), p.usize("n")?, p.u64("seed")?)?;
        falkon::falkon::lscores::approx_leverage_scores(
            &engine,
            &data.x,
            Kernel::Gaussian,
            p.f64("sigma")?,
            p.f64("lam")?,
            sketch,
            &mut rng,
        )?
    };
    let mut sorted = scores.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |f: f64| sorted[((sorted.len() as f64 - 1.0) * f) as usize];
    println!(
        "n={}  dof≈{:.1}  min={:.4} p50={:.4} p90={:.4} max={:.4}",
        scores.len(),
        scores.iter().sum::<f64>(),
        q(0.0),
        q(0.5),
        q(0.9),
        q(1.0)
    );
    Ok(())
}

fn cmd_tune(args: &[String]) -> Result<()> {
    let spec = Command::new("tune", "grid-search σ/λ on a holdout split")
        .opt("dataset", "susy", "dataset name or file path")
        .opt("n", "10000", "rows for synthetic datasets")
        .opt("m", "512", "Nyström centers M")
        .opt("t", "15", "CG iterations")
        .opt("sigmas", "1,2,4,8", "comma-separated σ grid")
        .opt("lam-lo", "1e-8", "λ grid low end")
        .opt("lam-hi", "1e-2", "λ grid high end")
        .opt("lam-count", "4", "λ grid points (log-spaced)")
        .opt("engine", Engine::default_name(), "xla | xla-jnp | rust")
        .opt("seed", "0", "rng seed");
    let p = spec.parse(args)?;
    let engine = Engine::by_name(p.str("engine"), 1)?;
    let cfg = ExperimentConfig {
        dataset: p.str("dataset").to_string(),
        n: p.usize("n")?,
        falkon: FalkonConfig {
            m: p.usize("m")?,
            t: p.usize("t")?,
            seed: p.u64("seed")?,
            ..Default::default()
        },
        ..Default::default()
    };
    let (train, valid) = prepare_data(&cfg)?;
    anyhow::ensure!(!train.is_multiclass(), "tune supports regression/binary tasks");
    let sigmas: Vec<f64> = p
        .str("sigmas")
        .split(',')
        .map(|s| s.trim().parse::<f64>())
        .collect::<Result<_, _>>()
        .map_err(|e| anyhow!("--sigmas: {e}"))?;
    let lams = falkon::falkon::tune::log_grid(
        p.f64("lam-lo")?,
        p.f64("lam-hi")?,
        p.usize("lam-count")?.max(2),
    );
    let objective = if train.n_classes == 2 {
        falkon::falkon::tune::Objective::BinaryError
    } else {
        falkon::falkon::tune::Objective::Mse
    };
    let res = falkon::falkon::tune::grid_search(
        &engine, &train.x, &train.y, &valid.x, &valid.y, &cfg.falkon, &sigmas, &lams, objective,
    )?;
    println!("evaluated {} configs in {:.1}s:", res.trace.len(), res.secs);
    for (s, l, v) in &res.trace {
        println!("  σ={s:<8} λ={l:<10.2e} score={v:.5}");
    }
    println!("\nbest: σ={} λ={:.2e} score={:.5}", res.sigma, res.lam, res.score);
    Ok(())
}

fn cmd_info(args: &[String]) -> Result<()> {
    let spec = Command::new("info", "artifact registry / engine status");
    let _ = spec.parse(args)?;
    match falkon::runtime::Registry::load_default() {
        Ok(reg) => {
            println!(
                "artifacts: {} entries at {}",
                reg.entries.len(),
                reg.dir.display()
            );
            println!("row block: {} (test {})", reg.block, reg.test_block);
            for kern in [Kernel::Gaussian, Kernel::Laplacian, Kernel::Linear] {
                for d in [8usize, 32, 128, 512] {
                    let ms = reg.usable_ms(kern, d);
                    if !ms.is_empty() {
                        println!("  {:<10} d≤{:<4} M ∈ {:?}", kern.name(), d, ms);
                    }
                }
            }
            match Engine::xla_default() {
                Ok(_) => println!("PJRT CPU client: ok"),
                Err(e) => println!("PJRT CPU client: FAILED ({e})"),
            }
        }
        Err(e) => println!("no artifacts ({e}); rust engine only"),
    }
    Ok(())
}

//! Evaluation metrics matching the paper's Tables 2–3: MSE, RMSE, relative
//! error (MillionSongs), classification error (TIMIT, IMAGENET, SUSY) and
//! AUC (SUSY, HIGGS).

/// Mean squared error.
pub fn mse(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    assert!(!pred.is_empty());
    pred.iter()
        .zip(truth)
        .map(|(p, t)| (p - t) * (p - t))
        .sum::<f64>()
        / pred.len() as f64
}

pub fn rmse(pred: &[f64], truth: &[f64]) -> f64 {
    mse(pred, truth).sqrt()
}

/// The "relative error" used for MillionSongs in Table 2 (as in [4], [33]):
/// normalized by the mean-squared magnitude of the targets.
pub fn relative_error(pred: &[f64], truth: &[f64]) -> f64 {
    let num = mse(pred, truth);
    let den = truth.iter().map(|t| t * t).sum::<f64>() / truth.len() as f64;
    num / den.max(1e-30)
}

/// Binary classification error with labels in {-1, +1} and a real-valued
/// score (sign decision).
pub fn binary_error(score: &[f64], label: &[f64]) -> f64 {
    assert_eq!(score.len(), label.len());
    assert!(!score.is_empty());
    let wrong = score
        .iter()
        .zip(label)
        .filter(|(s, l)| (s.is_sign_negative() && **l > 0.0) || (!s.is_sign_negative() && **l < 0.0))
        .count();
    wrong as f64 / score.len() as f64
}

/// Multiclass classification error from per-class scores (one-vs-all):
/// `scores[k][i]` is class k's score for example i; `labels[i]` in 0..K.
pub fn multiclass_error(scores: &[Vec<f64>], labels: &[usize]) -> f64 {
    assert!(!scores.is_empty());
    let n = labels.len();
    let mut wrong = 0usize;
    for i in 0..n {
        let mut best = 0usize;
        let mut best_s = f64::NEG_INFINITY;
        for (k, sk) in scores.iter().enumerate() {
            if sk[i] > best_s {
                best_s = sk[i];
                best = k;
            }
        }
        if best != labels[i] {
            wrong += 1;
        }
    }
    wrong as f64 / n as f64
}

/// Area under the ROC curve via the rank statistic (ties get mid-ranks).
/// Labels in {-1, +1} (or any sign convention: >0 is positive).
pub fn auc(score: &[f64], label: &[f64]) -> f64 {
    assert_eq!(score.len(), label.len());
    let n = score.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| score[a].partial_cmp(&score[b]).unwrap());
    // mid-rank assignment for tied scores
    let mut ranks = vec![0.0f64; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && score[idx[j + 1]] == score[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            ranks[idx[k]] = avg;
        }
        i = j + 1;
    }
    let npos = label.iter().filter(|l| **l > 0.0).count();
    let nneg = n - npos;
    if npos == 0 || nneg == 0 {
        return 0.5;
    }
    let rank_sum: f64 = (0..n).filter(|&i| label[i] > 0.0).map(|i| ranks[i]).sum();
    (rank_sum - (npos * (npos + 1)) as f64 / 2.0) / (npos * nneg) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mse_rmse() {
        assert_eq!(mse(&[1.0, 2.0], &[1.0, 4.0]), 2.0);
        assert!((rmse(&[0.0, 0.0], &[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn relative_error_scales() {
        let p = [11.0, 19.0];
        let t = [10.0, 20.0];
        let re = relative_error(&p, &t);
        assert!((re - 1.0 / 250.0).abs() < 1e-12);
    }

    #[test]
    fn binary_error_counts_sign_mismatches() {
        let s = [0.5, -0.5, 2.0, -3.0];
        let l = [1.0, 1.0, -1.0, -1.0];
        assert_eq!(binary_error(&s, &l), 0.5);
    }

    #[test]
    fn multiclass_argmax() {
        // 3 classes, 2 examples
        let scores = vec![vec![0.9, 0.1], vec![0.0, 0.8], vec![0.5, 0.2]];
        let labels = vec![0usize, 2];
        assert_eq!(multiclass_error(&scores, &labels), 0.5); // ex1 -> class1, wrong
    }

    #[test]
    fn auc_perfect_and_random() {
        let l = [1.0, 1.0, -1.0, -1.0];
        assert_eq!(auc(&[4.0, 3.0, 2.0, 1.0], &l), 1.0);
        assert_eq!(auc(&[1.0, 2.0, 3.0, 4.0], &l), 0.0);
        // all tied -> 0.5
        assert_eq!(auc(&[1.0, 1.0, 1.0, 1.0], &l), 0.5);
    }

    #[test]
    fn auc_known_value() {
        // scores: pos {0.8, 0.4}, neg {0.6, 0.2}: pairs won = (0.8>0.6)+(0.8>0.2)+(0.4>0.2)=3 of 4
        let s = [0.8, 0.4, 0.6, 0.2];
        let l = [1.0, 1.0, -1.0, -1.0];
        assert_eq!(auc(&s, &l), 0.75);
    }

    #[test]
    fn auc_degenerate_single_class() {
        assert_eq!(auc(&[1.0, 2.0], &[1.0, 1.0]), 0.5);
    }
}

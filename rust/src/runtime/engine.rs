//! The compute engine abstraction: every data-touching op the coordinator
//! needs, served either by the AOT XLA artifacts (production path, behind
//! the `xla` cargo feature) or by the pure-Rust kernels (fallback /
//! cross-check / "compute on the fly" baseline).
//!
//! The hot object is the [`MatvecPlan`]: built once per fit, it owns the
//! per-block prepared inputs and then serves
//! `w = Σ_blocks Krᵀ(mask(Kr u + v))` every CG iteration:
//!
//! - **XLA**: row blocks padded + masked and uploaded as literals exactly
//!   once; staging buffers for `u`/`v` are reused across applies.
//! - **Rust**: row blocks sliced and their squared row norms precomputed at
//!   *plan construction* (the seed re-sliced the whole dataset on every CG
//!   iteration), served by the tiled kernels with per-thread reusable Kr
//!   tile buffers, and fanned out over the engine's **shared persistent
//!   worker pool** (`util/pool.rs`) — spawned once per engine and serving
//!   the setup path (K_MM panels, blocked Cholesky, SYRK) as well as the
//!   applies, so a 20-iteration fit spawns threads once, not 20×. See
//!   DESIGN.md §Perf.
//!
//! [`MatvecPlan::apply_multi`] is the multi-RHS variant: an `M×K`
//! coefficient block rides one pass over the row blocks, so the one-vs-all
//! multiclass solve computes each Kr panel once per iteration instead of
//! once per class (DESIGN.md §Perf "Multi-RHS path"). The XLA plan serves
//! it as a loop over columns (the artifact contract is vector-shaped).

use crate::data::source::DataSource;
use crate::kernels::simd::{Isa, SimdMode};
use crate::kernels::{self, Kernel};
use crate::linalg::mat::Mat;
use crate::linalg::mat32::{Dtype, MatF32, XBlock};
use crate::linalg::{chol, gemm, tri};
#[cfg(feature = "xla")]
use crate::runtime::exe::{literal_from_f32, literal_scalar, literal_to_f32, Exe};
#[cfg(feature = "xla")]
use crate::runtime::spec::Op;
use crate::runtime::spec::{Impl, Registry};
use crate::util::pool::{chunk_ranges, WorkerPool};
#[cfg(feature = "xla")]
use anyhow::Context;
use anyhow::{anyhow, Result};
use std::cell::{Cell, RefCell};
#[cfg(feature = "xla")]
use std::collections::HashMap;
#[cfg(feature = "xla")]
use std::rc::Rc;
use std::sync::Arc;

/// Rows per Rust-engine block — the unit of work distribution across the
/// worker pool (the cache-level tiling inside a block is finer; see
/// [`kernels::DEFAULT_TILE`]).
const ROW_BLOCK: usize = 1024;

/// Engine configuration knobs that matter for perf experiments.
#[derive(Debug, Clone)]
pub struct EngineOptions {
    /// kernel-op implementation to request from the registry
    pub imp: Impl,
    /// worker threads for the blocked matvec *and* the setup-path linear
    /// algebra (K_MM, preconditioner factorization). Effective on the
    /// Rust engine; the XLA path stays single-threaded because the `xla`
    /// crate's client handle is an `Rc` (per-thread) — XLA itself can
    /// still use intra-op threads inside one executable.
    pub workers: usize,
    /// bounded-retry policy for transient source errors on the streaming
    /// paths (every [`StreamPlan`] sweep and streaming predict re-reads
    /// the source, so one flaky read must not kill an O(n√n) fit;
    /// DESIGN.md §Fault tolerance)
    pub retry: crate::util::fault::RetryPolicy,
    /// storage format for the Rust plan's row blocks (DESIGN.md
    /// §"Precision model"): `F32` rounds each sliced block once at plan
    /// build and serves it with the mixed-precision kernels
    /// ([`kernels::mixed`]) — half the resident bytes, f64 accumulation,
    /// error within [`kernels::tol`]. The coordinator math (CG, [`Bhb`],
    /// preconditioner) stays f64 either way. The XLA engine ignores this
    /// knob: its artifacts already stage blocks as f32 literals.
    pub dtype: Dtype,
    /// instruction-set arm for the Rust kernel panels (CLI `--simd`;
    /// DESIGN.md §Perf "SIMD panels"). `Auto` defers to `FALKON_SIMD`,
    /// then runtime feature detection; an explicit mode here beats the
    /// environment. Resolved **once** at engine construction
    /// ([`kernels::simd::resolve_logged`]) and threaded through every
    /// plan and predict sweep, so one engine never mixes arms.
    pub simd: SimdMode,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            imp: Impl::Pallas,
            workers: 1,
            retry: crate::util::fault::RetryPolicy::default(),
            dtype: Dtype::F64,
            simd: SimdMode::Auto,
        }
    }
}

/// Which compute path serves the ops.
pub enum Engine {
    /// Pure-Rust f64 tiled kernels (no artifacts needed). With
    /// `workers > 1` the engine owns one shared [`WorkerPool`]
    /// (`util/pool.rs`) serving *both* the per-iteration matvec applies
    /// and the setup-path linear algebra (K_MM panels, blocked Cholesky
    /// trailing updates, SYRK) — threads are spawned once per engine, not
    /// per plan or per fit.
    Rust {
        opts: EngineOptions,
        pool: Option<Arc<WorkerPool>>,
        /// panel ISA resolved once at construction from `opts.simd` /
        /// `FALKON_SIMD` / feature detection — every plan built by this
        /// engine inherits it (see `kernels::simd`)
        isa: Isa,
    },
    /// AOT XLA artifacts via PJRT (production).
    #[cfg(feature = "xla")]
    Xla {
        registry: Rc<Registry>,
        cache: RefCell<HashMap<String, Rc<Exe>>>,
        /// padded-center f32 literals keyed by (data fingerprint, rows,
        /// cols, artifact D): `kmm`/`predict`/`matvec_plan` previously
        /// re-padded and re-converted the same centers to a literal on
        /// every call — one conversion per (centers, artifact) now
        center_cache: RefCell<HashMap<(u64, usize, usize, usize), Rc<xla::Literal>>>,
        opts: EngineOptions,
    },
}

impl Engine {
    pub fn xla_default() -> Result<Engine> {
        Engine::xla(EngineOptions::default())
    }

    #[cfg(feature = "xla")]
    pub fn xla(opts: EngineOptions) -> Result<Engine> {
        Ok(Engine::Xla {
            registry: Rc::new(Registry::load_default()?),
            cache: RefCell::new(HashMap::new()),
            center_cache: RefCell::new(HashMap::new()),
            opts,
        })
    }

    #[cfg(not(feature = "xla"))]
    pub fn xla(opts: EngineOptions) -> Result<Engine> {
        let _ = opts;
        Err(anyhow!(
            "built without the `xla` cargo feature (no PJRT runtime); \
             use the rust engine"
        ))
    }

    #[cfg(feature = "xla")]
    pub fn xla_with_registry(registry: Registry, opts: EngineOptions) -> Engine {
        Engine::Xla {
            registry: Rc::new(registry),
            cache: RefCell::new(HashMap::new()),
            center_cache: RefCell::new(HashMap::new()),
            opts,
        }
    }

    pub fn rust() -> Engine {
        Engine::rust_with(EngineOptions::default())
    }

    pub fn rust_with(opts: EngineOptions) -> Engine {
        // a failed thread spawn (resource exhaustion) degrades to the
        // serial path rather than killing the engine — loudly, so a
        // slow workers=N engine is distinguishable from a perf bug
        let pool = if opts.workers > 1 {
            match WorkerPool::new("falkon-worker", opts.workers) {
                Ok(p) => Some(Arc::new(p)),
                Err(e) => {
                    eprintln!(
                        "[engine] worker pool spawn failed ({e}); \
                         falling back to serial applies"
                    );
                    None
                }
            }
        } else {
            None
        };
        let isa = resolve_engine_simd(opts.simd);
        Engine::Rust { opts, pool, isa }
    }

    /// Name of the engine compiled into this binary: `"xla"` when the
    /// `xla` feature (PJRT runtime) is built in, `"rust"` otherwise.
    /// This is the default for CLI `--engine` flags and
    /// [`crate::serve::ServeConfig`], so defaults never select an engine
    /// the binary cannot construct.
    pub fn default_name() -> &'static str {
        if cfg!(feature = "xla") {
            "xla"
        } else {
            "rust"
        }
    }

    /// Parse "xla", "xla-jnp", "rust" (CLI `--engine`).
    pub fn by_name(name: &str, workers: usize) -> Result<Engine> {
        Engine::by_name_dtype(name, workers, Dtype::F64, SimdMode::Auto)
    }

    /// [`Engine::by_name`] with an explicit block storage format (CLI
    /// `--dtype`) and panel ISA override (CLI `--simd`). Both effective
    /// on the Rust engine; the XLA path stages blocks as f32 literals
    /// and serves panels from its artifacts regardless.
    pub fn by_name_dtype(
        name: &str,
        workers: usize,
        dtype: Dtype,
        simd: SimdMode,
    ) -> Result<Engine> {
        let mut opts = EngineOptions {
            workers,
            dtype,
            simd,
            ..Default::default()
        };
        match name {
            "xla" | "xla-pallas" => Engine::xla(opts),
            "xla-jnp" => {
                opts.imp = Impl::Jnp;
                Engine::xla(opts)
            }
            "rust" => Ok(Engine::rust_with(opts)),
            other => Err(anyhow!("unknown engine {other:?} (xla, xla-jnp, rust)")),
        }
    }

    pub fn name(&self) -> String {
        match self {
            Engine::Rust { .. } => "rust".into(),
            #[cfg(feature = "xla")]
            Engine::Xla { opts, .. } => format!("xla/{}", opts.imp.name()),
        }
    }

    pub fn opts(&self) -> &EngineOptions {
        match self {
            Engine::Rust { opts, .. } => opts,
            #[cfg(feature = "xla")]
            Engine::Xla { opts, .. } => opts,
        }
    }

    /// The engine's shared worker pool, for callers fanning their own
    /// panel reductions (e.g. the leverage-score SYRK accumulation).
    /// `None` on serial engines and on the XLA engine (which keeps its
    /// parallelism inside the runtime).
    pub(crate) fn pool(&self) -> Option<&WorkerPool> {
        match self {
            Engine::Rust { pool, .. } => pool.as_deref(),
            #[cfg(feature = "xla")]
            Engine::Xla { .. } => None,
        }
    }

    pub fn registry(&self) -> Option<&Registry> {
        match self {
            Engine::Rust { .. } => None,
            #[cfg(feature = "xla")]
            Engine::Xla { registry, .. } => Some(registry),
        }
    }

    /// Artifact spec + compiled executable for a request.
    #[cfg(feature = "xla")]
    fn compiled(
        &self,
        op: Op,
        kern: Kernel,
        m: usize,
        d: usize,
        n: usize,
    ) -> Result<(Rc<Exe>, usize, usize)> {
        let (registry, cache, opts) = match self {
            Engine::Xla {
                registry,
                cache,
                opts,
            } => (registry, cache, opts),
            Engine::Rust { .. } => unreachable!("compiled() on rust engine"),
        };
        let spec = match op {
            Op::Precond => registry.find_precond(m)?,
            // kmm artifacts exist only as jnp lowering
            Op::Kmm => registry.find(op, kern, Impl::Jnp, m, d, n)?,
            _ => registry.find(op, kern, opts.imp, m, d, n)?,
        };
        let key = spec.file.clone();
        if let Some(e) = cache.borrow().get(&key) {
            return Ok((e.clone(), spec.b, spec.d));
        }
        let exe = Rc::new(Exe::compile_file(&registry.path_of(spec), spec.name())?);
        cache.borrow_mut().insert(key, exe.clone());
        Ok((exe, spec.b, spec.d))
    }

    /// Padded-center f32 literal for an artifact with feature dim `d_art`,
    /// cached per (centers, artifact shape). The fit/serve paths call
    /// `kmm`, `matvec_plan` and `predict` repeatedly with the *same*
    /// centers, so the O(M·D) pad + f32 conversion + literal upload
    /// happens once instead of per call.
    #[cfg(feature = "xla")]
    fn center_literal(&self, c: &Mat, d_art: usize) -> Result<Rc<xla::Literal>> {
        // cap on distinct (centers, artifact) literals held at once — a
        // fit/serve session touches a handful; a tuning sweep over many
        // center sets must not accumulate O(M·D) literals unboundedly
        const CENTER_CACHE_CAP: usize = 8;
        let center_cache = match self {
            Engine::Xla { center_cache, .. } => center_cache,
            Engine::Rust { .. } => unreachable!("center_literal() on rust engine"),
        };
        let key = (mat_fingerprint(c), c.rows, c.cols, d_art);
        if let Some(lit) = center_cache.borrow().get(&key) {
            return Ok(lit.clone());
        }
        let c_pad = c.pad_cols(d_art);
        let lit = Rc::new(literal_from_f32(&c_pad.to_f32(), &[c.rows, d_art])?);
        let mut cache = center_cache.borrow_mut();
        if cache.len() >= CENTER_CACHE_CAP {
            cache.clear();
        }
        cache.insert(key, lit.clone());
        Ok(lit)
    }

    // ------------------------------------------------------------------
    // K_MM and the preconditioner
    // ------------------------------------------------------------------

    /// K_MM over the centers (tiled + symmetric on the Rust path, row
    /// blocks fanned out over the shared pool).
    pub fn kmm(&self, kern: Kernel, c: &Mat, param: f64) -> Result<Mat> {
        match self {
            Engine::Rust { pool, isa, .. } => {
                Ok(kernels::kmm_par(kern, c, param, pool.as_deref(), *isa))
            }
            #[cfg(feature = "xla")]
            Engine::Xla { .. } => {
                let m = c.rows;
                let (exe, _, d_art) = self.compiled(Op::Kmm, kern, m, c.cols, m)?;
                let c_lit = self.center_literal(c, d_art)?;
                let p_lit = literal_scalar(param as f32);
                let out = exe.call1_f32(&[c_lit.as_ref(), &p_lit])?;
                Ok(Mat::from_f32(m, m, &out))
            }
        }
    }

    /// Preconditioner factors (Eq. 13): upper-triangular (T, A) with
    /// TᵀT = K_MM + eps·M·I and AᵀA = TTᵀ/M + λI.
    ///
    /// The XLA path runs in f32; if the factorization comes back
    /// non-finite (ill-conditioned K_MM at f32), we escalate the jitter
    /// and finally fall back to the f64 Rust factorization — a fit must
    /// not die on a borderline K_MM.
    pub fn precond(&self, kmm: &Mat, lam: f64, eps: f64) -> Result<(Mat, Mat)> {
        self.precond_traced(kmm, lam, eps).map(|(t, a, _)| (t, a))
    }

    /// [`Engine::precond`] plus the number of jitter escalations the
    /// factorization needed (0 = clean first try) — the degradation
    /// ladder's observability hook
    /// ([`crate::falkon::estimator::setup_precond`] records nonzero rungs
    /// in the fit report).
    pub fn precond_traced(&self, kmm: &Mat, lam: f64, eps: f64) -> Result<(Mat, Mat, usize)> {
        match self {
            Engine::Rust { pool, .. } => precond_rust(kmm, lam, eps, pool.as_deref()),
            #[cfg(feature = "xla")]
            Engine::Xla { .. } => {
                let m = kmm.rows;
                let (exe, _, _) = self.compiled(Op::Precond, Kernel::Gaussian, m, 0, m)?;
                let kmm_lit = literal_from_f32(&kmm.to_f32(), &[m, m])?;
                let lam_lit = literal_scalar(lam as f32);
                let mut eps_try = eps;
                for rung in 0..3 {
                    let eps_lit = literal_scalar(eps_try as f32);
                    let outs = exe.call(&[&kmm_lit, &lam_lit, &eps_lit])?;
                    anyhow::ensure!(outs.len() == 2, "precond returned {} outputs", outs.len());
                    let t = Mat::from_f32(m, m, &literal_to_f32(&outs[0])?);
                    let a = Mat::from_f32(m, m, &literal_to_f32(&outs[1])?);
                    if t.is_finite() && a.is_finite() {
                        return Ok((t, a, rung));
                    }
                    eps_try *= 100.0;
                }
                // last resort: f64 factorization on the coordinator
                let (t, a, rungs) = precond_rust(kmm, lam, eps, None)?;
                Ok((t, a, 3 + rungs))
            }
        }
    }

    // ------------------------------------------------------------------
    // the blocked Nyström matvec (CG hot path)
    // ------------------------------------------------------------------

    /// Build the per-fit plan. Rust: rows sliced into blocks with their
    /// squared norms precomputed, worker pool spawned. XLA: blocks padded,
    /// masked and uploaded once.
    pub fn matvec_plan(&self, kern: Kernel, x: &Mat, c: &Mat, param: f64) -> Result<MatvecPlan> {
        anyhow::ensure!(x.cols == c.cols, "x/c feature dims differ");
        match self {
            Engine::Rust { opts, pool, isa } => Ok(MatvecPlan::Rust(RustPlan::build(
                kern,
                x,
                c,
                param,
                opts.dtype,
                pool.clone(),
                *isa,
            )?)),
            #[cfg(feature = "xla")]
            Engine::Xla { opts, .. } => {
                let (n, m) = (x.rows, c.rows);
                let (exe, b_art, d_art) = self.compiled(Op::KnmMatvec, kern, m, x.cols, n)?;
                let c_lit = self.center_literal(c, d_art)?;
                let param_lit = literal_scalar(param as f32);
                let zeros_v = literal_from_f32(&vec![0.0; b_art], &[b_art])?;
                let mut blocks = Vec::new();
                let mut start = 0;
                while start < n {
                    let rows = (n - start).min(b_art);
                    let mut xbuf = vec![0.0f32; b_art * d_art];
                    for i in 0..rows {
                        for (j, &v) in x.row(start + i).iter().enumerate() {
                            xbuf[i * d_art + j] = v as f32;
                        }
                    }
                    let mut mask = vec![0.0f32; b_art];
                    mask[..rows].fill(1.0);
                    blocks.push(XlaBlock {
                        x: literal_from_f32(&xbuf, &[b_art, d_art])?,
                        mask: literal_from_f32(&mask, &[b_art])?,
                        start,
                        rows,
                    });
                    start += rows;
                }
                let _ = opts;
                Ok(MatvecPlan::Xla(XlaPlan {
                    exe,
                    c_lit,
                    param_lit,
                    zeros_v,
                    blocks,
                    b_art,
                    n,
                    m,
                    scratch: RefCell::new(XlaScratch {
                        u32v: Vec::new(),
                        vbuf: vec![0.0f32; b_art],
                    }),
                }))
            }
        }
    }

    /// Build an **out-of-core** plan over a chunked [`DataSource`]: no
    /// row blocks are retained — every apply re-streams the source and
    /// accumulates per-chunk partial products, so only the centers
    /// (`M×d`), one chunk, and O(M) vectors are resident
    /// (DESIGN.md § "Out-of-core path"). `n` is the exact row count
    /// (known from the source's open scan or the setup pass).
    ///
    /// The sweep runs the coordinator's f64 tiled kernels on both
    /// engines; the Rust engine additionally fans each resident chunk
    /// out over its shared worker pool.
    pub fn matvec_plan_source(
        &self,
        kern: Kernel,
        source: Box<dyn DataSource>,
        c: &Mat,
        param: f64,
        n: usize,
    ) -> Result<MatvecPlan> {
        anyhow::ensure!(source.d() == c.cols, "source/c feature dims differ");
        if let Some(hint) = source.len_hint() {
            anyhow::ensure!(hint == n, "source len_hint {hint} != n {n}");
        }
        let (pool, isa) = match self {
            Engine::Rust { pool, isa, .. } => (pool.clone(), *isa),
            // the XLA engine's streaming sweeps run the coordinator's
            // Rust tiled kernels too — resolve its arm the same way
            #[cfg(feature = "xla")]
            Engine::Xla { opts, .. } => (None, resolve_engine_simd(opts.simd)),
        };
        let m = c.rows;
        let chunk_rows = source.chunk_rows();
        Ok(MatvecPlan::Stream(StreamPlan {
            kern,
            param,
            isa,
            centers: CenterSet::build(c),
            source: RefCell::new(source),
            scratch: RefCell::new(kernels::TileScratch::new(kernels::DEFAULT_TILE, m)),
            pool,
            n,
            m,
            chunks_seen: Cell::new(n.div_ceil(chunk_rows.max(1))),
            max_chunk_bytes: Cell::new(0),
            retry: self.opts().retry,
        }))
    }

    /// Streaming prediction: sweep a [`DataSource`] once, predicting each
    /// resident chunk with the blocked predict path, so serving a
    /// larger-than-RAM dataset needs O(chunk) feature memory.
    pub fn predict_source(
        &self,
        kern: Kernel,
        source: &mut dyn DataSource,
        c: &Mat,
        alpha: &[f64],
        param: f64,
    ) -> Result<Vec<f64>> {
        anyhow::ensure!(source.d() == c.cols, "source/c feature dims differ");
        let retry = self.opts().retry;
        retry.run("streaming predict: reset", || source.reset())?;
        let mut preds = match source.len_hint() {
            Some(n) => Vec::with_capacity(n),
            None => Vec::new(),
        };
        while let Some(chunk) = retry.run("predict: next_chunk", || source.next_chunk())? {
            anyhow::ensure!(chunk.start == preds.len(), "source chunks must be contiguous");
            let p = self.predict_block(kern, &chunk.x, c, alpha, param)?;
            preds.extend_from_slice(&p);
        }
        Ok(preds)
    }

    // ------------------------------------------------------------------
    // kernel blocks and prediction
    // ------------------------------------------------------------------

    /// Dense K(x, c) — used by the leverage-score sketch. Blocked on the
    /// XLA path through the kernel_block artifact.
    pub fn kernel_block(&self, kern: Kernel, x: &Mat, c: &Mat, param: f64) -> Result<Mat> {
        match self {
            Engine::Rust { pool, isa, .. } => Ok(kernels::kernel_block_par(
                kern,
                x,
                c,
                param,
                pool.as_deref(),
                *isa,
            )),
            #[cfg(feature = "xla")]
            Engine::Xla { .. } => {
                let mut out = Mat::zeros(x.rows, c.rows);
                self.for_kernel_blocks(kern, x, c, param, |start, rows, m, kr| {
                    for i in 0..rows {
                        for j in 0..m {
                            out[(start + i, j)] = kr[i * m + j] as f64;
                        }
                    }
                })?;
                Ok(out)
            }
        }
    }

    /// Blocked prediction f(x_i) = Σ_j α_j K(x_i, c_j).
    pub fn predict(
        &self,
        kern: Kernel,
        x: &Mat,
        c: &Mat,
        alpha: &[f64],
        param: f64,
    ) -> Result<Vec<f64>> {
        anyhow::ensure!(alpha.len() == c.rows, "alpha length");
        anyhow::ensure!(x.cols == c.cols, "x/c feature dims differ");
        match self {
            Engine::Rust { pool, isa, .. } => Ok(kernels::predict_blocked_pool(
                kern,
                x,
                c,
                alpha,
                param,
                pool.as_deref(),
                *isa,
            )),
            #[cfg(feature = "xla")]
            Engine::Xla { .. } => {
                let mut preds = vec![0.0f64; x.rows];
                self.for_kernel_blocks(kern, x, c, param, |start, rows, m, kr| {
                    for i in 0..rows {
                        let mut acc = 0.0;
                        for j in 0..m {
                            acc += kr[i * m + j] as f64 * alpha[j];
                        }
                        preds[start + i] = acc;
                    }
                })?;
                Ok(preds)
            }
        }
    }

    /// [`Engine::predict`] for a feature block in either storage format —
    /// the streaming-predict / serving dispatch point. f64 blocks take the
    /// usual path; f32 blocks run the mixed-precision blocked predict
    /// ([`kernels::mixed::predict_blocked_pool_f32`]) against a
    /// once-rounded f32 copy of the centers (O(M·d) per call, negligible
    /// next to the O(rows·M·d) panel work). On the XLA engine an f32
    /// block is widened and served by the artifact path (which stages f32
    /// internally anyway).
    pub fn predict_block(
        &self,
        kern: Kernel,
        x: &XBlock,
        c: &Mat,
        alpha: &[f64],
        param: f64,
    ) -> Result<Vec<f64>> {
        match x {
            XBlock::F64(xm) => self.predict(kern, xm, c, alpha, param),
            XBlock::F32(xm) => {
                anyhow::ensure!(alpha.len() == c.rows, "alpha length");
                anyhow::ensure!(xm.cols == c.cols, "x/c feature dims differ");
                match self {
                    Engine::Rust { pool, isa, .. } => {
                        let c32 = MatF32::from_mat(c);
                        Ok(kernels::mixed::predict_blocked_pool_f32(
                            kern,
                            xm,
                            &c32,
                            alpha,
                            param,
                            pool.as_deref(),
                            *isa,
                        ))
                    }
                    #[cfg(feature = "xla")]
                    Engine::Xla { .. } => self.predict(kern, &xm.to_mat(), c, alpha, param),
                }
            }
        }
    }

    /// Multi-output prediction F = Kr·A for an `M×K` coefficient block
    /// (column k = class k's α) — the multiclass serving path. Each
    /// kernel panel/block is computed once and serves all K classes on
    /// *both* engines (the XLA path streams its kernel_block artifact
    /// outputs through the K columns).
    pub fn predict_multi(
        &self,
        kern: Kernel,
        x: &Mat,
        c: &Mat,
        alphas: &Mat,
        param: f64,
    ) -> Result<Mat> {
        anyhow::ensure!(alphas.rows == c.rows, "alphas rows != centers");
        anyhow::ensure!(x.cols == c.cols, "x/c feature dims differ");
        match self {
            Engine::Rust { pool, isa, .. } => Ok(kernels::predict_multi_blocked_pool(
                kern,
                x,
                c,
                alphas,
                param,
                pool.as_deref(),
                *isa,
            )),
            #[cfg(feature = "xla")]
            Engine::Xla { .. } => {
                let k = alphas.cols;
                let mut preds = Mat::zeros(x.rows, k);
                self.for_kernel_blocks(kern, x, c, param, |start, rows, m, kr| {
                    for i in 0..rows {
                        let orow = preds.row_mut(start + i);
                        for j in 0..m {
                            let kv = kr[i * m + j] as f64;
                            for (o, &a) in orow.iter_mut().zip(alphas.row(j)) {
                                *o += kv * a;
                            }
                        }
                    }
                })?;
                Ok(preds)
            }
        }
    }

    /// [`Engine::predict_multi`] for a feature block in either storage
    /// format. An f32 block is widened (exact) and served by the f64
    /// panel-amortized path: multiclass serving is bound by the K-column
    /// fan-out, so a dedicated f32 matmat-predict tier is not worth its
    /// surface — the storage rounding already happened at chunk emission.
    pub fn predict_multi_block(
        &self,
        kern: Kernel,
        x: &XBlock,
        c: &Mat,
        alphas: &Mat,
        param: f64,
    ) -> Result<Mat> {
        match x {
            XBlock::F64(xm) => self.predict_multi(kern, xm, c, alphas, param),
            XBlock::F32(xm) => self.predict_multi(kern, &xm.to_mat(), c, alphas, param),
        }
    }

    /// Shared streaming loop over kernel_block artifact calls.
    #[cfg(feature = "xla")]
    fn for_kernel_blocks(
        &self,
        kern: Kernel,
        x: &Mat,
        c: &Mat,
        param: f64,
        mut sink: impl FnMut(usize, usize, usize, &[f32]),
    ) -> Result<()> {
        let (n, m) = (x.rows, c.rows);
        let (exe, b_art, d_art) = self.compiled(Op::KernelBlock, kern, m, x.cols, n)?;
        let c_lit = self.center_literal(c, d_art)?;
        let p_lit = literal_scalar(param as f32);
        let mut start = 0;
        let mut xbuf = vec![0.0f32; b_art * d_art];
        while start < n {
            let rows = (n - start).min(b_art);
            xbuf.fill(0.0);
            for i in 0..rows {
                for (j, &v) in x.row(start + i).iter().enumerate() {
                    xbuf[i * d_art + j] = v as f32;
                }
            }
            let x_lit = literal_from_f32(&xbuf, &[b_art, d_art])?;
            let kr = exe.call1_f32(&[&x_lit, c_lit.as_ref(), &p_lit])?;
            sink(start, rows, m, &kr);
            start += rows;
        }
        Ok(())
    }
}

/// FNV-1a over the matrix's f64 bit patterns — the cache key for
/// per-(centers, artifact) literals. Collisions would need two different
/// center sets with identical shape *and* a 64-bit hash collision inside
/// one engine's lifetime; the key also carries (rows, cols) so only
/// same-shape matrices can ever collide.
#[cfg(feature = "xla")]
fn mat_fingerprint(m: &Mat) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &v in &m.data {
        h ^= v.to_bits();
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Resolve an engine's panel ISA: an explicit [`SimdMode`] on the
/// options wins, `Auto` defers to `FALKON_SIMD`, and the result is
/// feature-checked (a forced-but-unavailable arm degrades loudly to
/// scalar). Logged once per process so CI logs and bench JSONs record
/// which arm actually ran.
fn resolve_engine_simd(mode: SimdMode) -> Isa {
    kernels::simd::resolve_logged(match mode {
        SimdMode::Auto => SimdMode::from_env(),
        explicit => explicit,
    })
}

/// f64 preconditioner factorization with jitter escalation. The O(M³)
/// pieces — both Cholesky factors and the T·Tᵀ SYRK — run blocked, with
/// trailing updates and output panels fanned out over the shared pool
/// (DESIGN.md §Perf "Setup path"). The third tuple element is the jitter
/// rung that succeeded (0 = first try), surfaced through
/// [`Engine::precond_traced`] so the degradation ladder can record it.
fn precond_rust(
    kmm: &Mat,
    lam: f64,
    eps: f64,
    pool: Option<&WorkerPool>,
) -> Result<(Mat, Mat, usize)> {
    let m = kmm.rows;
    let mut eps_try = eps;
    for rung in 0..6 {
        let mut kj = kmm.clone();
        kj.add_diag(eps_try * m as f64);
        if let Ok(t) = chol::cholesky_upper_blocked(&kj, chol::CHOL_BLOCK, pool) {
            // A: chol(T Tᵀ / M + lam I)
            let mut tta = gemm::syrk_t_par(&t, pool);
            tta.scale(1.0 / m as f64);
            tta.add_diag(lam);
            if let Ok(a) = chol::cholesky_upper_blocked(&tta, chol::CHOL_BLOCK, pool) {
                return Ok((t, a, rung));
            }
        }
        eps_try *= 100.0;
    }
    Err(anyhow!(
        "preconditioner factorization failed for M={m} even with jitter"
    ))
}

// ---------------------------------------------------------------------
// plans
// ---------------------------------------------------------------------

#[cfg(feature = "xla")]
struct XlaBlock {
    x: xla::Literal,
    mask: xla::Literal,
    start: usize,
    rows: usize,
}

/// Staging buffers reused across applies (the seed reallocated them on
/// every CG iteration).
#[cfg(feature = "xla")]
struct XlaScratch {
    u32v: Vec<f32>,
    vbuf: Vec<f32>,
}

#[cfg(feature = "xla")]
pub struct XlaPlan {
    exe: Rc<Exe>,
    /// shared with the engine's per-(centers, artifact) literal cache
    c_lit: Rc<xla::Literal>,
    param_lit: xla::Literal,
    zeros_v: xla::Literal,
    blocks: Vec<XlaBlock>,
    b_art: usize,
    n: usize,
    m: usize,
    scratch: RefCell<XlaScratch>,
}

/// One Rust-engine row block, sliced and norm-precomputed at plan build.
struct RustBlock {
    /// owned copy of rows [start, start + rows) of the dataset, in the
    /// plan's storage format (f32 blocks were rounded once at build)
    x: XBlock,
    /// squared row norms of `x`, accumulated in f64 from the *stored*
    /// values (read by the Gaussian panel)
    xn: Vec<f64>,
    start: usize,
}

/// Both storage tiers of a plan's centers with their squared row norms,
/// so per-block/per-chunk dtype dispatch picks the matching tier without
/// re-deriving anything. The f32 copy is M×d — negligible next to the row
/// blocks — and its norms are recomputed from the *rounded* values, as
/// the mixed-precision kernels require (a norm from unrounded centers
/// would reintroduce an O(eps32) argument error the tolerance model does
/// not budget for).
struct CenterSet {
    c: Mat,
    cn: Vec<f64>,
    c32: MatF32,
    cn32: Vec<f64>,
}

impl CenterSet {
    fn build(c: &Mat) -> CenterSet {
        let c32 = MatF32::from_mat(c);
        CenterSet {
            cn: kernels::row_sq_norms(c),
            cn32: kernels::mixed::row_sq_norms_f32(&c32),
            c: c.clone(),
            c32,
        }
    }
}

/// Squared row norms of a block in either storage format (f64
/// accumulation on both tiers).
fn block_sq_norms(x: &XBlock) -> Vec<f64> {
    match x {
        XBlock::F64(m) => kernels::row_sq_norms(m),
        XBlock::F32(m) => kernels::mixed::row_sq_norms_f32(m),
    }
}

/// Fused `w += Krᵀ(Kr·u + v)` over rows `[start, end)` of a block in
/// either storage format — the single dtype-dispatch point of every
/// matvec apply path (inline, pooled, in-memory, streaming). Both arms
/// read the matching center tier of `cs`; `(0, rows)` reproduces the
/// blocked sweep bitwise (the blocked entry points delegate to the ranged
/// ones).
#[allow(clippy::too_many_arguments)]
fn matvec_ranged_any(
    kern: Kernel,
    x: &XBlock,
    cs: &CenterSet,
    xn: &[f64],
    u: &[f64],
    v: Option<&[f64]>,
    param: f64,
    scratch: &mut kernels::TileScratch,
    w: &mut [f64],
    start: usize,
    end: usize,
    isa: Isa,
) {
    match x {
        XBlock::F64(xm) => kernels::knm_matvec_ranged(
            kern, xm, &cs.c, xn, &cs.cn, u, v, None, param, scratch, w, start, end, isa,
        ),
        XBlock::F32(xm) => kernels::mixed::knm_matvec_ranged_f32(
            kern, xm, &cs.c32, xn, &cs.cn32, u, v, None, param, scratch, w, start, end, isa,
        ),
    }
}

/// Multi-RHS sibling of [`matvec_ranged_any`]:
/// `W += Krᵀ(Kr·U + V_block)` with per-block dtype dispatch.
#[allow(clippy::too_many_arguments)]
fn matmat_ranged_any(
    kern: Kernel,
    x: &XBlock,
    cs: &CenterSet,
    xn: &[f64],
    u: &Mat,
    v: Option<&[f64]>,
    param: f64,
    scratch: &mut kernels::TileScratch,
    w: &mut Mat,
    start: usize,
    end: usize,
    isa: Isa,
) {
    match x {
        XBlock::F64(xm) => kernels::knm_matmat_ranged(
            kern, xm, &cs.c, xn, &cs.cn, u, v, None, param, scratch, w, start, end, isa,
        ),
        XBlock::F32(xm) => kernels::mixed::knm_matmat_ranged_f32(
            kern, xm, &cs.c32, xn, &cs.cn32, u, v, None, param, scratch, w, start, end, isa,
        ),
    }
}

thread_local! {
    /// Per-thread tile scratch for pooled applies: a pool worker allocates
    /// its Kr buffer on the first job it runs and reuses it across every
    /// block, apply, CG iteration, and plan served by its engine
    /// ([`kernels::TileScratch::ensure`] grows it if a later plan has a
    /// larger M).
    static POOL_SCRATCH: RefCell<Option<kernels::TileScratch>> = const { RefCell::new(None) };
}

pub struct RustPlan {
    kern: Kernel,
    param: f64,
    centers: CenterSet,
    blocks: Vec<RustBlock>,
    /// scratch for the inline (single-worker) path
    scratch: RefCell<kernels::TileScratch>,
    /// shared engine pool (None = inline applies)
    pool: Option<Arc<WorkerPool>>,
    /// panel ISA inherited from the engine at build — every apply (inline
    /// or pooled) runs this one arm, preserving pooled-vs-serial bitwise
    /// determinism
    isa: Isa,
    n: usize,
    m: usize,
}

impl RustPlan {
    fn build(
        kern: Kernel,
        x: &Mat,
        c: &Mat,
        param: f64,
        dtype: Dtype,
        pool: Option<Arc<WorkerPool>>,
        isa: Isa,
    ) -> Result<RustPlan> {
        let (n, m) = (x.rows, c.rows);
        let mut blocks = Vec::with_capacity(n.div_ceil(ROW_BLOCK.max(1)));
        let mut start = 0;
        while start < n {
            let end = (start + ROW_BLOCK).min(n);
            // round once at build (when dtype = F32), then derive the
            // norms from the stored values
            let xb = XBlock::from_mat_dtype(x.slice_rows(start, end), dtype);
            let xn = block_sq_norms(&xb);
            blocks.push(RustBlock { x: xb, xn, start });
            start = end;
        }
        Ok(RustPlan {
            kern,
            param,
            centers: CenterSet::build(c),
            blocks,
            scratch: RefCell::new(kernels::TileScratch::new(kernels::DEFAULT_TILE, m)),
            pool,
            isa,
            n,
            m,
        })
    }

    fn apply(&self, u: &[f64], v: Option<&[f64]>) -> Result<Vec<f64>> {
        anyhow::ensure!(u.len() == self.m, "u length {} != M {}", u.len(), self.m);
        if let Some(v) = v {
            anyhow::ensure!(v.len() == self.n, "v length {} != n {}", v.len(), self.n);
        }
        let mut w = vec![0.0f64; self.m];
        let nb = self.blocks.len();
        if nb == 0 {
            return Ok(w);
        }
        match self.pool.as_deref() {
            None => {
                let mut scratch = self.scratch.borrow_mut();
                apply_blocks(
                    self.kern,
                    &self.centers,
                    &self.blocks,
                    u,
                    v,
                    self.param,
                    &mut scratch,
                    &mut w,
                    self.isa,
                );
            }
            Some(pool) => {
                // one partial-w per job, written by exactly one task each
                // and summed in job order so pooled applies are bitwise
                // deterministic (the tasks capture only Sync plan fields,
                // not the plan itself — its inline scratch is a RefCell)
                let ranges = chunk_ranges(nb, pool.workers());
                let mut parts: Vec<Vec<f64>> = vec![vec![0.0f64; self.m]; ranges.len()];
                let tile = kernels::DEFAULT_TILE;
                let m = self.m;
                let (kern, param, isa) = (self.kern, self.param, self.isa);
                let (cs, blocks) = (&self.centers, self.blocks.as_slice());
                let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = ranges
                    .iter()
                    .zip(parts.iter_mut())
                    .map(|(&(lo, hi), part)| {
                        let f: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                            POOL_SCRATCH.with(|cell| {
                                let mut cell = cell.borrow_mut();
                                let scratch = cell
                                    .get_or_insert_with(|| kernels::TileScratch::new(tile, m));
                                apply_blocks(
                                    kern,
                                    cs,
                                    &blocks[lo..hi],
                                    u,
                                    v,
                                    param,
                                    scratch,
                                    part,
                                    isa,
                                );
                            });
                        });
                        f
                    })
                    .collect();
                pool.run_scoped(tasks);
                for part in parts {
                    for j in 0..self.m {
                        w[j] += part[j];
                    }
                }
            }
        }
        Ok(w)
    }

    /// Multi-RHS apply: `W = Σ_blocks Krᵀ(Kr·U + V)` for an `M×K`
    /// coefficient block — each row block's Kr panels are computed once
    /// and serve all K columns (DESIGN.md §Perf "Multi-RHS path"). Same
    /// pooled fan-out and job-order partial reduction as [`Self::apply`],
    /// with each worker's thread-local scratch grown to the plan's K.
    fn apply_multi(&self, u: &Mat, v: Option<&Mat>) -> Result<Mat> {
        let k = u.cols;
        anyhow::ensure!(u.rows == self.m, "u rows {} != M {}", u.rows, self.m);
        if let Some(v) = v {
            anyhow::ensure!(v.rows == self.n, "v rows {} != n {}", v.rows, self.n);
            anyhow::ensure!(v.cols == k, "v cols {} != u cols {}", v.cols, k);
        }
        let mut w = Mat::zeros(self.m, k);
        let nb = self.blocks.len();
        if nb == 0 || k == 0 {
            return Ok(w);
        }
        match self.pool.as_deref() {
            None => {
                let mut scratch = self.scratch.borrow_mut();
                apply_blocks_multi(
                    self.kern,
                    &self.centers,
                    &self.blocks,
                    u,
                    v,
                    self.param,
                    &mut scratch,
                    &mut w,
                    self.isa,
                );
            }
            Some(pool) => {
                let ranges = chunk_ranges(nb, pool.workers());
                let mut parts: Vec<Mat> = vec![Mat::zeros(self.m, k); ranges.len()];
                let tile = kernels::DEFAULT_TILE;
                let m = self.m;
                let (kern, param, isa) = (self.kern, self.param, self.isa);
                let (cs, blocks) = (&self.centers, self.blocks.as_slice());
                let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = ranges
                    .iter()
                    .zip(parts.iter_mut())
                    .map(|(&(lo, hi), part)| {
                        let f: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                            POOL_SCRATCH.with(|cell| {
                                let mut cell = cell.borrow_mut();
                                let scratch = cell
                                    .get_or_insert_with(|| kernels::TileScratch::new(tile, m));
                                apply_blocks_multi(
                                    kern,
                                    cs,
                                    &blocks[lo..hi],
                                    u,
                                    v,
                                    param,
                                    scratch,
                                    part,
                                    isa,
                                );
                            });
                        });
                        f
                    })
                    .collect();
                pool.run_scoped(tasks);
                for part in parts {
                    w.add(&part);
                }
            }
        }
        Ok(w)
    }
}

/// The out-of-core plan: instead of retaining sliced row blocks like
/// [`RustPlan`], every apply **re-streams** a chunked [`DataSource`] and
/// accumulates per-chunk partial products, so the working set is
/// O(M² + chunk) — one resident chunk, the centers, and the M-vectors —
/// regardless of n. With a worker pool, each resident chunk's rows fan
/// out over disjoint ranges (no per-worker copies; see
/// [`kernels::knm_matvec_ranged`]) and the per-job partials are summed
/// in job order, so repeated pooled applies are bitwise deterministic.
/// Serial applies are bitwise-equal to the in-memory plan's: both
/// accumulate per-row contributions in global row order.
pub struct StreamPlan {
    kern: Kernel,
    param: f64,
    /// panel ISA inherited from the engine at build (see [`RustPlan`])
    isa: Isa,
    /// both center tiers — the source may yield f64 *or* f32 chunks (even
    /// mixed across one sweep), and each resident chunk dispatches to the
    /// kernels matching its own storage
    centers: CenterSet,
    /// the rewindable chunk stream; `RefCell` because applies take `&self`
    source: RefCell<Box<dyn DataSource>>,
    /// scratch for the inline (single-worker) path
    scratch: RefCell<kernels::TileScratch>,
    /// shared engine pool (None = inline applies)
    pool: Option<Arc<WorkerPool>>,
    n: usize,
    m: usize,
    /// chunks served by the last sweep (estimate before the first)
    chunks_seen: Cell<usize>,
    /// peak resident chunk bytes across all sweeps — the out-of-core
    /// bench's peak-RSS proxy
    max_chunk_bytes: Cell<usize>,
    /// bounded retry for transient source errors (every CG iteration is
    /// one sweep; inherited from [`EngineOptions::retry`])
    retry: crate::util::fault::RetryPolicy,
}

impl StreamPlan {
    /// Largest resident chunk (feature bytes) any sweep has held.
    pub fn max_resident_bytes(&self) -> usize {
        self.max_chunk_bytes.get()
    }

    /// Run one full sweep over the source, handing each resident chunk
    /// (with its row norms and global start row) to `per_chunk`.
    fn sweep(
        &self,
        mut per_chunk: impl FnMut(&crate::data::Chunk, &[f64]) -> Result<()>,
    ) -> Result<()> {
        let mut src = self.source.borrow_mut();
        self.retry.run("streaming sweep: reset", || src.reset())?;
        let mut seen = 0usize;
        let mut chunks = 0usize;
        while let Some(chunk) = self.retry.run("stream sweep: next_chunk", || src.next_chunk())? {
            anyhow::ensure!(chunk.start == seen, "source chunks must be contiguous");
            seen += chunk.x.rows();
            anyhow::ensure!(seen <= self.n, "source yielded more rows than n = {}", self.n);
            self.max_chunk_bytes.set(self.max_chunk_bytes.get().max(chunk.x_bytes()));
            let xn = block_sq_norms(&chunk.x);
            per_chunk(&chunk, &xn)?;
            chunks += 1;
        }
        anyhow::ensure!(seen == self.n, "source yielded {seen} rows, plan expects {}", self.n);
        self.chunks_seen.set(chunks);
        Ok(())
    }

    fn apply(&self, u: &[f64], v: Option<&[f64]>) -> Result<Vec<f64>> {
        anyhow::ensure!(u.len() == self.m, "u length {} != M {}", u.len(), self.m);
        if let Some(v) = v {
            anyhow::ensure!(v.len() == self.n, "v length {} != n {}", v.len(), self.n);
        }
        let mut w = vec![0.0f64; self.m];
        let tile = kernels::DEFAULT_TILE;
        let m = self.m;
        let (kern, param, isa) = (self.kern, self.param, self.isa);
        let cs = &self.centers;
        self.sweep(|chunk, xn| {
            let rows = chunk.x.rows();
            let vb = v.map(|vf| &vf[chunk.start..chunk.start + rows]);
            match self.pool.as_deref() {
                None => {
                    let mut scratch = self.scratch.borrow_mut();
                    matvec_ranged_any(
                        kern, &chunk.x, cs, xn, u, vb, param, &mut scratch, &mut w, 0, rows, isa,
                    );
                }
                Some(pool) => {
                    // disjoint row ranges of the one resident chunk, one
                    // partial-w per job, summed in job order (bitwise
                    // deterministic; same reduction as RustPlan::apply)
                    let workers = pool.workers().min(rows.div_ceil(tile).max(1));
                    let ranges = chunk_ranges(rows, workers);
                    let mut parts: Vec<Vec<f64>> = vec![vec![0.0f64; m]; ranges.len()];
                    let x = &chunk.x;
                    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = ranges
                        .iter()
                        .zip(parts.iter_mut())
                        .map(|(&(lo, hi), part)| {
                            let f: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                                POOL_SCRATCH.with(|cell| {
                                    let mut cell = cell.borrow_mut();
                                    let scratch = cell
                                        .get_or_insert_with(|| kernels::TileScratch::new(tile, m));
                                    matvec_ranged_any(
                                        kern, x, cs, xn, u, vb, param, scratch, part, lo, hi, isa,
                                    );
                                });
                            });
                            f
                        })
                        .collect();
                    pool.run_scoped(tasks);
                    for part in parts {
                        for j in 0..m {
                            w[j] += part[j];
                        }
                    }
                }
            }
            Ok(())
        })?;
        Ok(w)
    }

    /// Multi-RHS streaming apply — same chunk lifecycle as
    /// [`StreamPlan::apply`], with each resident chunk's Kr panels
    /// serving all K columns ([`kernels::knm_matmat_ranged`]).
    fn apply_multi(&self, u: &Mat, v: Option<&Mat>) -> Result<Mat> {
        let k = u.cols;
        anyhow::ensure!(u.rows == self.m, "u rows {} != M {}", u.rows, self.m);
        if let Some(v) = v {
            anyhow::ensure!(v.rows == self.n, "v rows {} != n {}", v.rows, self.n);
            anyhow::ensure!(v.cols == k, "v cols {} != u cols {}", v.cols, k);
        }
        let mut w = Mat::zeros(self.m, k);
        if k == 0 {
            return Ok(w);
        }
        let tile = kernels::DEFAULT_TILE;
        let m = self.m;
        let (kern, param, isa) = (self.kern, self.param, self.isa);
        let cs = &self.centers;
        self.sweep(|chunk, xn| {
            let rows = chunk.x.rows();
            let vb = v.map(|vf| &vf.data[chunk.start * k..(chunk.start + rows) * k]);
            match self.pool.as_deref() {
                None => {
                    let mut scratch = self.scratch.borrow_mut();
                    matmat_ranged_any(
                        kern, &chunk.x, cs, xn, u, vb, param, &mut scratch, &mut w, 0, rows, isa,
                    );
                }
                Some(pool) => {
                    let workers = pool.workers().min(rows.div_ceil(tile).max(1));
                    let ranges = chunk_ranges(rows, workers);
                    let mut parts: Vec<Mat> = vec![Mat::zeros(m, k); ranges.len()];
                    let x = &chunk.x;
                    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = ranges
                        .iter()
                        .zip(parts.iter_mut())
                        .map(|(&(lo, hi), part)| {
                            let f: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                                POOL_SCRATCH.with(|cell| {
                                    let mut cell = cell.borrow_mut();
                                    let scratch = cell
                                        .get_or_insert_with(|| kernels::TileScratch::new(tile, m));
                                    matmat_ranged_any(
                                        kern, x, cs, xn, u, vb, param, scratch, part, lo, hi, isa,
                                    );
                                });
                            });
                            f
                        })
                        .collect();
                    pool.run_scoped(tasks);
                    for part in parts {
                        w.add(&part);
                    }
                }
            }
            Ok(())
        })?;
        Ok(w)
    }
}

/// Accumulate `w += Σ_blocks Krᵀ(mask ⊙ (Kr·u + v))` over `blocks` — the
/// shared body of the inline and pooled apply paths (free function so the
/// pooled tasks only capture `Sync` plan fields).
#[allow(clippy::too_many_arguments)]
fn apply_blocks(
    kern: Kernel,
    cs: &CenterSet,
    blocks: &[RustBlock],
    u: &[f64],
    v: Option<&[f64]>,
    param: f64,
    scratch: &mut kernels::TileScratch,
    w: &mut [f64],
    isa: Isa,
) {
    for blk in blocks {
        let rows = blk.x.rows();
        let vb = v.map(|vf| &vf[blk.start..blk.start + rows]);
        matvec_ranged_any(kern, &blk.x, cs, &blk.xn, u, vb, param, scratch, w, 0, rows, isa);
    }
}

/// Multi-RHS body of the inline and pooled `apply_multi` paths:
/// `W += Σ_blocks Krᵀ(Kr·U + V_block)` with `V_block` the contiguous
/// row-major `rows × K` slice of the full `n × K` offset block.
#[allow(clippy::too_many_arguments)]
fn apply_blocks_multi(
    kern: Kernel,
    cs: &CenterSet,
    blocks: &[RustBlock],
    u: &Mat,
    v: Option<&Mat>,
    param: f64,
    scratch: &mut kernels::TileScratch,
    w: &mut Mat,
    isa: Isa,
) {
    let k = u.cols;
    for blk in blocks {
        let rows = blk.x.rows();
        let vb = v.map(|vf| &vf.data[blk.start * k..(blk.start + rows) * k]);
        matmat_ranged_any(kern, &blk.x, cs, &blk.xn, u, vb, param, scratch, w, 0, rows, isa);
    }
}

/// The per-fit blocked matvec: `apply` computes
/// `w = Σ_blocks Krᵀ(mask ⊙ (Kr·u + v_block))` (Alg. 1's
/// KnM_times_vector). `v = None` means zeros (the CG iteration);
/// `v = Some(y/n)` builds the right-hand side.
pub enum MatvecPlan {
    Rust(RustPlan),
    /// out-of-core: re-streams a chunked [`DataSource`] every apply
    Stream(StreamPlan),
    #[cfg(feature = "xla")]
    Xla(XlaPlan),
}

impl MatvecPlan {
    pub fn n(&self) -> usize {
        match self {
            MatvecPlan::Rust(p) => p.n,
            MatvecPlan::Stream(p) => p.n,
            #[cfg(feature = "xla")]
            MatvecPlan::Xla(p) => p.n,
        }
    }

    pub fn m(&self) -> usize {
        match self {
            MatvecPlan::Rust(p) => p.m,
            MatvecPlan::Stream(p) => p.m,
            #[cfg(feature = "xla")]
            MatvecPlan::Xla(p) => p.m,
        }
    }

    pub fn n_blocks(&self) -> usize {
        match self {
            MatvecPlan::Rust(p) => p.blocks.len(),
            MatvecPlan::Stream(p) => p.chunks_seen.get(),
            #[cfg(feature = "xla")]
            MatvecPlan::Xla(p) => p.blocks.len(),
        }
    }

    /// Worker threads serving this plan (1 = inline).
    pub fn workers(&self) -> usize {
        match self {
            MatvecPlan::Rust(p) => p.pool.as_deref().map(WorkerPool::workers).unwrap_or(1),
            MatvecPlan::Stream(p) => p.pool.as_deref().map(WorkerPool::workers).unwrap_or(1),
            #[cfg(feature = "xla")]
            MatvecPlan::Xla(_) => 1,
        }
    }

    /// Kernel evaluations one `apply` performs (bench accounting; the XLA
    /// path pays for padded rows too, and evaluates each block twice —
    /// once per fused stage).
    pub fn kernel_evals_per_apply(&self) -> usize {
        match self {
            MatvecPlan::Rust(p) => p.n * p.m,
            MatvecPlan::Stream(p) => p.n * p.m,
            #[cfg(feature = "xla")]
            MatvecPlan::Xla(p) => p.blocks.len() * p.b_art * p.m * 2,
        }
    }

    /// Feature bytes this plan keeps resident: the in-memory plan retains
    /// every sliced row block (≈ the full `n×d` dataset); the streaming
    /// plan only ever holds one chunk, so this reports the **peak** chunk
    /// seen — the out-of-core bench's peak-RSS proxy. `None` on the XLA
    /// plan (blocks live device-side as literals).
    pub fn resident_x_bytes(&self) -> Option<usize> {
        match self {
            // dtype-aware: 4 bytes/element for f32 blocks, 8 for f64
            MatvecPlan::Rust(p) => Some(p.blocks.iter().map(|b| b.x.bytes()).sum()),
            MatvecPlan::Stream(p) => Some(p.max_resident_bytes()),
            #[cfg(feature = "xla")]
            MatvecPlan::Xla(_) => None,
        }
    }

    pub fn apply(&self, u: &[f64], v: Option<&[f64]>) -> Result<Vec<f64>> {
        match self {
            MatvecPlan::Rust(p) => p.apply(u, v),
            MatvecPlan::Stream(p) => p.apply(u, v),
            #[cfg(feature = "xla")]
            MatvecPlan::Xla(p) => p.apply(u, v),
        }
    }

    /// Multi-RHS apply: `W = Σ_blocks Krᵀ(Kr·U + V)` for an `M×K`
    /// coefficient block (`v = None` means zeros). The Rust engine
    /// computes each Kr panel once for all K columns; the XLA plan falls
    /// back to a loop over columns (the artifact contract is
    /// vector-shaped), which is correct but pays K panel sweeps.
    pub fn apply_multi(&self, u: &Mat, v: Option<&Mat>) -> Result<Mat> {
        match self {
            MatvecPlan::Rust(p) => p.apply_multi(u, v),
            MatvecPlan::Stream(p) => p.apply_multi(u, v),
            #[cfg(feature = "xla")]
            MatvecPlan::Xla(p) => p.apply_multi(u, v),
        }
    }
}

#[cfg(feature = "xla")]
impl XlaPlan {
    fn apply(&self, u: &[f64], v: Option<&[f64]>) -> Result<Vec<f64>> {
        anyhow::ensure!(u.len() == self.m, "u length {} != M {}", u.len(), self.m);
        if let Some(v) = v {
            anyhow::ensure!(v.len() == self.n, "v length {} != n {}", v.len(), self.n);
        }
        let mut scratch = self.scratch.borrow_mut();
        let XlaScratch { u32v, vbuf } = &mut *scratch;
        u32v.clear();
        u32v.extend(u.iter().map(|&x| x as f32));
        let u_lit = literal_from_f32(u32v, &[self.m])?;
        let mut w = vec![0.0f64; self.m];
        for blk in &self.blocks {
            let v_lit;
            let v_ref: &xla::Literal = match v {
                None => &self.zeros_v,
                Some(vfull) => {
                    let src = &vfull[blk.start..blk.start + blk.rows];
                    if src.iter().all(|&x| x == 0.0) {
                        // all-zero block: reuse the shared zeros literal
                        // instead of staging a fresh one
                        &self.zeros_v
                    } else {
                        vbuf.fill(0.0);
                        for (dst, &sv) in vbuf.iter_mut().zip(src) {
                            *dst = sv as f32;
                        }
                        v_lit = literal_from_f32(vbuf, &[self.b_art])?;
                        &v_lit
                    }
                }
            };
            let part = self
                .exe
                .call1_f32(&[
                    &blk.x,
                    self.c_lit.as_ref(),
                    &u_lit,
                    v_ref,
                    &blk.mask,
                    &self.param_lit,
                ])
                .with_context(|| format!("block @{}", blk.start))?;
            for j in 0..self.m {
                w[j] += part[j] as f64;
            }
        }
        Ok(w)
    }

    /// Loop-over-columns fallback for the multi-RHS apply: the AOT
    /// artifacts take vector u/v, so each column pays its own pass over
    /// the uploaded blocks. Correct (tested against the Rust engine via
    /// the plan-level property tests) but without panel amortization —
    /// the Rust engine is the fast multiclass path.
    fn apply_multi(&self, u: &Mat, v: Option<&Mat>) -> Result<Mat> {
        let k = u.cols;
        anyhow::ensure!(u.rows == self.m, "u rows {} != M {}", u.rows, self.m);
        if let Some(v) = v {
            anyhow::ensure!(v.rows == self.n, "v rows {} != n {}", v.rows, self.n);
            anyhow::ensure!(v.cols == k, "v cols {} != u cols {}", v.cols, k);
        }
        let mut w = Mat::zeros(self.m, k);
        for kc in 0..k {
            let ucol = u.col(kc);
            let wcol = match v {
                None => self.apply(&ucol, None)?,
                Some(vm) => self.apply(&ucol, Some(&vm.col(kc)))?,
            };
            w.set_col(kc, &wcol);
        }
        Ok(w)
    }
}

/// Apply the preconditioned operator (Alg. 2's BHB, generalized per
/// Def. 3 with the leverage-score reweighting D and the rank-deficient
/// partial isometry Q from appendix A / Example 2):
///
///   BᵀHB u = Aᵀ\(Tᵀ\(Qᵀ·D·matvec(D·Q·(T\(A\u)), 0))/n + λ(A\u))
///
/// where Q·TᵀT·Qᵀ = D·K_MM·D (Q = I, TᵀT = D·K_MM·D + εMI on the
/// full-rank Cholesky path) and AᵀA = TTᵀ/M + λI. With uniform sampling
/// D = I (`d = None`) and Q = I (`q = None`) this is exactly Alg. 1/2.
/// Shared by the estimator and the condition-number diagnostics.
pub struct Bhb<'p> {
    pub plan: &'p MatvecPlan,
    /// q×q upper-triangular (diagonal on the eig path)
    pub t: &'p Mat,
    /// q×q upper-triangular (diagonal on the eig path)
    pub a: &'p Mat,
    pub lam: f64,
    /// Def. 2 diagonal reweighting (leverage-score sampling); None = I
    pub d: Option<&'p [f64]>,
    /// M×q partial isometry from the rank-revealing preconditioner
    /// (Example 2); None = identity (full-rank path)
    pub q: Option<&'p Mat>,
}

impl<'p> Bhb<'p> {
    fn dmul(&self, v: &mut [f64]) {
        if let Some(d) = self.d {
            for (x, w) in v.iter_mut().zip(d) {
                *x *= w;
            }
        }
    }

    /// rank of the preconditioned system (q ≤ M)
    pub fn rank(&self) -> usize {
        self.t.rows
    }

    /// lift a q-vector to R^M through Q (no-op when Q = I)
    fn q_lift(&self, v: &[f64]) -> Vec<f64> {
        match self.q {
            None => v.to_vec(),
            Some(q) => crate::linalg::gemm::matvec(q, v),
        }
    }

    /// project an M-vector to R^q through Qᵀ (no-op when Q = I)
    fn q_proj(&self, v: &[f64]) -> Vec<f64> {
        match self.q {
            None => v.to_vec(),
            Some(q) => crate::linalg::gemm::matvec_t(q, v),
        }
    }

    pub fn apply(&self, u: &[f64]) -> Result<Vec<f64>> {
        let n = self.plan.n() as f64;
        let au = tri::solve_upper(self.a, u); // A\u
        let tau = tri::solve_upper(self.t, &au); // T\(A\u)
        let mut lifted = self.q_lift(&tau); // Q·
        self.dmul(&mut lifted); // D·
        let mut w = self.plan.apply(&lifted, None)?; // KnMᵀKnM ·
        self.dmul(&mut w); // D·
        let wq = self.q_proj(&w); // Qᵀ·
        let mut inner = tri::solve_lower_t(self.t, &wq); // Tᵀ\ ·
        for j in 0..inner.len() {
            inner[j] = inner[j] / n + self.lam * au[j];
        }
        Ok(tri::solve_lower_t(self.a, &inner)) // Aᵀ\ ·
    }

    /// Right-hand side r = Aᵀ\(Tᵀ\(Qᵀ·D·KnMᵀ(y/n))).
    pub fn rhs(&self, y: &[f64]) -> Result<Vec<f64>> {
        let n = self.plan.n() as f64;
        let yn: Vec<f64> = y.iter().map(|v| v / n).collect();
        let zeros = vec![0.0; self.plan.m()];
        let mut w = self.plan.apply(&zeros, Some(&yn))?;
        self.dmul(&mut w);
        let wq = self.q_proj(&w);
        let ti = tri::solve_lower_t(self.t, &wq);
        Ok(tri::solve_lower_t(self.a, &ti))
    }

    /// Map CG solution β back to Nyström coefficients α = D·Q·(T\(A\β)).
    pub fn beta_to_alpha(&self, beta: &[f64]) -> Vec<f64> {
        let ab = tri::solve_upper(self.a, beta);
        let tb = tri::solve_upper(self.t, &ab);
        let mut alpha = self.q_lift(&tb);
        self.dmul(&mut alpha);
        alpha
    }

    // -- multi-RHS (one-vs-all multiclass) ------------------------------

    fn dmul_mat(&self, v: &mut Mat) {
        if let Some(d) = self.d {
            v.scale_rows(d);
        }
    }

    /// lift a q×K block to R^{M×K} through Q (no-op when Q = I)
    fn q_lift_mat(&self, v: &Mat) -> Mat {
        match self.q {
            None => v.clone(),
            Some(q) => crate::linalg::gemm::matmul(q, v),
        }
    }

    /// project an M×K block to R^{q×K} through Qᵀ (no-op when Q = I)
    fn q_proj_mat(&self, v: &Mat) -> Mat {
        match self.q {
            None => v.clone(),
            Some(q) => crate::linalg::gemm::matmul(&q.t(), v),
        }
    }

    /// [`Bhb::apply`] for an `M×K` direction block: the triangular
    /// solves run as blocked multi-RHS TRSMs (`tri::solve_*_mat`) and the
    /// plan apply amortizes its kernel panels across the K columns —
    /// column k equals `apply(u_k)` to roundoff.
    pub fn apply_multi(&self, u: &Mat) -> Result<Mat> {
        let n = self.plan.n() as f64;
        let au = tri::solve_upper_mat(self.a, u); // A\U
        let tau = tri::solve_upper_mat(self.t, &au); // T\(A\U)
        let mut lifted = self.q_lift_mat(&tau); // Q·
        self.dmul_mat(&mut lifted); // D·
        let mut w = self.plan.apply_multi(&lifted, None)?; // KnMᵀKnM ·
        self.dmul_mat(&mut w); // D·
        let wq = self.q_proj_mat(&w); // Qᵀ·
        let mut inner = tri::solve_lower_t_mat(self.t, &wq); // Tᵀ\ ·
        for i in 0..inner.rows {
            for (iv, &av) in inner.row_mut(i).iter_mut().zip(au.row(i)) {
                *iv = *iv / n + self.lam * av;
            }
        }
        Ok(tri::solve_lower_t_mat(self.a, &inner)) // Aᵀ\ ·
    }

    /// Multi-RHS right-hand side R = Aᵀ\(Tᵀ\(Qᵀ·D·KnMᵀ(Y/n))) for an
    /// `n×K` target block (one column per one-vs-all subproblem).
    pub fn rhs_multi(&self, y: &Mat) -> Result<Mat> {
        let n = self.plan.n() as f64;
        let mut yn = y.clone();
        yn.scale(1.0 / n);
        let zeros = Mat::zeros(self.plan.m(), y.cols);
        let mut w = self.plan.apply_multi(&zeros, Some(&yn))?;
        self.dmul_mat(&mut w);
        let wq = self.q_proj_mat(&w);
        let ti = tri::solve_lower_t_mat(self.t, &wq);
        Ok(tri::solve_lower_t_mat(self.a, &ti))
    }

    /// Map a block of CG solutions back to Nyström coefficients,
    /// column-wise: A = D·Q·(T\(A\B)).
    pub fn beta_to_alpha_multi(&self, beta: &Mat) -> Mat {
        let ab = tri::solve_upper_mat(self.a, beta);
        let tb = tri::solve_upper_mat(self.t, &ab);
        let mut alpha = self.q_lift_mat(&tb);
        self.dmul_mat(&mut alpha);
        alpha
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn toy(n: usize, d: usize, seed: u64) -> (Mat, Mat, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let x = Mat::from_vec(n, d, rng.normals(n * d));
        let c = x.select_rows(&rng.choose(n, 8.min(n)));
        let y = rng.normals(n);
        (x, c, y)
    }

    #[test]
    fn rust_plan_matches_dense() {
        let (x, c, y) = toy(300, 5, 1);
        let eng = Engine::rust();
        let plan = eng.matvec_plan(Kernel::Gaussian, &x, &c, 1.0).unwrap();
        let mut rng = Rng::new(2);
        let u = rng.normals(c.rows);
        let got = plan.apply(&u, Some(&y)).unwrap();

        let kr = kernels::kernel_block(Kernel::Gaussian, &x, &c, 1.0);
        let mut yv = crate::linalg::gemm::matvec(&kr, &u);
        for i in 0..x.rows {
            yv[i] += y[i];
        }
        let want = crate::linalg::gemm::matvec_t(&kr, &yv);
        for j in 0..c.rows {
            assert!((got[j] - want[j]).abs() < 1e-8);
        }
    }

    #[test]
    fn rust_plan_matches_reference_all_kernels() {
        // plan spans several ROW_BLOCKs; compare against the row-at-a-time
        // reference kernels for every family
        let mut rng = Rng::new(21);
        let (n, d, m) = (2100, 6, 17);
        let x = Mat::from_vec(n, d, rng.normals(n * d));
        let c = x.select_rows(&rng.choose(n, m));
        let u = rng.normals(m);
        let v = rng.normals(n);
        let eng = Engine::rust();
        for kern in [Kernel::Gaussian, Kernel::Laplacian, Kernel::Linear] {
            let plan = eng.matvec_plan(kern, &x, &c, 1.4).unwrap();
            let got = plan.apply(&u, Some(&v)).unwrap();
            let want = kernels::knm_matvec(kern, &x, &c, &u, &v, None, 1.4);
            let diff = crate::linalg::vec_ops::max_abs_diff(&got, &want);
            assert!(diff < 1e-9, "{kern:?} diff={diff}");
        }
    }

    #[test]
    fn rust_plan_parallel_matches_serial() {
        let (x, c, _) = toy(2500, 4, 3);
        let eng1 = Engine::rust();
        let eng4 = Engine::rust_with(EngineOptions {
            imp: Impl::Pallas,
            workers: 4,
            ..Default::default()
        });
        let mut rng = Rng::new(4);
        let u = rng.normals(c.rows);
        let p1 = eng1.matvec_plan(Kernel::Gaussian, &x, &c, 1.3).unwrap();
        let p4 = eng4.matvec_plan(Kernel::Gaussian, &x, &c, 1.3).unwrap();
        let w1 = p1.apply(&u, None).unwrap();
        let w4 = p4.apply(&u, None).unwrap();
        for j in 0..c.rows {
            assert!((w1[j] - w4[j]).abs() < 1e-9);
        }
    }

    #[test]
    fn worker_pool_survives_many_applies() {
        // a 20-iteration fit reuses the same pool; exercise repeated
        // applies (u and v paths) plus ragged block chunking (3 workers,
        // 5 blocks)
        let mut rng = Rng::new(5);
        let (n, d, m) = (4300, 3, 12);
        let x = Mat::from_vec(n, d, rng.normals(n * d));
        let c = x.select_rows(&rng.choose(n, m));
        let eng = Engine::rust_with(EngineOptions {
            imp: Impl::Pallas,
            workers: 3,
            ..Default::default()
        });
        let eng1 = Engine::rust();
        let plan = eng.matvec_plan(Kernel::Gaussian, &x, &c, 1.0).unwrap();
        let serial = eng1.matvec_plan(Kernel::Gaussian, &x, &c, 1.0).unwrap();
        assert_eq!(plan.n_blocks(), 5);
        for it in 0..6 {
            let u = rng.normals(m);
            let v = if it % 2 == 0 { Some(rng.normals(n)) } else { None };
            let got = plan.apply(&u, v.as_deref()).unwrap();
            let want = serial.apply(&u, v.as_deref()).unwrap();
            let diff = crate::linalg::vec_ops::max_abs_diff(&got, &want);
            assert!(diff < 1e-9, "iter {it}: {diff}");
        }
    }

    #[test]
    fn plan_applies_are_deterministic() {
        let (x, c, _) = toy(2500, 4, 6);
        let eng = Engine::rust_with(EngineOptions {
            imp: Impl::Pallas,
            workers: 4,
            ..Default::default()
        });
        let mut rng = Rng::new(7);
        let u = rng.normals(c.rows);
        let plan = eng.matvec_plan(Kernel::Gaussian, &x, &c, 1.3).unwrap();
        let w1 = plan.apply(&u, None).unwrap();
        let w2 = plan.apply(&u, None).unwrap();
        assert_eq!(w1, w2, "pooled apply must be bitwise deterministic");
    }

    #[test]
    fn apply_multi_matches_k_applies() {
        // column k of apply_multi must equal apply on (u_k, v_k) — the
        // panel-amortized path against the vector hot path, all kernels,
        // plan spanning several ROW_BLOCKs, ragged K including K = 1
        let mut rng = Rng::new(31);
        let (n, d, m) = (2300, 5, 19);
        let x = Mat::from_vec(n, d, rng.normals(n * d));
        let c = x.select_rows(&rng.choose(n, m));
        let eng = Engine::rust();
        for kern in [Kernel::Gaussian, Kernel::Laplacian, Kernel::Linear] {
            let plan = eng.matvec_plan(kern, &x, &c, 1.3).unwrap();
            for k in [1usize, 3, 5] {
                let u = Mat::from_vec(m, k, rng.normals(m * k));
                let v = Mat::from_vec(n, k, rng.normals(n * k));
                for vopt in [None, Some(&v)] {
                    let got = plan.apply_multi(&u, vopt).unwrap();
                    assert_eq!((got.rows, got.cols), (m, k));
                    for kc in 0..k {
                        let vcol = vopt.map(|vm| vm.col(kc));
                        let want = plan.apply(&u.col(kc), vcol.as_deref()).unwrap();
                        for j in 0..m {
                            let diff = (got[(j, kc)] - want[j]).abs();
                            assert!(diff < 1e-9, "{kern:?} k={k} col={kc} row={j} diff={diff}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn pooled_apply_multi_matches_serial_and_is_deterministic() {
        let (x, c, _) = toy(2600, 4, 13);
        let eng1 = Engine::rust();
        let eng4 = Engine::rust_with(EngineOptions {
            imp: Impl::Pallas,
            workers: 4,
            ..Default::default()
        });
        let mut rng = Rng::new(14);
        let k = 6;
        let u = Mat::from_vec(c.rows, k, rng.normals(c.rows * k));
        let v = Mat::from_vec(x.rows, k, rng.normals(x.rows * k));
        let p1 = eng1.matvec_plan(Kernel::Gaussian, &x, &c, 1.2).unwrap();
        let p4 = eng4.matvec_plan(Kernel::Gaussian, &x, &c, 1.2).unwrap();
        let w1 = p1.apply_multi(&u, Some(&v)).unwrap();
        let w4 = p4.apply_multi(&u, Some(&v)).unwrap();
        assert!(w1.max_abs_diff(&w4) < 1e-9);
        // pooled applies must be bitwise deterministic across repeats
        let w4b = p4.apply_multi(&u, Some(&v)).unwrap();
        assert_eq!(w4.data, w4b.data);
        // and the single-worker multi path is bitwise equal to itself via
        // the inline scratch (sanity of scratch reuse across calls)
        let w1b = p1.apply_multi(&u, Some(&v)).unwrap();
        assert_eq!(w1.data, w1b.data);
    }

    #[test]
    fn pooled_predict_multi_matches_engine_predict() {
        let (x, c, _) = toy(900, 4, 15);
        let eng = Engine::rust_with(EngineOptions {
            imp: Impl::Pallas,
            workers: 3,
            ..Default::default()
        });
        let mut rng = Rng::new(16);
        let k = 4;
        let alphas = Mat::from_vec(c.rows, k, rng.normals(c.rows * k));
        let multi = eng.predict_multi(Kernel::Gaussian, &x, &c, &alphas, 1.1).unwrap();
        for kc in 0..k {
            let want = eng.predict(Kernel::Gaussian, &x, &c, &alphas.col(kc), 1.1).unwrap();
            for i in 0..x.rows {
                assert!((multi[(i, kc)] - want[i]).abs() < 1e-10, "col {kc} row {i}");
            }
        }
    }

    #[test]
    fn bhb_multi_matches_vector_bhb() {
        // apply_multi / rhs_multi / beta_to_alpha_multi vs their vector
        // counterparts, with and without the D reweighting
        let (x, c, y) = toy(400, 4, 17);
        let eng = Engine::rust();
        let kmm = eng.kmm(Kernel::Gaussian, &c, 1.0).unwrap();
        let lam = 1e-3;
        let (t, a) = eng.precond(&kmm, lam, 1e-10).unwrap();
        let plan = eng.matvec_plan(Kernel::Gaussian, &x, &c, 1.0).unwrap();
        let m = c.rows;
        let mut rng = Rng::new(18);
        let dw: Vec<f64> = (0..m).map(|_| 0.5 + rng.f64()).collect();
        for dopt in [None, Some(dw.as_slice())] {
            let bhb = Bhb {
                plan: &plan,
                t: &t,
                a: &a,
                lam,
                d: dopt,
                q: None,
            };
            let k = 3;
            let u = Mat::from_vec(m, k, rng.normals(m * k));
            let got = bhb.apply_multi(&u).unwrap();
            for kc in 0..k {
                let want = bhb.apply(&u.col(kc)).unwrap();
                for j in 0..m {
                    assert!((got[(j, kc)] - want[j]).abs() < 1e-9, "apply col {kc}");
                }
            }
            // rhs: stack y and a shifted copy
            let mut ym = Mat::zeros(x.rows, 2);
            for i in 0..x.rows {
                ym[(i, 0)] = y[i];
                ym[(i, 1)] = 2.0 * y[i] - 0.3;
            }
            let rm = bhb.rhs_multi(&ym).unwrap();
            for kc in 0..2 {
                let want = bhb.rhs(&ym.col(kc)).unwrap();
                for j in 0..bhb.rank() {
                    assert!((rm[(j, kc)] - want[j]).abs() < 1e-9, "rhs col {kc}");
                }
            }
            let beta = Mat::from_vec(bhb.rank(), k, rng.normals(bhb.rank() * k));
            let am = bhb.beta_to_alpha_multi(&beta);
            for kc in 0..k {
                let want = bhb.beta_to_alpha(&beta.col(kc));
                for j in 0..m {
                    assert!((am[(j, kc)] - want[j]).abs() < 1e-10, "alpha col {kc}");
                }
            }
        }
    }

    #[test]
    fn bhb_multi_matches_vector_on_eig_path() {
        // the rank-revealing preconditioner's Q must flow through the
        // multi-RHS lift/project identically to the vector path
        let (x, c, _) = toy(300, 3, 19);
        let eng = Engine::rust();
        let kmm = eng.kmm(Kernel::Gaussian, &c, 1.0).unwrap();
        let lam = 1e-3;
        let (t, a, q) = crate::falkon::precond::precond_eig(&kmm, lam, 1e-12).unwrap();
        let plan = eng.matvec_plan(Kernel::Gaussian, &x, &c, 1.0).unwrap();
        let bhb = Bhb {
            plan: &plan,
            t: &t,
            a: &a,
            lam,
            d: None,
            q: Some(&q),
        };
        let mut rng = Rng::new(20);
        let k = 3;
        let u = Mat::from_vec(bhb.rank(), k, rng.normals(bhb.rank() * k));
        let got = bhb.apply_multi(&u).unwrap();
        for kc in 0..k {
            let want = bhb.apply(&u.col(kc)).unwrap();
            for j in 0..bhb.rank() {
                assert!((got[(j, kc)] - want[j]).abs() < 1e-9, "eig apply col {kc}");
            }
        }
    }

    #[test]
    fn rust_precond_factors() {
        let mut rng = Rng::new(5);
        let c = Mat::from_vec(10, 3, rng.normals(30));
        let kmm = kernels::kmm(Kernel::Gaussian, &c, 1.0);
        let eng = Engine::rust();
        let (t, a) = eng.precond(&kmm, 1e-3, 1e-10).unwrap();
        // TᵀT ≈ KMM
        let back = crate::linalg::gemm::matmul(&t.t(), &t);
        assert!(back.max_abs_diff(&kmm) < 1e-6);
        let mut tta = crate::linalg::gemm::matmul(&t, &t.t());
        tta.scale(0.1);
        tta.add_diag(1e-3);
        let back_a = crate::linalg::gemm::matmul(&a.t(), &a);
        assert!(back_a.max_abs_diff(&tta) < 1e-8);
    }

    #[test]
    fn rust_precond_rank_deficient() {
        // duplicated centers -> singular KMM; jitter must save it
        let mut rng = Rng::new(6);
        let base = Mat::from_vec(5, 3, rng.normals(15));
        let mut rows: Vec<Vec<f64>> = (0..5).map(|i| base.row(i).to_vec()).collect();
        rows.push(base.row(0).to_vec());
        rows.push(base.row(1).to_vec());
        let c = Mat::from_rows(&rows);
        let kmm = kernels::kmm(Kernel::Gaussian, &c, 1.0);
        let eng = Engine::rust();
        let (t, a) = eng.precond(&kmm, 1e-4, 1e-12).unwrap();
        assert!(t.is_finite() && a.is_finite());
    }

    #[test]
    fn engine_by_name() {
        assert!(Engine::by_name("rust", 1).is_ok());
        assert!(Engine::by_name("bogus", 1).is_err());
    }

    #[test]
    fn pooled_setup_is_bitwise_equal_to_serial() {
        // kmm + precond through a workers=4 engine must equal workers=1
        // exactly (ISSUE 2 determinism contract for the setup path)
        let mut rng = Rng::new(9);
        let c = Mat::from_vec(170, 6, rng.normals(170 * 6));
        let eng1 = Engine::rust();
        let eng4 = Engine::rust_with(EngineOptions {
            imp: Impl::Pallas,
            workers: 4,
            ..Default::default()
        });
        let k1 = eng1.kmm(Kernel::Gaussian, &c, 1.2).unwrap();
        let k4 = eng4.kmm(Kernel::Gaussian, &c, 1.2).unwrap();
        assert_eq!(k1.data, k4.data, "pooled kmm");
        let (t1, a1) = eng1.precond(&k1, 1e-3, 1e-10).unwrap();
        let (t4, a4) = eng4.precond(&k4, 1e-3, 1e-10).unwrap();
        assert_eq!(t1.data, t4.data, "pooled T factor");
        assert_eq!(a1.data, a4.data, "pooled A factor");
    }

    #[test]
    fn blocked_setup_matches_reference_setup_predictions() {
        // end-to-end contract: a fit whose setup ran the blocked
        // kmm/cholesky/SYRK path predicts within 1e-8 relative of one
        // whose factors come from the pre-PR scalar reference routines
        let (x, c, y) = toy(400, 4, 11);
        let eng = Engine::rust();
        let lam = 1e-3;
        let kmm_blocked = eng.kmm(Kernel::Gaussian, &c, 1.0).unwrap();
        let (t_b, a_b) = eng.precond(&kmm_blocked, lam, 1e-10).unwrap();

        // reference factors: scalar kernel block + scalar cholesky + matmul
        let kmm_ref = kernels::kernel_block_ref(Kernel::Gaussian, &c, &c, 1.0);
        let m = c.rows;
        let mut kj = kmm_ref.clone();
        kj.add_diag(1e-10 * m as f64);
        let t_r = chol::cholesky_upper_ref(&kj).unwrap();
        let mut tta = crate::linalg::gemm::matmul(&t_r, &t_r.t());
        tta.scale(1.0 / m as f64);
        tta.add_diag(lam);
        let a_r = chol::cholesky_upper_ref(&tta).unwrap();

        let plan = eng.matvec_plan(Kernel::Gaussian, &x, &c, 1.0).unwrap();
        let mut alphas = Vec::new();
        for (t, a) in [(&t_b, &a_b), (&t_r, &a_r)] {
            let bhb = Bhb {
                plan: &plan,
                t,
                a,
                lam,
                d: None,
                q: None,
            };
            let r = bhb.rhs(&y).unwrap();
            let cg = crate::falkon::cg::conjgrad(
                |p| bhb.apply(p),
                &r,
                crate::falkon::cg::CgOptions { t_max: 25, tol: 0.0 },
                None,
            )
            .unwrap();
            alphas.push(bhb.beta_to_alpha(&cg.beta));
        }
        let p1 = kernels::predict(Kernel::Gaussian, &x, &c, &alphas[0], 1.0);
        let p2 = kernels::predict(Kernel::Gaussian, &x, &c, &alphas[1], 1.0);
        let rel = crate::linalg::vec_ops::rel_diff(&p1, &p2);
        assert!(rel < 1e-8, "rel {rel}");
    }

    #[test]
    fn bhb_is_symmetric_positive() {
        let (x, c, _) = toy(200, 4, 7);
        let eng = Engine::rust();
        let kmm = eng.kmm(Kernel::Gaussian, &c, 1.0).unwrap();
        let lam = 1e-2;
        let (t, a) = eng.precond(&kmm, lam, 1e-10).unwrap();
        let plan = eng.matvec_plan(Kernel::Gaussian, &x, &c, 1.0).unwrap();
        let bhb = Bhb {
            plan: &plan,
            t: &t,
            a: &a,
            lam,
            d: None,
            q: None,
        };
        let m = c.rows;
        // materialize W and check symmetry + positive diagonal
        let mut w = Mat::zeros(m, m);
        for j in 0..m {
            let mut e = vec![0.0; m];
            e[j] = 1.0;
            let col = bhb.apply(&e).unwrap();
            for i in 0..m {
                w[(i, j)] = col[i];
            }
        }
        for i in 0..m {
            assert!(w[(i, i)] > 0.0);
            for j in 0..m {
                assert!((w[(i, j)] - w[(j, i)]).abs() < 1e-7, "asym at {i},{j}");
            }
        }
    }

    #[test]
    fn bhb_close_to_identity_in_falkon_regime() {
        // Thm. 2: with M >~ 1/lam, W = I + E with ||E|| < 1.
        let mut rng = Rng::new(8);
        let n = 400;
        let x = Mat::from_vec(n, 3, rng.normals(n * 3));
        let c = x.select_rows(&rng.choose(n, 60));
        let lam = 1.0 / (n as f64).sqrt();
        let eng = Engine::rust();
        let kmm = eng.kmm(Kernel::Gaussian, &c, 1.0).unwrap();
        let (t, a) = eng.precond(&kmm, lam, 1e-10).unwrap();
        let plan = eng.matvec_plan(Kernel::Gaussian, &x, &c, 1.0).unwrap();
        let bhb = Bhb {
            plan: &plan,
            t: &t,
            a: &a,
            lam,
            d: None,
            q: None,
        };
        let m = c.rows;
        let mut max_offdiag = 0.0f64;
        let mut diag_dev = 0.0f64;
        for j in 0..m {
            let mut e = vec![0.0; m];
            e[j] = 1.0;
            let col = bhb.apply(&e).unwrap();
            for i in 0..m {
                if i == j {
                    diag_dev = diag_dev.max((col[i] - 1.0).abs());
                } else {
                    max_offdiag = max_offdiag.max(col[i].abs());
                }
            }
        }
        assert!(diag_dev < 0.9, "diag deviation {diag_dev}");
        assert!(max_offdiag < 0.9, "offdiag {max_offdiag}");
    }

    // -- out-of-core streaming plan ------------------------------------

    use crate::data::source::MemSource;
    use crate::data::Dataset;

    fn stream_plan_over(
        eng: &Engine,
        x: &Mat,
        c: &Mat,
        chunk_rows: usize,
        param: f64,
    ) -> MatvecPlan {
        let data = Dataset::new_regression("t", x.clone(), vec![0.0; x.rows]);
        eng.matvec_plan_source(
            Kernel::Gaussian,
            Box::new(MemSource::new(data, chunk_rows)),
            c,
            param,
            x.rows,
        )
        .unwrap()
    }

    #[test]
    fn stream_plan_matches_in_memory_bitwise_serial() {
        // serial chunked sweeps accumulate per-row in global row order,
        // exactly like the in-memory plan — bitwise, at ANY chunk budget
        let (x, c, y) = toy(2700, 5, 31);
        let eng = Engine::rust();
        let plan_mem = eng.matvec_plan(Kernel::Gaussian, &x, &c, 1.2).unwrap();
        let mut rng = Rng::new(32);
        let u = rng.normals(c.rows);
        let want = plan_mem.apply(&u, Some(&y)).unwrap();
        let want0 = plan_mem.apply(&u, None).unwrap();
        for chunk_rows in [64usize, 1000, 1024, 5000] {
            let plan = stream_plan_over(&eng, &x, &c, chunk_rows, 1.2);
            assert_eq!(plan.n(), x.rows);
            assert_eq!(plan.m(), c.rows);
            let got = plan.apply(&u, Some(&y)).unwrap();
            assert_eq!(got, want, "chunk {chunk_rows}");
            assert_eq!(plan.apply(&u, None).unwrap(), want0, "chunk {chunk_rows} v=0");
            // resident bytes = the largest chunk, not the dataset
            let resident = plan.resident_x_bytes().unwrap();
            assert_eq!(resident, chunk_rows.min(x.rows) * x.cols * 8);
            assert!(resident <= plan_mem.resident_x_bytes().unwrap());
        }
    }

    #[test]
    fn stream_plan_pooled_matches_serial() {
        let (x, c, y) = toy(3100, 4, 33);
        let eng1 = Engine::rust();
        let eng4 = Engine::rust_with(EngineOptions {
            imp: Impl::Pallas,
            workers: 4,
            ..Default::default()
        });
        let mut rng = Rng::new(34);
        let u = rng.normals(c.rows);
        let serial = stream_plan_over(&eng1, &x, &c, 700, 1.1);
        let pooled = stream_plan_over(&eng4, &x, &c, 700, 1.1);
        for v in [None, Some(&y)] {
            let w1 = serial.apply(&u, v.map(|f| f.as_slice())).unwrap();
            let w4 = pooled.apply(&u, v.map(|f| f.as_slice())).unwrap();
            let diff = crate::linalg::vec_ops::max_abs_diff(&w1, &w4);
            assert!(diff < 1e-9, "{diff}");
        }
        // pooled repeats are bitwise deterministic
        let a = pooled.apply(&u, Some(&y)).unwrap();
        let b = pooled.apply(&u, Some(&y)).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn stream_apply_multi_matches_k_applies() {
        let (x, c, _) = toy(1900, 4, 35);
        let eng = Engine::rust();
        let (n, m) = (x.rows, c.rows);
        let mut rng = Rng::new(36);
        for k in [1usize, 3] {
            let u = Mat::from_vec(m, k, rng.normals(m * k));
            let v = Mat::from_vec(n, k, rng.normals(n * k));
            let plan = stream_plan_over(&eng, &x, &c, 450, 1.3);
            for vopt in [None, Some(&v)] {
                let got = plan.apply_multi(&u, vopt).unwrap();
                for kc in 0..k {
                    let vcol = vopt.map(|vm| vm.col(kc));
                    let want = plan.apply(&u.col(kc), vcol.as_deref()).unwrap();
                    for j in 0..m {
                        let diff = (got[(j, kc)] - want[j]).abs();
                        assert!(diff < 1e-9, "k={k} col={kc} row={j} diff={diff}");
                    }
                }
            }
        }
    }

    #[test]
    fn stream_plan_rejects_mismatched_source() {
        let (x, c, _) = toy(300, 4, 37);
        let eng = Engine::rust();
        let data = Dataset::new_regression("t", x.clone(), vec![0.0; x.rows]);
        // wrong n
        assert!(eng
            .matvec_plan_source(
                Kernel::Gaussian,
                Box::new(MemSource::new(data.clone(), 64)),
                &c,
                1.0,
                x.rows + 1,
            )
            .is_err());
        // wrong feature dim
        let bad_c = Mat::zeros(8, 3);
        assert!(eng
            .matvec_plan_source(
                Kernel::Gaussian,
                Box::new(MemSource::new(data, 64)),
                &bad_c,
                1.0,
                x.rows,
            )
            .is_err());
    }

    #[test]
    fn predict_source_matches_in_memory_predict() {
        let (x, c, _) = toy(1500, 5, 38);
        let mut rng = Rng::new(39);
        let alpha = rng.normals(c.rows);
        for workers in [1usize, 3] {
            let eng = Engine::rust_with(EngineOptions {
                imp: Impl::Pallas,
                workers,
                ..Default::default()
            });
            let want = eng.predict(Kernel::Gaussian, &x, &c, &alpha, 1.4).unwrap();
            let data = Dataset::new_regression("t", x.clone(), vec![0.0; x.rows]);
            let mut src = MemSource::new(data, 333);
            let got = eng
                .predict_source(Kernel::Gaussian, &mut src, &c, &alpha, 1.4)
                .unwrap();
            assert_eq!(got, want, "workers {workers}");
        }
    }

    // -- mixed precision (f32 storage, f64 accumulation) ----------------

    use crate::kernels::tol;

    fn rust_f32(workers: usize) -> Engine {
        Engine::rust_with(EngineOptions {
            imp: Impl::Pallas,
            workers,
            dtype: Dtype::F32,
            ..Default::default()
        })
    }

    #[test]
    fn f32_plan_matches_f64_oracle_within_model() {
        // an f32-storage plan against the f64 plan built on the SAME
        // rounded values, every kernel family, within the documented
        // tolerance model — not an ad-hoc epsilon
        let mut rng = Rng::new(41);
        let (n, d, m) = (2300, 5, 16);
        let x = Mat::from_vec(n, d, rng.normals(n * d));
        let c = x.select_rows(&rng.choose(n, m));
        let u = rng.normals(m);
        let v = rng.normals(n);
        let x32 = MatF32::from_mat(&x);
        let c32 = MatF32::from_mat(&c);
        let (xr, cr) = (x32.to_mat(), c32.to_mat());
        let eng32 = rust_f32(1);
        let eng64 = Engine::rust();
        for kern in [Kernel::Gaussian, Kernel::Laplacian, Kernel::Linear] {
            let p32 = eng32.matvec_plan(kern, &x, &c, 1.3).unwrap();
            let p64 = eng64.matvec_plan(kern, &xr, &cr, 1.3).unwrap();
            for vopt in [None, Some(v.as_slice())] {
                let got = p32.apply(&u, vopt).unwrap();
                let want = p64.apply(&u, vopt).unwrap();
                let diff = crate::linalg::vec_ops::max_abs_diff(&got, &want);
                let bound = tol::matvec_bound(kern, &x32, &c32, n, &u, vopt);
                assert!(diff <= bound, "{kern:?} diff={diff} bound={bound}");
            }
            // multi-RHS: K columns through the panel-amortized f32 path
            let k = 3;
            let um = Mat::from_vec(m, k, rng.normals(m * k));
            let got = p32.apply_multi(&um, None).unwrap();
            let want = p64.apply_multi(&um, None).unwrap();
            let bound = tol::matmat_bound(kern, &x32, &c32, n, &um, None);
            assert!(got.max_abs_diff(&want) <= bound, "{kern:?} multi");
        }
    }

    #[test]
    fn f32_plan_halves_resident_bytes_and_pools_deterministically() {
        // satellite: memory accounting must report 4 bytes/element for
        // f32 blocks, and pooled f32 applies stay bitwise deterministic
        let (x, c, _) = toy(2500, 4, 43);
        let p1 = rust_f32(1).matvec_plan(Kernel::Gaussian, &x, &c, 1.2).unwrap();
        let p4 = rust_f32(4).matvec_plan(Kernel::Gaussian, &x, &c, 1.2).unwrap();
        let p64 = Engine::rust().matvec_plan(Kernel::Gaussian, &x, &c, 1.2).unwrap();
        assert_eq!(p1.resident_x_bytes().unwrap(), x.rows * x.cols * 4);
        assert_eq!(p64.resident_x_bytes().unwrap(), 2 * p1.resident_x_bytes().unwrap());
        let mut rng = Rng::new(44);
        let u = rng.normals(c.rows);
        let w1 = p1.apply(&u, None).unwrap();
        let w4 = p4.apply(&u, None).unwrap();
        let w4b = p4.apply(&u, None).unwrap();
        assert_eq!(w4, w4b, "pooled f32 apply must be bitwise deterministic");
        let diff = crate::linalg::vec_ops::max_abs_diff(&w1, &w4);
        assert!(diff < 1e-9, "pooled vs serial f32: {diff}");
    }

    #[test]
    fn f32_stream_plan_matches_f32_in_memory_bitwise() {
        // an f32 chunk stream and an f32 in-memory plan store identically
        // rounded values and accumulate per-row in global row order —
        // bitwise equal, like the f64 pair; and the peak-chunk proxy is
        // dtype-aware (satellite: half the resident bytes at equal rows)
        let (x, c, y) = toy(1700, 4, 45);
        let eng32 = rust_f32(1);
        let plan_mem = eng32.matvec_plan(Kernel::Gaussian, &x, &c, 1.1).unwrap();
        let mut rng = Rng::new(46);
        let u = rng.normals(c.rows);
        let want = plan_mem.apply(&u, Some(&y)).unwrap();
        let data = Dataset::new_regression("t", x.clone(), vec![0.0; x.rows]);
        let src = MemSource::with_dtype(data, 450, Dtype::F32);
        let plan = eng32
            .matvec_plan_source(Kernel::Gaussian, Box::new(src), &c, 1.1, x.rows)
            .unwrap();
        let got = plan.apply(&u, Some(&y)).unwrap();
        assert_eq!(got, want);
        assert_eq!(plan.resident_x_bytes().unwrap(), 450 * x.cols * 4);
        // multi-RHS over the same stream
        let k = 2;
        let um = Mat::from_vec(c.rows, k, rng.normals(c.rows * k));
        let gm = plan.apply_multi(&um, None).unwrap();
        let wm = plan_mem.apply_multi(&um, None).unwrap();
        assert_eq!(gm.data, wm.data);
    }

    #[test]
    fn predict_block_dispatches_both_dtypes() {
        let (x, c, _) = toy(900, 4, 47);
        let mut rng = Rng::new(48);
        let alpha = rng.normals(c.rows);
        let eng = Engine::rust();
        // f64 arm is exactly Engine::predict
        let want64 = eng.predict(Kernel::Gaussian, &x, &c, &alpha, 1.1).unwrap();
        let got64 = eng
            .predict_block(Kernel::Gaussian, &XBlock::F64(x.clone()), &c, &alpha, 1.1)
            .unwrap();
        assert_eq!(got64, want64);
        // f32 arm: within the predict bound of the f64 oracle on the same
        // rounded values; pooled == serial bitwise
        let x32 = MatF32::from_mat(&x);
        let c32 = MatF32::from_mat(&c);
        let blk = XBlock::F32(x32.clone());
        let got32 = eng
            .predict_block(Kernel::Gaussian, &blk, &c, &alpha, 1.1)
            .unwrap();
        let oracle = eng
            .predict(Kernel::Gaussian, &x32.to_mat(), &c32.to_mat(), &alpha, 1.1)
            .unwrap();
        let diff = crate::linalg::vec_ops::max_abs_diff(&got32, &oracle);
        let bound = tol::predict_bound(Kernel::Gaussian, &x32, &c32, &alpha);
        assert!(diff <= bound, "diff={diff} bound={bound}");
        let eng3 = Engine::rust_with(EngineOptions {
            imp: Impl::Pallas,
            workers: 3,
            ..Default::default()
        });
        let pooled = eng3
            .predict_block(Kernel::Gaussian, &blk, &c, &alpha, 1.1)
            .unwrap();
        assert_eq!(pooled, got32);
    }

    #[test]
    fn predict_source_serves_f32_chunks_within_model() {
        let (x, c, _) = toy(1100, 5, 49);
        let mut rng = Rng::new(50);
        let alpha = rng.normals(c.rows);
        let eng = Engine::rust();
        let data = Dataset::new_regression("t", x.clone(), vec![0.0; x.rows]);
        let mut src = MemSource::with_dtype(data, 256, Dtype::F32);
        let got = eng
            .predict_source(Kernel::Gaussian, &mut src, &c, &alpha, 1.2)
            .unwrap();
        let x32 = MatF32::from_mat(&x);
        let c32 = MatF32::from_mat(&c);
        let oracle = eng
            .predict(Kernel::Gaussian, &x32.to_mat(), &c32.to_mat(), &alpha, 1.2)
            .unwrap();
        let diff = crate::linalg::vec_ops::max_abs_diff(&got, &oracle);
        let bound = tol::predict_bound(Kernel::Gaussian, &x32, &c32, &alpha);
        assert!(diff <= bound, "diff={diff} bound={bound}");
        assert_eq!(got.len(), x.rows);
    }
}

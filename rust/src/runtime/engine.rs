//! The compute engine abstraction: every data-touching op the coordinator
//! needs, served either by the AOT XLA artifacts (production path) or by
//! the pure-Rust reference kernels (fallback / cross-check / "compute on
//! the fly" baseline).
//!
//! The hot object is the [`MatvecPlan`]: built once per fit, it owns the
//! per-block prepared inputs (row blocks padded + masked, uploaded as XLA
//! literals exactly once) and then serves `w = Σ_blocks Krᵀ(mask(Kr u + v))`
//! every CG iteration, optionally fanning blocks out across a worker pool.

use crate::kernels::{self, Kernel};
use crate::linalg::mat::Mat;
use crate::linalg::{chol, tri};
use crate::runtime::exe::{literal_from_f32, literal_scalar, literal_to_f32, Exe};
use crate::runtime::spec::{Impl, Op, Registry};
use anyhow::{anyhow, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

/// Engine configuration knobs that matter for perf experiments.
#[derive(Debug, Clone)]
pub struct EngineOptions {
    /// kernel-op implementation to request from the registry
    pub imp: Impl,
    /// worker threads for the blocked matvec. Effective on the Rust
    /// engine; the XLA path stays single-threaded because the `xla`
    /// crate's client handle is an `Rc` (per-thread) — XLA itself can
    /// still use intra-op threads inside one executable.
    pub workers: usize,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            imp: Impl::Pallas,
            workers: 1,
        }
    }
}

/// Which compute path serves the ops.
pub enum Engine {
    /// AOT XLA artifacts via PJRT (production).
    Xla {
        registry: Rc<Registry>,
        cache: RefCell<HashMap<String, Rc<Exe>>>,
        opts: EngineOptions,
    },
    /// Pure-Rust f64 reference (no artifacts needed).
    Rust { opts: EngineOptions },
}

impl Engine {
    pub fn xla_default() -> Result<Engine> {
        Engine::xla(EngineOptions::default())
    }

    pub fn xla(opts: EngineOptions) -> Result<Engine> {
        Ok(Engine::Xla {
            registry: Rc::new(Registry::load_default()?),
            cache: RefCell::new(HashMap::new()),
            opts,
        })
    }

    pub fn xla_with_registry(registry: Registry, opts: EngineOptions) -> Engine {
        Engine::Xla {
            registry: Rc::new(registry),
            cache: RefCell::new(HashMap::new()),
            opts,
        }
    }

    pub fn rust() -> Engine {
        Engine::Rust {
            opts: EngineOptions::default(),
        }
    }

    pub fn rust_with(opts: EngineOptions) -> Engine {
        Engine::Rust { opts }
    }

    /// Parse "xla", "xla-jnp", "rust" (CLI `--engine`).
    pub fn by_name(name: &str, workers: usize) -> Result<Engine> {
        let mut opts = EngineOptions {
            workers,
            ..Default::default()
        };
        match name {
            "xla" | "xla-pallas" => Engine::xla(opts),
            "xla-jnp" => {
                opts.imp = Impl::Jnp;
                Engine::xla(opts)
            }
            "rust" => Ok(Engine::rust_with(opts)),
            other => Err(anyhow!("unknown engine {other:?} (xla, xla-jnp, rust)")),
        }
    }

    pub fn name(&self) -> String {
        match self {
            Engine::Xla { opts, .. } => format!("xla/{}", opts.imp.name()),
            Engine::Rust { .. } => "rust".into(),
        }
    }

    pub fn opts(&self) -> &EngineOptions {
        match self {
            Engine::Xla { opts, .. } => opts,
            Engine::Rust { opts } => opts,
        }
    }

    pub fn registry(&self) -> Option<&Registry> {
        match self {
            Engine::Xla { registry, .. } => Some(registry),
            Engine::Rust { .. } => None,
        }
    }

    /// Artifact spec + compiled executable for a request.
    fn compiled(
        &self,
        op: Op,
        kern: Kernel,
        m: usize,
        d: usize,
        n: usize,
    ) -> Result<(Rc<Exe>, usize, usize)> {
        let (registry, cache, opts) = match self {
            Engine::Xla {
                registry,
                cache,
                opts,
            } => (registry, cache, opts),
            Engine::Rust { .. } => unreachable!("compiled() on rust engine"),
        };
        let spec = match op {
            Op::Precond => registry.find_precond(m)?,
            // kmm artifacts exist only as jnp lowering
            Op::Kmm => registry.find(op, kern, Impl::Jnp, m, d, n)?,
            _ => registry.find(op, kern, opts.imp, m, d, n)?,
        };
        let key = spec.file.clone();
        if let Some(e) = cache.borrow().get(&key) {
            return Ok((e.clone(), spec.b, spec.d));
        }
        let exe = Rc::new(Exe::compile_file(&registry.path_of(spec), spec.name())?);
        cache.borrow_mut().insert(key, exe.clone());
        Ok((exe, spec.b, spec.d))
    }

    // ------------------------------------------------------------------
    // K_MM and the preconditioner
    // ------------------------------------------------------------------

    /// K_MM over the centers.
    pub fn kmm(&self, kern: Kernel, c: &Mat, param: f64) -> Result<Mat> {
        match self {
            Engine::Rust { .. } => Ok(kernels::kmm(kern, c, param)),
            Engine::Xla { .. } => {
                let m = c.rows;
                let (exe, _, d_art) = self.compiled(Op::Kmm, kern, m, c.cols, m)?;
                let c_pad = c.pad_cols(d_art);
                let c_lit = literal_from_f32(&c_pad.to_f32(), &[m, d_art])?;
                let p_lit = literal_scalar(param as f32);
                let out = exe.call1_f32(&[&c_lit, &p_lit])?;
                Ok(Mat::from_f32(m, m, &out))
            }
        }
    }

    /// Preconditioner factors (Eq. 13): upper-triangular (T, A) with
    /// TᵀT = K_MM + eps·M·I and AᵀA = TTᵀ/M + λI.
    ///
    /// The XLA path runs in f32; if the factorization comes back
    /// non-finite (ill-conditioned K_MM at f32), we escalate the jitter
    /// and finally fall back to the f64 Rust factorization — a fit must
    /// not die on a borderline K_MM.
    pub fn precond(&self, kmm: &Mat, lam: f64, eps: f64) -> Result<(Mat, Mat)> {
        let m = kmm.rows;
        match self {
            Engine::Rust { .. } => precond_rust(kmm, lam, eps),
            Engine::Xla { .. } => {
                let (exe, _, _) = self.compiled(Op::Precond, Kernel::Gaussian, m, 0, m)?;
                let kmm_lit = literal_from_f32(&kmm.to_f32(), &[m, m])?;
                let lam_lit = literal_scalar(lam as f32);
                let mut eps_try = eps;
                for _ in 0..3 {
                    let eps_lit = literal_scalar(eps_try as f32);
                    let outs = exe.call(&[&kmm_lit, &lam_lit, &eps_lit])?;
                    anyhow::ensure!(outs.len() == 2, "precond returned {} outputs", outs.len());
                    let t = Mat::from_f32(m, m, &literal_to_f32(&outs[0])?);
                    let a = Mat::from_f32(m, m, &literal_to_f32(&outs[1])?);
                    if t.is_finite() && a.is_finite() {
                        return Ok((t, a));
                    }
                    eps_try *= 100.0;
                }
                // last resort: f64 factorization on the coordinator
                precond_rust(kmm, lam, eps)
            }
        }
    }

    // ------------------------------------------------------------------
    // the blocked Nyström matvec (CG hot path)
    // ------------------------------------------------------------------

    /// Build the per-fit plan: rows of `x` split into artifact-sized
    /// blocks, padded, masked and uploaded once.
    pub fn matvec_plan<'a>(
        &'a self,
        kern: Kernel,
        x: &'a Mat,
        c: &Mat,
        param: f64,
    ) -> Result<MatvecPlan<'a>> {
        anyhow::ensure!(x.cols == c.cols, "x/c feature dims differ");
        let (n, m) = (x.rows, c.rows);
        match self {
            Engine::Rust { opts } => Ok(MatvecPlan::Rust(RustPlan {
                x,
                c: c.clone(),
                kern,
                param,
                block: 1024,
                n,
                m,
                workers: opts.workers,
            })),
            Engine::Xla { opts, .. } => {
                let (exe, b_art, d_art) = self.compiled(Op::KnmMatvec, kern, m, x.cols, n)?;
                let c_pad = c.pad_cols(d_art);
                let c_lit = literal_from_f32(&c_pad.to_f32(), &[m, d_art])?;
                let param_lit = literal_scalar(param as f32);
                let zeros_v = literal_from_f32(&vec![0.0; b_art], &[b_art])?;
                let mut blocks = Vec::new();
                let mut start = 0;
                while start < n {
                    let rows = (n - start).min(b_art);
                    let mut xbuf = vec![0.0f32; b_art * d_art];
                    for i in 0..rows {
                        for (j, &v) in x.row(start + i).iter().enumerate() {
                            xbuf[i * d_art + j] = v as f32;
                        }
                    }
                    let mut mask = vec![0.0f32; b_art];
                    mask[..rows].fill(1.0);
                    blocks.push(XlaBlock {
                        x: literal_from_f32(&xbuf, &[b_art, d_art])?,
                        mask: literal_from_f32(&mask, &[b_art])?,
                        start,
                        rows,
                    });
                    start += rows;
                }
                let _ = opts;
                Ok(MatvecPlan::Xla(XlaPlan {
                    exe,
                    c_lit,
                    param_lit,
                    zeros_v,
                    blocks,
                    b_art,
                    n,
                    m,
                }))
            }
        }
    }

    // ------------------------------------------------------------------
    // kernel blocks and prediction
    // ------------------------------------------------------------------

    /// Dense K(x, c) — used by the leverage-score sketch. Blocked on the
    /// XLA path through the kernel_block artifact.
    pub fn kernel_block(&self, kern: Kernel, x: &Mat, c: &Mat, param: f64) -> Result<Mat> {
        match self {
            Engine::Rust { .. } => Ok(kernels::kernel_block(kern, x, c, param)),
            Engine::Xla { .. } => {
                let mut out = Mat::zeros(x.rows, c.rows);
                self.for_kernel_blocks(kern, x, c, param, |start, rows, m, kr| {
                    for i in 0..rows {
                        for j in 0..m {
                            out[(start + i, j)] = kr[i * m + j] as f64;
                        }
                    }
                })?;
                Ok(out)
            }
        }
    }

    /// Blocked prediction f(x_i) = Σ_j α_j K(x_i, c_j).
    pub fn predict(
        &self,
        kern: Kernel,
        x: &Mat,
        c: &Mat,
        alpha: &[f64],
        param: f64,
    ) -> Result<Vec<f64>> {
        anyhow::ensure!(alpha.len() == c.rows, "alpha length");
        match self {
            Engine::Rust { .. } => Ok(kernels::predict(kern, x, c, alpha, param)),
            Engine::Xla { .. } => {
                let mut preds = vec![0.0f64; x.rows];
                self.for_kernel_blocks(kern, x, c, param, |start, rows, m, kr| {
                    for i in 0..rows {
                        let mut acc = 0.0;
                        for j in 0..m {
                            acc += kr[i * m + j] as f64 * alpha[j];
                        }
                        preds[start + i] = acc;
                    }
                })?;
                Ok(preds)
            }
        }
    }

    /// Shared streaming loop over kernel_block artifact calls.
    fn for_kernel_blocks(
        &self,
        kern: Kernel,
        x: &Mat,
        c: &Mat,
        param: f64,
        mut sink: impl FnMut(usize, usize, usize, &[f32]),
    ) -> Result<()> {
        let (n, m) = (x.rows, c.rows);
        let (exe, b_art, d_art) = self.compiled(Op::KernelBlock, kern, m, x.cols, n)?;
        let c_pad = c.pad_cols(d_art);
        let c_lit = literal_from_f32(&c_pad.to_f32(), &[m, d_art])?;
        let p_lit = literal_scalar(param as f32);
        let mut start = 0;
        let mut xbuf = vec![0.0f32; b_art * d_art];
        while start < n {
            let rows = (n - start).min(b_art);
            xbuf.fill(0.0);
            for i in 0..rows {
                for (j, &v) in x.row(start + i).iter().enumerate() {
                    xbuf[i * d_art + j] = v as f32;
                }
            }
            let x_lit = literal_from_f32(&xbuf, &[b_art, d_art])?;
            let kr = exe.call1_f32(&[&x_lit, &c_lit, &p_lit])?;
            sink(start, rows, m, &kr);
            start += rows;
        }
        Ok(())
    }
}

/// f64 preconditioner factorization with jitter escalation.
fn precond_rust(kmm: &Mat, lam: f64, eps: f64) -> Result<(Mat, Mat)> {
    let m = kmm.rows;
    let mut eps_try = eps;
    for _ in 0..6 {
        let mut kj = kmm.clone();
        kj.add_diag(eps_try * m as f64);
        if let Ok(t) = chol::cholesky_upper(&kj) {
            // A: chol(T Tᵀ / M + lam I)
            let mut tta = crate::linalg::gemm::matmul(&t, &t.t());
            tta.scale(1.0 / m as f64);
            tta.add_diag(lam);
            if let Ok(a) = chol::cholesky_upper(&tta) {
                return Ok((t, a));
            }
        }
        eps_try *= 100.0;
    }
    Err(anyhow!(
        "preconditioner factorization failed for M={m} even with jitter"
    ))
}

// ---------------------------------------------------------------------
// plans
// ---------------------------------------------------------------------

struct XlaBlock {
    x: xla::Literal,
    mask: xla::Literal,
    start: usize,
    rows: usize,
}

pub struct XlaPlan {
    exe: Rc<Exe>,
    c_lit: xla::Literal,
    param_lit: xla::Literal,
    zeros_v: xla::Literal,
    blocks: Vec<XlaBlock>,
    b_art: usize,
    n: usize,
    m: usize,
}

pub struct RustPlan<'a> {
    x: &'a Mat,
    c: Mat,
    kern: Kernel,
    param: f64,
    block: usize,
    n: usize,
    m: usize,
    workers: usize,
}

/// The per-fit blocked matvec: `apply` computes
/// `w = Σ_blocks Krᵀ(mask ⊙ (Kr·u + v_block))` (Alg. 1's
/// KnM_times_vector). `v = None` means zeros (the CG iteration);
/// `v = Some(y/n)` builds the right-hand side.
pub enum MatvecPlan<'a> {
    Xla(XlaPlan),
    Rust(RustPlan<'a>),
}

impl<'a> MatvecPlan<'a> {
    pub fn n(&self) -> usize {
        match self {
            MatvecPlan::Xla(p) => p.n,
            MatvecPlan::Rust(p) => p.n,
        }
    }

    pub fn m(&self) -> usize {
        match self {
            MatvecPlan::Xla(p) => p.m,
            MatvecPlan::Rust(p) => p.m,
        }
    }

    pub fn n_blocks(&self) -> usize {
        match self {
            MatvecPlan::Xla(p) => p.blocks.len(),
            MatvecPlan::Rust(p) => p.n.div_ceil(p.block),
        }
    }

    /// Kernel evaluations one `apply` performs (bench accounting; the XLA
    /// path pays for padded rows too, and evaluates each block twice —
    /// once per fused stage).
    pub fn kernel_evals_per_apply(&self) -> usize {
        match self {
            MatvecPlan::Xla(p) => p.blocks.len() * p.b_art * p.m * 2,
            MatvecPlan::Rust(p) => p.n * p.m,
        }
    }

    pub fn apply(&self, u: &[f64], v: Option<&[f64]>) -> Result<Vec<f64>> {
        match self {
            MatvecPlan::Rust(p) => p.apply(u, v),
            MatvecPlan::Xla(p) => p.apply(u, v),
        }
    }
}

impl XlaPlan {
    fn apply(&self, u: &[f64], v: Option<&[f64]>) -> Result<Vec<f64>> {
        anyhow::ensure!(u.len() == self.m, "u length {} != M {}", u.len(), self.m);
        if let Some(v) = v {
            anyhow::ensure!(v.len() == self.n, "v length {} != n {}", v.len(), self.n);
        }
        let u32v: Vec<f32> = u.iter().map(|&x| x as f32).collect();
        let u_lit = literal_from_f32(&u32v, &[self.m])?;
        let mut w = vec![0.0f64; self.m];
        let mut vbuf = vec![0.0f32; self.b_art];
        for blk in &self.blocks {
            let v_lit;
            let v_ref: &xla::Literal = match v {
                None => &self.zeros_v,
                Some(vfull) => {
                    vbuf.fill(0.0);
                    for i in 0..blk.rows {
                        vbuf[i] = vfull[blk.start + i] as f32;
                    }
                    v_lit = literal_from_f32(&vbuf, &[self.b_art])?;
                    &v_lit
                }
            };
            let part = self
                .exe
                .call1_f32(&[
                    &blk.x,
                    &self.c_lit,
                    &u_lit,
                    v_ref,
                    &blk.mask,
                    &self.param_lit,
                ])
                .with_context(|| format!("block @{}", blk.start))?;
            for j in 0..self.m {
                w[j] += part[j] as f64;
            }
        }
        Ok(w)
    }
}

impl<'a> RustPlan<'a> {
    fn apply(&self, u: &[f64], v: Option<&[f64]>) -> Result<Vec<f64>> {
        anyhow::ensure!(u.len() == self.m, "u length");
        let ranges: Vec<(usize, usize)> = (0..self.n)
            .step_by(self.block)
            .map(|s| (s, (s + self.block).min(self.n)))
            .collect();
        let workers = self.workers.max(1).min(ranges.len().max(1));
        let run = |&(s, e): &(usize, usize)| -> Vec<f64> {
            let xb = self.x.slice_rows(s, e);
            let vb: Vec<f64> = match v {
                Some(vf) => vf[s..e].to_vec(),
                None => vec![0.0; e - s],
            };
            kernels::knm_matvec(self.kern, &xb, &self.c, u, &vb, None, self.param)
        };
        let mut w = vec![0.0f64; self.m];
        if workers <= 1 {
            for r in &ranges {
                let part = run(r);
                for j in 0..self.m {
                    w[j] += part[j];
                }
            }
        } else {
            let partials: Vec<Vec<f64>> = std::thread::scope(|sc| {
                let chunks: Vec<&[(usize, usize)]> =
                    ranges.chunks(ranges.len().div_ceil(workers)).collect();
                let handles: Vec<_> = chunks
                    .into_iter()
                    .map(|chunk| {
                        sc.spawn(move || {
                            let mut acc = vec![0.0f64; self.m];
                            for r in chunk {
                                let part = run(r);
                                for j in 0..self.m {
                                    acc[j] += part[j];
                                }
                            }
                            acc
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for p in partials {
                for j in 0..self.m {
                    w[j] += p[j];
                }
            }
        }
        Ok(w)
    }
}

/// Apply the preconditioned operator (Alg. 2's BHB, generalized per
/// Def. 3 with the leverage-score reweighting D and the rank-deficient
/// partial isometry Q from appendix A / Example 2):
///
///   BᵀHB u = Aᵀ\(Tᵀ\(Qᵀ·D·matvec(D·Q·(T\(A\u)), 0))/n + λ(A\u))
///
/// where Q·TᵀT·Qᵀ = D·K_MM·D (Q = I, TᵀT = D·K_MM·D + εMI on the
/// full-rank Cholesky path) and AᵀA = TTᵀ/M + λI. With uniform sampling
/// D = I (`d = None`) and Q = I (`q = None`) this is exactly Alg. 1/2.
/// Shared by the estimator and the condition-number diagnostics.
pub struct Bhb<'p, 'a> {
    pub plan: &'p MatvecPlan<'a>,
    /// q×q upper-triangular (diagonal on the eig path)
    pub t: &'p Mat,
    /// q×q upper-triangular (diagonal on the eig path)
    pub a: &'p Mat,
    pub lam: f64,
    /// Def. 2 diagonal reweighting (leverage-score sampling); None = I
    pub d: Option<&'p [f64]>,
    /// M×q partial isometry from the rank-revealing preconditioner
    /// (Example 2); None = identity (full-rank path)
    pub q: Option<&'p Mat>,
}

impl<'p, 'a> Bhb<'p, 'a> {
    fn dmul(&self, v: &mut [f64]) {
        if let Some(d) = self.d {
            for (x, w) in v.iter_mut().zip(d) {
                *x *= w;
            }
        }
    }

    /// rank of the preconditioned system (q ≤ M)
    pub fn rank(&self) -> usize {
        self.t.rows
    }

    /// lift a q-vector to R^M through Q (no-op when Q = I)
    fn q_lift(&self, v: &[f64]) -> Vec<f64> {
        match self.q {
            None => v.to_vec(),
            Some(q) => crate::linalg::gemm::matvec(q, v),
        }
    }

    /// project an M-vector to R^q through Qᵀ (no-op when Q = I)
    fn q_proj(&self, v: &[f64]) -> Vec<f64> {
        match self.q {
            None => v.to_vec(),
            Some(q) => crate::linalg::gemm::matvec_t(q, v),
        }
    }

    pub fn apply(&self, u: &[f64]) -> Result<Vec<f64>> {
        let n = self.plan.n() as f64;
        let au = tri::solve_upper(self.a, u); // A\u
        let tau = tri::solve_upper(self.t, &au); // T\(A\u)
        let mut lifted = self.q_lift(&tau); // Q·
        self.dmul(&mut lifted); // D·
        let mut w = self.plan.apply(&lifted, None)?; // KnMᵀKnM ·
        self.dmul(&mut w); // D·
        let wq = self.q_proj(&w); // Qᵀ·
        let mut inner = tri::solve_lower_t(self.t, &wq); // Tᵀ\ ·
        for j in 0..inner.len() {
            inner[j] = inner[j] / n + self.lam * au[j];
        }
        Ok(tri::solve_lower_t(self.a, &inner)) // Aᵀ\ ·
    }

    /// Right-hand side r = Aᵀ\(Tᵀ\(Qᵀ·D·KnMᵀ(y/n))).
    pub fn rhs(&self, y: &[f64]) -> Result<Vec<f64>> {
        let n = self.plan.n() as f64;
        let yn: Vec<f64> = y.iter().map(|v| v / n).collect();
        let zeros = vec![0.0; self.plan.m()];
        let mut w = self.plan.apply(&zeros, Some(&yn))?;
        self.dmul(&mut w);
        let wq = self.q_proj(&w);
        let ti = tri::solve_lower_t(self.t, &wq);
        Ok(tri::solve_lower_t(self.a, &ti))
    }

    /// Map CG solution β back to Nyström coefficients α = D·Q·(T\(A\β)).
    pub fn beta_to_alpha(&self, beta: &[f64]) -> Vec<f64> {
        let ab = tri::solve_upper(self.a, beta);
        let tb = tri::solve_upper(self.t, &ab);
        let mut alpha = self.q_lift(&tb);
        self.dmul(&mut alpha);
        alpha
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn toy(n: usize, d: usize, seed: u64) -> (Mat, Mat, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let x = Mat::from_vec(n, d, rng.normals(n * d));
        let c = x.select_rows(&rng.choose(n, 8.min(n)));
        let y = rng.normals(n);
        (x, c, y)
    }

    #[test]
    fn rust_plan_matches_dense() {
        let (x, c, y) = toy(300, 5, 1);
        let eng = Engine::rust();
        let plan = eng.matvec_plan(Kernel::Gaussian, &x, &c, 1.0).unwrap();
        let mut rng = Rng::new(2);
        let u = rng.normals(c.rows);
        let got = plan.apply(&u, Some(&y)).unwrap();

        let kr = kernels::kernel_block(Kernel::Gaussian, &x, &c, 1.0);
        let mut yv = crate::linalg::gemm::matvec(&kr, &u);
        for i in 0..x.rows {
            yv[i] += y[i];
        }
        let want = crate::linalg::gemm::matvec_t(&kr, &yv);
        for j in 0..c.rows {
            assert!((got[j] - want[j]).abs() < 1e-8);
        }
    }

    #[test]
    fn rust_plan_parallel_matches_serial() {
        let (x, c, _) = toy(2500, 4, 3);
        let eng1 = Engine::rust();
        let eng4 = Engine::rust_with(EngineOptions {
            imp: Impl::Pallas,
            workers: 4,
        });
        let mut rng = Rng::new(4);
        let u = rng.normals(c.rows);
        let p1 = eng1.matvec_plan(Kernel::Gaussian, &x, &c, 1.3).unwrap();
        let p4 = eng4.matvec_plan(Kernel::Gaussian, &x, &c, 1.3).unwrap();
        let w1 = p1.apply(&u, None).unwrap();
        let w4 = p4.apply(&u, None).unwrap();
        for j in 0..c.rows {
            assert!((w1[j] - w4[j]).abs() < 1e-9);
        }
    }

    #[test]
    fn rust_precond_factors() {
        let mut rng = Rng::new(5);
        let c = Mat::from_vec(10, 3, rng.normals(30));
        let kmm = kernels::kmm(Kernel::Gaussian, &c, 1.0);
        let eng = Engine::rust();
        let (t, a) = eng.precond(&kmm, 1e-3, 1e-10).unwrap();
        // TᵀT ≈ KMM
        let back = crate::linalg::gemm::matmul(&t.t(), &t);
        assert!(back.max_abs_diff(&kmm) < 1e-6);
        let mut tta = crate::linalg::gemm::matmul(&t, &t.t());
        tta.scale(0.1);
        tta.add_diag(1e-3);
        let back_a = crate::linalg::gemm::matmul(&a.t(), &a);
        assert!(back_a.max_abs_diff(&tta) < 1e-8);
    }

    #[test]
    fn rust_precond_rank_deficient() {
        // duplicated centers -> singular KMM; jitter must save it
        let mut rng = Rng::new(6);
        let base = Mat::from_vec(5, 3, rng.normals(15));
        let mut rows: Vec<Vec<f64>> = (0..5).map(|i| base.row(i).to_vec()).collect();
        rows.push(base.row(0).to_vec());
        rows.push(base.row(1).to_vec());
        let c = Mat::from_rows(&rows);
        let kmm = kernels::kmm(Kernel::Gaussian, &c, 1.0);
        let eng = Engine::rust();
        let (t, a) = eng.precond(&kmm, 1e-4, 1e-12).unwrap();
        assert!(t.is_finite() && a.is_finite());
    }

    #[test]
    fn engine_by_name() {
        assert!(Engine::by_name("rust", 1).is_ok());
        assert!(Engine::by_name("bogus", 1).is_err());
    }

    #[test]
    fn bhb_is_symmetric_positive() {
        let (x, c, _) = toy(200, 4, 7);
        let eng = Engine::rust();
        let kmm = eng.kmm(Kernel::Gaussian, &c, 1.0).unwrap();
        let lam = 1e-2;
        let (t, a) = eng.precond(&kmm, lam, 1e-10).unwrap();
        let plan = eng.matvec_plan(Kernel::Gaussian, &x, &c, 1.0).unwrap();
        let bhb = Bhb {
            plan: &plan,
            t: &t,
            a: &a,
            lam,
            d: None,
            q: None,
        };
        let m = c.rows;
        // materialize W and check symmetry + positive diagonal
        let mut w = Mat::zeros(m, m);
        for j in 0..m {
            let mut e = vec![0.0; m];
            e[j] = 1.0;
            let col = bhb.apply(&e).unwrap();
            for i in 0..m {
                w[(i, j)] = col[i];
            }
        }
        for i in 0..m {
            assert!(w[(i, i)] > 0.0);
            for j in 0..m {
                assert!((w[(i, j)] - w[(j, i)]).abs() < 1e-7, "asym at {i},{j}");
            }
        }
    }

    #[test]
    fn bhb_close_to_identity_in_falkon_regime() {
        // Thm. 2: with M >~ 1/lam, W = I + E with ||E|| < 1.
        let mut rng = Rng::new(8);
        let n = 400;
        let x = Mat::from_vec(n, 3, rng.normals(n * 3));
        let c = x.select_rows(&rng.choose(n, 60));
        let lam = 1.0 / (n as f64).sqrt();
        let eng = Engine::rust();
        let kmm = eng.kmm(Kernel::Gaussian, &c, 1.0).unwrap();
        let (t, a) = eng.precond(&kmm, lam, 1e-10).unwrap();
        let plan = eng.matvec_plan(Kernel::Gaussian, &x, &c, 1.0).unwrap();
        let bhb = Bhb {
            plan: &plan,
            t: &t,
            a: &a,
            lam,
            d: None,
            q: None,
        };
        let m = c.rows;
        let mut max_offdiag = 0.0f64;
        let mut diag_dev = 0.0f64;
        for j in 0..m {
            let mut e = vec![0.0; m];
            e[j] = 1.0;
            let col = bhb.apply(&e).unwrap();
            for i in 0..m {
                if i == j {
                    diag_dev = diag_dev.max((col[i] - 1.0).abs());
                } else {
                    max_offdiag = max_offdiag.max(col[i].abs());
                }
            }
        }
        assert!(diag_dev < 0.9, "diag deviation {diag_dev}");
        assert!(max_offdiag < 0.9, "offdiag {max_offdiag}");
    }
}

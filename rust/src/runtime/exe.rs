//! PJRT executable wrapper: HLO-text loading, literal marshalling, typed
//! call helpers, and the (documented) `Send + Sync` wrapper that lets the
//! worker pool share compiled executables.

use anyhow::{anyhow, Context, Result};
use std::cell::RefCell;
use std::path::Path;

thread_local! {
    static CLIENT: RefCell<Option<xla::PjRtClient>> = const { RefCell::new(None) };
}

/// Shared PJRT CPU client (one per thread — the `xla` crate's client is an
/// `Rc` handle, so it must not cross threads; all PJRT work is dispatched
/// from the thread that owns the engine. The Rust engine's worker pool is
/// where multi-threading happens instead — see DESIGN.md §Perf).
pub fn client() -> Result<xla::PjRtClient> {
    CLIENT.with(|slot| {
        let mut slot = slot.borrow_mut();
        if slot.is_none() {
            *slot = Some(
                xla::PjRtClient::cpu().map_err(|e| anyhow!("creating PJRT CPU client: {e}"))?,
            );
        }
        Ok(slot.as_ref().unwrap().clone())
    })
}

/// A compiled artifact (immutable once built; single-thread use).
pub struct Exe {
    inner: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Exe {
    /// Load HLO text from a file and compile it on the shared CPU client.
    pub fn compile_file(path: &Path, name: &str) -> Result<Exe> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow!("non-utf8 path {path:?}"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client()?
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", path.display()))?;
        Ok(Exe {
            inner: exe,
            name: name.to_string(),
        })
    }

    /// Execute with literal inputs; returns the tuple elements of the
    /// single output (jax lowers with return_tuple=True). Takes references
    /// so prepared literals are reused across calls without copying.
    pub fn call(&self, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs = self
            .inner
            .execute::<&xla::Literal>(args)
            .map_err(|e| anyhow!("executing {}: {e}", self.name))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching output of {}: {e}", self.name))?;
        lit.to_tuple()
            .map_err(|e| anyhow!("untupling output of {}: {e}", self.name))
    }

    /// Execute expecting exactly one output array, returned as f32s.
    pub fn call1_f32(&self, args: &[&xla::Literal]) -> Result<Vec<f32>> {
        let mut outs = self.call(args)?;
        if outs.len() != 1 {
            return Err(anyhow!("{}: expected 1 output, got {}", self.name, outs.len()));
        }
        literal_to_f32(&outs.pop().unwrap()).context(self.name.clone())
    }
}

/// f32 slice -> rank-N literal.
pub fn literal_from_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "literal shape {dims:?} vs len {}", data.len());
    let flat = xla::Literal::vec1(data);
    if dims.len() == 1 {
        return Ok(flat);
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    flat.reshape(&dims_i64)
        .map_err(|e| anyhow!("reshape to {dims:?}: {e}"))
}

pub fn literal_scalar(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

pub fn literal_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("literal to f32: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_ready() -> Option<crate::runtime::spec::Registry> {
        crate::runtime::spec::Registry::load_default().ok()
    }

    #[test]
    fn literal_roundtrip() {
        let l = literal_from_f32(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        assert_eq!(l.element_count(), 6);
        assert_eq!(literal_to_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn literal_shape_mismatch_errors() {
        assert!(literal_from_f32(&[1.0], &[2, 3]).is_err());
    }

    #[test]
    fn compile_and_run_tiny_matvec() {
        // Integration smoke: needs `make artifacts`.
        let Some(reg) = artifacts_ready() else { return };
        let spec = reg
            .find(
                crate::runtime::spec::Op::KnmMatvec,
                crate::kernels::Kernel::Gaussian,
                crate::runtime::spec::Impl::Pallas,
                32,
                8,
                64,
            )
            .unwrap();
        let exe = Exe::compile_file(&reg.path_of(spec), spec.name()).unwrap();
        let (b, m, d) = (spec.b, spec.m, spec.d);
        let x = literal_from_f32(&vec![0.1; b * d], &[b, d]).unwrap();
        let c = literal_from_f32(&vec![0.2; m * d], &[m, d]).unwrap();
        let u = literal_from_f32(&vec![0.0; m], &[m]).unwrap();
        let v = literal_from_f32(&vec![1.0; b], &[b]).unwrap();
        let mask = literal_from_f32(&vec![1.0; b], &[b]).unwrap();
        let p = literal_scalar(1.0);
        let w = exe.call1_f32(&[&x, &c, &u, &v, &mask, &p]).unwrap();
        assert_eq!(w.len(), m);
        // identical rows/centers: w_j = sum_i K(x_i, c_j) * 1, all equal & positive
        assert!(w[0] > 0.0);
        for j in 1..m {
            assert!((w[j] - w[0]).abs() < 1e-3);
        }
    }
}

//! Runtime: the [`Engine`]/[`MatvecPlan`] compute abstraction the FALKON
//! coordinator drives, the artifact registry, and (behind the `xla` cargo
//! feature) the PJRT executable cache + literal marshalling. Python never
//! runs here — artifacts are HLO text produced once by `make artifacts`.
//! Without the `xla` feature only the pure-Rust tiled engine is built.
//!
//! Plans come in three shapes: the in-memory Rust plan (row blocks sliced
//! once, served by the shared worker pool), the XLA plan (blocks uploaded
//! as literals), and the **streaming plan** (`Engine::matvec_plan_source`)
//! that re-reads a chunked [`crate::data::DataSource`] every apply so
//! only O(chunk) features stay resident (DESIGN.md § "Out-of-core path").
pub mod engine;
#[cfg(feature = "xla")]
pub mod exe;
pub mod spec;

pub use crate::kernels::simd::{Isa, SimdMode};
pub use engine::{Bhb, Engine, EngineOptions, MatvecPlan};
pub use spec::{ArtifactSpec, Impl, Op, Registry};

//! Runtime: the [`Engine`]/[`MatvecPlan`] compute abstraction the FALKON
//! coordinator drives, the artifact registry, and (behind the `xla` cargo
//! feature) the PJRT executable cache + literal marshalling. Python never
//! runs here — artifacts are HLO text produced once by `make artifacts`.
//! Without the `xla` feature only the pure-Rust tiled engine is built.
pub mod engine;
#[cfg(feature = "xla")]
pub mod exe;
pub mod spec;

pub use engine::{Bhb, Engine, EngineOptions, MatvecPlan};
pub use spec::{ArtifactSpec, Impl, Op, Registry};

//! PJRT runtime: artifact registry, compiled-executable cache, literal
//! marshalling, and the [`Engine`]/[`MatvecPlan`] compute abstraction that
//! the FALKON coordinator drives. Python never runs here — artifacts are
//! HLO text produced once by `make artifacts`.
pub mod engine;
pub mod exe;
pub mod spec;

pub use engine::{Bhb, Engine, EngineOptions, MatvecPlan};
pub use spec::{ArtifactSpec, Impl, Op, Registry};

//! Artifact manifest: the rust-side mirror of `python/compile/manifest.py`.
//!
//! `make artifacts` writes `artifacts/manifest.json` describing every
//! AOT-lowered HLO module (op, kernel, impl, static shapes, file). The
//! registry here parses it and answers "which artifact serves this
//! request?" under the padding rules of the artifact contract
//! (DESIGN.md §2): rows padded+masked, features zero-padded up to the
//! artifact D, M matched exactly.

use crate::kernels::Kernel;
use crate::util::json::{self, Value};
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Which op an artifact implements (mirror of the python `op` field).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Op {
    KnmMatvec,
    KernelBlock,
    Kmm,
    Precond,
}

impl Op {
    pub fn parse(s: &str) -> Option<Op> {
        match s {
            "knm_matvec" => Some(Op::KnmMatvec),
            "kernel_block" => Some(Op::KernelBlock),
            "kmm" => Some(Op::Kmm),
            "precond" => Some(Op::Precond),
            _ => None,
        }
    }
}

/// Kernel-op implementation variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Impl {
    /// tiled Pallas kernels (interpret-mode lowering) — the default
    Pallas,
    /// plain-XLA lowering of the same math
    Jnp,
}

impl Impl {
    pub fn parse(s: &str) -> Option<Impl> {
        match s {
            "pallas" => Some(Impl::Pallas),
            "jnp" => Some(Impl::Jnp),
            _ => None,
        }
    }
    pub fn name(self) -> &'static str {
        match self {
            Impl::Pallas => "pallas",
            Impl::Jnp => "jnp",
        }
    }
}

/// One artifact (one HLO file with static shapes).
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub op: Op,
    pub kern: Option<Kernel>,
    pub imp: Impl,
    pub b: usize,
    pub m: usize,
    pub d: usize,
    pub file: String,
}

impl ArtifactSpec {
    pub fn name(&self) -> &str {
        self.file.trim_end_matches(".hlo.txt")
    }
}

/// Parsed manifest + lookup logic.
#[derive(Debug)]
pub struct Registry {
    pub dir: PathBuf,
    pub block: usize,
    pub test_block: usize,
    pub entries: Vec<ArtifactSpec>,
}

/// Locate the artifacts directory: `$FALKON_ARTIFACTS`, then `./artifacts`,
/// then `<crate root>/artifacts`.
pub fn default_dir() -> Result<PathBuf> {
    if let Ok(p) = std::env::var("FALKON_ARTIFACTS") {
        return Ok(PathBuf::from(p));
    }
    for cand in ["artifacts", concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")] {
        let p = PathBuf::from(cand);
        if p.join("manifest.json").exists() {
            return Ok(p);
        }
    }
    bail!(
        "artifacts/manifest.json not found — run `make artifacts` \
         (or set FALKON_ARTIFACTS)"
    )
}

impl Registry {
    pub fn load_default() -> Result<Registry> {
        Registry::load(&default_dir()?)
    }

    pub fn load(dir: &Path) -> Result<Registry> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = json::parse(&text).map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let block = v
            .get("block")
            .as_usize()
            .ok_or_else(|| anyhow!("manifest missing 'block'"))?;
        let test_block = v.get("test_block").as_usize().unwrap_or(block);
        let mut entries = Vec::new();
        for row in v
            .get("entries")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest missing 'entries'"))?
        {
            entries.push(parse_entry(row)?);
        }
        Ok(Registry {
            dir: dir.to_path_buf(),
            block,
            test_block,
            entries,
        })
    }

    /// All center counts available for an op/kernel pair, ascending.
    pub fn available_ms(&self, op: Op, kern: Kernel) -> Vec<usize> {
        let mut ms: Vec<usize> = self
            .entries
            .iter()
            .filter(|e| e.op == op && e.kern == Some(kern))
            .map(|e| e.m)
            .collect();
        ms.sort_unstable();
        ms.dedup();
        ms
    }

    /// Center counts usable end-to-end for a (kernel, d) pair — i.e. with
    /// matvec, kernel_block, kmm and precond artifacts all present.
    pub fn usable_ms(&self, kern: Kernel, d: usize) -> Vec<usize> {
        let has = |op: Op, m: usize| {
            self.entries.iter().any(|e| {
                e.op == op
                    && e.m == m
                    && (op == Op::Precond || (e.kern == Some(kern) && e.d >= d))
            })
        };
        let mut ms = self.available_ms(Op::KnmMatvec, kern);
        ms.retain(|&m| has(Op::KernelBlock, m) && has(Op::Kmm, m) && has(Op::Precond, m));
        ms
    }

    /// Pick the artifact for a data-touching op: exact (op, kern, impl, m),
    /// smallest compiled d >= the dataset d, and the row-block size that
    /// fits `n` best (the tiny test block when the whole problem fits it).
    pub fn find(
        &self,
        op: Op,
        kern: Kernel,
        imp: Impl,
        m: usize,
        d: usize,
        n: usize,
    ) -> Result<&ArtifactSpec> {
        let mut best: Option<&ArtifactSpec> = None;
        for e in &self.entries {
            if e.op != op || e.kern != Some(kern) || e.imp != imp || e.m != m || e.d < d {
                continue;
            }
            let better = match best {
                None => true,
                Some(b) => {
                    // prefer smaller padded d; then prefer block size
                    // test_block iff n fits in it, else the full block
                    let want_b = if n <= self.test_block {
                        self.test_block
                    } else {
                        self.block
                    };
                    (e.d, (e.b != want_b) as u8) < (b.d, (b.b != want_b) as u8)
                }
            };
            if better {
                best = Some(e);
            }
        }
        best.ok_or_else(|| {
            anyhow!(
                "no artifact for op={op:?} kern={} impl={} M={m} d>={d}; \
                 available M for this op/kernel: {:?} — adjust the config to a \
                 compiled M (python/compile/manifest.py) and rerun `make artifacts`",
                kern.name(),
                imp.name(),
                self.available_ms(op, kern),
            )
        })
    }

    /// Pick the preconditioner artifact (shape keyed by M only).
    pub fn find_precond(&self, m: usize) -> Result<&ArtifactSpec> {
        self.entries
            .iter()
            .find(|e| e.op == Op::Precond && e.m == m)
            .ok_or_else(|| {
                let mut ms: Vec<usize> = self
                    .entries
                    .iter()
                    .filter(|e| e.op == Op::Precond)
                    .map(|e| e.m)
                    .collect();
                ms.sort_unstable();
                anyhow!("no precond artifact for M={m}; available: {ms:?}")
            })
    }

    pub fn path_of(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

fn parse_entry(row: &Value) -> Result<ArtifactSpec> {
    let op_s = row
        .get("op")
        .as_str()
        .ok_or_else(|| anyhow!("entry missing op"))?;
    let op = Op::parse(op_s).ok_or_else(|| anyhow!("unknown op {op_s}"))?;
    let kern = match row.get("kern").as_str() {
        Some("") | None => None,
        Some(k) => Some(Kernel::parse(k).ok_or_else(|| anyhow!("unknown kernel {k}"))?),
    };
    let imp = Impl::parse(row.get("impl").as_str().unwrap_or("jnp"))
        .ok_or_else(|| anyhow!("unknown impl"))?;
    Ok(ArtifactSpec {
        op,
        kern,
        imp,
        b: row.get("b").as_usize().unwrap_or(0),
        m: row.get("m").as_usize().ok_or_else(|| anyhow!("missing m"))?,
        d: row.get("d").as_usize().unwrap_or(0),
        file: row
            .get("file")
            .as_str()
            .ok_or_else(|| anyhow!("missing file"))?
            .to_string(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_registry() -> Registry {
        let mk = |op, kern, imp, b, m, d| ArtifactSpec {
            op,
            kern,
            imp,
            b,
            m,
            d,
            file: format!("{op:?}_{b}_{m}_{d}.hlo.txt"),
        };
        Registry {
            dir: PathBuf::from("/nonexistent"),
            block: 1024,
            test_block: 64,
            entries: vec![
                mk(Op::KnmMatvec, Some(Kernel::Gaussian), Impl::Pallas, 64, 32, 8),
                mk(Op::KnmMatvec, Some(Kernel::Gaussian), Impl::Pallas, 64, 256, 32),
                mk(Op::KnmMatvec, Some(Kernel::Gaussian), Impl::Pallas, 1024, 256, 32),
                mk(Op::KnmMatvec, Some(Kernel::Gaussian), Impl::Pallas, 1024, 256, 128),
                mk(Op::KernelBlock, Some(Kernel::Gaussian), Impl::Pallas, 1024, 256, 32),
                mk(Op::Kmm, Some(Kernel::Gaussian), Impl::Jnp, 0, 256, 32),
                mk(Op::Precond, None, Impl::Jnp, 0, 256, 0),
            ],
        }
    }

    #[test]
    fn find_prefers_smallest_d() {
        let r = toy_registry();
        let e = r
            .find(Op::KnmMatvec, Kernel::Gaussian, Impl::Pallas, 256, 20, 5000)
            .unwrap();
        assert_eq!(e.d, 32);
    }

    #[test]
    fn find_prefers_block_matching_n() {
        let r = toy_registry();
        let small = r
            .find(Op::KnmMatvec, Kernel::Gaussian, Impl::Pallas, 256, 32, 50)
            .unwrap();
        assert_eq!(small.b, 64);
        let big = r
            .find(Op::KnmMatvec, Kernel::Gaussian, Impl::Pallas, 256, 32, 50_000)
            .unwrap();
        assert_eq!(big.b, 1024);
    }

    #[test]
    fn find_errors_list_available_ms() {
        let r = toy_registry();
        let err = r
            .find(Op::KnmMatvec, Kernel::Gaussian, Impl::Pallas, 999, 8, 100)
            .unwrap_err()
            .to_string();
        assert!(err.contains("M=999"), "{err}");
        assert!(err.contains("256"), "{err}");
    }

    #[test]
    fn usable_ms_requires_all_ops() {
        let r = toy_registry();
        assert_eq!(r.usable_ms(Kernel::Gaussian, 10), vec![256]);
        // d too large for any kernel_block artifact
        assert!(r.usable_ms(Kernel::Gaussian, 256).is_empty());
    }

    #[test]
    fn parses_real_manifest_when_present() {
        if let Ok(reg) = Registry::load_default() {
            assert!(reg.entries.len() > 100);
            assert_eq!(reg.block, 1024);
            let ms = reg.usable_ms(Kernel::Gaussian, 90);
            assert!(ms.contains(&1024), "{ms:?}");
            // every referenced file exists
            for e in reg.entries.iter().take(20) {
                assert!(reg.path_of(e).exists(), "{}", e.file);
            }
        }
    }
}

//! The shared admission batcher behind every serving front end.
//!
//! Extracted from the original `serve_loop` so the in-process channel
//! servers ([`super::Server`], [`super::MulticlassServer`]) and the
//! network front door ([`super::net::NetServer`]) run the **same**
//! batching logic: gather one request (polling the stop channel at
//! [`IDLE_POLL`] cadence while idle), linger up to `max_wait` for
//! stragglers until `max_batch` *rows* are admitted, stack every
//! admitted row into one row-block, and run a single blocked predict —
//! the `MulticlassServer` panel-amortization trick (DESIGN.md §Perf
//! "Multi-RHS path"), applied across requests and across connections.
//!
//! Requests are weighted by row count, so a 32-row batch request fills
//! the admission budget as fast as 32 single-row requests and the sweep
//! size stays panel-shaped regardless of how clients chop their load.
//!
//! The worker reads its model from a [`ModelSlot`] snapshot taken once
//! per executed batch, which is what makes registry hot-swap atomic
//! from the client's point of view: answers within one batch (and hence
//! within one request) always come from a single model generation.

use super::registry::{ModelSlot, ServedModel};
use super::{panic_msg, ClassPrediction, ServeConfig, ServeEvent, ServeStats};
use crate::linalg::mat::Mat;
use crate::runtime::{Engine, EngineOptions};
use crate::util::fault::FaultError;
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Idle poll granularity: while the request queue is empty the serve
/// loop re-checks its stop channel at this cadence, bounding how long
/// `stop()` can block when live client handles keep the queue open.
pub(crate) const IDLE_POLL: Duration = Duration::from_millis(20);

/// One queued prediction request: `rows` feature rows, row-major.
/// Single-row clients ([`super::Handle`]) send `rows == 1`; the network
/// batch ops send many rows per request.
pub(crate) struct RowsRequest {
    pub x: Vec<f64>,
    pub rows: usize,
    pub reply: Sender<Result<RowsReply>>,
}

/// Per-request answer, one entry per request row.
pub(crate) enum RowsReply {
    /// regression predictions
    Scalars(Vec<f64>),
    /// multiclass argmax + per-class scores
    Classes(Vec<ClassPrediction>),
}

/// Outcome of one admission-gather attempt.
pub(crate) enum Gathered<R> {
    Batch(Vec<R>),
    /// queue empty for one idle poll — re-check stop and try again
    Idle,
    /// every producer handle dropped — first-class shutdown path
    Disconnected,
    /// explicit stop signal received
    Stopped,
}

/// Admission batching policy (from [`ServeConfig`]): collect up to
/// `max_batch` rows, waiting at most `max_wait` for stragglers after
/// the first request of a batch arrives.
pub(crate) struct Batcher {
    max_batch: usize,
    max_wait: Duration,
}

impl Batcher {
    pub fn new(cfg: &ServeConfig) -> Batcher {
        Batcher {
            max_batch: cfg.max_batch.max(1),
            max_wait: cfg.max_wait,
        }
    }

    /// Gather one batch. `weight` is the row-count contribution of a
    /// request (1 for single-row front ends); a single request heavier
    /// than `max_batch` is still admitted whole, as its own sweep.
    pub fn gather<R>(
        &self,
        rx: &Receiver<R>,
        stop: &Receiver<()>,
        weight: impl Fn(&R) -> usize,
    ) -> Gathered<R> {
        if stop.try_recv().is_ok() {
            return Gathered::Stopped;
        }
        // block for the first request of the batch
        let first = match rx.recv_timeout(IDLE_POLL) {
            Ok(r) => r,
            Err(RecvTimeoutError::Timeout) => return Gathered::Idle,
            Err(RecvTimeoutError::Disconnected) => return Gathered::Disconnected,
        };
        let mut rows = weight(&first);
        let mut batch = vec![first];
        // then linger for stragglers up to max_batch rows / max_wait
        let deadline = Instant::now() + self.max_wait;
        while rows < self.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => {
                    rows += weight(&r);
                    batch.push(r);
                }
                Err(_) => break,
            }
        }
        Gathered::Batch(batch)
    }
}

/// Live serving counters shared between a model worker and the stats
/// front ends (the channel servers snapshot at `stop()`; the network
/// stats op snapshots while serving).
#[derive(Default)]
pub(crate) struct StatsCell {
    pub requests: AtomicU64,
    pub rejected: AtomicU64,
    pub batches: AtomicU64,
    pub rows: AtomicU64,
    pub engine_fallbacks: AtomicU64,
}

impl StatsCell {
    pub fn snapshot(&self) -> ServeStats {
        let batches = self.batches.load(Ordering::Relaxed);
        let rows = self.rows.load(Ordering::Relaxed);
        ServeStats {
            requests: self.requests.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            batches,
            rows,
            mean_batch: if batches > 0 {
                rows as f64 / batches as f64
            } else {
                0.0
            },
            engine_fallbacks: self.engine_fallbacks.load(Ordering::Relaxed),
        }
    }
}

/// Build the configured engine, or degrade to the always-available rust
/// engine as a **logged, typed event** (counted in
/// [`ServeStats::engine_fallbacks`]) — a misconfigured engine name must
/// not take the serving path down, but it must not be silent either.
pub(crate) fn engine_or_fallback(name: &str, workers: usize, stats: &StatsCell) -> Engine {
    match Engine::by_name(name, workers) {
        Ok(e) => e,
        Err(err) => {
            stats.engine_fallbacks.fetch_add(1, Ordering::Relaxed);
            let event = ServeEvent::EngineFallback {
                requested: name.to_string(),
                fallback: "rust".to_string(),
                error: format!("{err:#}"),
            };
            eprintln!("[serve] {event}");
            Engine::rust_with(EngineOptions {
                workers,
                ..Default::default()
            })
        }
    }
}

/// The unified model-worker loop: one thread per served model, owning
/// the engine (PJRT handles are per-thread) and draining one request
/// queue with admission batching. Returns the final stats snapshot.
pub(crate) fn run_model_worker(
    slot: Arc<ModelSlot>,
    cfg: ServeConfig,
    rx: Receiver<RowsRequest>,
    stop: Receiver<()>,
    stats: Arc<StatsCell>,
) -> ServeStats {
    let engine = engine_or_fallback(&cfg.engine, cfg.workers, &stats);
    let batcher = Batcher::new(&cfg);
    // multiclass coefficient block, stacked once per model generation
    // (not once per batch) and invalidated by hot swap
    let mut alphas_cache: Option<(u64, Mat)> = None;
    loop {
        let batch = match batcher.gather(&rx, &stop, |r: &RowsRequest| r.rows.max(1)) {
            Gathered::Batch(b) => b,
            Gathered::Idle => continue,
            Gathered::Disconnected | Gathered::Stopped => break,
        };
        // snapshot the model once per batch: every answer in this batch
        // comes from one generation even if a swap lands mid-predict
        let (model, generation) = slot.current();
        exec_batch(
            &model,
            generation,
            &engine,
            batch,
            &stats,
            &mut alphas_cache,
        );
    }
    stats.snapshot()
}

enum BatchOut {
    Scalars(Vec<f64>),
    /// rows × K multiclass score block
    Scores(Mat),
}

/// Validate, stack, predict once, fan back out.
fn exec_batch(
    model: &Arc<ServedModel>,
    generation: u64,
    engine: &Engine,
    batch: Vec<RowsRequest>,
    stats: &StatsCell,
    alphas_cache: &mut Option<(u64, Mat)>,
) {
    let d = model.d();
    // every dequeued request is counted, answered or rejected — the
    // stats must reconcile with what clients observed
    stats.requests.fetch_add(batch.len() as u64, Ordering::Relaxed);
    // validate at the queue boundary: client handles already check dims,
    // but the queue is a public boundary (the network path feeds it
    // directly) — a malformed request gets a typed error back and fails
    // alone, never panicking the stacking copy below
    let mut admitted: Vec<RowsRequest> = Vec::with_capacity(batch.len());
    let mut rows_total = 0usize;
    for r in batch {
        if r.rows == 0 || r.x.len() != r.rows * d {
            stats.rejected.fetch_add(1, Ordering::Relaxed);
            let _ = r.reply.send(Err(FaultError::fatal(format!(
                "request shape ({} floats / {} rows) != model dim {d}",
                r.x.len(),
                r.rows
            ))));
            continue;
        }
        rows_total += r.rows;
        admitted.push(r);
    }
    if admitted.is_empty() {
        return;
    }
    // stack every admitted row into one row-block
    let mut x = Mat::zeros(rows_total, d);
    let mut off = 0usize;
    for r in &admitted {
        x.data[off * d..(off + r.rows) * d].copy_from_slice(&r.x);
        off += r.rows;
    }
    // one panel-amortized predict for the whole cross-request batch; a
    // panic inside the predict path fails this batch, not the server
    let out: Result<BatchOut> =
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| match &**model {
            ServedModel::Regression(m) => m.predict(engine, &x).map(BatchOut::Scalars),
            ServedModel::Multiclass(m) => {
                if !matches!(alphas_cache, Some((g, _)) if *g == generation) {
                    *alphas_cache = Some((generation, m.alphas_mat()));
                }
                let (_, alphas) =
                    alphas_cache.get_or_insert_with(|| (generation, m.alphas_mat()));
                engine
                    .predict_multi(m.config.kernel, &x, &m.centers, alphas, m.config.sigma)
                    .map(BatchOut::Scores)
            }
        }))
        .unwrap_or_else(|p| Err(anyhow!("prediction panicked: {}", panic_msg(p.as_ref()))));
    stats.batches.fetch_add(1, Ordering::Relaxed);
    stats.rows.fetch_add(rows_total as u64, Ordering::Relaxed);
    match out {
        Ok(BatchOut::Scalars(p)) => {
            let mut off = 0usize;
            for r in admitted {
                let preds = p[off..off + r.rows].to_vec();
                off += r.rows;
                let _ = r.reply.send(Ok(RowsReply::Scalars(preds)));
            }
        }
        Ok(BatchOut::Scores(sm)) => {
            let mut off = 0usize;
            for r in admitted {
                let mut preds = Vec::with_capacity(r.rows);
                for i in off..off + r.rows {
                    let row = sm.row(i);
                    // total_cmp: NaN scores must not panic the worker
                    let class = (0..row.len())
                        .max_by(|&a, &b| row[a].total_cmp(&row[b]))
                        .unwrap_or(0);
                    preds.push(ClassPrediction {
                        class,
                        scores: row.to_vec(),
                    });
                }
                off += r.rows;
                let _ = r.reply.send(Ok(RowsReply::Classes(preds)));
            }
        }
        Err(e) => {
            let msg = e.to_string();
            for r in admitted {
                let _ = r.reply.send(Err(anyhow!("{msg}")));
            }
        }
    }
}
